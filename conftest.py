"""Repo-root pytest config.

Puts ``src/`` and ``tests/`` on ``sys.path`` (so ``python -m pytest``
works without PYTHONPATH gymnastics) and loads the recompile-guard
plugin — ``pytest_plugins`` may only be declared in the rootdir
conftest, and the pytest.ini at the repo root pins rootdir here.
"""

import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent
for _p in (_ROOT / "src", _ROOT / "tests"):
    if str(_p) not in sys.path:
        sys.path.insert(0, str(_p))

pytest_plugins = ["plugins.recompile_guard"]
