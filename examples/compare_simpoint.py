"""SimPoint (BBV) vs two-phase RFV sampling, head to head.

Reproduces the paper's central comparison on one command: for each scheme,
select 20 regions, project CPI for all 7 microarchitecture configurations,
and print the error against the full-census ground truth.

    PYTHONPATH=src python examples/compare_simpoint.py [app]
"""

import sys

import jax
import numpy as np

from repro.core.clustering import Standardizer, kmeans, random_project
from repro.core.sampling import draw_srs, select_centroid
from repro.simcpu import CONFIGS, get_bbvs, make_simulator

K = 20


def main() -> None:
    app = sys.argv[1] if len(sys.argv) > 1 else "557.xz_r"
    sim = make_simulator(app)
    pop = sim.pop
    truth = [sim.true_mean_cpi(c) for c in CONFIGS]

    # --- SimPoint: BBVs over the whole run, random projection, k-means ----
    bbv = get_bbvs(pop)
    z = np.asarray(random_project(bbv, 15, key=jax.random.PRNGKey(0)))
    km = kmeans(z, K, seed=0)
    w_bbv = np.bincount(km.labels, minlength=K) / pop.n_regions
    sel_bbv = select_centroid(km.labels, z, km.centroids)

    # --- two-phase RFV: phase-1 SRS -> RFV k-means -> centroids -----------
    rng = np.random.default_rng(0)
    idx1 = draw_srs(rng, pop.n_regions, pop.spec.phase1_n)
    _, rfv = sim.simulate_rfv(idx1, CONFIGS[0])
    _, zr = Standardizer.fit_transform(rfv)
    zr = np.asarray(zr)
    km2 = kmeans(zr, K, seed=0)
    w_rfv = np.bincount(km2.labels, minlength=K) / idx1.size
    sel_rfv = [idx1[s] for s in select_centroid(km2.labels, zr,
                                                km2.centroids)]

    print(f"{app}: per-config CPI projection error (20 regions each)")
    print(f"{'config':8s} {'truth':>7s} {'SimPoint/BBV':>14s} "
          f"{'two-phase/RFV':>14s}")
    for i, cfg in enumerate(CONFIGS):
        est_b = sum(w_bbv[h] * float(sim.simulate_cpi(sel_bbv[h], cfg)[0])
                    for h in range(K) if sel_bbv[h].size)
        est_r = sum(w_rfv[h] * float(sim.simulate_cpi(sel_rfv[h], cfg)[0])
                    for h in range(K) if sel_rfv[h].size)
        eb = 100 * abs(est_b - truth[i]) / truth[i]
        er = 100 * abs(est_r - truth[i]) / truth[i]
        print(f"config{i:2d} {truth[i]:7.3f} {est_b:7.3f} ({eb:4.1f}%) "
              f"{est_r:7.3f} ({er:4.1f}%)")


if __name__ == "__main__":
    main()
