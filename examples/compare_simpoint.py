"""SimPoint (BBV) vs two-phase RFV sampling, head to head.

Reproduces the paper's central comparison through the app-sharded sweep
engine: for each sampling plan, ONE ``run_sweep`` selects 20 regions per
app and projects CPI for all 7 microarchitecture configurations in a
single batched dispatch (sharded over an ``("app",)`` mesh when more
than one device is available). No host-side per-app or per-config loops
— the app argument may be one application or ``all`` for the full 10-app
matrix.

Designs are ``SamplingPlan`` objects (stratifier × selection policy ×
estimator): the third column swaps SimPoint's centroid policy for the
registry-provided ``RankedSetUnit`` order-statistic policy (per-stratum
median by phase-1 CPI rank, after *CPU Simulation with Ranked Set
Sampling and Repeated Subsampling*) — a plug-in that reaches the sweep
engine purely through the plan registry.

    PYTHONPATH=src python examples/compare_simpoint.py [app|all]
"""

import sys

from repro.core.sampling import (BBVClusters, Centroid, RankedSetUnit,
                                 RFVClusters, SamplingPlan)
from repro.experiments import ExperimentEngine, SweepSpec, run_sweep
from repro.simcpu import APP_NAMES, CONFIGS

PLANS = {
    "bbv": SamplingPlan(stratifier=BBVClusters(), policy=Centroid()),
    "rfv": SamplingPlan(stratifier=RFVClusters(), policy=Centroid()),
    "rfv+rank": SamplingPlan(stratifier=RFVClusters(),
                             policy=RankedSetUnit()),
}


def main() -> None:
    arg = sys.argv[1] if len(sys.argv) > 1 else "557.xz_r"
    apps = tuple(APP_NAMES) if arg == "all" else (arg,)
    engine = ExperimentEngine.auto()
    if engine.mesh is not None:
        print(f"# app axis sharded over {engine.mesh.devices.size} devices")

    # three batched sweeps: every app x config x plan estimate, served
    # through the shared region x config memo bank
    tables = {label: run_sweep(engine, SweepSpec(apps=apps, plan=plan))
              for label, plan in PLANS.items()}

    for app in apps:
        exp = engine.app(app)
        print(f"{app}: per-config CPI projection error (20 regions each)")
        print(f"{'config':8s} {'truth':>7s} {'SimPoint/BBV':>14s} "
              f"{'two-phase/RFV':>14s} {'RFV+ranked-set':>15s}")
        rows = {s: tables[s].filter(app=app) for s in tables}
        for i in range(len(CONFIGS)):
            rb = rows["bbv"].filter(config_index=i).rows[0]
            rr = rows["rfv"].filter(config_index=i).rows[0]
            rk = rows["rfv+rank"].filter(config_index=i).rows[0]
            print(f"config{i:2d} {rb.truth:7.3f} "
                  f"{rb.estimate:7.3f} ({rb.err_pct:4.1f}%) "
                  f"{rr.estimate:7.3f} ({rr.err_pct:4.1f}%) "
                  f"{rk.estimate:7.3f} ({rk.err_pct:4.1f}%)")
        print(f"simulation cost: {exp.sim.ledger.regions_simulated} region "
              f"simulations ({exp.sim.hits} cache hits)")


if __name__ == "__main__":
    main()
