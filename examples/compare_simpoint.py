"""SimPoint (BBV) vs two-phase RFV sampling, head to head.

Reproduces the paper's central comparison on one command through the
batched experiment engine: for each scheme, select 20 regions, project
CPI for all 7 microarchitecture configurations in ONE vmapped dispatch,
and print the error against the full-census ground truth.

    PYTHONPATH=src python examples/compare_simpoint.py [app]
"""

import sys

import numpy as np

from repro.experiments import ExperimentEngine, scheme_selection
from repro.simcpu import CONFIGS


def main() -> None:
    app = sys.argv[1] if len(sys.argv) > 1 else "557.xz_r"
    engine = ExperimentEngine()
    exp = engine.app(app)

    ests = {}
    for scheme in ("bbv", "rfv"):
        sel, w = scheme_selection(exp, scheme, "centroid")
        # per-config weighted estimates from ONE batched dispatch over all
        # 7 configs, served through the region x config memo table
        ests[scheme] = exp.weighted_cpi_all(sel, w)

    print(f"{app}: per-config CPI projection error (20 regions each)")
    print(f"{'config':8s} {'truth':>7s} {'SimPoint/BBV':>14s} "
          f"{'two-phase/RFV':>14s}")
    for i in range(len(CONFIGS)):
        eb = 100 * abs(ests["bbv"][i] - exp.truth[i]) / exp.truth[i]
        er = 100 * abs(ests["rfv"][i] - exp.truth[i]) / exp.truth[i]
        print(f"config{i:2d} {exp.truth[i]:7.3f} "
              f"{ests['bbv'][i]:7.3f} ({eb:4.1f}%) "
              f"{ests['rfv'][i]:7.3f} ({er:4.1f}%)")
    print(f"simulation cost: {exp.sim.ledger.regions_simulated} region "
          f"simulations ({exp.sim.hits} cache hits)")


if __name__ == "__main__":
    main()
