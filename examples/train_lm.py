"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate — sharded params, AdamW, deterministic data,
atomic checkpoints, straggler monitoring — plus the paper's technique
running inside the loop as stratified sampled evaluation.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to 60 steps so the example finishes quickly on CPU)
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import make_pipeline
from repro.distributed.ctx import activation_sharding
from repro.launch.mesh import make_host_mesh
from repro.models.registry import init_params, loss_fn
from repro.optim import AdamW, apply_updates, cosine_with_warmup
from repro.runtime.checkpoint import save_checkpoint
from repro.runtime.health import StepTimer, StragglerDetector
from repro.train.sampled_eval import SampledEval


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: a deep-narrow llama3-style config
    cfg = dataclasses.replace(
        get_config("llama3.2-3b", smoke=True),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_ff=1536,
        vocab=8192, head_dim=64)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} derivative, {n_params/1e6:.0f}M params")

    mesh = make_host_mesh()
    pipe = make_pipeline(cfg, args.seq, args.batch)
    opt = AdamW(lr=cosine_with_warmup(1e-3, 20, args.steps))
    lfn = loss_fn(cfg)

    with mesh, activation_sharding(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt.init(params)

        @jax.jit
        def step_fn(p, s, batch):
            loss, g = jax.value_and_grad(lfn)(p, batch)
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s, loss

        timer = StepTimer()
        det = StragglerDetector()
        for step in range(args.steps):
            batch = pipe.batch(step)
            t0 = time.perf_counter()
            params, opt_state, loss = step_fn(params, opt_state, batch)
            dt = time.perf_counter() - t0
            timer.record(dt)
            if step % 10 == 0:
                flag = " STRAGGLER" if det.is_straggler(timer.times, dt) \
                    else ""
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"{dt*1e3:7.1f} ms{flag}", flush=True)
        save_checkpoint(args.ckpt_dir, args.steps - 1,
                        (params, opt_state), extra={"step": args.steps - 1})

        # --- the paper's technique, in-loop: sampled eval with CI ---------
        eval_pipe = make_pipeline(cfg, args.seq, args.batch, seed=999)
        eval_loss = jax.jit(lfn)

        def eval_batch(i: int):
            b = eval_pipe.batch(i)
            loss = float(eval_loss(params, b))
            feats = np.array([loss,
                              float(np.mean(np.asarray(b["tokens"]) == 0)),
                              float(np.std(np.asarray(b["tokens"])))])
            return loss, feats

        se = SampledEval(n_batches=400, eval_batch=eval_batch,
                         num_strata=8)
        est1 = se.characterize(n_phase1=48)
        print(f"[sampled-eval] phase-1 (48 fwd): "
              f"{est1.mean:.4f} ± {est1.margin_pct:.2f}%")
        quick = se.quick_estimate()
        print(f"[sampled-eval] day-to-day (8 fwd): {quick:.4f} "
              f"(delta {100*abs(quick-est1.mean)/est1.mean:.2f}%)")
        ci = se.ci_check(per_stratum=3)
        print(f"[sampled-eval] CI-check (24 fwd): {ci.mean:.4f} "
              f"± {ci.margin_pct:.2f}%")


if __name__ == "__main__":
    main()
