"""Quickstart: the paper's two-phase stratified sampling flow, end to end.

Runs the recommended methodology (paper Fig. 14) on one synthetic SPECint
application through the app-sharded experiment engine and prints every
artifact: the phase-1 estimate, the strata, the 20-region day-to-day
estimate, its error vs ground truth, a collapsed-strata confidence
interval from those same 20 runs, and a Monte-Carlo check of the whole
scheme (``run_trials``: 200 vmapped selection trials in one dispatch).

Every simulation goes through the engine's shared ``MemoBank``: a region
is *charged* once per configuration, so re-measuring regions the flow
already paid for (e.g. re-reading phase-1 results) costs nothing — the
ledger matches the paper's "number of region simulations" cost unit
exactly.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.sampling import (Centroid, RFVClusters, SamplingPlan,
                                 Stratification, TwoPhaseFlow, srs_estimate)
from repro.experiments import (ExperimentEngine, TrialSpec, plan_selection,
                               run_trials)

APP = "502.gcc_r"          # the paper's hardest application
NUM_STRATA = 20


def main() -> None:
    engine = ExperimentEngine()
    # ONE stacked build: census truth, phase-1 SRS, BBV/RFV/DG strata.
    # (Add more app names — or mesh=make_app_mesh() — and the same call
    # builds them all batched over the app axis.)
    exp = engine.app(APP)
    ledger = exp.sim.ledger
    true0, true6 = float(exp.truth[0]), float(exp.truth[6])

    # Step 1 — initial characterization: large SRS on the baseline config
    # (measured — and charged — during the engine build).
    est1 = srs_estimate(exp.cpi0_1)
    print(f"[1] phase-1: n={exp.idx1.size} regions,  "
          f"CPI = {est1.mean:.3f} ± {est1.margin_pct:.2f}%  "
          f"(true {true0:.3f})")

    # Steps 2+3 — stratify on RFVs, pick centroids: one SamplingPlan.
    plan = SamplingPlan(stratifier=RFVClusters(), policy=Centroid())
    selected, weights = plan_selection(exp, plan)
    print(f"[2] stratified into {exp.num_strata} strata, "
          f"weights {np.round(np.sort(weights)[-3:], 3)} (top 3)")

    # Step 3 self-check: estimate the baseline from the 20 regions. These
    # were already simulated on config 0 in phase 1, so the memo bank
    # serves them for free — watch the ledger stand still.
    before = ledger.regions_simulated
    est0 = float(exp.weighted_cpi_all(selected, weights,
                                      config_indices=(0,))[0])
    err0 = 100 * abs(est0 - true0) / true0
    print(f"[3] 20-region estimate of baseline: {est0:.3f} "
          f"(error {err0:.2f}% vs phase-1/census; "
          f"{ledger.regions_simulated - before} new simulations — "
          "cache hits are free)")

    # Step 4a — day-to-day study of a NEW configuration (Config 6).
    before = ledger.regions_simulated
    est6 = float(exp.weighted_cpi_all(selected, weights,
                                      config_indices=(6,))[0])
    cost = ledger.regions_simulated - before
    print(f"[4a] Config-6 estimate from {cost} simulations: {est6:.3f} "
          f"(true {true6:.3f}, error {100*abs(est6-true6)/true6:.2f}%)")

    # ... with a practical CI from the same 20 runs (collapsed strata).
    # Empty strata (possible for some app/seed pairs) are dropped from
    # values, weights, and ordering consistently, weights renormalized.
    # Config 6 for these regions is now memoized: zero additional cost.
    from repro.core.sampling import collapsed_strata_estimate
    from repro.simcpu import CONFIGS
    before = ledger.regions_simulated
    occupied = [h for h, s in enumerate(selected) if s.size]
    y_h = np.array([float(exp.sim.simulate_cpi(selected[h], CONFIGS[6])[0])
                    for h in occupied])
    w_h = weights[occupied] / weights[occupied].sum()
    order = np.array([exp.cpi0_1[exp.rfv_labels == h].mean()
                      for h in occupied])
    ci = collapsed_strata_estimate(y_h, w_h, order_by=order)
    print(f"     collapsed-strata 95% CI: ±{ci.margin_pct:.1f}%  "
          f"covers truth: {ci.covers(true6)}  "
          f"({ledger.regions_simulated - before} new simulations)")

    # Step 4b — periodic multi-unit CI check (tight, ~10x cheaper than SRS).
    # The flow's CI machinery runs directly off the engine's artifacts
    # (it collapses under-sampled strata itself).
    strat = Stratification(
        labels=exp.rfv_labels, weights=weights,
        centroids=exp.rfv_centroids, features=exp.rfv_z,
        phase1_indices=exp.idx1, phase1_baseline_y=exp.cpi0_1, scheme="rfv")
    flow = TwoPhaseFlow(population_size=exp.sim.pop.n_regions,
                        rng=np.random.default_rng(0))
    before = ledger.regions_simulated
    est_ci = flow.ci_check(strat,
                           lambda i: exp.sim.simulate_cpi(i, CONFIGS[6]),
                           per_stratum_sizes=np.full(NUM_STRATA, 8))
    cost = ledger.regions_simulated - before
    print(f"[4b] CI-check from {cost} simulations: {est_ci.mean:.3f} "
          f"± {est_ci.margin_pct:.2f}%  covers truth: "
          f"{est_ci.covers(true6)}")

    print(f"total simulation budget spent: {ledger.regions_simulated} "
          f"regions ({ledger.instructions_simulated/1e9:.1f} B instructions; "
          f"{exp.sim.hits} cache hits avoided re-simulation)")

    # Step 5 — Monte-Carlo sanity check of the whole design: 200 random-
    # selection trials per scheme folded into vmapped (trial, stratum)
    # axes — ONE dispatch per scheme, no Python trial loops. (The rfv/dg
    # pools re-measure the phase-1 sample on Config 6, charged once.)
    before = ledger.regions_simulated
    mc = run_trials(engine, TrialSpec(trials=200), apps=(APP,))
    p95 = {s: float(mc.p95(s)[0]) for s in mc.errors}
    print(f"[5] Monte-Carlo p95 |error| over 200 trials "
          f"(+{ledger.regions_simulated - before} simulations):  "
          f"random {p95['random']:.1f}%  bbv {p95['bbv']:.1f}%  "
          f"rfv {p95['rfv']:.1f}%  dg {p95['dg']:.1f}%")


if __name__ == "__main__":
    main()
