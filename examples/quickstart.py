"""Quickstart: the paper's two-phase stratified sampling flow, end to end.

Runs the recommended methodology (paper Fig. 14) on one synthetic SPECint
application and prints every artifact: the phase-1 estimate, the strata,
the 20-region day-to-day estimate, its error vs ground truth, and a
collapsed-strata confidence interval computed from those same 20 runs.

The simulator is wrapped in ``CachedSimulator``: a region is *charged*
once per configuration, so re-measuring regions the flow already paid for
(e.g. re-reading phase-1 results) costs nothing — the ledger matches the
paper's "number of region simulations" cost unit exactly.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.sampling import TwoPhaseFlow
from repro.simcpu import CONFIGS, Ledger, make_cached_simulator

APP = "502.gcc_r"          # the paper's hardest application
NUM_STRATA = 20


def main() -> None:
    ledger = Ledger()
    sim = make_cached_simulator(APP, ledger=ledger)
    flow = TwoPhaseFlow(population_size=sim.pop.n_regions,
                        rng=np.random.default_rng(0))

    # Step 1 — initial characterization: large SRS on the baseline config.
    idx1, cpi0, rfv, est1 = flow.characterize(
        lambda idx: sim.simulate_rfv(idx, CONFIGS[0]),
        n_phase1=sim.pop.spec.phase1_n)
    print(f"[1] phase-1: n={idx1.size} regions,  "
          f"CPI = {est1.mean:.3f} ± {est1.margin_pct:.2f}%  "
          f"(true {sim.true_mean_cpi(CONFIGS[0]):.3f})")

    # Steps 2+3 — stratify on RFVs, pick centroids.
    strat = flow.stratify(idx1, cpi0, rfv, num_strata=NUM_STRATA,
                          scheme="rfv")
    selected = flow.select(strat, policy="centroid")
    print(f"[2] stratified into {strat.num_strata} strata, "
          f"weights {np.round(np.sort(strat.weights)[-3:], 3)} (top 3)")

    # Step 3 self-check: estimate the baseline from the 20 regions.
    # These regions were already simulated on config 0 in phase 1, so the
    # memoizing cache serves them for free — watch the ledger stand still.
    before = ledger.regions_simulated
    est0 = flow.point_estimate(
        strat, selected, lambda i: sim.simulate_cpi(i, CONFIGS[0]))
    err0 = 100 * abs(est0 - sim.true_mean_cpi(CONFIGS[0])) \
        / sim.true_mean_cpi(CONFIGS[0])
    print(f"[3] 20-region estimate of baseline: {est0:.3f} "
          f"(error {err0:.2f}% vs phase-1/census; "
          f"{ledger.regions_simulated - before} new simulations — "
          "cache hits are free)")

    # Step 4a — day-to-day study of a NEW configuration (Config 6).
    before = ledger.regions_simulated
    est6 = flow.point_estimate(
        strat, selected, lambda i: sim.simulate_cpi(i, CONFIGS[6]))
    cost = ledger.regions_simulated - before
    true6 = sim.true_mean_cpi(CONFIGS[6])
    print(f"[4a] Config-6 estimate from {cost} simulations: {est6:.3f} "
          f"(true {true6:.3f}, error {100*abs(est6-true6)/true6:.2f}%)")

    # ... with a practical CI from the same 20 runs (collapsed strata).
    # Config 6 for these regions is now memoized: zero additional cost.
    before = ledger.regions_simulated
    ci = flow.collapsed_ci(strat, selected,
                           lambda i: sim.simulate_cpi(i, CONFIGS[6]))
    print(f"     collapsed-strata 95% CI: ±{ci.margin_pct:.1f}%  "
          f"covers truth: {ci.covers(true6)}  "
          f"({ledger.regions_simulated - before} new simulations)")

    # Step 4b — periodic multi-unit CI check (tight, ~10x cheaper than SRS).
    before = ledger.regions_simulated
    est_ci = flow.ci_check(strat,
                           lambda i: sim.simulate_cpi(i, CONFIGS[6]),
                           per_stratum_sizes=np.full(NUM_STRATA, 8))
    cost = ledger.regions_simulated - before
    print(f"[4b] CI-check from {cost} simulations: {est_ci.mean:.3f} "
          f"± {est_ci.margin_pct:.2f}%  covers truth: "
          f"{est_ci.covers(true6)}")
    print(f"total simulation budget spent: {ledger.regions_simulated} "
          f"regions ({ledger.instructions_simulated/1e9:.1f} B instructions; "
          f"{sim.hits} cache hits avoided re-simulation)")


if __name__ == "__main__":
    main()
