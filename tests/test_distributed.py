"""Sharding rules / mesh / distributed-clustering tests (host mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        opt_state_specs, param_specs)
from repro.launch.mesh import data_axes, make_host_mesh
from repro.models.registry import init_params, make_decode_state


class _FakeMesh:
    """Shape-only mesh stand-in for spec-rule tests (no devices needed)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})
MESH_MULTI = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_specs_dense_rules():
    cfg = get_config("llama3.2-3b")
    params = init_params(cfg, abstract=True)
    specs = param_specs(params, MESH)
    assert specs["embed"] == P("model", "data")
    assert specs["lm_head"] == P("data", "model")
    lay = specs["layers"]
    assert lay["attn"]["wq"] == P(None, "data", "model")
    assert lay["attn"]["wo"] == P(None, "model", "data")
    assert lay["ffn"]["w_gate"] == P(None, "data", "model")
    assert lay["ffn"]["w_down"] == P(None, "model", "data")
    assert lay["ln1"] == P()


def test_param_specs_moe_expert_sharding():
    cfg = get_config("qwen3-moe-235b-a22b")
    params = init_params(cfg, abstract=True)
    specs = param_specs(params, MESH)
    lay = specs["layers"]
    assert lay["ffn"]["w_gate"] == P(None, "model", "data", None)
    # router replicated (no sharded axes)
    assert all(a is None for a in tuple(lay["ffn"]["router"]))


def test_param_specs_divisibility_fallback():
    """Dims not divisible by an axis are replicated, never mis-sharded."""
    cfg = get_config("recurrentgemma-2b")   # 10 heads, kv=1
    params = init_params(cfg, abstract=True)
    specs = param_specs(params, MESH)
    sup = specs["supers"]
    # wk: (L, d, 1*256) -> 256 divisible by 16 => sharded on flat dim
    assert sup["attn"]["attn"]["wk"][-1] == "model"
    # lam: (L, 2560) with model=16 divides 2560
    assert sup["r0"]["rglru"]["lam"] == P(None, "model")


def test_opt_state_specs_add_dp_only_once():
    cfg = get_config("llama3.2-3b")
    params = init_params(cfg, abstract=True)
    o = opt_state_specs(params, MESH)
    flat = jax.tree_util.tree_leaves(
        o, is_leaf=lambda x: isinstance(x, P))
    for spec in flat:
        axes = [a for part in spec for a in
                (part if isinstance(part, tuple) else (part,))
                if a is not None]
        assert len(axes) == len(set(axes)), spec  # no duplicate mesh axes


def test_batch_and_cache_specs():
    cfg = get_config("llama3.2-3b")
    b = batch_specs(cfg, MESH_MULTI, "train")
    assert b["tokens"] == P(("pod", "data"), None)
    caches = make_decode_state(cfg, 128, 32768, abstract=True)
    cs = cache_specs(cfg, caches, MESH)
    k_spec = cs.kv[0]
    assert k_spec[1] == "data"      # batch dim
    assert "model" in tuple(k_spec)  # long seq dim sharded


def test_data_axes_helper():
    assert data_axes(MESH_MULTI) == ("pod", "data")
    assert data_axes(MESH) == ("data",)


def test_distributed_kmeans_matches_quality():
    from repro.core.clustering import kmeans
    from repro.core.clustering.distributed import distributed_kmeans
    rng = np.random.default_rng(0)
    x = np.concatenate([rng.normal(4.0 * i, 0.3, (400, 8))
                        for i in range(4)]).astype(np.float32)
    mesh = make_host_mesh()
    _, labels, inertia = distributed_kmeans(x, 4, mesh, iters=20)
    ref = kmeans(x, 4, seed=0)
    assert inertia <= ref.inertia * 1.3
    labels = np.asarray(labels).reshape(4, 400)
    for i in range(4):
        assert len(np.unique(labels[i])) == 1


def test_activation_constrain_noop_off_mesh():
    from repro.distributed.ctx import constrain
    x = jnp.ones((4, 8, 16))
    y = constrain(x, "bsd")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_grad_compression_error_feedback():
    from repro.optim import AdamW, Int8EF, apply_updates
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    opt = AdamW(lr=5e-2, weight_decay=0.0, compress=Int8EF())
    state = opt.init(params)
    assert state.ef is not None

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    losses = []
    for _ in range(80):
        g = jax.grad(loss)(params)
        u, state = opt.update(g, state, params)
        params = apply_updates(params, u)
        losses.append(float(loss(params)))
    assert losses[-1] < losses[0] * 0.1   # converges despite int8 grads
