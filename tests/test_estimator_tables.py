"""Array-native estimator engine: batched-vs-scalar parity, lane-wise NaN
semantics, collapse equivalence, CI coverage calibration, and the
segment_stats-backed stratum-summary dispatch contract."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sampling import (collapsed_strata_estimate, critical_values,
                                 neyman_allocation, proportional_allocation,
                                 stratified_mean, stratified_variance,
                                 satterthwaite_df, summarize_strata,
                                 two_phase_estimate)
from repro.core.sampling import tables as T
from repro.kernels.backend import (BackendFallbackWarning,
                                   reset_backend_warnings)
from repro.kernels.segment_stats import ops as seg_ops

RNG = np.random.default_rng(42)


def _random_design(n, L, rng, *, empty=()):
    """Sampled values + labels with the strata in ``empty`` unpopulated."""
    pop = [h for h in range(L) if h not in empty]
    labels = rng.choice(pop, size=n)
    y = rng.normal(2.0, 1.0, n) + 0.5 * labels
    weights = np.full(L, 1.0 / L)
    return y, labels, weights


# ------------------------------------------------------- scalar one-lane parity
@pytest.mark.parametrize("n,L", [(200, 5), (37, 3), (500, 20), (10, 1)])
def test_one_lane_matches_scalar_reference(n, L):
    """Batched estimators on a single lane == the scalar reference
    (rtol <= 1e-6 — the acceptance bar; float64 path is ~bitwise)."""
    rng = np.random.default_rng(n * L)
    y, labels, w = _random_design(n, L, rng)
    summ = summarize_strata(y, labels, weights=w, num_strata=L)
    t = T.stratum_tables(y, labels, weights=w, num_strata=L)
    assert float(T.stratified_mean(t)) == pytest.approx(
        stratified_mean(summ), rel=1e-6)
    assert float(T.stratified_variance(t)) == pytest.approx(
        stratified_variance(summ), rel=1e-6)
    assert float(T.satterthwaite_df(t)) == pytest.approx(
        satterthwaite_df(summ), rel=1e-6)
    for formula, kw in (("phase2_only", {}),
                        ("with_phase1_var", {"phase1_var": 2.5})):
        est = two_phase_estimate(summ, phase1_n=100, formula=formula, **kw)
        assert float(T.two_phase_variance(t, 100, formula=formula, **kw)) \
            == pytest.approx(est.variance, rel=1e-6)


def test_ragged_lanes_match_per_lane_scalar():
    """(A, T) batch of ragged designs == a per-lane scalar loop."""
    L, n = 6, 120
    rng = np.random.default_rng(0)
    Y = rng.normal(0, 1, (3, 4, n))
    LAB = rng.integers(0, L, (3, 4, n))
    t = T.stratum_tables(Y, LAB, num_strata=L)
    assert t.batch_shape == (3, 4)
    mb, vb, db = (T.stratified_mean(t), T.stratified_variance(t),
                  T.satterthwaite_df(t))
    tpb = T.two_phase_variance(t, 64)
    for a in range(3):
        for j in range(4):
            summ = summarize_strata(Y[a, j], LAB[a, j], num_strata=L)
            assert mb[a, j] == pytest.approx(stratified_mean(summ),
                                             rel=1e-6)
            assert vb[a, j] == pytest.approx(stratified_variance(summ),
                                             rel=1e-6)
            assert db[a, j] == pytest.approx(satterthwaite_df(summ),
                                             rel=1e-6)
            est = two_phase_estimate(summ, phase1_n=64)
            assert tpb[a, j] == pytest.approx(est.variance, rel=1e-6)


def test_empty_stratum_lane_nan_and_renormalization():
    """Lanes with an uncovered positive-weight stratum renormalize (the
    coverage contract); all-empty lanes are NaN — never an exception."""
    L = 4
    rng = np.random.default_rng(1)
    y, labels, w = _random_design(300, L, rng, empty=(2,))
    t = T.stratum_tables(y, labels, weights=w, num_strata=L)
    covered = float(T.covered_weight(t))
    assert covered == pytest.approx(0.75)
    # renormalized mean equals the weighted mean over covered strata
    man = sum(w[h] * y[labels == h].mean() for h in (0, 1, 3)) / covered
    assert float(T.stratified_mean(t)) == pytest.approx(man, rel=1e-12)
    # fully empty lane
    t0 = T.StratumTables(counts=np.zeros(L), sums=np.zeros(L),
                         sumsqs=np.zeros(L), weights=w)
    assert np.isnan(T.stratified_mean(t0))
    assert np.isnan(T.stratified_variance(t0))


def test_single_unit_stratum_lane_nan():
    """n_h == 1 in a covered stratum makes the lane variance NaN (the
    scalar view raises instead — strict contract)."""
    y = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    labels = np.array([0, 0, 1, 1, 2])
    t = T.stratum_tables(y, labels, num_strata=3)
    assert np.isnan(T.stratified_variance(t))
    assert np.isfinite(T.stratified_mean(t))
    with pytest.raises(ValueError, match="n_h >= 2"):
        stratified_variance(summarize_strata(y, labels, num_strata=3))


# ----------------------------------------------------------- collapsed strata
@pytest.mark.parametrize("L", [2, 3, 4, 7, 20])
def test_collapsed_pairs_matches_scalar(L):
    rng = np.random.default_rng(L)
    y = rng.normal(size=L)
    w = rng.dirichlet(np.ones(L))
    key = rng.normal(size=L)
    est = collapsed_strata_estimate(y, w, order_by=key)
    order = np.argsort(key, kind="stable")
    var, df = T.collapsed_pairs_variance(y[order], w[order], L,
                                         num_strata=L)
    assert float(var) == pytest.approx(est.variance, rel=1e-6)
    assert float(max(df, 1.0)) == est.df


def test_collapsed_pairs_batched_lanes():
    """(A, T) value lanes against per-lane scalar estimates."""
    L, A, Tn = 9, 2, 5
    rng = np.random.default_rng(3)
    w = rng.dirichlet(np.ones(L), size=A)                  # (A, L)
    key = rng.normal(size=(A, L))
    y = rng.normal(size=(A, Tn, L))
    order = np.argsort(key, axis=-1, kind="stable")
    y_s = np.take_along_axis(y, order[:, None, :], axis=2)
    w_s = np.take_along_axis(w, order, axis=1)
    var, df = T.collapsed_pairs_variance(
        y_s, w_s[:, None, :], np.full((A, 1), L), num_strata=L)
    for a in range(A):
        for t in range(Tn):
            est = collapsed_strata_estimate(y[a, t], w[a],
                                            order_by=key[a])
            assert var[a, t] == pytest.approx(est.variance, rel=1e-6)


def test_collapsed_missing_stratum_contract():
    """NaN stratum values follow the coverage contract: warn + drop +
    renormalize by default, raise under strict=True."""
    y = np.array([1.0, np.nan, 3.0, 4.0])
    w = np.full(4, 0.25)
    with pytest.warns(UserWarning, match="cover only"):
        est = collapsed_strata_estimate(y, w)
    assert est.n == 3
    assert est.mean == pytest.approx(np.nanmean([1.0, 3.0, 4.0]))
    # the variance renormalizes consistently with the mean (W_h/covered,
    # so ×1/covered² per pair term) — else the CI is too narrow for the
    # renormalized estimate it brackets
    valid = np.array([1.0, 3.0, 4.0])
    w_eff = np.full(3, 0.25) / 0.75
    var_ref, _ = T.collapsed_pairs_variance(valid, w_eff, 3, num_strata=3)
    assert est.variance == pytest.approx(float(var_ref), rel=1e-12)
    with pytest.raises(ValueError, match="cover only"):
        collapsed_strata_estimate(y, w, strict=True)


def _scalar_collapse_groups(counts, key, active, min_count=2):
    """The ci_check backtracking merge, as an independent reference."""
    order = [h for h in np.argsort(np.where(active, key, np.inf),
                                   kind="stable") if active[h]]
    groups = [[h] for h in order]
    g = 0
    while g < len(groups):
        tot = sum(counts[h] for h in groups[g])
        if tot >= min_count or len(groups) == 1:
            g += 1
            continue
        into = g - 1 if g > 0 else g + 1
        groups[into] = groups[into] + groups[g]
        del groups[g]
        g = max(g - 1, 0)
    return groups


@pytest.mark.parametrize("seed", range(8))
def test_collapse_small_strata_matches_scalar_merge(seed):
    """Lane-wise collapse reproduces the scalar backtracking merge on
    random count patterns (incl. boundary cases via small counts)."""
    rng = np.random.default_rng(seed)
    L = 8
    counts = rng.integers(0, 4, L).astype(np.float64)
    if counts.sum() < 2:
        counts[0] = 2.0
    key = rng.normal(size=L)
    w = np.where(counts > 0, 1.0, 0.0)
    w = w / max(w.sum(), 1.0)
    tbl = T.StratumTables(counts=counts, sums=counts * 1.5,
                          sumsqs=counts * 3.0, weights=w)
    merged, group_of, n_groups = T.collapse_small_strata(tbl, key)
    active = (w > 0) | (counts > 0)
    ref_groups = _scalar_collapse_groups(counts, key, active)
    assert int(n_groups) == len(ref_groups)
    # same partition: strata sharing a reference group share a group id
    for g in ref_groups:
        ids = {int(group_of[h]) for h in g}
        assert len(ids) == 1
    # merged counts per group match
    got = sorted(float(c) for c in merged.counts[:int(n_groups)])
    want = sorted(sum(counts[h] for h in g) for g in ref_groups)
    assert got == pytest.approx(want)


def test_large_mean_variance_no_cancellation():
    """Shifted moments: a huge common mean must not annihilate a tiny
    variance (regression — raw sumsq − n·mean² lost it entirely)."""
    rng = np.random.default_rng(9)
    base = 1e7
    y = base + rng.normal(0, 1e-2, 400)
    labels = rng.integers(0, 4, 400)
    t = T.stratum_tables(y, labels, num_strata=4)
    v_ref = stratified_variance(summarize_strata(y, labels, num_strata=4))
    assert float(T.stratified_variance(t)) == pytest.approx(v_ref, rel=1e-6)
    assert v_ref > 0
    # per-stratum variances match the two-pass reference
    for h in range(4):
        seg = y[labels == h]
        assert float(t.variances[h]) == pytest.approx(seg.var(ddof=1),
                                                      rel=1e-6)
    # and the scalar bridge (summaries -> tables) keeps them too
    tb = T.tables_from_summaries(summarize_strata(y, labels, num_strata=4))
    np.testing.assert_allclose(tb.variances, t.variances, rtol=1e-9)


def test_device_path_centers_moments_too():
    """The jnp/kernel constructor also shifts its moments: float32 raw
    sumsqs at |ȳ| ≫ s would have no significant bits left."""
    rng = np.random.default_rng(11)
    y = (1e4 + rng.normal(0, 0.01, (2, 500))).astype(np.float32)
    lab = rng.integers(0, 4, (2, 500))
    t_dev = T.stratum_tables(jnp.asarray(y), jnp.asarray(lab),
                             num_strata=4, backend="jnp")
    t_host = T.stratum_tables(y.astype(np.float64), lab, num_strata=4)
    np.testing.assert_allclose(np.asarray(t_dev.variances),
                               t_host.variances, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(t_dev.means), t_host.means,
                               rtol=1e-6)


def test_masked_rows_with_nan_values_contribute_nothing():
    """Label -1 (or >= k) rows must contribute nothing even when their
    value is NaN — 0·NaN poisoning would NaN every segment of the lane,
    on both the kernel and the oracle path."""
    x = np.array([[np.nan, 1.0, 2.0]], np.float32)
    labels = np.array([[-1, 0, 1]], np.int32)
    for backend in ("jnp", "pallas"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", BackendFallbackWarning)
            sums, sumsq, counts = seg_ops.segment_stats(
                x, labels, 2, backend=backend)
        np.testing.assert_allclose(np.asarray(sums)[0, :, 0], [1.0, 2.0],
                                   err_msg=backend)
        np.testing.assert_allclose(np.asarray(sumsq)[0, :, 0], [1.0, 4.0],
                                   err_msg=backend)
        np.testing.assert_allclose(np.asarray(counts)[0], [1, 1])
    # out-of-range + NaN is dropped too
    x2 = np.array([[np.nan, 1.0]], np.float32)
    lab2 = np.array([[5, 0]], np.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        s, q, c = seg_ops.segment_stats(x2, lab2, 2, backend="pallas")
    assert np.isfinite(np.asarray(s)).all()
    np.testing.assert_allclose(np.asarray(c)[0], [1, 0])


def test_out_of_range_labels_do_not_bleed_across_lanes():
    """Labels >= num_segments are dropped — in the oracle as in the
    kernel — instead of contaminating the next lane's segment 0."""
    from repro.kernels.segment_stats.ref import segment_stats_ref

    x = np.array([[1.0, 10.0, 100.0], [5.0, 6.0, 7.0]], np.float32)
    labels = np.array([[0, 1, 2], [0, 0, 1]], np.int32)   # 2 >= k
    sums, _, counts = segment_stats_ref(x, labels, 2)
    np.testing.assert_allclose(np.asarray(counts), [[1, 1], [2, 1]])
    np.testing.assert_allclose(np.asarray(sums)[..., 0],
                               [[1, 10], [11, 7]])
    # host constructor, same contract when validation is off
    t = T.stratum_tables(x.astype(np.float64), labels, num_strata=2,
                         validate=False)
    np.testing.assert_allclose(t.counts, [[1, 1], [2, 1]])


# ----------------------------------------------------------------- allocation
def test_batched_allocation_matches_scalar():
    w = np.array([[0.5, 0.3, 0.2], [0.1, 0.1, 0.8]])
    s = np.array([[1.0, 4.0, 0.1], [0.0, 0.0, 0.0]])
    prop_b = T.proportional_allocation(w, 100)
    ney_b = T.neyman_allocation(w, s, 100)
    for a in range(2):
        np.testing.assert_array_equal(prop_b[a],
                                      proportional_allocation(w[a], 100))
        np.testing.assert_array_equal(ney_b[a],
                                      neyman_allocation(w[a], s[a], 100))


# ------------------------------------------------------------ jit / pytree use
def test_tables_pytree_through_jit():
    """StratumTables crosses jit; the same estimator code runs on device
    arrays and matches the float64 host path."""
    L, n = 5, 400
    y = RNG.normal(3.0, 1.0, (2, n)).astype(np.float32)
    labels = RNG.integers(0, L, (2, n))

    @jax.jit
    def device_mean_var(yj, labj):
        t = T.stratum_tables(yj, labj, num_strata=L, backend="jnp")
        return T.stratified_mean(t), T.two_phase_variance(t, 100)

    m_dev, v_dev = device_mean_var(jnp.asarray(y), jnp.asarray(labels))
    t_host = T.stratum_tables(y, labels, num_strata=L)
    np.testing.assert_allclose(np.asarray(m_dev),
                               T.stratified_mean(t_host), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(v_dev),
                               T.two_phase_variance(t_host, 100), rtol=1e-3)


# ------------------------------------------------------- CI coverage sanity
def test_two_phase_ci_coverage_calibrated():
    """Nominal 95% two-phase CIs cover the truth >= ~90% over 1000
    batched trials on synthetic stratified data (one program, no loop)."""
    rng = np.random.default_rng(7)
    L, per, trials, n_h = 8, 500, 1000, 5
    pop = rng.normal(0, 1, (L, per)) + 3.0 * np.arange(L)[:, None]
    truth = pop.mean()
    weights = np.full(L, 1.0 / L)
    # (T, L, n_h) stratified draws -> (T, L*n_h) sample lanes
    picks = rng.integers(0, per, (trials, L, n_h))
    y = np.take_along_axis(pop[None], picks, axis=2)       # (T, L, n_h)
    labels = np.broadcast_to(np.arange(L)[None, :, None],
                             y.shape)
    t = T.stratum_tables(y.reshape(trials, -1),
                         labels.reshape(trials, -1),
                         weights=weights, num_strata=L)
    mean = T.stratified_mean(t)
    var = T.two_phase_variance(t, phase1_n=10_000)
    df = T.satterthwaite_df(t)
    crit = critical_values(0.95, df)
    half = crit * np.sqrt(var)
    coverage = (np.abs(mean - truth) <= half).mean()
    assert 0.90 <= coverage <= 1.0, coverage


# ------------------------------------- segment_stats dispatch-marker contract
def test_stratum_summary_path_dispatches_kernel_batch_native():
    """The stratum-summary path must feed leading axes to the kernel's
    batch grid natively: a vmap-of-pallas_call would strip them and
    record batch_shape == ()."""
    A, Tn, n, L = 2, 3, 600, 5
    y = RNG.normal(size=(A, Tn, n)).astype(np.float32)
    labels = RNG.integers(0, L, (A, Tn, n)).astype(np.int32)
    seg_ops._reset_dispatch_record()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        t = T.stratum_tables(y, labels, num_strata=L, backend="pallas")
    rec = seg_ops.last_dispatch()
    assert rec is not None, "pallas kernel never dispatched"
    assert rec["batch"] == A * Tn
    assert rec["batch_shape"] == (A, Tn)
    assert rec["grid"][0] == A * Tn
    # parity of the kernel-built tables vs the float64 host path (the
    # host path centers its moments, so compare the shift-independent
    # derived statistics, not raw sums)
    t_ref = T.stratum_tables(y, labels, num_strata=L)
    np.testing.assert_allclose(np.asarray(t.counts), t_ref.counts)
    np.testing.assert_allclose(np.asarray(t.means), t_ref.means,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(t.variances), t_ref.variances,
                               rtol=1e-3, atol=1e-3)


def test_auto_backend_falls_back_with_one_warning_off_tpu():
    if jax.default_backend() == "tpu":
        pytest.skip("fallback contract is for non-TPU hosts")
    reset_backend_warnings()
    x = RNG.normal(size=(2, 300)).astype(np.float32)
    lab = RNG.integers(0, 4, (2, 300)).astype(np.int32)
    seg_ops._reset_dispatch_record()
    with pytest.warns(BackendFallbackWarning, match="platform="):
        seg_ops.segment_stats(x, lab, 4)
    # the oracle served the call: no kernel dispatch was recorded
    assert seg_ops.last_dispatch() is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # second call must be silent
        seg_ops.segment_stats(x, lab, 4)


def test_engine_summarization_routes_through_segment_stats():
    """engine._offset_bincount == the historic numpy bincount, via the
    batched segment_stats path."""
    from repro.experiments.engine import _offset_bincount
    A, n, L = 3, 500, 7
    labels = RNG.integers(0, L, (A, n))
    valid = RNG.random((A, n)) > 0.2
    vals = RNG.normal(size=(A, n))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        counts = _offset_bincount(labels, valid, L)
        sums = _offset_bincount(labels, valid, L, weights=vals)
    off = labels + L * np.arange(A)[:, None]
    ref_c = np.bincount(off[valid].ravel(), minlength=A * L).reshape(A, L)
    ref_s = np.bincount(off[valid].ravel(), weights=vals[valid].ravel(),
                        minlength=A * L).reshape(A, L)
    np.testing.assert_array_equal(counts, ref_c)
    np.testing.assert_allclose(sums, ref_s, rtol=1e-5, atol=1e-5)
