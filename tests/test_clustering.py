"""k-means / projection / standardizer tests."""

import jax
import numpy as np
import pytest

from repro.core.clustering import (Standardizer, best_of, kmeans,
                                   kmeans_multi_seed, random_project)


def _blobs(n_per, k, d, seed=0):
    rng = np.random.default_rng(seed)
    return np.concatenate([rng.normal(4.0 * i, 0.3, (n_per, d))
                           for i in range(k)])


def test_kmeans_recovers_separated_blobs():
    x = _blobs(100, 4, 6)
    km = kmeans(x, 4, seed=0, restarts=4)
    # each true blob maps to exactly one cluster
    labels = km.labels.reshape(4, 100)
    for i in range(4):
        assert len(np.unique(labels[i])) == 1
    assert km.inertia < 4 * 100 * 6 * 0.5


def test_kmeans_centroid_is_mean_of_members():
    x = _blobs(50, 3, 4)
    km = kmeans(x, 3, seed=1)
    for h in range(3):
        m = km.labels == h
        np.testing.assert_allclose(km.centroids[h], x[m].mean(0), atol=1e-3)


def test_kmeans_inertia_decreases_with_k():
    x = _blobs(80, 5, 5, seed=2)
    inertias = [kmeans(x, k, seed=0).inertia for k in (2, 5, 10)]
    assert inertias[0] > inertias[1] > inertias[2]


def test_kmeans_pallas_backend_matches_jnp():
    x = _blobs(60, 3, 5, seed=3)
    a = kmeans(x, 3, seed=0, backend="jnp")
    b = kmeans(x, 3, seed=0, backend="pallas")
    assert (a.labels == b.labels).mean() > 0.99
    np.testing.assert_allclose(a.inertia, b.inertia, rtol=1e-4)


def test_multi_seed_best_of():
    x = _blobs(40, 4, 4, seed=4)
    results = kmeans_multi_seed(x, 4, seeds=range(5))
    best = best_of(results)
    assert best.inertia == min(r.inertia for r in results)


def test_standardizer_zero_mean_unit_var():
    rng = np.random.default_rng(5)
    x = rng.normal(3, 7, (500, 4))
    x[:, 2] = 1.234                   # constant column
    st, z = Standardizer.fit_transform(x)
    z = np.asarray(z)
    np.testing.assert_allclose(z.mean(0), 0, atol=1e-6)
    np.testing.assert_allclose(z[:, [0, 1, 3]].std(0), 1, atol=1e-3)
    assert np.all(z[:, 2] == 0)      # constant -> 0, not NaN


def test_random_projection_separates_clusters():
    """JL property on structured data: projected blobs remain separable
    (within-blob distances << across-blob distances)."""
    rng = np.random.default_rng(6)
    base = rng.normal(size=(4, 500)).astype(np.float32) * 5
    x = np.concatenate([base[i] + rng.normal(0, 0.2, (20, 500))
                        for i in range(4)]).astype(np.float32)
    z = np.asarray(random_project(x, 32, key=jax.random.PRNGKey(0),
                                  normalize_rows=False))
    z = z.reshape(4, 20, 32)
    within = max(np.linalg.norm(z[i] - z[i].mean(0), axis=-1).max()
                 for i in range(4))
    centers = z.mean(1)
    across = min(np.linalg.norm(centers[i] - centers[j])
                 for i in range(4) for j in range(i + 1, 4))
    assert across > 3 * within


def test_kmeans_invalid_k():
    x = _blobs(10, 2, 3)
    with pytest.raises(ValueError):
        kmeans(x, 0)
    with pytest.raises(ValueError):
        kmeans(x, 100)
