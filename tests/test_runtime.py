"""Checkpoint / elastic / health runtime tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)
from repro.runtime.elastic import build_mesh, plan_mesh, reshard
from repro.runtime.health import (StepTimer, StragglerDetector,
                                  one_per_stratum_steptime_ci,
                                  stratified_steptime_estimate)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree, extra={"step": 7})
    restored, extra = restore_checkpoint(tmp_path, tree)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.asarray(restored["a"]))
    np.testing.assert_array_equal(np.asarray(tree["nested"]["b"]),
                                  np.asarray(restored["nested"]["b"]))


def test_checkpoint_retention_and_latest(tmp_path):
    tree = _tree()
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, step, tree, keep=3)
    assert latest_step(tmp_path) == 5
    kept = sorted(int(p.name.split("_")[1])
                  for p in tmp_path.glob("step_*"))
    assert kept == [3, 4, 5]


def test_checkpoint_shape_mismatch_detected(tmp_path):
    save_checkpoint(tmp_path, 0, _tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros(3, jnp.int32)}}
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, bad)


def test_elastic_mesh_plans():
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    p = plan_mesh(240, model_parallel=16)    # lost a node's chips
    assert p.shape == (15, 16)
    p = plan_mesh(8, model_parallel=16)      # degrade TP
    assert p.shape[0] * p.shape[1] <= 8
    with pytest.raises(ValueError):
        plan_mesh(0)


def test_elastic_reshard_on_host():
    plan = plan_mesh(len(jax.devices()), model_parallel=1)
    mesh = build_mesh(plan)
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = _tree()
    sh = {"a": NamedSharding(mesh, P()), "nested": {
        "b": NamedSharding(mesh, P())}}
    out = reshard(tree, sh)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_straggler_detector():
    det = StragglerDetector(k=3.0, min_samples=10)
    times = np.full(100, 0.1) + np.random.default_rng(0).normal(0, 0.002, 100)
    assert not det.is_straggler(times, 0.105)
    assert det.is_straggler(times, 0.5)


def test_step_timer_window():
    t = StepTimer(window=5)
    for i in range(10):
        t.record(float(i))
    assert t.times.size == 5
    assert t.times[-1] == 9.0


def test_stratified_steptime_cis():
    rng = np.random.default_rng(1)
    # two regimes: fast data shapes and slow ones
    labels = rng.integers(0, 2, 200)
    times = np.where(labels == 0, 0.1, 0.3) + rng.normal(0, 0.01, 200)
    est = stratified_steptime_estimate(times, labels, num_strata=2)
    assert abs(est.mean - times.mean()) < 0.02
    est1 = one_per_stratum_steptime_ci([0.1, 0.12, 0.3, 0.29],
                                       [0.25, 0.25, 0.25, 0.25])
    assert np.isfinite(est1.margin)
