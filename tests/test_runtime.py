"""Checkpoint / elastic / health runtime tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.sampling import tables as sampling_tables
from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      restore_memobank, save_checkpoint,
                                      save_memobank)
from repro.runtime.elastic import (build_mesh, plan_app_mesh,
                                   plan_app_trial_mesh, plan_mesh, reshard)
from repro.runtime.health import (QuantumHealth, StepTimer,
                                  StragglerDetector,
                                  one_per_stratum_steptime_ci,
                                  stratified_steptime_estimate)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree, extra={"step": 7})
    restored, extra = restore_checkpoint(tmp_path, tree)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.asarray(restored["a"]))
    np.testing.assert_array_equal(np.asarray(tree["nested"]["b"]),
                                  np.asarray(restored["nested"]["b"]))


def test_checkpoint_retention_and_latest(tmp_path):
    tree = _tree()
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, step, tree, keep=3)
    assert latest_step(tmp_path) == 5
    kept = sorted(int(p.name.split("_")[1])
                  for p in tmp_path.glob("step_*"))
    assert kept == [3, 4, 5]


def test_checkpoint_shape_mismatch_detected(tmp_path):
    save_checkpoint(tmp_path, 0, _tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros(3, jnp.int32)}}
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, bad)


def test_checkpoint_sharding_aware_restore(tmp_path):
    """``shardings=`` places restored leaves on devices with the given
    sharding (the elastic supervisor restores onto the NEW mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree()
    save_checkpoint(tmp_path, 0, tree)
    mesh = build_mesh(plan_app_mesh(len(jax.devices())))
    sh = {"a": NamedSharding(mesh, P()),
          "nested": {"b": NamedSharding(mesh, P())}}
    restored, _ = restore_checkpoint(tmp_path, tree, shardings=sh)
    assert restored["a"].sharding == sh["a"]
    assert restored["a"].dtype == tree["a"].dtype
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


# ---------------------------------------------------------------- MemoBank
def _toy_bank(register):
    """A two-app bank with ledgers, filled through the memoized path.

    ``register`` pre-registers config columns in the given order, so a
    restore target can hold a PERMUTED (or empty) column layout relative
    to the snapshot source.
    """
    from repro.simcpu.cache import MemoBank
    from repro.simcpu.simulator import Ledger
    from repro.simcpu.uarch import UarchConfig

    c0, c1 = UarchConfig(name="cfg-a"), UarchConfig(name="cfg-b")
    bank = MemoBank()
    bank.add_app("alpha", 6, Ledger())
    bank.add_app("beta", 5, Ledger())
    bank.cols_for([(c0, c1), (c1, c0), ()][register])
    return bank, (c0, c1)


def _fill_toy(bank, cfgs, *, seed=0):
    rng = np.random.default_rng(seed)
    idx = np.asarray([[0, 2, 4], [1, 3, 3]])
    vals = rng.uniform(0.5, 3.0, size=(2, 2, 3)).astype(np.float32)
    return bank.fill([0, 1], idx, None, cfgs, values=vals)


def test_memobank_checkpoint_roundtrip_permuted_columns(tmp_path):
    """A bank snapshot restores into a fresh bank whose config columns
    were registered in a different order: dtypes/shapes/version survive,
    accounting is replaced exactly, and the restored memo serves the
    original fills as pure hits with identical CPI."""
    src, cfgs = _toy_bank(0)
    cpi_src, _ = _fill_toy(src, cfgs)
    save_memobank(tmp_path, 0, src, extra={"tag": "t"})

    for register in (1, 2):                    # permuted / unregistered
        dst, _ = _toy_bank(register)
        extra = restore_memobank(tmp_path, dst, universe=cfgs)
        assert extra["tag"] == "t"
        assert dst.mask.dtype == np.bool_ and dst.cpi.dtype == np.float32
        assert dst.version == src.version
        assert dst.hit_count == src.hit_count
        assert dst.miss_count == src.miss_count
        assert [l.regions_simulated for l in dst.ledgers] == \
               [l.regions_simulated for l in src.ledgers]
        cpi_dst, n_miss = _fill_toy(dst, cfgs)
        assert not n_miss.any()                # fully memoized after restore
        np.testing.assert_array_equal(cpi_dst, cpi_src)
        assert np.asarray(dst.charges).sum() == np.asarray(src.charges).sum()


def test_memobank_restore_refuses_identity_drift(tmp_path):
    from repro.simcpu.cache import MemoBank
    from repro.simcpu.simulator import Ledger

    src, cfgs = _toy_bank(0)
    _fill_toy(src, cfgs)
    save_memobank(tmp_path, 0, src)
    other = MemoBank()
    other.add_app("gamma", 6, Ledger())
    other.add_app("beta", 5, Ledger())
    with pytest.raises(ValueError, match="apps"):
        restore_memobank(tmp_path, other, universe=cfgs)
    fresh, _ = _toy_bank(2)
    with pytest.raises(ValueError, match="not resolvable"):
        restore_memobank(tmp_path, fresh, universe=())


def test_memobank_version_never_rolls_back(tmp_path):
    """Restoring an older snapshot onto a bank that already advanced past
    it must move ``version`` forward (stale device-resident mirrors keyed
    on the saved version would otherwise revalidate)."""
    src, cfgs = _toy_bank(0)
    _fill_toy(src, cfgs)
    save_memobank(tmp_path, 0, src)
    dst, _ = _toy_bank(0)
    for _ in range(src.version + 3):
        dst.touch()
    before = dst.version
    restore_memobank(tmp_path, dst, universe=cfgs)
    assert dst.version > before >= src.version


def test_trial_stats_checkpoint_roundtrip(tmp_path):
    """TrialStats (a registered pytree) checkpoints leaf-for-leaf: dtypes,
    shapes and exact bit patterns survive the round-trip."""
    rng = np.random.default_rng(3)
    st = sampling_tables.trial_stats_update(
        sampling_tables.trial_stats_init((2,)),
        rng.uniform(0.1, 20.0, (2, 32)), rng.uniform(0.01, 1.0, (2, 32)),
        rng.random((2, 32)) < 0.9, np.ones((2, 32), bool))
    save_checkpoint(tmp_path, 0, {"stats": st})
    restored, _ = restore_checkpoint(
        tmp_path, {"stats": sampling_tables.trial_stats_init((2,))})
    got = jax.tree_util.tree_leaves(restored["stats"])
    want = jax.tree_util.tree_leaves(st)
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        assert g.dtype == w.dtype and g.shape == w.shape
        assert g.tobytes() == w.tobytes()


def test_elastic_mesh_plans():
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    p = plan_mesh(240, model_parallel=16)    # lost a node's chips
    assert p.shape == (15, 16)
    p = plan_mesh(8, model_parallel=16)      # degrade TP
    assert p.shape[0] * p.shape[1] <= 8
    with pytest.raises(ValueError):
        plan_mesh(0)


def test_elastic_app_mesh_plans():
    assert plan_app_mesh(5).shape == (5,)
    assert plan_app_mesh(5).axes == ("app",)
    p = plan_app_trial_mesh(8, app_devices=2)
    assert p.shape == (2, 4) and p.axes == ("app", "trial")
    # app degree clamps to the pool; leftover devices idle off-rectangle
    assert plan_app_trial_mesh(3, app_devices=8).shape == (3, 1)
    with pytest.raises(ValueError):
        plan_app_trial_mesh(0)


def test_quantum_health_trace():
    h = QuantumHealth()
    h.detector.min_samples = 4
    for q in range(8):
        assert not h.record(q, 0.1)
    assert h.record(8, 5.0)                    # obvious straggler
    assert h.summary()["quanta"] == 9
    assert h.summary()["stragglers"] == 1
    assert h.stragglers[0][0] == 8


def test_elastic_reshard_on_host():
    plan = plan_mesh(len(jax.devices()), model_parallel=1)
    mesh = build_mesh(plan)
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = _tree()
    sh = {"a": NamedSharding(mesh, P()), "nested": {
        "b": NamedSharding(mesh, P())}}
    out = reshard(tree, sh)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_straggler_detector():
    det = StragglerDetector(k=3.0, min_samples=10)
    times = np.full(100, 0.1) + np.random.default_rng(0).normal(0, 0.002, 100)
    assert not det.is_straggler(times, 0.105)
    assert det.is_straggler(times, 0.5)


def test_step_timer_window():
    t = StepTimer(window=5)
    for i in range(10):
        t.record(float(i))
    assert t.times.size == 5
    assert t.times[-1] == 9.0


def test_stratified_steptime_cis():
    rng = np.random.default_rng(1)
    # two regimes: fast data shapes and slow ones
    labels = rng.integers(0, 2, 200)
    times = np.where(labels == 0, 0.1, 0.3) + rng.normal(0, 0.01, 200)
    est = stratified_steptime_estimate(times, labels, num_strata=2)
    assert abs(est.mean - times.mean()) < 0.02
    est1 = one_per_stratum_steptime_ci([0.1, 0.12, 0.3, 0.29],
                                       [0.25, 0.25, 0.25, 0.25])
    assert np.isfinite(est1.margin)
