"""End-to-end two-phase flow on the simcpu substrate (paper Fig 14)."""

import numpy as np
import pytest

from repro.core.sampling import TwoPhaseFlow
from repro.simcpu import CONFIGS, make_simulator

APP = "520.omnetpp_r"


@pytest.fixture(scope="module")
def flow_artifacts():
    sim = make_simulator(APP)
    flow = TwoPhaseFlow(population_size=sim.pop.n_regions,
                        rng=np.random.default_rng(11))

    def measure_baseline(idx):
        return sim.simulate_rfv(idx, CONFIGS[0])

    idx1, y0, feats, est1 = flow.characterize(measure_baseline, 900)
    strat = flow.stratify(idx1, y0, feats, num_strata=20, scheme="rfv")
    return sim, flow, strat, est1


def test_phase1_estimate_tight_and_correct(flow_artifacts):
    sim, flow, strat, est1 = flow_artifacts
    truth = sim.true_mean_cpi(CONFIGS[0])
    assert est1.covers(truth)
    assert est1.margin_pct < 5.0


def test_centroid_selection_small_error_across_configs(flow_artifacts):
    sim, flow, strat, _ = flow_artifacts
    selected = flow.select(strat, policy="centroid")
    for cfg_i in (0, 3, 6):
        est = flow.point_estimate(
            strat, selected,
            lambda idx, c=CONFIGS[cfg_i]: sim.simulate_cpi(idx, c))
        truth = sim.true_mean_cpi(CONFIGS[cfg_i])
        assert abs(est - truth) / truth < 0.08, (cfg_i, est, truth)


def test_collapsed_ci_from_20_sims(flow_artifacts):
    sim, flow, strat, _ = flow_artifacts
    selected = flow.select(strat, policy="random", seed=5)
    est = flow.collapsed_ci(
        strat, selected, lambda idx: sim.simulate_cpi(idx, CONFIGS[6]))
    assert est.n == 20
    assert np.isfinite(est.margin)
    assert est.df == 10


def test_ci_check_multi_unit(flow_artifacts):
    sim, flow, strat, _ = flow_artifacts
    sizes = np.full(strat.num_strata, 4)
    est = flow.ci_check(
        strat, lambda idx: sim.simulate_cpi(idx, CONFIGS[6]),
        per_stratum_sizes=sizes)
    truth = sim.true_mean_cpi(CONFIGS[6])
    # multi-unit stratified CI should be tight AND cover
    assert est.margin_pct < 12.0
    assert est.covers(truth) or abs(est.mean - truth) / truth < 0.05


def test_stratified_needs_fewer_sims_than_random(flow_artifacts):
    """The headline efficiency claim at test scale: matching a random-
    sampling margin with far fewer stratified simulations."""
    from repro.core.sampling import srs_estimate
    sim, flow, strat, _ = flow_artifacts
    rng = np.random.default_rng(3)
    # random: n=400 margin
    idx = rng.choice(sim.pop.n_regions, 400, replace=False)
    est_rand = srs_estimate(sim.simulate_cpi(idx, CONFIGS[6]))
    # stratified: 4/stratum = 80 sims
    est_strat = flow.ci_check(
        strat, lambda i: sim.simulate_cpi(i, CONFIGS[6]),
        per_stratum_sizes=np.full(strat.num_strata, 4))
    assert est_strat.margin <= est_rand.margin * 1.6
