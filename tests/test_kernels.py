"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kmeans_assign.ops import kmeans_assign
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
from repro.kernels.segment_stats.ops import segment_stats, stratum_moments
from repro.kernels.segment_stats.ref import segment_stats_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n,d,k", [
    (100, 15, 20), (1000, 38, 20), (513, 7, 3), (2048, 128, 128),
    (64, 1, 2), (4096, 15, 500),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_kmeans_assign_matches_ref(n, d, k, dtype):
    x = RNG.normal(size=(n, d)).astype(dtype)
    c = RNG.normal(size=(k, d)).astype(dtype)
    l1, d1 = kmeans_assign(x, c)
    l2, d2 = kmeans_assign_ref(jnp.asarray(x), jnp.asarray(c))
    assert (np.asarray(l1) == np.asarray(l2)).mean() > 0.999
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("n,d,k", [
    (100, 1, 4), (3000, 38, 20), (1024, 8, 7), (4096, 4, 64),
])
def test_segment_stats_matches_ref(n, d, k):
    x = RNG.normal(size=(n, d)).astype(np.float32)
    lab = RNG.integers(0, k, n).astype(np.int32)
    s1, q1, c1 = segment_stats(x, lab, k, backend="pallas")
    s2, q2, c2 = segment_stats_ref(jnp.asarray(x), jnp.asarray(lab), k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("batch_shape", [(3,), (2, 3)])
def test_segment_stats_batched_matches_ref(batch_shape):
    """Leading batch axes (app / app×trial stacks) with -1 masked rows."""
    n, k = 700, 6
    x = RNG.normal(size=(*batch_shape, n)).astype(np.float32)
    lab = RNG.integers(-1, k, (*batch_shape, n)).astype(np.int32)
    s1, q1, c1 = segment_stats(x, lab, k, backend="pallas")
    s2, q2, c2 = segment_stats_ref(jnp.asarray(x), jnp.asarray(lab), k)
    assert s1.shape == (*batch_shape, k, 1)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))


def test_stratum_moments_match_numpy():
    x = RNG.normal(size=2000).astype(np.float32)
    lab = RNG.integers(0, 10, 2000).astype(np.int32)
    m, v, c = stratum_moments(x, lab, 10, backend="pallas")
    for h in range(10):
        seg = x[lab == h]
        assert float(m[h, 0]) == pytest.approx(seg.mean(), rel=1e-4)
        assert float(v[h, 0]) == pytest.approx(seg.var(ddof=1), rel=1e-3)
        assert float(c[h]) == seg.size


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 4, 2, 256, 256, 64),
    (2, 8, 4, 300, 300, 32),
    (1, 4, 1, 1, 512, 64),      # decode
    (1, 2, 2, 1, 700, 128),     # decode, unaligned cache
    (1, 4, 4, 512, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, sq, skv, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), dtype)
    o1 = flash_attention(q, k, v)
    kk = jnp.repeat(k, hq // hkv, axis=1)
    vv = jnp.repeat(v, hq // hkv, axis=1)
    o2 = attention_ref(q, kk, vv, causal=True)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_rejects_non_causal():
    q = jnp.zeros((1, 2, 8, 16))
    with pytest.raises(NotImplementedError):
        flash_attention(q, q, q, causal=False)


def test_chunked_attention_matches_ref():
    """The pure-jnp streaming attention used by the big-model forward."""
    from repro.models.attention import _attend_chunked
    q = jnp.asarray(RNG.normal(size=(2, 4, 300, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 4, 300, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 4, 300, 32)), jnp.float32)
    o1 = _attend_chunked(q, k, v, window=None, kv_chunk=64)
    o2 = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    # windowed (local attention)
    o3 = _attend_chunked(q, k, v, window=50, kv_chunk=64)
    o4 = attention_ref(q, k, v, causal=True, window=50)
    np.testing.assert_allclose(np.asarray(o3), np.asarray(o4),
                               rtol=2e-4, atol=2e-4)
