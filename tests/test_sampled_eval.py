"""Sampled-eval integration (the paper's technique inside the LM stack)."""

import numpy as np

from repro.train.sampled_eval import SampledEval


def _make_corpus(n=500, seed=0):
    """Synthetic eval corpus: batch loss depends on a latent difficulty."""
    rng = np.random.default_rng(seed)
    difficulty = rng.choice([1.0, 2.0, 4.0], size=n, p=[0.6, 0.3, 0.1])
    noise = rng.normal(0, 0.05, n)
    losses = difficulty + noise
    feats = np.stack([difficulty + rng.normal(0, 0.1, n),
                      rng.normal(0, 1, n)], axis=1)
    return losses, feats


def test_sampled_eval_flow():
    losses, feats = _make_corpus()
    calls = {"n": 0}

    def eval_batch(i):
        calls["n"] += 1
        return float(losses[i]), feats[i]

    se = SampledEval(n_batches=500, eval_batch=eval_batch, num_strata=6)
    est1 = se.characterize(n_phase1=200)
    true = losses.mean()
    assert est1.covers(true) or abs(est1.mean - true) / true < 0.05

    c0 = calls["n"]
    quick = se.quick_estimate()
    assert calls["n"] - c0 <= 6                 # one per stratum
    assert abs(quick - true) / true < 0.10

    ci = se.ci_check(per_stratum=6)
    assert ci.margin_pct < 16   # few effective strata => small t-df
    assert ci.covers(true) or abs(ci.mean - true) / true < 0.05


def test_quick_estimate_beats_same_budget_random():
    losses, feats = _make_corpus(seed=3)

    def eval_batch(i):
        return float(losses[i]), feats[i]

    se = SampledEval(n_batches=500, eval_batch=eval_batch, num_strata=8)
    se.characterize(n_phase1=250)
    true = losses.mean()
    strat_err = abs(se.quick_estimate() - true)

    rng = np.random.default_rng(0)
    rand_errs = [abs(losses[rng.choice(500, 8, replace=False)].mean() - true)
                 for _ in range(200)]
    # stratified centroid selection should beat the MEDIAN random draw
    assert strat_err <= np.median(rand_errs) + 1e-9
