"""End-to-end behaviour tests for the paper's system."""

import subprocess
import sys


def test_quickstart_example_runs():
    out = subprocess.run(
        [sys.executable, "examples/quickstart.py"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "phase-1" in out.stdout
    assert "covers truth: True" in out.stdout


def test_data_pipeline_determinism():
    from repro.configs import get_config
    from repro.data.synthetic import make_pipeline
    import numpy as np
    cfg = get_config("llama3.2-3b", smoke=True)
    p1 = make_pipeline(cfg, 64, 4, seed=7)
    p2 = make_pipeline(cfg, 64, 4, seed=7)
    b1, b2 = p1.batch(12), p2.batch(12)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch(13)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_mini_training_descends_and_resumes(tmp_path):
    """Loss descends; a killed-and-restarted run continues bit-exact data."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.data.synthetic import make_pipeline
    from repro.models.registry import init_params, loss_fn
    from repro.optim import AdamW, apply_updates
    from repro.runtime.checkpoint import restore_checkpoint, save_checkpoint

    cfg = get_config("llama3.2-3b", smoke=True)
    pipe = make_pipeline(cfg, 64, 4)
    opt = AdamW(lr=5e-3)
    lfn = loss_fn(cfg)

    @jax.jit
    def step_fn(p, s, batch):
        loss, g = jax.value_and_grad(lfn)(p, batch)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, loss

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    losses = []
    for step in range(8):
        params, state, loss = step_fn(params, state, pipe.batch(step))
        losses.append(float(loss))
        if step == 4:
            save_checkpoint(tmp_path, step, (params, state),
                            extra={"step": step})
    assert losses[-1] < losses[0]

    # restart from step 5 and verify identical continuation
    (p2, s2), extra = restore_checkpoint(tmp_path, (params, state))
    start = extra["step"] + 1
    for step in range(start, 8):
        p2, s2, loss2 = step_fn(p2, s2, pipe.batch(step))
    np.testing.assert_allclose(float(loss2), losses[-1], rtol=1e-4)
