"""Per-architecture smoke tests + recurrence-equivalence invariants.

Each assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes + no NaNs (full configs
are exercised via the dry-run only).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models.registry import (decode_fn, forward_fn, init_params,
                                   loss_fn, make_decode_state)

RNG = np.random.default_rng(0)


def _batch(cfg, b=2, s=64):
    out = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s))),
           "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)))}
    if cfg.family == "encdec":
        out["src_embeds"] = jnp.asarray(
            RNG.normal(size=(b, 32, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = forward_fn(cfg)(params, batch)
    assert logits.shape == (2, 64, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = loss_fn(cfg)(params, batch)
    assert np.isfinite(float(loss))
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step_descends(arch):
    from repro.optim import AdamW, apply_updates
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=5e-3)
    state = opt.init(params)
    lfn = loss_fn(cfg)
    batch = _batch(cfg)

    @jax.jit
    def step(p, s):
        l, g = jax.value_and_grad(lfn)(p, batch)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    losses = []
    for _ in range(4):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    caches = make_decode_state(cfg, 2, 128, s_src=32)
    if cfg.family == "encdec":
        from repro.models.encdec import encode, precompute_cross_kv
        src = jnp.asarray(RNG.normal(size=(2, 32, cfg.d_model)), jnp.float32)
        memory = encode(params, src, cfg)
        ck, cv = precompute_cross_kv(params, memory, cfg)
        caches = caches._replace(cross_k=ck, cross_v=cv)
    tok = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    logits, caches2 = decode_fn(cfg)(params, tok, caches, jnp.int32(3))
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_decode_matches_forward_dense():
    """Teacher-forced decode equals the parallel forward (same tokens)."""
    cfg = get_config("llama3.2-3b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    s = 24
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (1, s)), jnp.int32)
    full = forward_fn(cfg)(params, {"tokens": tokens})
    caches = make_decode_state(cfg, 1, 64)
    dfn = decode_fn(cfg)
    outs = []
    for t in range(s):
        logits, caches = dfn(params, tokens[:, t:t + 1], caches,
                             jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_rwkv_chunked_equals_step():
    from repro.models.common import KeyGen
    from repro.models.rwkv6 import (RwkvState, init_rwkv_time_mix,
                                    rwkv_time_mix_chunked,
                                    rwkv_time_mix_step)
    cfg = get_config("rwkv6-7b", smoke=True)
    kg = KeyGen(jax.random.PRNGKey(1), False)
    p = init_rwkv_time_mix(cfg, kg)
    b, s, d = 2, 96, cfg.d_model
    x = jnp.asarray(RNG.normal(size=(b, s, d)), jnp.float32) * 0.5
    h = d // cfg.rwkv_head_dim
    st0 = RwkvState(jnp.zeros((b, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                              jnp.float32), jnp.zeros((b, d), jnp.float32))
    out_c, st_c = rwkv_time_mix_chunked(p, x, cfg, st0, chunk=32)
    st = st0
    outs = []
    for t in range(s):
        o, st = rwkv_time_mix_step(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    out_s = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c.s), np.asarray(st.s),
                               rtol=1e-3, atol=1e-4)


def test_rglru_scan_equals_step():
    from repro.models.common import KeyGen
    from repro.models.rglru import (RglruState, init_rglru, make_rglru_state,
                                    rglru_block, rglru_step)
    cfg = get_config("recurrentgemma-2b", smoke=True)
    kg = KeyGen(jax.random.PRNGKey(2), False)
    p = init_rglru(cfg, kg)
    b, s, d = 2, 48, cfg.d_model
    w = cfg.rnn_width
    x = jnp.asarray(RNG.normal(size=(b, s, d)), jnp.float32) * 0.3
    st0 = RglruState(jnp.zeros((b, w), jnp.float32),
                     jnp.zeros((b, 3, w), jnp.float32))
    out_p, st_p = rglru_block(p, x, cfg, st0)
    st = st0
    outs = []
    for t in range(s):
        o, st = rglru_step(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    out_s = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_s),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_p.h), np.asarray(st.h),
                               rtol=2e-3, atol=2e-4)


def test_moe_routes_to_topk_and_drops_overflow():
    from repro.models.common import KeyGen
    from repro.models.moe import init_moe, moe
    cfg = get_config("olmoe-1b-7b", smoke=True)
    kg = KeyGen(jax.random.PRNGKey(3), False)
    p = init_moe(cfg, kg)
    x = jnp.asarray(RNG.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    out = moe(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # zero input -> zero output (router gates scale expert outputs of 0)
    out0 = moe(p, jnp.zeros_like(x), cfg)
    np.testing.assert_allclose(np.asarray(out0), 0.0, atol=1e-5)


def test_long_context_shape_skips_match_design():
    from repro.configs import cells_for
    runs_500k = {a for a in ALL_ARCHS
                 if any(c.name == "long_500k"
                        for c in cells_for(get_config(a)))}
    assert runs_500k == {"rwkv6-7b", "recurrentgemma-2b"}
