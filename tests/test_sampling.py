"""Estimator unit + property tests (paper Appendix A formulas).

The property tests need ``hypothesis`` (pinned in requirements-dev.txt).
When it is absent the module must still collect — only the property tests
skip, the plain unit tests keep running.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade gracefully: skip property tests
    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        def __getattr__(self, _name):
            return lambda *a, **k: None
    st = st()

from repro.core.sampling import (collapsed_strata_estimate,
                                 dalenius_gurney_strata, draw_srs,
                                 neyman_allocation, proportional_allocation,
                                 srs_estimate, srs_required_n,
                                 stratified_estimate_from_samples,
                                 stratum_products, summarize_strata,
                                 two_phase_estimate, critical_value)


def test_srs_matches_numpy():
    rng = np.random.default_rng(0)
    y = rng.normal(5.0, 2.0, 1000)
    est = srs_estimate(y)
    assert est.mean == pytest.approx(y.mean())
    assert est.variance == pytest.approx(y.var(ddof=1) / 1000)
    lo, hi = est.interval
    assert lo < y.mean() < hi


def test_srs_small_sample_uses_t():
    rng = np.random.default_rng(1)
    y = rng.normal(0, 1, 10)
    est = srs_estimate(y)
    assert est.df == 9
    # t margin wider than z margin
    z = critical_value(0.95, None)
    t = critical_value(0.95, 9)
    assert t > z


@given(st.integers(2, 6), st.integers(20, 200))
@settings(max_examples=20, deadline=None)
def test_stratified_census_recovers_population_mean(L, per):
    """Property: sampling EVERY unit stratified == population mean."""
    rng = np.random.default_rng(L * 1000 + per)
    y = rng.normal(0, 1, L * per) + np.repeat(np.arange(L), per) * 3.0
    labels = np.repeat(np.arange(L), per)
    est = stratified_estimate_from_samples(y, labels, num_strata=L)
    assert est.mean == pytest.approx(y.mean(), abs=1e-9)


def test_stratification_reduces_variance():
    """Stratifying on a variable correlated with y tightens the CI."""
    rng = np.random.default_rng(2)
    n = 4000
    strata = rng.integers(0, 4, n)
    y = strata * 5.0 + rng.normal(0, 0.5, n)
    # proportional stratified sample of 100 vs SRS of 100
    sel = np.concatenate([np.flatnonzero(strata == h)[:25] for h in range(4)])
    w = np.bincount(strata) / n
    est_strat = stratified_estimate_from_samples(
        y[sel], strata[sel], weights=w, num_strata=4)
    est_srs = srs_estimate(y[rng.choice(n, 100, replace=False)])
    assert est_strat.margin < est_srs.margin


def test_srs_coverage_property():
    """~95% of 95% CIs cover the true mean (frequentist calibration)."""
    rng = np.random.default_rng(3)
    pop = rng.gamma(2.0, 2.0, 100_000)
    true = pop.mean()
    cover = 0
    trials = 400
    for _ in range(trials):
        y = pop[rng.choice(pop.size, 100, replace=False)]
        if srs_estimate(y).covers(true):
            cover += 1
    assert 0.90 <= cover / trials <= 0.99


def test_collapsed_strata_df_and_mean():
    y = np.arange(20, dtype=float)
    w = np.full(20, 1 / 20)
    est = collapsed_strata_estimate(y, w)
    assert est.mean == pytest.approx(y.mean())
    assert est.df == 10          # L/2 for pairwise collapsing
    assert est.variance > 0


def test_collapsed_strata_odd_L():
    y = np.arange(7, dtype=float)
    w = np.full(7, 1 / 7)
    est = collapsed_strata_estimate(y, w)
    assert est.mean == pytest.approx(y.mean())
    assert np.isfinite(est.margin)


def test_two_phase_formulas_agree_when_phase1_large():
    """eq.(5)/(6) both reduce to plain stratified for huge phase-1 n."""
    rng = np.random.default_rng(4)
    y = rng.normal(0, 1, 200)
    labels = rng.integers(0, 5, 200)
    summ = summarize_strata(y, labels, num_strata=5)
    big = two_phase_estimate(summ, phase1_n=10**9)
    small = two_phase_estimate(summ, phase1_n=50)
    assert big.variance < small.variance
    assert big.mean == pytest.approx(small.mean)


@given(st.integers(2, 10))
@settings(max_examples=15, deadline=None)
def test_dalenius_gurney_balances_products(L):
    rng = np.random.default_rng(L)
    x = rng.lognormal(0, 1, 5000)
    labels = dalenius_gurney_strata(x, L)
    assert labels.min() >= 0 and labels.max() == L - 1
    prods = stratum_products(x, labels, L)
    # products should be far more balanced than equal-count strata
    eq = np.quantile(x, np.linspace(0, 1, L + 1))
    eq_labels = np.clip(np.searchsorted(eq, x, side="right") - 1, 0, L - 1)
    eq_prods = stratum_products(x, eq_labels, L)
    assert prods.std() <= eq_prods.std() * 1.5 + 1e-9


def test_allocations_sum_and_minima():
    w = np.array([0.5, 0.3, 0.2])
    s = np.array([1.0, 4.0, 0.1])
    n_prop = proportional_allocation(w, 100)
    n_ney = neyman_allocation(w, s, 100)
    assert n_prop.sum() >= 100
    assert (n_prop >= 2).all() and (n_ney >= 2).all()
    # Neyman puts more where W*S is big
    assert n_ney[1] > n_prop[1]


def test_required_n_scales_with_precision():
    rng = np.random.default_rng(5)
    pilot = rng.normal(10, 3, 50)
    n1 = srs_required_n(pilot, target_margin_pct=5)
    n2 = srs_required_n(pilot, target_margin_pct=1)
    assert n2 > n1 * 10


def test_draw_srs_without_replacement():
    rng = np.random.default_rng(6)
    idx = draw_srs(rng, 100, 50)
    assert len(set(idx.tolist())) == 50
    with pytest.raises(ValueError):
        draw_srs(rng, 10, 20)
