"""Batched experiment engine tests: batched-vs-sequential equivalence,
memoizing simulator cost accounting, and the sweep driver."""

import warnings

import jax
import numpy as np
import pytest

from repro.core.clustering import kmeans, kmeans_batch
from repro.core.sampling import (SamplingPlan, StratumSummary,
                                 summarize_strata, weighted_point_estimate)
from repro.experiments import ExperimentEngine, SweepSpec, run_sweep
from repro.simcpu import (CONFIGS, REGION_LEN_INSTR, evaluate_regions,
                          evaluate_regions_batch, cpi_batch,
                          get_population, make_cached_simulator,
                          make_simulator)

APP = "505.mcf_r"       # smallest population: fast to build


# ------------------------------------------------- batched perf model
def test_evaluate_regions_batch_matches_per_config():
    """The acceptance-criterion equivalence: one vmapped program over the
    stacked (C, 14) config matrix == C sequential evaluations."""
    feats = get_population(APP).features[:400]
    batch = evaluate_regions_batch(feats, CONFIGS)
    for i, cfg in enumerate(CONFIGS):
        single = evaluate_regions(feats, cfg)
        assert set(batch) == set(single)
        for metric in single:
            assert batch[metric].shape == (len(CONFIGS), 400)
            np.testing.assert_allclose(batch[metric][i], single[metric],
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"{cfg.name}:{metric}")


def test_cpi_batch_matches_and_respects_indices():
    feats = get_population(APP).features
    idx = np.array([5, 17, 200, 3])
    mat = cpi_batch(feats, CONFIGS, idx)
    assert mat.shape == (7, 4)
    np.testing.assert_allclose(
        mat[2], evaluate_regions(feats, CONFIGS[2], idx)["cpi"],
        rtol=1e-5, atol=1e-6)


# ------------------------------------------------- batched k-means
def test_kmeans_batch_matches_per_seed_fits():
    rng = np.random.default_rng(0)
    x = np.concatenate([rng.normal(3.0 * i, 0.4, (60, 5)) for i in range(4)])
    seeds = [0, 1, 2, 7]
    batch = kmeans_batch(x, 4, seeds=seeds)
    assert len(batch) == len(seeds)
    for s, res in zip(seeds, batch):
        single = kmeans(x, 4, key=jax.random.PRNGKey(s))
        np.testing.assert_array_equal(res.labels, single.labels)
        np.testing.assert_allclose(res.centroids, single.centroids,
                                   rtol=1e-5, atol=1e-6)
        assert res.inertia == pytest.approx(single.inertia, rel=1e-5)


def test_kmeans_batch_validates_key_args():
    x = np.random.default_rng(1).normal(size=(50, 3))
    with pytest.raises(ValueError):
        kmeans_batch(x, 3)                       # neither keys nor seeds
    with pytest.raises(ValueError):
        kmeans_batch(x, 3, seeds=[0], keys=jax.random.PRNGKey(0))


def test_kmeans_restarts_picks_best_of_batch():
    x = np.random.default_rng(2).normal(size=(120, 4))
    best = kmeans(x, 5, seed=3, restarts=4)
    assert np.isfinite(best.inertia)
    # best-of cannot be worse than a single fit from the same root key
    key = jax.random.PRNGKey(3)
    _, sub = jax.random.split(key)
    assert best.inertia <= kmeans(x, 5, key=sub).inertia + 1e-6


# ------------------------------------------------- memoizing simulator
def test_cached_simulator_second_simulation_is_free():
    sim = make_cached_simulator(APP)
    idx = np.arange(25)
    first = sim.simulate_cpi(idx, CONFIGS[0])
    assert sim.ledger.regions_simulated == 25
    second = sim.simulate_cpi(idx, CONFIGS[0])
    assert sim.ledger.regions_simulated == 25        # zero new charges
    assert sim.hits == 25
    np.testing.assert_array_equal(first, second)
    # a different config is a different memo row: charged again
    sim.simulate_cpi(idx, CONFIGS[1])
    assert sim.ledger.regions_simulated == 50
    assert sim.ledger.instructions_simulated == 50 * REGION_LEN_INSTR


def test_cached_simulator_charges_unique_regions_only():
    sim = make_cached_simulator(APP)
    sim.simulate_cpi([3, 3, 3, 9], CONFIGS[0])
    assert sim.ledger.regions_simulated == 2         # {3, 9}


def test_cached_simulator_batch_charges_per_config_misses():
    sim = make_cached_simulator(APP)
    sim.simulate_cpi(np.arange(10), CONFIGS[0])      # pre-warm config 0
    mat = sim.simulate_cpi_batch(np.arange(10), CONFIGS)
    assert mat.shape == (7, 10)
    # config 0 fully cached; the other 6 configs charged 10 each
    assert sim.ledger.regions_simulated == 10 + 6 * 10
    base = make_simulator(APP)
    for i, cfg in enumerate(CONFIGS):
        np.testing.assert_allclose(
            mat[i], base.simulate_cpi(np.arange(10), cfg),
            rtol=1e-5, atol=1e-6)


def test_cached_simulator_census_stays_off_the_books():
    sim = make_cached_simulator(APP)
    sim.census_stats(CONFIGS[0])
    assert sim.ledger.regions_simulated == 0
    # and the census does NOT pre-populate the charged memo
    sim.simulate_cpi(np.arange(5), CONFIGS[0])
    assert sim.ledger.regions_simulated == 5


def test_cached_simulator_matches_uncached_stats():
    cached = make_cached_simulator(APP)
    base = make_simulator(APP)
    idx = np.array([0, 11, 42, 999])
    a = cached.simulate(idx, CONFIGS[4])
    b = base.simulate(idx, CONFIGS[4])
    assert set(a) == set(b)
    for metric in b:
        np.testing.assert_allclose(a[metric], b[metric],
                                   rtol=1e-5, atol=1e-6)


# ------------------------------------------------- engine + sweeps
@pytest.fixture(scope="module")
def engine():
    eng = ExperimentEngine()
    eng.app(APP)            # build once for the whole module
    return eng


def test_engine_truth_matches_census(engine):
    exp = engine.app(APP)
    base = make_simulator(APP)
    for i, cfg in enumerate(CONFIGS):
        assert exp.truth[i] == pytest.approx(base.true_mean_cpi(cfg),
                                             rel=1e-5)


def test_srs_sweep_matches_sequential(engine):
    from repro.core.sampling import srs_estimate
    table = run_sweep(engine, SweepSpec(apps=(APP,), scheme="srs"))
    assert len(table) == len(CONFIGS)
    exp = engine.app(APP)
    for row in table:
        est = srs_estimate(exp.cpi(row.config_index, exp.idx1))
        assert row.estimate == pytest.approx(est.mean, rel=1e-6)
        assert row.margin_pct == pytest.approx(est.margin_pct, rel=1e-6)


def test_stratified_sweep_matches_sequential(engine):
    from repro.experiments import scheme_selection
    table = run_sweep(engine, SweepSpec(apps=(APP,), scheme="rfv",
                                        policy="centroid"))
    exp = engine.app(APP)
    sel, w = scheme_selection(exp, "rfv", "centroid")
    flat = np.concatenate([s for s in sel if s.size])
    for row in table:
        cpi = exp.cpi(row.config_index, flat)
        est, wt, off = 0.0, 0.0, 0
        for h, s in enumerate(sel):
            if s.size == 0:
                continue
            est += w[h] * cpi[off:off + s.size].mean()
            wt += w[h]
            off += s.size
        assert row.estimate == pytest.approx(est / wt, rel=1e-6)
        assert row.truth == pytest.approx(float(exp.truth[row.config_index]),
                                          rel=1e-9)


def test_sweep_config_subset_charges_only_those_configs():
    eng = ExperimentEngine()
    exp = eng.app(APP)
    before = exp.sim.ledger.regions_simulated
    run_sweep(eng, SweepSpec(apps=(APP,), scheme="srs",
                             config_indices=(0, 6)))
    # config 0 was fully simulated in phase 1 (cache hits); only config 6
    # costs anything — configs 1-5 must not be touched at all
    assert exp.sim.ledger.regions_simulated - before == exp.idx1.size


def test_weighted_cpi_all_matches_loop_and_warns(engine):
    exp = engine.app(APP)
    sel = [np.array([h]) for h in range(4)]
    w = np.full(4, 0.25)
    ests = exp.weighted_cpi_all(sel, w)
    assert ests.shape == (len(CONFIGS),)
    for ci in range(len(CONFIGS)):
        manual = sum(w[h] * float(exp.cpi(ci, sel[h])[0]) for h in range(4))
        assert ests[ci] == pytest.approx(manual, rel=1e-6)
    partial = [np.array([0]), np.empty(0, np.int64)]
    with pytest.warns(UserWarning, match="cover only"):
        exp.weighted_cpi_all(partial, np.array([0.5, 0.5]))
    with pytest.raises(ValueError, match="cover only"):
        exp.weighted_cpi_all(partial, np.array([0.5, 0.5]), strict=True)


def test_sweep_spec_rejects_unknown_scheme():
    with pytest.raises(ValueError):
        SweepSpec(apps=(APP,), scheme="bogus")


def test_results_table_helpers(engine):
    table = run_sweep(engine, SweepSpec(apps=(APP,), scheme="srs",
                                        config_indices=(0, 6)))
    assert len(table.filter(config_index=6)) == 1
    assert table.matrix("estimate").shape == (2, 1)
    assert table.to_csv().count("\n") == len(table)


def test_multi_seed_stratifications_batched(engine):
    fits = engine.rfv_stratifications(APP, seeds=range(3))
    assert len(fits) == 3
    exp = engine.app(APP)
    for fit in fits:
        assert fit.labels.shape == exp.rfv_labels.shape
        assert np.unique(fit.labels).size == exp.num_strata


# ------------------------------------------------- satellite bugfixes
def test_weighted_point_estimate_warns_on_uncovered_weight():
    y = np.arange(4, dtype=float)
    w = np.array([0.5, 0.5])
    full = [np.array([0, 1]), np.array([2, 3])]
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # full coverage: no warning
        assert weighted_point_estimate(full, y, w) == pytest.approx(1.5)
    partial = [np.array([0, 1]), np.array([], dtype=int)]
    with pytest.warns(UserWarning, match="cover only"):
        est = weighted_point_estimate(partial, y, w)
    assert est == pytest.approx(0.5)             # renormalized (biased)
    with pytest.raises(ValueError, match="cover only"):
        weighted_point_estimate(partial, y, w, strict=True)


def test_summarize_strata_infers_count_from_weights():
    y = np.array([1.0, 2.0, 3.0, 4.0])
    labels = np.array([0, 0, 1, 1])              # stratum 2 unobserved
    w = np.array([0.3, 0.3, 0.4])
    summ = summarize_strata(y, labels, weights=w)     # num_strata=None
    assert len(summ) == 3
    assert summ[2].n == 0                        # trailing empty stratum
    assert isinstance(summ[0], StratumSummary)


# ------------------------------------------------- fused sweep megaprogram
def _memo_state(memo):
    return (memo.mask.copy(), memo.cpi.copy(), memo.charges.copy(),
            list(memo.hit_count), list(memo.miss_count),
            [None if l is None else (l.regions_simulated,
                                     l.instructions_simulated)
             for l in memo.ledgers])


def _memo_reset(memo, state):
    memo.mask[...], memo.cpi[...], memo.charges[...] = state[:3]
    memo.hit_count[:], memo.miss_count[:] = state[3], state[4]
    for ledger, vals in zip(memo.ledgers, state[5]):
        if ledger is not None:
            ledger.regions_simulated, ledger.instructions_simulated = vals
    memo.touch()          # direct table writes: drop device-block mirrors


def test_fused_sweep_matches_staged(engine):
    """The fused megaprogram and the staged reference chain agree:
    estimates to 1e-6 (XLA compiles the f32 perf model differently in
    the two program contexts, so a few CPI cells land 1-2 ulps apart —
    bitwise equality across compiles is not attainable), and the memo
    mask, charge matrix, hit/miss counters and ledger totals BITWISE
    (miss accounting is integer arithmetic, path-independent)."""
    import dataclasses

    cfg_idx = (0, 2, 5)
    engine.memo.cols_for(tuple(engine.configs[i] for i in cfg_idx))
    spec = SweepSpec(apps=(APP,),
                     plan=SamplingPlan.from_strings("rfv", "centroid"),
                     config_indices=cfg_idx)
    before = _memo_state(engine.memo)
    fused_table = run_sweep(engine, spec)
    after_fused = _memo_state(engine.memo)
    _memo_reset(engine.memo, before)
    staged_table = run_sweep(engine,
                             dataclasses.replace(spec, fused=False))
    after_staged = _memo_state(engine.memo)
    _memo_reset(engine.memo, before)

    ef = fused_table.column("estimate")
    es = staged_table.column("estimate")
    np.testing.assert_allclose(ef, es, rtol=1e-6)
    np.testing.assert_allclose(fused_table.column("err_pct"),
                               staged_table.column("err_pct"), atol=1e-4)
    np.testing.assert_array_equal(after_fused[0], after_staged[0])  # mask
    np.testing.assert_array_equal(after_fused[2], after_staged[2])  # charges
    assert after_fused[3] == after_staged[3]                 # hit counts
    assert after_fused[4] == after_staged[4]                 # miss counts
    assert after_fused[5] == after_staged[5]                 # ledger totals


def test_fused_sweep_single_dispatch_marker(engine):
    """One fused sweep costs exactly ONE device program dispatch."""
    from repro.core.sampling import plan as plan_mod

    plan_mod._reset_sweep_dispatch()
    run_sweep(engine, SweepSpec(
        apps=(APP,), plan=SamplingPlan.from_strings("rfv", "centroid"),
        config_indices=(0, 3)))
    marker = plan_mod.last_sweep_dispatch()
    assert marker is not None
    assert marker["fused"] is True
    assert marker["count"] == 1
    assert marker["batch_shape"] == (1, 2)
    assert marker["num_strata"] == engine.num_strata


def test_fused_sweep_donation_safety(engine):
    """The memo blocks enter the megaprogram as donated buffers: the
    dispatch marker records whether the runtime consumed them, and the
    driver never reads a donated device array after dispatch (this test
    would abort with a deleted-buffer error if it did). CPU XLA honors
    donation; other backends may decline, so False is tolerated."""
    from repro.core.sampling import plan as plan_mod

    plan_mod._reset_sweep_dispatch()
    run_sweep(engine, SweepSpec(
        apps=(APP,), plan=SamplingPlan.from_strings("rfv", "centroid"),
        config_indices=(0,)))
    marker = plan_mod.last_sweep_dispatch()
    assert isinstance(marker["donated"], bool)
    if jax.default_backend() == "cpu":
        assert marker["donated"] is True


def test_fused_sweep_warm_call_does_not_recompile(engine, compile_counter):
    """A second identical fused sweep reuses the compiled megaprogram.

    The first call traces and compiles; the second — same apps, plan,
    config subset, shapes — must hit the jit cache even though the memo
    tables were charged (mutated) in between: table CONTENT flows in as
    device buffers, never as trace constants (recompile guard teeth)."""
    spec = SweepSpec(apps=(APP,),
                     plan=SamplingPlan.from_strings("rfv", "centroid"),
                     config_indices=(0, 3))
    run_sweep(engine, spec)                       # warm: trace + compile
    with compile_counter.no_recompile("second identical fused sweep"):
        run_sweep(engine, spec)


def test_staged_sweep_marker_not_fused(engine):
    """The staged fallback records a non-fused, non-donated dispatch."""
    import dataclasses
    from repro.core.sampling import plan as plan_mod

    plan_mod._reset_sweep_dispatch()
    spec = SweepSpec(apps=(APP,),
                     plan=SamplingPlan.from_strings("rfv", "centroid"),
                     config_indices=(0,))
    run_sweep(engine, dataclasses.replace(spec, fused=False))
    marker = plan_mod.last_sweep_dispatch()
    assert marker["fused"] is False
    assert marker["donated"] is False
