"""Serving-layer tests: request coalescing bitwise-equals serial
dispatch, the persistent MemoBank's eviction/spill accounting, and the
``SweepService`` queue loop.

The central contract (ISSUE: sweep-as-a-service): a coalesced batch of K
same-shape sweep requests must be **bitwise** identical to K serial
``run_sweep`` calls — estimates AND the shared bank's mask/CPI tables,
charge matrix, hit/miss counters, and per-app ledger totals. Eviction
semantics: a dropped column is re-charged exactly once on re-request; a
host-spilled column restores free (ledger equals a never-evicted run);
every evict/spill/unspill bumps ``MemoBank.version`` so the fused
driver's device-block mirror cache can never serve stale state.
"""

import numpy as np
import pytest

from repro.core.sampling import plan as sampling_plan
from repro.core.sampling.plan import (Centroid, RFVClusters, RandomUnit,
                                      SamplingPlan)
from repro.experiments.engine import ExperimentEngine
from repro.experiments.montecarlo import TrialSpec, run_trials
from repro.experiments.sweep import SweepSpec, run_sweep
from repro.serving import (SweepService, coalesce_key, coalescible,
                           prepare_sweep, run_coalesced_sweeps)
from repro.simcpu.cache import MemoBank
from repro.simcpu.simulator import Ledger
from repro.simcpu.uarch import CONFIGS

APPS = ("505.mcf_r", "520.omnetpp_r")
CFGS = (0, 1, 2)


@pytest.fixture(scope="module")
def engine():
    eng = ExperimentEngine()
    eng.build(APPS)
    return eng


def _memo_state(memo):
    return (memo.mask.copy(), memo.cpi.copy(), memo.charges.copy(),
            list(memo.hit_count), list(memo.miss_count),
            [None if l is None else (l.regions_simulated,
                                     l.instructions_simulated)
             for l in memo.ledgers])


def _memo_reset(memo, state):
    # columns may have GROWN since the snapshot: restore through leading
    # slices (plain `mask[...] = old` would broadcast a 1-column snapshot
    # across every column)
    mask, cpi, charges = state[:3]
    memo.mask[...], memo.cpi[...], memo.charges[...] = False, 0.0, 0
    memo.mask[tuple(slice(0, d) for d in mask.shape)] = mask
    memo.cpi[tuple(slice(0, d) for d in cpi.shape)] = cpi
    memo.charges[tuple(slice(0, d) for d in charges.shape)] = charges
    memo.hit_count[:], memo.miss_count[:] = state[3], state[4]
    for ledger, vals in zip(memo.ledgers, state[5]):
        if ledger is not None:
            ledger.regions_simulated, ledger.instructions_simulated = vals
    memo._spill.clear()
    memo._col_tick.clear()
    memo.touch()          # direct table writes: drop device-block mirrors


def _ledger_totals(memo):
    return [None if l is None else l.regions_simulated
            for l in memo.ledgers]


def _mixed_specs():
    """3 same-shape RandomUnit requests (coalesce via stacking) + 2
    identical Centroid requests (coalesce as duplicates)."""
    plan_r = SamplingPlan(RFVClusters(), RandomUnit())
    plan_c = SamplingPlan(RFVClusters(), Centroid())
    return [
        SweepSpec(apps=APPS, plan=plan_r, config_indices=CFGS,
                  selection_seed=s) for s in (1, 2, 3)
    ] + [
        SweepSpec(apps=APPS, plan=plan_c, config_indices=CFGS),
        SweepSpec(apps=APPS, plan=plan_c, config_indices=CFGS),
    ]


# --------------------------------------------------------------------------
# coalescing == serial, bitwise
# --------------------------------------------------------------------------

def test_coalesced_matches_serial_bitwise(engine):
    """K coalesced same-shape sweeps == K serial run_sweep calls:
    estimates, memo tables, charges, counters, ledgers — all bitwise."""
    before = _memo_state(engine.memo)
    serial = [run_sweep(engine, s) for s in _mixed_specs()]
    state_serial = _memo_state(engine.memo)
    _memo_reset(engine.memo, before)

    coal = run_coalesced_sweeps(engine, _mixed_specs())
    state_coal = _memo_state(engine.memo)
    _memo_reset(engine.memo, before)

    marker = sampling_plan.last_sweep_dispatch()
    assert marker["coalesced"] == 2          # last group: the Centroid pair
    assert marker["batch_shape"] == (2 * len(APPS), len(CFGS))

    for st, ct in zip(serial, coal):
        for col in ("estimate", "err_pct", "truth", "n_units"):
            np.testing.assert_array_equal(
                np.asarray(st.column(col), float),
                np.asarray(ct.column(col), float))
        assert [r.app for r in st.rows] == [r.app for r in ct.rows]

    for a, b in zip(state_serial[:3], state_coal[:3]):
        np.testing.assert_array_equal(a, b)   # mask, cpi, charges
    assert state_serial[3:] == state_coal[3:]  # hit/miss counters, ledgers


def test_coalesce_key_and_predicate(engine):
    plan = SamplingPlan(RFVClusters(), Centroid())
    a = prepare_sweep(engine, SweepSpec(apps=APPS, plan=plan,
                                        config_indices=CFGS))
    b = prepare_sweep(engine, SweepSpec(apps=APPS, plan=plan,
                                        config_indices=CFGS,
                                        selection_seed=9))
    assert coalesce_key(a) == coalesce_key(b)
    c = prepare_sweep(engine, SweepSpec(apps=APPS, plan=plan,
                                        config_indices=(0, 1)))
    assert coalesce_key(a) != coalesce_key(c)   # different config tuple

    assert coalescible(SweepSpec(apps=APPS, plan=plan))
    assert not coalescible(SweepSpec(apps=APPS))              # SRS
    assert not coalescible(SweepSpec(apps=APPS, plan=plan, fused=False))
    assert not coalescible(
        SweepSpec(apps=APPS, plan=plan, trials=TrialSpec(trials=4)))


def test_singleton_groups_fall_back_to_serial(engine):
    """A lone coalescible request takes the plain run_sweep path (no
    stacked dispatch) and still matches it bitwise."""
    spec = SweepSpec(apps=APPS, plan=SamplingPlan(RFVClusters(), Centroid()),
                     config_indices=CFGS)
    before = _memo_state(engine.memo)
    direct = run_sweep(engine, spec)
    _memo_reset(engine.memo, before)
    (via_batcher,) = run_coalesced_sweeps(engine, [spec])
    _memo_reset(engine.memo, before)
    marker = sampling_plan.last_sweep_dispatch()
    assert "coalesced" not in marker
    np.testing.assert_array_equal(direct.column("estimate"),
                                  via_batcher.column("estimate"))


# --------------------------------------------------------------------------
# eviction / spill accounting
# --------------------------------------------------------------------------

def test_evicted_column_recharged_exactly_once(engine):
    """Evict (drop) -> the next request re-charges exactly the original
    cost, once; a stale fused device-block mirror would charge zero."""
    memo = engine.memo
    spec = SweepSpec(apps=APPS, plan=SamplingPlan(RFVClusters(), Centroid()),
                     config_indices=CFGS)
    before = _memo_state(engine.memo)
    t0 = _ledger_totals(memo)

    table = run_sweep(engine, spec)
    t1 = _ledger_totals(memo)
    assert sum(a - b for a, b in zip(t1, t0)) > 0
    run_sweep(engine, spec)                    # warm repeat: pure hits
    assert _ledger_totals(memo) == t1

    ver = memo.version
    cols = memo.cols_for([engine.configs[i] for i in CFGS])
    memo.evict(cols)                           # drop, no spill
    assert memo.version > ver                  # mirror caches invalidated
    run_sweep(engine, spec)                    # re-charged exactly once:
    # the full cold cost (every selected unit at every config), even for
    # cells the pre-evict run had hit in build-time fills
    cold = {r.app: r.n_units * len(CFGS) for r in table.rows}
    np.testing.assert_array_equal(
        np.subtract(_ledger_totals(memo), t1),
        [cold[n] for n in memo.names])
    t2 = _ledger_totals(memo)
    run_sweep(engine, spec)                    # and warm again
    assert _ledger_totals(memo) == t2
    _memo_reset(engine.memo, before)


def test_spilled_column_restores_free(engine):
    """Host-spill -> re-request restores transparently in cols_for with
    ZERO new charges: ledger totals equal the never-evicted run."""
    memo = engine.memo
    spec = SweepSpec(apps=APPS, plan=SamplingPlan(RFVClusters(), Centroid()),
                     config_indices=CFGS)
    before = _memo_state(engine.memo)

    run_sweep(engine, spec)
    t1 = _ledger_totals(memo)
    mask1, cpi1 = memo.mask.copy(), memo.cpi.copy()

    cols = memo.cols_for([engine.configs[i] for i in CFGS])
    ver = memo.version
    memo.spill(cols)
    assert memo.version > ver
    resident = memo.resident_columns()
    assert not set(int(c) for c in cols) & set(resident)

    run_sweep(engine, spec)                    # unspill + serve, free
    assert _ledger_totals(memo) == t1          # == never-evicted
    np.testing.assert_array_equal(memo.mask, mask1)
    np.testing.assert_array_equal(memo.cpi, cpi1)
    _memo_reset(engine.memo, before)


def test_evict_to_cap_policies():
    """LRU evicts the stalest columns; charge policy the cheapest-to-
    recompute; both leave exactly ``cap`` resident."""
    def _fill(bank, cfg, k):
        bank.fill([0], np.arange(k)[None], None, [cfg],
                  values=np.ones((1, 1, k), np.float32))

    memo = MemoBank()
    memo.add_app("a", 8, Ledger())
    for i, cfg in enumerate(CONFIGS[:4]):       # touch order: 0,1,2,3
        _fill(memo, cfg, 2 + 2 * i)
    memo.cols_for([CONFIGS[1]])                 # re-touch col 1
    victims = memo.evict_to_cap(2, policy="lru")
    assert sorted(int(v) for v in victims) == [0, 2]   # stalest two
    assert sorted(memo.resident_columns()) == [1, 3]

    memo2 = MemoBank()
    memo2.add_app("a", 8, Ledger())
    for i, cfg in enumerate(CONFIGS[:3]):       # charges: 2, 4, 6 regions
        _fill(memo2, cfg, 2 + 2 * i)
    victims = memo2.evict_to_cap(1, policy="charge")
    assert sorted(int(v) for v in victims) == [0, 1]   # cheapest first
    assert memo2.resident_columns() == [2]

    with pytest.raises(ValueError, match="policy"):
        memo2.evict_to_cap(1, policy="fifo")


def test_absorb_picks_dedups_requests():
    """Dense-request scatter: duplicate picks across configs charge each
    distinct (config, region) cell once; a repeat call charges zero."""
    memo = MemoBank()
    memo.add_app("a", 8, Ledger())
    cols = memo.cols_for(CONFIGS[:2])
    picks = np.array([[1, 2, 2]])
    valid = np.ones((1, 3), bool)
    values = np.full((1, 2, 3), 1.5)
    n_miss = memo.absorb_picks([0], cols, picks, valid, values)
    assert int(n_miss.sum()) == 4              # 2 distinct x 2 configs
    assert memo.ledgers[0].regions_simulated == 4
    n_miss = memo.absorb_picks([0], cols, picks, valid, values)
    assert int(n_miss.sum()) == 0              # warm: all hits


def test_merge_rejects_mismatched_universes():
    a, b = MemoBank(), MemoBank()
    a.add_app("505.mcf_r", 8, None)
    b.add_app("505.mcf_r", 12, None)
    with pytest.raises(ValueError, match=r"mismatched app universes.*"
                                         r"505\.mcf_r"):
        a.merge(b)


# --------------------------------------------------------------------------
# SweepService loop
# --------------------------------------------------------------------------

def test_service_serves_and_coalesces(engine):
    before = _memo_state(engine.memo)
    service = SweepService(engine)
    ids = [service.submit(s) for s in _mixed_specs()]
    assert service.pending == len(ids)
    served = service.drain()
    assert served == len(ids)

    direct = run_coalesced_sweeps(engine, _mixed_specs())
    _memo_reset(engine.memo, before)
    for rid, table in zip(ids, direct):
        np.testing.assert_array_equal(service.result(rid).column("estimate"),
                                      table.column("estimate"))
    stats = service.stats()
    assert stats.completed == len(ids)
    assert stats.coalesced_requests == 5       # both groups stacked
    assert stats.dispatches == 2
    assert stats.latency_p95_s >= stats.latency_p50_s > 0
    _memo_reset(engine.memo, before)


def test_service_trial_dedup_matches_serial(engine):
    """Two identical TrialSpec requests: one execution + a charged-fill
    replay leaves counters identical to two serial run_trials calls."""
    spec = TrialSpec(trials=16, schemes=("random", "rfv"), config_index=0,
                     seed=3)
    before = _memo_state(engine.memo)
    run_trials(engine, spec, apps=APPS)
    run_trials(engine, spec, apps=APPS)
    state_serial = _memo_state(engine.memo)
    _memo_reset(engine.memo, before)

    service = SweepService(engine)
    r1 = service.submit(spec, apps=APPS)
    r2 = service.submit(spec, apps=APPS)
    service.tick()
    state_service = _memo_state(engine.memo)
    _memo_reset(engine.memo, before)

    assert service.result(r1) is service.result(r2)   # deduped execution
    for a, b in zip(state_serial[:3], state_service[:3]):
        np.testing.assert_array_equal(a, b)
    assert state_serial[3:] == state_service[3:]

    with pytest.raises(ValueError, match="apps"):
        service.submit(spec)                   # TrialSpec needs apps=


def test_service_memo_cap_bounds_residency(engine):
    """memo_cap holds resident columns at/below the cap after every
    tick; spilled columns restore free when re-requested."""
    memo = engine.memo
    before = _memo_state(engine.memo)
    memo.evict([c for c in memo.resident_columns()])   # start cold
    cold = _memo_state(engine.memo)

    plan = SamplingPlan(RFVClusters(), Centroid())
    service = SweepService(engine, memo_cap=2, spill=True)
    for cfg_is in ((0, 1, 2), (3, 4, 5), (0, 1, 2)):
        service.submit(SweepSpec(apps=APPS, plan=plan,
                                 config_indices=cfg_is))
        service.tick()
        assert len(memo.resident_columns()) <= 2

    stats = service.stats()
    assert stats.evicted_cols > 0
    assert stats.peak_resident_cols <= 3       # one tick's working set
    capped_totals = _ledger_totals(memo)

    # every charge was paid once: spill means re-requests restored free,
    # so totals equal the cap-less schedule's
    _memo_reset(engine.memo, cold)
    uncapped = SweepService(engine)
    for cfg_is in ((0, 1, 2), (3, 4, 5), (0, 1, 2)):
        uncapped.submit(SweepSpec(apps=APPS, plan=plan,
                                  config_indices=cfg_is))
    uncapped.drain()
    assert _ledger_totals(memo) == capped_totals
    _memo_reset(engine.memo, before)
