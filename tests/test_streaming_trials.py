"""Streaming Monte-Carlo trial engine tests (chunked scan + precision).

Covers the streaming-reduction contracts:

* chunked == unchunked bitwise at matching seeds (the per-block PRNG
  fold-in contract),
* streamed ``TrialStats`` == dense per-trial reductions (coverage exact,
  sketch quantiles within grid resolution),
* sharded ``("app", "trial")`` totals == single-device totals (needs
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, as in
  ``scripts/ci.sh``),
* the 10^5-trial coverage-calibration gate: empirical coverage of the
  calibrated/conservative schemes stays >= 90% at nominal 95% while the
  f32 accumulator policy streams every chunk,
* ``PrecisionPolicy`` plumbing and the jitted Table IV sizing program.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.precision import PrecisionPolicy, resolve_precision
from repro.core.sampling import tables as sampling_tables
from repro.core.sampling.two_phase import phase2_sizes_for_margin
from repro.experiments import ExperimentEngine, TrialSpec, run_trials
from repro.experiments.montecarlo import TRIAL_BLOCK, trial_uniforms

APP = "505.mcf_r"
APPS2 = ("505.mcf_r", "520.omnetpp_r")

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def engine():
    return ExperimentEngine()


# ------------------------------------------------ chunked == unchunked
def test_chunked_equals_unchunked_bitwise(engine):
    """Any chunking of the scan consumes identical per-block draws, so
    per-trial outputs are bitwise equal and integer stats exact."""
    spec = TrialSpec(trials=1000, schemes=("random", "dg"),
                     keep_trials=True)
    res1 = run_trials(engine, spec, apps=(APP,))                # 1 chunk
    res2 = run_trials(engine, dataclasses.replace(
        spec, chunk_size=TRIAL_BLOCK), apps=(APP,))             # 4 chunks
    for s in spec.schemes:
        np.testing.assert_array_equal(res1.estimates[s], res2.estimates[s])
        np.testing.assert_array_equal(res1.errors[s], res2.errors[s])
        np.testing.assert_array_equal(res1.half_widths[s],
                                      res2.half_widths[s])
        st1, st2 = res1.stats[s], res2.stats[s]
        np.testing.assert_array_equal(st1.count, st2.count)
        np.testing.assert_array_equal(st1.cover, st2.cover)
        np.testing.assert_array_equal(st1.err_hist, st2.err_hist)
        np.testing.assert_array_equal(st1.half_hist, st2.half_hist)
        # float moment sums only differ by summation order across chunks
        np.testing.assert_allclose(st1.err_sum, st2.err_sum, rtol=1e-5)


def test_trial_uniforms_matches_block_contract(engine):
    """The dense reference helper reproduces the exact draws the chunked
    scan consumes — trial t at offset t % TRIAL_BLOCK of block
    t // TRIAL_BLOCK, regardless of the requested trial count."""
    spec = TrialSpec(trials=600, schemes=("random",))
    u_all = trial_uniforms(spec, "random", 2, 5)
    assert u_all.shape == (2, 600, 5)
    u_short = trial_uniforms(dataclasses.replace(spec, trials=100),
                             "random", 2, 5)
    np.testing.assert_array_equal(u_all[:, :100], u_short)


def test_chunk_size_must_align_to_block():
    with pytest.raises(ValueError, match="multiple of TRIAL_BLOCK"):
        TrialSpec(chunk_size=100)


# ------------------------------------------------ streamed vs dense parity
def test_streamed_stats_match_dense_reductions(engine):
    """TrialStats totals agree with dense per-trial reductions: counts
    exactly, moments to rounding, sketch quantiles to grid resolution
    (the satellite parity test for p95/half_width_pct at 1000 trials)."""
    spec = TrialSpec(trials=1000, keep_trials=True)
    res = run_trials(engine, spec, apps=APPS2)
    truth = np.stack(
        [e.truth[spec.config_index] for e in engine.build(APPS2)])
    for s in spec.schemes:
        st = res.stats[s]
        est, half = res.estimates[s], res.half_widths[s]
        err = res.errors[s]
        assert st.count.tolist() == [spec.trials, spec.trials]
        # coverage counts vs the dense |est - truth| <= half definition
        # (NaN half-widths never cover); same-op f32 host recomputation
        dense_cover = np.where(
            np.isnan(half), False,
            np.abs(est - truth[:, None].astype(est.dtype))
            <= np.nan_to_num(half)).mean(axis=1)
        np.testing.assert_allclose(res.coverage[s], dense_cover,
                                   atol=2.0 / spec.trials)
        # p95 from the sketch vs np.percentile on the dense errors
        np.testing.assert_allclose(res.p95(s),
                                   np.percentile(err, 95, axis=1),
                                   rtol=0.03)
        # streamed mean half-width == nanmean of dense half-widths
        # (f32 accumulation vs f64 host sum)
        np.testing.assert_allclose(np.asarray(st.half_mean),
                                   np.nanmean(half, axis=1), rtol=1e-4)
        # streamed error moments == dense sums (accumulated in f32)
        np.testing.assert_allclose(np.asarray(st.err_sum),
                                   err.sum(axis=1), rtol=1e-4)


def test_half_width_pct_streams(engine):
    """half_width_pct works off accumulated moments — identical with and
    without dense per-trial arrays materialized."""
    spec = TrialSpec(trials=512, schemes=("dg",))
    truth = np.asarray([1.0])
    r_keep = run_trials(engine, dataclasses.replace(spec, keep_trials=True),
                        apps=(APP,))
    r_stream = run_trials(engine,
                          dataclasses.replace(spec, keep_trials=False),
                          apps=(APP,))
    assert not r_stream.estimates and not r_stream.half_widths
    np.testing.assert_allclose(r_keep.half_width_pct("dg", truth),
                               r_stream.half_width_pct("dg", truth),
                               rtol=1e-6)
    dense = 100.0 * np.nanmean(r_keep.half_widths["dg"], axis=1)
    np.testing.assert_allclose(r_keep.half_width_pct("dg", truth), dense,
                               rtol=1e-4)


def test_run_trials_warm_call_does_not_recompile(engine, compile_counter):
    """A second identical ``run_trials`` hits the compiled chunk scan.

    Same spec, apps, chunking — the trial program must come back from
    the jit cache; a retrace here means the chunk scan's shapes or
    static args are derived from something unstable (recompile guard
    teeth on the streaming hot path)."""
    spec = TrialSpec(trials=TRIAL_BLOCK * 2, schemes=("random",))
    run_trials(engine, spec, apps=(APP,))         # warm: trace + compile
    with compile_counter.no_recompile("second identical run_trials"):
        run_trials(engine, spec, apps=(APP,))


# ------------------------------------------------ scale + calibration gate
def test_100k_trials_stream_with_calibrated_coverage(engine):
    """10^5 trials run through the chunked scan in bounded memory (no
    dense per-trial arrays) and the f32 accumulator policy keeps the
    calibrated/conservative schemes' empirical coverage >= 90% at
    nominal 95% — the gate proving streaming + f32 accumulation does not
    silently degrade calibration at scale."""
    spec = TrialSpec(trials=100_000, schemes=("random", "rfv"))
    res = run_trials(engine, spec, apps=(APP,))
    assert not res.estimates            # > keep threshold: streamed only
    for s in spec.schemes:
        st = res.stats[s]
        assert int(st.count[0]) == spec.trials
        assert float(res.coverage[s][0]) >= 0.90, (
            f"{s} coverage degraded: {res.coverage[s]}")
    # the quantile sketch is populated and readable at scale
    assert np.isfinite(res.p95("random")).all()


# ------------------------------------------------ sharded (app x trial)
@needs_devices
def test_app_trial_mesh_totals_match_single_device(engine):
    """(app x trial) sharded totals == single-device: integer leaves
    bitwise, dense per-trial arrays bitwise (the same PRNG blocks are
    evaluated, merely on different devices), moments to rounding."""
    from repro.launch.mesh import make_app_trial_mesh

    spec = TrialSpec(trials=1000, keep_trials=True)
    single = run_trials(engine, spec, apps=APPS2, mesh=None)
    mesh = make_app_trial_mesh(app_devices=2)           # 2 apps x 4 trial
    eng2 = ExperimentEngine(mesh=mesh)
    sharded = run_trials(eng2, spec, apps=APPS2)
    for s in spec.schemes:
        st1, st2 = single.stats[s], sharded.stats[s]
        np.testing.assert_array_equal(st1.count, st2.count)
        np.testing.assert_array_equal(st1.cover, st2.cover)
        np.testing.assert_array_equal(st1.err_hist, st2.err_hist)
        np.testing.assert_allclose(st1.err_sum, st2.err_sum, rtol=1e-5)
        np.testing.assert_array_equal(single.estimates[s],
                                      sharded.estimates[s])
        np.testing.assert_array_equal(single.half_widths[s],
                                      sharded.half_widths[s])


@needs_devices
def test_trial_axis_splits_chunks():
    """The trial mesh axis actually divides each chunk's blocks."""
    from repro.distributed.appaxis import app_trial_axes
    from repro.launch.mesh import make_app_trial_mesh

    mesh = make_app_trial_mesh(app_devices=2)
    app_axis, trial_axis = app_trial_axes(mesh)
    assert (app_axis, trial_axis) == ("app", "trial")
    assert mesh.shape["app"] == 2 and mesh.shape["trial"] == 4


# ------------------------------------------------ precision policy
def test_precision_policy_contract():
    pp = PrecisionPolicy()
    assert (pp.trace, pp.accum, pp.host) == ("float32", "float32",
                                             "float64")
    assert not pp.needs_x64
    assert PrecisionPolicy(trace="float64").needs_x64
    assert PrecisionPolicy(trace=np.float64).trace == "float64"
    with pytest.raises(ValueError, match="must be one of"):
        PrecisionPolicy(trace="float16")
    # hashable + value equality (lru_cache / jit static keys)
    assert PrecisionPolicy() == PrecisionPolicy(trace=np.float32)
    assert len({PrecisionPolicy(), PrecisionPolicy.default()}) == 1
    assert resolve_precision(None, None) == PrecisionPolicy()
    assert resolve_precision(None, pp) is pp


def test_trials_under_x64_policy_agree_with_f32(engine):
    """A full-f64 policy reproduces the f32 policy's *distribution* —
    the cross-check that the default f32 trace/accum loses nothing that
    matters. Per-trial values are NOT comparable across trace dtypes
    (f64 uniforms consume different PRNG bits than f32), so the
    comparison is over aggregate statistics at 2048 trials."""
    spec32 = TrialSpec(trials=2048, schemes=("dg",), keep_trials=True)
    spec64 = dataclasses.replace(
        spec32, precision=PrecisionPolicy(trace="float64", accum="float64"))
    r32 = run_trials(engine, spec32, apps=(APP,))
    r64 = run_trials(engine, spec64, apps=(APP,))
    assert r64.estimates["dg"].dtype == np.float64
    np.testing.assert_allclose(np.mean(r32.estimates["dg"], axis=1),
                               np.mean(r64.estimates["dg"], axis=1),
                               rtol=1e-2)
    np.testing.assert_allclose(np.asarray(r32.stats["dg"].half_mean),
                               np.asarray(r64.stats["dg"].half_mean),
                               rtol=0.1)
    assert abs(float(r32.coverage["dg"][0])
               - float(r64.coverage["dg"][0])) <= 0.04


def test_trial_stats_merge_matches_split_accumulation():
    """Host-side merge of two partial accumulations == one accumulation
    over the concatenation (the additive-leaves contract the in-program
    psum relies on)."""
    rng = np.random.default_rng(0)
    err = rng.uniform(0.1, 30.0, size=(2, 64))
    half = rng.uniform(1e-3, 2.0, size=(2, 64))
    covered = rng.random((2, 64)) < 0.9
    valid = np.ones((2, 64), bool)
    whole = sampling_tables.trial_stats_update(
        sampling_tables.trial_stats_init((2,)), err, half, covered, valid)
    a = sampling_tables.trial_stats_update(
        sampling_tables.trial_stats_init((2,)), err[:, :40], half[:, :40],
        covered[:, :40], valid[:, :40])
    b = sampling_tables.trial_stats_update(
        sampling_tables.trial_stats_init((2,)), err[:, 40:], half[:, 40:],
        covered[:, 40:], valid[:, 40:])
    merged = sampling_tables.trial_stats_merge(a, b)
    np.testing.assert_array_equal(whole.count, merged.count)
    np.testing.assert_array_equal(whole.cover, merged.cover)
    np.testing.assert_array_equal(whole.err_hist, merged.err_hist)
    np.testing.assert_allclose(whole.err_sum, merged.err_sum, rtol=1e-6)
    # sketch quantiles track the dense percentile
    np.testing.assert_allclose(merged.err_quantile(0.95),
                               np.percentile(err, 95, axis=1), rtol=0.05)


# ------------------------------------------------ jitted Table IV sizing
def test_phase2_sizing_jit_matches_host_reference():
    """The jitted allocation program reproduces the historic host-numpy
    sizing exactly (f64 host-parity policy on CPU)."""
    from repro.core.sampling.allocation import neyman_allocation
    from repro.core.sampling.types import critical_value

    w = np.asarray([0.4, 0.3, 0.2, 0.1])
    s = np.asarray([1.5, 0.7, 0.3, 0.05])
    z = critical_value(0.95, None)
    margin, p1n, bvar = 0.05, 400, 0.09
    v_budget = (margin / z) ** 2 - bvar / p1n
    n_total = int(np.ceil((w * s).sum() ** 2 / v_budget))
    n_total = min(max(n_total, 2 * len(w)), 10**7)
    ref = neyman_allocation(w, s, n_total, min_per_stratum=2)
    got = phase2_sizes_for_margin(w, s, p1n, bvar,
                                  target_margin_abs=margin)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # proportional allocation routes through the same jitted program
    got_p = phase2_sizes_for_margin(w, s, p1n, bvar,
                                    target_margin_abs=margin,
                                    allocation="proportional")
    assert int(np.asarray(got_p).sum()) >= 2 * len(w)
    with pytest.raises(ValueError, match="unattainable"):
        phase2_sizes_for_margin(w, s, 10, 1.0, target_margin_abs=margin)