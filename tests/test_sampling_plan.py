"""SamplingPlan API tests: string-shim parity, registry round-trips,
plug-in extensibility (RankedSetUnit), and the jitted on-device
sweep-estimation contract.

The parity suite is the acceptance bar of the plan redesign: every
legacy ``(scheme, policy)`` string pair must produce a *bitwise
identical* ``ResultsTable`` through the deprecated shim and through the
explicit ``SamplingPlan`` spelling — the shim constructs the equivalent
plan, so both run the same code path.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import repro.core.sampling.plan as plan_mod
from repro.core.sampling import (Centroid, DaleniusGurney, RandomUnit,
                                 RankedSetUnit, RFVClusters, SamplingPlan,
                                 StratumMean, TwoPhaseFlow)
from repro.experiments import (ExperimentEngine, ResultsTable, SweepRow,
                               SweepSpec, TrialSpec, plan_selection,
                               run_sweep, run_trials, trial_uniforms)

APP = "505.mcf_r"       # smallest population: fast to build

LEGACY_SCHEMES = ("bbv", "rfv", "dg")
LEGACY_POLICIES = ("centroid", "mean", "random")


@pytest.fixture(scope="module")
def engine():
    eng = ExperimentEngine()
    eng.app(APP)
    return eng


# ------------------------------------------------- string-vs-plan parity
@pytest.mark.parametrize("scheme", LEGACY_SCHEMES)
@pytest.mark.parametrize("policy", LEGACY_POLICIES)
def test_legacy_strings_bitwise_equal_plan(engine, scheme, policy):
    """Every legacy (scheme, policy) pair == its plan via the shim,
    row-for-row bitwise (same floats, same labels)."""
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = SweepSpec(apps=(APP,), scheme=scheme, policy=policy,
                           config_indices=(0, 6), selection_seed=11)
    modern = SweepSpec(apps=(APP,),
                       plan=SamplingPlan.from_strings(scheme, policy),
                       config_indices=(0, 6), selection_seed=11)
    t_legacy = run_sweep(engine, legacy)
    t_modern = run_sweep(engine, modern)
    assert t_legacy.rows == t_modern.rows       # SweepRow dataclass eq
    assert all(r.scheme == scheme for r in t_modern.rows)


def test_scheme_selection_shim_warns_and_matches(engine):
    from repro.experiments import scheme_selection
    exp = engine.app(APP)
    with pytest.warns(DeprecationWarning, match="scheme_selection"):
        sel_a, w_a = scheme_selection(exp, "rfv", "centroid")
    sel_b, w_b = plan_selection(exp, SamplingPlan(RFVClusters(), Centroid()))
    np.testing.assert_array_equal(w_a, w_b)
    for a, b in zip(sel_a, sel_b):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------- registry round-trips
def test_registry_round_trip():
    assert set(LEGACY_SCHEMES) <= set(plan_mod.registered_stratifiers())
    assert {"centroid", "mean", "random", "ranked_set"} \
        <= set(plan_mod.registered_policies())
    plan = SamplingPlan.from_strings("dg", "mean")
    assert isinstance(plan.stratifier, DaleniusGurney)
    assert isinstance(plan.policy, StratumMean)
    assert plan.scheme == "dg" and plan.policy_name == "mean"
    # "cpi" is the historic TwoPhaseFlow alias for the same design: it
    # resolves, but is NOT a second scheme name (no separate PRNG
    # fold-in, no separate row label)
    assert isinstance(plan_mod.make_stratifier("cpi"), DaleniusGurney)
    assert "cpi" not in plan_mod.registered_stratifiers()
    with pytest.raises(ValueError, match="unknown trial scheme"):
        TrialSpec(schemes=("cpi",))
    with pytest.warns(DeprecationWarning):
        spec = SweepSpec(apps=(APP,), scheme="cpi")
    assert spec.scheme == "dg"               # label normalized


def test_registry_unknown_names_raise_with_listing():
    with pytest.raises(ValueError, match="unknown stratifier.*registered"):
        plan_mod.make_stratifier("bogus")
    with pytest.raises(ValueError, match="unknown selection policy"):
        plan_mod.make_policy("bogus")


def test_make_stratifier_filters_params():
    """Shims pass one kwargs superset; factories take only their fields."""
    s = plan_mod.make_stratifier("rfv", num_strata=7, seed=3,
                                 backend="jnp", per_stratum=4)
    assert s == RFVClusters(num_strata=7, seed=3, backend="jnp")
    p = plan_mod.make_policy("random", per_stratum=4, num_strata=7)
    assert p == RandomUnit(per_stratum=4)


def test_spec_validation_at_construction():
    with pytest.raises(ValueError, match="unknown stratifier"):
        SweepSpec(apps=(APP,), scheme="bogus")
    with pytest.raises(ValueError, match="unknown selection policy"):
        SweepSpec(apps=(APP,), scheme="rfv", policy="bogus")
    with pytest.raises(ValueError, match="no selection policy"):
        SweepSpec(apps=(APP,), scheme="srs", policy="centroid")
    with pytest.raises(ValueError, match="unknown trial scheme"):
        TrialSpec(schemes=("random", "bogus"))
    # stale strings alongside plan= must not be silently relabeled
    with pytest.raises(ValueError, match="conflict with plan"):
        SweepSpec(apps=(APP,), scheme="bbv",
                  plan=SamplingPlan(RFVClusters(), Centroid()))
    # matching strings (or the defaults) are fine
    spec = SweepSpec(apps=(APP,), scheme="rfv", policy="centroid",
                     plan=SamplingPlan(RFVClusters(), Centroid()))
    assert spec.scheme == "rfv"


def test_plans_are_static_pytrees():
    import jax
    plan = SamplingPlan(RFVClusters(), RandomUnit(per_stratum=2))
    leaves = jax.tree_util.tree_leaves(plan)
    assert leaves == []                      # hyperparameters, not data
    back = jax.tree_util.tree_map(lambda x: x, plan)
    assert back == plan


# ------------------------------------------------- plug-in extensibility
def test_ranked_set_unit_runs_through_sweep(engine):
    """The in-repo order-statistic policy reaches run_sweep purely via
    the registry — no engine/sweep edits — and its picks match a numpy
    rank-within-stratum reference."""
    spec = SweepSpec(
        apps=(APP,), plan=SamplingPlan.from_strings("rfv", "ranked_set"),
        config_indices=(6,))
    assert spec.policy == "ranked_set"       # row label from the plan
    table = run_sweep(engine, spec)
    assert len(table) == 1
    assert np.isfinite(table.rows[0].estimate)

    exp = engine.app(APP)
    sel, _ = plan_selection(exp, SamplingPlan(RFVClusters(),
                                              RankedSetUnit()))
    base = exp.cpi0_1
    for h in range(exp.num_strata):
        members = np.flatnonzero(exp.rfv_labels == h)
        if members.size == 0:
            assert sel[h].size == 0
            continue
        ranked = members[np.argsort(base[members], kind="stable")]
        median = ranked[int(round(0.5 * (members.size - 1)))]
        assert sel[h][0] == exp.idx1[median], h


def test_plugin_policy_via_registry_only(engine):
    """A policy defined against plan.py alone (no engine imports) plugs
    into the batched selection path."""

    @dataclasses.dataclass(frozen=True)
    class FirstUnit(plan_mod.SelectionPolicy):
        """Deterministic reference plug-in: lowest-index member unit."""

        name = "first_unit"

        def __call__(self, ctx):
            # offsets point at each stratum's first member in index order
            pos = np.minimum(ctx.offsets, max(ctx.order.shape[1] - 1, 0))
            return np.take_along_axis(ctx.order, pos, axis=1)

    plan_mod.register_policy("first_unit", FirstUnit)
    try:
        exp = engine.app(APP)
        sel, _ = plan_selection(
            exp, SamplingPlan.from_strings("dg", "first_unit"))
        for h in range(exp.num_strata):
            members = np.flatnonzero(exp.dg_labels == h)
            if members.size:
                assert sel[h][0] == exp.idx1[members.min()], h
            else:
                assert sel[h].size == 0
    finally:
        plan_mod._POLICIES.pop("first_unit", None)


def test_plugin_stratifier_runs_trials(engine):
    """A registered stratifier plug-in is a valid TrialSpec scheme with
    draws independent of the canonical schemes'."""

    @dataclasses.dataclass(frozen=True)
    class RFVAgain(RFVClusters):
        """Plug-in reusing the engine's RFV artifacts under a new name."""

        name = "rfv2"

    plan_mod.register_stratifier("rfv2", RFVAgain)
    try:
        spec = TrialSpec(trials=8, schemes=("rfv", "rfv2"), config_index=6)
        res = run_trials(engine, spec, apps=(APP,))
        assert res.estimates["rfv2"].shape == (1, 8)
        # same stratification, different fold-in position => new draws
        u1 = trial_uniforms(spec, "rfv", 1, 20)
        u2 = trial_uniforms(spec, "rfv2", 1, 20)
        assert not np.allclose(u1, u2)
        assert plan_mod.trial_scheme_index("rfv2", ("random", "bbv", "rfv",
                                                    "dg")) >= 4
    finally:
        plan_mod._STRATIFIERS.pop("rfv2", None)


# ------------------------------------------------- on-device estimation
def test_sweep_estimates_dispatch_marker_and_parity(engine):
    """Stratified sweep estimates come from the jitted StratumTables
    program (dispatch marker set, correct lane geometry) and equal the
    host-numpy weighted-mean reference."""
    from repro.experiments.engine import plan_selection_bank

    plan_mod._reset_sweep_dispatch()
    assert plan_mod.last_sweep_dispatch() is None
    plan = SamplingPlan(RFVClusters(), Centroid())
    table = run_sweep(engine, SweepSpec(apps=(APP,), plan=plan,
                                        config_indices=(0, 3, 6)))
    marker = plan_mod.last_sweep_dispatch()
    assert marker is not None, "no on-device sweep estimation dispatched"
    assert marker["batch_shape"] == (1, 3)
    assert marker["num_strata"] == engine.num_strata

    exp = engine.app(APP)
    picks, valid, weights = plan_selection_bank([exp], plan)
    cpi = exp.cpi_for(picks[0], config_indices=(0, 3, 6))   # (3, L)
    w = np.where(valid[0], weights[0], 0.0)
    ref = (cpi * w[None, :]).sum(axis=1) / w.sum()
    np.testing.assert_allclose(table.column("estimate"), ref, rtol=1e-9)


def test_srs_sweep_has_no_plan_and_no_marker(engine):
    plan_mod._reset_sweep_dispatch()
    spec = SweepSpec(apps=(APP,), scheme="srs", config_indices=(0,))
    assert spec.plan is None
    run_sweep(engine, spec)
    assert plan_mod.last_sweep_dispatch() is None


# ------------------------------------------------- ResultsTable.matrix
def test_matrix_respects_spec_config_order():
    rows = [SweepRow(app="a", scheme="rfv", config_index=c,
                     estimate=float(c), truth=1.0, err_pct=0.0, n_units=1)
            for c in (6, 0, 3)]
    mat = ResultsTable(rows).matrix("estimate")
    # first-appearance order (6, 0, 3) — NOT sorted (0, 3, 6)
    np.testing.assert_array_equal(mat[:, 0], [6.0, 0.0, 3.0])


# ------------------------------------------------- TwoPhaseFlow shims
@pytest.fixture(scope="module")
def flow_inputs():
    rng = np.random.default_rng(5)
    y0 = rng.normal(2.0, 0.7, 240)
    feats = y0[:, None] + rng.normal(0.0, 0.1, (240, 4))
    idx1 = np.arange(240)
    return idx1, y0, feats


def test_flow_stratify_string_shim_matches_object(flow_inputs):
    idx1, y0, feats = flow_inputs
    flow = TwoPhaseFlow(population_size=1000,
                        rng=np.random.default_rng(0))
    with pytest.warns(DeprecationWarning, match="stratify"):
        legacy = flow.stratify(idx1, y0, feats, num_strata=6, scheme="rfv",
                               seed=3)
    modern = flow.stratify(idx1, y0, feats,
                           scheme=RFVClusters(num_strata=6, seed=3))
    np.testing.assert_array_equal(legacy.labels, modern.labels)
    np.testing.assert_allclose(legacy.centroids, modern.centroids)
    assert legacy.scheme == modern.scheme == "rfv"
    # the historic "cpi" name still resolves (to DaleniusGurney)
    with pytest.warns(DeprecationWarning):
        dg = flow.stratify(idx1, y0, None, num_strata=6, scheme="cpi")
    assert dg.scheme == "dg"
    # keywords conflicting with a Stratifier OBJECT raise, not ignore
    with pytest.raises(ValueError, match="conflicts with the Stratifier"):
        flow.stratify(idx1, y0, feats, scheme=RFVClusters(num_strata=6),
                      num_strata=30)
    with pytest.raises(ValueError, match="conflicts with the Stratifier"):
        flow.stratify(idx1, y0, feats, scheme=RFVClusters(num_strata=6),
                      kmeans_backend="np")
    # matching keywords are fine
    ok = flow.stratify(idx1, y0, feats,
                       scheme=RFVClusters(num_strata=6, seed=3),
                       num_strata=6, seed=3)
    np.testing.assert_array_equal(ok.labels, modern.labels)


def test_flow_select_string_shim_matches_object(flow_inputs):
    idx1, y0, feats = flow_inputs
    flow = TwoPhaseFlow(population_size=1000,
                        rng=np.random.default_rng(0))
    strat = flow.stratify(idx1, y0, feats,
                          scheme=RFVClusters(num_strata=6, seed=3))
    for policy_name, policy in (("centroid", Centroid()),
                                ("mean", StratumMean()),
                                ("random", RandomUnit())):
        with pytest.warns(DeprecationWarning, match="select"):
            legacy = flow.select(strat, policy=policy_name, seed=9)
        modern = flow.select(strat, policy=policy, seed=9)
        assert len(legacy) == len(modern)
        for a, b in zip(legacy, modern):
            np.testing.assert_array_equal(a, b)
    # per_stratum forwards through the string shim (RandomUnit field)
    with pytest.warns(DeprecationWarning):
        multi = flow.select(strat, policy="random", per_stratum=3, seed=9)
    assert max(s.size for s in multi) == 3
    # ... and overrides a policy OBJECT's own configuration too
    multi_obj = flow.select(strat, policy=RandomUnit(), per_stratum=3,
                            seed=9)
    for a, b in zip(multi, multi_obj):
        np.testing.assert_array_equal(a, b)
    # one-unit-only policies refuse a multi-unit request loudly
    with pytest.raises(NotImplementedError, match="one unit per stratum"):
        flow.select(strat, policy=RankedSetUnit(), per_stratum=2)


def test_trials_pool_kind_and_stratifier_instance(engine):
    """A stratifier's declared pool_kind drives trial cost semantics,
    and run_sweep's trial study uses the plan's configured stratifier
    instance (not a default-constructed registry copy)."""
    from repro.experiments import run_sweep

    resolved = []

    @dataclasses.dataclass(frozen=True)
    class FreeRFV(RFVClusters):
        """RFV labels over the phase-1 pool, census-valued (free)."""

        name = "rfvfree"
        pool_kind = "census"

        def resolve(self, exps):
            resolved.append(self)
            return super().resolve(exps)

    plan_mod.register_stratifier("rfvfree", FreeRFV)
    try:
        exp = engine.app(APP)
        before = exp.sim.ledger.regions_simulated
        run_trials(engine, TrialSpec(trials=4, schemes=("rfvfree",),
                                     config_index=4), apps=(APP,))
        # census-kind pool: analysis-only, nothing charged
        assert exp.sim.ledger.regions_simulated == before
        # run_sweep threads ITS stratifier instance into the MC study
        configured = FreeRFV(seed=1)
        resolved.clear()
        run_sweep(engine, SweepSpec(
            apps=(APP,), plan=SamplingPlan(configured, Centroid()),
            config_indices=(4,),
            trials=TrialSpec(trials=4, config_index=4)))
        assert any(s is configured for s in resolved)
    finally:
        plan_mod._STRATIFIERS.pop("rfvfree", None)


def test_ranked_set_select_local_via_flow(flow_inputs):
    idx1, y0, feats = flow_inputs
    flow = TwoPhaseFlow(population_size=1000,
                        rng=np.random.default_rng(0))
    strat = flow.stratify(idx1, y0, None,
                          scheme=DaleniusGurney(num_strata=5))
    picked = flow.select(strat, policy=RankedSetUnit(rank_fraction=1.0))
    for h in range(5):
        members = np.flatnonzero(strat.labels == h)
        if members.size:
            top = members[np.argmax(y0[members])]
            assert picked[h][0] == idx1[top]


def test_deprecated_warning_is_not_an_error_path(engine):
    """The shims must stay fully functional: a legacy spec drives a
    complete sweep with trials attached."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        spec = SweepSpec(apps=(APP,), scheme="dg", policy="random",
                         config_indices=(6,),
                         trials=TrialSpec(trials=8, config_index=6))
    table = run_sweep(engine, spec)
    assert table.rows[0].p95_err_pct is not None
