"""Batch-native k-means assignment kernel: oracle equivalence, batch-axis
invariances, dispatch-path regression, backend fallback contract."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import kmeans, kmeans_bank, kmeans_batch
from repro.core.clustering.kmeans import (BackendFallbackWarning,
                                          _reset_backend_warnings,
                                          resolve_backend)
from repro.kernels.kmeans_assign import ops as assign_ops
from repro.kernels.kmeans_assign.ops import kmeans_assign, last_dispatch
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref

RNG = np.random.default_rng(7)


def _problem(shape_x, shape_c):
    x = RNG.normal(size=shape_x).astype(np.float32)
    c = RNG.normal(size=shape_c).astype(np.float32)
    return x, c


# ---------------------------------------------------------- oracle equivalence
@pytest.mark.parametrize("b,n,k,d", [
    (3, 513, 7, 5),       # odd n remainder, odd k
    (2, 129, 130, 3),     # n just past one 128 sub-tile, k just past one pad
    (4, 100, 20, 15),     # paper-like shapes
    (1, 64, 3, 1),        # degenerate d
    (5, 511, 129, 33),    # both n and k one short of an alignment boundary
])
def test_batched_matches_oracle_odd_remainders(b, n, k, d):
    x, c = _problem((b, n, d), (b, k, d))
    l1, d1 = kmeans_assign(x, c)
    l2, d2 = kmeans_assign_ref(jnp.asarray(x), jnp.asarray(c))
    assert l1.shape == (b, n)
    assert (np.asarray(l1) == np.asarray(l2)).mean() > 0.999
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=3e-4, atol=3e-4)


def test_bank_rank4_matches_oracle():
    x, c = _problem((2, 3, 140, 6), (2, 3, 9, 6))
    l1, d1 = kmeans_assign(x, c)
    l2, d2 = kmeans_assign_ref(jnp.asarray(x), jnp.asarray(c))
    assert l1.shape == (2, 3, 140)
    assert (np.asarray(l1) == np.asarray(l2)).all()
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=3e-4, atol=3e-4)


# ------------------------------------------------------- batch-axis invariance
def test_batch_axis_permutation_invariance():
    b = 6
    x, c = _problem((b, 257, 11), (b, 13, 11))
    perm = RNG.permutation(b)
    l_base, d_base = (np.asarray(o) for o in kmeans_assign(x, c))
    l_perm, d_perm = (np.asarray(o) for o in kmeans_assign(x[perm], c[perm]))
    np.testing.assert_array_equal(l_perm, l_base[perm])
    np.testing.assert_allclose(d_perm, d_base[perm], rtol=1e-6, atol=1e-6)


def test_batched_lane_equals_unbatched_call():
    """Each lane of a batched dispatch matches its own 2-D dispatch —
    batching (and the padding it shares) cannot leak across lanes."""
    b = 4
    x, c = _problem((b, 200, 8), (b, 10, 8))
    lb, db = (np.asarray(o) for o in kmeans_assign(x, c))
    for i in range(b):
        li, di = (np.asarray(o) for o in kmeans_assign(x[i], c[i]))
        np.testing.assert_array_equal(lb[i], li)
        np.testing.assert_allclose(db[i], di, rtol=1e-6, atol=1e-6)


# --------------------------------------------------- dispatch-path regression
def test_kmeans_bank_uses_batch_native_grid():
    """Regression: the bank fit must feed its app axis to the kernel's
    batch grid axis natively. A vmap-of-pallas_call would strip the axis
    before ``ops.kmeans_assign`` ran, recording batch_shape == ()."""
    a, n, d = 3, 142, 6                      # fresh shape -> forces a trace
    x = RNG.normal(size=(a, n, d)).astype(np.float32)
    assign_ops._reset_dispatch_record()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        bank = kmeans_bank(x, 4, seed=3, backend="pallas")
    rec = last_dispatch()
    assert rec is not None, "pallas kernel never dispatched"
    assert rec["batch"] == a
    assert rec["batch_shape"] == (a,)
    assert rec["grid"][0] == a
    assert bank.backend == resolve_backend("pallas").active


def test_kmeans_batch_uses_batch_native_grid():
    n_seeds, n, d = 4, 151, 5                # fresh shape -> forces a trace
    x = RNG.normal(size=(n, d)).astype(np.float32)
    assign_ops._reset_dispatch_record()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        fits = kmeans_batch(x, 3, seeds=range(n_seeds), backend="pallas")
    rec = last_dispatch()
    assert rec is not None
    assert rec["batch"] == n_seeds
    assert rec["batch_shape"] == (n_seeds,)
    assert all(f.backend == resolve_backend("pallas").active for f in fits)


def test_bank_pallas_matches_jnp_backend():
    """The batched kernel path and the jnp oracle path agree lane-by-lane
    on a weighted (padded) bank fit."""
    a, n, d = 3, 120, 5
    x = RNG.normal(size=(a, n, d)).astype(np.float32)
    w = np.ones((a, n), np.float32)
    w[:, 100:] = 0.0                         # padded tail rows
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        bp = kmeans_bank(x, 4, weights=w, seed=1, backend="pallas")
    bj = kmeans_bank(x, 4, weights=w, seed=1, backend="jnp")
    assert (bp.labels == bj.labels).mean() > 0.99
    np.testing.assert_allclose(bp.inertia, bj.inertia, rtol=1e-4)


# ------------------------------------------------------------- backend policy
def test_pallas_fallback_warns_once_with_reason():
    _reset_backend_warnings()
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        assert resolve_backend("pallas").active == "pallas"
        return
    with pytest.warns(BackendFallbackWarning, match="platform="):
        resolved = resolve_backend("pallas")
    assert resolved.requested == "pallas"
    assert resolved.active == "pallas_interpret"
    assert "interpret" in resolved.reason
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # second resolve must be silent
        again = resolve_backend("pallas")
    assert again == resolved


def test_jnp_backend_never_warns_and_is_recorded():
    _reset_backend_warnings()
    x = RNG.normal(size=(80, 4)).astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", BackendFallbackWarning)
        fit = kmeans(x, 3, seed=0, backend="jnp")
    assert fit.backend == "jnp"


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda")


# ------------------------------------------------------------ shape contracts
def test_rank_and_batch_mismatches_rejected():
    x = np.zeros((2, 10, 3), np.float32)
    with pytest.raises(ValueError, match="rank mismatch"):
        kmeans_assign(x, np.zeros((4, 3), np.float32))
    with pytest.raises(ValueError, match="batch mismatch"):
        kmeans_assign(x, np.zeros((3, 4, 3), np.float32))
    with pytest.raises(ValueError, match="dim mismatch"):
        kmeans_assign(x, np.zeros((2, 4, 5), np.float32))
