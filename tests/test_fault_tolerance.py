"""Elastic checkpointed fleet gates: resume == uninterrupted, bitwise.

The headline claim of the fault-tolerance subsystem
(``repro.experiments.resumable`` + ``repro.runtime.{faults, checkpoint,
elastic, health}``): a sweep or Monte-Carlo study killed at randomized
restart quanta — cleanly after a checkpoint publishes, before it is
written, or mid-write with the tmp dir corrupted — and resumed
(possibly on a shrunken device pool, re-meshed elastically) produces
bitwise-identical estimates, ledger charge totals and ``TrialStats``
moments to the same run uninterrupted.

Equivalence discipline (see ``repro.experiments.resumable``):

* killed/resumed vs uninterrupted **of the same blocking**: bitwise on
  everything, including float moment sums (identical summation order);
* vs a **different blocking** (the plain drivers, or an elastic
  re-mesh changing the reduction order): integer stats leaves and
  dense per-trial arrays stay bitwise, float moments agree to
  summation order (allclose).

The sharded legs run under ``CI_FORCE_DEVICES=8`` (``scripts/ci.sh``);
the wider scheme matrix is marked ``slow`` for the dedicated CI leg.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.sampling.plan import SamplingPlan
from repro.experiments import (ExperimentEngine, SweepSpec, TrialSpec,
                               run_sweep, run_sweep_resumable, run_trials,
                               run_trials_resumable, supervise_sweep,
                               supervise_trials)
from repro.experiments.montecarlo import TRIAL_BLOCK
from repro.runtime.checkpoint import (ManifestMismatch, latest_step,
                                      restore_checkpoint, save_checkpoint)
from repro.runtime.faults import (FAULT_KINDS, FaultEvent, FaultPlan,
                                  HostLoss)

APPS = ("505.mcf_r", "520.omnetpp_r")
CONFIGS = (0, 6)

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _capture_engines():
    """A ``make_engine`` for the supervisors that records every engine it
    builds, so tests can inspect the final attempt's memo bank."""
    engines = []

    def make(mesh):
        eng = ExperimentEngine(mesh=mesh)
        engines.append(eng)
        return eng

    return engines, make


def _sweep_spec(scheme, policy, fused):
    if scheme == "srs":
        return SweepSpec(apps=APPS, config_indices=CONFIGS, fused=fused)
    return SweepSpec(apps=APPS,
                     plan=SamplingPlan.from_strings(scheme, policy),
                     config_indices=CONFIGS, fused=fused)


def _assert_rows_bitwise(got, want):
    assert len(got.rows) == len(want.rows)
    for r, b in zip(got.rows, want.rows):
        assert (r.app, r.scheme, r.config_index) == \
               (b.app, b.scheme, b.config_index)
        assert np.float64(r.estimate).tobytes() == \
               np.float64(b.estimate).tobytes()
        assert np.float64(r.err_pct).tobytes() == \
               np.float64(b.err_pct).tobytes()
        assert r.n_units == b.n_units
        if b.margin_pct is not None:
            assert np.float64(r.margin_pct).tobytes() == \
                   np.float64(b.margin_pct).tobytes()


def _assert_stats_equal(got, want, *, exact_floats):
    """Every TrialStats leaf: integers bitwise always; floats bitwise for
    same-blocking comparisons, to summation order across blockings."""
    leaves_g = jax.tree_util.tree_flatten_with_path(got)[0]
    leaves_w = jax.tree_util.tree_flatten_with_path(want)[0]
    assert len(leaves_g) == len(leaves_w)
    for (path, g), (_, w) in zip(leaves_g, leaves_w):
        g, w = np.asarray(g), np.asarray(w)
        name = jax.tree_util.keystr(path)
        assert g.dtype == w.dtype and g.shape == w.shape, name
        if np.issubdtype(g.dtype, np.integer) or exact_floats:
            assert g.tobytes() == w.tobytes(), name
        else:
            np.testing.assert_allclose(g, w, rtol=1e-5, err_msg=name)


def _assert_memo_equal(bank_a, bank_b, *, keys=None):
    tree_a, meta_a = bank_a.state()
    tree_b, meta_b = bank_b.state()
    assert meta_a == meta_b
    # `version` counts table mutations, which restart attempts legally
    # repeat (rebuild fill -> overwrite); everything observable is keyed
    for k in (keys if keys is not None else
              [k for k in tree_a if k != "version"]):
        np.testing.assert_array_equal(np.asarray(tree_a[k]),
                                      np.asarray(tree_b[k]), err_msg=k)


# ------------------------------------------------------- fault plan units
def test_fault_plan_random_is_deterministic():
    a = FaultPlan.random(5, 16, kills=4, max_devices_lost=3)
    b = FaultPlan.random(5, 16, kills=4, max_devices_lost=3)
    assert a == b
    assert len(a.events) == 4
    assert [e.quantum for e in a.events] == \
           sorted({e.quantum for e in a.events})
    assert all(e.kind in FAULT_KINDS for e in a.events)
    assert all(0 <= e.devices_lost <= 3 for e in a.events)
    assert FaultPlan.random(6, 16, kills=4) != a


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meteor", 0)
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent("kill", -1)


def test_injector_fires_events_in_order():
    plan = FaultPlan((FaultEvent("kill", 1, devices_lost=2),
                      FaultEvent("kill_dirty", 0)))    # sorts to front
    inj = plan.injector()
    assert [e.quantum for e in inj.pending] == [0, 1]
    with pytest.raises(HostLoss):
        inj.quantum_computed()                 # kill_dirty@0
    inj.on_resume(0)                           # nothing was checkpointed
    inj.quantum_computed()                     # q0 recomputes cleanly
    inj.quantum_checkpointed()
    inj.quantum_computed()
    with pytest.raises(HostLoss) as err:       # kill@1 after q1 publishes
        inj.quantum_checkpointed()
    assert err.value.devices_lost == 2 and err.value.quantum == 1
    assert not inj.pending
    assert [e.kind for e in inj.fired] == ["kill_dirty", "kill"]


def test_plan_tail_beyond_run_never_fires():
    inj = FaultPlan((FaultEvent("kill", 9),)).injector()
    for _ in range(4):                         # a 4-quantum run
        inj.quantum_computed()
        inj.quantum_checkpointed()
    assert len(inj.pending) == 1 and not inj.fired


# -------------------------------------------------- checkpoint atomicity
def test_corrupt_mid_write_keeps_previous_checkpoint_restorable(tmp_path):
    """A crash that truncates the half-written archive must leave the
    previously published checkpoint fully restorable (atomic rename)."""
    tree0 = {"x": np.arange(8, dtype=np.int64)}
    save_checkpoint(tmp_path, 0, tree0, extra={"next_quantum": 1})
    inj = FaultPlan((FaultEvent("corrupt", 1),)).injector()
    inj.on_resume(1)
    with pytest.raises(HostLoss, match="mid-checkpoint-write"):
        save_checkpoint(tmp_path, 1, {"x": np.arange(8, dtype=np.int64) * 2},
                        extra={"next_quantum": 2}, fault_hook=inj.hook)
    # the corrupt tmp dir exists but was never published
    assert (tmp_path / "step_1.tmp").exists()
    assert latest_step(tmp_path) == 0
    restored, extra = restore_checkpoint(tmp_path, tree0)
    assert extra["next_quantum"] == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]), tree0["x"])


def test_manifest_mismatch_raises_before_reading_arrays(tmp_path):
    """Identity validation is manifest-first: with the array archive
    replaced by garbage, every mismatching restore still raises
    ``ManifestMismatch`` — proving no array data is read before the
    identity checks pass."""
    tree = {"x": np.arange(4, dtype=np.float32)}
    save_checkpoint(tmp_path, 0, tree, extra={"run": {"kind": "sweep"}})
    (tmp_path / "step_0" / "arrays.npz").write_bytes(b"not-a-zipfile")
    with pytest.raises(ManifestMismatch, match="extra"):
        restore_checkpoint(tmp_path, tree, expect={"run": {"kind": "trial"}})
    with pytest.raises(ManifestMismatch, match="shape"):
        restore_checkpoint(tmp_path, {"x": np.zeros((9, 9), np.float32)})
    with pytest.raises(ManifestMismatch, match="missing"):
        restore_checkpoint(tmp_path, {"y": np.arange(4, dtype=np.float32)})


# ------------------------------------------------- sweeps: resume == run
SWEEP_MATRIX = [
    pytest.param("srs", None, True, 5, id="srs"),
    pytest.param("rfv", "centroid", True, 6, id="rfv-fused"),
    pytest.param("bbv", "centroid", True, 7, id="bbv-fused",
                 marks=pytest.mark.slow),
    pytest.param("dg", "centroid", True, 8, id="dg-fused",
                 marks=pytest.mark.slow),
    pytest.param("rfv", "centroid", False, 9, id="rfv-staged",
                 marks=pytest.mark.slow),
]


@pytest.mark.parametrize("scheme,policy,fused,seed", SWEEP_MATRIX)
def test_sweep_killed_and_resumed_is_bitwise_identical(
        tmp_path, scheme, policy, fused, seed):
    """The headline gate: >= 3 randomized fault points (kinds drawn from
    all three failure modes) across the quantum grid; the supervised run
    must equal the uninterrupted run bitwise — estimates, errors, memo
    mask, charge matrix, ledger totals, hit/miss counters."""
    spec = _sweep_spec(scheme, policy, fused)
    n_quanta = len(APPS) * len(CONFIGS)        # app_block=1, config_block=1
    plan = FaultPlan.random(seed, n_quanta, kills=3)
    assert len(plan.events) == 3

    eng_u = ExperimentEngine()
    uninterrupted = run_sweep_resumable(eng_u, spec, tmp_path / "u",
                                        app_block=1, config_block=1)

    engines, make = _capture_engines()
    res, rep = supervise_sweep(make, spec, tmp_path / "f", faults=plan,
                               app_block=1, config_block=1)
    assert rep.restarts == 3                   # every planned fault fired
    assert len(rep.quanta) >= n_quanta         # health trace saw the work

    _assert_rows_bitwise(res, uninterrupted)
    _assert_memo_equal(engines[-1].memo, eng_u.memo)

    # deterministic policies are blocking-invariant: the plain unblocked
    # driver agrees bitwise too, and charges are path-independent
    eng_p = ExperimentEngine()
    _assert_rows_bitwise(res, run_sweep(eng_p, spec))
    _assert_memo_equal(engines[-1].memo, eng_p.memo,
                       keys=["mask", "charges", "ledger_regions",
                             "ledger_instr"])


def test_sweep_checkpoint_identity_guards_resume(tmp_path):
    """A directory holding a different run's checkpoints refuses to
    resume (manifest-first), instead of silently mixing runs."""
    spec = _sweep_spec("rfv", "centroid", True)
    run_sweep_resumable(ExperimentEngine(), spec, tmp_path,
                        app_block=1, config_block=1)
    other = _sweep_spec("rfv", "mean", True)
    with pytest.raises(ManifestMismatch):
        run_sweep_resumable(ExperimentEngine(), other, tmp_path,
                            app_block=1, config_block=1)


# ------------------------------------------------- trials: resume == run
def _trials_spec():
    # chunk_size=TRIAL_BLOCK -> 1 block/chunk, 2 chunks; with
    # segment_trials=256 that is 2 segments x 4 schemes = 8 quanta
    return TrialSpec(trials=512, chunk_size=TRIAL_BLOCK, keep_trials=True)


def _assert_trials_equal(got, want, *, exact_floats):
    for s in want.spec.schemes:
        _assert_stats_equal(got.stats[s], want.stats[s],
                            exact_floats=exact_floats)
        for field in ("estimates", "errors", "half_widths"):
            a = getattr(got, field)[s]
            b = getattr(want, field)[s]
            assert a.dtype == b.dtype and a.shape == b.shape
            assert a.tobytes() == b.tobytes(), (s, field)


def test_trials_killed_and_resumed_is_bitwise_identical(tmp_path):
    """Monte-Carlo headline gate: every paper-matrix scheme killed and
    resumed at >= 4 randomized segment boundaries reproduces every
    ``TrialStats`` leaf and dense per-trial array bitwise."""
    spec = _trials_spec()
    plan = FaultPlan.random(12, 8, kills=4)
    assert len(plan.events) == 4

    uninterrupted = run_trials_resumable(ExperimentEngine(), spec,
                                         tmp_path / "u", apps=APPS,
                                         segment_trials=256)
    engines, make = _capture_engines()
    res, rep = supervise_trials(make, spec, tmp_path / "f", apps=APPS,
                                faults=plan, segment_trials=256)
    assert rep.restarts == 4
    _assert_trials_equal(res, uninterrupted, exact_floats=True)

    # vs the plain driver's different blocking (one 4096-trial chunk):
    # dense per-trial arrays and integer leaves stay bitwise (the PRNG
    # block contract), float moments agree to summation order
    plain = run_trials(ExperimentEngine(),
                       dataclasses.replace(spec, chunk_size=None),
                       apps=APPS)
    _assert_trials_equal(res, plain, exact_floats=False)


# ----------------------------------------- sharded + elastic device drop
@needs_devices
@pytest.mark.multidevice
def test_sharded_sweep_with_device_drops_matches_single_device(tmp_path):
    """8-device app-sharded fleet loses 5 devices, then 2 more (ending
    on a single unmeshed device): every elastic re-plan must keep the
    estimates bitwise-equal to the plain single-device sweep."""
    spec = _sweep_spec("rfv", "centroid", True)
    plan = FaultPlan((FaultEvent("kill", 1, devices_lost=5),
                      FaultEvent("kill_dirty", 2, devices_lost=2)))
    engines, make = _capture_engines()
    res, rep = supervise_sweep(make, spec, tmp_path, faults=plan,
                               app_block=1, config_block=1)
    assert [a["n_devices"] for a in rep.attempts] == [8, 3, 1]
    assert rep.attempts[-1]["outcome"] == "completed"
    eng_p = ExperimentEngine()                 # no mesh: single device
    _assert_rows_bitwise(res, run_sweep(eng_p, spec))
    _assert_memo_equal(engines[-1].memo, eng_p.memo,
                       keys=["mask", "charges", "ledger_regions",
                             "ledger_instr"])


@needs_devices
@pytest.mark.multidevice
def test_sharded_trials_with_device_drop_matches_single_device(tmp_path):
    """(app x trial)-sharded streaming trials survive a mid-run loss of
    half the pool (the trial axis re-plans 4 -> 2 lanes between scheme
    quanta): integer stats and dense per-trial arrays stay bitwise vs an
    unsharded run; float moment sums agree to psum order."""
    spec = TrialSpec(trials=1024, keep_trials=True)   # kb=16: 4 and 2 lanes
    plan = FaultPlan((FaultEvent("kill", 1, devices_lost=4),))
    res, rep = supervise_trials(
        lambda mesh: ExperimentEngine(mesh=mesh), spec, tmp_path,
        apps=APPS, faults=plan, app_devices=2)
    assert [a["n_devices"] for a in rep.attempts] == [8, 4]
    single = run_trials(ExperimentEngine(), spec, apps=APPS, mesh=None)
    _assert_trials_equal(res, single, exact_floats=False)
