"""Synthetic CPU-population substrate tests."""

import numpy as np
import pytest

from repro.core.features import RFV_METRICS
from repro.simcpu import (APP_NAMES, BASELINE, CONFIGS, Ledger,
                          REGION_LEN_INSTR, get_bbvs, get_population,
                          make_simulator)

APP = "520.omnetpp_r"


def test_population_deterministic_across_builds():
    a = get_population(APP)
    import repro.simcpu.workload as w
    b = w.generate_population(a.spec, seed=0)
    np.testing.assert_array_equal(a.features, b.features)
    np.testing.assert_array_equal(a.phase_ids, b.phase_ids)


def test_simulator_returns_all_38_metrics():
    sim = make_simulator(APP)
    stats = sim.simulate(np.arange(10), BASELINE)
    for m in RFV_METRICS:
        assert m in stats, m
        assert stats[m].shape == (10,)
        assert np.isfinite(stats[m]).all()
    assert len(RFV_METRICS) == 38


def test_simulation_is_repeatable():
    sim = make_simulator(APP)
    a = sim.simulate_cpi(np.arange(50), CONFIGS[3])
    b = sim.simulate_cpi(np.arange(50), CONFIGS[3])
    np.testing.assert_array_equal(a, b)


def test_configs_monotonically_faster():
    for name in APP_NAMES:
        sim = make_simulator(name)
        means = [sim.true_mean_cpi(c) for c in CONFIGS]
        for i in range(6):
            assert means[i + 1] <= means[i] * 1.001, (name, i, means)


def test_geomean_speedup_in_paper_band():
    ipc0, ipc6 = [], []
    for name in APP_NAMES:
        sim = make_simulator(name)
        ipc0.append(1 / sim.true_mean_cpi(CONFIGS[0]))
        ipc6.append(1 / sim.true_mean_cpi(CONFIGS[6]))
    g0 = np.exp(np.mean(np.log(ipc0)))
    g6 = np.exp(np.mean(np.log(ipc6)))
    assert 1.5 <= g6 / g0 <= 1.9          # paper: 1.68


def test_gcc_has_heavy_outliers():
    sim = make_simulator("502.gcc_r")
    cpi = sim.census_stats(CONFIGS[0])["cpi"]
    assert cpi.max() > 20 * cpi.mean()     # paper: ~28 vs mean 1.36
    # and the best config largely fixes them (paper: 28 -> 5.66)
    cpi6 = sim.census_stats(CONFIGS[6])["cpi"]
    worst = np.argsort(cpi)[-10:]
    assert cpi6[worst].max() < 0.4 * cpi[worst].max()


def test_bbv_shapes_and_region_length():
    pop = get_population(APP)
    bbv = get_bbvs(pop)
    assert bbv.shape[0] == pop.n_regions
    np.testing.assert_allclose(bbv.sum(axis=1), REGION_LEN_INSTR, rtol=1e-3)


def test_aliased_phases_share_bbv_profiles():
    pop = get_population("502.gcc_r")
    ids = pop.bbv_profile_ids
    assert len(np.unique(ids)) < ids.shape[0]


def test_ledger_accounting():
    ledger = Ledger()
    sim = make_simulator(APP, ledger=ledger)
    sim.simulate_cpi(np.arange(7), CONFIGS[0])
    sim.simulate_cpi(np.arange(5), CONFIGS[1])
    assert ledger.regions_simulated == 12
    assert ledger.instructions_simulated == 12 * REGION_LEN_INSTR


def test_unknown_app_raises():
    with pytest.raises(KeyError):
        get_population("999.nonesuch")
