"""Fixture suite for ``repro.analysis`` (jaxlint).

Every rule gets positive snippets (the regression class it exists to
catch — each a distilled version of a real bug shape from PRs 3/6/7)
and negative snippets pinning the conservatism: the idioms this
codebase actually uses must NOT be flagged. Snippets are linted inside
a tmp fake repo tree so the path-scoped rules (JL003, JL100, JL101)
see in-scope paths; ``--select`` isolates each rule from the others.

The suite never imports jax — jaxlint is dependency-free by contract
and these tests must run in the CI static-analysis job's bare
environment.
"""

import json
import pathlib
import textwrap

from repro.analysis import main, run_lint
from repro.analysis.registry import RULES

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

IN_SCOPE = "src/repro/core/sampling/snippet.py"      # JL003/JL100 scope
EXP_SCOPE = "src/repro/experiments/snippet.py"       # JL101 scope too
NO_SCOPE = "src/repro/models/snippet.py"             # outside JL003 scope


def lint(tmp_path, code, rel=IN_SCOPE, select=None, **kw):
    """Write one snippet into a fake tree and lint just that file."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    return run_lint([rel], root=tmp_path,
                    baseline_path=tmp_path / "baseline.json",
                    select=select, **kw)


def rules_of(report):
    """Rule ids of the active findings, in report order."""
    return [f.rule for f in report.active]


# ---------------------------------------------------------------- registry
def test_rule_registry_complete():
    """The full pack is registered: jax discipline + repo contracts."""
    assert sorted(RULES) == ["JL001", "JL002", "JL003", "JL004", "JL005",
                             "JL006", "JL100", "JL101", "JL102"]


# ------------------------------------------------- JL001 host-sync-in-trace
def test_jl001_item_in_jitted_function(tmp_path):
    r = lint(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """, select=["JL001"])
    assert rules_of(r) == ["JL001"]
    assert ".item()" in r.active[0].message


def test_jl001_np_asarray_in_function_passed_to_jit(tmp_path):
    r = lint(tmp_path, """
        import jax
        import numpy as np

        def body(x):
            return np.asarray(x) + 1

        run = jax.jit(body)
    """, select=["JL001"])
    assert rules_of(r) == ["JL001"]


def test_jl001_print_in_transitively_traced_callee(tmp_path):
    r = lint(tmp_path, """
        import jax

        def helper(x):
            print(x)
            return x

        @jax.jit
        def f(x):
            return helper(x)
    """, select=["JL001"])
    assert rules_of(r) == ["JL001"]


def test_jl001_negative_host_code_and_static_attrs(tmp_path):
    r = lint(tmp_path, """
        import jax
        import numpy as np

        def host_summary(x):
            return float(np.asarray(x).sum())

        @jax.jit
        def f(x):
            n = x.shape[0]
            return x * n
    """, select=["JL001"])
    assert rules_of(r) == []


# --------------------------------------------------- JL002 prng-key-reuse
def test_jl002_key_consumed_by_two_draws(tmp_path):
    r = lint(tmp_path, """
        import jax

        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
    """, select=["JL002"])
    assert rules_of(r) == ["JL002"]
    assert "split" in r.active[0].message


def test_jl002_loop_invariant_key_reuse(tmp_path):
    r = lint(tmp_path, """
        import jax

        def f(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key, ()))
            return out
    """, select=["JL002"])
    assert rules_of(r) == ["JL002"]


def test_jl002_negative_split_between_draws(tmp_path):
    r = lint(tmp_path, """
        import jax

        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, ())
            b = jax.random.normal(k2, ())
            return a + b

        def g(key, i):
            a = jax.random.normal(jax.random.fold_in(key, i), ())
            key = jax.random.fold_in(key, 1)
            b = jax.random.normal(key, ())
            return a + b
    """, select=["JL002"])
    assert rules_of(r) == []


def test_jl002_negative_branches_are_alternatives(tmp_path):
    r = lint(tmp_path, """
        import jax

        def f(key, flag):
            if flag:
                a = jax.random.normal(key, ())
            else:
                a = jax.random.uniform(key, ())
            return a
    """, select=["JL002"])
    assert rules_of(r) == []


# -------------------------------------------------- JL003 raw-dtype-literal
def test_jl003_jnp_dtype_attribute(tmp_path):
    r = lint(tmp_path, """
        import jax.numpy as jnp

        def f(x):
            return jnp.asarray(x, jnp.float32)
    """, select=["JL003"])
    assert rules_of(r) == ["JL003"]
    assert "jax.numpy.float32" in r.active[0].message


def test_jl003_astype_string_and_dtype_kwarg(tmp_path):
    r = lint(tmp_path, """
        import numpy as np

        def f(x):
            return x.astype("float32")

        def g(n):
            return np.zeros(n, dtype="bfloat16")
    """, select=["JL003"])
    assert rules_of(r) == ["JL003", "JL003"]


def test_jl003_negative_policy_and_host_f64(tmp_path):
    r = lint(tmp_path, """
        import numpy as np

        def f(x, policy):
            y = np.asarray(x, np.float64)
            return y.astype(policy.host_dtype)
    """, select=["JL003"])
    assert rules_of(r) == []


def test_jl003_negative_out_of_scope_path(tmp_path):
    r = lint(tmp_path, """
        import jax.numpy as jnp

        X = jnp.asarray([1.0], jnp.float32)
    """, rel=NO_SCOPE, select=["JL003"])
    assert rules_of(r) == []


# ------------------------------------------------ JL004 donation-after-use
def test_jl004_read_after_donating_dispatch(tmp_path):
    r = lint(tmp_path, """
        import jax

        def step(buf, x):
            return buf + x

        run = jax.jit(step, donate_argnums=(0,))

        def drive(buf, x):
            out = run(buf, x)
            return buf.sum() + out.sum()
    """, select=["JL004"])
    assert rules_of(r) == ["JL004"]
    assert "`buf` was donated" in r.active[0].message


def test_jl004_module_const_indirection(tmp_path):
    r = lint(tmp_path, """
        import jax

        _DONATE = (0,)

        def step(buf, x):
            return buf + x

        run = jax.jit(step, donate_argnums=_DONATE)
        y = run(table, delta)
        z = table + y
    """, select=["JL004"])
    assert rules_of(r) == ["JL004"]


def test_jl004_negative_reassignment_restores_ownership(tmp_path):
    r = lint(tmp_path, """
        import jax

        def step(buf, x):
            return buf + x

        run = jax.jit(step, donate_argnums=(0,))

        def drive(buf, x):
            buf = run(buf, x)
            return buf.sum()
    """, select=["JL004"])
    assert rules_of(r) == []


# -------------------------------------------- JL005 untraced-python-branch
def test_jl005_if_on_traced_param(tmp_path):
    r = lint(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """, select=["JL005"])
    assert rules_of(r) == ["JL005"]
    assert "lax.cond" in r.active[0].message


def test_jl005_for_over_traced_param(tmp_path):
    r = lint(tmp_path, """
        import jax

        def body(xs):
            total = 0.0
            for x in xs:
                total = total + x
            return total

        run = jax.jit(body)
    """, select=["JL005"])
    assert rules_of(r) == ["JL005"]


def test_jl005_negative_static_argnames(tmp_path):
    r = lint(tmp_path, """
        from functools import partial

        import jax

        @partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            if n > 2:
                return x
            return x * 2.0
    """, select=["JL005"])
    assert rules_of(r) == []


def test_jl005_negative_config_hint_and_shape(tmp_path):
    r = lint(tmp_path, """
        import jax

        @jax.jit
        def f(x, cfg):
            if cfg.deep:
                x = x * 2.0
            if x.ndim == 2:
                return x
            return x[None]
    """, select=["JL005"])
    assert rules_of(r) == []


# --------------------------------------------- JL006 vmap-of-pallas_call
def test_jl006_vmap_of_local_pallas_wrapper(tmp_path):
    r = lint(tmp_path, """
        import jax
        from jax.experimental import pallas as pl

        def op(x):
            return pl.pallas_call(kernel, out_shape=None)(x)

        batched = jax.vmap(op)
    """, select=["JL006"])
    assert rules_of(r) == ["JL006"]
    assert "batch" in r.active[0].message


def test_jl006_vmap_of_repro_kernels_op(tmp_path):
    r = lint(tmp_path, """
        import jax
        from repro.kernels.segment_stats.ops import segment_stats

        v = jax.vmap(segment_stats)
    """, select=["JL006"])
    assert rules_of(r) == ["JL006"]


def test_jl006_negative_vmap_of_plain_function(tmp_path):
    r = lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        def plain(x):
            return jnp.sum(x * x)

        v = jax.vmap(plain)
    """, select=["JL006"])
    assert rules_of(r) == []


# ------------------------------------------------------ JL100 api-surface
def test_jl100_missing_dunder_all(tmp_path):
    r = lint(tmp_path, """
        X = 1
    """, select=["JL100"])
    assert rules_of(r) == ["JL100"]
    assert "__all__" in r.active[0].message


def test_jl100_string_literal_dispatch(tmp_path):
    r = lint(tmp_path, """
        __all__ = []

        def pick(scheme):
            if scheme == "bbv":
                return 1
            return 0
    """, select=["JL100"])
    assert rules_of(r) == ["JL100"]
    assert "registry" in r.active[0].message


def test_jl100_isinstance_dispatch_on_plan_type(tmp_path):
    r = lint(tmp_path, """
        __all__ = []

        def handle(s):
            return isinstance(s, (Stratifier, Centroid))
    """, select=["JL100"])
    assert rules_of(r) == ["JL100"]
    assert "isinstance" in r.active[0].message


def test_jl100_negative_plan_module_may_dispatch(tmp_path):
    r = lint(tmp_path, """
        __all__ = []

        def lookup(scheme, s):
            if scheme == "bbv" and isinstance(s, Stratifier):
                return 1
            return 0
    """, rel="src/repro/core/sampling/plan.py", select=["JL100"])
    assert rules_of(r) == []


def test_jl100_negative_clean_module(tmp_path):
    r = lint(tmp_path, """
        __all__ = ["f"]

        def f(kind):
            return kind == "weighted"
    """, select=["JL100"])
    assert rules_of(r) == []


# ------------------------------------------------ JL101 missing-docstring
def test_jl101_missing_module_docstring(tmp_path):
    r = lint(tmp_path, """
        X = 1
    """, rel=EXP_SCOPE, select=["JL101"])
    assert rules_of(r) == ["JL101"]


def test_jl101_missing_public_function_and_class_docstrings(tmp_path):
    r = lint(tmp_path, '''
        """Module docstring."""

        def public_fn():
            return 1

        class PublicClass:
            pass
    ''', rel=EXP_SCOPE, select=["JL101"])
    assert rules_of(r) == ["JL101", "JL101"]


def test_jl101_negative_documented_and_private(tmp_path):
    r = lint(tmp_path, '''
        """Module docstring."""

        def public_fn():
            """Documented."""

        def _private_fn():
            return 1
    ''', rel=EXP_SCOPE, select=["JL101"])
    assert rules_of(r) == []


# ------------------------------------------------ JL102 broken-doc-link
def test_jl102_broken_link_and_missing_anchor(tmp_path):
    (tmp_path / "README.md").write_text(
        "# Real Heading\n\n[gone](docs/missing.md)\n[frag](#nope)\n")
    r = run_lint(None, root=tmp_path, baseline_path=tmp_path / "bl.json",
                 select=["JL102"])
    assert rules_of(r) == ["JL102", "JL102"]


def test_jl102_negative_resolving_links(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (docs / "guide.md").write_text("# Guide Heading\n")
    (tmp_path / "README.md").write_text(
        "# Top\n\n[ok](docs/guide.md#guide-heading)\n[self](#top)\n"
        "[web](https://example.com)\n")
    r = run_lint(None, root=tmp_path, baseline_path=tmp_path / "bl.json",
                 select=["JL102"])
    assert rules_of(r) == []


# ------------------------------------------------------------ suppression
_VIOLATION = """
    import jax.numpy as jnp

    X = jnp.asarray([1.0], jnp.float32)
"""


def test_inline_suppression_comment(tmp_path):
    code = _VIOLATION.replace(
        "jnp.float32)", "jnp.float32)  # jaxlint: disable=JL003")
    r = lint(tmp_path, code, select=["JL003"])
    assert rules_of(r) == []
    assert r.suppressed == 1


def test_file_level_suppression_comment(tmp_path):
    r = lint(tmp_path, """
        # jaxlint: disable-file=JL003
        import jax.numpy as jnp

        X = jnp.asarray([1.0], jnp.float32)
        Y = jnp.asarray([2.0], jnp.float16)
    """, select=["JL003"])
    assert rules_of(r) == []
    assert r.suppressed == 2


def test_suppression_is_rule_specific(tmp_path):
    code = _VIOLATION.replace(
        "jnp.float32)", "jnp.float32)  # jaxlint: disable=JL001")
    r = lint(tmp_path, code, select=["JL003"])
    assert rules_of(r) == ["JL003"]       # wrong rule id: not covered


# --------------------------------------------------------------- baseline
def test_baseline_roundtrip_and_staleness(tmp_path):
    bl = tmp_path / "baseline.json"
    r1 = lint(tmp_path, _VIOLATION, select=["JL003"])
    assert rules_of(r1) == ["JL003"] and not r1.ok

    r2 = lint(tmp_path, _VIOLATION, select=["JL003"], update_baseline=True)
    assert bl.exists() and len(r2.baselined) == 1

    entries = json.loads(bl.read_text())["entries"]
    assert len(entries) == 1 and entries[0]["rule"] == "JL003"
    assert entries[0]["justification"]          # placeholder is non-empty

    r3 = lint(tmp_path, _VIOLATION, select=["JL003"])
    assert r3.ok and rules_of(r3) == [] and len(r3.baselined) == 1

    # fixing the violation makes the baseline entry stale -> build fails
    r4 = lint(tmp_path, "import jax.numpy as jnp\nX = 1\n",
              select=["JL003"])
    assert not r4.ok and len(r4.stale) == 1 and rules_of(r4) == []


def test_baseline_survives_line_drift_but_not_new_violations(tmp_path):
    lint(tmp_path, _VIOLATION, select=["JL003"], update_baseline=True)
    drifted = "import jax.numpy as jnp\n\n\n# pushed down\n" \
        "X = jnp.asarray([1.0], jnp.float32)\n"
    r = lint(tmp_path, drifted, select=["JL003"])
    assert r.ok and len(r.baselined) == 1     # same code line, new lineno

    doubled = drifted + "Y = jnp.asarray([2.0], jnp.float32)\n"
    r2 = lint(tmp_path, doubled, select=["JL003"])
    assert rules_of(r2) == ["JL003"]          # the NEW line is active


# ------------------------------------------------------------ JSON schema
def test_json_report_schema(tmp_path):
    r = lint(tmp_path, _VIOLATION, select=["JL003"])
    d = r.to_json()
    assert d["version"] == 1
    assert set(d) == {"version", "root", "rules", "findings", "summary"}
    assert [row["id"] for row in d["rules"]] == sorted(RULES)
    f = d["findings"][0]
    assert set(f) == {"rule", "path", "line", "col", "message", "status"}
    assert f["status"] == "active"
    s = d["summary"]
    assert {"files", "active", "baselined", "suppressed", "stale_baseline",
            "errors", "duration_s", "ok"} <= set(s)
    assert s["active"] == 1 and s["ok"] is False
    json.dumps(d)                             # round-trips to JSON


# ------------------------------------------------------------------- CLI
def test_cli_list_rules_and_bad_select(capsys):
    assert main(["--list-rules"]) == 0
    assert "JL001" in capsys.readouterr().out
    assert main(["--select", "JL999"]) == 2


def test_cli_json_exit_codes(tmp_path, capsys):
    path = tmp_path / IN_SCOPE
    path.parent.mkdir(parents=True)
    path.write_text(textwrap.dedent(_VIOLATION))
    code = main([IN_SCOPE, "--root", str(tmp_path), "--select", "JL003",
                 "--baseline", str(tmp_path / "bl.json"), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert code == 1 and out["summary"]["active"] == 1


# ------------------------------------------------------------- self-check
def test_repo_lints_clean():
    """The committed tree passes its own gate (active findings = 0,
    every baseline entry alive and justified)."""
    report = run_lint(root=REPO_ROOT)
    detail = "\n".join(f.render() for f in report.active) or report.errors
    assert report.ok, f"repo must lint clean:\n{detail}"
    for entry in json.loads(
            (REPO_ROOT / "lint_baseline.json").read_text())["entries"]:
        assert "grandfathered" not in entry["justification"], \
            f"unjustified baseline entry: {entry}"
