"""Collective-byte analyzer tests (crafted HLO + a real lowered module)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import collective_bytes, summarize_collectives

FAKE_HLO = """
HloModule test

%cond (arg: (s32[], f32[4])) -> pred[] {
  %gte = s32[] get-tuple-element(%arg), index=0
  %limit = s32[] constant(10)
  ROOT %lt = pred[] compare(%gte, %limit), direction=LT
}

%body (arg: (s32[], f32[4])) -> (s32[], f32[4]) {
  %x = f32[4]{0} get-tuple-element(%arg), index=1
  %ar = f32[4]{0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%sum
  ROOT %t = (s32[], f32[4]) tuple(%gte2, %ar)
}

ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %ag = f32[128,256]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={0}
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[128,256]{1,0} add(%ag, %ag)
}
"""


def test_parser_counts_and_loop_weighting():
    stats = collective_bytes(FAKE_HLO)
    assert "all-gather" in stats
    assert stats["all-gather"].count == 1
    assert stats["all-gather"].result_bytes == 128 * 256 * 4
    # the all-reduce sits in a while body with trip count 10
    assert stats["all-reduce"].count == 10
    assert stats["all-reduce"].result_bytes == 10 * 4 * 4
    # AR wire = 2x result
    assert stats["all-reduce"].wire_bytes == 2 * 10 * 4 * 4


def test_summarize_totals():
    s = summarize_collectives(FAKE_HLO)
    assert s["total_count"] == 11
    assert s["total_wire_bytes"] > s["total_result_bytes"]


@pytest.mark.skipif(len(jax.devices()) < 1, reason="needs a device")
def test_real_module_collectives_detected():
    """A psum under shard_map must appear as an all-reduce."""
    from jax.sharding import Mesh, PartitionSpec as P
    if hasattr(jax, "shard_map"):
        shard_map = jax.shard_map
    else:  # jax 0.4.x keeps shard_map under jax.experimental
        from jax.experimental.shard_map import shard_map
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(len(devs)), ("d",))

    def f(x):
        return jax.lax.psum(x, "d")

    sf = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P())
    lowered = jax.jit(sf).lower(
        jax.ShapeDtypeStruct((len(jax.devices()) * 4,), jnp.float32))
    txt = lowered.compile().as_text()
    stats = collective_bytes(txt)
    if len(jax.devices()) > 1:
        assert any("all-reduce" in k for k in stats)
