"""App-sharded sweep engine tests: stacked populations, memo-bank merge,
vmapped Monte-Carlo trials, and sharded-vs-single-host equivalence.

The sharded tests need forced host devices, e.g.:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_sharded_sweeps.py

(scripts/ci.sh runs a CI_FORCE_DEVICES=8 matrix leg); on a single device
they skip and the single-device equivalence/reference tests still run.
"""

import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.core.clustering import kmeans_bank
from repro.core.sampling import (Centroid, DaleniusGurney, RandomUnit,
                                 SamplingPlan)
from repro.experiments import (ExperimentEngine, SweepSpec, TrialSpec,
                               plan_selection, run_sweep, run_trials,
                               trial_uniforms)
from repro.simcpu import (CONFIGS, MemoBank, cpi_bank, evaluate_regions,
                          get_population_bank, make_cached_simulator)

APP = "505.mcf_r"
APPS2 = ("505.mcf_r", "520.omnetpp_r")

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ------------------------------------------------ stacked population bank
def test_population_bank_stacks_and_masks():
    bank = get_population_bank(APPS2)
    assert bank.features.shape[0] == 2
    assert bank.features.shape[2] == bank.pops[0].features.shape[1]
    for a, pop in enumerate(bank.pops):
        n = pop.n_regions
        assert bank.n_regions[a] == n
        assert bank.mask[a, :n].all() and not bank.mask[a, n:].any()
        np.testing.assert_allclose(bank.features[a, :n],
                                   pop.features.astype(np.float32))


def test_cpi_bank_matches_per_app_eval():
    bank = get_population_bank(APPS2)
    mat = cpi_bank(bank.features, CONFIGS[:3])          # (A, 3, N)
    for a, pop in enumerate(bank.pops):
        n = pop.n_regions
        for c in range(3):
            ref = evaluate_regions(pop.features, CONFIGS[c])["cpi"]
            np.testing.assert_allclose(mat[a, c, :n], ref,
                                       rtol=1e-5, atol=1e-6)


def test_kmeans_bank_padding_invariance():
    """Zero-weight padding rows change nothing for the real rows."""
    rng = np.random.default_rng(0)
    x = np.concatenate([rng.normal(3.0 * i, 0.3, (50, 4))
                        for i in range(3)]).astype(np.float32)
    plain = kmeans_bank(x[None], 3, weights=np.ones((1, x.shape[0])), seed=1)
    padded_x = np.concatenate([x, np.zeros((37, 4), np.float32)])[None]
    padded_w = np.concatenate([np.ones(x.shape[0]), np.zeros(37)])[None]
    padded = kmeans_bank(padded_x, 3, weights=padded_w, seed=1)
    np.testing.assert_array_equal(plain.labels[0],
                                  padded.labels[0, :x.shape[0]])
    np.testing.assert_allclose(plain.centroids[0], padded.centroids[0],
                               rtol=1e-6, atol=1e-7)


# ------------------------------------------------ memo bank merge
def test_memo_bank_merge_values_and_charges():
    a = make_cached_simulator(APP)
    b = make_cached_simulator(APP)
    a.simulate_cpi(np.arange(10), CONFIGS[0])
    b.simulate_cpi(np.arange(5, 15), CONFIGS[0])        # 5-region overlap
    a.bank.merge(b.bank)
    row, col = 0, 0
    assert a.bank.mask[row, col, :15].all()
    # both devices paid for their own misses: 10 + 10, overlap included
    assert a.bank.charges[row, col] == 20
    assert a.ledger.regions_simulated == 20
    served = a.simulate_cpi(np.arange(15), CONFIGS[0])
    assert a.ledger.regions_simulated == 20             # all hits post-merge
    np.testing.assert_allclose(
        served, evaluate_regions(a.pop.features, CONFIGS[0],
                                 np.arange(15))["cpi"], rtol=1e-5, atol=1e-6)


def test_memo_bank_merge_app_partition_equals_single_host():
    """Disjoint app partitions merge to the same totals as one shared bank."""
    shared = ExperimentEngine()
    shared.build(APPS2)
    parts = [ExperimentEngine(), ExperimentEngine()]
    parts[0].app(APPS2[0])
    parts[1].app(APPS2[1])
    merged = MemoBank()
    merged.merge(parts[0].memo)
    merged.merge(parts[1].memo)
    assert merged.total_charges() == shared.memo.total_charges()
    assert sorted(merged.names) == sorted(shared.memo.names)


# ------------------------------------------------ Monte-Carlo trials
@pytest.fixture(scope="module")
def engine():
    eng = ExperimentEngine()
    eng.app(APP)
    return eng


def test_run_trials_matches_numpy_loop(engine):
    """run_trials == a per-trial/per-stratum numpy loop on the same seeds."""
    spec = TrialSpec(trials=32, seed=3, config_index=6)
    res = run_trials(engine, spec, apps=(APP,))
    exp = engine.app(APP)
    truth = float(exp.truth[6])

    # SRS scheme: n-unit draws from the census pool
    census = exp.census(6)
    n = np.float32(census.size)
    u = trial_uniforms(spec, "random", 1, spec.units_per_trial)[0]
    for t in range(spec.trials):
        idx = np.minimum((u[t] * n).astype(np.int32), census.size - 1)
        est = census[idx].mean()
        assert res.estimates["random"][0, t] == pytest.approx(est, rel=1e-5)
        assert res.errors["random"][0, t] == pytest.approx(
            100 * abs(est - truth) / truth, rel=1e-4)

    # stratified schemes: one unit per non-empty stratum, weighted sum
    pools = {"bbv": (exp.bbv_labels, exp.bbv_weights, census),
             "rfv": (exp.rfv_labels, exp.rfv_weights, exp.cpi(6, exp.idx1)),
             "dg": (exp.dg_labels, exp.dg_weights, exp.cpi(6, exp.idx1))}
    for scheme, (labels, weights, pool) in pools.items():
        u = trial_uniforms(spec, scheme, 1, exp.num_strata)[0]
        members = [np.flatnonzero(labels == h) for h in range(exp.num_strata)]
        for t in range(0, spec.trials, 7):
            est = 0.0
            for h, m in enumerate(members):
                if m.size == 0:
                    continue
                pick = min(int(np.float32(u[t, h]) * np.float32(m.size)),
                           m.size - 1)
                est += weights[h] * pool[m[pick]]
            assert res.estimates[scheme][0, t] == pytest.approx(
                est, rel=1e-5), (scheme, t)


def test_run_trials_charges_phase1_pool_once(engine):
    exp = engine.app(APP)
    before = exp.sim.ledger.regions_simulated
    run_trials(engine, TrialSpec(trials=8, config_index=5), apps=(APP,))
    # rfv/dg pools re-measure the phase-1 sample on config 5: charged once
    assert exp.sim.ledger.regions_simulated - before == exp.idx1.size
    run_trials(engine, TrialSpec(trials=16, config_index=5), apps=(APP,))
    assert exp.sim.ledger.regions_simulated - before == exp.idx1.size


def test_sweep_spec_trials_plumbing(engine):
    table = run_sweep(engine, SweepSpec(
        apps=(APP,), scheme="rfv", config_indices=(0, 6),
        trials=TrialSpec(trials=16, config_index=6)))
    by_cfg = {r.config_index: r for r in table}
    assert by_cfg[6].p95_err_pct is not None
    assert by_cfg[0].p95_err_pct is None
    # the CI-claim bridge columns ride along at the trial config
    assert by_cfg[6].ci_half_pct is not None and by_cfg[6].ci_half_pct > 0
    assert by_cfg[6].coverage is not None
    assert 0.0 <= by_cfg[6].coverage <= 1.0
    assert by_cfg[0].ci_half_pct is None and by_cfg[0].coverage is None
    hdr = table.to_csv().splitlines()[0]
    for col in ("p95_err_pct", "ci_half_pct", "coverage"):
        assert col in hdr


def test_run_trials_ci_matches_collapsed_reference(engine):
    """Per-trial CI half-widths == a hand-built collapsed-pairs reference
    (eq. 4 over occupied strata in baseline-CPI order), and coverage is
    the fraction of trials whose CI contains the truth."""
    from repro.core.sampling.types import critical_value

    spec = TrialSpec(trials=16, seed=3, config_index=6)
    res = run_trials(engine, spec, apps=(APP,))
    exp = engine.app(APP)
    truth = float(exp.truth[6])

    labels, weights = exp.dg_labels, exp.dg_weights
    pool = exp.cpi(6, exp.idx1)
    baseline = exp.cpi0_1.astype(np.float32)
    L = exp.num_strata
    members = [np.flatnonzero(labels == h) for h in range(L)]
    occ = [h for h in range(L) if members[h].size]
    key = np.array([baseline[members[h]].mean() if members[h].size
                    else np.inf for h in range(L)], np.float32)
    order = [h for h in np.argsort(key, kind="stable") if members[h].size]
    v_cnt = len(occ)
    df = v_cnt - v_cnt // 2
    crit = critical_value(spec.confidence, float(df))

    u = trial_uniforms(spec, "dg", 1, L)[0]
    for t in range(0, spec.trials, 5):
        y = {}
        for h in occ:
            m = members[h]
            pick = min(int(np.float32(u[t, h]) * np.float32(m.size)),
                       m.size - 1)
            y[h] = float(pool[m[pick]])
        ys = [y[h] for h in order]
        ws = [float(weights[h]) for h in order]
        var = 0.0
        g_count = v_cnt // 2
        for j in range(g_count):
            tri = (v_cnt % 2 == 1) and (j == g_count - 1)
            idx = [2 * j, 2 * j + 1] + ([2 * j + 2] if tri else [])
            vals = np.array([ys[i] for i in idx])
            s2 = (vals[0] - vals[1]) ** 2 / 4.0 if not tri \
                else float(vals.var(ddof=1))
            var += sum(ws[i] ** 2 for i in idx) * s2
        half_ref = crit * np.sqrt(var)
        assert res.half_widths["dg"][0, t] == pytest.approx(
            half_ref, rel=2e-4), t
    # coverage is the empirical fraction of covering trials
    covers = (np.abs(res.estimates["dg"][0] - truth)
              <= res.half_widths["dg"][0])
    assert res.coverage["dg"][0] == pytest.approx(covers.mean(), abs=1e-6)
    # every scheme reports (A, T) half-widths and (A,) coverage in [0, 1]
    for scheme in spec.schemes:
        assert res.half_widths[scheme].shape == (1, spec.trials)
        assert 0.0 <= float(res.coverage[scheme][0]) <= 1.0


# ------------------------------------------------ satellite bug fixes
def test_weighted_cpi_all_empty_selection_contract(engine):
    exp = engine.app(APP)
    empty = [np.empty(0, np.int64)] * 4
    w = np.full(4, 0.25)
    with pytest.warns(UserWarning, match="every stratum selection is empty"):
        ests = exp.weighted_cpi_all(empty, w)
    assert ests.shape == (len(CONFIGS),)
    assert np.isnan(ests).all()
    with pytest.raises(ValueError, match="every stratum selection is empty"):
        exp.weighted_cpi_all(empty, w, strict=True)


def test_dg_selection_masks_empty_strata(engine):
    """Empty dg strata must yield empty selections — and no NaN anywhere
    in the centroid path (historically [nan] centroids leaked into the
    distance computation)."""
    exp = engine.app(APP)
    crafted = dataclasses.replace(
        exp, dg_labels=np.where(exp.dg_labels == 3, 0, exp.dg_labels),
        dg_weights=np.bincount(
            np.where(exp.dg_labels == 3, 0, exp.dg_labels),
            minlength=exp.num_strata) / exp.dg_labels.size)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # NaN ops would warn
        sel, w = plan_selection(crafted,
                                SamplingPlan(DaleniusGurney(), Centroid()))
    assert sel[3].size == 0                  # masked out, not NaN-selected
    assert sum(s.size for s in sel) == exp.num_strata - 1
    assert np.isfinite(w).all()


def test_random_selection_with_trailing_empty_stratum(engine):
    """A trailing empty stratum puts its gather offset at the row width;
    the random policy must clamp, not IndexError."""
    exp = engine.app(APP)
    last = exp.num_strata - 1
    relabeled = np.where(exp.dg_labels == last, 0, exp.dg_labels)
    crafted = dataclasses.replace(
        exp, dg_labels=relabeled,
        dg_weights=np.bincount(relabeled, minlength=exp.num_strata)
        / relabeled.size)
    sel, w = plan_selection(crafted,
                            SamplingPlan(DaleniusGurney(), RandomUnit()),
                            seed=11)
    assert sel[last].size == 0
    assert sum(s.size for s in sel) == exp.num_strata - 1
    for h, s in enumerate(sel):
        if s.size:
            assert relabeled[np.flatnonzero(crafted.idx1 == s[0])[0]] == h


# ------------------------------------------------ sharded equivalence
@needs_devices
def test_sharded_engine_matches_single_host():
    from repro.launch.mesh import make_app_mesh
    single = ExperimentEngine()
    sharded = ExperimentEngine(mesh=make_app_mesh())
    spec = SweepSpec(apps=APPS2, scheme="rfv", policy="centroid")
    t1 = run_sweep(single, spec)
    t2 = run_sweep(sharded, spec)
    np.testing.assert_allclose(t1.column("estimate"), t2.column("estimate"),
                               rtol=1e-7)
    s1 = run_sweep(single, SweepSpec(apps=APPS2, scheme="srs"))
    s2 = run_sweep(sharded, SweepSpec(apps=APPS2, scheme="srs"))
    np.testing.assert_allclose(s1.column("estimate"), s2.column("estimate"),
                               rtol=1e-7)
    np.testing.assert_allclose(s1.column("margin_pct"),
                               s2.column("margin_pct"), rtol=1e-5)
    # identical Monte-Carlo draws -> identical trial estimates and CIs
    mc1 = run_trials(single, TrialSpec(trials=64), apps=APPS2)
    mc2 = run_trials(sharded, TrialSpec(trials=64), apps=APPS2)
    for scheme in mc1.errors:
        np.testing.assert_allclose(mc1.errors[scheme], mc2.errors[scheme],
                                   rtol=1e-6)
        np.testing.assert_allclose(mc1.half_widths[scheme],
                                   mc2.half_widths[scheme], rtol=1e-6)
        np.testing.assert_allclose(mc1.coverage[scheme],
                                   mc2.coverage[scheme], rtol=1e-6)
    # merged ledger totals equal single-host totals
    assert sharded.memo.total_charges() == single.memo.total_charges()
    for e1, e2 in zip(single.build(APPS2), sharded.build(APPS2)):
        assert e1.sim.ledger.regions_simulated == \
            e2.sim.ledger.regions_simulated
