"""Pytest plugin: count real XLA backend compilations via jax.monitoring.

The static side of trace discipline lives in ``repro.analysis``
(jaxlint); this is the runtime teeth. It hooks jax's monitoring bus —
``jax.monitoring.register_event_duration_secs_listener`` — and counts
the ``/jax/core/compile/backend_compile_duration`` event, which fires
only when XLA actually compiles a program. A warm call that hits the
jit cache emits nothing (unlike the plain event listener, which fires
on cache hits too), so the counter is a precise recompile detector.

Hot-path tests use the ``compile_counter`` fixture with the
snapshot-after-warmup pattern::

    run_sweep(engine, spec)                       # warm: trace+compile
    with compile_counter.no_recompile("2nd identical sweep"):
        run_sweep(engine, spec)                   # must hit the cache

A failure means the second identical call retraced and recompiled —
the exact regression class (shape-dependent Python, unhashable or
unstable static args, rebuilt wrappers) the fused sweep megaprogram
and the streaming trial engine must never reintroduce.
"""

from __future__ import annotations

import contextlib

import pytest

__all__ = ["CompileCounter", "compile_counter"]

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class CompileCounter:
    """Process-global monotone counter of XLA backend compilations."""

    def __init__(self):
        self.count = 0
        self._installed = False

    def _install(self):
        if self._installed:
            return
        import jax.monitoring

        def _on_duration(event, duration, **kwargs):
            if event == _COMPILE_EVENT:
                self.count += 1

        jax.monitoring.register_event_duration_secs_listener(_on_duration)
        self._installed = True

    def snapshot(self) -> int:
        """Current compile count (compare after a warm call)."""
        return self.count

    @contextlib.contextmanager
    def no_recompile(self, label: str = "this block"):
        """Fail the test if any backend compile happens inside the block."""
        before = self.count
        yield self
        delta = self.count - before
        if delta:
            pytest.fail(
                f"{delta} XLA backend compilation(s) during {label} — the "
                "call was expected to hit the jit cache; something in the "
                "hot path retraces on identical inputs (recompile guard)")


_COUNTER = CompileCounter()


@pytest.fixture()
def compile_counter() -> CompileCounter:
    """The process-global :class:`CompileCounter`, listener installed."""
    _COUNTER._install()
    return _COUNTER
