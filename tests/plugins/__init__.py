"""Local pytest plugins (loaded via the repo-root ``conftest.py``)."""
