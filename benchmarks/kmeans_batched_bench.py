"""Batched k-means assignment: batch-grid kernel vs vmap-of-kernel vs oracle.

Measures the dispatch the tentpole replaced against the one it introduced,
over a (B, N, K) sweep:

* ``batched`` — ONE ``(batch, tile)``-grid Pallas launch for the whole
  stack (the path ``kmeans_batch``/``kmeans_bank`` now take);
* ``vmapped`` — ``jax.vmap`` over the per-problem 2-D wrapper, i.e. the
  legacy vmap-of-``pallas_call`` lifting;
* ``oracle`` — the jitted pure-jnp reference (also the ``"jnp"`` backend).

On this CPU container both Pallas variants run in interpret mode, so their
timings characterize the interpreter, not the MXU — the numbers to watch
off-TPU are the oracle timings and the agreement columns (which gate CI:
``benchmarks/run.py`` FAILs the claim row if agreement drops). On TPU the
same rows compare compiled launch strategies directly.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# (B, N, K) sweep; D fixed at the post-projection feature width
SWEEP = ((2, 512, 20), (4, 1024, 20), (8, 512, 64))
FEAT_D = 16


def _time_us(fn, *args, iters: int = 3) -> float:
    """Mean wall time of the jitted call in microseconds (post-warmup)."""
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kmeans_batched() -> dict:
    """CSV rows per (B, N, K) point + worst-case agreement for CI gating."""
    from repro.kernels.kmeans_assign.ops import kmeans_assign, last_dispatch
    from repro.kernels.kmeans_assign.ref import kmeans_assign_ref

    batched = jax.jit(kmeans_assign)
    # the vmap-of-kernel leg IS the measured anti-pattern (JL006's
    # regression baseline), not production dispatch
    vmapped = jax.jit(jax.vmap(kmeans_assign))  # jaxlint: disable=JL006
    oracle = jax.jit(kmeans_assign_ref)

    rng = np.random.default_rng(0)
    worst_agree = 1.0
    for b, n, k in SWEEP:
        x = jnp.asarray(rng.normal(size=(b, n, FEAT_D)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(b, k, FEAT_D)), jnp.float32)

        us_batched = _time_us(batched, x, c)
        rec = last_dispatch()
        us_vmapped = _time_us(vmapped, x, c)
        us_oracle = _time_us(oracle, x, c)

        l_b, _ = batched(x, c)
        l_o, _ = oracle(x, c)
        agree = float((np.asarray(l_b) == np.asarray(l_o)).mean())
        worst_agree = min(worst_agree, agree)

        tag = f"B{b}_N{n}_K{k}"
        mode = "interpret" if rec and rec["interpret"] else "compiled"
        print(f"kmeans_assign_batched_{tag},{us_batched:.0f},"
              f"us_per_call grid={rec['grid'] if rec else '?'} {mode}")
        print(f"kmeans_assign_vmapped_{tag},{us_vmapped:.0f},"
              f"us_per_call vmap-of-pallas_call {mode}")
        print(f"kmeans_assign_oracle_{tag},{us_oracle:.0f},us_per_call jnp")
        print(f"kmeans_assign_agreement_{tag},{agree:.4f},batched vs oracle")

    print(f"kmeans_assign_worst_agreement,{worst_agree:.4f},"
          "min over (B,N,K) sweep")
    return {"worst_agree": worst_agree}
