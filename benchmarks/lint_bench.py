"""Bench the static gate itself: one full jaxlint sweep of the repo.

Feeds the ``lint_clean`` claim row: the committed tree must pass its
own static gate (0 active findings, no stale baseline entries, every
baseline entry justified) and the full sweep must stay far inside the
CI fail-fast budget (< 10 s, stdlib ``ast`` only — the jax import
never happens on this path).
"""

from __future__ import annotations

import json
import pathlib
import sys

__all__ = ["bench_lint"]

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def bench_lint() -> dict:
    """One repo-wide jaxlint sweep: CSV rows + the claim-row summary."""
    src = str(_ROOT / "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.analysis import run_lint
    from repro.analysis.registry import RULES

    report = run_lint(root=_ROOT)
    baseline = json.loads((_ROOT / "lint_baseline.json").read_text())
    out = {
        "files": report.files,
        "rules": len(RULES),
        "active": len(report.active),
        "baselined": len(report.baselined),
        "suppressed": report.suppressed,
        "stale": len(report.stale),
        "errors": len(report.errors),
        "baseline_entries": len(baseline["entries"]),
        "seconds": round(report.duration_s, 3),
        "ok": report.ok,
    }
    print(f"lint_files,{report.files},python files swept")
    print(f"lint_rules,{out['rules']},registered rules")
    print(f"lint_active,{out['active']},findings failing the gate")
    print(f"lint_baselined,{out['baselined']},grandfathered+justified")
    print(f"lint_suppressed,{out['suppressed']},inline jaxlint comments")
    print(f"lint_seconds,{out['seconds']},full-sweep wall time")
    for f in report.active:
        print(f"lint_finding,0,{f.render()}")
    return out
