"""Streaming trial engine: throughput at scale + chunking invariance.

The tentpole claim of the streaming refactor is that Monte-Carlo trials
run as a chunked ``lax.scan`` whose memory is bounded by one chunk at
ANY trial count — so the 10^5-trial coverage-calibration study the
conservative-CI claim needs is a routine bench run, not an OOM. This
bench measures the streamed path end to end and reports:

* ``trials_streaming_rows`` — wall time and trials/sec per trial count
  (each row covers every scheme x app lane of the study, streamed with
  ``keep_trials=False``: no dense per-trial arrays come home);
* ``trials_chunked_bitwise`` — chunk_size=TRIAL_BLOCK vs the default
  chunking at 1000 trials: per-trial estimates and half-widths must be
  bitwise identical (the per-block PRNG fold-in contract). Gated in
  ``run.py`` claim validation;
* ``trials_coverage`` — empirical coverage of the calibrated schemes
  (``random`` eq. 2, ``rfv`` two-phase) at the largest trial count,
  gated >= 0.90 at nominal 95% — the proof that f32 accumulators stay
  calibrated at 10^5+ trials.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.experiments import ExperimentEngine, TrialSpec, run_trials
from repro.experiments.montecarlo import TRIAL_BLOCK

APPS = ("505.mcf_r", "520.omnetpp_r")
SCHEMES = ("random", "rfv")     # the calibrated/conservative CI paths


def bench_trials_streaming(trials: int = 100_000,
                           quick: bool = False) -> dict:
    """CSV rows + streaming claims for run.py validation."""
    import jax

    # multi-device hosts (CI_FORCE_DEVICES=8) stream through the 2-D
    # ("app", "trial") mesh — the psum coverage/CI merge runs for real
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        from repro.launch.mesh import make_app_trial_mesh
        mesh = make_app_trial_mesh(app_devices=min(len(APPS), n_dev))
        print(f"trials_mesh,{dict(mesh.shape)},app x trial devices")
    engine = ExperimentEngine(mesh=mesh)
    counts = [1000, 10_000, trials]
    if quick:
        counts = [1000, trials]
    counts = sorted(set(c for c in counts if c <= trials))

    # chunking invariance first (also warms every compile the timed rows
    # reuse at 1000 trials): chunked == unchunked must be bitwise
    base = TrialSpec(trials=1000, schemes=SCHEMES, keep_trials=True)
    r_def = run_trials(engine, base, apps=APPS)
    r_blk = run_trials(engine, dataclasses.replace(
        base, chunk_size=TRIAL_BLOCK), apps=APPS)
    bitwise = all(
        np.array_equal(r_def.estimates[s], r_blk.estimates[s])
        and np.array_equal(r_def.half_widths[s], r_blk.half_widths[s])
        and np.array_equal(r_def.stats[s].cover, r_blk.stats[s].cover)
        for s in SCHEMES)
    print(f"trials_chunked_bitwise,{bitwise},"
          f"chunk={TRIAL_BLOCK} vs default at 1000 trials")

    rows = []
    coverage: dict[str, float] = {}
    lanes = len(SCHEMES) * len(APPS)
    for n in counts:
        spec = TrialSpec(trials=n, schemes=SCHEMES, keep_trials=False)
        t0 = time.perf_counter()
        res = run_trials(engine, spec, apps=APPS)
        dt = time.perf_counter() - t0
        tps = n * lanes / dt
        rows.append({"trials": n, "seconds": round(dt, 3),
                     "trials_per_sec": round(tps, 1),
                     "devices": len(jax.devices())})
        print(f"trials_streaming_{n},{dt:.2f}s,"
              f"{tps:,.0f} trial-lanes/s over {lanes} scheme-app lanes, "
              f"streamed (no dense arrays)")
        coverage = {s: float(np.min(res.coverage[s])) for s in SCHEMES}
    for s, c in coverage.items():
        print(f"trials_coverage_{s},{c:.4f},"
              f"worst-app empirical coverage at {counts[-1]} trials "
              "(nominal 0.95)")
    return {"rows": rows, "chunked_bitwise": bool(bitwise),
            "coverage": coverage, "max_trials": counts[-1],
            "quick": bool(quick)}
