"""Streaming trial engine: throughput at scale + chunking invariance.

The tentpole claim of the streaming refactor is that Monte-Carlo trials
run as a chunked ``lax.scan`` whose memory is bounded by one chunk at
ANY trial count — so the 10^5-trial coverage-calibration study the
conservative-CI claim needs is a routine bench run, not an OOM. This
bench measures the streamed path end to end and reports:

* ``trials_streaming_rows`` — wall time and trials/sec per trial count
  (each row covers every scheme x app lane of the study, streamed with
  ``keep_trials=False``: no dense per-trial arrays come home);
* ``trials_chunked_bitwise`` — chunk_size=TRIAL_BLOCK vs the default
  chunking at 1000 trials: per-trial estimates and half-widths must be
  bitwise identical (the per-block PRNG fold-in contract). Gated in
  ``run.py`` claim validation;
* ``trials_coverage`` — empirical coverage of the calibrated schemes
  (``random`` eq. 2, ``rfv`` two-phase) at the largest trial count,
  gated >= 0.90 at nominal 95% — the proof that f32 accumulators stay
  calibrated at 10^5+ trials.

``bench_checkpoint_overhead`` times the fault-tolerance tax: the atomic
fleet snapshots (memo bank + every scheme's ``TrialStats``, the exact
tree ``run_trials_resumable`` writes per quantum) must cost < 5% of the
steady-state 10^6-trial study they protect — gated in ``run.py`` claim
validation.
"""

from __future__ import annotations

import dataclasses
import tempfile
import time

import numpy as np

from repro.experiments import ExperimentEngine, TrialSpec, run_trials
from repro.experiments.montecarlo import TRIAL_BLOCK
from repro.runtime.checkpoint import save_checkpoint

APPS = ("505.mcf_r", "520.omnetpp_r")
SCHEMES = ("random", "rfv")     # the calibrated/conservative CI paths


def bench_trials_streaming(trials: int = 100_000,
                           quick: bool = False) -> dict:
    """CSV rows + streaming claims for run.py validation."""
    import jax

    # multi-device hosts (CI_FORCE_DEVICES=8) stream through the 2-D
    # ("app", "trial") mesh — the psum coverage/CI merge runs for real
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        from repro.launch.mesh import make_app_trial_mesh
        mesh = make_app_trial_mesh(app_devices=min(len(APPS), n_dev))
        print(f"trials_mesh,{dict(mesh.shape)},app x trial devices")
    engine = ExperimentEngine(mesh=mesh)
    counts = [1000, 10_000, trials]
    if quick:
        counts = [1000, trials]
    counts = sorted(set(c for c in counts if c <= trials))

    # chunking invariance first (also warms every compile the timed rows
    # reuse at 1000 trials): chunked == unchunked must be bitwise
    base = TrialSpec(trials=1000, schemes=SCHEMES, keep_trials=True)
    r_def = run_trials(engine, base, apps=APPS)
    r_blk = run_trials(engine, dataclasses.replace(
        base, chunk_size=TRIAL_BLOCK), apps=APPS)
    bitwise = all(
        np.array_equal(r_def.estimates[s], r_blk.estimates[s])
        and np.array_equal(r_def.half_widths[s], r_blk.half_widths[s])
        and np.array_equal(r_def.stats[s].cover, r_blk.stats[s].cover)
        for s in SCHEMES)
    print(f"trials_chunked_bitwise,{bitwise},"
          f"chunk={TRIAL_BLOCK} vs default at 1000 trials")

    rows = []
    coverage: dict[str, float] = {}
    lanes = len(SCHEMES) * len(APPS)
    for n in counts:
        spec = TrialSpec(trials=n, schemes=SCHEMES, keep_trials=False)
        t0 = time.perf_counter()
        res = run_trials(engine, spec, apps=APPS)
        jax.block_until_ready(res.stats)   # async dispatch: sync the timer
        dt = time.perf_counter() - t0
        tps = n * lanes / dt
        rows.append({"trials": n, "seconds": round(dt, 3),
                     "trials_per_sec": round(tps, 1),
                     "devices": len(jax.devices())})
        print(f"trials_streaming_{n},{dt:.2f}s,"
              f"{tps:,.0f} trial-lanes/s over {lanes} scheme-app lanes, "
              f"streamed (no dense arrays)")
        coverage = {s: float(np.min(res.coverage[s])) for s in SCHEMES}
    for s, c in coverage.items():
        print(f"trials_coverage_{s},{c:.4f},"
              f"worst-app empirical coverage at {counts[-1]} trials "
              "(nominal 0.95)")
    return {"rows": rows, "chunked_bitwise": bool(bitwise),
            "coverage": coverage, "max_trials": counts[-1],
            "quick": bool(quick)}


def bench_checkpoint_overhead(trials: int = 1_000_000,
                              quick: bool = False) -> dict:
    """Checkpoint tax of a resumable trial study at default cadence.

    Times the steady-state (warm-compile, synced) 10^6-trial streamed
    study, then the exact snapshot the resumable driver publishes after
    each quantum (``MemoBank.state()`` + all ``TrialStats`` accumulators
    through ``save_checkpoint``, fsync + atomic rename included; best of
    3). At the default cadence ``run_trials_resumable`` writes one
    checkpoint per scheme quantum, so the study-level tax is
    ``len(schemes) * snapshot_s``; the claim gate in ``run.py`` requires
    that tax to stay under 5% of the run it makes resumable. The trial
    count stays at the 10^6 campaign scale even under ``--quick`` — the
    ratio is meaningless against a toy run (one warm 10^6 dispatch is
    only ~a second on a CPU host).
    """
    import jax

    engine = ExperimentEngine()
    spec = TrialSpec(trials=trials, schemes=SCHEMES, keep_trials=False)
    # warm at the FULL trial count (a different count is a different
    # compiled shape) and block on the timed results: run_trials
    # dispatches asynchronously, so an unsynced timer measures only the
    # enqueue, not the streamed scan the snapshot is compared against
    jax.block_until_ready(run_trials(engine, spec, apps=APPS).stats)
    t0 = time.perf_counter()
    res = run_trials(engine, spec, apps=APPS)
    jax.block_until_ready(res.stats)
    run_s = time.perf_counter() - t0

    memo_tree, meta = engine.memo.state()
    tree = {"memo": memo_tree, "stats": res.stats}
    snap_s = float("inf")
    with tempfile.TemporaryDirectory() as d:
        for step in range(3):
            t0 = time.perf_counter()
            save_checkpoint(d, step, tree,
                            extra={"memobank": meta, "next_quantum": step})
            snap_s = min(snap_s, time.perf_counter() - t0)
    nbytes = sum(np.asarray(leaf).nbytes
                 for leaf in jax.tree_util.tree_leaves(tree))
    n_quanta = len(SCHEMES)            # default cadence: 1/scheme quantum
    ratio = n_quanta * snap_s / run_s
    print(f"checkpoint_snapshot,{snap_s * 1e3:.1f}ms,"
          f"{nbytes / 1e6:.2f}MB fleet state (memo bank + "
          f"{len(SCHEMES)} schemes' TrialStats)")
    print(f"checkpoint_overhead_ratio,{ratio:.4f},"
          f"{n_quanta} snapshots / steady-state {trials}-trial run "
          f"({run_s:.2f}s), gate < 0.05")
    return {"trials": trials, "run_seconds": round(run_s, 3),
            "snapshot_seconds": round(snap_s, 4),
            "snapshots_per_study": n_quanta,
            "snapshot_mb": round(nbytes / 1e6, 3),
            "ratio": ratio, "quick": bool(quick)}
