"""Batched estimator engine vs the scalar loop: parity + throughput.

The tentpole claim of the array-native statistics layer is that one
batched ``StratumTables`` program over ``(A, T)`` design lanes replaces
A·T scalar ``summarize_strata`` + ``two_phase_estimate`` calls — with
identical results. This bench measures both paths on synthetic stratified
lanes and reports:

* ``estimators_scalar_us_per_lane`` / ``estimators_batched_us_per_lane``
  — wall time per design lane for each path (host CPU, float64);
* ``estimators_batched_speedup`` — scalar / batched;
* ``estimators_max_rel_err`` — worst relative deviation of the batched
  mean / two-phase variance / Satterthwaite df from the scalar reference
  across every lane. Gated in ``run.py`` claim validation at 1e-6 (the
  acceptance bar for batched == scalar).
"""

from __future__ import annotations

import dataclasses
import time
import warnings

import numpy as np

from repro.core.sampling import (WeightedPoint, critical_values,
                                 summarize_strata, two_phase_estimate)
from repro.core.sampling import plan as sampling_plan
from repro.core.sampling import tables as T

A_LANES = 4          # app-like axis
T_LANES = 250        # trial-like axis
N_SAMPLES = 200      # sampled units per lane
L_STRATA = 20
PHASE1_N = 6000

SWEEP_A = 10         # sweep-estimation shape: apps ...
SWEEP_C = 7          # ... x configs
SWEEP_A_LARGE = 2048  # service-scale rung: a coalesced tick's worth of
SWEEP_C_LARGE = 64    # stacked requests x a design-space config grid
SWEEP_REPS = 50      # timed repetitions (both paths, post-warmup)


def _rel_err(a, b):
    """Worst relative deviation; a one-sided NaN (batched NaN where the
    scalar is finite, or vice versa) counts as infinite mismatch rather
    than being silently dropped from the gate."""
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    if not np.array_equal(np.isnan(a), np.isnan(b)):
        return float("inf")
    denom = np.maximum(np.abs(b), 1e-12)
    with np.errstate(invalid="ignore"):
        err = np.abs(a - b) / denom
    return float(np.nanmax(err)) if np.isfinite(err).any() else 0.0


def bench_estimators() -> dict:
    """CSV rows + {max_rel_err, speedup} for claim validation."""
    rng = np.random.default_rng(0)
    y = rng.normal(2.0, 1.0, (A_LANES, T_LANES, N_SAMPLES)) \
        + 0.3 * rng.integers(0, 4, (A_LANES, 1, 1))
    labels = rng.integers(0, L_STRATA, (A_LANES, T_LANES, N_SAMPLES))
    weights = np.full(L_STRATA, 1.0 / L_STRATA)
    lanes = A_LANES * T_LANES

    # scalar reference: one summarize + estimate per lane (rare degenerate
    # lanes — an n_h < 2 stratum — warn in the scalar API; the batched
    # path marks the same lanes NaN, so both stay comparable)
    t0 = time.perf_counter()
    means_s = np.empty((A_LANES, T_LANES))
    vars_s = np.empty((A_LANES, T_LANES))
    dfs_s = np.empty((A_LANES, T_LANES))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)
        for a in range(A_LANES):
            for t in range(T_LANES):
                summ = summarize_strata(y[a, t], labels[a, t],
                                        weights=weights)
                est = two_phase_estimate(summ, phase1_n=PHASE1_N)
                means_s[a, t] = est.mean
                vars_s[a, t] = est.variance
                dfs_s[a, t] = est.df if est.df is not None else np.inf
    scalar_s = time.perf_counter() - t0

    # batched: ONE tables build + estimator evaluation for every lane
    t0 = time.perf_counter()
    tbl = T.stratum_tables(y, labels, weights=weights,
                           num_strata=L_STRATA)
    means_b = T.stratified_mean(tbl)
    vars_b = T.two_phase_variance(tbl, PHASE1_N)
    dfs_b = T.satterthwaite_df(tbl)
    margins = critical_values(0.95, dfs_b) * np.sqrt(vars_b)
    batched_s = time.perf_counter() - t0

    err = max(_rel_err(means_b, means_s), _rel_err(vars_b, vars_s),
              _rel_err(np.where(np.isfinite(dfs_b), dfs_b, np.inf), dfs_s))
    speedup = scalar_s / max(batched_s, 1e-9)

    print(f"estimators_scalar_us_per_lane,{scalar_s / lanes * 1e6:.1f},"
          f"{lanes} lanes")
    print(f"estimators_batched_us_per_lane,{batched_s / lanes * 1e6:.1f},"
          f"one (A,T,L) tables program")
    print(f"estimators_batched_speedup,{speedup:.1f},scalar/batched")
    print(f"estimators_max_rel_err,{err:.2e},mean|variance|df vs scalar")
    print(f"estimators_mean_margin_pct,"
          f"{float(np.nanmean(100 * margins / np.abs(means_b))):.3f},"
          "sanity: eq.6 margin at these lane sizes")
    sweep = _bench_sweep_estimates()
    return {"max_rel_err": err, "speedup": speedup,
            "scalar_s": scalar_s, "batched_s": batched_s, **sweep}


def _host_sweep_reduction(cpi, valid, weights, truth):
    """The historic host-numpy sweep reduction (pre-plan ``run_sweep``):
    covered-weight-renormalized weighted mean + percent error, float64."""
    w = np.where(valid, weights, 0.0)
    covered = w.sum(axis=1)
    ests = (cpi * w[:, None, :]).sum(axis=2) / covered[:, None]
    errs = 100.0 * np.abs(ests - truth) / truth
    return ests, errs


def _sweep_rung(a_n: int, c_n: int) -> dict:
    """One (apps x configs) rung of host-numpy vs jitted on-device sweep
    estimation: returns {max_rel_err, speedup, host_s, device_s, x64}."""
    rng = np.random.default_rng(1)
    cpi = rng.normal(2.0, 0.6, (a_n, c_n, L_STRATA))
    valid = rng.random((a_n, L_STRATA)) > 0.1
    valid[:, 0] = True                        # no fully-empty app lanes
    weights = rng.random((a_n, L_STRATA))
    weights /= weights.sum(axis=1, keepdims=True)
    truth = rng.normal(2.0, 0.1, (a_n, c_n))
    est = WeightedPoint()

    est_d, err_d = est.sweep_estimates(cpi, valid, weights, truth)  # warmup
    t0 = time.perf_counter()
    for _ in range(SWEEP_REPS):
        est_d, err_d = est.sweep_estimates(cpi, valid, weights, truth)
    device_s = (time.perf_counter() - t0) / SWEEP_REPS

    est_h, err_h = _host_sweep_reduction(cpi, valid, weights, truth)
    t0 = time.perf_counter()
    for _ in range(SWEEP_REPS):
        est_h, err_h = _host_sweep_reduction(cpi, valid, weights, truth)
    host_s = (time.perf_counter() - t0) / SWEEP_REPS

    marker = sampling_plan.last_sweep_dispatch() or {}
    return {"max_rel_err": max(_rel_err(est_d, est_h),
                               _rel_err(err_d, err_h)),
            "speedup": host_s / max(device_s, 1e-12),
            "host_s": host_s, "device_s": device_s,
            "x64": bool(marker.get("x64", False))}


def _bench_sweep_estimates() -> dict:
    """Host-numpy vs jitted on-device sweep estimation (the run_sweep
    stratified path) at TWO rungs: the paper's 10x7 matrix (tiny —
    launch cost dominates, device expected <1x) and a service-scale
    512x32 batch (where the device side should win). Parity gated at
    1e-6 in run.py claim validation; both speedups recorded so the
    claim row reflects where the device program actually pays off."""
    tiny = _sweep_rung(SWEEP_A, SWEEP_C)
    large = _sweep_rung(SWEEP_A_LARGE, SWEEP_C_LARGE)

    print(f"sweep_est_host_us,{tiny['host_s'] * 1e6:.1f},"
          f"numpy reduction ({SWEEP_A}x{SWEEP_C}x{L_STRATA})")
    print(f"sweep_est_device_us,{tiny['device_s'] * 1e6:.1f},"
          f"jitted StratumTables program (x64={tiny['x64']})")
    # "staged": the estimate-stage-only dispatch of the staged pipeline —
    # expected <1x at the tiny shape (launch cost dominates); the fused
    # megaprogram's crossover is bench_fused_sweep's claim, not this one's
    print(f"staged_sweep_speedup,{tiny['speedup']:.2f},host/device at "
          f"{SWEEP_A}x{SWEEP_C} (legacy staged row; see fused_sweep for "
          "the gated crossover)")
    print(f"staged_sweep_speedup_large,{large['speedup']:.2f},"
          f"host/device at {SWEEP_A_LARGE}x{SWEEP_C_LARGE} "
          "(service-scale batch)")
    err = max(tiny["max_rel_err"], large["max_rel_err"])
    print(f"sweep_est_max_rel_err,{err:.2e},device vs host f64, "
          "both rungs")
    return {"sweep_max_rel_err": err,
            "staged_sweep_speedup": tiny["speedup"],
            "staged_sweep_speedup_large": large["speedup"],
            "sweep_host_s": tiny["host_s"],
            "sweep_device_s": tiny["device_s"],
            "sweep_x64": tiny["x64"]}


# --------------------------------------------------- fused sweep megaprogram
FUSED_LADDER = [(2, 2), (4, 4), (10, 7)]      # (apps, configs) rungs
FUSED_LADDER_QUICK = [(2, 2), (2, 7)]         # CI smoke (reduced scale)
FUSED_REPS = 10
FUSED_REPS_QUICK = 4


def _memo_snapshot(memo):
    """Copy-out of every mutable MemoBank field (arrays may GROW between
    snapshot and restore as new config columns appear; restore handles
    the leading-slice writeback)."""
    return (memo.mask.copy(), memo.cpi.copy(), memo.charges.copy(),
            list(memo.hit_count), list(memo.miss_count),
            [(l.regions_simulated, l.instructions_simulated)
             if l is not None else None for l in memo.ledgers])


def _memo_restore(memo, snap):
    """Restore a ``_memo_snapshot`` (column growth since is zeroed)."""
    mask, cpi, charges, hits, misses, leds = snap
    memo.mask[...] = False
    memo.cpi[...] = 0.0
    memo.charges[...] = 0
    s3 = tuple(slice(0, d) for d in mask.shape)
    memo.mask[s3], memo.cpi[s3] = mask, cpi
    memo.charges[tuple(slice(0, d) for d in charges.shape)] = charges
    memo.hit_count[:] = hits
    memo.miss_count[:] = misses
    for led, st in zip(memo.ledgers, leds):
        if led is not None and st is not None:
            led.regions_simulated, led.instructions_simulated = st
    memo._spill.clear()   # spilled columns belong to the discarded state
    memo._col_tick.clear()
    memo.touch()          # direct table writes: drop device-block mirrors


def _ledger_totals(memo):
    return [(l.regions_simulated, l.instructions_simulated)
            if l is not None else None for l in memo.ledgers]


def bench_fused_sweep(quick: bool = False) -> dict:
    """Fused megaprogram vs staged pipeline over an (apps x configs)
    ladder: measures the host/device crossover — the smallest sweep at
    which ONE donated-buffer device program beats the staged
    selection -> fill -> estimate chain — and gates parity (<=1e-6) and
    bitwise ledger-charge equality at every rung."""
    import jax

    from repro.core.sampling import SamplingPlan
    from repro.experiments import SweepSpec, run_sweep

    from .simcpu_common import all_apps, get_engine

    engine = get_engine()
    ladder = FUSED_LADDER_QUICK if quick else FUSED_LADDER
    reps = FUSED_REPS_QUICK if quick else FUSED_REPS
    apps_all = all_apps()
    plan = SamplingPlan.from_strings("rfv", "centroid")
    rows = []
    for a_n, c_n in ladder:
        apps = tuple(apps_all[:a_n])
        engine.build(apps)
        spec = SweepSpec(apps=apps, plan=plan,
                         config_indices=tuple(range(c_n)))
        base = _memo_snapshot(engine.memo)

        t_s = run_sweep(engine, dataclasses.replace(spec, fused=False))
        led_staged = _ledger_totals(engine.memo)
        t0 = time.perf_counter()
        for _ in range(reps):
            run_sweep(engine, dataclasses.replace(spec, fused=False))
        staged_s = (time.perf_counter() - t0) / reps
        _memo_restore(engine.memo, base)

        t_f = run_sweep(engine, spec)                 # cold: compile + fill
        led_fused = _ledger_totals(engine.memo)
        marker = sampling_plan.last_sweep_dispatch() or {}
        t0 = time.perf_counter()
        for _ in range(reps):
            run_sweep(engine, spec)
        fused_s = (time.perf_counter() - t0) / reps
        _memo_restore(engine.memo, base)

        err = _rel_err([r.estimate for r in t_f], [r.estimate for r in t_s])
        speedup = staged_s / max(fused_s, 1e-12)
        n_units = int(sum(r.n_units for r in t_f)) // c_n
        rows.append({"apps": a_n, "configs": c_n, "regions": n_units,
                     "staged_ms": staged_s * 1e3, "fused_ms": fused_s * 1e3,
                     "speedup": speedup, "max_rel_err": err,
                     "ledger_eq": led_staged == led_fused,
                     "donated": bool(marker.get("donated", False))})
        print(f"fused_sweep_{a_n}x{c_n},{speedup:.2f},staged/fused "
              f"(staged {staged_s * 1e3:.1f}ms fused {fused_s * 1e3:.1f}ms "
              f"rel_err {err:.1e} ledger_eq={led_staged == led_fused})")

    crossover = next((r for r in rows if r["speedup"] >= 1.0), None)
    print("fused_sweep_crossover,"
          + (f"{crossover['apps']}x{crossover['configs']}" if crossover
             else "none")
          + f",smallest rung where fused >= 1x staged "
          f"({len(jax.devices())} device(s))")
    return {"rows": rows, "quick": bool(quick),
            "crossover": ((crossover["apps"], crossover["configs"])
                          if crossover else None),
            "max_rung": max((r["apps"], r["configs"]) for r in rows),
            "max_rel_err": max(r["max_rel_err"] for r in rows),
            "ledger_eq": all(r["ledger_eq"] for r in rows),
            "devices": len(jax.devices())}


def profile_fused_sweep(out_dir: str = "profile_traces") -> str:
    """Dump a ``jax.profiler`` trace of ONE warm fused sweep dispatch
    (for inspecting that the pipeline really is a single device program).
    Returns the trace directory."""
    import jax

    from repro.core.sampling import SamplingPlan
    from repro.experiments import SweepSpec, run_sweep

    from .simcpu_common import all_apps, get_engine

    engine = get_engine()
    apps = tuple(all_apps()[:2])
    engine.build(apps)
    spec = SweepSpec(apps=apps,
                     plan=SamplingPlan.from_strings("rfv", "centroid"),
                     config_indices=(0, 1))
    run_sweep(engine, spec)                           # compile + fill
    with jax.profiler.trace(out_dir):
        run_sweep(engine, spec)
    print(f"fused_sweep_profile,{out_dir},jax.profiler trace of one "
          "warm fused sweep")
    return out_dir
