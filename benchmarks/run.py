"""Benchmark harness: one function per paper table/figure.

Prints ``name,value,derived`` CSV and a final claim-validation summary,
and writes a machine-readable ``BENCH_results.json`` (per-bench timings
and results + claim outcomes) so the perf trajectory is tracked across
PRs. ``--quick`` trims Monte-Carlo trial counts (CI smoke).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

RESULTS_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_results.json"
HISTORY_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_history.jsonl"


def _force_devices(n: int) -> None:
    """Set the XLA host-device flag; must run BEFORE any jax import."""
    if n < 1:
        sys.exit(f"--devices must be >= 1, got {n}")
    if "jax" in sys.modules:
        sys.exit("--devices must take effect before jax is imported; "
                 "set XLA_FLAGS=--xla_force_host_platform_device_count="
                 f"{n} in the environment instead")
    flag = f"--xla_force_host_platform_device_count={n}"
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {flag}".strip()


def _jsonable(obj):
    """Conversion of bench results to STRICTLY valid JSON values.

    NaN/±Inf (python floats, numpy scalars, and entries inside numpy
    arrays) all become null — json.dumps would otherwise emit bare
    ``NaN``/``Infinity`` tokens that strict parsers reject, defeating
    the machine-readable ledger."""
    import math

    import numpy as np
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, np.generic):
        return _jsonable(obj.item())
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def write_results_json(benches: dict, claims: dict, ok: bool,
                       errors: list, total_s: float,
                       path: pathlib.Path = RESULTS_PATH) -> None:
    """Dump the machine-readable run record (the cross-PR perf ledger).

    Merges into an existing ledger: a partial run (``--only``) updates
    its own bench/claim rows and leaves the rest in place, so a targeted
    rerun never erases the full-suite record. ``overall_pass`` reflects
    only the rows this run validated."""
    payload = {
        "benches": _jsonable(benches),
        "claims": _jsonable(claims),
        "overall_pass": bool(ok),
        "errors": list(errors),
        "total_seconds": round(total_s, 2),
    }
    if path.exists():
        try:
            prior = json.loads(path.read_text())
            payload["benches"] = {**prior.get("benches", {}),
                                  **payload["benches"]}
            payload["claims"] = {**prior.get("claims", {}),
                                 **payload["claims"]}
        except (json.JSONDecodeError, AttributeError):
            pass                      # corrupt ledger: rewrite from scratch
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# results written to {path.name}")


def append_history(claims: dict, ok: bool, errors: list, total_s: float,
                   path: pathlib.Path = HISTORY_PATH) -> None:
    """Append one run record to the cross-PR perf trajectory ledger.

    ``BENCH_history.jsonl`` is append-only (one JSON object per line,
    committed to the repo, unlike the overwritten ``BENCH_results.json``
    snapshot): each CI run adds its git SHA, UTC timestamp and claim
    outcomes, so regressions are attributable to a commit by reading the
    ledger alone."""
    import datetime
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=path.parent, capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    entry = {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "claims": {name: bool(c["pass"]) for name, c in claims.items()},
        "overall_pass": bool(ok),
        "errors": list(errors),
        "total_seconds": round(total_s, 2),
    }
    with path.open("a") as fh:
        fh.write(json.dumps(entry, sort_keys=True) + "\n")
    print(f"# history entry appended to {path.name} ({sha})")


def main() -> None:
    """CLI entry: run benches, validate claims, write BENCH_results.json."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N XLA host devices (app-sharded sweeps); "
                    "must be set before jax initializes")
    ap.add_argument("--profile", action="store_true",
                    help="dump a jax.profiler trace of one warm fused "
                    "sweep dispatch (dispatch-count inspection)")
    ap.add_argument("--trials", type=int, default=None,
                    help="largest Monte-Carlo trial count for the "
                    "streaming trials bench (default 100000, or 10000 "
                    "with --quick)")
    args = ap.parse_args()

    if args.devices is not None:
        _force_devices(args.devices)

    from . import (estimators_bench, kernels_bench, kmeans_batched_bench,
                   lint_bench, paper_figs, serving_bench, trials_bench)

    max_trials = args.trials if args.trials is not None \
        else (10_000 if args.quick else 100_000)
    benches = {
        "fig1_cpi_distributions": paper_figs.bench_cpi_distributions,
        "fig5_config_sweep": paper_figs.bench_config_sweep,
        "fig7_ci_analytical": paper_figs.bench_ci_analytical,
        "fig8_ci_empirical": (lambda: paper_figs.bench_ci_empirical(
            trials=100 if args.quick else 1000)),
        "fig9_ci_collapsed": paper_figs.bench_ci_collapsed,
        "fig10_selection_centroid": paper_figs.bench_selection_centroid,
        "fig11_selection_mean": paper_figs.bench_selection_mean,
        "fig12_13_distribution_approx": paper_figs.bench_distribution_approx,
        "table4_two_phase_sizing": paper_figs.bench_two_phase_sizing,
        "gcc_cluster_sensitivity": paper_figs.bench_gcc_cluster_sensitivity,
        "beyond_approx_phase1": paper_figs.bench_approx_phase1,
        "beyond_isa_features": paper_figs.bench_isa_features,
        "kernels": kernels_bench.bench_kernels,
        "kmeans_batched": kmeans_batched_bench.bench_kmeans_batched,
        "estimators": estimators_bench.bench_estimators,
        # registered after fig5/estimators so a combined --only run shares
        # the process-wide engine (and its MemoBank) they already built
        "fused_sweep": (lambda: estimators_bench.bench_fused_sweep(
            quick=args.quick)),
        "trials_streaming": (lambda: trials_bench.bench_trials_streaming(
            trials=max_trials, quick=args.quick)),
        "checkpoint_overhead": (
            lambda: trials_bench.bench_checkpoint_overhead(
                quick=args.quick)),
        "serving": (lambda: serving_bench.bench_serving(quick=args.quick)),
        "lint": lint_bench.bench_lint,
    }
    if args.only:
        names = args.only.split(",")
        unknown = [n for n in names if n not in benches]
        if unknown:
            sys.exit(f"unknown bench name(s): {', '.join(unknown)}; "
                     f"choose from: {', '.join(benches)}")
        benches = {k: v for k, v in benches.items() if k in names}

    t0 = time.time()
    results = {}
    bench_records = {}
    errors = []
    for name, fn in benches.items():
        print(f"# === {name} ===", flush=True)
        tb = time.time()
        try:
            results[name] = fn()
            bench_records[name] = {"seconds": round(time.time() - tb, 3),
                                   "result": results[name]}
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            results[name] = None
            bench_records[name] = {"seconds": round(time.time() - tb, 3),
                                   "error": f"{type(e).__name__}: {e}"}
            errors.append(name)

    if args.profile:
        print("# === fused sweep profiler trace ===", flush=True)
        try:
            estimators_bench.profile_fused_sweep()
        except Exception as e:  # noqa: BLE001
            print(f"fused_sweep_profile,ERROR,{type(e).__name__}: {e}")
            errors.append("fused_sweep_profile")

    # ------------------------------------------------ claim validation
    print("# === claim validation (paper vs reproduction) ===")
    ok = True
    claims: dict[str, dict] = {}

    def check(name, cond, detail):
        nonlocal ok
        print(f"claim_{name},{'PASS' if cond else 'FAIL'},{detail}")
        claims[name] = {"pass": bool(cond), "detail": detail}
        ok = ok and cond

    r5 = results.get("fig5_config_sweep")
    if r5:
        check("geomean_speedup", 1.5 <= r5["speedup"] <= 1.9,
              f"cfg6/cfg0 {r5['speedup']:.2f} vs paper 1.68")
    r10 = results.get("fig10_selection_centroid")
    if r10:
        check("simpoint20_large_error", r10["worst_bbv"] >= 20.0,
              f"worst BBV centroid err {r10['worst_bbv']:.1f}% "
              "(paper: 40-60% for two apps)")
        check("two_phase_rfv_low_error", r10["worst_rfv"] <= 8.0,
              f"worst RFV err {r10['worst_rfv']:.1f}% (paper: ~3%)")
    r7 = results.get("fig7_ci_analytical")
    if r7:
        # qualitative phenomenon: BBV-stratified CIs CAN be worse than SRS
        # (paper: 5 of 10 apps; ours: the dominant-phase apps — see
        # EXPERIMENTS.md known deltas)
        check("bbv_worse_than_random", r7["bbv_worse"] >= 2,
              f"{r7['bbv_worse']} apps (paper: ~5)")
    rt = results.get("table4_two_phase_sizing")
    if rt:
        check("order_of_magnitude_reduction",
              rt["reduction_rfv"] >= 5.0,
              f"RFV phase-2 reduction {rt['reduction_rfv']:.1f}x "
              "(paper: 12.6x)")
        check("rfv_beats_bbv_sizing",
              rt["reduction_rfv"] > rt["reduction_bbv"],
              f"rfv {rt['reduction_rfv']:.1f}x vs bbv "
              f"{rt['reduction_bbv']:.1f}x (paper: 12.6 vs 3.5)")
    rg = results.get("gcc_cluster_sensitivity")
    if rg:
        check("gcc_k50_fixes_bbv", rg.get(50, 99) < rg.get(20, 0),
              f"k=20: {rg.get(20, 0):.1f}% -> k=50: {rg.get(50, 99):.1f}% "
              "(paper: 5.4% at k=50)")

    rb = results.get("kmeans_batched")
    if rb:
        check("batched_assign_matches_oracle", rb["worst_agree"] > 0.999,
              f"worst batched-vs-oracle agreement {rb['worst_agree']:.4f}")

    re_ = results.get("estimators")
    if re_:
        check("batched_estimators_match_scalar",
              re_["max_rel_err"] <= 1e-6,
              f"max rel err {re_['max_rel_err']:.2e} "
              f"(batched {re_['speedup']:.0f}x faster than scalar loop)")
        # f64 hosts must match to 1e-6; TPU keeps the program in f32 by
        # design (no native f64), so the gate loosens to f32 precision
        # there instead of failing by construction
        sweep_bound = 1e-6 if re_.get("sweep_x64") else 1e-4
        check("sweep_estimates_on_device_match_host",
              re_["sweep_max_rel_err"] <= sweep_bound,
              f"jitted StratumTables sweep estimation vs host numpy: "
              f"max rel err {re_['sweep_max_rel_err']:.2e} "
              f"(gate {sweep_bound:g}), "
              f"{re_['staged_sweep_speedup']:.2f}x host/device at 10x7 "
              f"(launch-bound) vs "
              f"{re_.get('staged_sweep_speedup_large', float('nan')):.2f}x "
              f"at service scale, x64={re_['sweep_x64']}")

    rf = results.get("fused_sweep")
    if rf:
        # two-part gate: parity + ledger equality at every rung, and the
        # fused megaprogram must beat the staged pipeline at (or below)
        # the largest rung tested — the full paper matrix (10 apps x 7
        # configs) on a non-quick run
        fused_bound = 1e-6
        won = rf["crossover"] is not None
        check("sweep_device_crossover",
              won and rf["max_rel_err"] <= fused_bound and rf["ledger_eq"],
              (f"fused megaprogram >= 1x staged at "
               f"{rf['crossover'][0]}x{rf['crossover'][1]} " if won
               else f"fused never beat staged up to "
               f"{rf['max_rung'][0]}x{rf['max_rung'][1]} ")
              + f"(max rel err {rf['max_rel_err']:.1e} gate "
              f"{fused_bound:g}, ledger_eq={rf['ledger_eq']}, "
              f"{rf['devices']} device(s), quick={rf['quick']})")

    rtr = results.get("trials_streaming")
    if rtr:
        check("streaming_chunked_bitwise", rtr["chunked_bitwise"],
              "chunked scan == unchunked bitwise at 1000 trials "
              "(per-block PRNG contract)")
        worst = min(rtr["coverage"].values())
        check("streaming_coverage_calibrated", worst >= 0.90,
              f"worst calibrated-scheme coverage {worst:.3f} at "
              f"{rtr['max_trials']} trials (gate 0.90, nominal 0.95, "
              "f32 accumulators)")
        scale_floor = 10_000 if rtr.get("quick") else 100_000
        top = rtr["rows"][-1]
        check("streaming_trials_scale", rtr["max_trials"] >= scale_floor,
              f"{top['trials']} trials streamed in {top['seconds']}s "
              f"({top['trials_per_sec']:,.0f} trial-lanes/s, "
              f"{top['devices']} device(s), bounded memory)")

    rco = results.get("checkpoint_overhead")
    if rco:
        check("checkpoint_overhead_small", rco["ratio"] < 0.05,
              f"{rco['snapshots_per_study']} fleet snapshots x "
              f"{rco['snapshot_seconds'] * 1e3:.1f}ms = "
              f"{100 * rco['ratio']:.2f}% of the steady-state "
              f"{rco['trials']}-trial run ({rco['run_seconds']}s, "
              f"{rco['snapshot_mb']}MB state, gate < 5%)")

    rl = results.get("lint")
    if rl:
        check("lint_clean", rl["ok"] and rl["seconds"] < 10.0,
              f"{rl['rules']} rules x {rl['files']} files: "
              f"{rl['active']} active, {rl['baselined']} baselined "
              f"({rl['baseline_entries']} justified entries), "
              f"{rl['suppressed']} suppressed, {rl['stale']} stale, "
              f"{rl['errors']} errors in {rl['seconds']:.2f}s "
              "(gate: clean and < 10s)")

    rs = results.get("serving")
    if rs:
        check("serving_coalesced_bitwise", rs["bitwise"],
              "coalesced stacked dispatches == serial run_sweep bitwise "
              "(estimates + ledger charge totals) at every K rung")
        # throughput gates the launch-bound rung (smallest apps) where
        # coalescing's launch amortization is the measured effect; quick
        # runs only smoke the machinery (2 reps, cold-heavy), so the
        # gate applies to full runs
        k8 = rs.get("speedup_k8") or 0.0
        gate = 2.0 if not rs.get("quick") else 0.5
        check("serving_coalesced_speedup", k8 >= gate,
              f"coalesced K=8 {k8:.2f}x vs serial (gate {gate:g}x, "
              f"quick={rs.get('quick')}; smallest-app launch-bound rung)")
        check("serving_eviction_bounded",
              rs["eviction_bounded"] and rs["eviction_ledger_exact"],
              f"peak resident {rs['peak_resident_cols']} <= cap "
              f"{rs['memo_cap']} ({rs['evicted_cols']} evictions), "
              f"ledger exact={rs['eviction_ledger_exact']} under spill")

    # a bench that crashed is a failure even if no claim row references it
    check("no_bench_errors", not errors,
          "errors in: " + "|".join(errors) if errors else "all benches ran")

    total_s = time.time() - t0
    print(f"benchmarks_total_s,{total_s:.1f},")
    print(f"benchmarks_overall,{'PASS' if ok else 'FAIL'},")
    write_results_json(bench_records, claims, ok, errors, total_s)
    append_history(claims, ok, errors, total_s)
    # CI contract: any FAILing claim-validation row (or bench error) must
    # make the process exit non-zero.
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
