"""Sweep-as-a-service benchmark: coalescing throughput, latency, and
bounded-memory eviction.

Three measurements over the shared process-wide engine:

* **Coalesced vs serial** — K same-shape sweep requests dispatched as
  ONE stacked fused launch (``run_coalesced_sweeps``) vs K warm serial
  ``run_sweep`` calls, at K in ``COALESCE_KS``. Both paths time the
  steady state (memo warm, programs compiled, device mirrors chained);
  the K ladder runs on the two smallest-population apps — the
  launch-bound regime coalescing exists for, where per-request dispatch
  overhead dominates compute — with a default-apps K=8 context row
  showing the compute-bound end. The claim row gates a >= 2x throughput
  win at K=8 on a full run, and also verifies the coalesced results +
  ledger totals are BITWISE equal to serial.
* **Service stream** — a deterministic mixed request stream through
  ``SweepService`` ticks: latency p50/p95, request throughput, and the
  lifetime memo cache-hit rate.
* **Eviction-bounded run** — the same stream under ``memo_cap`` with
  host-spill: resident memo columns must stay at/below the cap after
  every tick while ledger totals stay exact (spilled columns restore
  free).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.sampling.plan import RFVClusters, RandomUnit, SamplingPlan
from repro.experiments import SweepSpec, run_sweep
from repro.serving import SweepService, run_coalesced_sweeps
from repro.serving.cli import synthetic_stream
from repro.simcpu.workload import APP_SPECS

from .estimators_bench import _ledger_totals, _memo_restore, _memo_snapshot
from .simcpu_common import all_apps, get_engine

COALESCE_KS = (2, 8, 32)
COALESCE_KS_QUICK = (2, 8)
REPS = 9
REPS_QUICK = 2
STREAM_N = 48
STREAM_N_QUICK = 12
TICK = 8
MEMO_CAP = 2


def _coalesce_specs(apps, k: int) -> list[SweepSpec]:
    """K same-shape requests (one group): same plan/apps/configs,
    distinct selection seeds."""
    plan = SamplingPlan(RFVClusters(), RandomUnit())
    return [SweepSpec(apps=apps, plan=plan, config_indices=(0, 1, 2),
                      selection_seed=s) for s in range(k)]


def _small_apps(n: int = 2) -> tuple:
    """The n smallest-population apps — the launch-bound regime where
    per-request dispatch overhead dominates per-region compute."""
    return tuple(s.name for s in
                 sorted(APP_SPECS, key=lambda s: s.n_regions)[:n])


def _median_time(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _coalesce_rung(engine, apps, k: int, reps: int, base) -> dict:
    """Steady-state serial-vs-coalesced timing for one (apps, K) rung,
    with the bitwise + ledger equivalence check."""
    specs = _coalesce_specs(apps, k)

    serial = [run_sweep(engine, s) for s in specs]    # warm + compile
    led_serial = _ledger_totals(engine.memo)
    serial_s = _median_time(
        lambda: [run_sweep(engine, s) for s in specs], reps)
    _memo_restore(engine.memo, base)

    coal = run_coalesced_sweeps(engine, specs)        # warm + compile
    led_coal = _ledger_totals(engine.memo)
    run_coalesced_sweeps(engine, specs)   # reach mirror-chained steady
    coal_s = _median_time(lambda: run_coalesced_sweeps(engine, specs),
                          reps)
    _memo_restore(engine.memo, base)

    bitwise = _bitwise_eq(serial, coal) and led_serial == led_coal
    return {"k": k, "apps": list(apps), "serial_ms": serial_s * 1e3,
            "coalesced_ms": coal_s * 1e3,
            "speedup": serial_s / max(coal_s, 1e-12), "bitwise": bitwise}


def _bitwise_eq(tables_a, tables_b) -> bool:
    for ta, tb in zip(tables_a, tables_b):
        for col in ("estimate", "err_pct", "truth", "n_units"):
            if not np.array_equal(np.asarray(ta.column(col), float),
                                  np.asarray(tb.column(col), float)):
                return False
    return True


def bench_serving(quick: bool = False) -> dict:
    """CSV rows + claim inputs for the serving subsystem."""
    engine = get_engine()
    apps = _small_apps()
    engine.build(apps)
    ks = COALESCE_KS_QUICK if quick else COALESCE_KS
    reps = REPS_QUICK if quick else REPS
    base = _memo_snapshot(engine.memo)

    # ---------------------------------------- coalesced vs serial at K
    rows = []
    bitwise = True
    for k in ks:
        r = _coalesce_rung(engine, apps, k, reps, base)
        bitwise = bitwise and r["bitwise"]
        rows.append(r)
        print(f"serving_coalesce_k{k},{r['speedup']:.2f},serial/coalesced "
              f"(serial {r['serial_ms']:.1f}ms coalesced "
              f"{r['coalesced_ms']:.1f}ms bitwise={r['bitwise']})")
    speedup_k8 = next((r["speedup"] for r in rows if r["k"] == 8), None)

    # ------------------------------------------------- service stream
    n = STREAM_N_QUICK if quick else STREAM_N
    service = SweepService(engine)
    stream = synthetic_stream(n, seed=0, apps=apps)
    for start in range(0, n, TICK):
        for spec in stream[start:start + TICK]:
            service.submit(spec)
        service.tick()
    stats = service.stats()
    _memo_restore(engine.memo, base)
    print(f"serving_latency_p50_ms,{stats.latency_p50_s * 1e3:.1f},"
          f"{n} mixed requests, ticks of {TICK}")
    print(f"serving_latency_p95_ms,{stats.latency_p95_s * 1e3:.1f},"
          f"includes per-tick compile of new shapes")
    print(f"serving_throughput_rps,{stats.throughput_rps:.1f},"
          f"completed / busy seconds")
    print(f"serving_cache_hit_rate,{stats.cache_hit_rate:.3f},"
          f"bank hits / requested units, lifetime")
    print(f"serving_coalesced_requests,{stats.coalesced_requests},"
          f"of {n} served by stacked launches "
          f"({stats.dispatches} dispatches)")

    # ------------------------------------------- eviction-bounded run
    memo = engine.memo
    memo.evict(memo.resident_columns())        # start cold, charges kept
    cold = _memo_snapshot(memo)
    capped = SweepService(engine, memo_cap=MEMO_CAP, spill=True)
    over_cap = 0
    for start in range(0, n, TICK):
        for spec in stream[start:start + TICK]:
            capped.submit(spec)
        capped.tick()
        over_cap = max(over_cap,
                       len(memo.resident_columns()) - MEMO_CAP)
    cap_stats = capped.stats()
    capped_totals = _ledger_totals(memo)

    _memo_restore(memo, cold)                  # same stream, no cap
    free = SweepService(engine)
    for spec in stream:
        free.submit(spec)
    free.drain()
    exact = _ledger_totals(memo) == capped_totals
    _memo_restore(engine.memo, base)
    bounded = over_cap <= 0
    print(f"serving_eviction_peak_resident,{cap_stats.peak_resident_cols},"
          f"cap {MEMO_CAP}, {cap_stats.evicted_cols} evictions, "
          f"bounded={bounded}")
    print(f"serving_eviction_ledger_exact,{exact},capped+spill totals == "
          "uncapped (spilled columns restore free)")

    if not quick:
        # Compute-bound context rung on the default (larger) apps. Runs
        # LAST: building them grows the memo's app rows, which earlier
        # snapshots do not cover.
        big = tuple(all_apps()[:2])
        engine.build(big)
        rb = _coalesce_rung(engine, big, 8, reps,
                            _memo_snapshot(engine.memo))
        bitwise = bitwise and rb["bitwise"]
        rows.append(rb)
        print(f"serving_coalesce_k8_large,{rb['speedup']:.2f},"
              f"serial/coalesced on {'+'.join(big)} (compute-bound "
              f"context; bitwise={rb['bitwise']})")

    return {"rows": rows, "bitwise": bitwise, "speedup_k8": speedup_k8,
            "latency_p50_s": stats.latency_p50_s,
            "latency_p95_s": stats.latency_p95_s,
            "throughput_rps": stats.throughput_rps,
            "cache_hit_rate": stats.cache_hit_rate,
            "coalesced_requests": stats.coalesced_requests,
            "dispatches": stats.dispatches,
            "eviction_bounded": bounded,
            "eviction_ledger_exact": exact,
            "peak_resident_cols": cap_stats.peak_resident_cols,
            "evicted_cols": cap_stats.evicted_cols,
            "memo_cap": MEMO_CAP, "quick": bool(quick)}


def main(argv=None) -> None:
    """Standalone entry: ``python -m benchmarks.serving_bench [--quick]``."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    r = bench_serving(quick=args.quick)
    ok = (r["bitwise"] and r["eviction_bounded"]
          and r["eviction_ledger_exact"])
    print(f"serving_bench_ok,{ok},bitwise+bounded+exact")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
