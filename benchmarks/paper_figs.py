"""Paper-figure/table reproductions (one function per artifact).

Every function prints ``name,value,derived`` CSV rows and returns a dict of
headline numbers used by run.py for the summary + claim validation.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.sampling import (Estimate, StratumSummary,
                                 collapsed_strata_estimate,
                                 phase2_sizes_for_margin, srs_estimate,
                                 stratified_estimate, summarize_strata,
                                 two_phase_estimate)
from repro.core.sampling import SamplingPlan
from repro.experiments import SweepSpec, TrialSpec, run_sweep, run_trials
from repro.simcpu import CONFIGS

from .simcpu_common import (NUM_STRATA, all_apps, build_experiment,
                            get_engine, plan_selection)


def _row(name: str, value, derived: str = "") -> None:
    print(f"{name},{value},{derived}", flush=True)


# ---------------------------------------------------------------------- Fig 1/6
def bench_cpi_distributions() -> dict:
    """Fig 1 + Fig 6: CPI dispersion per app; aggregation over longer
    regions (10M/100M instructions = means of 10/100 consecutive 1M
    regions) lowers dispersion."""
    t0 = time.time()
    out = {}
    # one batched-over-app build for everything downstream
    for exp in get_engine().apps(all_apps()):
        name = exp.name
        cpi = exp.census(0)
        cvs = []
        for agg in (1, 10, 100):
            n = (cpi.shape[0] // agg) * agg
            c = cpi[:n].reshape(-1, agg).mean(axis=1)
            cvs.append(float(c.std() / c.mean()))
        out[name] = cvs
        _row(f"fig1_cv_{name}", round(cvs[0], 3),
             f"cv10M={cvs[1]:.3f};cv100M={cvs[2]:.3f}")
    mono = sum(1 for v in out.values() if v[0] >= v[1] >= v[2])
    _row("fig1_dispersion_monotone_apps", mono, "of 10 (expect ~10)")
    _row("fig1_time_s", round(time.time() - t0, 1))
    return {"monotone_apps": mono}


# ---------------------------------------------------------------------- Fig 5
def bench_config_sweep() -> dict:
    """Fig 5: per-app IPC across Configs 0-6 with tight phase-1 CIs.

    Runs through the experiment engine: per app, ONE vmapped dispatch
    evaluates the phase-1 sample on all 7 configs at once."""
    t0 = time.time()
    table = run_sweep(get_engine(), SweepSpec(apps=tuple(all_apps()),
                                              scheme="srs"))
    for r in table:
        if r.config_index in (0, 6):
            _row(f"fig5_ipc_{r.app}_cfg{r.config_index}",
                 round(1 / r.estimate, 3), f"margin_pct={r.margin_pct:.2f}")
    ipc = 1.0 / table.matrix("estimate")            # (7, n_apps)
    geo = np.exp(np.log(ipc).mean(axis=1))
    speedup = float(geo[6] / geo[0])
    _row("fig5_geomean_ipc_cfg0", round(geo[0], 3))
    _row("fig5_geomean_ipc_cfg6", round(geo[6], 3))
    _row("fig5_speedup_cfg6_over_cfg0", round(speedup, 3),
         "paper: 1.68 (1.52->2.56)")
    _row("fig5_time_s", round(time.time() - t0, 1))
    return {"speedup": speedup, "geo0": geo[0], "geo6": geo[6]}


# ------------------------------------------------------------------- helpers
def _analytical_margin(exp, scheme: str, cfg_i: int,
                       kmeans_seed: int = 0) -> float:
    """95% margin (%) for one-unit-per-stratum stratified sampling using
    exact within-stratum variances (census for BBV, phase-1 for RFV/DG)."""
    if scheme == "random":
        cpi = exp.census(cfg_i)
        n = 20
        var = float(cpi.var(ddof=1)) / n
        est = Estimate(mean=float(cpi.mean()), variance=var, n=n,
                       df=float(n - 1))
        return est.margin_pct
    if scheme == "bbv":
        labels, weights = exp.bbv_labels, exp.bbv_weights
        cpi = exp.census(cfg_i)
    else:
        labels = exp.rfv_labels if scheme == "rfv" else exp.dg_labels
        weights = exp.rfv_weights if scheme == "rfv" else exp.dg_weights
        cpi = exp.cpi(cfg_i, exp.idx1)
    summ = []
    for h in range(NUM_STRATA):
        m = labels == h
        if m.sum() < 2:
            summ.append(StratumSummary(weight=float(weights[h]),
                                       n=2, mean=float(cpi[m].mean())
                                       if m.any() else 0.0, var=0.0))
            continue
        v = float(cpi[m].var(ddof=1))
        summ.append(StratumSummary(weight=float(weights[h]), n=1,
                                   mean=float(cpi[m].mean()), var=v))
    # one unit per stratum: v(ybar) = sum W_h^2 s_h^2 (n_h = 1)
    var = sum(s.weight ** 2 * s.var for s in summ)
    mean = sum(s.weight * s.mean for s in summ)
    est = Estimate(mean=mean, variance=var, n=NUM_STRATA,
                   df=float(NUM_STRATA // 2))
    return est.margin_pct


# ---------------------------------------------------------------------- Fig 7
def bench_ci_analytical() -> dict:
    """Fig 7: analytical 95% margins at n=20 for the four schemes
    (config 6, stratifications built from config-0 data)."""
    t0 = time.time()
    worse_than_random = []
    margins = {}
    for name in all_apps():
        exp = build_experiment(name)
        m_rand = _analytical_margin(exp, "random", 6)
        m_bbv = _analytical_margin(exp, "bbv", 6)
        m_rfv = _analytical_margin(exp, "rfv", 6)
        m_dg = _analytical_margin(exp, "dg", 6)
        margins[name] = (m_rand, m_bbv, m_rfv, m_dg)
        if m_bbv > m_rand:
            worse_than_random.append(name)
        _row(f"fig7_margin_{name}", round(m_rand, 1),
             f"bbv={m_bbv:.1f};rfv={m_rfv:.1f};dg={m_dg:.1f}")
    _row("fig7_bbv_worse_than_random", len(worse_than_random),
         "apps (paper: ~5 of 10): " + "|".join(
             w.split(".")[1] for w in worse_than_random))
    rfv_ok = sum(1 for m in margins.values() if m[2] < 12.0)
    _row("fig7_rfv_margin_lt12pct", rfv_ok, "apps (paper: most <10%)")
    _row("fig7_time_s", round(time.time() - t0, 1))
    return {"bbv_worse": len(worse_than_random), "margins": margins}


# ---------------------------------------------------------------------- Fig 8
def bench_ci_empirical(trials: int = 1000) -> dict:
    """Fig 8: Monte-Carlo 95th-percentile |error| at n=20 per scheme.

    Runs through ``run_trials``: ONE vmapped (app-sharded when a mesh is
    configured) dispatch per scheme over the (app, trial, stratum) axes —
    the historic per-app, per-stratum numpy loops are gone."""
    t0 = time.time()
    res = run_trials(get_engine(), TrialSpec(trials=trials),
                     apps=tuple(all_apps()))
    results = {}
    for a, name in enumerate(res.apps):
        results[name] = {k: float(np.percentile(res.errors[k][a], 95))
                         for k in res.errors}
        r = results[name]
        _row(f"fig8_p95err_{name}", round(r["random"], 1),
             f"bbv={r['bbv']:.1f};rfv={r['rfv']:.1f};dg={r['dg']:.1f}")
    # the Fig 8 -> CI-claim bridge: empirical coverage of the per-trial
    # CIs (SRS t-interval / stratified collapsed pairs), per scheme
    for scheme, cov in res.coverage.items():
        _row(f"fig8_ci_coverage_{scheme}", round(float(np.mean(cov)), 3),
             "mean empirical coverage of nominal 95% per-trial CIs")
    _row("fig8_time_s", round(time.time() - t0, 1))
    results["coverage"] = {k: float(np.mean(v))
                           for k, v in res.coverage.items()}
    return results


# ---------------------------------------------------------------------- Fig 9
def bench_ci_collapsed() -> dict:
    """Fig 9: practically computable CI — collapsed strata from exactly 20
    simulations of config 6 (one per RFV stratum, random unit)."""
    t0 = time.time()
    out = {}
    for name in all_apps():
        exp = build_experiment(name)
        sel, weights = plan_selection(
            exp, SamplingPlan.from_strings("rfv", "random"), seed=3)
        y = np.array([float(exp.cpi(6, s)[0]) for s in sel if s.size])
        w = np.array([weights[h] for h, s in enumerate(sel) if s.size])
        w = w / w.sum()
        order = np.array([exp.cpi0_1[exp.rfv_labels == h].mean()
                          for h, s in enumerate(sel) if s.size])
        est = collapsed_strata_estimate(y, w, order_by=order)
        covered = est.covers(exp.truth[6])
        out[name] = (est.margin_pct, covered)
        _row(f"fig9_collapsed_margin_{name}", round(est.margin_pct, 1),
             f"covers_truth={covered}")
    cov = sum(1 for _, c in out.values() if c)
    _row("fig9_coverage", cov, "of 10 apps (95% CI; collapsed strata are "
                               "approximate)")
    _row("fig9_time_s", round(time.time() - t0, 1))
    return out


# --------------------------------------------------------------------- Fig 10
def bench_selection_centroid() -> dict:
    """Fig 10: measured errors (Configs 0-6) with centroid selection.

    One ``run_sweep`` per scheme: each app's 20 selected regions are
    evaluated on all 7 configs in a single batched dispatch."""
    t0 = time.time()
    engine = get_engine()
    out = {name: {} for name in all_apps()}
    for scheme in ("bbv", "rfv", "dg"):
        table = run_sweep(engine, SweepSpec(
            apps=tuple(all_apps()),
            plan=SamplingPlan.from_strings(scheme, "centroid")))
        for name in all_apps():
            out[name][scheme] = float(
                table.filter(app=name).column("err_pct").max())
    for name, maxerr in out.items():
        _row(f"fig10_maxerr_{name}", round(maxerr["bbv"], 1),
             f"rfv={maxerr['rfv']:.1f};dg={maxerr['dg']:.1f}")
    worst_bbv = max(v["bbv"] for v in out.values())
    worst_rfv = max(v["rfv"] for v in out.values())
    _row("fig10_worst_bbv_err", round(worst_bbv, 1),
         "paper: 40-60% for two apps")
    _row("fig10_worst_rfv_err", round(worst_rfv, 1), "paper: ~3%")
    _row("fig10_time_s", round(time.time() - t0, 1))
    return {"worst_bbv": worst_bbv, "worst_rfv": worst_rfv, "per_app": out}


# --------------------------------------------------------------------- Fig 11
def bench_selection_mean() -> dict:
    """Fig 11: mean selection (baseline-CPI nearest stratum mean)."""
    t0 = time.time()
    engine = get_engine()
    out = {name: {} for name in all_apps()}
    for scheme in ("bbv", "rfv", "dg"):
        table = run_sweep(engine, SweepSpec(
            apps=tuple(all_apps()),
            plan=SamplingPlan.from_strings(scheme, "mean")))
        for name in all_apps():
            out[name][scheme] = float(
                table.filter(app=name).column("err_pct").max())
    for name, maxerr in out.items():
        _row(f"fig11_maxerr_{name}", round(maxerr["bbv"], 1),
             f"rfv={maxerr['rfv']:.1f};dg={maxerr['dg']:.1f}")
    worst_bbv = max(v["bbv"] for v in out.values())
    _row("fig11_worst_bbv_err", round(worst_bbv, 1),
         "paper: BBV improved vs Fig 10, still worse than RFV")
    _row("fig11_time_s", round(time.time() - t0, 1))
    return {"worst_bbv_mean": worst_bbv, "per_app": out}


# ------------------------------------------------------------------ Fig 12/13
def bench_distribution_approx() -> dict:
    """Fig 12/13: distribution approximated by 20 vs 500 selected regions —
    Kolmogorov-Smirnov distance to the census CPI distribution."""
    from repro.core.clustering import kmeans
    from repro.core.sampling import select_centroid
    t0 = time.time()
    out = {}
    for name in all_apps():
        exp = build_experiment(name)
        census = np.sort(exp.census(0))
        ks = {}
        for k in (20, 500):
            if k == 20:
                sel, weights = plan_selection(
                    exp, SamplingPlan.from_strings("rfv", "centroid"))
            else:
                km = kmeans(exp.rfv_z, min(k, exp.idx1.size // 2), seed=0)
                w = np.bincount(km.labels,
                                minlength=km.centroids.shape[0]).astype(float)
                w /= w.sum()
                local = select_centroid(km.labels, exp.rfv_z, km.centroids)
                sel, weights = [exp.idx1[l] for l in local], w
            vals, ws = [], []
            for h, s in enumerate(sel):
                if s.size:
                    vals.append(float(exp.cpi(0, s)[0]))
                    ws.append(weights[h])
            vals = np.asarray(vals)
            ws = np.asarray(ws) / np.sum(ws)
            order = np.argsort(vals)
            vals, ws = vals[order], ws[order]
            approx_cdf_at = np.cumsum(ws)
            census_cdf = np.searchsorted(census, vals, side="right") \
                / census.size
            ks[k] = float(np.max(np.abs(approx_cdf_at - census_cdf)))
        out[name] = ks
        _row(f"fig12_ks20_{name}", round(ks[20], 3),
             f"ks500={ks[500]:.3f}")
    improved = sum(1 for v in out.values() if v[500] <= v[20] + 1e-9)
    _row("fig13_ks_improved_at_500", improved, "of 10 apps")
    _row("fig12_time_s", round(time.time() - t0, 1))
    return out


# -------------------------------------------------------------------- Table IV
def bench_two_phase_sizing() -> dict:
    """Table IV: phase-2 sizes for <=1.5x the phase-1 random margin, RFV vs
    BBV stratification; derived reduction factors vs simple random."""
    t0 = time.time()
    tot_rand = tot_rfv = tot_bbv = 0
    rows = {}
    for name in all_apps():
        exp = build_experiment(name)
        cpi6_p1 = exp.cpi(6, exp.idx1)
        n1 = exp.idx1.size
        est1 = srs_estimate(cpi6_p1)
        target_abs = 1.5 * est1.margin / 1.959964  # margin -> sigma units
        # within-stratum stds + between-var for eq.(6)
        sizes = {}
        for scheme, labels, weights in (
                ("rfv", exp.rfv_labels, exp.rfv_weights),
                ("bbv_p1", None, None)):
            if scheme == "bbv_p1":
                # classify phase-1 units into census BBV strata
                labels = exp.bbv_labels[exp.idx1]
                weights = exp.bbv_weights
            stds = np.array([cpi6_p1[labels == h].std(ddof=1)
                             if (labels == h).sum() > 1 else 0.0
                             for h in range(NUM_STRATA)])
            mean = float(np.sum(weights * np.array(
                [cpi6_p1[labels == h].mean() if (labels == h).any() else 0.0
                 for h in range(NUM_STRATA)])))
            between = float(np.sum(weights * (np.array(
                [cpi6_p1[labels == h].mean() if (labels == h).any() else mean
                 for h in range(NUM_STRATA)]) - mean) ** 2))
            try:
                n_h = phase2_sizes_for_margin(
                    weights, stds, n1, between,
                    target_margin_abs=1.5 * est1.margin,
                    allocation="neyman")
                sizes[scheme] = int(n_h.sum())
            except ValueError:
                sizes[scheme] = n1  # unattainable: fall back to full SRS
        rows[name] = (n1, sizes["rfv"], sizes["bbv_p1"])
        tot_rand += n1
        tot_rfv += sizes["rfv"]
        tot_bbv += sizes["bbv_p1"]
        _row(f"table4_{name}", n1,
             f"rfv={sizes['rfv']};bbv={sizes['bbv_p1']};"
             f"margin_random_pct={est1.margin_pct:.2f}")
    red_rfv = tot_rand / max(tot_rfv, 1)
    red_bbv = tot_rand / max(tot_bbv, 1)
    _row("table4_total_random", tot_rand, "paper: 24079")
    _row("table4_total_rfv", tot_rfv,
         f"reduction={red_rfv:.1f}x (paper: 12.6x, 1917 sims)")
    _row("table4_total_bbv", tot_bbv,
         f"reduction={red_bbv:.1f}x (paper: 3.5x, 6818 sims)")
    _row("table4_time_s", round(time.time() - t0, 1))
    return {"reduction_rfv": red_rfv, "reduction_bbv": red_bbv,
            "per_app": rows}


# ------------------------------------------------- gcc k-sensitivity (V.B.1)
def bench_gcc_cluster_sensitivity() -> dict:
    """Paper V.B.1: raising gcc's BBV clusters 20 -> 50 collapses the
    centroid-selection error (our dominant-phase mechanism reproduces it)."""
    from repro.core.clustering import kmeans as _kmeans
    from repro.core.sampling import select_centroid
    t0 = time.time()
    exp = build_experiment("502.gcc_r")
    z = exp.bbv_feats
    out = {}
    for k in (20, 50):
        km = _kmeans(z, k, seed=0)
        w = np.bincount(km.labels, minlength=k) / z.shape[0]
        sel = select_centroid(km.labels, z, km.centroids)
        ests = exp.weighted_cpi_all(sel, w)        # one batched dispatch
        errs = 100 * np.abs(ests - exp.truth) / exp.truth
        out[k] = float(errs.max())
        _row(f"gcc_bbv_maxerr_k{k}", round(out[k], 1),
             "paper: k=50 -> 5.4%")
    _row("gcc_sensitivity_time_s", round(time.time() - t0, 1))
    return out


# ------------------------------------------ beyond-paper: §VI.C directions
def bench_approx_phase1() -> dict:
    """Paper §VI.C (proposed, not evaluated): run phase 1 on a FAST
    approximate simulator, stratify on its (biased) RFV, then study
    accurate configurations on the selected regions. The phase-1 cost drops
    ~6x (model-term count); the question is how much selection quality
    degrades vs accurate-RFV stratification."""
    import numpy as np

    from repro.core.clustering import Standardizer, kmeans
    from repro.core.sampling import select_centroid
    from repro.simcpu.perfmodel import evaluate_regions_approx
    t0 = time.time()
    worst = {}
    for name in all_apps():
        exp = build_experiment(name)
        pop = exp.sim.pop
        # approximate RFV on the same phase-1 sample
        stats = evaluate_regions_approx(pop.features, CONFIGS[0], exp.idx1)
        feats = np.stack([stats[k] for k in sorted(stats)], axis=1)
        _, z = Standardizer.fit_transform(feats)
        z = np.asarray(z)
        km = kmeans(z, NUM_STRATA, seed=0, restarts=2)
        w = np.bincount(km.labels, minlength=NUM_STRATA) / exp.idx1.size
        sel = [exp.idx1[s] for s in
               select_centroid(km.labels, z, km.centroids)]
        ests = exp.weighted_cpi_all(sel, w)        # one batched dispatch
        errs = 100 * np.abs(ests - exp.truth) / exp.truth
        worst[name] = float(errs.max())
        _row(f"approx_phase1_maxerr_{name}", round(worst[name], 1))
    _row("approx_phase1_worst", round(max(worst.values()), 1),
         "approximate-simulator phase 1 (beyond-paper, paper proposes in "
         "VI.C)")
    _row("approx_phase1_time_s", round(time.time() - t0, 1))
    return {"worst": max(worst.values()), "per_app": worst}


def bench_isa_features() -> dict:
    """Paper §VI.C: stratify on microarchitecture-INDEPENDENT (ISA-level)
    features. Our populations' intrinsic feature vectors (ILP, branch/miss
    potentials, working-set sensitivities) are exactly such features —
    available without any cycle-accurate run."""
    import numpy as np

    from repro.core.clustering import Standardizer, kmeans
    from repro.core.sampling import select_centroid
    t0 = time.time()
    worst = {}
    for name in all_apps():
        exp = build_experiment(name)
        pop = exp.sim.pop
        feats = pop.features[exp.idx1]
        _, z = Standardizer.fit_transform(feats)
        z = np.asarray(z)
        km = kmeans(z, NUM_STRATA, seed=0, restarts=2)
        w = np.bincount(km.labels, minlength=NUM_STRATA) / exp.idx1.size
        sel = [exp.idx1[s] for s in
               select_centroid(km.labels, z, km.centroids)]
        ests = exp.weighted_cpi_all(sel, w)        # one batched dispatch
        errs = 100 * np.abs(ests - exp.truth) / exp.truth
        worst[name] = float(errs.max())
        _row(f"isa_features_maxerr_{name}", round(worst[name], 1))
    _row("isa_features_worst", round(max(worst.values()), 1),
         "ISA-level stratification (beyond-paper, paper proposes in VI.C)")
    _row("isa_features_time_s", round(time.time() - t0, 1))
    return {"worst": max(worst.values()), "per_app": worst}
