"""Kernel micro-benchmarks: correctness deltas + host-side timings.

On this CPU container the Pallas kernels run in interpret mode (slow by
construction — correctness validation only); the jnp reference paths are
what the timings characterize. us_per_call is wall time of the jitted call.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        leaf = out[0] if isinstance(out, tuple) else out
        leaf.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels() -> dict:
    from repro.core.clustering.kmeans import _assign_jnp
    from repro.kernels.kmeans_assign.ops import kmeans_assign
    from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
    from repro.kernels.segment_stats.ops import segment_stats
    from repro.kernels.segment_stats.ref import segment_stats_ref

    rng = np.random.default_rng(0)
    out = {}

    # k-means assignment: the paper's scalability hot spot (>=100k BBVs)
    x = jnp.asarray(rng.normal(size=(100_000, 15)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(20, 15)), jnp.float32)
    ref = jax.jit(kmeans_assign_ref)
    us_ref = _timeit(ref, x, c)
    l1, d1 = kmeans_assign(x[:4096], c)
    l2, d2 = kmeans_assign_ref(x[:4096], c)
    agree = float((np.asarray(l1) == np.asarray(l2)).mean())
    print(f"kmeans_assign_ref_100k,{us_ref:.0f},us_per_call")
    print(f"kmeans_assign_pallas_agreement,{agree:.4f},interpret-mode vs ref")
    out["kmeans_agree"] = agree

    # segment stats (stratified moments); backend="pallas" so the kernel
    # body is actually exercised off-TPU (interpret mode) — the default
    # "auto" would serve the oracle and compare it to itself
    lab = jnp.asarray(rng.integers(0, 20, 100_000), jnp.int32)
    ref2 = jax.jit(lambda a, b: segment_stats_ref(a, b, 20))
    us2 = _timeit(ref2, x, lab)
    s1, q1, c1 = segment_stats(x[:8192], lab[:8192], 20, backend="pallas")
    s2, q2, c2 = segment_stats_ref(x[:8192], lab[:8192], 20)
    err = float(jnp.max(jnp.abs(s1 - s2)))
    print(f"segment_stats_ref_100k,{us2:.0f},us_per_call")
    print(f"segment_stats_pallas_maxerr,{err:.2e},interpret-mode vs ref")
    out["segment_err"] = err

    # flash attention (oracle check at a serving-ish shape)
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    o1 = flash_attention(q, k, v)
    o2 = attention_ref(q, k, v, causal=True)
    ferr = float(jnp.max(jnp.abs(o1 - o2)))
    us3 = _timeit(jax.jit(lambda a, b, c_: attention_ref(a, b, c_,
                                                         causal=True)),
                  q, k, v)
    print(f"flash_attention_ref,{us3:.0f},us_per_call")
    print(f"flash_attention_pallas_maxerr,{ferr:.2e},interpret-mode vs ref")
    out["flash_err"] = ferr

    # distributed k-means (paper §VII.B at host scale)
    from repro.core.clustering.distributed import distributed_kmeans
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    t0 = time.perf_counter()
    _, _, inertia = distributed_kmeans(np.asarray(x[:20_000]), 20, mesh,
                                       iters=5)
    dt = (time.perf_counter() - t0) * 1e6
    print(f"distributed_kmeans_20k_5it,{dt:.0f},inertia={inertia:.3e}")
    return out
