"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) single-pod cell, from the trip-count-weighted HLO costs
(see repro/launch/hlo_analysis.py — XLA's cost_analysis() counts loop
bodies once):

    compute    = weighted_HLO_FLOPs(per device) / peak_FLOPs
    memory     = weighted_HLO_bytes(per device) / HBM_bw
    collective = weighted_wire_bytes(per device) / ICI_bw

"Useful" work per device:
    train/prefill: MODEL_FLOPS/device at peak        (compute-normalized)
    decode:        minimum stream bytes (params + caches, read once) / HBM
                   (decode is memory-bound by construction)

roofline_fraction = useful_time / max(term) — the fraction of the
achievable bound spent on useful work; the score the perf loop drives up.

Hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI.
"""

from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = Path("results/dryrun")


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("ok"):
            cells.append(rec)
    return cells


def _min_decode_bytes(rec: dict) -> float:
    """Per-device lower bound on decode-step HBM traffic: every live
    parameter byte + cache byte must stream once."""
    from repro.configs import get_config
    from repro.configs.base import SHAPE_BY_NAME
    from repro.models.common import ModelConfig  # noqa: F401
    cfg = get_config(rec["arch"])
    cell = SHAPE_BY_NAME[rec["shape"]]
    n_dev = 1
    for s in rec["mesh_shape"]:
        n_dev *= s
    param_bytes = cfg.active_param_count() * 2          # bf16
    if cfg.family in ("ssm", "hybrid"):
        cache = cfg.n_layers * cell.global_batch * cfg.d_model * 64 * 4
        if cfg.family == "hybrid":
            win = min(cfg.window or cell.seq_len, cell.seq_len)
            cache = (cfg.n_layers // 3) * cell.global_batch * \
                cfg.n_kv_heads * win * cfg.head_dim * 2 * 2
    else:
        cache = cfg.n_layers * cell.global_batch * cfg.n_kv_heads * \
            cell.seq_len * cfg.head_dim * 2 * 2
    return (param_bytes + cache) / n_dev


def roofline_terms(rec: dict) -> dict:
    cw = rec.get("cost_weighted") or {
        "flops": rec["cost"]["flops"], "bytes": rec["cost"]["bytes_accessed"]}
    flops = cw["flops"]
    bytes_acc = cw["bytes"]
    wire = rec["collectives"]["total_wire_bytes"]
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_acc / HBM_BW
    t_coll = wire / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_comp, t_mem, t_coll, 1e-12)
    n_dev = 1
    for s in rec["mesh_shape"]:
        n_dev *= s
    model_flops_dev = rec["model_flops"] / n_dev
    if rec["shape"].startswith(("decode", "long")):
        useful_t = _min_decode_bytes(rec) / HBM_BW
    else:
        useful_t = model_flops_dev / PEAK_FLOPS
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant, "bound_s": bound,
        "model_flops_per_dev": model_flops_dev,
        "hlo_flops_per_dev": flops,
        "useful_ratio": min(model_flops_dev / flops, 1.0) if flops else 0.0,
        "roofline_fraction": min(useful_t / bound, 1.0),
    }


def main() -> None:
    cells = load_cells("single")
    if not cells:
        print("roofline,0,no dry-run artifacts found (run repro.launch.dryrun)")
        return
    print("arch,shape,t_compute_ms,t_memory_ms,t_collective_ms,dominant,"
          "useful_ratio,roofline_fraction")
    rows = []
    for rec in cells:
        r = roofline_terms(rec)
        rows.append(r)
        print(f"{r['arch']},{r['shape']},{r['t_compute_s']*1e3:.2f},"
              f"{r['t_memory_s']*1e3:.2f},{r['t_collective_s']*1e3:.2f},"
              f"{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['roofline_fraction']:.3f}")
    Path("results").mkdir(exist_ok=True)
    Path("results/roofline.json").write_text(json.dumps(rows, indent=1))
    train_rows = [r for r in rows if r["shape"].startswith(
        ("train", "prefill"))]
    worst = min(train_rows, key=lambda r: r["roofline_fraction"])
    coll = max(rows, key=lambda r: r["t_collective_s"] /
               max(r["bound_s"], 1e-12))
    best = max(train_rows, key=lambda r: r["roofline_fraction"])
    print(f"roofline_worst_train_cell,{worst['arch']}|{worst['shape']},"
          f"fraction={worst['roofline_fraction']:.3f}")
    print(f"roofline_best_train_cell,{best['arch']}|{best['shape']},"
          f"fraction={best['roofline_fraction']:.3f}")
    print(f"roofline_most_collective,{coll['arch']}|{coll['shape']},"
          f"t_coll_ms={coll['t_collective_s']*1e3:.2f}")


if __name__ == "__main__":
    main()
