"""Shared experiment state for the paper-reproduction benchmarks.

The per-app state (stratifications, phase-1 sample, memoized simulator)
now lives in ``repro.experiments.engine``; this module keeps the historic
``build_experiment`` entry point as a thin shim over a process-wide
``ExperimentEngine`` so every benchmark shares one memo table and one set
of k-means fits.
"""

from __future__ import annotations

import numpy as np

from repro.experiments import (NUM_STRATA, PHASE1_SEED, AppExperiment,
                               ExperimentEngine, scheme_selection)
from repro.simcpu import APP_NAMES

__all__ = ["NUM_STRATA", "PHASE1_SEED", "AppExperiment", "all_apps",
           "build_experiment", "get_engine", "scheme_selection",
           "weighted_estimate"]

_ENGINE = ExperimentEngine()


def get_engine() -> ExperimentEngine:
    return _ENGINE


def build_experiment(name: str, kmeans_seed: int = 0) -> AppExperiment:
    return _ENGINE.app(name, kmeans_seed)


def weighted_estimate(selected: list[np.ndarray], cpi: np.ndarray,
                      weights: np.ndarray) -> float:
    """Stratified weighted mean over concatenated per-stratum CPI values."""
    est, wtot = 0.0, 0.0
    off = 0
    for h, sel in enumerate(selected):
        if sel.size == 0:
            continue
        est += weights[h] * cpi[off:off + sel.size].mean()
        wtot += weights[h]
        off += sel.size
    return est / max(wtot, 1e-12)


def all_apps() -> list[str]:
    return list(APP_NAMES)
