"""Shared experiment state for the paper-reproduction benchmarks.

Caches per-application stratifications (expensive k-means runs) across the
benchmark modules so `python -m benchmarks.run` builds each once.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.core.clustering import Standardizer, kmeans, random_project
from repro.core.sampling import (dalenius_gurney_strata, draw_srs,
                                 select_centroid, select_mean, select_random)
from repro.simcpu import (APP_NAMES, CONFIGS, get_bbvs, get_population,
                          make_simulator)

NUM_STRATA = 20
PHASE1_SEED = 42


@dataclasses.dataclass
class AppExperiment:
    name: str
    sim: object
    truth: np.ndarray            # (7,) census mean CPI per config
    census_cpi: dict             # config index -> (N,) cpi
    # BBV stratification (census, SimPoint-style)
    bbv_labels: np.ndarray       # (N,)
    bbv_weights: np.ndarray      # (20,)
    bbv_feats: np.ndarray        # projected (N, 15)
    bbv_centroids: np.ndarray
    # phase-1 sample + RFV stratification
    idx1: np.ndarray
    cpi0_1: np.ndarray           # baseline CPI of phase-1 units
    rfv_z: np.ndarray            # standardized RFVs of phase-1 units
    rfv_labels: np.ndarray
    rfv_weights: np.ndarray
    rfv_centroids: np.ndarray
    # Dalenius-Gurney on baseline CPI (phase-1 sample)
    dg_labels: np.ndarray
    dg_weights: np.ndarray

    def cpi(self, cfg_i: int, indices) -> np.ndarray:
        return self.sim.simulate_cpi(indices, CONFIGS[cfg_i])

    def census(self, cfg_i: int) -> np.ndarray:
        if cfg_i not in self.census_cpi:
            self.census_cpi[cfg_i] = self.sim.census_stats(
                CONFIGS[cfg_i])["cpi"]
        return self.census_cpi[cfg_i]


@functools.lru_cache(maxsize=None)
def build_experiment(name: str, kmeans_seed: int = 0) -> AppExperiment:
    sim = make_simulator(name)
    pop = sim.pop
    N = pop.n_regions
    rng = np.random.default_rng(PHASE1_SEED)

    census0 = sim.census_stats(CONFIGS[0])["cpi"]
    truth = np.array([sim.true_mean_cpi(c) for c in CONFIGS])

    # SimPoint-style BBV stratification over the full population
    bbv = get_bbvs(pop)
    z = np.asarray(random_project(bbv, 15, key=jax.random.PRNGKey(0)))
    km = kmeans(z, NUM_STRATA, seed=kmeans_seed)
    bbv_w = np.bincount(km.labels, minlength=NUM_STRATA) / N

    # phase 1: SRS at the paper's Table II size, RFVs on config 0
    idx1 = draw_srs(rng, N, pop.spec.phase1_n)
    cpi0_1, rfv = sim.simulate_rfv(idx1, CONFIGS[0])
    _, zr = Standardizer.fit_transform(rfv)
    zr = np.asarray(zr)
    km2 = kmeans(zr, NUM_STRATA, seed=kmeans_seed)
    rfv_w = np.bincount(km2.labels, minlength=NUM_STRATA) / idx1.size

    dg = dalenius_gurney_strata(cpi0_1, NUM_STRATA)
    dg_w = np.bincount(dg, minlength=NUM_STRATA) / idx1.size

    return AppExperiment(
        name=name, sim=sim, truth=truth, census_cpi={0: census0},
        bbv_labels=km.labels, bbv_weights=bbv_w, bbv_feats=z,
        bbv_centroids=km.centroids,
        idx1=idx1, cpi0_1=np.asarray(cpi0_1), rfv_z=zr,
        rfv_labels=km2.labels, rfv_weights=rfv_w,
        rfv_centroids=km2.centroids,
        dg_labels=dg, dg_weights=dg_w)


def weighted_estimate(selected: list[np.ndarray], cpi: np.ndarray,
                      weights: np.ndarray) -> float:
    est, wtot = 0.0, 0.0
    off = 0
    for h, sel in enumerate(selected):
        if sel.size == 0:
            continue
        est += weights[h] * cpi[off:off + sel.size].mean()
        wtot += weights[h]
        off += sel.size
    return est / max(wtot, 1e-12)


def scheme_selection(exp: AppExperiment, scheme: str, policy: str,
                     seed: int = 0) -> tuple[list[np.ndarray], np.ndarray]:
    """Population indices per stratum + weights for a scheme/policy."""
    if scheme == "bbv":
        labels, weights = exp.bbv_labels, exp.bbv_weights
        feats, cents = exp.bbv_feats, exp.bbv_centroids
        pool = np.arange(labels.shape[0])
        baseline = exp.census(0)
    else:
        labels = exp.rfv_labels if scheme == "rfv" else exp.dg_labels
        weights = exp.rfv_weights if scheme == "rfv" else exp.dg_weights
        feats = exp.rfv_z if scheme == "rfv" else exp.cpi0_1[:, None]
        pool = exp.idx1
        baseline = exp.cpi0_1
        if scheme == "dg":
            cents = np.array([[baseline[labels == h].mean()]
                              if (labels == h).any() else [np.nan]
                              for h in range(NUM_STRATA)])
        else:
            cents = exp.rfv_centroids
    if policy == "random":
        local = select_random(labels, NUM_STRATA,
                              np.random.default_rng(seed))
    elif policy == "centroid":
        local = select_centroid(labels, feats, cents)
    elif policy == "mean":
        local = select_mean(labels, baseline, num_strata=NUM_STRATA)
    else:
        raise ValueError(policy)
    return [pool[l] for l in local], weights


def all_apps() -> list[str]:
    return list(APP_NAMES)
