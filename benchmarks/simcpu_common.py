"""Shared experiment state for the paper-reproduction benchmarks.

The per-app state (stratifications, phase-1 sample, memoized simulator)
lives in ``repro.experiments.engine``; this module keeps the historic
``build_experiment`` entry point as a thin shim over a process-wide
``ExperimentEngine`` so every benchmark shares one memo bank and one set
of k-means fits. When more than one device is available (e.g. via
``benchmarks/run.py --devices N``) the engine gets an ``("app",)`` mesh
and every batched dispatch is sharded over the app axis.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.experiments import (NUM_STRATA, PHASE1_SEED, AppExperiment,
                               ExperimentEngine, plan_selection,
                               scheme_selection)
from repro.simcpu import APP_NAMES

__all__ = ["NUM_STRATA", "PHASE1_SEED", "AppExperiment", "all_apps",
           "build_experiment", "get_engine", "plan_selection",
           "scheme_selection", "weighted_estimate"]

_ENGINE: Optional[ExperimentEngine] = None


def get_engine() -> ExperimentEngine:
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = ExperimentEngine.auto()
    return _ENGINE


def build_experiment(name: str, kmeans_seed: int = 0) -> AppExperiment:
    return get_engine().app(name, kmeans_seed)


def weighted_estimate(selected: list[np.ndarray], cpi: np.ndarray,
                      weights: np.ndarray) -> float:
    """Stratified weighted mean over concatenated per-stratum CPI values."""
    est, wtot = 0.0, 0.0
    off = 0
    for h, sel in enumerate(selected):
        if sel.size == 0:
            continue
        est += weights[h] * cpi[off:off + sel.size].mean()
        wtot += weights[h]
        off += sel.size
    return est / max(wtot, 1e-12)


def all_apps() -> list[str]:
    return list(APP_NAMES)
