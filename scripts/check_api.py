#!/usr/bin/env python
"""Public-API surface checker for the sampling-plan redesign.

Two AST-level gates over the public ``repro.core.sampling`` and
``repro.experiments`` packages (no third-party deps, mirrors
``check_docstrings.py``):

1. **``__all__`` declarations** — every module in scope must declare its
   public surface explicitly, so the docs tree and the registry shims
   can rely on a stable import contract.
2. **No string-literal scheme/policy dispatch** — the sampling-plan
   registry (``repro.core.sampling.plan``) is the ONLY place names like
   ``"bbv"``/``"rfv"``/``"dg"``/``"centroid"``/``"mean"``/``"random"``
   may be mapped to behavior. A comparison or membership test against
   one of those literals (``if scheme == "bbv": ...``,
   ``policy in ("mean", "random")``) re-creates the pre-plan dispatch
   this redesign removed, so any such node outside the declared shim
   allowlist fails the build. Registrations (dict/tuple literals,
   keyword defaults, docstrings) are fine — only *comparisons*
   dispatch.

Exit code 1 with a ``path:line: reason`` listing on any violation.
"""

from __future__ import annotations

import ast
import pathlib
import sys

SCOPE = ("src/repro/core/sampling", "src/repro/experiments",
         "src/repro/serving")

# the scheme/policy names the pre-plan engine dispatched on (ISSUE 5);
# comparisons against them outside plan.py are re-grown string dispatch
DISPATCH_LITERALS = frozenset(
    {"bbv", "rfv", "dg", "centroid", "mean", "random"})

# modules allowed to compare dispatch literals: none — even the legacy
# shims resolve names through the registry instead of comparing them
SHIM_ALLOWLIST: frozenset[str] = frozenset()


def _literal_strs(node: ast.AST):
    """String constants inside a comparator (descending into tuples &c)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for elt in node.elts:
            yield from _literal_strs(elt)


def check_file(path: pathlib.Path, rel: str) -> list[str]:
    """All API-contract violations in one module."""
    tree = ast.parse(path.read_text(), filename=str(path))
    errors: list[str] = []

    has_all = any(
        isinstance(node, ast.Assign)
        and any(isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets)
        for node in tree.body)
    if not has_all:
        errors.append(f"{rel}:1: module does not declare __all__")

    if pathlib.PurePosixPath(rel).name in SHIM_ALLOWLIST:
        return errors
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        hit = sorted(
            s for operand in (node.left, *node.comparators)
            for s in _literal_strs(operand) if s in DISPATCH_LITERALS)
        if hit:
            errors.append(
                f"{rel}:{node.lineno}: scheme/policy string-literal "
                f"dispatch on {hit} — route through the sampling-plan "
                "registry (repro.core.sampling.plan) instead")
    return errors


def main(argv: list[str]) -> int:
    """Check every ``.py`` under the scoped packages."""
    root = pathlib.Path(__file__).resolve().parent.parent
    scope = argv or [str(root / p) for p in SCOPE]
    errors: list[str] = []
    n_files = 0
    for top in scope:
        top_p = pathlib.Path(top)
        if not top_p.is_dir():
            errors.append(f"{top}: scope path does not exist — the check "
                          "would pass vacuously")
            continue
        for path in sorted(top_p.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            n_files += 1
            rel = str(path.relative_to(root)) if path.is_relative_to(root) \
                else str(path)
            errors.extend(check_file(path, rel))
    if n_files == 0:
        errors.append("no Python files found in scope")
    for e in errors:
        print(e)
    print(f"check_api: {n_files} files, {len(errors)} violation(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
