#!/usr/bin/env python
"""Markdown link checker for README.md + docs/ (lychee-lite, offline).

Verifies that every relative markdown link resolves to an existing file,
and that ``#anchor`` fragments pointing into markdown files match a
heading in the target (GitHub slug rules: lowercase, punctuation
stripped, spaces → dashes). External ``http(s)``/``mailto`` links are
skipped — CI has no network. Exit code 1 with a listing on any broken
link.
"""

from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(md: pathlib.Path) -> set[str]:
    return {_slug(h) for h in HEADING_RE.findall(md.read_text())}


def check_file(md: pathlib.Path, root: pathlib.Path) -> list[str]:
    """All broken relative links/anchors in one markdown file."""
    errors: list[str] = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        if path_part and not dest.exists():
            errors.append(f"{md.relative_to(root)}: broken link -> {target}")
            continue
        if fragment and dest.suffix == ".md":
            if fragment not in _anchors(dest):
                errors.append(f"{md.relative_to(root)}: missing anchor "
                              f"#{fragment} in {path_part or md.name}")
    return errors


def main() -> int:
    """Check README.md and every markdown file under docs/."""
    root = pathlib.Path(__file__).resolve().parent.parent
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    errors: list[str] = []
    for md in files:
        if md.exists():
            errors.extend(check_file(md, root))
    for e in errors:
        print(e)
    print(f"check_docs_links: {len(files)} files, {len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
