#!/usr/bin/env python
"""The single static gate: run jaxlint (``repro.analysis``) on the repo.

Replaces the three pre-jaxlint gate scripts (``check_api.py``,
``check_docstrings.py``, ``check_docs_links.py``) — their checks now
run as rules JL100–JL102 alongside the jax-discipline pack JL001–JL006.
Dependency-free (stdlib ``ast`` only, never imports jax), so the CI
static-analysis job needs no environment beyond Python.

Usage mirrors the module CLI: ``python scripts/lint.py [--json]
[--select JL003] [paths...]``; see ``--list-rules`` for the rule table
and ``docs/contributing.md`` for suppression/baseline policy.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.analysis import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
