#!/usr/bin/env python
"""Docstring presence checker (pydocstyle-lite) for scoped packages.

The container/CI images don't ship pydocstyle or ruff, so this is a small
AST-based stand-in enforcing the subset we care about on the public
experiment/kernel surface:

* every module has a module docstring (D100/D104);
* every public class, function and method — name not starting with
  ``_``, not a dunder — has a docstring (D101/D102/D103).

Scope defaults to ``src/repro/experiments`` and ``src/repro/kernels``
(the packages whose surface the docs tree documents). Exit code 1 with a
``path:line: symbol`` listing on any violation.
"""

from __future__ import annotations

import ast
import pathlib
import sys

DEFAULT_SCOPE = ("src/repro/experiments", "src/repro/kernels",
                 "src/repro/serving")


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _check_body(body: list[ast.stmt], qual: str, path: pathlib.Path,
                errors: list[str]) -> None:
    """Recurse over class/module bodies collecting undocumented symbols."""
    for node in body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _is_public(node.name) and ast.get_docstring(node) is None:
                errors.append(f"{path}:{node.lineno}: missing docstring on "
                              f"function {qual}{node.name}")
        elif isinstance(node, ast.ClassDef):
            if _is_public(node.name):
                if ast.get_docstring(node) is None:
                    errors.append(f"{path}:{node.lineno}: missing docstring "
                                  f"on class {qual}{node.name}")
                _check_body(node.body, f"{qual}{node.name}.", path, errors)


def check_file(path: pathlib.Path) -> list[str]:
    """All docstring violations in one Python file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    errors: list[str] = []
    if ast.get_docstring(tree) is None:
        errors.append(f"{path}:1: missing module docstring")
    _check_body(tree.body, "", path, errors)
    return errors


def main(argv: list[str]) -> int:
    """Check every ``.py`` under the given (or default) scope paths."""
    root = pathlib.Path(__file__).resolve().parent.parent
    scope = argv or [str(root / p) for p in DEFAULT_SCOPE]
    errors: list[str] = []
    n_files = 0
    for top in scope:
        if not pathlib.Path(top).is_dir():
            errors.append(f"{top}: scope path does not exist — the check "
                          "would pass vacuously")
            continue
        for path in sorted(pathlib.Path(top).rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            n_files += 1
            errors.extend(check_file(path))
    if n_files == 0:
        errors.append("no Python files found in scope")
    for e in errors:
        print(e)
    print(f"check_docstrings: {n_files} files, {len(errors)} violation(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
