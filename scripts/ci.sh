#!/usr/bin/env bash
# Minimal CI: tier-1 tests + a --quick benchmark smoke through the
# experiment engine. benchmarks/run.py exits non-zero on any FAILing
# claim-validation row or bench error, so this script's exit code is the
# CI verdict.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# dev extras (hypothesis property tests) are best-effort: the suite
# degrades gracefully without them
pip install -q -r requirements-dev.txt 2>/dev/null || true

python -m pytest -x -q
python -m benchmarks.run --quick --only fig5_config_sweep,kernels
