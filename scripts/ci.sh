#!/usr/bin/env bash
# Minimal CI: tier-1 tests + a --quick benchmark smoke through the
# experiment engine. benchmarks/run.py exits non-zero on any FAILing
# claim-validation row or bench error, so this script's exit code is the
# CI verdict.
#
# CI_FORCE_DEVICES=N forces N XLA host devices BEFORE jax initializes so
# the app-sharded engine paths (shard_map over the ("app",) mesh, memo
# merges, sharded-vs-single equivalence tests) are exercised on every push.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ -n "${CI_FORCE_DEVICES:-}" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=${CI_FORCE_DEVICES} ${XLA_FLAGS:-}"
fi

# dev extras (hypothesis property tests) are best-effort: the suite
# degrades gracefully without them
pip install -q -r requirements-dev.txt 2>/dev/null || true

# static-analysis gate: jaxlint (repro.analysis) — trace hygiene,
# PRNG discipline, donation safety, precision-policy conformance
# (JL001-JL006) plus the folded-in api/docstring/doc-link gates
# (JL100-JL102). Dependency-free, offline, seconds; baseline policy in
# docs/contributing.md#static-analysis
python scripts/lint.py

# estimator parity suite first (fast, no engine builds): batched
# StratumTables estimators must match the scalar reference before the
# full tier-1 run exercises everything built on them
python -m pytest -x -q tests/test_estimator_tables.py

python -m pytest -x -q
# bench smoke; the `estimators` leg gates the batched-vs-scalar claim row
# and `fused_sweep` the megaprogram crossover/parity/ledger gate (it
# reuses the engine fig5 built, so the ladder costs seconds, not a build)
python -m benchmarks.run --quick --only fig5_config_sweep,kernels,kmeans_batched,estimators,fused_sweep,lint

# sharded fused-megaprogram smoke at reduced scale: the donated-buffer
# program shard_maps over an ("app",) mesh of 8 forced host devices and
# must match single-device results (parity + ledger gates inside the
# bench claim row). When CI_FORCE_DEVICES is already exported the flag is
# in XLA_FLAGS above; otherwise force 8 devices for this leg only.
if [[ -n "${CI_FORCE_DEVICES:-}" ]]; then
  python -m benchmarks.run --quick --only fused_sweep
else
  python -m benchmarks.run --quick --devices 8 --only fused_sweep
fi

# serving smoke: request-coalescing batched estimation through
# SweepService under the forced 8-device ("app",) mesh — gates the
# coalesced==serial bitwise claim row (estimates + ledger totals), the
# eviction-bounded memo run, and smoke-checks the stacked-dispatch
# throughput machinery (the >= 2x K=8 gate applies to full runs only)
if [[ -n "${CI_FORCE_DEVICES:-}" ]]; then
  python -m benchmarks.run --quick --only serving
else
  python -m benchmarks.run --quick --devices 8 --only serving
fi

# scaled-trials smoke: a chunked 10^4-trial streamed run through the
# trial engine (keep_trials off -> bounded memory), gating the
# chunked==unchunked bitwise and coverage-calibration claim rows; under
# CI_FORCE_DEVICES=8 the ("app","trial") mesh reduction runs for real.
# checkpoint_overhead gates the fault-tolerance tax (< 5% of the run)
# and appends this run's claim outcomes to BENCH_history.jsonl
python -m benchmarks.run --quick --trials 10000 \
  --only trials_streaming,checkpoint_overhead

# fault-tolerance leg: the full resume-equivalence matrix (slow-marked
# scheme sweeps; the pytest.ini addopts excludes them from the tier-1
# run above, the explicit -m here overrides it). Under CI_FORCE_DEVICES=8
# this includes the sharded + elastic device-drop scenarios (multidevice
# marker); tight deadline — the whole leg is minutes, not hours
timeout 1200 python -m pytest -q -m "slow or multidevice" \
  tests/test_fault_tolerance.py
