"""App-axis request coalescing: K same-shape sweeps, ONE fused dispatch.

``run_coalesced_sweeps`` takes a tick's worth of sweep requests and
dispatches each compiled-program-shape group (``coalesce_key``) as ONE
stacked fused megaprogram launch: per-request arrays concatenate along
the app axis (the fused program is data-parallel over that axis — the
same property the sharded ``("app",)`` mesh path already relies on), the
group checks out one memo donation block covering every member's rows,
and the single program computes every member's selection → miss-only
fill → estimates. 32 queued 2×2 sweeps cost one launch, not 32.

Why coalesced results are bitwise-equal to serial ``run_sweep`` calls:

* **Estimates** — each request's lanes are rows of the same batched ops
  a serial dispatch would run (picks are program-shape independent by
  the fused module's ``optimization_barrier`` contract). Where two
  coalesced requests share a cold memo cell, each lane computes the CPI
  itself — the same jitted perf model on the same inputs — which is
  bit-identical to the serial second request reading the first's stored
  value.
* **Accounting** — the in-trace miss counts see only the shared
  PRE-dispatch block, so overlapping requests would double-charge.
  They are therefore discarded; ``MemoBank.absorb_picks`` re-derives
  each request's dedup-exact miss flags against the host tables in
  submission order, making charges, hit/miss counters and ledger totals
  identical to the serial schedule.

Groups dispatch sequentially with a fresh block checkout each, so a
later group reads every earlier group's fills exactly as serial
dispatch order would. Non-coalescible requests (SRS, staged, riding
trials) run serially inside the same call, in submission order.
"""

from __future__ import annotations

import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.precision import PrecisionPolicy, resolve_precision
from ..core.sampling import plan as sampling_plan
from ..experiments.fused import _dev_config_matrix, fused_sweep_program
from ..experiments.sweep import (ResultsTable, _warn_partial_coverage,
                                 assemble_rows, run_sweep)
from .coalesce import coalesce_key, coalescible, prepare_sweep

__all__ = ["run_coalesced_sweeps"]

# Group-constant device uploads: the concatenated bank/stack inputs of a
# coalesce group depend only on each member's (bank, stack) — both are
# engine-cached host objects — so a warm service tick re-dispatching the
# same group shape skips the app-axis concat AND the host->device copies
# (the dominant warm-dispatch cost; the fused driver's per-bank
# ``_DEV_CACHE`` plays the same role for serial sweeps). Keyed by member
# object identities; held references keep the ids valid.
_GROUP_CACHE: dict = {}
_GROUP_CACHE_CAP = 16

# Device memo blocks chained through donation across warm coalesced
# dispatches — the batcher's analogue of ``fused._BLOCK_CACHE``. Only
# stamped when the dispatch produced ZERO new misses (version unchanged
# through every member's absorb): with no fills, every stacked lane's
# output block is bitwise the checked-out block, so duplicated rows
# across members cannot diverge. One entry per MemoBank.
_MIRROR: dict = {}


def _cat(arrs: list):
    """App-axis concat for per-request arrays; all-``None`` passes
    through (the group key guarantees presence agrees across members)."""
    return None if arrs[0] is None else np.concatenate(
        [np.asarray(a) for a in arrs], axis=0)


def _group_dev_args(preps, dt, x64: bool):
    """Concatenated + uploaded group-constant traced inputs.

    Returns the eight bank/stack-derived device arrays (labels, valid,
    weights, baseline, pool, feats_sel, centroids, feats_pop), cached
    per group composition. Per-request ``uniforms`` and ``truth`` are
    NOT cached — they vary with seed and config selection.
    """
    key = (tuple(id(p.bank) for p in preps),
           tuple(id(p.stack.feats) for p in preps),
           np.dtype(dt).name, x64)
    hit = _GROUP_CACHE.get(key)
    if (hit is not None
            and all(g is p.bank for g, p in zip(hit[0], preps))
            and all(g is p.stack.feats for g, p in zip(hit[1], preps))):
        return hit[2]
    arrs = (jnp.asarray(_cat([p.bank.labels for p in preps])),
            jnp.asarray(_cat([p.bank.valid for p in preps])),
            jnp.asarray(_cat([p.bank.weights for p in preps]), dt),
            jnp.asarray(_cat([p.bank.baseline for p in preps])),
            _opt_dev(_cat([p.bank.pool for p in preps])),
            _opt_dev(_cat([p.bank.feats for p in preps])),
            _opt_dev(_cat([p.bank.centroids for p in preps])),
            jnp.asarray(_cat([p.stack.feats for p in preps])))
    if len(_GROUP_CACHE) >= _GROUP_CACHE_CAP:
        _GROUP_CACHE.pop(next(iter(_GROUP_CACHE)))
    _GROUP_CACHE[key] = (tuple(p.bank for p in preps),
                         tuple(p.stack.feats for p in preps), arrs)
    return arrs


def _opt_dev(a):
    return None if a is None else jnp.asarray(a)


def _checkout_group_blocks(memo, rows_cat, cfgs):
    """(mask, cpi, cols, keys) for the group dispatch: the chained
    device mirror when the bank is unchanged since the last warm
    coalesced dispatch of this exact block, else a fresh host checkout
    (numpy; uploaded by the caller). The mirror entry is REMOVED here —
    its blocks are about to be donated."""
    cols = memo.cols_for(cfgs)
    rows_key = tuple(rows_cat.tolist())
    cols_key = tuple(cols.tolist())
    hit = _MIRROR.get(id(memo))
    if (hit is not None and hit[0] is memo and hit[1] == rows_key
            and hit[2] == cols_key and hit[3] == memo.version):
        del _MIRROR[id(memo)]
        return hit[4], hit[5], cols, rows_key, cols_key
    mask_blk, cpi_blk, cols = memo.donation_block(rows_cat, cfgs)
    return mask_blk, cpi_blk, cols, rows_key, cols_key


def _dispatch_group(engine, members, mesh) -> list:
    """ONE stacked fused dispatch for a same-key group; returns
    ``(request_index, ResultsTable)`` pairs in member order."""
    preps = [p for _, p in members]
    plan = preps[0].spec.plan
    cfgs = preps[0].cfgs
    pp = resolve_precision(engine.precision, PrecisionPolicy.host_parity())
    dt = pp.trace_dtype
    a_sizes = [p.num_apps for p in preps]
    rows_cat = np.concatenate([p.stack.rows for p in preps])
    # fresh checkout per group unless the device mirror chains (duplicate
    # rows across members are fine: every lane reads the same
    # pre-dispatch copy, by design)
    mask_blk, cpi_blk, cols, rows_key, cols_key = _checkout_group_blocks(
        engine.memo, rows_cat, cfgs)
    v_checkout = engine.memo.version

    cm = _dev_config_matrix(cfgs)
    prog = fused_sweep_program(plan, pp, mesh)
    with pp.x64_context():
        bank_args = _group_dev_args(preps, dt, pp.needs_x64)
        uniforms = _cat([p.uniforms for p in preps])
        truth = _cat([p.truth for p in preps])
        mask_dev = jnp.asarray(mask_blk)
        cpi_dev = jnp.asarray(cpi_blk)
        args = bank_args[:7] + (
            None if uniforms is None else jnp.asarray(uniforms, dt),
            bank_args[7], cm, jnp.asarray(truth, dt), mask_dev, cpi_dev)
        with warnings.catch_warnings():
            # CPU XLA may decline donation; correctness is unaffected
            warnings.filterwarnings(
                "ignore", message=".*donated buffers were not usable.*")
            (est, err, valid_sel, picks, _n_miss, _miss_sel, cpi_sel,
             new_mask, new_cpi) = prog(*args)
        # in-trace accounting (_n_miss/_miss_sel) is per-request vs the
        # SHARED pre-dispatch block — discarded; absorb_picks below
        # recomputes it sequentially for serial-exact totals
        est, err = np.asarray(est), np.asarray(err)
        valid = np.asarray(valid_sel)
        picks, cpi_sel = np.asarray(picks), np.asarray(cpi_sel)
    donated = bool(mask_dev.is_deleted() and cpi_dev.is_deleted())

    out, off = [], 0
    for (i, prep), a_n in zip(members, a_sizes):
        sl = slice(off, off + a_n)
        off += a_n
        engine.memo.absorb_picks(prep.stack.rows, cols, picks[sl],
                                 valid[sl], cpi_sel[sl])
        _warn_partial_coverage(prep.spec, valid[sl],
                               np.asarray(prep.bank.weights))
        out.append((i, assemble_rows(
            prep.spec, prep.cfg_is, est[sl], err[sl],
            valid[sl].sum(axis=1), prep.truth)))
    if mesh is None and engine.memo.version == v_checkout:
        # zero misses across every member: every lane's output block is
        # bitwise the host tables — chain it into the next dispatch
        # (single-device only, matching ``fused._BLOCK_CACHE``: the
        # sharded program's output blocks may carry app padding)
        _MIRROR[id(engine.memo)] = (engine.memo, rows_key, cols_key,
                                    engine.memo.version, new_mask, new_cpi)
    sampling_plan._record_sweep_dispatch(
        batch_shape=(int(sum(a_sizes)), len(cfgs)),
        num_strata=int(preps[0].bank.weights.shape[1]), x64=pp.needs_x64,
        backend=jax.default_backend(), fused=True, donated=donated,
        coalesced=len(members))
    return out


def run_coalesced_sweeps(engine, specs: Sequence, mesh=None
                         ) -> list[ResultsTable]:
    """Run many sweep requests, one fused dispatch per shape group.

    Returns one ``ResultsTable`` per request, in request order. Requests
    sharing a ``coalesce_key`` (same plan, configs, and array shapes)
    stack into a single fused megaprogram launch; singleton groups and
    non-coalescible requests fall back to serial ``run_sweep``. Results
    AND cost accounting are bitwise-identical to running the same
    requests serially in submission order (see the module docstring for
    why); the dispatch marker (``sampling_plan.last_sweep_dispatch``)
    records ``coalesced=K`` for stacked launches.
    """
    mesh = engine.mesh if mesh is None else mesh
    results: list = [None] * len(specs)
    groups: dict = {}
    for i, spec in enumerate(specs):
        if not coalescible(spec):
            results[i] = run_sweep(engine, spec, mesh=mesh)
            continue
        prep = prepare_sweep(engine, spec)
        groups.setdefault(coalesce_key(prep), []).append((i, prep))
    for members in groups.values():
        if len(members) == 1:
            i, prep = members[0]
            results[i] = run_sweep(engine, prep.spec, mesh=mesh)
        else:
            for i, table in _dispatch_group(engine, members, mesh):
                results[i] = table
    return results
