"""Request preparation + compiled-program-shape grouping for the sweep
service.

A sweep request (``SweepSpec``) is coalescible when it dispatches
through the fused megaprogram (stratified plan, ``fused=True``, no
riding Monte-Carlo study). ``prepare_sweep`` resolves exactly the host
inputs ``run_fused_sweep`` would build for the request — engine build,
stacked population view, the plan's ``StratumBank``, the staged-rng
uniforms — and ``coalesce_key`` reduces them to the hashable
compiled-program-shape key the batcher groups by: plan identity (the
traced code), the config tuple (the replicated config matrix), and
every trailing array shape (jit's specialization). Requests sharing a
key stack along the app axis with NO re-padding, so each lane's arrays
are byte-identical to its serial dispatch — the root of the
coalesced == serial bitwise guarantee.

Stratifier resolution is cached per (engine, stratifier, app tuple):
``Stratifier.resolve`` builds fresh arrays each call, and a long-lived
service would otherwise re-stack (and re-upload — the fused driver's
device cache is keyed on host-object identity) the same bank for every
repeat request.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Optional

import numpy as np

from ..core.sampling import plan as sampling_plan
from ..experiments.engine import ExperimentEngine, SweepStack
from ..experiments.sweep import SweepSpec

__all__ = ["PreparedSweep", "coalesce_key", "coalescible", "prepare_sweep"]

# engine -> {(stratifier, apps): StratumBank}; weak on the engine so a
# dropped engine releases its banks (and their device uploads)
_RESOLVE_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def coalescible(spec) -> bool:
    """True when the batcher may stack this request into a fused group.

    Coalescing rides the fused megaprogram, so only stratified
    ``fused=True`` sweeps without a riding Monte-Carlo study qualify;
    everything else (phase-1 SRS, staged-reference, ``trials=``) runs
    serially through ``run_sweep`` inside the same tick.
    """
    return (isinstance(spec, SweepSpec) and spec.plan is not None
            and spec.fused and spec.trials is None)


@dataclasses.dataclass
class PreparedSweep:
    """One request's resolved dispatch inputs (``prepare_sweep``).

    Everything ``run_fused_sweep`` derives per sweep, held as host
    arrays so the batcher can either stack them into a group dispatch
    or fall back to a serial ``run_sweep`` — the two paths consume the
    same objects.
    """

    spec: SweepSpec
    stack: SweepStack
    bank: sampling_plan.StratumBank
    cfg_is: tuple
    cfgs: tuple
    truth: np.ndarray                       # (A, C) census truth
    uniforms: Optional[np.ndarray]          # (A, L) staged-rng draws

    @property
    def num_apps(self) -> int:
        """App-axis width this request contributes to a stacked group."""
        return int(self.bank.weights.shape[0])


def resolve_bank(engine: ExperimentEngine, stratifier,
                 apps: tuple) -> sampling_plan.StratumBank:
    """``stratifier.resolve`` with a per-(engine, stratifier, apps)
    cache, so repeat requests reuse one ``StratumBank`` (same host
    object identity -> the fused driver's device-upload cache hits)."""
    per_engine = _RESOLVE_CACHE.setdefault(engine, {})
    key = (stratifier, tuple(apps))
    bank = per_engine.get(key)
    if bank is None:
        bank = stratifier.resolve(engine.build(apps))
        per_engine[key] = bank
    return bank


def prepare_sweep(engine: ExperimentEngine, spec: SweepSpec
                  ) -> PreparedSweep:
    """Resolve one coalescible request's dispatch inputs.

    Mirrors ``run_sweep``/``run_fused_sweep`` exactly: engine build +
    stacked view, config subset and census truth, the plan's
    ``StratumBank``, and — for ``uses_uniforms`` policies — the staged
    rng sequence's first draw from ``spec.selection_seed`` (so coalesced
    picks equal staged picks bit-for-bit).
    """
    exps = engine.build(spec.apps)
    stack = engine.stack(spec.apps)
    cfg_is = (tuple(range(len(engine.configs)))
              if spec.config_indices is None else spec.config_indices)
    cfgs = tuple(engine.configs[i] for i in cfg_is)
    truth = np.stack([e.truth for e in exps])[:, list(cfg_is)]
    bank = resolve_bank(engine, spec.plan.stratifier, spec.apps)
    uniforms = None
    if spec.plan.policy.uses_uniforms:
        a_n, n_strata = bank.weights.shape
        uniforms = np.random.default_rng(spec.selection_seed).random(
            (a_n, n_strata))
    return PreparedSweep(spec=spec, stack=stack, bank=bank, cfg_is=cfg_is,
                         cfgs=cfgs, truth=truth, uniforms=uniforms)


def _opt_shape(arr) -> Optional[tuple]:
    """Trailing shape of an optional array (None stays None — the traced
    program branches statically on absent inputs)."""
    return None if arr is None else tuple(np.shape(arr)[1:])


def coalesce_key(prep: PreparedSweep) -> tuple:
    """The hashable compiled-program-shape key requests group by.

    Two requests share a key iff stacking their arrays along the app
    axis feeds the SAME jitted specialization of the plan's fused
    megaprogram: same ``SamplingPlan`` (traced code), same config tuple
    (shared replicated config matrix), same trailing shapes for every
    bank/stack array, and agreeing presence of the optional inputs
    (pool/features/centroids/uniforms). Within a group, concatenation
    adds rows verbatim — no re-padding — which keeps every lane's
    computation bitwise-equal to its serial dispatch.
    """
    bank = prep.bank
    return (prep.spec.plan, prep.cfgs,
            _opt_shape(bank.labels), _opt_shape(bank.weights),
            _opt_shape(bank.baseline), _opt_shape(bank.pool),
            _opt_shape(bank.feats), _opt_shape(bank.centroids),
            _opt_shape(prep.stack.feats),
            prep.uniforms is None)
