"""``SweepService``: a request-coalescing estimation front-end.

The serving loop the ROADMAP's "millions of users" leg asks for: many
concurrent ``SweepSpec``/``TrialSpec`` requests against ONE persistent
engine + ``MemoBank``. Requests enqueue via ``submit``; each ``tick``
drains the queue and

1. groups coalescible sweep requests by compiled-program shape and
   dispatches each group as ONE stacked fused launch
   (``run_coalesced_sweeps``); non-coalescible sweeps run serially in
   submission order;
2. dedups identical Monte-Carlo requests — one ``run_trials`` execution
   per distinct (spec, apps), with the charged phase-1 fill REPLAYED per
   duplicate (a pure cache hit) so hit/miss counters and ledger totals
   equal the serial schedule;
3. enforces the memo residency cap: ``memo_cap`` bounds the resident
   config columns via ``MemoBank.evict_to_cap`` (LRU or charge-weighted,
   drop or host-spill) after the tick's dispatches.

Cache-hit accounting contract: repeat configs across requests are hits
against the shared bank (miss-only ledger, exact); an evicted column is
re-charged exactly once on re-request; a spilled column restores free.
The service is synchronous and single-threaded — "concurrency" is queue
depth per tick, which is what the coalescer converts into one launch.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import numpy as np

from ..experiments.engine import ExperimentEngine
from ..experiments.montecarlo import (TrialResult, TrialSpec,
                                      charged_pool_fill, run_trials)
from ..experiments.sweep import ResultsTable, SweepSpec
from .batcher import run_coalesced_sweeps

__all__ = ["ServiceStats", "SweepRequest", "SweepService"]


@dataclasses.dataclass
class SweepRequest:
    """One queued request and its lifecycle timestamps/result."""

    req_id: int
    spec: Union[SweepSpec, TrialSpec]
    apps: Optional[tuple]                 # TrialSpec carries no app axis
    submitted: float
    completed: Optional[float] = None
    result: Union[ResultsTable, TrialResult, None] = None

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-completion wall seconds (None while pending)."""
        return (None if self.completed is None
                else self.completed - self.submitted)


@dataclasses.dataclass
class ServiceStats:
    """Aggregate service counters (``SweepService.stats``)."""

    completed: int
    ticks: int
    dispatches: int            # device launches: groups + serial runs
    coalesced_requests: int    # requests served by a stacked launch
    latency_p50_s: float
    latency_p95_s: float
    throughput_rps: float      # completed requests / busy seconds
    cache_hit_rate: float      # bank hits / requested units, lifetime
    peak_resident_cols: int    # max resident memo columns at tick ends
    evicted_cols: int


class SweepService:
    """Request-coalescing sweep/trial service over one shared engine.

    ``memo_cap`` bounds resident memo columns (``None`` = unbounded);
    ``evict_policy`` is ``"lru"`` or ``"charge"``; ``spill=True`` parks
    evicted columns in the host spill store (free restore) instead of
    dropping them (re-charge on re-request).
    """

    def __init__(self, engine: Optional[ExperimentEngine] = None, *,
                 mesh=None, memo_cap: Optional[int] = None,
                 evict_policy: str = "lru", spill: bool = True):
        self.engine = engine if engine is not None \
            else ExperimentEngine.auto()
        self.mesh = self.engine.mesh if mesh is None else mesh
        self.memo_cap = memo_cap
        self.evict_policy = evict_policy
        self.spill = spill
        self._pending: list[SweepRequest] = []
        self._done: dict[int, SweepRequest] = {}
        self._next_id = 0
        self._ticks = 0
        self._busy_s = 0.0
        self._dispatches = 0
        self._coalesced = 0
        self._peak_resident = len(self.engine.memo.resident_columns())
        self._evicted = 0

    # ------------------------------------------------------------- queue
    def submit(self, spec: Union[SweepSpec, TrialSpec],
               apps: Optional[Sequence[str]] = None) -> int:
        """Enqueue a request; returns its id (``result(id)`` after a
        tick). ``apps`` is required for ``TrialSpec`` requests (the spec
        carries no app axis) and ignored for sweeps."""
        if isinstance(spec, TrialSpec) and apps is None:
            raise ValueError("TrialSpec requests need apps=(...) — the "
                             "spec carries no app axis")
        req = SweepRequest(req_id=self._next_id, spec=spec,
                           apps=None if apps is None else tuple(apps),
                           submitted=time.perf_counter())
        self._next_id += 1
        self._pending.append(req)
        return req.req_id

    def result(self, req_id: int):
        """A completed request's result (raises ``KeyError`` while it is
        still pending — call ``tick``/``drain`` first)."""
        return self._done[req_id].result

    @property
    def pending(self) -> int:
        """Requests waiting for the next tick."""
        return len(self._pending)

    # -------------------------------------------------------------- tick
    def tick(self) -> int:
        """Serve everything queued: coalesce + dispatch sweeps, dedup +
        run trials, then enforce the memo cap. Returns the number of
        requests completed this tick."""
        batch, self._pending = self._pending, []
        if not batch:
            return 0
        t0 = time.perf_counter()

        sweeps = [r for r in batch if isinstance(r.spec, SweepSpec)]
        trials = [r for r in batch if not isinstance(r.spec, SweepSpec)]

        if sweeps:
            tables = run_coalesced_sweeps(
                self.engine, [r.spec for r in sweeps], mesh=self.mesh)
            for req, table in zip(sweeps, tables):
                req.result = table
            self._count_sweep_dispatches(sweeps)

        # identical trial studies dedup to ONE execution; duplicates
        # replay the charged fill (pure hit) for serial-equal accounting
        by_study: dict = {}
        for req in trials:
            by_study.setdefault((req.spec, req.apps), []).append(req)
        for (spec, apps), reqs in by_study.items():
            result = run_trials(self.engine, spec, apps=apps,
                                mesh=self.mesh)
            self._dispatches += len(spec.schemes)
            for dup in reqs[1:]:
                charged_pool_fill(self.engine, spec, apps, mesh=self.mesh)
            for req in reqs:
                req.result = result

        now = time.perf_counter()
        for req in batch:
            req.completed = now
            self._done[req.req_id] = req
        self._busy_s += now - t0
        self._ticks += 1
        self._enforce_cap()
        return len(batch)

    def drain(self) -> int:
        """Tick until the queue is empty; returns requests completed."""
        total = 0
        while self._pending:
            total += self.tick()
        return total

    def _count_sweep_dispatches(self, sweeps) -> None:
        """Update launch/coalescing counters from the tick's sweep batch
        (groups of size K count one dispatch serving K requests)."""
        from .coalesce import coalesce_key, coalescible, prepare_sweep

        groups: dict = {}
        serial = 0
        for req in sweeps:
            if coalescible(req.spec):
                key = coalesce_key(prepare_sweep(self.engine, req.spec))
                groups.setdefault(key, 0)
                groups[key] += 1
            else:
                serial += 1
        for size in groups.values():
            self._dispatches += 1
            if size > 1:
                self._coalesced += size
        self._dispatches += serial

    def _enforce_cap(self) -> None:
        """Apply ``memo_cap`` via the bank's eviction policy and sample
        the post-enforcement residency for the peak statistic."""
        memo = self.engine.memo
        if self.memo_cap is not None:
            self._evicted += len(memo.evict_to_cap(
                self.memo_cap, policy=self.evict_policy, spill=self.spill))
        self._peak_resident = max(self._peak_resident,
                                  len(memo.resident_columns()))

    # ------------------------------------------------------------- stats
    def stats(self) -> ServiceStats:
        """Aggregate latency/throughput/cache counters so far."""
        lats = [r.latency_s for r in self._done.values()]
        memo = self.engine.memo
        hits = float(sum(memo.hit_count))
        units = hits + float(sum(memo.miss_count))
        return ServiceStats(
            completed=len(self._done),
            ticks=self._ticks,
            dispatches=self._dispatches,
            coalesced_requests=self._coalesced,
            latency_p50_s=float(np.percentile(lats, 50)) if lats else 0.0,
            latency_p95_s=float(np.percentile(lats, 95)) if lats else 0.0,
            throughput_rps=(len(self._done) / self._busy_s
                            if self._busy_s > 0 else 0.0),
            cache_hit_rate=hits / units if units else 0.0,
            peak_resident_cols=self._peak_resident,
            evicted_cols=self._evicted)
