"""Command-line driver for ``SweepService``.

Feeds the service a deterministic synthetic request stream (a mix of
stratified plans, selection seeds, and config subsets over a few apps),
serves it in ``--batch``-sized ticks, and prints the resulting
latency/throughput/coalescing/cache statistics:

    PYTHONPATH=src python -m repro.serving.cli --requests 64 --batch 16 \\
        --memo-cap 4 --evict-policy lru --spill

``--quick`` shrinks the stream for CI smoke runs.
"""

from __future__ import annotations

import argparse
from typing import Sequence

import numpy as np

from ..core.sampling.plan import (Centroid, DaleniusGurney, RFVClusters,
                                  RandomUnit, SamplingPlan)
from ..experiments.engine import ExperimentEngine
from ..experiments.sweep import SweepSpec
from .service import SweepService

__all__ = ["main", "synthetic_stream"]

_APPS = ("505.mcf_r", "520.omnetpp_r", "525.x264_r")


def synthetic_stream(n: int, seed: int = 0,
                     apps: Sequence[str] = _APPS) -> list[SweepSpec]:
    """``n`` deterministic sweep requests mixing plans, seeds and config
    subsets — repeats are common by construction, so the stream
    exercises both coalescing (same shape, different seeds) and the
    memo's cross-request cache hits."""
    rng = np.random.default_rng(seed)
    plans = (SamplingPlan(RFVClusters(), Centroid()),
             SamplingPlan(RFVClusters(), RandomUnit()),
             SamplingPlan(DaleniusGurney(), Centroid()))
    cfg_subsets = ((0, 1, 2), (0, 1, 2), (3, 4, 5, 6))
    out = []
    for _ in range(n):
        plan = plans[int(rng.integers(len(plans)))]
        out.append(SweepSpec(
            apps=tuple(apps), plan=plan,
            config_indices=cfg_subsets[int(rng.integers(len(cfg_subsets)))],
            selection_seed=int(rng.integers(4))))
    return out


def main(argv: Sequence[str] | None = None) -> None:
    """Run a synthetic request stream through ``SweepService``."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=64,
                    help="synthetic requests to serve")
    ap.add_argument("--batch", type=int, default=16,
                    help="requests submitted per tick")
    ap.add_argument("--memo-cap", type=int, default=None,
                    help="max resident memo columns (default: unbounded)")
    ap.add_argument("--evict-policy", choices=("lru", "charge"),
                    default="lru")
    ap.add_argument("--spill", action="store_true",
                    help="host-spill evicted columns instead of dropping")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small stream for CI smoke runs")
    args = ap.parse_args(argv)

    if args.quick:
        args.requests = min(args.requests, 12)
        args.batch = min(args.batch, 6)

    service = SweepService(ExperimentEngine.auto(),
                           memo_cap=args.memo_cap,
                           evict_policy=args.evict_policy,
                           spill=args.spill)
    stream = synthetic_stream(args.requests, seed=args.seed)
    for start in range(0, len(stream), args.batch):
        for spec in stream[start:start + args.batch]:
            service.submit(spec)
        service.tick()

    s = service.stats()
    print(f"served {s.completed} requests in {s.ticks} ticks "
          f"({s.dispatches} dispatches, {s.coalesced_requests} coalesced)")
    print(f"latency p50 {s.latency_p50_s * 1e3:.1f} ms  "
          f"p95 {s.latency_p95_s * 1e3:.1f} ms  "
          f"throughput {s.throughput_rps:.1f} req/s")
    print(f"cache hit rate {s.cache_hit_rate:.3f}  "
          f"peak resident cols {s.peak_resident_cols}  "
          f"evicted {s.evicted_cols}")


if __name__ == "__main__":
    main()
