"""Sweep-as-a-service: request coalescing over a persistent MemoBank.

Public surface of the serving subsystem:

* ``SweepService`` — submit/tick/drain request loop with memo-cap
  eviction (``repro.serving.service``);
* ``run_coalesced_sweeps`` — one fused dispatch per compiled-program
  shape group, bitwise-equal to serial (``repro.serving.batcher``);
* ``coalescible`` / ``coalesce_key`` / ``prepare_sweep`` — the grouping
  predicate and key (``repro.serving.coalesce``).
"""

from .batcher import run_coalesced_sweeps
from .coalesce import PreparedSweep, coalesce_key, coalescible, prepare_sweep
from .service import ServiceStats, SweepRequest, SweepService

__all__ = [
    "PreparedSweep",
    "ServiceStats",
    "SweepRequest",
    "SweepService",
    "coalesce_key",
    "coalescible",
    "prepare_sweep",
    "run_coalesced_sweeps",
]
