"""Repo-contract rules: JL100 api-surface, JL101 missing-docstring,
JL102 broken-doc-link.

These are the three pre-jaxlint gate scripts (``check_api.py``,
``check_docstrings.py``, ``check_docs_links.py``) folded into the
lint driver so ``scripts/lint.py`` is the single static gate. JL100
additionally forbids ``isinstance`` dispatch on the sampling-plan
types outside ``plan.py`` — the registry-bypass follow-up to the
no-string-dispatch rule: branching on ``isinstance(x, Stratifier)``
(or a concrete plan type) re-creates closed-world dispatch that every
registry plug-in (ranked-set estimators, MemoryAccessVectors
stratifiers) would silently fall through.
"""

from __future__ import annotations

import ast
import pathlib
import re

from .context import FileContext
from .findings import Finding
from .registry import register_rule

__all__ = ["check_api_surface", "check_docstrings", "check_doc_links"]

_API_SCOPE = ("src/repro/core/sampling", "src/repro/experiments",
              "src/repro/serving", "src/repro/analysis")
_DOCSTRING_SCOPE = ("src/repro/experiments", "src/repro/kernels",
                    "src/repro/serving", "src/repro/analysis")

# scheme/policy names the pre-plan engine dispatched on (ISSUE 5);
# comparisons against them outside plan.py are re-grown string dispatch
_DISPATCH_LITERALS = frozenset(
    {"bbv", "rfv", "dg", "centroid", "mean", "random"})

# sampling-plan types; isinstance chains on them outside plan.py bypass
# the registry (base protocols AND the concrete built-ins)
_PLAN_TYPES = frozenset({
    "Stratifier", "SelectionPolicy", "Estimator",
    "BBVClusters", "RFVClusters", "DaleniusGurney",
    "Centroid", "StratumMean", "RandomUnit", "RankedSetUnit",
    "WeightedPoint", "CollapsedPairsCI", "TwoPhaseCI",
})
_PLAN_MODULE = "src/repro/core/sampling/plan.py"


def _literal_strs(node):
    """String constants inside a comparator (descending into tuples &c)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for elt in node.elts:
            yield from _literal_strs(elt)


def _type_names(node):
    """Bare/dotted type names in an isinstance second argument."""
    if isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            yield from _type_names(elt)
    elif isinstance(node, ast.Name):
        yield node.id
    elif isinstance(node, ast.Attribute):
        yield node.attr
    elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        yield from _type_names(node.left)
        yield from _type_names(node.right)


@register_rule(
    "JL100", "api-surface",
    "__all__ on every public module; no scheme/policy string-literal "
    "or isinstance dispatch outside the sampling-plan registry",
    scope=_API_SCOPE)
def check_api_surface(ctx: FileContext):
    """Port of check_api.py plus the isinstance-chain registry guard."""
    findings: list[Finding] = []

    def declares_all(node) -> bool:
        if isinstance(node, ast.Assign):
            return any(isinstance(t, ast.Name) and t.id == "__all__"
                       for t in node.targets)
        if isinstance(node, ast.AnnAssign):
            return isinstance(node.target, ast.Name) \
                and node.target.id == "__all__"
        return False

    has_all = any(declares_all(node) for node in ctx.tree.body)
    if not has_all:
        findings.append(Finding(
            rule="JL100", path=ctx.rel, line=1, col=0,
            message="module does not declare __all__ — the public import "
            "contract must be explicit"))

    is_plan = ctx.rel == _PLAN_MODULE
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Compare) and not is_plan:
            hit = sorted(
                s for operand in (node.left, *node.comparators)
                for s in _literal_strs(operand) if s in _DISPATCH_LITERALS)
            if hit:
                findings.append(Finding(
                    rule="JL100", path=ctx.rel, line=node.lineno,
                    col=node.col_offset,
                    message=f"scheme/policy string-literal dispatch on "
                    f"{hit} — route through the sampling-plan registry "
                    "(repro.core.sampling.plan) instead"))
        elif (isinstance(node, ast.Call) and not is_plan
                and isinstance(node.func, ast.Name)
                and node.func.id == "isinstance" and len(node.args) == 2):
            hit = sorted(set(_type_names(node.args[1])) & _PLAN_TYPES)
            if hit:
                findings.append(Finding(
                    rule="JL100", path=ctx.rel, line=node.lineno,
                    col=node.col_offset,
                    message=f"isinstance dispatch on plan type(s) {hit} "
                    "outside plan.py bypasses the registry — registry "
                    "plug-ins would fall through; dispatch on registered "
                    "behavior (methods/attributes) instead"))
    return findings


def _is_public(name: str) -> bool:
    return not name.startswith("_")


@register_rule(
    "JL101", "missing-docstring",
    "module + public class/function docstrings on the documented "
    "experiment/kernel/serving surface (pydocstyle-lite)",
    scope=_DOCSTRING_SCOPE)
def check_docstrings(ctx: FileContext):
    """Port of check_docstrings.py as a driver rule."""
    findings: list[Finding] = []
    if ast.get_docstring(ctx.tree) is None:
        findings.append(Finding(rule="JL101", path=ctx.rel, line=1, col=0,
                                message="missing module docstring"))

    def visit(body, qual):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_public(node.name) and ast.get_docstring(node) is None:
                    findings.append(Finding(
                        rule="JL101", path=ctx.rel, line=node.lineno,
                        col=node.col_offset,
                        message=f"missing docstring on function "
                        f"{qual}{node.name}"))
            elif isinstance(node, ast.ClassDef):
                if _is_public(node.name):
                    if ast.get_docstring(node) is None:
                        findings.append(Finding(
                            rule="JL101", path=ctx.rel, line=node.lineno,
                            col=node.col_offset,
                            message=f"missing docstring on class "
                            f"{qual}{node.name}"))
                    visit(node.body, f"{qual}{node.name}.")

    visit(ctx.tree.body, "")
    return findings


_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(md: pathlib.Path) -> set:
    return {_slug(h) for h in _HEADING_RE.findall(md.read_text())}


@register_rule(
    "JL102", "broken-doc-link",
    "every relative markdown link in README.md/docs/ resolves, and "
    "#anchors match a heading in the target (offline lychee-lite)",
    kind="repo")
def check_doc_links(root: pathlib.Path):
    """Port of check_docs_links.py as a repo-level rule."""
    findings: list[Finding] = []
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    for md in files:
        if not md.exists():
            continue
        rel = md.relative_to(root).as_posix()
        for lineno, line in enumerate(md.read_text().splitlines(), start=1):
            for target in _LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path_part, _, fragment = target.partition("#")
                dest = (md.parent / path_part).resolve() if path_part else md
                if path_part and not dest.exists():
                    findings.append(Finding(
                        rule="JL102", path=rel, line=lineno, col=0,
                        message=f"broken link -> {target}"))
                    continue
                if fragment and dest.suffix == ".md":
                    if fragment not in _anchors(dest):
                        findings.append(Finding(
                            rule="JL102", path=rel, line=lineno, col=0,
                            message=f"missing anchor #{fragment} in "
                            f"{path_part or md.name}"))
    return findings
