"""Per-file analysis context shared by the rule packs.

One :class:`FileContext` per linted Python file carries the parsed
tree plus lazily computed, cached analyses every jax-discipline rule
needs:

* **import resolution** — a map from local names to the dotted origin
  they were imported from (``jnp`` → ``jax.numpy``, relative imports
  resolved against the module's package), and :meth:`resolve` turning
  a ``Name``/``Attribute`` chain into a dotted path through that map;
* **function index** — every ``def``/``lambda`` with its parameters
  and statically-declared arguments;
* **traced reachability** — the set of functions reachable from a
  ``jit``/``shard_map``/``pallas_call``/``scan``-style trace site in
  the same module (decorated, passed as a function argument to a trace
  wrapper, or called from an already-traced function), which is what
  "inside a trace" means to JL001/JL005.

Everything is intra-module by design: a dependency-free ``ast`` pass
cannot see across imports, so reachability is conservative — it only
claims tracedness it can prove, and the fixture suite pins the
patterns it must catch.
"""

from __future__ import annotations

import ast
import pathlib
from functools import cached_property

__all__ = ["FileContext", "FunctionInfo", "TRACE_WRAPPERS"]

# dotted names (post import-resolution) that trace the function they
# are given; bare-name imports resolve to these through the import map
TRACE_WRAPPERS = frozenset({
    "jax.jit", "jax.pjit", "jax.experimental.pjit.pjit",
    "jax.vmap", "jax.pmap", "jax.checkpoint", "jax.remat",
    "jax.grad", "jax.value_and_grad", "jax.lax.scan", "jax.lax.map",
    "jax.lax.while_loop", "jax.lax.cond", "jax.lax.fori_loop",
    "jax.lax.switch", "jax.lax.associative_scan",
    "jax.experimental.shard_map.shard_map",
    "jax.experimental.pallas.pallas_call",
})
# unambiguous last components: anything.pallas_call / anything.shard_map
# is a trace site no matter how the module was imported
_TRACE_SUFFIXES = frozenset({"pallas_call", "shard_map"})


class FunctionInfo:
    """Static facts about one function definition (or lambda)."""

    def __init__(self, node, qualname: str, parent):
        self.node = node
        self.qualname = qualname
        self.parent = parent          # enclosing FunctionInfo or None
        args = node.args
        self.params = [a.arg for a in
                       (args.posonlyargs + args.args + args.kwonlyargs)]
        if args.vararg:
            self.params.append(args.vararg.arg)
        if args.kwarg:
            self.params.append(args.kwarg.arg)
        self.static_params: set[str] = set()

    @property
    def name(self) -> str:
        """Bare function name (``<lambda>`` for lambdas)."""
        return getattr(self.node, "name", "<lambda>")


class FileContext:
    """Parsed file + cached shared analyses handed to every rule."""

    def __init__(self, path: pathlib.Path, rel: str, text: str):
        self.path = path
        self.rel = rel                  # root-relative posix path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        self.module = self._module_name(rel)

    @staticmethod
    def _module_name(rel: str) -> str:
        parts = pathlib.PurePosixPath(rel).with_suffix("").parts
        if "src" in parts:
            parts = parts[parts.index("src") + 1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    # ------------------------------------------------------------ imports
    @cached_property
    def imports(self) -> dict:
        """Local name -> dotted origin, for every import in the file."""
        out: dict[str, str] = {}
        pkg_parts = self.module.split(".")[:-1] if self.module else []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.asname:
                        out[alias.asname] = alias.name
                    else:
                        head = alias.name.split(".")[0]
                        out[head] = head
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base_parts = pkg_parts[:len(pkg_parts) - (node.level - 1)]
                    base = ".".join(base_parts)
                    if node.module:
                        base = f"{base}.{node.module}" if base else node.module
                else:
                    base = node.module or ""
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    local = alias.asname or alias.name
                    out[local] = f"{base}.{alias.name}" if base else alias.name
        return out

    def resolve(self, node) -> str:
        """Dotted path of a Name/Attribute chain through the import map.

        Unresolvable roots keep their raw name (``key.item`` stays
        ``key.item``), so callers can still match on suffixes. Returns
        ``""`` for non-chain expressions.
        """
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return ""
        root = self.imports.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))

    def is_trace_wrapper(self, node) -> bool:
        """Whether an expression names a jit/shard_map/pallas_call-style
        tracer."""
        dotted = self.resolve(node)
        if not dotted:
            return False
        return (dotted in TRACE_WRAPPERS
                or dotted.rsplit(".", 1)[-1] in _TRACE_SUFFIXES)

    # ---------------------------------------------------------- functions
    @cached_property
    def functions(self) -> list:
        """Every function/lambda in the file as :class:`FunctionInfo`."""
        infos: list[FunctionInfo] = []

        def visit(node, qual, parent):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    info = FunctionInfo(child, f"{qual}{child.name}", parent)
                    info.static_params = _static_params(child, self)
                    infos.append(info)
                    visit(child, f"{qual}{child.name}.", info)
                elif isinstance(child, ast.Lambda):
                    info = FunctionInfo(child, f"{qual}<lambda>", parent)
                    infos.append(info)
                    visit(child, f"{qual}<lambda>.", info)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{qual}{child.name}.", parent)
                else:
                    visit(child, qual, parent)

        visit(self.tree, "", None)
        return infos

    @cached_property
    def functions_by_name(self) -> dict:
        """Bare name -> list[FunctionInfo] (conservative, module-wide)."""
        out: dict[str, list] = {}
        for info in self.functions:
            out.setdefault(info.name, []).append(info)
        return out

    @cached_property
    def _info_by_node(self) -> dict:
        return {id(info.node): info for info in self.functions}

    # ------------------------------------------------------ tracedness
    @cached_property
    def traced_functions(self) -> list:
        """Functions reachable from a trace site, deepest contract first.

        Roots: decorated with a trace wrapper (directly or through
        ``functools.partial``), or passed by name/lambda to a trace
        wrapper call. Closure: a traced function tracing through a
        locally-defined callee marks the callee traced too.
        """
        traced: set[int] = set()

        for info in self.functions:
            for deco in getattr(info.node, "decorator_list", []):
                target = deco.func if isinstance(deco, ast.Call) else deco
                if self.is_trace_wrapper(target):
                    traced.add(id(info.node))
                elif (isinstance(deco, ast.Call)
                      and self.resolve(deco.func) in ("functools.partial",
                                                      "partial")
                      and deco.args
                      and self.is_trace_wrapper(deco.args[0])):
                    traced.add(id(info.node))

        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and self.is_trace_wrapper(node.func)):
                continue
            cands = list(node.args) + [kw.value for kw in node.keywords]
            for arg in cands:
                if isinstance(arg, ast.Lambda):
                    traced.add(id(arg))
                elif isinstance(arg, ast.Name):
                    for info in self.functions_by_name.get(arg.id, []):
                        traced.add(id(info.node))

        # closure over intra-module calls from traced bodies
        changed = True
        while changed:
            changed = False
            for info in self.functions:
                if id(info.node) not in traced:
                    continue
                for sub in self._own_body_walk(info.node):
                    if (isinstance(sub, ast.Call)
                            and isinstance(sub.func, ast.Name)):
                        for callee in self.functions_by_name.get(
                                sub.func.id, []):
                            if id(callee.node) not in traced:
                                traced.add(id(callee.node))
                                changed = True
        return [info for info in self.functions if id(info.node) in traced]

    @staticmethod
    def _own_body_walk(fn_node):
        """Walk a function body WITHOUT descending into nested defs
        (nested functions are analyzed separately if reachable)."""
        stack = list(ast.iter_child_nodes(fn_node))
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            stack.extend(ast.iter_child_nodes(node))


def _static_params(fn_node, ctx: FileContext) -> set:
    """Parameter names declared static via jit decorator kwargs."""
    static: set[str] = set()
    args = fn_node.args
    positional = [a.arg for a in (args.posonlyargs + args.args)]
    for deco in fn_node.decorator_list:
        if not isinstance(deco, ast.Call):
            continue
        target = deco.func
        if isinstance(target, ast.Call):
            continue
        if not (ctx.is_trace_wrapper(target)
                or ctx.resolve(target) in ("functools.partial", "partial")):
            continue
        for kw in deco.keywords:
            if kw.arg == "static_argnames":
                for s in ast.walk(kw.value):
                    if isinstance(s, ast.Constant) and isinstance(s.value,
                                                                  str):
                        static.add(s.value)
            elif kw.arg == "static_argnums":
                for s in ast.walk(kw.value):
                    if isinstance(s, ast.Constant) and isinstance(s.value,
                                                                  int):
                        if 0 <= s.value < len(positional):
                            static.add(positional[s.value])
    return static
