"""Rule registry for the jaxlint driver.

A rule is a plain function registered with :func:`register_rule`. Two
kinds exist:

* ``kind="python"`` (default) — called once per in-scope Python file
  with a :class:`~repro.analysis.context.FileContext`; yields/returns
  :class:`~repro.analysis.findings.Finding`s.
* ``kind="repo"`` — called once per run with the repo root path;
  used for cross-file checks (markdown link integrity).

``scope`` is a tuple of root-relative posix path prefixes the rule
applies to (``None`` = every scanned file). Scoping is part of each
rule's contract — e.g. JL003 sweeps only the estimator-pipeline
packages where ``PrecisionPolicy`` is the law, not the model zoo where
mixed-precision f32 pinning is idiomatic.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["Rule", "RULES", "register_rule", "rules_for"]


@dataclasses.dataclass(frozen=True)
class Rule:
    """One registered lint rule."""

    id: str                       # "JL001"
    name: str                     # "host-sync-in-trace"
    help: str                     # one-line rationale for --list-rules
    fn: Callable
    scope: Optional[tuple]        # path prefixes, None = all files
    kind: str                     # "python" | "repo"

    def applies_to(self, rel: str) -> bool:
        """Whether a root-relative posix path is in this rule's scope."""
        if self.scope is None:
            return True
        return any(rel == p or rel.startswith(p.rstrip("/") + "/")
                   for p in self.scope)


RULES: dict[str, Rule] = {}


def register_rule(rule_id: str, name: str, help_text: str, *,
                  scope: Optional[tuple] = None,
                  kind: str = "python") -> Callable:
    """Decorator registering a rule function under ``rule_id``."""
    if kind not in ("python", "repo"):
        raise ValueError(f"unknown rule kind {kind!r}")

    def deco(fn: Callable) -> Callable:
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        RULES[rule_id] = Rule(id=rule_id, name=name, help=help_text,
                              fn=fn, scope=scope, kind=kind)
        return fn

    return deco


def rules_for(rel: str, select: Optional[set] = None) -> list[Rule]:
    """Python-file rules applying to ``rel``, optionally id-filtered."""
    return [r for r in RULES.values()
            if r.kind == "python" and r.applies_to(rel)
            and (select is None or r.id in select)]
