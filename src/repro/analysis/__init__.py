"""jaxlint: repo-native static analysis for the two-phase sampling stack.

Nine PRs of growth accreted load-bearing conventions that no unit test
reliably enforces: ONE-dispatch-per-site markers, ``PrecisionPolicy``
threading, donated-buffer discipline, ``fold_in`` PRNG derivation, the
batch-native-kernel (never ``vmap``-of-``pallas_call``) contract, and
the sampling-plan registry as the only dispatch surface. This package
mechanizes them as an AST-based lint pass — stdlib ``ast``/``tokenize``
only, no third-party dependencies, never imports jax — so the gate runs
in the dependency-free CI job and in a few seconds locally.

Rule packs
----------
* ``rules_trace``     — JL001 host-sync-in-trace, JL005
  untraced-python-branch, JL006 vmap-of-pallas_call (shared
  traced-reachability analysis).
* ``rules_prng``      — JL002 prng-key-reuse.
* ``rules_precision`` — JL003 raw-dtype-literal, JL004
  donation-after-use.
* ``rules_repo``      — JL100 api-surface (``__all__`` + string/
  ``isinstance`` dispatch), JL101 missing-docstring, JL102
  broken-doc-link: the three pre-jaxlint gate scripts folded into the
  same driver.

Entry points: ``python -m repro.analysis`` or ``scripts/lint.py``.
Suppression (``# jaxlint: disable=JL003``) and the grandfathering
baseline (``lint_baseline.json``) are documented in
``docs/contributing.md``.
"""

from .driver import main, run_lint
from .findings import Finding
from .registry import RULES, register_rule

# importing the packs registers their rules with the driver registry
from . import rules_trace, rules_prng, rules_precision, rules_repo  # noqa: E402,F401 isort:skip

__all__ = ["main", "run_lint", "Finding", "RULES", "register_rule"]
