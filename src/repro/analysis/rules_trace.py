"""Trace-hygiene rules: JL001, JL005, JL006.

These enforce the conventions the fused megaprogram (PR 7) and the
streaming trial engine (PR 6) rely on: traced bodies never round-trip
to the host, never branch in Python on traced values, and kernels are
batch-native — ``vmap``-of-``pallas_call`` is the exact regression the
batched ``(batch, tile)`` grid eliminated in PR 3.
"""

from __future__ import annotations

import ast

from .context import FileContext
from .findings import Finding
from .registry import register_rule

__all__ = ["check_host_sync", "check_untraced_branch",
           "check_vmap_of_pallas"]

# numpy functions that force device->host materialization of their
# argument when it is traced (silent sync, or a tracer leak error)
_NP_SYNC_FNS = frozenset({"asarray", "array", "frombuffer",
                          "ascontiguousarray", "copyto", "save", "savez"})
# attribute calls that block on / materialize a device value
_SYNC_ATTRS = frozenset({"item", "tolist", "block_until_ready"})
# attributes of a traced array that are static at trace time — reading
# them neither syncs (JL001) nor makes a Python branch dynamic (JL005)
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding",
                           "aval", "weak_type"})
# builtins whose result on a non-constant argument is static/hashable
_STATIC_CALLS = frozenset({"len", "isinstance", "issubclass", "getattr",
                           "hasattr", "type", "id", "repr", "str"})
# parameter names conventionally bound to static (hashable, non-array)
# configuration in this codebase — branching on them is trace-time
# specialization, not a traced-value branch. Arrays must not use these
# names (rename or suppress if they do).
_STATIC_NAME_HINTS = frozenset({"cfg", "config", "spec", "plan", "policy",
                                "precision", "mesh", "backend", "axes",
                                "hparams", "strict"})


def _findings(ctx, rule, nodes_msgs):
    return [Finding(rule=rule, path=ctx.rel, line=n.lineno,
                    col=n.col_offset, message=m) for n, m in nodes_msgs]


@register_rule(
    "JL001", "host-sync-in-trace",
    "host round-trips (.item()/float()/np.asarray/device_get/print) "
    "inside functions reachable from a jit/shard_map/pallas_call site "
    "corrupt or abort the trace")
def check_host_sync(ctx: FileContext):
    """Flag host-materializing calls inside traced-reachable functions."""
    hits = []
    for info in ctx.traced_functions:
        for node in FileContext._own_body_walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if (isinstance(fn, ast.Attribute) and fn.attr in _SYNC_ATTRS
                    and not node.args):
                hits.append((node, f"`.{fn.attr}()` inside traced function "
                             f"`{info.qualname}` forces a device->host "
                             "sync; keep the value on device or hoist to "
                             "the host caller"))
                continue
            dotted = ctx.resolve(fn)
            if dotted == "jax.device_get":
                hits.append((node, "`jax.device_get` inside traced function "
                             f"`{info.qualname}`; traced values cannot be "
                             "fetched mid-program"))
            elif (dotted.startswith("numpy.")
                    and dotted.rsplit(".", 1)[-1] in _NP_SYNC_FNS):
                hits.append((node, f"`{dotted}` inside traced function "
                             f"`{info.qualname}` materializes its argument "
                             "on host; use jnp under the PrecisionPolicy "
                             "trace dtype instead"))
            elif isinstance(fn, ast.Name) and fn.id == "print":
                hits.append((node, "`print` inside traced function "
                             f"`{info.qualname}` runs at trace time only "
                             "(or syncs); use jax.debug.print"))
            elif (isinstance(fn, ast.Name) and fn.id in ("float", "int",
                                                         "bool")
                    and len(node.args) == 1
                    and not isinstance(node.args[0], ast.Constant)):
                hits.append((node, f"`{fn.id}(...)` on a non-constant inside "
                             f"traced function `{info.qualname}` "
                             "concretizes a traced value"))
    return _findings(ctx, "JL001", hits)


def _dynamic_names(node) -> set:
    """Names whose runtime VALUE the expression depends on.

    Skips subtrees that are static at trace time: ``.shape``-style
    attribute reads, ``len``/``isinstance`` calls, and pure
    ``is``/``is not`` comparisons (structural ``None`` checks).
    """
    out: set[str] = set()
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return out
        out |= _dynamic_names(node.value)
        return out
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in _STATIC_CALLS:
            return out
        for child in ast.iter_child_nodes(node):
            out |= _dynamic_names(child)
        return out
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return out
    if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
        out.add(node.id)
        return out
    for child in ast.iter_child_nodes(node):
        out |= _dynamic_names(child)
    return out


def _assigned_names(target) -> set:
    return {n.id for n in ast.walk(target)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)}


@register_rule(
    "JL005", "untraced-python-branch",
    "Python if/while/for on values derived from traced parameters "
    "either crashes at trace time or silently bakes one branch into "
    "the compiled program; use lax.cond/scan or declare the argument "
    "static")
def check_untraced_branch(ctx: FileContext):
    """Flag Python control flow on traced-parameter-derived values."""
    hits = []
    for info in ctx.traced_functions:
        tainted = (set(info.params) - info.static_params
                   - {"self", "cls"} - _STATIC_NAME_HINTS)
        if not tainted:
            continue
        # one forward pass of taint propagation through plain
        # assignments; names bound to list/tuple literals stay
        # Python-structured (their LENGTH is static even when their
        # elements are traced), so iterating them is fine
        container_names: set[str] = set()
        for node in ast.walk(info.node):
            if isinstance(node, ast.Assign):
                if isinstance(node.value, (ast.List, ast.Tuple,
                                           ast.ListComp, ast.Dict,
                                           ast.DictComp)):
                    container_names |= _assigned_names(node.targets[0])
                elif _dynamic_names(node.value) & tainted:
                    for t in node.targets:
                        tainted |= _assigned_names(t)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                if node.value is not None \
                        and _dynamic_names(node.value) & tainted:
                    tainted |= _assigned_names(node.target)
        tainted -= container_names
        for node in FileContext._own_body_walk(info.node):
            if isinstance(node, (ast.If, ast.While)):
                dyn = _dynamic_names(node.test) & tainted
                if dyn:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    hits.append((node, f"Python `{kind}` on traced value(s) "
                                 f"{sorted(dyn)} in `{info.qualname}`; use "
                                 "jnp.where/lax.cond or mark the argument "
                                 "static"))
            elif isinstance(node, ast.For):
                dyn = _dynamic_names(node.iter) & tainted
                if dyn:
                    hits.append((node, "Python `for` over traced value(s) "
                                 f"{sorted(dyn)} in `{info.qualname}`; use "
                                 "lax.scan/fori_loop"))
    return _findings(ctx, "JL005", hits)


def _calls_pallas(info, ctx: FileContext, seen=None) -> bool:
    """Whether a function (transitively, intra-module) calls pallas_call."""
    if seen is None:
        seen = set()
    if id(info.node) in seen:
        return False
    seen.add(id(info.node))
    for node in FileContext._own_body_walk(info.node):
        if not isinstance(node, ast.Call):
            continue
        dotted = ctx.resolve(node.func)
        if dotted.rsplit(".", 1)[-1] == "pallas_call":
            return True
        if isinstance(node.func, ast.Name):
            for callee in ctx.functions_by_name.get(node.func.id, []):
                if _calls_pallas(callee, ctx, seen):
                    return True
    return False


@register_rule(
    "JL006", "vmap-of-pallas_call",
    "kernels are batch-native ((batch, tile) grid); vmapping a "
    "pallas_call or a repro.kernels op re-creates the per-lane "
    "dispatch PR 3 eliminated")
def check_vmap_of_pallas(ctx: FileContext):
    """Flag ``vmap`` applied to pallas kernels or repro.kernels ops."""
    hits = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        if ctx.resolve(node.func) not in ("jax.vmap", "jax.api.vmap"):
            continue
        target = node.args[0]
        reason = None
        if isinstance(target, ast.Call) \
                and ctx.resolve(target.func).rsplit(".", 1)[-1] \
                == "pallas_call":
            reason = "a pallas_call"
        else:
            dotted = ctx.resolve(target)
            if dotted.startswith("repro.kernels"):
                reason = f"`{dotted}` (a batch-native repro.kernels op)"
            elif isinstance(target, ast.Name):
                for info in ctx.functions_by_name.get(target.id, []):
                    if _calls_pallas(info, ctx):
                        reason = (f"`{target.id}`, which dispatches a "
                                  "pallas_call")
                        break
        if reason:
            hits.append((node, f"vmap over {reason}; kernels take leading "
                         "batch axes natively — pass the stacked array "
                         "instead"))
    return _findings(ctx, "JL006", hits)
