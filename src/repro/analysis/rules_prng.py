"""PRNG-discipline rule: JL002 prng-key-reuse.

The streaming trial engine's reproducibility contract (PR 6) hangs on
every key being consumed exactly once: block ``b`` of app ``a`` draws
from ``fold_in(fold_in(trial_key, b), a)``, a pure function of the
(seed, block, app) coordinates. A key fed to two ``jax.random`` draws
produces *correlated* samples — the two-phase estimator's variance
math silently assumes independence, so reuse biases the confidence
intervals no unit test will catch.

The check is an order-aware walk of each function body: a name
consumed by a draw (``uniform``/``normal``/...) is poisoned until
reassigned (typically via ``split``/``fold_in``, which only *derive*
and never consume). ``if``/``else`` branches are alternatives — the
same key drawn in both arms is fine — so each arm starts from a
snapshot and the merged state is the conservative union. Loop bodies
are processed twice: a draw from a loop-invariant key is reuse on the
second iteration even though a single linear pass never sees it twice.
"""

from __future__ import annotations

import ast

from .context import FileContext
from .findings import Finding
from .registry import register_rule

__all__ = ["check_key_reuse"]

# jax.random functions that CONSUME their key argument
_DRAWS = frozenset({
    "uniform", "normal", "bernoulli", "randint", "choice", "permutation",
    "shuffle", "gamma", "beta", "poisson", "exponential", "categorical",
    "gumbel", "laplace", "dirichlet", "truncated_normal", "bits", "t",
    "cauchy", "logistic", "rademacher", "maxwell", "orthogonal", "ball",
    "multivariate_normal", "loggamma", "binomial", "geometric", "rayleigh",
    "triangular", "weibull_min", "chisquare", "f", "generalized_normal",
})
# jax.random functions that DERIVE new keys without consuming
_DERIVES = frozenset({"split", "fold_in", "clone", "key_data", "wrap_key_data"})


def _key_expr(node) -> str:
    """Stable textual id for a key argument (Name or dotted chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _KeyState:
    """Names consumed so far, mapping to the draw that consumed them."""

    def __init__(self, ctx: FileContext):
        self.ctx = ctx
        self.consumed: dict[str, ast.AST] = {}
        self.findings: list[Finding] = []
        self._reported: set[int] = set()

    def copy(self) -> "_KeyState":
        dup = _KeyState(self.ctx)
        dup.consumed = dict(self.consumed)
        dup.findings = self.findings          # shared sink
        dup._reported = self._reported        # shared dedupe
        return dup

    def merge(self, *branches: "_KeyState") -> None:
        for b in branches:
            self.consumed.update(b.consumed)

    def reset(self, names) -> None:
        for n in names:
            self.consumed.pop(n, None)

    def draw(self, call: ast.Call, fn_name: str) -> None:
        if not call.args:
            return
        key = _key_expr(call.args[0])
        if not key:
            return
        prior = self.consumed.get(key)
        if prior is not None and id(call) not in self._reported:
            self._reported.add(id(call))
            self.findings.append(Finding(
                rule="JL002", path=self.ctx.rel, line=call.lineno,
                col=call.col_offset,
                message=f"PRNG key `{key}` already consumed by a "
                f"`random.*` draw at line {prior.lineno}; draws from the "
                f"same key are correlated — `split`/`fold_in` before "
                f"`{fn_name}`"))
        self.consumed[key] = call


def _scan_expr(node, state: _KeyState) -> None:
    """Visit draw calls inside one expression, in walk order."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        dotted = state.ctx.resolve(sub.func)
        if not dotted:
            continue
        head, _, last = dotted.rpartition(".")
        if last in _DRAWS and head.endswith("random"):
            state.draw(sub, last)


def _scan_stmts(stmts, state: _KeyState) -> None:
    for stmt in stmts:
        _scan_one(stmt, state)


def _scan_one(stmt, state: _KeyState) -> None:
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                         ast.ClassDef)):
        return                      # nested scopes are scanned separately
    if isinstance(stmt, ast.If):
        _scan_expr(stmt.test, state)
        then_state, else_state = state.copy(), state.copy()
        _scan_stmts(stmt.body, then_state)
        _scan_stmts(stmt.orelse, else_state)
        state.merge(then_state, else_state)
        return
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        _scan_expr(stmt.iter, state)
        loop_targets = {n.id for n in ast.walk(stmt.target)
                        if isinstance(n, ast.Name)}
        for _pass in range(2):      # 2nd pass: loop-invariant key reuse
            state.reset(loop_targets)
            _scan_stmts(stmt.body, state)
        _scan_stmts(stmt.orelse, state)
        return
    if isinstance(stmt, ast.While):
        _scan_expr(stmt.test, state)
        for _pass in range(2):
            _scan_stmts(stmt.body, state)
        _scan_stmts(stmt.orelse, state)
        return
    if isinstance(stmt, ast.Try):
        _scan_stmts(stmt.body, state)
        for handler in stmt.handlers:
            _scan_stmts(handler.body, state)
        _scan_stmts(stmt.orelse, state)
        _scan_stmts(stmt.finalbody, state)
        return
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            _scan_expr(item.context_expr, state)
        _scan_stmts(stmt.body, state)
        return
    # plain statement: draws first (value side), then reassignment resets
    _scan_expr(stmt, state)
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.Delete):
        targets = stmt.targets
    reset = set()
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                reset.add(n.id)
            elif isinstance(n, ast.Attribute):
                dotted = _key_expr(n)
                if dotted:
                    reset.add(dotted)
    # walrus assignments anywhere in the statement also rebind
    for n in ast.walk(stmt):
        if isinstance(n, ast.NamedExpr) and isinstance(n.target, ast.Name):
            reset.add(n.target.id)
    state.reset(reset)


@register_rule(
    "JL002", "prng-key-reuse",
    "a key consumed by two random.* draws without an intervening "
    "split/fold_in yields correlated samples and biases the two-phase "
    "CI math")
def check_key_reuse(ctx: FileContext):
    """Flag PRNG keys consumed by more than one ``jax.random`` draw."""
    findings: list[Finding] = []
    # module body counts as a scope too (bench/example scripts)
    scopes = [ctx.tree.body] + [
        info.node.body if isinstance(info.node.body, list)
        else [ast.Expr(value=info.node.body)]
        for info in ctx.functions]
    for body in scopes:
        state = _KeyState(ctx)
        state.findings = findings
        _scan_stmts(body, state)
    return findings
