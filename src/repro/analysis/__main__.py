"""``python -m repro.analysis`` — run the jaxlint static-analysis gate."""

import sys

from . import main

__all__: list = []

if __name__ == "__main__":
    sys.exit(main())
