"""Precision-policy and donation-safety rules: JL003, JL004.

JL003 enforces the PR 6 contract that ONE ``PrecisionPolicy`` (trace /
accum / host dtype triple, ``repro.core.precision``) owns every dtype
decision in the estimator pipeline. A raw ``jnp.float32`` or
``astype("float32")`` in that scope is exactly the class of silent
downcast that produced PR 7's ``Centroid`` f32 catastrophic-
cancellation bug. Scope: the sampling/experiment/serving/simcpu
packages. ``np.float64`` attribute references are exempt by
definition — numpy never runs inside a trace and f64 IS the policy's
``host`` role; every other float dtype literal must route through the
policy (or carry a justification).

JL004 guards the fused megaprogram's donation contract (PR 7): a
buffer passed at a ``donate_argnums`` position is DELETED by the
dispatch — reading the same name afterwards raises (CPU) or returns
garbage (some backends). The rule tracks names bound to
``jax.jit(..., donate_argnums=...)`` programs and flags any read of a
donated argument after the dispatch call in the same scope.
"""

from __future__ import annotations

import ast

from .context import FileContext
from .findings import Finding
from .registry import register_rule

__all__ = ["check_dtype_literal", "check_donation_after_use"]

_PRECISION_SCOPE = (
    "src/repro/core/sampling",
    "src/repro/experiments",
    "src/repro/serving",
    "src/repro/simcpu",
    "src/repro/distributed",
)

# dotted dtype attributes that bypass PrecisionPolicy in scope; numpy
# float64 is exempt (it IS the host role — numpy code never traces)
_BANNED_DTYPE_ATTRS = frozenset({
    "jax.numpy.float32", "jax.numpy.float64", "jax.numpy.float16",
    "jax.numpy.bfloat16", "numpy.float32", "numpy.float16",
})
_DTYPE_STRINGS = frozenset({"float32", "float64", "float16", "bfloat16"})


@register_rule(
    "JL003", "raw-dtype-literal",
    "float dtype literals in the estimator pipeline bypass "
    "PrecisionPolicy (core/precision.py) — the silent-downcast class "
    "of bug behind PR 7's Centroid cancellation",
    scope=_PRECISION_SCOPE)
def check_dtype_literal(ctx: FileContext):
    """Flag raw float dtype literals outside ``PrecisionPolicy``."""
    hits = []
    flagged: set[int] = set()

    def flag(node, msg):
        if id(node) not in flagged:
            flagged.add(id(node))
            hits.append((node, msg))

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute):
            dotted = ctx.resolve(node)
            if dotted in _BANNED_DTYPE_ATTRS:
                flag(node, f"raw dtype literal `{dotted}` — thread a "
                     "PrecisionPolicy and use policy.trace_dtype/"
                     "accum_dtype/host_dtype instead")
        elif isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "astype":
                for arg in node.args:
                    if isinstance(arg, ast.Constant) \
                            and arg.value in _DTYPE_STRINGS:
                        flag(arg, f"raw dtype string `astype("
                             f"\"{arg.value}\")` — use the "
                             "PrecisionPolicy dtype for this role")
            if ctx.resolve(fn) in ("numpy.dtype", "jax.numpy.dtype"):
                for arg in node.args:
                    if isinstance(arg, ast.Constant) \
                            and arg.value in _DTYPE_STRINGS:
                        flag(arg, f"raw dtype string `dtype("
                             f"\"{arg.value}\")` — use the "
                             "PrecisionPolicy dtype for this role")
            for kw in node.keywords:
                if kw.arg == "dtype" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value in _DTYPE_STRINGS:
                    flag(kw.value, f"raw dtype string `dtype="
                         f"\"{kw.value.value}\"` — use the "
                         "PrecisionPolicy dtype for this role")
    return [Finding(rule="JL003", path=ctx.rel, line=n.lineno,
                    col=n.col_offset, message=m) for n, m in hits]


def _donate_positions(call: ast.Call, module_consts: dict) -> tuple:
    """Donated positional indices from a jax.jit call's keywords."""
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        value = kw.value
        if isinstance(value, ast.Name):
            value = module_consts.get(value.id)
        if isinstance(value, ast.Constant) and isinstance(value.value, int):
            return (value.value,)
        if isinstance(value, (ast.Tuple, ast.List)):
            out = []
            for elt in value.elts:
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, int):
                    out.append(elt.value)
            return tuple(out)
    return ()


@register_rule(
    "JL004", "donation-after-use",
    "an argument passed at a donate_argnums position is deleted by the "
    "dispatch; reading it afterwards raises or returns garbage")
def check_donation_after_use(ctx: FileContext):
    """Flag reads of donated buffers after the donating dispatch."""
    module_consts = {
        t.id: node.value
        for node in ctx.tree.body if isinstance(node, ast.Assign)
        for t in node.targets if isinstance(t, ast.Name)}

    # names bound (module- or function-level) to donating jitted programs
    donating: dict[str, tuple] = {}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Assign):
            continue
        value = node.value
        if isinstance(value, ast.Call) \
                and ctx.resolve(value.func) in ("jax.jit", "jax.pjit"):
            pos = _donate_positions(value, module_consts)
            if pos:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        donating[t.id] = pos

    findings: list[Finding] = []

    def scan_body(stmts):
        donated: dict[str, ast.AST] = {}   # name -> dispatch call site

        def dispatch_args(call: ast.Call, positions):
            for i in positions:
                if i < len(call.args) and isinstance(call.args[i], ast.Name):
                    donated[call.args[i].id] = call

        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            # reads of already-donated names anywhere in this statement
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Name) and isinstance(sub.ctx,
                                                            ast.Load) \
                        and sub.id in donated:
                    site = donated[sub.id]
                    findings.append(Finding(
                        rule="JL004", path=ctx.rel, line=sub.lineno,
                        col=sub.col_offset,
                        message=f"`{sub.id}` was donated to the dispatch at "
                        f"line {site.lineno} (donate_argnums) and no longer "
                        "owns its buffer; reload or re-checkout the value"))
                    donated.pop(sub.id, None)   # one report per donation
            # new dispatches in this statement
            for sub in ast.walk(stmt):
                if not isinstance(sub, ast.Call):
                    continue
                if isinstance(sub.func, ast.Name) \
                        and sub.func.id in donating:
                    dispatch_args(sub, donating[sub.func.id])
                elif isinstance(sub.func, ast.Call) \
                        and ctx.resolve(sub.func.func) in ("jax.jit",
                                                           "jax.pjit"):
                    pos = _donate_positions(sub.func, module_consts)
                    if pos:
                        dispatch_args(sub, pos)
            # reassignment restores ownership
            targets = []
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                targets = [stmt.target]
            elif isinstance(stmt, ast.Delete):
                targets = stmt.targets
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        donated.pop(n.id, None)

    for info in ctx.functions:
        if isinstance(info.node, ast.Lambda):
            continue
        scan_body(info.node.body)
    scan_body(ctx.tree.body)
    return findings
