"""Finding records, suppression comments, and the grandfathering baseline.

A finding is identified for baseline purposes by its *fingerprint*:
``(rule, path, stripped source line)``. Line numbers drift with every
edit, but the offending line's text only changes when the finding
itself changes, so a committed ``lint_baseline.json`` survives
unrelated refactors while any NEW violation (even in a heavily
baselined file) still fails the build.

Suppression comments are the in-code alternative for findings whose
justification belongs next to the code:

* ``# jaxlint: disable=JL003`` (same line, comma-separated ids) —
  suppresses those rules on that one line;
* ``# jaxlint: disable-file=JL003`` (its own line, anywhere) —
  suppresses the rule for the whole file.

Baseline entries MUST carry a non-empty ``justification``; stale
entries (no longer matching any finding) fail the lint so the ledger
never rots.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import re
from collections import Counter

__all__ = ["Finding", "Suppressions", "Baseline", "fingerprint"]

_SUPPRESS_RE = re.compile(
    r"#\s*jaxlint:\s*disable(?P<file>-file)?\s*=\s*"
    r"(?P<rules>[A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation at a source location."""

    rule: str       # "JL001"
    path: str       # repo-root-relative posix path
    line: int       # 1-based
    col: int        # 0-based
    message: str

    def render(self) -> str:
        """Human one-liner, ``path:line:col: RULE message``."""
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def fingerprint(finding: Finding, source_lines: list[str]) -> tuple:
    """Line-content fingerprint used for baseline matching."""
    idx = finding.line - 1
    code = source_lines[idx].strip() if 0 <= idx < len(source_lines) else ""
    return (finding.rule, finding.path, code)


class Suppressions:
    """Per-file suppression state parsed from ``# jaxlint:`` comments."""

    def __init__(self, text: str):
        self.line_rules: dict[int, set[str]] = {}
        self.file_rules: set[str] = set()
        for lineno, line in enumerate(text.splitlines(), start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",")}
            if m.group("file"):
                self.file_rules |= rules
            else:
                self.line_rules.setdefault(lineno, set()).update(rules)

    def covers(self, finding: Finding) -> bool:
        """Whether this finding is suppressed in-code."""
        if finding.rule in self.file_rules:
            return True
        return finding.rule in self.line_rules.get(finding.line, set())


class Baseline:
    """The committed grandfathered-findings ledger (``lint_baseline.json``).

    Matching is a multiset draw on fingerprints: each entry absorbs at
    most one finding with the same ``(rule, path, code)``, so adding a
    second identical violation to an already-baselined line count still
    fails.
    """

    def __init__(self, path: pathlib.Path | None):
        self.path = path
        self.entries: list[dict] = []
        self.errors: list[str] = []
        if path is not None and path.exists():
            try:
                payload = json.loads(path.read_text())
            except json.JSONDecodeError as e:
                self.errors.append(f"{path.name}: invalid JSON ({e})")
                payload = {}
            self.entries = list(payload.get("entries", []))
        for i, entry in enumerate(self.entries):
            if not str(entry.get("justification", "")).strip():
                self.errors.append(
                    f"{path.name}: entry {i} ({entry.get('rule')} "
                    f"{entry.get('path')}) has no justification — every "
                    "baselined finding must say why it is unavoidable")

    def partition(self, findings_with_fp: list[tuple[Finding, tuple]]
                  ) -> tuple[list[Finding], list[Finding], list[dict]]:
        """Split findings into (active, baselined) and return stale entries."""
        budget = Counter(
            (e.get("rule"), e.get("path"), str(e.get("code", "")).strip())
            for e in self.entries)
        active: list[Finding] = []
        baselined: list[Finding] = []
        for finding, fp in findings_with_fp:
            if budget.get(fp, 0) > 0:
                budget[fp] -= 1
                baselined.append(finding)
            else:
                active.append(finding)
        # whatever budget is left after the draw corresponds to entries
        # no finding matched — they are stale and must be pruned
        stale: list[dict] = []
        for e in self.entries:
            key = (e.get("rule"), e.get("path"),
                   str(e.get("code", "")).strip())
            if budget.get(key, 0) > 0:
                budget[key] -= 1
                stale.append(e)
        return active, baselined, stale

    @staticmethod
    def write(path: pathlib.Path, findings_with_fp: list[tuple[Finding, tuple]],
              prior_entries: list[dict]) -> None:
        """Regenerate the baseline from the current findings.

        Justifications of surviving entries are preserved (matched by
        fingerprint); new entries get an explicit placeholder that a
        reviewer must replace.
        """
        prior_just: dict[tuple, str] = {}
        for e in prior_entries:
            key = (e.get("rule"), e.get("path"), str(e.get("code", "")).strip())
            prior_just.setdefault(key, str(e.get("justification", "")))
        entries = []
        for finding, fp in sorted(findings_with_fp,
                                  key=lambda t: (t[0].path, t[0].line,
                                                 t[0].rule)):
            entries.append({
                "rule": finding.rule,
                "path": finding.path,
                "line": finding.line,
                "code": fp[2],
                "justification": prior_just.get(
                    fp, "grandfathered by --update-baseline; justify or fix"),
            })
        payload = {"version": 1, "entries": entries}
        path.write_text(json.dumps(payload, indent=2) + "\n")
