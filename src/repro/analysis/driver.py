"""jaxlint driver: discovery, rule dispatch, baseline, output, CLI.

``run_lint`` is the library surface (the fixture tests and the bench
claim row call it in-process); ``main`` is the CLI behind both
``python -m repro.analysis`` and ``scripts/lint.py``.

Exit codes: 0 clean (baselined findings included), 1 active findings /
stale or unjustified baseline entries, 2 usage or parse errors.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time

from .context import FileContext
from .findings import Baseline, Finding, Suppressions, fingerprint
from .registry import RULES, rules_for

__all__ = ["LintReport", "run_lint", "main", "DEFAULT_ROOTS",
           "DEFAULT_BASELINE"]

# scanned by default, relative to the repo root: the package itself,
# plus the bench/example/script code the PRNG- and trace-discipline
# rules must sweep (key reuse historically hides in driver scripts)
DEFAULT_ROOTS = ("src/repro", "benchmarks", "examples", "scripts")
DEFAULT_BASELINE = "lint_baseline.json"


@dataclasses.dataclass
class LintReport:
    """Everything one lint run produced."""

    root: str
    files: int
    active: list        # findings failing the build
    baselined: list     # findings absorbed by lint_baseline.json
    suppressed: int     # findings silenced by # jaxlint: comments
    stale: list         # baseline entries matching nothing (must prune)
    errors: list        # parse/config errors (fail the build)
    duration_s: float

    @property
    def ok(self) -> bool:
        """Build verdict: no active findings, stale entries, or errors."""
        return not self.active and not self.stale and not self.errors

    def to_json(self) -> dict:
        """The machine-readable report (schema pinned by the tests)."""
        def row(f: Finding, status: str) -> dict:
            return {"rule": f.rule, "path": f.path, "line": f.line,
                    "col": f.col, "message": f.message, "status": status}
        return {
            "version": 1,
            "root": self.root,
            "rules": [{"id": r.id, "name": r.name, "help": r.help}
                      for r in sorted(RULES.values(), key=lambda r: r.id)],
            "findings": ([row(f, "active") for f in self.active]
                         + [row(f, "baselined") for f in self.baselined]),
            "summary": {
                "files": self.files,
                "active": len(self.active),
                "baselined": len(self.baselined),
                "suppressed": self.suppressed,
                "stale_baseline": len(self.stale),
                "errors": list(self.errors),
                "duration_s": round(self.duration_s, 3),
                "ok": self.ok,
            },
        }


def _repo_root() -> pathlib.Path:
    # src/repro/analysis/driver.py -> repo root is four levels up
    return pathlib.Path(__file__).resolve().parents[3]


def _discover(root: pathlib.Path, paths) -> list:
    """Python files to lint, as (abs_path, root-relative posix) pairs."""
    tops = [root / p for p in DEFAULT_ROOTS] if not paths \
        else [pathlib.Path(p) if pathlib.Path(p).is_absolute()
              else root / p for p in paths]
    out = []
    seen = set()
    for top in tops:
        if top.is_file():
            candidates = [top]
        elif top.is_dir():
            candidates = sorted(top.rglob("*.py"))
        else:
            continue
        for path in candidates:
            if "__pycache__" in path.parts or path in seen:
                continue
            seen.add(path)
            try:
                rel = path.resolve().relative_to(root).as_posix()
            except ValueError:
                rel = path.as_posix()
            out.append((path, rel))
    return out


def run_lint(paths=None, *, root=None, baseline_path=None, select=None,
             update_baseline: bool = False) -> LintReport:
    """Run every registered rule and reconcile against the baseline.

    ``paths`` (root-relative or absolute files/dirs) override the
    default roots; ``select`` is an iterable of rule ids to run
    exclusively; ``update_baseline`` rewrites the baseline from the
    current findings instead of failing on them.
    """
    t0 = time.time()
    root = pathlib.Path(root).resolve() if root else _repo_root()
    select_set = set(select) if select else None
    bl_path = pathlib.Path(baseline_path) if baseline_path \
        else root / DEFAULT_BASELINE

    errors: list[str] = []
    collected: list[tuple[Finding, tuple]] = []
    suppressed = 0
    files = _discover(root, paths)
    for path, rel in files:
        try:
            text = path.read_text()
            ctx = FileContext(path, rel, text)
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append(f"{rel}: unparseable ({e})")
            continue
        supp = Suppressions(text)
        for rule in rules_for(rel, select_set):
            try:
                found = list(rule.fn(ctx) or [])
            except Exception as e:  # noqa: BLE001 — a crashing rule
                errors.append(f"{rel}: rule {rule.id} crashed: "
                              f"{type(e).__name__}: {e}")
                continue
            for f in found:
                if supp.covers(f):
                    suppressed += 1
                else:
                    collected.append((f, fingerprint(f, ctx.lines)))

    # repo-level rules run once (markdown link integrity)
    if paths is None:
        for rule in RULES.values():
            if rule.kind != "repo" or \
                    (select_set is not None and rule.id not in select_set):
                continue
            try:
                for f in rule.fn(root) or []:
                    collected.append((f, (f.rule, f.path, "")))
            except Exception as e:  # noqa: BLE001
                errors.append(f"rule {rule.id} crashed: "
                              f"{type(e).__name__}: {e}")

    baseline = Baseline(bl_path if bl_path.exists() else None)
    errors.extend(baseline.errors)
    if update_baseline:
        Baseline.write(bl_path, collected, baseline.entries)
        active, baselined, stale = [], [f for f, _ in collected], []
    else:
        active, baselined, stale = baseline.partition(collected)

    active.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintReport(root=str(root), files=len(files), active=active,
                      baselined=baselined, suppressed=suppressed,
                      stale=stale, errors=errors,
                      duration_s=time.time() - t0)


def main(argv=None) -> int:
    """CLI entry for ``python -m repro.analysis`` / ``scripts/lint.py``."""
    ap = argparse.ArgumentParser(
        prog="jaxlint",
        description="repo-native static analysis: trace hygiene, PRNG "
        "discipline, donation safety, precision-policy conformance, and "
        "the api/docstring/doc-link gates")
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: the repo's "
                    "standard roots)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help=f"baseline file (default {DEFAULT_BASELINE} at "
                    "the repo root)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                    "(preserving existing justifications)")
    ap.add_argument("--select", default=None, metavar="IDS",
                    help="comma-separated rule ids to run exclusively")
    ap.add_argument("--root", default=None, metavar="DIR",
                    help="treat DIR as the repo root (testing)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES.values(), key=lambda r: r.id):
            scope = "all files" if rule.scope is None \
                else ", ".join(rule.scope)
            print(f"{rule.id} {rule.name}\n    {rule.help}\n"
                  f"    scope: {scope}")
        return 0

    select = [s.strip() for s in args.select.split(",")] \
        if args.select else None
    if select:
        unknown = [s for s in select if s not in RULES]
        if unknown:
            print(f"jaxlint: unknown rule id(s): {', '.join(unknown)}; "
                  f"known: {', '.join(sorted(RULES))}", file=sys.stderr)
            return 2

    report = run_lint(args.paths or None, root=args.root,
                      baseline_path=args.baseline, select=select,
                      update_baseline=args.update_baseline)

    if args.json:
        print(json.dumps(report.to_json(), indent=2))
    else:
        for f in report.active:
            print(f.render())
        for entry in report.stale:
            print(f"{entry.get('path')}: stale baseline entry "
                  f"({entry.get('rule')}: {str(entry.get('code'))[:60]!r}) "
                  "— the finding is gone, prune it from the baseline")
        for e in report.errors:
            print(f"error: {e}")
        print(f"jaxlint: {report.files} files, {len(RULES)} rules, "
              f"{len(report.active)} finding(s), "
              f"{len(report.baselined)} baselined, "
              f"{report.suppressed} suppressed, "
              f"{len(report.stale)} stale baseline entr(ies) "
              f"[{report.duration_s:.2f}s]")
    if report.errors:
        return 2 if not report.active else 1
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
