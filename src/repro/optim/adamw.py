"""AdamW optimizer (pure JAX, pytree states) with optional gradient
compression hook (int8 + error feedback) and global-norm clipping."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree
    ef: Optional[PyTree] = None     # error-feedback residual (compression)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    compress: Optional["GradTransform"] = None
    moment_dtype: Any = jnp.float32

    def init(self, params: PyTree, *, abstract: bool = False) -> AdamWState:
        def zero(leaf):
            if abstract:
                return jax.ShapeDtypeStruct(leaf.shape, self.moment_dtype)
            return jnp.zeros(leaf.shape, self.moment_dtype)
        step = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                else jnp.zeros((), jnp.int32))
        ef = None
        if self.compress is not None:
            ef = jax.tree.map(zero, params)
        return AdamWState(step=step, m=jax.tree.map(zero, params),
                          v=jax.tree.map(zero, params), ef=ef)

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> tuple[PyTree, AdamWState]:
        step = state.step + 1
        ef = state.ef
        if self.compress is not None:
            grads, ef = self.compress.apply(grads, ef)
        if self.clip_norm is not None:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        lr = self.lr(step) if callable(self.lr) else self.lr
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g32
            v = self.b2 * v + (1 - self.b2) * g32 * g32
            mh = m / b1c
            vh = v / b2c
            u = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:                      # decay matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamWState(step=step, m=m_new, v=v_new, ef=ef)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


class GradTransform:
    """Interface for gradient compression (see grad_compress.py)."""

    def apply(self, grads: PyTree, ef: PyTree
              ) -> tuple[PyTree, PyTree]:  # pragma: no cover - interface
        raise NotImplementedError
