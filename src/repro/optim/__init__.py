from .adamw import AdamW, AdamWState, apply_updates  # noqa: F401
from .grad_compress import Int8EF  # noqa: F401
from .schedule import cosine_with_warmup  # noqa: F401
