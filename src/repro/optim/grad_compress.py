"""Int8 gradient compression with error feedback.

Cross-pod gradient reduction is the dominant inter-pod collective for
data-parallel training. Quantizing gradients to int8 (per-tensor absmax
scale) before the reduction cuts those bytes 4x (bf16) / 2x (f32); the
quantization error is carried in an error-feedback buffer and re-added the
next step, which keeps SGD/Adam convergence (Seide et al. / EF-SGD).

Under GSPMD the reduction itself is implicit, so this transform models the
production path as quantize -> dequantize around the gradient use, with the
EF state threaded through the optimizer. The collective-byte savings are
counted in the roofline analysis (benchmarks/roofline.py) as a
bytes-on-the-"pod"-axis reduction factor.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .adamw import GradTransform

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Int8EF(GradTransform):
    """Per-tensor absmax int8 quantization with error feedback."""

    def apply(self, grads: PyTree, ef: PyTree) -> tuple[PyTree, PyTree]:
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq.astype(g.dtype), (g32 - deq)
        out = jax.tree.map(one, grads, ef)
        new_grads = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_grads, new_ef

    # roofline accounting: bytes multiplier vs bf16 gradients
    BYTES_FACTOR = 0.5
