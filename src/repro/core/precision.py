"""The ONE precision policy for the numeric stack.

Before this module, dtype choices were scattered as ad-hoc casts:
``run_trials`` forced ``float32`` pools, ``trial_uniforms`` drew f32,
``tables.py`` cast device inputs to ``jnp.float32`` while keeping f64 on
the numpy path, the sweep estimator picked f64-off-TPU inside
``plan._x64_sweep_programs``, and the ``segment_stats`` kernel hardcoded
f32 accumulation. ``PrecisionPolicy`` replaces all of those with one
explicit, threadable object of three dtypes:

* ``trace`` — the dtype traced device programs compute in (uniform
  draws, gathers, per-trial estimates). f32 by default: it is what the
  TPU kernels run natively.
* ``accum`` — the dtype streaming accumulators carry (error-moment
  sums in the chunked trial scan). f32 by default; the
  coverage-calibration gate in ``tests/test_streaming_trials.py`` proves
  f32 accumulators do not degrade empirical coverage at 10^5+ trials
  (the load-bearing counters — coverage, histogram sketches — are
  integers and therefore exact in any accumulator dtype).
* ``host`` — the dtype host-side (numpy) statistics use. f64: the
  scalar-parity reference path.

Policies are frozen, hashable (usable as ``lru_cache``/``jit`` static
keys) and carry dtypes as canonical numpy names so equality is by value.
Jax is imported lazily: constructing a policy never initializes device
state (``host_parity`` and ``x64_context`` touch jax on use only).
"""

from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

__all__ = ["PrecisionPolicy", "DEFAULT_PRECISION", "resolve_precision"]

_ALLOWED = ("float32", "float64")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Trace / accumulator / host dtype triple for one numeric pipeline."""

    trace: str = "float32"   # traced device programs (kernels, trial math)
    accum: str = "float32"   # streaming accumulators (chunked scan carry)
    host: str = "float64"    # host-side numpy statistics (parity path)

    def __post_init__(self):
        for field in ("trace", "accum", "host"):
            name = np.dtype(getattr(self, field)).name
            if name not in _ALLOWED:
                raise ValueError(
                    f"PrecisionPolicy.{field} must be one of {_ALLOWED}, "
                    f"got {getattr(self, field)!r}")
            object.__setattr__(self, field, name)

    # dtype views -----------------------------------------------------------
    @property
    def trace_dtype(self) -> np.dtype:
        return np.dtype(self.trace)

    @property
    def accum_dtype(self) -> np.dtype:
        return np.dtype(self.accum)

    @property
    def host_dtype(self) -> np.dtype:
        return np.dtype(self.host)

    @property
    def needs_x64(self) -> bool:
        """Whether traced programs under this policy require 64-bit jax."""
        return "float64" in (self.trace, self.accum)

    def x64_context(self):
        """Context manager enabling jax x64 iff this policy needs it.

        Device programs run under ``with policy.x64_context():`` so a
        64-bit trace/accumulator request actually computes in f64
        (outside the context jax silently truncates to f32).
        """
        if not self.needs_x64:
            return contextlib.nullcontext()
        from jax.experimental import enable_x64
        return enable_x64(True)

    # canonical policies ----------------------------------------------------
    @classmethod
    def default(cls) -> "PrecisionPolicy":
        """The trial-path production policy: f32 trace/accum, f64 host."""
        return cls()

    @classmethod
    def host_parity(cls) -> "PrecisionPolicy":
        """The sweep-estimate policy: trace in the host dtype off-TPU so
        on-device estimates match the numpy reference bitwise (f64 on CPU
        hosts), f32 trace on TPU where f64 is emulated and the parity
        tolerance widens instead (``benchmarks/run.py``)."""
        import jax
        if jax.default_backend() == "tpu":
            return cls(trace="float32", accum="float32", host="float64")
        return cls(trace="float64", accum="float64", host="float64")


DEFAULT_PRECISION = PrecisionPolicy()


def resolve_precision(precision: PrecisionPolicy | None,
                      *fallbacks: PrecisionPolicy | None) -> PrecisionPolicy:
    """First non-None of (precision, *fallbacks, DEFAULT_PRECISION)."""
    for p in (precision,) + fallbacks:
        if p is not None:
            return p
    return DEFAULT_PRECISION
