# The paper's primary contribution: two-phase stratified sampling for
# simulation-region selection, with analytically sound confidence intervals.
from . import clustering, sampling  # noqa: F401
from .features import RFV_METRICS, build_rfv  # noqa: F401
