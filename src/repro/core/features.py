"""Rich Feature Vector (RFV) construction (paper Section III.B, Table III).

An RFV is the per-region vector of CPI plus microarchitectural counters
(cache misses, branch mispredicts, top-down stall bins, ...) measured on the
*baseline* configuration during phase 1. Counters are normalized per
kilo-instruction so region length never enters, then z-standardized before
k-means (paper IV.B).
"""

from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

# Table III metric names (38 total): 1 global + 5 frontend + 5 LSU + 3 L2 +
# 3 L3 + 21 top-down stall bins.
FRONTEND_EVENTS = (
    "branch_mispredicts", "cond_branch_mispredicts",
    "target_branch_mispredicts", "icache_misses", "itlb_misses",
)
LSU_EVENTS = (
    "l1d_access", "l1d_load_miss", "l1d_store_miss",
    "l1d_total_miss", "l1d_writeback",
)
L2_EVENTS = ("l2_misses", "l2_load_misses", "l2_writebacks")
L3_EVENTS = ("l3_read_accesses", "l3_write_accesses", "l3_misses")
STALL_BINS = tuple(f"stall_bin_{i:02d}" for i in range(21))

RFV_METRICS: tuple[str, ...] = (
    ("cpi",) + FRONTEND_EVENTS + LSU_EVENTS + L2_EVENTS + L3_EVENTS + STALL_BINS
)
assert len(RFV_METRICS) == 38, len(RFV_METRICS)


def build_rfv(stats: Mapping[str, np.ndarray],
              metrics: Sequence[str] = RFV_METRICS) -> np.ndarray:
    """Stack per-region metric arrays into an (n_regions, n_metrics) matrix.

    ``stats`` maps metric name -> (n_regions,) array (already rate-
    normalized by the simulator). Missing metrics raise — a truncated RFV
    silently degrades stratification quality.
    """
    cols = []
    n = None
    for m in metrics:
        if m not in stats:
            raise KeyError(f"RFV metric {m!r} missing from simulator stats")
        col = np.asarray(stats[m], dtype=np.float64).reshape(-1)
        if n is None:
            n = col.shape[0]
        elif col.shape[0] != n:
            raise ValueError(f"metric {m!r} length {col.shape[0]} != {n}")
        cols.append(col)
    return np.stack(cols, axis=1)
