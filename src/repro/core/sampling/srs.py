"""Simple random sampling (Appendix A, Section A; Cochran Ch. 2).

Estimators (paper eq. 2):
    ybar = (1/n) sum y_i
    s^2  = (1/(n-1)) sum (y_i - ybar)^2
    v(ybar) = s^2 / n           [without-replacement fpc optional]

For n < 30 the t-distribution with df = n-1 is used for the interval.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .types import Estimate, as_float_array

__all__ = [
    "srs_estimate",
    "srs_required_n",
    "draw_srs",
]



def srs_estimate(
    y,
    *,
    confidence: float = 0.95,
    population_size: Optional[int] = None,
    use_fpc: bool = False,
) -> Estimate:
    """Estimate the population mean from a simple random sample ``y``.

    ``use_fpc`` applies the finite-population correction (1 - n/N); the paper
    samples a negligible fraction of each application's regions so its
    formulas omit it, and we default to matching the paper.
    """
    arr = as_float_array(y)
    n = int(arr.size)
    if n < 1:
        raise ValueError("need at least one observation")
    mean = float(arr.mean())
    if n == 1:
        var_units = float("nan")
        v_mean = float("inf")
    else:
        var_units = float(arr.var(ddof=1))
        v_mean = var_units / n
        if use_fpc and population_size is not None and population_size > 0:
            v_mean *= max(0.0, 1.0 - n / population_size)
    df = float(n - 1) if n < 30 else None
    return Estimate(
        mean=mean, variance=v_mean, n=n, df=df,
        confidence=confidence, scheme="srs",
    )


def srs_required_n(
    pilot_y,
    *,
    target_margin_pct: float,
    confidence: float = 0.95,
    max_n: int = 10**9,
) -> int:
    """Sample size needed for a target relative margin of error.

    Uses the pilot sample's variance (the paper's Step 1 note: "start small,
    estimate variance, then scale to meet a target confidence").
    """
    from .types import critical_value

    arr = as_float_array(pilot_y)
    if arr.size < 2:
        raise ValueError("pilot needs >= 2 observations")
    s2 = float(arr.var(ddof=1))
    mean = float(arr.mean())
    if mean == 0.0:
        raise ValueError("pilot mean is zero; relative margin undefined")
    z = critical_value(confidence, None)
    target_abs = abs(mean) * target_margin_pct / 100.0
    n = int(np.ceil(z * z * s2 / (target_abs * target_abs)))
    return int(min(max(n, 2), max_n))


def draw_srs(rng: np.random.Generator, population_size: int, n: int) -> np.ndarray:
    """Indices of a without-replacement simple random sample."""
    if n > population_size:
        raise ValueError(f"sample size {n} exceeds population {population_size}")
    return rng.choice(population_size, size=n, replace=False)
