"""Array-native stratified statistics: the ``StratumTables`` engine.

The scalar estimators in this package (``stratified.py``, ``two_phase.py``,
``collapsed.py``, ``allocation.py``) are one-lane views over this module:
a ``StratumTables`` holds the per-stratum *sufficient statistics* —
counts, sums, sums of squares and population weights — as ``(..., L)``
arrays with arbitrary leading batch axes (apps, trials, configs, ...),
and every estimator of the paper's Appendix A maps those tables to
batched results lane-wise:

* eq. (3)  stratified mean / variance       — ``stratified_mean/variance``
* eq. (5)/(6) two-phase variance            — ``two_phase_variance``
* Satterthwaite effective df [30]           — ``satterthwaite_df``
* eq. (4)  pairwise collapsed strata        — ``collapsed_pairs_variance``
* fn. 7    small-stratum collapse           — ``collapse_small_strata``
* Cochran 5.5-5.9 allocation                — ``neyman/proportional_allocation``

All estimator functions are *namespace-agnostic*: they run on numpy
arrays (host, float64 — the exact scalar-parity path) and on jnp arrays
or tracers (device, inside ``jit`` — the Monte-Carlo hot path) with the
same code. Degenerate lanes never raise inside the batched functions —
they produce NaN lane-wise, and the scalar wrappers translate NaN into
the package's documented NaN/warn/raise ``strict=`` contract
(``docs/statistics.md``).

Construction routes through the ``segment_stats`` kernel
(``repro.kernels.segment_stats``) on device backends — one batch-native
dispatch for any leading axes — and through an exact float64 bincount on
the numpy path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..precision import DEFAULT_PRECISION, PrecisionPolicy, resolve_precision

__all__ = [
    "StratumTables",
    "stratum_tables",
    "tables_from_summaries",
    "sweep_point_tables",
    "covered_weight",
    "total_weight",
    "stratified_mean",
    "stratified_variance",
    "satterthwaite_df",
    "two_phase_variance",
    "collapse_small_strata",
    "collapsed_pairs_variance",
    "proportional_allocation",
    "neyman_allocation",
    "masked_srs_stats",
    # streaming trial statistics (the chunked Monte-Carlo accumulator)
    "TRIAL_HIST_BINS",
    "TRIAL_HIST_LO",
    "TRIAL_HIST_HI",
    "TrialStats",
    "trial_stats_init",
    "trial_stats_update",
    "trial_stats_merge",
    "log_hist_quantile",
]



def _ns(*arrays):
    """numpy or jax.numpy, picked from the argument types (tracers are
    ``jax.Array`` instances, so jitted callers get jnp)."""
    return jnp if any(isinstance(a, jax.Array) for a in arrays) else np


def _argsort(xp, a):
    """Stable argsort in either namespace (jnp's sort is always stable)."""
    return np.argsort(a, axis=-1, kind="stable") if xp is np \
        else jnp.argsort(a, axis=-1)


# --------------------------------------------------------------- the pytree
@dataclasses.dataclass(frozen=True)
class StratumTables:
    """Masked per-stratum sufficient statistics with leading batch axes.

    Every stratum leaf is ``(..., L)``; the leading axes are shared batch
    axes (one lane = one stratified design). ``counts[..., h] == 0``
    marks an empty stratum — means/variances are NaN there, and the
    estimators treat the lane according to the coverage contract.

    ``sums``/``sumsqs`` hold *shifted* moments: moments of ``y − shift``
    for a per-lane offset ``shift`` (the standard stability trick —
    variances computed from raw moments suffer catastrophic cancellation
    when ``|ȳ| ≫ s``). Constructors center on the lane sample mean;
    ``shift = 0`` recovers plain moments, so hand-built tables work
    unchanged. Registered as a jax pytree so tables can cross
    ``jit``/``vmap``/``shard_map`` boundaries.
    """

    counts: np.ndarray | jax.Array     # (..., L) units sampled per stratum
    sums: np.ndarray | jax.Array       # (..., L) sum of (y - shift)
    sumsqs: np.ndarray | jax.Array     # (..., L) sum of (y - shift)^2
    weights: np.ndarray | jax.Array    # (..., L) population weights W_h
    shift: np.ndarray | jax.Array | float = 0.0   # (...) per-lane offset

    @property
    def num_strata(self) -> int:
        """L, the trailing stratum axis length."""
        return int(self.counts.shape[-1])

    @property
    def batch_shape(self) -> tuple[int, ...]:
        """The leading batch axes (``()`` for a single design)."""
        return tuple(self.counts.shape[:-1])

    def _shift_col(self, xp):
        """The shift broadcast against the trailing stratum axis."""
        return xp.asarray(self.shift)[..., None]

    @property
    def means(self):
        """(..., L) stratum sample means ȳ_h; NaN where n_h == 0."""
        xp = _ns(self.counts, self.sums)
        safe = xp.maximum(self.counts, 1.0)
        return xp.where(self.counts > 0,
                        self._shift_col(xp) + self.sums / safe, xp.nan)

    @property
    def variances(self):
        """(..., L) within-stratum sample variances s_h² (ddof=1, eq. 2);
        NaN where n_h < 2. Shift-invariant (computed on the centered
        moments)."""
        xp = _ns(self.counts, self.sums)
        safe = xp.maximum(self.counts, 1.0)
        mean = self.sums / safe
        ss = self.sumsqs - self.counts * mean * mean
        return xp.where(self.counts > 1,
                        ss / xp.maximum(self.counts - 1.0, 1.0), xp.nan)

    def lane(self, index) -> "StratumTables":
        """The single-design view at ``index`` of the leading axes."""
        shift = self.shift[index] if np.ndim(self.shift) else self.shift
        return StratumTables(self.counts[index], self.sums[index],
                             self.sumsqs[index], self.weights[index],
                             shift)


jax.tree_util.register_pytree_node(
    StratumTables,
    lambda t: ((t.counts, t.sums, t.sumsqs, t.weights, t.shift), None),
    lambda _, leaves: StratumTables(*leaves))


# ------------------------------------------------------------- construction
def stratum_tables(
    y,
    labels,
    *,
    weights=None,
    num_strata: Optional[int] = None,
    valid=None,
    backend: str = "numpy",
    validate: bool = True,
    precision: Optional[PrecisionPolicy] = None,
) -> StratumTables:
    """Build ``StratumTables`` from samples + stratum labels, batched.

    Args:
      y: study values, ``(..., n)`` (leading axes = batch lanes).
      labels: int stratum ids aligned with ``y``; negative ids mark
        masked entries.
      weights: population stratum weights W_h — ``(L,)`` shared or
        ``(..., L)`` per-lane. Defaults to the *sample* proportions per
        lane (valid for proportional allocation / post-stratification).
      num_strata: L. Required when ``weights`` is omitted and the label
        range does not determine it; defaults to ``weights.shape[-1]``.
      valid: optional bool mask aligned with ``y`` (ANDed with
        ``labels >= 0``).
      backend: ``"numpy"`` — exact host path in the policy's host dtype
        (the scalar-parity reference); ``"auto"``/``"pallas"``/``"jnp"``
        — the ``segment_stats`` kernel contract (kernel on TPU, jnp
        oracle off-TPU) computing in the policy's trace dtype.
      validate: check label range and weight normalization (numpy path
        only; device paths are jit-safe and skip data-dependent checks).
      precision: the ``PrecisionPolicy`` governing dtypes on both paths
        (default: ``DEFAULT_PRECISION`` — f32 trace, f64 host).
    """
    pp = resolve_precision(precision)
    if backend == "numpy":
        return _stratum_tables_np(y, labels, weights=weights,
                                  num_strata=num_strata, valid=valid,
                                  validate=validate, dtype=pp.host_dtype)
    from repro.kernels.segment_stats.ops import segment_stats

    dt = pp.trace_dtype
    labels = jnp.asarray(labels, jnp.int32)
    y = jnp.asarray(y, dt)
    if valid is not None:
        labels = jnp.where(jnp.asarray(valid, bool), labels, -1)
    if num_strata is None:
        if weights is None:
            raise ValueError("device backends need num_strata (or weights) "
                             "— the label range is not traceable")
        num_strata = np.shape(weights)[-1]
    L = int(num_strata)
    # shifted moments on device too: center on the per-lane valid mean so
    # float32 sumsqs keep significant bits when |ȳ| ≫ s (the masked rows
    # carry label -1 and contribute nothing either way)
    ok = (labels >= 0) & (labels < L)
    n_ok = jnp.maximum(ok.sum(axis=-1), 1).astype(dt)
    shift = jnp.where(ok, y, 0.0).sum(axis=-1) / n_ok
    sums, sumsqs, counts = segment_stats(y - shift[..., None], labels, L,
                                         backend=backend, precision=pp)
    sums, sumsqs = sums[..., 0], sumsqs[..., 0]
    if weights is None:
        total = jnp.maximum(counts.sum(axis=-1, keepdims=True), 1.0)
        w = counts / total
    else:
        w = jnp.broadcast_to(jnp.asarray(weights, dt), counts.shape)
    return StratumTables(counts=counts, sums=sums, sumsqs=sumsqs, weights=w,
                         shift=shift)


def _stratum_tables_np(y, labels, *, weights, num_strata, valid,
                       validate, dtype=np.float64) -> StratumTables:
    """Exact host constructor (vectorized offset-bincount) in the policy's
    host dtype (float64 by default — the scalar-parity reference)."""
    yv = np.asarray(y, dtype)
    lab = np.asarray(labels)
    if yv.shape != lab.shape:
        raise ValueError(f"y shape {yv.shape} != labels shape {lab.shape}")
    ok = lab >= 0
    if valid is not None:
        ok = ok & np.asarray(valid, bool)
    if num_strata is not None:
        L = int(num_strata)
    elif weights is not None:
        L = int(np.shape(weights)[-1])
    else:
        L = int(lab[ok].max() + 1) if ok.any() else 0
    if validate and ok.any() and lab[ok].max() >= L:
        raise ValueError(f"label {int(lab[ok].max())} out of range for "
                         f"num_strata={L}")
    ok = ok & (lab < L)      # kernel semantics: out-of-range rows drop

    batch_shape = yv.shape[:-1]
    n = yv.shape[-1] if yv.ndim else 0
    b = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    lab2 = lab.reshape(b, n)
    ok2 = ok.reshape(b, n)
    # center on the per-lane sample mean (shifted moments: keeps the
    # variance free of the sumsq - n·mean² cancellation when |ȳ| ≫ s)
    n_ok = np.maximum(ok2.sum(axis=1), 1)
    shift = np.where(ok2, yv.reshape(b, n), 0.0).sum(axis=1) / n_ok
    yc = yv.reshape(b, n) - shift[:, None]
    # flat segment ids: lane i owns [i*L, (i+1)*L); invalid rows dump into
    # one trailing slot that is dropped after the bincount
    flat = np.where(ok2, lab2 + L * np.arange(b)[:, None], b * L)
    yz = np.where(ok2, yc, 0.0)
    counts = np.bincount(flat.ravel(), minlength=b * L + 1)[:-1]
    sums = np.bincount(flat.ravel(), weights=yz.ravel(),
                       minlength=b * L + 1)[:-1]
    sumsqs = np.bincount(flat.ravel(), weights=(yz * yz).ravel(),
                         minlength=b * L + 1)[:-1]
    counts = counts.astype(np.float64).reshape(*batch_shape, L)
    sums = sums.reshape(*batch_shape, L)
    sumsqs = sumsqs.reshape(*batch_shape, L)
    shift = shift.reshape(batch_shape)

    if weights is None:
        total = np.maximum(counts.sum(axis=-1, keepdims=True), 1.0)
        w = counts / total
    else:
        wa = np.asarray(weights, np.float64)
        if wa.shape[-1:] != (L,):
            raise ValueError(
                f"weights length {wa.shape[-1] if wa.ndim else 0} != "
                f"num strata {L}")
        w = np.broadcast_to(wa, counts.shape).copy()
        if validate:
            tot = w.sum(axis=-1)
            if not np.allclose(tot, 1.0, atol=1e-6):
                raise ValueError(
                    f"stratum weights sum to {np.asarray(tot).ravel()[:8]}, "
                    "expected 1")
    return StratumTables(counts=counts, sums=sums, sumsqs=sumsqs, weights=w,
                         shift=shift)


def tables_from_summaries(summaries: Sequence) -> StratumTables:
    """One-lane tables from a ``list[StratumSummary]`` (the scalar bridge).

    Inverts the mean/variance back to *shifted* sums/sums-of-squares —
    centered on the mean of the occupied stratum means — so the scalar
    wrappers can reuse the batched estimators without reintroducing the
    ``sumsq − n·mean²`` cancellation: for n ≥ 1, ``sum = n·(ȳ − c)`` and
    ``sumsq = (n−1)·s² + n·(ȳ − c)²``.
    """
    counts = np.array([s.n for s in summaries], np.float64)
    means = np.array([s.mean if s.n > 0 else 0.0 for s in summaries],
                     np.float64)
    variances = np.array(
        [s.var if s.n > 1 and np.isfinite(s.var) else 0.0 for s in summaries],
        np.float64)
    weights = np.array([s.weight for s in summaries], np.float64)
    occupied = counts > 0
    shift = float(means[occupied].mean()) if occupied.any() else 0.0
    centered = np.where(occupied, means - shift, 0.0)
    sums = counts * centered
    sumsqs = np.maximum(counts - 1.0, 0.0) * variances \
        + counts * centered ** 2
    return StratumTables(counts=counts, sums=sums, sumsqs=sumsqs,
                         weights=weights, shift=shift)


def sweep_point_tables(cpi, valid, weights) -> StratumTables:
    """``StratumTables`` for a one-unit-per-stratum sweep, lane-wise.

    ``cpi``: (A, C, L) per-stratum selected-unit CPI; ``valid``: (A, L)
    pick validity; ``weights``: (A, L) stratum weights. Lanes are
    (app, config): each occupied stratum holds exactly its one selected
    unit — counts ARE the validity mask — so ``stratified_mean`` reduces
    to the covered-weight-renormalized weighted mean the sweep reports.

    This is the sweep estimators' fusable tables stage: counts come from
    the pick mask directly, with no ``segment_stats`` dispatch (each
    stratum contributes one known unit — there is nothing to segment;
    see ``docs/kernels.md``). Namespace-agnostic: numpy in the host
    path, tracers inside the staged jitted program and the fused sweep
    megaprogram alike.
    """
    xp = _ns(cpi, valid, weights)
    counts = xp.broadcast_to(valid[:, None, :], cpi.shape).astype(cpi.dtype)
    return StratumTables(
        counts=counts, sums=xp.where(counts > 0, cpi, 0.0),
        sumsqs=xp.zeros_like(cpi),
        weights=xp.broadcast_to(weights[:, None, :], cpi.shape))


# -------------------------------------------------------------- estimators
def covered_weight(tables: StratumTables):
    """(...) total weight of strata with at least one sampled unit."""
    xp = _ns(tables.counts)
    return xp.where(tables.counts > 0, tables.weights, 0.0).sum(axis=-1)


def total_weight(tables: StratumTables):
    """(...) total stratum weight per lane (≈ 1 for normalized designs)."""
    return tables.weights.sum(axis=-1)


def stratified_mean(tables: StratumTables, *, renormalize: bool = True):
    """Batched eq. (3) point estimate ``ȳ_st = Σ_h W_h ȳ_h``, lane-wise.

    Strata with no sampled units contribute nothing. With
    ``renormalize=True`` (the coverage-contract default) the sum is
    divided by the covered weight, matching ``weighted_point_estimate``;
    with ``renormalize=False`` the lost weight simply vanishes (the
    Fig 8 Monte-Carlo estimator's semantics). Lanes with no covered
    weight at all are NaN.
    """
    xp = _ns(tables.counts, tables.sums)
    term = xp.where(tables.counts > 0,
                    tables.weights * tables.means, 0.0)
    est = term.sum(axis=-1)
    cov = covered_weight(tables)
    if renormalize:
        est = est / xp.where(cov > 0, cov, 1.0)
    return xp.where(cov > 0, est, xp.nan)


def stratified_variance(tables: StratumTables, *, renormalize: bool = True):
    """Batched eq. (3) variance ``v(ȳ_st) = Σ_h W_h² s_h² / n_h``.

    Lane-wise NaN when any stratum with positive weight and sampled
    units has n_h < 2 (s_h² is not estimable — paper fn. 7; collapse
    first). Uncovered strata (n_h = 0) are renormalized away under
    ``renormalize=True``; callers wanting the strict interpretation
    check coverage separately (see the scalar wrappers).
    """
    xp = _ns(tables.counts)
    w = tables.weights
    if renormalize:
        cov = covered_weight(tables)[..., None]
        w = xp.where(tables.counts > 0,
                     w / xp.where(cov > 0, cov, 1.0), 0.0)
    s2 = tables.variances
    occupied = tables.counts > 0
    contrib = xp.where(occupied & (w > 0),
                       (w ** 2) * s2 / xp.maximum(tables.counts, 1.0), 0.0)
    v = contrib.sum(axis=-1)
    bad = (occupied & (tables.weights > 0)
           & (tables.counts < 2)).any(axis=-1)
    return xp.where(bad | (covered_weight(tables) <= 0), xp.nan, v)


def satterthwaite_df(tables: StratumTables):
    """Batched Satterthwaite [30] effective degrees of freedom, lane-wise.

    Strata with n_h < 2 or zero weight are excluded (as in the scalar
    reference); lanes whose denominator is zero get +inf (z interval).
    The statistic is invariant to weight renormalization.
    """
    xp = _ns(tables.counts)
    usable = (tables.counts > 1) & (tables.weights > 0)
    g = xp.where(usable,
                 (tables.weights ** 2) * xp.where(usable, tables.variances,
                                                  0.0)
                 / xp.maximum(tables.counts, 1.0), 0.0)
    num = g.sum(axis=-1)
    den = xp.where(usable, g * g / xp.maximum(tables.counts - 1.0, 1.0),
                   0.0).sum(axis=-1)
    return xp.where(den > 0, num * num / xp.where(den > 0, den, 1.0), xp.inf)


def two_phase_variance(tables: StratumTables, phase1_n, *,
                       formula: str = "phase2_only", phase1_var=None,
                       renormalize: bool = True):
    """Batched two-phase variance — paper eq. (5)/(6), lane-wise.

    ``formula="with_phase1_var"`` is eq. (5): ``s²/n' + Σ W_h² s_h²/n_h``
    and needs ``phase1_var`` (broadcastable to the lane shape).
    ``formula="phase2_only"`` is eq. (6): the phase-1 term is the
    between-stratum spread ``(1/n') Σ W_h (ȳ_h − ȳ)²`` — computable
    without phase-1 y values. ``phase1_n`` may be a scalar or an array
    broadcastable to the lane shape.
    """
    xp = _ns(tables.counts)
    v2 = stratified_variance(tables, renormalize=renormalize)
    if formula == "with_phase1_var":
        if phase1_var is None:
            raise ValueError("eq. (5) needs phase1_var")
        v1 = xp.asarray(phase1_var) / phase1_n
        return v1 + v2
    if formula != "phase2_only":
        raise ValueError(f"unknown formula {formula!r}")
    mean = stratified_mean(tables, renormalize=renormalize)
    w = tables.weights
    if renormalize:
        cov = covered_weight(tables)[..., None]
        w = xp.where(tables.counts > 0,
                     w / xp.where(cov > 0, cov, 1.0), 0.0)
    dev = tables.means - mean[..., None]
    between = xp.where(tables.counts > 0, w * dev * dev, 0.0).sum(axis=-1)
    return between / phase1_n + v2


# ------------------------------------------------- collapse (fn. 7, eq. 4)
def collapse_small_strata(tables: StratumTables, order_key, *,
                          min_count: float = 2):
    """Merge under-sampled strata into their key-order neighbor, lane-wise.

    Replicates ``TwoPhaseFlow.ci_check``'s host algorithm exactly, per
    lane: strata are ordered by ``order_key`` (e.g. baseline-CPI stratum
    means); strata with zero weight and no samples are dropped; walking
    the order, each stratum either closes a group (count ≥ min_count),
    joins the still-open group, or — when undersized after a closed
    group — merges backward into it; a trailing undersized group merges
    backward too. Returns ``(merged, group_of, n_groups)``: merged
    ``StratumTables`` whose group g occupies slot g (trailing slots are
    zero), the per-stratum group assignment (−1 = dropped), and the
    per-lane group count (0 marks a degenerate lane with < min_count
    total samples — estimates there are NaN).
    """
    xp = _ns(tables.counts)
    L = tables.num_strata
    counts, weights = tables.counts, tables.weights
    active = (weights > 0) | (counts > 0)
    key = xp.where(active,
                   xp.broadcast_to(xp.asarray(order_key, counts.dtype),
                                   counts.shape), xp.inf)
    order = _argsort(xp, key)
    c_s = xp.take_along_axis(counts, order, axis=-1)
    a_s = xp.take_along_axis(active, order, axis=-1)

    batch = counts.shape[:-1]
    gid = xp.zeros(batch, dtype=int) - 1
    acc = xp.zeros(batch, dtype=counts.dtype)
    slots = []
    for p in range(L):
        act = a_s[..., p]
        c = c_s[..., p]
        no_grp = gid < 0
        open_ = acc < min_count
        start = act & ((no_grp) | (~open_ & (c >= min_count)))
        gid = xp.where(start, gid + 1, gid)
        acc = xp.where(start, c, xp.where(act, acc + c, acc))
        slots.append(xp.where(act, gid, -1))
    g_sorted = xp.stack(slots, axis=-1)
    # a group with gid > 0 only ever starts on a stratum with
    # c >= min_count, so only group 0 can end undersized — that lane is
    # degenerate (ci_check: "needs at least 2 sampled units")
    n_groups = xp.where(gid < 0, 0, gid + 1)
    n_groups = xp.where((gid == 0) & (acc < min_count), 0, n_groups)

    inv = _argsort(xp, order)
    group_of = xp.take_along_axis(g_sorted, inv, axis=-1)

    onehot = (group_of[..., :, None] == xp.arange(L)).astype(counts.dtype)
    merged = StratumTables(
        counts=(counts[..., :, None] * onehot).sum(axis=-2),
        sums=(tables.sums[..., :, None] * onehot).sum(axis=-2),
        sumsqs=(tables.sumsqs[..., :, None] * onehot).sum(axis=-2),
        weights=(weights[..., :, None] * onehot).sum(axis=-2),
        shift=tables.shift)
    return merged, group_of, n_groups


def collapsed_pairs_variance(y_sorted, w_sorted, n_valid, *,
                             num_strata: int):
    """Batched pairwise collapsed-strata variance (paper eq. 4), lane-wise.

    Args:
      y_sorted: ``(..., L)`` — the single sampled value per stratum,
        gathered into key order with the ``n_valid`` occupied strata
        first (positions ≥ n_valid are ignored).
      w_sorted: stratum weights in the same order (broadcastable).
      n_valid: (...) occupied-stratum count V per lane (broadcastable).
      num_strata: L (static).

    Groups are neighbor pairs in the sorted order; an odd V makes the
    final three strata one group whose variance is their sample variance
    (exactly the scalar ``collapsed_strata_estimate`` grouping). Per
    pair, eq. (4): ``s² = (y₁ − y₂)²/4`` entering the stratified formula
    with n_h = 1. Returns ``(variance, df)`` — both NaN for lanes with
    V < 2; ``df = V − ⌊V/2⌋`` ([18]: L − J).
    """
    xp = _ns(y_sorted, w_sorted, n_valid)
    L = int(num_strata)
    v_cnt = xp.asarray(n_valid)
    n_groups = v_cnt // 2
    odd = (v_cnt % 2) == 1
    var = xp.zeros(xp.broadcast_shapes(
        xp.shape(y_sorted)[:-1], xp.shape(w_sorted)[:-1],
        xp.shape(v_cnt)), dtype=xp.asarray(y_sorted).dtype)
    for j in range(max(L // 2, 1)):
        p1, p2, p3 = 2 * j, 2 * j + 1, min(2 * j + 2, L - 1)
        if p2 >= L:
            break
        in_grp = j < n_groups
        has3 = odd & (j == n_groups - 1)
        y1, y2, y3 = (y_sorted[..., p] for p in (p1, p2, p3))
        w1, w2, w3 = (w_sorted[..., p] for p in (p1, p2, p3))
        s2_pair = (y1 - y2) ** 2 / 4.0
        m3 = (y1 + y2 + y3) / 3.0
        s2_tri = ((y1 - m3) ** 2 + (y2 - m3) ** 2 + (y3 - m3) ** 2) / 2.0
        s2 = xp.where(has3, s2_tri, s2_pair)
        wsq = w1 ** 2 + w2 ** 2 + xp.where(has3, w3 ** 2, 0.0)
        var = var + xp.where(in_grp, wsq * s2, 0.0)
    bad = v_cnt < 2
    var = xp.where(bad, xp.nan, var)
    df = xp.where(bad, xp.nan, (v_cnt - n_groups).astype(var.dtype))
    return var, df


# ------------------------------------------------------------- allocation
def proportional_allocation(weights, n_total, *, min_per_stratum: int = 2):
    """Batched proportional allocation: n_h ∝ W_h, each ≥ min_per_stratum.

    ``weights``: ``(..., L)``; ``n_total`` scalar or ``(...)``. Returns
    int allocations ``(..., L)`` using the same largest-remainder fixup
    as the scalar reference (overshoot accepted when minima force it).
    """
    xp = _ns(weights)
    # host lanes promote to f64 (the exact reference); device lanes keep
    # the caller's trace dtype (f32 default, f64 under an x64 policy)
    w = xp.asarray(weights, np.float64) if xp is np else xp.asarray(weights)
    nt = xp.asarray(n_total)
    raw = w * (nt[..., None] if nt.ndim else nt)
    n_h = xp.maximum(xp.floor(raw).astype(int), min_per_stratum)
    return _largest_remainder_fixup(n_h, raw, n_total)


def neyman_allocation(weights, stds, n_total, *, min_per_stratum: int = 2):
    """Batched Neyman allocation: n_h ∝ W_h·S_h (optimal for fixed n).

    Lanes whose W·S products are all zero fall back to proportional
    allocation (mirroring the scalar reference), lane-wise.
    """
    xp = _ns(weights, stds)
    w = xp.asarray(weights)
    s = xp.maximum(xp.asarray(stds), 0.0)
    prod = w * s
    tot = prod.sum(axis=-1, keepdims=True)
    zero = tot <= 0
    share = prod / xp.where(zero, 1.0, tot)
    nt = xp.asarray(n_total)
    raw = share * (nt[..., None] if nt.ndim else nt)
    n_h = xp.maximum(xp.floor(raw).astype(int), min_per_stratum)
    ney = _largest_remainder_fixup(n_h, raw, n_total)
    prop = proportional_allocation(w, n_total,
                                   min_per_stratum=min_per_stratum)
    return xp.where(zero, prop, ney)


def _largest_remainder_fixup(n_h, raw, n_total):
    """Lane-wise largest-remainder rounding to hit the n_total budget.

    Exactly the scalar rule: distribute the deficit one unit at a time
    in descending fractional-remainder order, wrapping around; a
    negative deficit (minima overshoot) is accepted.
    """
    xp = _ns(n_h, raw)
    L = n_h.shape[-1]
    deficit = (xp.asarray(n_total) - n_h.sum(axis=-1)).astype(int)
    deficit = xp.maximum(deficit, 0)
    frac = raw - xp.floor(raw)
    # rank 0 = largest remainder (stable, matching argsort of -frac)
    order = _argsort(xp, -frac)
    rank = _argsort(xp, order)
    extra = deficit[..., None] // L + (
        rank < (deficit[..., None] % L)).astype(int)
    return n_h + extra


# ------------------------------------------------------------- SRS helper
def masked_srs_stats(x, valid):
    """Lane-wise SRS sample mean and variance-of-the-mean (paper eq. 2).

    ``x``: ``(..., n)`` values; ``valid``: broadcastable bool mask.
    Returns ``(mean, v_mean, n)`` with ``v_mean = s²/n`` (ddof=1); lanes
    with n < 2 get NaN variance, n = 0 NaN mean.
    """
    xp = _ns(x)
    v = xp.broadcast_to(xp.asarray(valid, bool), xp.shape(x))
    n = v.sum(axis=-1).astype(xp.asarray(x).dtype)
    safe_n = xp.maximum(n, 1.0)
    mean = xp.where(v, x, 0.0).sum(axis=-1) / safe_n
    ss = xp.where(v, (x - mean[..., None]) ** 2, 0.0).sum(axis=-1)
    s2 = xp.where(n > 1, ss / xp.maximum(n - 1.0, 1.0), xp.nan)
    mean = xp.where(n > 0, mean, xp.nan)
    return mean, s2 / safe_n, n


# ----------------------------------------------- streaming trial statistics
# Log-histogram sketch grid shared by every TrialStats: 4096 bins over
# [1e-6, 1e6) gives ~0.68% relative resolution — far below the Monte-Carlo
# noise of any quantile read from it. Percent errors and absolute CI
# half-widths both live comfortably inside this range; out-of-range values
# clip into the edge bins.
TRIAL_HIST_BINS = 4096
TRIAL_HIST_LO = 1e-6
TRIAL_HIST_HI = 1e6
_HIST_LOG_LO = float(np.log(TRIAL_HIST_LO))
_HIST_LOG_SPAN = float(np.log(TRIAL_HIST_HI) - np.log(TRIAL_HIST_LO))


@dataclasses.dataclass(frozen=True)
class TrialStats:
    """Streaming-accumulable Monte-Carlo trial statistics, batched.

    Every leaf is *additive*: chunk updates, cross-chunk scan carries and
    cross-device ``psum`` merges are all elementwise sums, so any
    chunking or sharding of the trial axis accumulates to the same
    totals — bitwise for the integer leaves (trial counts, coverage
    counts, histogram sketches) and up to float summation order for the
    moment sums. Leading axes (``...``) are batch lanes (apps); per-trial
    ``T``-axis arrays never materialize.

    ``err_hist``/``half_hist`` are log-spaced histogram sketches over
    ``[TRIAL_HIST_LO, TRIAL_HIST_HI)``; quantile readouts (the Fig 8
    p95) come from ``log_hist_quantile``. Registered as a jax pytree so
    the stats ride a ``lax.scan`` carry and cross ``shard_map``
    boundaries.
    """

    count: np.ndarray | jax.Array      # (...,) valid trials accumulated
    cover: np.ndarray | jax.Array      # (...,) trials whose CI covered truth
    err_sum: np.ndarray | jax.Array    # (...,) Σ pct |error|   (accum dtype)
    err_sumsq: np.ndarray | jax.Array  # (...,) Σ pct |error|²
    half_n: np.ndarray | jax.Array     # (...,) trials with finite half-width
    half_sum: np.ndarray | jax.Array   # (...,) Σ CI half-width
    half_sumsq: np.ndarray | jax.Array  # (...,) Σ half-width²
    err_hist: np.ndarray | jax.Array   # (..., B) log-bucketed error counts
    half_hist: np.ndarray | jax.Array  # (..., B) log-bucketed half counts

    # host-side readouts -----------------------------------------------
    @property
    def coverage(self):
        """(...) empirical coverage: covered / valid trials (NaN if 0)."""
        xp = _ns(self.count)
        denom = xp.maximum(self.count, 1).astype(np.float64)
        return xp.where(self.count > 0, self.cover / denom, xp.nan)

    @property
    def err_mean(self):
        """(...) mean percent |error| over trials with finite error."""
        xp = _ns(self.count)
        n = self.err_hist.sum(axis=-1)
        return xp.where(n > 0, self.err_sum / xp.maximum(n, 1), xp.nan)

    @property
    def half_mean(self):
        """(...) mean CI half-width over trials with a finite interval
        (the streamed analogue of ``nanmean`` over per-trial widths)."""
        xp = _ns(self.count)
        return xp.where(self.half_n > 0,
                        self.half_sum / xp.maximum(self.half_n, 1), xp.nan)

    def err_quantile(self, q: float):
        """(...) q-quantile of percent |error| from the sketch (host)."""
        return log_hist_quantile(self.err_hist, q)

    def half_quantile(self, q: float):
        """(...) q-quantile of the CI half-width from the sketch (host)."""
        return log_hist_quantile(self.half_hist, q)


jax.tree_util.register_pytree_node(
    TrialStats,
    lambda s: ((s.count, s.cover, s.err_sum, s.err_sumsq, s.half_n,
                s.half_sum, s.half_sumsq, s.err_hist, s.half_hist), None),
    lambda _, leaves: TrialStats(*leaves))


def trial_stats_init(batch_shape, *, bins: int = TRIAL_HIST_BINS,
                     accum_dtype=None, xp=np) -> TrialStats:
    """Zeroed accumulator for ``batch_shape`` lanes (the scan carry init).

    ``accum_dtype`` is the float-moment dtype, defaulting to the
    policy's ``PrecisionPolicy.accum``; the counters and sketches are
    int32 regardless — they are exact in any policy.
    """
    if accum_dtype is None:
        accum_dtype = DEFAULT_PRECISION.accum_dtype
    bs = tuple(batch_shape)
    zi = xp.zeros(bs, np.int32)
    zf = xp.zeros(bs, accum_dtype)
    zh = xp.zeros(bs + (int(bins),), np.int32)
    return TrialStats(count=zi, cover=zi, err_sum=zf, err_sumsq=zf,
                      half_n=zi, half_sum=zf, half_sumsq=zf,
                      err_hist=zh, half_hist=zh)


def _log_bucket(x, xp, bins: int):
    """Histogram bin index of ``x`` on the shared log grid (clipped)."""
    pos = xp.isfinite(x) & (x > 0)
    safe = xp.where(pos, x, TRIAL_HIST_LO)
    b = xp.floor((xp.log(safe) - _HIST_LOG_LO) * (bins / _HIST_LOG_SPAN))
    return xp.clip(b, 0, bins - 1).astype(np.int32)


def _hist_add(hist, values, mask, xp):
    """``hist + histogram(values[mask])`` lane-wise, namespace-agnostic.

    Lanes are flattened into one offset-bincount / scatter-add so a whole
    chunk folds in with a single dispatch (mirrors the flat-segment trick
    of ``_stratum_tables_np``).
    """
    bins = hist.shape[-1]
    lead = hist.shape[:-1]
    lanes = int(np.prod(lead, dtype=np.int64)) if lead else 1
    t = values.shape[-1]
    idx = _log_bucket(values, xp, bins).reshape(lanes, t)
    flat = (idx + bins * xp.arange(lanes, dtype=np.int32)[:, None]).reshape(-1)
    w = xp.broadcast_to(mask, values.shape).reshape(-1).astype(np.int32)
    if xp is np:
        add = np.bincount(flat, weights=w,
                          minlength=lanes * bins).astype(np.int32)
    else:
        add = jnp.zeros(lanes * bins, jnp.int32).at[flat].add(w)
    return hist + add.reshape(hist.shape)


def trial_stats_update(stats: TrialStats, err, half, covered,
                       valid) -> TrialStats:
    """Fold one chunk of per-trial outcomes into the running statistics.

    ``err``/``half`` are ``(..., Tc)`` per-trial chunk outcomes,
    ``covered`` the per-trial CI-covers-truth booleans, and ``valid``
    a broadcastable mask dropping padding trials (the chunk grid rounds
    the trial count up). Float moments are cast to the accumulator dtype
    *before* summing; counters stay int32 (exact, order-independent —
    the bitwise half of the chunked == unchunked contract).
    """
    xp = _ns(stats.count, err)
    v = xp.broadcast_to(xp.asarray(valid, bool), err.shape)
    acc = stats.err_sum.dtype
    err_ok = v & xp.isfinite(err)
    half_ok = v & xp.isfinite(half)

    def moments(x, m):
        xc = xp.where(m, x, 0).astype(acc)
        return xc.sum(axis=-1), (xc * xc).sum(axis=-1)

    err_s, err_ss = moments(err, err_ok)
    half_s, half_ss = moments(half, half_ok)
    return TrialStats(
        count=stats.count + v.sum(axis=-1).astype(np.int32),
        cover=stats.cover + (v & covered).sum(axis=-1).astype(np.int32),
        err_sum=stats.err_sum + err_s,
        err_sumsq=stats.err_sumsq + err_ss,
        half_n=stats.half_n + half_ok.sum(axis=-1).astype(np.int32),
        half_sum=stats.half_sum + half_s,
        half_sumsq=stats.half_sumsq + half_ss,
        err_hist=_hist_add(stats.err_hist, err, err_ok, xp),
        half_hist=_hist_add(stats.half_hist, half, half_ok, xp))


def trial_stats_merge(a: TrialStats, b: TrialStats) -> TrialStats:
    """Merge two partial accumulations (host-side analogue of the
    in-program ``psum`` over the trial mesh axis)."""
    return jax.tree.map(lambda x, y: x + y, a, b)


def log_hist_quantile(hist, q: float):
    """(...) quantile readout from a log-histogram sketch (host, numpy).

    Returns the geometric center of the bin holding the q-th order
    statistic; NaN for empty lanes. Accurate to one bin width (~0.68%
    relative at the default grid) plus the gap between neighboring order
    statistics — the parity test vs ``np.percentile`` on the dense path
    bounds both.
    """
    h = np.asarray(hist, np.float64)
    bins = h.shape[-1]
    tot = h.sum(axis=-1)
    cum = np.cumsum(h, axis=-1)
    idx = np.argmax(cum >= q * tot[..., None], axis=-1)
    centers = np.exp(_HIST_LOG_LO
                     + (np.arange(bins) + 0.5) * (_HIST_LOG_SPAN / bins))
    return np.where(tot > 0, centers[idx], np.nan)
