"""Common result types for the sampling estimators.

Terminology follows Cochran, *Sampling Techniques* (3rd ed.) and the paper's
Appendix A: the *population* is the set of all simulation regions of one
application, a *sampling unit* is one region, ``y`` is the study variable
(CPI under the configuration being estimated) and ``x`` is an auxiliary
variable known (or measured in phase 1) for stratification.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional

import numpy as np
from scipy import stats as _scipy_stats

__all__ = [
    "Estimate",
    "StratumSummary",
    "critical_value",
    "critical_values",
    "apply_coverage_contract",
    "as_float_array",
]



def critical_value(confidence: float, df: Optional[float]) -> float:
    """z- or t- critical value for a two-sided interval.

    ``df=None`` (or very large) selects the normal approximation; otherwise
    Student's t with ``df`` degrees of freedom (Appendix A: t for small n,
    Satterthwaite / rule-of-thumb dfs for stratified designs).
    """
    alpha = 1.0 - confidence
    if df is None or df >= 1e6:
        return float(_scipy_stats.norm.ppf(1.0 - alpha / 2.0))
    df = max(float(df), 1.0)
    return float(_scipy_stats.t.ppf(1.0 - alpha / 2.0, df))


def critical_values(confidence: float, dfs) -> np.ndarray:
    """Vectorized ``critical_value``: z-/t- critical values for an array
    of degrees of freedom (host-side scipy — not jit-able).

    ``inf``, NaN or very large (≥ 1e6) entries select the normal
    approximation; finite entries are clamped to ≥ 1 (matching the scalar
    rule). The batched estimator paths compute per-lane dfs on device and
    look critical values up here once per program, outside ``jit``.
    """
    alpha = 1.0 - confidence
    d = np.asarray(dfs, np.float64)
    z = float(_scipy_stats.norm.ppf(1.0 - alpha / 2.0))
    use_z = ~np.isfinite(d) | (d >= 1e6)
    out = np.where(
        use_z, z,
        _scipy_stats.t.ppf(1.0 - alpha / 2.0,
                           np.maximum(np.where(use_z, 1.0, d), 1.0)))
    return out


def apply_coverage_contract(covered: float, total: float, *,
                            strict: bool = False,
                            empty_action: str = "nan",
                            empty_msg: str = "no strata have sampled units",
                            what: str = "selected units",
                            stacklevel: int = 3) -> float:
    """The package-wide NaN/warn/raise coverage contract (docs/statistics.md).

    ``covered``/``total``: stratum weight with / without sampled units.
    Returns the covered fraction for renormalization (0.0 when nothing is
    covered — callers then produce NaN results). Nothing covered raises
    ``ValueError(empty_msg)`` when ``empty_action="raise"`` or
    ``strict=True``, else warns. Partial coverage warns by default
    (renormalizing silently biases the estimate toward the covered
    strata) and raises under ``strict=True``. Full coverage is silent.
    """
    if covered <= 0.0 or total <= 0.0:
        if strict or empty_action == "raise":
            raise ValueError(empty_msg)
        warnings.warn(empty_msg, UserWarning, stacklevel=stacklevel)
        return 0.0
    frac = covered / total
    if frac < 1.0 - 1e-6:
        msg = (f"{what} cover only {frac:.4f} of the stratum weight; "
               "renormalizing biases the estimate toward the covered strata")
        if strict:
            raise ValueError(msg)
        warnings.warn(msg, UserWarning, stacklevel=stacklevel)
    return frac


@dataclasses.dataclass(frozen=True)
class Estimate:
    """A point estimate with its sampling variance and a confidence interval.

    ``margin`` is the *absolute* half-width ``crit * sqrt(variance)``;
    ``margin_pct`` the relative margin of error in percent (the quantity the
    paper plots in Figs 7-9).
    """

    mean: float
    variance: float            # v(ybar): variance of the *sample mean*
    n: int                     # total sampled units
    df: Optional[float]        # degrees of freedom used (None => z)
    confidence: float = 0.95
    scheme: str = "srs"

    @property
    def std_error(self) -> float:
        return math.sqrt(max(self.variance, 0.0))

    @property
    def margin(self) -> float:
        return critical_value(self.confidence, self.df) * self.std_error

    @property
    def margin_pct(self) -> float:
        if self.mean == 0.0:
            return float("inf")
        return 100.0 * self.margin / abs(self.mean)

    @property
    def interval(self) -> tuple[float, float]:
        return (self.mean - self.margin, self.mean + self.margin)

    def covers(self, true_value: float) -> bool:
        lo, hi = self.interval
        return lo <= true_value <= hi

    def error_pct(self, true_value: float) -> float:
        """Relative estimation error vs a known reference (paper Fig 10/11)."""
        if true_value == 0.0:
            return float("inf")
        return 100.0 * abs(self.mean - true_value) / abs(true_value)


@dataclasses.dataclass(frozen=True)
class StratumSummary:
    """Per-stratum sample statistics (h indexes strata)."""

    weight: float              # W_h = N_h / N
    n: int                     # n_h sampled units
    mean: float                # ybar_h
    var: float                 # s_h^2 (within-stratum sample variance)

    def __post_init__(self):
        if self.weight < 0:
            raise ValueError(f"negative stratum weight {self.weight}")
        if self.n < 0:
            raise ValueError(f"negative stratum sample size {self.n}")


def as_float_array(values) -> np.ndarray:
    arr = np.asarray(values, dtype=np.float64)
    if arr.ndim != 1:
        arr = arr.reshape(-1)
    return arr
