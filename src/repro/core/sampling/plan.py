"""Composable sampling plans: stratifier × selection policy × estimator.

The paper's central decomposition of SimPoint — *stratification* (how
regions are grouped) is independent of *sample-unit selection* (which
region represents a stratum) and of *estimation* (how selected values
become a mean/CI) — is exactly the seam this module turns into an API.
A ``SamplingPlan`` is a pytree of three frozen dataclasses:

* a ``Stratifier`` (``BBVClusters`` / ``RFVClusters`` /
  ``DaleniusGurney``) owning its feature derivation and k-means /
  boundary-search parameters;
* a ``SelectionPolicy`` (``Centroid`` / ``StratumMean`` /
  ``RandomUnit`` / ``RankedSetUnit``) — a batched callable mapping a
  ``SelectionContext`` (per-stratum membership over a stacked app axis)
  to one pick per stratum per app;
* an ``Estimator`` (``WeightedPoint`` / ``CollapsedPairsCI`` /
  ``TwoPhaseCI``) — thin plan-level views over the batched
  ``StratumTables`` estimators in ``tables``; ``WeightedPoint`` also
  hosts the jitted on-device sweep-estimate program the sweep driver
  dispatches (``last_sweep_dispatch`` exposes the marker).

New designs plug in through the registry — ``register_stratifier`` /
``register_policy`` — without touching the engine or the sweep driver:
``repro.experiments`` dispatches on plan objects only, and
``SamplingPlan.from_strings("rfv", "ranked_set")`` resolves names
through the same registry the legacy string shims use. ``RankedSetUnit``
(order-statistic selection by phase-1 CPI rank within each stratum,
after *CPU Simulation with Ranked Set Sampling and Repeated
Subsampling*) is registered here purely through that mechanism as the
worked extensibility example.
"""

from __future__ import annotations

import dataclasses
import warnings
import zlib
from typing import Callable, ClassVar, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import tables as _tables
from .types import Estimate, critical_values

__all__ = [
    "SamplingPlan", "Stratifier", "SelectionPolicy", "Estimator",
    "BBVClusters", "RFVClusters", "DaleniusGurney",
    "Centroid", "StratumMean", "RandomUnit", "RankedSetUnit",
    "WeightedPoint", "CollapsedPairsCI", "TwoPhaseCI",
    "StratumBank", "SelectionContext", "build_selection_context",
    "register_stratifier", "register_policy",
    "registered_stratifiers", "registered_policies",
    "make_stratifier", "make_policy",
    "last_sweep_dispatch",
]


# ---------------------------------------------------------------- registry
_STRATIFIERS: dict[str, Callable] = {}
_POLICIES: dict[str, Callable] = {}
# legacy spellings resolvable by make_* but NOT listed as schemes: an
# alias must never become a second scheme name for the same design (it
# would get its own PRNG fold-in and its own row label)
_STRATIFIER_ALIASES: dict[str, str] = {}


def register_stratifier(name: str, factory: Callable, *,
                        aliases: Sequence[str] = ()) -> Callable:
    """Register a ``Stratifier`` factory under ``name`` (+ aliases).

    ``factory(**params)`` must return a ``Stratifier``; re-registering a
    name replaces the previous factory (latest wins, so downstream code
    can override the built-ins). ``aliases`` are legacy spellings that
    resolve through ``make_stratifier`` but are NOT separate scheme
    names (``registered_stratifiers`` omits them). Returns ``factory``
    so the call can be used as a decorator-style one-liner.
    """
    _STRATIFIERS[name] = factory
    for key in aliases:
        _STRATIFIER_ALIASES[key] = name
    return factory


def register_policy(name: str, factory: Callable) -> Callable:
    """Register a ``SelectionPolicy`` factory under ``name``."""
    _POLICIES[name] = factory
    return factory


def registered_stratifiers() -> tuple[str, ...]:
    """Registered stratifier scheme names (aliases omitted),
    registration order."""
    return tuple(_STRATIFIERS)


def registered_policies() -> tuple[str, ...]:
    """Registered selection-policy names, registration order."""
    return tuple(_POLICIES)


def _lookup(table: dict, kind: str, name: str) -> Callable:
    try:
        return table[name]
    except KeyError:
        raise ValueError(
            f"unknown {kind} {name!r}; registered: "
            f"{', '.join(sorted(table))}") from None


def make_stratifier(name: str, **params) -> "Stratifier":
    """Construct a registered stratifier by name (aliases resolve to
    their canonical design).

    ``params`` are filtered to the factory's dataclass fields so shims
    can pass a superset (e.g. ``kmeans_backend`` to ``DaleniusGurney``,
    which ignores it) without each factory declaring every knob.
    """
    name = _STRATIFIER_ALIASES.get(name, name)
    return _construct(_lookup(_STRATIFIERS, "stratifier", name), params)


def make_policy(name: str, **params) -> "SelectionPolicy":
    """Construct a registered selection policy by name (params filtered
    to the factory's fields, as in ``make_stratifier``)."""
    return _construct(_lookup(_POLICIES, "selection policy", name), params)


def _construct(factory: Callable, params: dict):
    if dataclasses.is_dataclass(factory):
        names = {f.name for f in dataclasses.fields(factory) if f.init}
        params = {k: v for k, v in params.items() if k in names}
    return factory(**params)


def _register_static_pytree(cls):
    """Register ``cls`` as a leafless jax pytree node (all fields static).

    Plan components are hyperparameters, not data: flattening to zero
    leaves keeps them out of tracers while letting whole plans cross
    ``jit``/``vmap`` boundaries and ``tree_map`` transparently.
    """
    jax.tree_util.register_pytree_node(
        cls, lambda t: ((), t), lambda aux, _: aux)
    return cls


# ------------------------------------------------------------ ragged stack
def _stack_ragged(arrays, *, dtype=None, fill=0):
    """(values, valid) stack of ragged-leading-length arrays.

    Local mirror of ``repro.simcpu.stack_ragged`` so the core sampling
    layer stays independent of the simulation substrate.
    """
    arrays = [np.asarray(a) for a in arrays]
    k_max = max((a.shape[0] for a in arrays), default=0)
    trail = arrays[0].shape[1:] if arrays else ()
    out = np.full((len(arrays), k_max) + trail, fill,
                  dtype=dtype or arrays[0].dtype)
    valid = np.zeros((len(arrays), k_max), bool)
    for i, a in enumerate(arrays):
        out[i, :a.shape[0]] = a
        valid[i, :a.shape[0]] = True
    return out, valid


# -------------------------------------------------------------- stratifiers
@dataclasses.dataclass(frozen=True)
class StratumBank:
    """Stacked-over-app stratification arrays a ``Stratifier`` resolves to.

    ``labels``/``valid`` are ``(A, n)`` over each app's unit pool (full
    population or phase-1 sample); ``weights`` is ``(A, L)``;
    ``baseline`` is the per-unit baseline-config CPI the selection
    policies and collapse-ordering keys read. ``feats``/``centroids``
    may be ``None`` — the selection context then derives them from the
    baseline values and the per-stratum baseline means (the
    Dalenius-Gurney convention). ``pool`` maps local unit positions to
    population indices (``None`` when labels already index the
    population directly).
    """

    labels: np.ndarray                  # (A, n) int stratum ids
    valid: np.ndarray                   # (A, n) bool
    weights: np.ndarray                 # (A, L) stratum weights W_h
    baseline: np.ndarray                # (A, n) baseline CPI per unit
    feats: Optional[np.ndarray] = None  # (A, n, F) selection features
    centroids: Optional[np.ndarray] = None   # (A, L, F)
    pool: Optional[np.ndarray] = None   # (A, n) population indices

    @property
    def num_strata(self) -> int:
        """L, the stratum-axis length."""
        return int(self.weights.shape[-1])


@dataclasses.dataclass(frozen=True)
class Stratifier:
    """Base class: how a population is grouped into strata.

    Subclasses own their feature derivation and fitting parameters and
    implement two entry points:

    * ``resolve(exps)`` — bind to engine-built artifacts: stack the
      per-app labels/weights/features this stratifier corresponds to
      into a ``StratumBank`` (``exps`` are ``AppExperiment``-shaped
      objects; duck-typed so this layer never imports the engine).
    * ``fit(baseline_y, features)`` — fit from scratch for the
      single-app ``TwoPhaseFlow`` path: returns
      ``(labels, centroids, features_used)``.

    ``pool_kind`` declares the value pool trials draw from: census pools
    are analysis-only (free); phase-1 pools are charged through the memo
    once.
    """

    name: ClassVar[str] = "?"
    pool_kind: ClassVar[str] = "phase1"        # "census" | "phase1"

    num_strata: int = 20
    seed: int = 0

    def resolve(self, exps: Sequence) -> StratumBank:
        """Stack this stratifier's engine-built artifacts over apps."""
        raise NotImplementedError

    def fit(self, baseline_y: np.ndarray,
            features: Optional[np.ndarray]):
        """Fit labels/centroids from phase-1 measurements (flow path)."""
        raise NotImplementedError


def _fit_kmeans(features, num_strata, seed, backend, restarts):
    """Standardize + k-means fit shared by the feature-space stratifiers
    (exactly the historic ``TwoPhaseFlow.stratify`` k-means branch)."""
    from ..clustering.kmeans import kmeans
    from ..clustering.standardize import Standardizer

    if features is None:
        raise ValueError("feature-space stratifiers need a feature matrix")
    _, z = Standardizer.fit_transform(features)
    z = np.asarray(z)
    km = kmeans(z, num_strata, key=jax.random.PRNGKey(seed),
                backend=backend, restarts=restarts)
    return km.labels, km.centroids, z


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class BBVClusters(Stratifier):
    """SimPoint-style stratification: k-means on projected BBVs over the
    full population (census baseline, analysis-only value pool)."""

    name: ClassVar[str] = "bbv"
    pool_kind: ClassVar[str] = "census"

    restarts: int = 3
    backend: str = "jnp"

    def resolve(self, exps: Sequence) -> StratumBank:
        """Stack the engine's census-BBV artifacts over apps."""
        labels, valid = _stack_ragged([e.bbv_labels for e in exps])
        feats, _ = _stack_ragged([e.bbv_feats for e in exps])
        baseline, _ = _stack_ragged([e.census(0) for e in exps])
        return StratumBank(
            labels=labels, valid=valid,
            weights=np.stack([e.bbv_weights for e in exps]),
            baseline=baseline, feats=feats,
            centroids=np.stack([e.bbv_centroids for e in exps]), pool=None)

    def fit(self, baseline_y, features):
        """k-means on (standardized) BBV features."""
        return _fit_kmeans(features, self.num_strata, self.seed,
                           self.backend, self.restarts)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class RFVClusters(Stratifier):
    """The paper's recommended stratification: k-means on standardized
    RFVs of the phase-1 sample (charged phase-1 value pool)."""

    name: ClassVar[str] = "rfv"
    pool_kind: ClassVar[str] = "phase1"

    restarts: int = 3
    backend: str = "jnp"

    def resolve(self, exps: Sequence) -> StratumBank:
        """Stack the engine's phase-1 RFV artifacts over apps."""
        labels, valid = _stack_ragged([e.rfv_labels for e in exps])
        feats, _ = _stack_ragged([e.rfv_z for e in exps])
        baseline, _ = _stack_ragged([e.cpi0_1 for e in exps])
        pool, _ = _stack_ragged([e.idx1 for e in exps])
        return StratumBank(
            labels=labels, valid=valid,
            weights=np.stack([e.rfv_weights for e in exps]),
            baseline=baseline, feats=feats,
            centroids=np.stack([e.rfv_centroids for e in exps]), pool=pool)

    def fit(self, baseline_y, features):
        """k-means on (standardized) RFV features."""
        return _fit_kmeans(features, self.num_strata, self.seed,
                           self.backend, self.restarts)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class DaleniusGurney(Stratifier):
    """Dalenius-Gurney boundary search on baseline CPI (paper V.B.1):
    one-dimensional strata whose "centroids" are stratum-mean CPIs."""

    name: ClassVar[str] = "dg"
    pool_kind: ClassVar[str] = "phase1"

    def resolve(self, exps: Sequence) -> StratumBank:
        """Stack the engine's DG artifacts; features/centroids are
        derived from baseline CPI by the selection context."""
        labels, valid = _stack_ragged([e.dg_labels for e in exps])
        baseline, _ = _stack_ragged([e.cpi0_1 for e in exps])
        pool, _ = _stack_ragged([e.idx1 for e in exps])
        return StratumBank(
            labels=labels, valid=valid,
            weights=np.stack([e.dg_weights for e in exps]),
            baseline=baseline, feats=None, centroids=None, pool=pool)

    def fit(self, baseline_y, features):
        """DG boundary search on baseline y; centroid = stratum mean."""
        from .dalenius import dalenius_gurney_strata

        y = np.asarray(baseline_y, np.float64)
        labels = dalenius_gurney_strata(y, self.num_strata)
        centroids = np.array([
            [y[labels == h].mean()] if (labels == h).any() else [np.nan]
            for h in range(self.num_strata)])
        return labels, centroids, y[:, None]


register_stratifier("bbv", BBVClusters)
register_stratifier("rfv", RFVClusters)
# "cpi" is the historic TwoPhaseFlow name for the same design
register_stratifier("dg", DaleniusGurney, aliases=("cpi",))


# ----------------------------------------------------------------- policies
@dataclasses.dataclass
class SelectionContext:
    """Everything a batched selection policy may read, app-stacked.

    Built once per selection (``build_selection_context``) from a
    ``StratumBank``; ``member[a, i, h]`` marks unit ``i`` of app ``a``
    as a valid member of stratum ``h``. ``order``/``offsets``/``counts``
    are the per-stratum gather tables (stratum ``h`` of app ``a`` owns
    ``order[a, offsets[a, h] : offsets[a, h] + counts[a, h]]``, in index
    order; trailing empty strata park their offset at the row width —
    gathers must clamp). ``member``/``order``/``offsets`` are lazy,
    cached on first read, so each policy materializes only the tables
    it actually dispatches on.

    The context is namespace-agnostic: fields may be numpy arrays (the
    staged host path) or jax tracers (the fused sweep megaprogram traces
    selection in-program — ``repro.experiments.fused``); the derived
    tables follow the input namespace. ``uniforms`` optionally carries
    pre-drawn ``(A, L)`` uniforms for ``RandomUnit`` so a traced context
    consumes the exact bits the host rng would have drawn.
    """

    labels: np.ndarray        # (A, n)
    valid: np.ndarray         # (A, n)
    feats: np.ndarray         # (A, n, F)
    centroids: np.ndarray     # (A, L, F)
    baseline: np.ndarray      # (A, n)
    base_means: np.ndarray    # (A, L) per-stratum mean baseline CPI
    counts: np.ndarray        # (A, L) int
    num_strata: int
    seed: int = 0
    uniforms: Optional[np.ndarray] = None    # (A, L) pre-drawn U[0,1)
    _member: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)
    _order: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)

    @property
    def member(self) -> np.ndarray:
        """(A, n, L) valid-membership mask (cached on first read)."""
        if self._member is None:
            xp = _tables._ns(self.labels, self.valid)
            self._member = (
                self.labels[:, :, None]
                == xp.arange(self.num_strata)[None, None, :]) \
                & self.valid[:, :, None]
        return self._member

    @property
    def order(self) -> np.ndarray:
        """(A, n) stratum-sorted gather table (cached on first read)."""
        if self._order is None:
            xp = _tables._ns(self.labels, self.valid)
            self._order = _tables._argsort(
                xp, xp.where(self.valid, self.labels, self.num_strata))
        return self._order

    @property
    def offsets(self) -> np.ndarray:
        """(A, L) per-stratum start positions into ``order``."""
        xp = _tables._ns(self.counts)
        return xp.cumsum(self.counts, axis=1) - self.counts


def _np_segment_sums_counts(labels, valid, num_strata, values):
    """Exact float64 host fallback for the stratum-summary dispatch
    (the engine substitutes its ``segment_stats``-kernel-backed path)."""
    lab = np.where(valid, labels, num_strata).astype(np.int64)
    a_n = lab.shape[0]
    flat = lab + (num_strata + 1) * np.arange(a_n)[:, None]
    minlength = a_n * (num_strata + 1)
    counts = np.bincount(flat.ravel(), minlength=minlength)
    sums = np.bincount(flat.ravel(),
                       weights=np.where(valid, values, 0.0).ravel(),
                       minlength=minlength)
    counts = counts.reshape(a_n, num_strata + 1)[:, :num_strata]
    sums = sums.reshape(a_n, num_strata + 1)[:, :num_strata]
    return sums.astype(np.float64), counts.astype(np.float64)


def build_selection_context(bank: StratumBank, *, seed: int = 0,
                            summarize: Optional[Callable] = None,
                            uniforms=None) -> SelectionContext:
    """Selection context for a ``StratumBank``: ONE stratum-summary
    dispatch serves the counts, the mean-policy targets AND (for
    banks without explicit centroids) the DG stratum-mean centroids.

    ``summarize(labels, valid, L, values) -> (sums, counts)`` lets the
    engine route the summary through its ``segment_stats`` kernel
    contract; the default is an exact float64 host bincount. Works on
    numpy arrays and on jax tracers alike (the fused sweep megaprogram
    builds its context in-trace, with ``uniforms`` carrying host-drawn
    random-policy draws so picks match the staged path exactly).
    """
    summarize = summarize or _np_segment_sums_counts
    L = bank.num_strata
    labels, valid = bank.labels, bank.valid
    base_sums, countsf = summarize(labels, valid, L, bank.baseline)
    xp = _tables._ns(labels, valid, countsf)
    base_means = base_sums / xp.maximum(countsf, 1)
    counts = countsf.astype(np.int64)
    feats = bank.feats if bank.feats is not None \
        else xp.asarray(bank.baseline)[:, :, None]
    # EMPTY strata get a zero derived centroid but are masked out of
    # selection entirely, so no NaN ever reaches a distance computation
    cents = bank.centroids if bank.centroids is not None \
        else base_means[:, :, None]
    return SelectionContext(
        labels=labels, valid=valid, feats=feats,
        centroids=cents, baseline=bank.baseline, base_means=base_means,
        counts=counts, num_strata=L, seed=seed, uniforms=uniforms)


@dataclasses.dataclass(frozen=True)
class SelectionPolicy:
    """Base class: which unit represents each stratum.

    A policy is a batched callable over the app stack —
    ``policy(ctx) -> (A, L)`` local unit positions, one per stratum
    (empty strata may return anything; the caller masks them with
    ``ctx.counts > 0``). ``select_local`` is the single-app
    ``TwoPhaseFlow`` entry point; the default builds a one-lane context
    and reuses the batched callable, so a plug-in policy only has to
    implement ``__call__``.

    ``uses_uniforms`` declares that the policy consumes per-(app,
    stratum) uniform draws (``SelectionContext.uniforms``): the fused
    sweep program host-draws them with the policy's exact rng sequence
    and feeds them into the trace, keeping traced picks equal to staged
    picks without string dispatch on policy names.
    """

    name: ClassVar[str] = "?"
    uses_uniforms: ClassVar[bool] = False

    def __call__(self, ctx: SelectionContext) -> np.ndarray:
        """(A, L) local pick positions for the stacked app axis."""
        raise NotImplementedError

    def select_local(self, labels, *, features, centroids, baseline,
                     num_strata: int, seed: int = 0,
                     per_stratum: Optional[int] = None) -> list[np.ndarray]:
        """Per-stratum local index arrays for one app (flow path).

        ``per_stratum=None`` defers to the policy's own configuration;
        an explicit value overrides it. The default implementation
        reuses the batched callable through a one-lane context and only
        supports one unit per stratum — multi-unit policies override.
        """
        if (per_stratum or 1) != 1:
            raise NotImplementedError(
                f"{type(self).name!r} selects one unit per stratum; "
                "override select_local for multi-unit designs")
        labels = np.asarray(labels)
        bank = StratumBank(
            labels=labels[None], valid=np.ones((1, labels.size), bool),
            weights=np.full((1, num_strata), 1.0 / max(num_strata, 1)),
            baseline=np.asarray(baseline)[None],
            feats=None if features is None
            else np.asarray(features)[None],
            centroids=None if centroids is None
            else np.asarray(centroids)[None])
        ctx = build_selection_context(bank, seed=seed)
        local = np.asarray(self(ctx))[0]
        return [np.atleast_1d(local[h]).astype(np.int64)
                if ctx.counts[0, h] > 0 else np.empty(0, np.int64)
                for h in range(num_strata)]


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class Centroid(SelectionPolicy):
    """SimPoint-style selection: the unit whose feature vector is nearest
    its stratum centroid (paper V.B, deterministic).

    ``per_stratum`` (the k nearest units) applies to the single-app flow
    path; the batched bank path picks one unit per stratum.
    """

    name: ClassVar[str] = "centroid"

    per_stratum: int = 1

    def __call__(self, ctx: SelectionContext) -> np.ndarray:
        """Argmin of squared feature distance to the centroid, per
        stratum (masked to members; empty strata are masked out)."""
        xp = _tables._ns(ctx.feats, ctx.centroids)
        # the expanded |x|^2 - 2<x,c> + |c|^2 form cancels catastrophically
        # in float32 at census scale (d2 ~ 1e-5 out of O(1) terms), enough
        # to flip near-boundary argmins between backends/compilations —
        # accumulate in the namespace's widest float (f64 on the host and
        # under x64; the canonical float via result_type(0.0) never warns)
        dt = xp.result_type(0.0)
        feats = xp.asarray(ctx.feats, dt)
        cents = xp.asarray(ctx.centroids, dt)
        x2 = (feats ** 2).sum(axis=2)                       # (A, n)
        c2 = (cents ** 2).sum(axis=2)                       # (A, L)
        d2 = x2[:, :, None] - 2.0 * xp.einsum(
            "and,ald->anl", feats, cents) + c2[:, None, :]
        return xp.where(ctx.member, d2, xp.inf).argmin(axis=1)

    def select_local(self, labels, *, features, centroids, baseline,
                     num_strata: int, seed: int = 0,
                     per_stratum: Optional[int] = None) -> list[np.ndarray]:
        """Flow path: exactly the historic ``select_centroid``."""
        from .selection import select_centroid
        return select_centroid(np.asarray(labels), np.asarray(features),
                               np.asarray(centroids),
                               per_stratum=per_stratum or self.per_stratum)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class StratumMean(SelectionPolicy):
    """Mean selection (paper V.B.2): the unit whose baseline CPI is
    nearest the stratum's mean baseline CPI.

    ``per_stratum`` (the k nearest units) applies to the single-app flow
    path; the batched bank path picks one unit per stratum.
    """

    name: ClassVar[str] = "mean"

    per_stratum: int = 1

    def __call__(self, ctx: SelectionContext) -> np.ndarray:
        """Argmin |baseline − stratum mean baseline| per stratum."""
        xp = _tables._ns(ctx.baseline, ctx.base_means)
        d = xp.abs(ctx.baseline[:, :, None] - ctx.base_means[:, None, :])
        return xp.where(ctx.member, d, xp.inf).argmin(axis=1)

    def select_local(self, labels, *, features, centroids, baseline,
                     num_strata: int, seed: int = 0,
                     per_stratum: Optional[int] = None) -> list[np.ndarray]:
        """Flow path: exactly the historic ``select_mean``."""
        from .selection import select_mean
        return select_mean(np.asarray(labels), np.asarray(baseline),
                           num_strata=num_strata,
                           per_stratum=per_stratum or self.per_stratum)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class RandomUnit(SelectionPolicy):
    """Textbook stratified sampling: a uniform random unit per stratum
    (the paper's conservative-CI reference policy).

    ``per_stratum`` applies to the single-app flow path (multi-unit
    designs); the batched bank path always picks one unit per stratum.
    """

    name: ClassVar[str] = "random"
    uses_uniforms: ClassVar[bool] = True

    per_stratum: int = 1

    def __call__(self, ctx: SelectionContext) -> np.ndarray:
        """One uniform draw per (app, stratum) from the gather tables.

        ``ctx.uniforms`` (when set) substitutes for the host rng draw —
        the fused sweep program passes the SAME ``default_rng(seed)``
        bits in as an array so traced picks equal staged picks.
        """
        xp = _tables._ns(ctx.counts, ctx.uniforms)
        if ctx.uniforms is None:
            u = np.random.default_rng(ctx.seed).random(
                np.shape(ctx.counts))                       # (A, L)
        else:
            u = ctx.uniforms
        pos = ctx.offsets + xp.minimum(
            (u * ctx.counts).astype(np.int64),
            xp.maximum(ctx.counts - 1, 0))
        # trailing empty strata park offsets at the row width: clamp (the
        # pick is discarded by the caller's validity mask)
        pos = xp.minimum(pos, max(ctx.order.shape[1] - 1, 0))
        return xp.take_along_axis(ctx.order, pos, axis=1)

    def select_local(self, labels, *, features, centroids, baseline,
                     num_strata: int, seed: int = 0,
                     per_stratum: Optional[int] = None) -> list[np.ndarray]:
        """Flow path: exactly the historic ``select_random``."""
        from .selection import select_random
        return select_random(np.asarray(labels), num_strata,
                             np.random.default_rng(seed),
                             per_stratum=per_stratum or self.per_stratum)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class RankedSetUnit(SelectionPolicy):
    """Order-statistic selection: the unit at a fixed baseline-CPI rank
    within each stratum.

    After *CPU Simulation with Ranked Set Sampling and Repeated
    Subsampling*: units are ranked by their (cheap, already-measured)
    phase-1 baseline CPI inside each stratum and the unit at rank
    fraction ``rank_fraction`` is selected — 0.5 picks the per-stratum
    median unit, 0.0/1.0 the extremes. Deterministic like ``Centroid``
    but needs only the scalar baseline, no feature geometry.

    Registered through the public registry exactly like an external
    plug-in would be — the engine and sweep driver dispatch on the plan
    object and need no edits for it.
    """

    name: ClassVar[str] = "ranked_set"

    rank_fraction: float = 0.5

    def __post_init__(self):
        if not 0.0 <= self.rank_fraction <= 1.0:
            raise ValueError(
                f"rank_fraction must be in [0, 1], got {self.rank_fraction}")

    def __call__(self, ctx: SelectionContext) -> np.ndarray:
        """Pick the unit at the configured baseline-CPI rank per stratum."""
        # within-stratum CPI order: stable sort by (stratum, baseline),
        # spelled as composed stable argsorts (== np.lexsort) so the same
        # code runs on numpy arrays and on jax tracers
        xp = _tables._ns(ctx.labels, ctx.baseline)
        primary = xp.where(ctx.valid, ctx.labels, ctx.num_strata)
        by_base = _tables._argsort(xp, ctx.baseline)
        rs_order = xp.take_along_axis(
            by_base,
            _tables._argsort(xp, xp.take_along_axis(primary, by_base,
                                                    axis=1)), axis=1)
        rank = xp.rint(self.rank_fraction
                       * xp.maximum(ctx.counts - 1, 0)).astype(np.int64)
        pos = xp.minimum(ctx.offsets + rank,
                         max(rs_order.shape[1] - 1, 0))
        return xp.take_along_axis(rs_order, pos, axis=1)


register_policy("centroid", Centroid)
register_policy("mean", StratumMean)
register_policy("random", RandomUnit)
register_policy("ranked_set", RankedSetUnit)


# --------------------------------------------------------------- estimators
# trace-/dispatch-time record of the most recent on-device sweep
# estimation (see last_sweep_dispatch)
_last_sweep_dispatch: Optional[dict] = None


def last_sweep_dispatch() -> Optional[dict]:
    """Marker describing the most recent jitted sweep-estimate dispatch.

    ``None`` until an ``Estimator.sweep_estimates`` program (or the
    fused sweep megaprogram — ``repro.experiments.fused``) ran; else a
    dict with ``batch_shape`` (the (A, C) lane axes), ``num_strata``,
    ``x64`` (whether the program ran in float64), ``backend``,
    ``fused`` (one megaprogram dispatch vs the staged estimate-only
    program), ``donated`` (whether the runtime actually consumed the
    donated memo buffers — backends without donation report False) and
    ``count`` (dispatches since the last reset, so tests can assert a
    sweep cost exactly ONE device program). Only the jitted device
    programs write it — there is no host fallback on the sweep-estimate
    path, so tests can assert estimates really came off-device.
    """
    return None if _last_sweep_dispatch is None \
        else dict(_last_sweep_dispatch)


def _record_sweep_dispatch(**fields) -> None:
    """Write the sweep-dispatch marker, accumulating ``count`` since the
    last ``_reset_sweep_dispatch`` (one fused sweep must record 1)."""
    global _last_sweep_dispatch
    prior = 0 if _last_sweep_dispatch is None \
        else _last_sweep_dispatch.get("count", 0)
    _last_sweep_dispatch = {**fields, "count": prior + 1}


def _reset_sweep_dispatch() -> None:
    """Clear the sweep-estimate dispatch marker (test helper)."""
    global _last_sweep_dispatch
    _last_sweep_dispatch = None


@jax.jit
def _weighted_point_program(cpi, valid, weights, truth):
    """Jitted ``StratumTables`` program for stratified sweep estimates.

    The staged spelling of ``Estimator.estimate_stage`` — one dispatch
    whose whole body is the fusable tables→estimates stage. Returns
    ``(estimate, err_pct)``.
    """
    return Estimator.estimate_stage(cpi, valid, weights, truth)


def _x64_sweep_programs() -> bool:
    """Whether the default sweep-estimate policy runs in float64.

    Delegates to ``PrecisionPolicy.host_parity`` — the ONE precision
    policy (``repro.core.precision``): CPU hosts trace the program under
    ``jax.experimental.enable_x64`` so on-device estimates match the
    historic float64 host reduction to rounding; TPU backends (no
    native f64) keep the default float32.
    """
    from ..precision import PrecisionPolicy

    return PrecisionPolicy.host_parity().needs_x64


@dataclasses.dataclass(frozen=True)
class Estimator:
    """Base class: how selected values become estimates.

    Every estimator shares the jitted on-device sweep-estimate program
    (``sweep_estimates``) — the weighted point estimate is the sweep's
    common denominator — and subclasses add their interval views over
    the batched ``tables`` estimators.
    """

    name: ClassVar[str] = "weighted_point"

    @staticmethod
    def estimate_stage(cpi, valid, weights, truth):
        """The fusable tables→estimates stage: traceable, no dispatch.

        Lanes are (app, config): ``sweep_point_tables`` turns the pick
        mask into one-unit-per-stratum ``StratumTables`` and
        ``stratified_mean`` reduces them to the covered-weight-
        renormalized weighted mean; ``err_pct`` follows. Shared verbatim
        by the staged jitted program (``sweep_estimates``) and the fused
        sweep megaprogram (``repro.experiments.fused``), so the two
        paths cannot drift. Returns ``(estimate, err_pct)``.
        """
        xp = _tables._ns(cpi, valid, weights, truth)
        t = _tables.sweep_point_tables(cpi, valid, weights)
        est = _tables.stratified_mean(t)
        err = 100.0 * xp.abs(est - truth) / truth
        return est, err

    def sweep_estimates(self, cpi, valid, weights, truth, *,
                        precision=None) -> tuple[np.ndarray, np.ndarray]:
        """(A, C) estimates + percent errors from one jitted dispatch.

        ``cpi``: (A, C, L) per-stratum selected-unit CPI; ``valid``:
        (A, L) pick validity; ``weights``: (A, L); ``truth``: (A, C).
        The reduction runs on device via the ``StratumTables`` program —
        no host-side weighted mean — and records the dispatch marker.
        ``precision`` overrides the default ``PrecisionPolicy``
        (``host_parity``: f64 trace off-TPU so device estimates match
        the numpy reference, f32 on TPU).
        """
        from ..precision import PrecisionPolicy

        pp = precision if precision is not None \
            else PrecisionPolicy.host_parity()
        dt = pp.trace_dtype
        args = (np.asarray(cpi, dt), np.asarray(valid, bool),
                np.asarray(weights, dt), np.asarray(truth, dt))
        with pp.x64_context():
            est, err = _weighted_point_program(*args)
        _record_sweep_dispatch(
            batch_shape=tuple(np.shape(cpi)[:-1]),
            num_strata=int(np.shape(cpi)[-1]),
            x64=pp.needs_x64, backend=jax.default_backend(),
            fused=False, donated=False)
        return np.asarray(est), np.asarray(err)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class WeightedPoint(Estimator):
    """SimPoint-style weighted point estimate (eq. 3 mean, no interval):
    the plan-level view over ``tables.stratified_mean``."""

    name: ClassVar[str] = "weighted_point"

    def estimate(self, tables: _tables.StratumTables):
        """Lane-wise eq. (3) weighted mean (covered-weight renormalized)."""
        return _tables.stratified_mean(tables)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class CollapsedPairsCI(Estimator):
    """One-unit-per-stratum interval via pairwise collapsed strata
    (paper eq. 4): the plan-level view over
    ``tables.collapsed_pairs_variance``."""

    name: ClassVar[str] = "collapsed_pairs"

    confidence: float = 0.95

    def interval(self, y_sorted, w_sorted, n_valid, *, num_strata: int):
        """(variance, df, half_width) lane-wise, occupied-first key order
        (see ``tables.collapsed_pairs_variance`` for the layout)."""
        var, df = _tables.collapsed_pairs_variance(
            y_sorted, w_sorted, n_valid, num_strata=num_strata)
        half = critical_values(self.confidence, np.asarray(df)) \
            * np.sqrt(np.asarray(var))
        return var, df, half

    def estimate(self, y_per_stratum, weights, *, order_by=None,
                 strict: bool = False) -> Estimate:
        """Scalar ``Estimate`` for one design (the quickstart view)."""
        from .collapsed import collapsed_strata_estimate
        return collapsed_strata_estimate(
            y_per_stratum, weights, order_by=order_by,
            confidence=self.confidence, strict=strict)


@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class TwoPhaseCI(Estimator):
    """Multi-unit two-phase interval (paper eq. 5/6 + Satterthwaite):
    the plan-level view over ``tables.two_phase_variance``."""

    name: ClassVar[str] = "two_phase"

    confidence: float = 0.95
    formula: str = "phase2_only"

    def estimate(self, tables: _tables.StratumTables, phase1_n: int, *,
                 phase1_var: Optional[float] = None,
                 strict: bool = False) -> Estimate:
        """Scalar ``Estimate`` from one-lane ``StratumTables`` (the
        ``TwoPhaseFlow.ci_check`` view)."""
        from .two_phase import two_phase_estimate_tables
        return two_phase_estimate_tables(
            tables, phase1_n, phase1_var=phase1_var,
            confidence=self.confidence, formula=self.formula,
            strict=strict)


# --------------------------------------------------------------------- plan
@_register_static_pytree
@dataclasses.dataclass(frozen=True)
class SamplingPlan:
    """A complete sampling design: stratifier × policy × estimator.

    The one object the experiment engine dispatches on: see
    ``repro.experiments.plan_selection_bank`` (batched selection),
    ``SweepSpec(plan=...)`` (sweeps) and ``TwoPhaseFlow`` (single-app
    flow). ``from_strings`` resolves registry names, which is also what
    the deprecated string shims construct.
    """

    stratifier: Stratifier
    policy: SelectionPolicy = Centroid()
    estimator: Estimator = WeightedPoint()

    @classmethod
    def from_strings(cls, scheme: str, policy: str = "centroid",
                     **params) -> "SamplingPlan":
        """Resolve registered names into a plan (the compat constructor).

        ``params`` (e.g. ``num_strata``, ``seed``, ``per_stratum``) are
        filtered to each component's fields, so one kwargs dict can
        parameterize both.
        """
        return cls(stratifier=make_stratifier(scheme, **params),
                   policy=make_policy(policy, **params))

    @property
    def scheme(self) -> str:
        """The stratifier's registered name (sweep-row label)."""
        return type(self.stratifier).name

    @property
    def policy_name(self) -> str:
        """The selection policy's registered name (sweep-row label)."""
        return type(self.policy).name


def trial_scheme_index(scheme: str, canonical: Sequence[str]) -> int:
    """Stable PRNG fold-in index for a trial scheme name.

    Canonical schemes keep their historic positions (draws are
    position-based and must not change); registry plug-ins hash their
    name past the canonical range so every scheme's draws are
    independent of registration order.
    """
    canonical = tuple(canonical)
    if scheme in canonical:
        return canonical.index(scheme)
    return len(canonical) + zlib.crc32(scheme.encode()) % (2 ** 20)


def warn_string_dispatch(where: str, repl: str) -> None:
    """One ``DeprecationWarning`` per (site, replacement) pair for the
    legacy string shims (``SweepSpec(scheme=...)``,
    ``TwoPhaseFlow.stratify(scheme=...)``, ...)."""
    warnings.warn(
        f"{where} with scheme/policy strings is deprecated; {repl}",
        DeprecationWarning, stacklevel=3)
