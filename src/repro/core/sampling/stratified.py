"""Stratified random sampling (Appendix A, Section B; Cochran Ch. 5).

Estimators (paper eq. 3):
    ybar  = sum_h W_h ybar_h
    v(ybar) = sum_h W_h^2 s_h^2 / n_h

Degrees of freedom: z when every stratum sample is large or L is large
(Lohr Sec. 4.2); otherwise Satterthwaite (eq. from [30]) or the rule of
thumb df = n - L ([31]).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .types import Estimate, StratumSummary, as_float_array


def summarize_strata(
    y,
    strata,
    *,
    weights: Optional[Sequence[float]] = None,
    num_strata: Optional[int] = None,
) -> list[StratumSummary]:
    """Build per-stratum summaries from sampled values + stratum labels.

    ``weights`` are population stratum weights W_h (must sum to ~1). When
    omitted, the *sample* proportions are used (valid for proportional
    allocation / post-stratification of a random sample).
    Strata with no sampled units get n=0 summaries (mean/var NaN) so callers
    can detect incomplete designs.

    With ``num_strata=None``, L comes from ``len(weights)`` when weights are
    given (trailing strata may legitimately have no sampled units); only
    when both are omitted is L inferred from the observed labels.
    """
    yv = as_float_array(y)
    sv = np.asarray(strata)
    if yv.shape[0] != sv.shape[0]:
        raise ValueError("y and strata must align")
    if num_strata is not None:
        L = int(num_strata)
    elif weights is not None:
        L = len(weights)
    else:
        L = int(sv.max() + 1) if sv.size else 0
    if weights is None:
        counts = np.bincount(sv, minlength=L).astype(np.float64)
        weights = counts / max(counts.sum(), 1.0)
    w = np.asarray(weights, dtype=np.float64)
    if w.shape[0] != L:
        raise ValueError(f"weights length {w.shape[0]} != num strata {L}")
    total_w = w.sum()
    if not np.isclose(total_w, 1.0, atol=1e-6):
        raise ValueError(f"stratum weights sum to {total_w}, expected 1")

    out: list[StratumSummary] = []
    for h in range(L):
        mask = sv == h
        n_h = int(mask.sum())
        if n_h == 0:
            out.append(StratumSummary(weight=float(w[h]), n=0,
                                      mean=float("nan"), var=float("nan")))
        elif n_h == 1:
            out.append(StratumSummary(weight=float(w[h]), n=1,
                                      mean=float(yv[mask][0]), var=float("nan")))
        else:
            vals = yv[mask]
            out.append(StratumSummary(weight=float(w[h]), n=n_h,
                                      mean=float(vals.mean()),
                                      var=float(vals.var(ddof=1))))
    return out


def stratified_mean(summaries: Sequence[StratumSummary]) -> float:
    """ybar_st = sum_h W_h ybar_h. Empty strata (n=0) are an error."""
    mean = 0.0
    for s in summaries:
        if s.n == 0 and s.weight > 0:
            raise ValueError("stratum with positive weight has no sampled units")
        if s.n > 0:
            mean += s.weight * s.mean
    return mean


def stratified_variance(summaries: Sequence[StratumSummary]) -> float:
    """v(ybar_st) = sum_h W_h^2 s_h^2 / n_h. Requires n_h >= 2 everywhere."""
    v = 0.0
    for s in summaries:
        if s.weight == 0.0:
            continue
        if s.n < 2 or not np.isfinite(s.var):
            raise ValueError(
                "within-stratum variance needs n_h >= 2 (paper fn.7); "
                "use collapsed strata for one-unit-per-stratum designs")
        v += (s.weight ** 2) * s.var / s.n
    return v


def satterthwaite_df(summaries: Sequence[StratumSummary]) -> float:
    """Satterthwaite [30] effective degrees of freedom for ybar_st."""
    num = 0.0
    den = 0.0
    for s in summaries:
        if s.n < 2 or s.weight == 0.0:
            continue
        g = (s.weight ** 2) * s.var / s.n
        num += g
        den += g * g / (s.n - 1)
    if den == 0.0:
        return float("inf")
    return num * num / den


def stratified_estimate(
    summaries: Sequence[StratumSummary],
    *,
    confidence: float = 0.95,
    df_method: str = "satterthwaite",
) -> Estimate:
    """Combine per-stratum summaries into a mean + CI (paper eq. 3).

    ``df_method``: "satterthwaite" | "n_minus_L" | "z".
    """
    mean = stratified_mean(summaries)
    var = stratified_variance(summaries)
    n = sum(s.n for s in summaries)
    L = sum(1 for s in summaries if s.weight > 0)
    if df_method == "z":
        df = None
    elif df_method == "n_minus_L":
        df = float(max(n - L, 1))
    elif df_method == "satterthwaite":
        df = satterthwaite_df(summaries)
        if not np.isfinite(df):
            df = None
    else:
        raise ValueError(f"unknown df_method {df_method!r}")
    return Estimate(mean=mean, variance=var, n=n, df=df,
                    confidence=confidence, scheme="stratified")


def stratified_estimate_from_samples(
    y,
    strata,
    *,
    weights: Optional[Sequence[float]] = None,
    num_strata: Optional[int] = None,
    confidence: float = 0.95,
    df_method: str = "satterthwaite",
) -> Estimate:
    summaries = summarize_strata(y, strata, weights=weights, num_strata=num_strata)
    return stratified_estimate(summaries, confidence=confidence, df_method=df_method)
