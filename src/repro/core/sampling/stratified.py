"""Stratified random sampling (Appendix A, Section B; Cochran Ch. 5).

Estimators (paper eq. 3):
    ybar  = sum_h W_h ybar_h
    v(ybar) = sum_h W_h^2 s_h^2 / n_h

Degrees of freedom: z when every stratum sample is large or L is large
(Lohr Sec. 4.2); otherwise Satterthwaite (eq. from [30]) or the rule of
thumb df = n - L ([31]).

These scalar functions are thin one-lane views over the array-native
engine in ``tables.py`` (``StratumTables`` + batched estimators): the
same code computes a single design here and a ``(..., L)`` stack of
designs inside the Monte-Carlo/sweep programs. The scalar views keep the
historic *strict* contract — degenerate strata raise — while the batched
functions produce NaN lane-wise (see ``docs/statistics.md``).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from . import tables as _tables
from .types import Estimate, StratumSummary, as_float_array

__all__ = [
    "StratumSummary",
    "summarize_strata",
    "stratified_mean",
    "stratified_variance",
    "satterthwaite_df",
    "stratified_estimate",
    "stratified_estimate_from_samples",
]



def summarize_strata(
    y,
    strata,
    *,
    weights: Optional[Sequence[float]] = None,
    num_strata: Optional[int] = None,
) -> list[StratumSummary]:
    """Build per-stratum summaries from sampled values + stratum labels.

    ``weights`` are population stratum weights W_h (must sum to ~1). When
    omitted, the *sample* proportions are used (valid for proportional
    allocation / post-stratification of a random sample).
    Strata with no sampled units get n=0 summaries (mean/var NaN) so callers
    can detect incomplete designs.

    With ``num_strata=None``, L comes from ``len(weights)`` when weights are
    given (trailing strata may legitimately have no sampled units); only
    when both are omitted is L inferred from the observed labels.

    One-lane view: the sufficient statistics come from
    ``tables.stratum_tables`` (float64 host path).
    """
    yv = as_float_array(y)
    sv = np.asarray(strata)
    if yv.shape[0] != sv.shape[0]:
        raise ValueError("y and strata must align")
    t = _tables.stratum_tables(yv, sv, weights=weights,
                               num_strata=num_strata)
    means, variances = t.means, t.variances
    out: list[StratumSummary] = []
    for h in range(t.num_strata):
        n_h = int(t.counts[h])
        out.append(StratumSummary(
            weight=float(t.weights[h]), n=n_h,
            mean=float(means[h]) if n_h > 0 else float("nan"),
            var=float(variances[h]) if n_h > 1 else float("nan")))
    return out


def stratified_mean(summaries: Sequence[StratumSummary]) -> float:
    """ybar_st = sum_h W_h ybar_h. Empty strata (n=0) are an error."""
    for s in summaries:
        if s.n == 0 and s.weight > 0:
            raise ValueError("stratum with positive weight has no sampled units")
    t = _tables.tables_from_summaries(summaries)
    return float(_tables.stratified_mean(t, renormalize=False))


def stratified_variance(summaries: Sequence[StratumSummary]) -> float:
    """v(ybar_st) = sum_h W_h^2 s_h^2 / n_h. Requires n_h >= 2 everywhere."""
    for s in summaries:
        if s.weight == 0.0:
            continue
        if s.n < 2 or not np.isfinite(s.var):
            raise ValueError(
                "within-stratum variance needs n_h >= 2 (paper fn.7); "
                "use collapsed strata for one-unit-per-stratum designs")
    t = _tables.tables_from_summaries(summaries)
    return float(_tables.stratified_variance(t, renormalize=False))


def satterthwaite_df(summaries: Sequence[StratumSummary]) -> float:
    """Satterthwaite [30] effective degrees of freedom for ybar_st."""
    t = _tables.tables_from_summaries(summaries)
    return float(_tables.satterthwaite_df(t))


def stratified_estimate(
    summaries: Sequence[StratumSummary],
    *,
    confidence: float = 0.95,
    df_method: str = "satterthwaite",
) -> Estimate:
    """Combine per-stratum summaries into a mean + CI (paper eq. 3).

    ``df_method``: "satterthwaite" | "n_minus_L" | "z".
    """
    mean = stratified_mean(summaries)
    var = stratified_variance(summaries)
    n = sum(s.n for s in summaries)
    L = sum(1 for s in summaries if s.weight > 0)
    if df_method == "z":
        df = None
    elif df_method == "n_minus_L":
        df = float(max(n - L, 1))
    elif df_method == "satterthwaite":
        df = satterthwaite_df(summaries)
        if not np.isfinite(df):
            df = None
    else:
        raise ValueError(f"unknown df_method {df_method!r}")
    return Estimate(mean=mean, variance=var, n=n, df=df,
                    confidence=confidence, scheme="stratified")


def stratified_estimate_from_samples(
    y,
    strata,
    *,
    weights: Optional[Sequence[float]] = None,
    num_strata: Optional[int] = None,
    confidence: float = 0.95,
    df_method: str = "satterthwaite",
) -> Estimate:
    """``summarize_strata`` + ``stratified_estimate`` in one call."""
    summaries = summarize_strata(y, strata, weights=weights, num_strata=num_strata)
    return stratified_estimate(summaries, confidence=confidence, df_method=df_method)
