"""Sample allocation across strata (Cochran Ch. 5.5-5.9).

Used by the Table IV experiment: given target precision, how many phase-2
units per stratum are needed under proportional or Neyman allocation.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .types import critical_value


def proportional_allocation(weights: Sequence[float], n_total: int) -> np.ndarray:
    """n_h proportional to W_h, each stratum >= 2 (so s_h^2 is estimable)."""
    w = np.asarray(weights, dtype=np.float64)
    raw = w * n_total
    n_h = np.maximum(np.floor(raw).astype(int), 2)
    return _largest_remainder_fixup(n_h, raw, n_total)


def neyman_allocation(
    weights: Sequence[float],
    stds: Sequence[float],
    n_total: int,
    *,
    min_per_stratum: int = 2,
) -> np.ndarray:
    """n_h proportional to W_h * S_h (optimal for fixed total n)."""
    w = np.asarray(weights, dtype=np.float64)
    s = np.asarray(stds, dtype=np.float64)
    prod = w * np.maximum(s, 0.0)
    if prod.sum() == 0.0:
        return proportional_allocation(weights, n_total)
    raw = prod / prod.sum() * n_total
    n_h = np.maximum(np.floor(raw).astype(int), min_per_stratum)
    return _largest_remainder_fixup(n_h, raw, n_total)


def _largest_remainder_fixup(n_h: np.ndarray, raw: np.ndarray, n_total: int) -> np.ndarray:
    """Adjust rounded allocation so sum(n_h) == max(n_total, minima sum)."""
    n_h = n_h.copy()
    deficit = n_total - int(n_h.sum())
    if deficit > 0:
        order = np.argsort(-(raw - np.floor(raw)))
        for i in range(deficit):
            n_h[order[i % len(order)]] += 1
    # If minima pushed us above n_total we accept the overshoot: correctness
    # (estimable variances) beats hitting the budget exactly.
    return n_h


def required_total_neyman(
    weights: Sequence[float],
    stds: Sequence[float],
    *,
    target_margin_abs: float,
    confidence: float = 0.95,
) -> int:
    """Total phase-2 n under Neyman allocation for a target absolute margin.

    From v(ybar) = (sum W_h S_h)^2 / n under Neyman allocation (no fpc):
        n = z^2 (sum W_h S_h)^2 / margin^2
    """
    w = np.asarray(weights, dtype=np.float64)
    s = np.asarray(stds, dtype=np.float64)
    z = critical_value(confidence, None)
    numer = (w * s).sum() ** 2
    if target_margin_abs <= 0:
        raise ValueError("target margin must be positive")
    n = int(np.ceil(z * z * numer / (target_margin_abs ** 2)))
    return max(n, 2)


def required_total_proportional(
    weights: Sequence[float],
    stds: Sequence[float],
    *,
    target_margin_abs: float,
    confidence: float = 0.95,
) -> int:
    """Total phase-2 n under proportional allocation for a target margin.

    v(ybar) = sum W_h S_h^2 / n  =>  n = z^2 sum(W_h S_h^2) / margin^2.
    """
    w = np.asarray(weights, dtype=np.float64)
    s = np.asarray(stds, dtype=np.float64)
    z = critical_value(confidence, None)
    numer = (w * s * s).sum()
    if target_margin_abs <= 0:
        raise ValueError("target margin must be positive")
    n = int(np.ceil(z * z * numer / (target_margin_abs ** 2)))
    return max(n, 2)
