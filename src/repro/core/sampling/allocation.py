"""Sample allocation across strata (Cochran Ch. 5.5-5.9).

Used by the Table IV experiment: given target precision, how many phase-2
units per stratum are needed under proportional or Neyman allocation.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from . import tables as _tables
from .types import critical_value

__all__ = [
    "proportional_allocation",
    "neyman_allocation",
    "required_total_neyman",
    "required_total_proportional",
]



def proportional_allocation(weights: Sequence[float], n_total: int) -> np.ndarray:
    """n_h proportional to W_h, each stratum >= 2 (so s_h^2 is estimable).

    One-lane view over ``tables.proportional_allocation`` (the batched
    largest-remainder rule; minima overshoot is accepted — correctness
    beats hitting the budget exactly).
    """
    w = np.asarray(weights, dtype=np.float64)
    return np.asarray(_tables.proportional_allocation(w, int(n_total)))


def neyman_allocation(
    weights: Sequence[float],
    stds: Sequence[float],
    n_total: int,
    *,
    min_per_stratum: int = 2,
) -> np.ndarray:
    """n_h proportional to W_h * S_h (optimal for fixed total n).

    One-lane view over ``tables.neyman_allocation`` (zero W·S products
    fall back to proportional allocation).
    """
    w = np.asarray(weights, dtype=np.float64)
    s = np.asarray(stds, dtype=np.float64)
    return np.asarray(_tables.neyman_allocation(
        w, s, int(n_total), min_per_stratum=min_per_stratum))


def required_total_neyman(
    weights: Sequence[float],
    stds: Sequence[float],
    *,
    target_margin_abs: float,
    confidence: float = 0.95,
) -> int:
    """Total phase-2 n under Neyman allocation for a target absolute margin.

    From v(ybar) = (sum W_h S_h)^2 / n under Neyman allocation (no fpc):
        n = z^2 (sum W_h S_h)^2 / margin^2
    """
    w = np.asarray(weights, dtype=np.float64)
    s = np.asarray(stds, dtype=np.float64)
    z = critical_value(confidence, None)
    numer = (w * s).sum() ** 2
    if target_margin_abs <= 0:
        raise ValueError("target margin must be positive")
    n = int(np.ceil(z * z * numer / (target_margin_abs ** 2)))
    return max(n, 2)


def required_total_proportional(
    weights: Sequence[float],
    stds: Sequence[float],
    *,
    target_margin_abs: float,
    confidence: float = 0.95,
) -> int:
    """Total phase-2 n under proportional allocation for a target margin.

    v(ybar) = sum W_h S_h^2 / n  =>  n = z^2 sum(W_h S_h^2) / margin^2.
    """
    w = np.asarray(weights, dtype=np.float64)
    s = np.asarray(stds, dtype=np.float64)
    z = critical_value(confidence, None)
    numer = (w * s * s).sum()
    if target_margin_abs <= 0:
        raise ValueError("target margin must be positive")
    n = int(np.ceil(z * z * numer / (target_margin_abs ** 2)))
    return max(n, 2)
