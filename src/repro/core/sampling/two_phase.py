"""Two-phase (double) sampling for stratification (Appendix A, Section D).

Phase 1: large SRS of size n' collects the auxiliary variable x (here: the
baseline-config RFV / CPI for each sampled region). The population is then
stratified from the phase-1 sample. Phase 2: stratified subsample measures
the study variable y (CPI under a new configuration).

Variance of the two-phase mean — paper eq. (5):
    v(ybar) = s^2 / n' + sum_h W_h^2 s_h^2 / n_h

and the phase-2-only form — paper eq. (6):
    v(ybar) = (1/n') sum_h W_h (ybar_h - ybar)^2 + sum_h W_h^2 s_h^2 / n_h

Equation (6) lets later studies compute CIs without the phase-1 y values:
only stratum weights (shaped by phase 1) and phase-2 data enter.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .stratified import (StratumSummary, satterthwaite_df, stratified_mean,
                         stratified_variance)
from .types import Estimate


def two_phase_estimate(
    summaries: Sequence[StratumSummary],
    phase1_n: int,
    *,
    phase1_var: Optional[float] = None,
    confidence: float = 0.95,
    formula: str = "phase2_only",
) -> Estimate:
    """Two-phase mean + CI from phase-2 per-stratum summaries.

    ``formula="with_phase1_var"`` uses eq. (5) and needs ``phase1_var`` (the
    phase-1 population variance estimate s^2 of *y*, only available when the
    phase-1 study variable matches). ``formula="phase2_only"`` uses eq. (6),
    the form the paper recommends for re-use across configurations.
    """
    if phase1_n < 1:
        raise ValueError("phase-1 sample size must be >= 1")
    mean = stratified_mean(summaries)
    v_phase2 = stratified_variance(summaries)

    if formula == "with_phase1_var":
        if phase1_var is None:
            raise ValueError("eq. (5) needs phase1_var")
        v_phase1 = float(phase1_var) / phase1_n
    elif formula == "phase2_only":
        between = 0.0
        for s in summaries:
            if s.n > 0:
                between += s.weight * (s.mean - mean) ** 2
        v_phase1 = between / phase1_n
    else:
        raise ValueError(f"unknown formula {formula!r}")

    var = v_phase1 + v_phase2
    n = sum(s.n for s in summaries)
    df = satterthwaite_df(summaries)
    if not np.isfinite(df):
        df = None
    return Estimate(mean=mean, variance=var, n=n, df=df,
                    confidence=confidence, scheme=f"two_phase[{formula}]")


def phase2_sizes_for_margin(
    weights: Sequence[float],
    within_stds: Sequence[float],
    phase1_n: int,
    between_var: float,
    *,
    target_margin_abs: float,
    confidence: float = 0.95,
    allocation: str = "neyman",
    min_per_stratum: int = 2,
    max_total: int = 10**7,
) -> np.ndarray:
    """Choose phase-2 per-stratum sizes so the eq. (6) margin hits a target.

    This implements the paper's Table IV sizing policy: the phase-1 term
    ``between_var / phase1_n`` is fixed; we solve for the total phase-2 size
    whose stratified term brings the *combined* margin under
    ``target_margin_abs``, then allocate across strata.
    """
    from .types import critical_value

    w = np.asarray(weights, dtype=np.float64)
    s = np.asarray(within_stds, dtype=np.float64)
    z = critical_value(confidence, None)
    v_target = (target_margin_abs / z) ** 2
    v_phase1 = between_var / phase1_n
    v_budget = v_target - v_phase1
    if v_budget <= 0:
        raise ValueError(
            "target margin unattainable: phase-1 variance term alone "
            f"({v_phase1:.3e}) exceeds the variance budget ({v_target:.3e})")

    if allocation == "neyman":
        # v_phase2(n) = (sum W_h S_h)^2 / n under Neyman allocation.
        n_total = int(np.ceil(((w * s).sum() ** 2) / v_budget))
        from .allocation import neyman_allocation
        n_total = min(max(n_total, 2 * len(w)), max_total)
        return neyman_allocation(w, s, n_total, min_per_stratum=min_per_stratum)
    elif allocation == "proportional":
        n_total = int(np.ceil((w * s * s).sum() / v_budget))
        from .allocation import proportional_allocation
        n_total = min(max(n_total, 2 * len(w)), max_total)
        return proportional_allocation(w, n_total)
    raise ValueError(f"unknown allocation {allocation!r}")
