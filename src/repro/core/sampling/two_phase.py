"""Two-phase (double) sampling for stratification (Appendix A, Section D).

Phase 1: large SRS of size n' collects the auxiliary variable x (here: the
baseline-config RFV / CPI for each sampled region). The population is then
stratified from the phase-1 sample. Phase 2: stratified subsample measures
the study variable y (CPI under a new configuration).

Variance of the two-phase mean — paper eq. (5):
    v(ybar) = s^2 / n' + sum_h W_h^2 s_h^2 / n_h

and the phase-2-only form — paper eq. (6):
    v(ybar) = (1/n') sum_h W_h (ybar_h - ybar)^2 + sum_h W_h^2 s_h^2 / n_h

Equation (6) lets later studies compute CIs without the phase-1 y values:
only stratum weights (shaped by phase 1) and phase-2 data enter.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional, Sequence

import numpy as np

from . import tables as _tables
from .stratified import StratumSummary
from .types import Estimate, apply_coverage_contract

__all__ = ["two_phase_estimate", "two_phase_estimate_tables",
           "phase2_sizes_for_margin"]


def two_phase_estimate_tables(
    t: "_tables.StratumTables",
    phase1_n: int,
    *,
    phase1_var: Optional[float] = None,
    confidence: float = 0.95,
    formula: str = "phase2_only",
    strict: bool = False,
) -> Estimate:
    """Two-phase mean + CI from one-lane ``StratumTables`` directly.

    The core the summaries wrapper and the plan-level ``TwoPhaseCI``
    estimator share: one-lane view over ``tables.two_phase_variance``
    with the package-wide coverage contract applied (see
    ``two_phase_estimate`` for the contract's terms).
    """
    if phase1_n < 1:
        raise ValueError("phase-1 sample size must be >= 1")
    covered = float(_tables.covered_weight(t))
    total = float(_tables.total_weight(t))
    frac = apply_coverage_contract(
        covered, total, strict=strict,
        empty_msg="every stratum is empty; no units to estimate from",
        what="sampled strata")
    if frac <= 0.0:
        return Estimate(mean=float("nan"), variance=float("nan"),
                        n=0, df=None, confidence=confidence,
                        scheme=f"two_phase[{formula}]")

    mean = float(_tables.stratified_mean(t))
    degenerate = bool(((t.counts > 0) & (t.weights > 0)
                       & (t.counts < 2)).any())
    if degenerate:
        msg = ("within-stratum variance needs n_h >= 2 (paper fn.7); "
               "use collapsed strata for one-unit-per-stratum designs")
        if strict:
            raise ValueError(msg)
        warnings.warn(msg, UserWarning, stacklevel=3)
    var = float(_tables.two_phase_variance(
        t, phase1_n, formula=formula, phase1_var=phase1_var))

    n = int(np.asarray(t.counts).sum())
    df = float(_tables.satterthwaite_df(t))
    if not np.isfinite(df):
        df = None
    return Estimate(mean=mean, variance=var, n=n, df=df,
                    confidence=confidence, scheme=f"two_phase[{formula}]")


def two_phase_estimate(
    summaries: Sequence[StratumSummary],
    phase1_n: int,
    *,
    phase1_var: Optional[float] = None,
    confidence: float = 0.95,
    formula: str = "phase2_only",
    strict: bool = False,
) -> Estimate:
    """Two-phase mean + CI from phase-2 per-stratum summaries.

    ``formula="with_phase1_var"`` uses eq. (5) and needs ``phase1_var`` (the
    phase-1 population variance estimate s^2 of *y*, only available when the
    phase-1 study variable matches). ``formula="phase2_only"`` uses eq. (6),
    the form the paper recommends for re-use across configurations.

    One-lane view over ``tables.two_phase_variance``, following the
    package-wide coverage contract (docs/statistics.md): positive-weight
    strata with no sampled units warn and renormalize the estimate by the
    covered weight (``strict=True`` raises); covered strata with n_h < 2
    warn and yield a NaN variance (``strict=True`` raises) — the point
    estimate stays finite either way.
    """
    return two_phase_estimate_tables(
        _tables.tables_from_summaries(summaries), phase1_n,
        phase1_var=phase1_var, confidence=confidence, formula=formula,
        strict=strict)


@functools.lru_cache(maxsize=None)
def _sizing_program(allocation: str, min_per_stratum: int):
    """Jitted Table IV sizing: the n_total solve AND the largest-remainder
    stratum allocation run as ONE device program (historically a host
    numpy reduction — PR 5 residual). ``lo``/``hi`` are traced clamp
    bounds so changing ``max_total`` never recompiles."""
    import jax
    import jax.numpy as jnp

    from . import tables as _tables

    neyman = allocation == "neyman"

    def prog(w, s, v_budget, lo, hi):
        if neyman:
            # v_phase2(n) = (sum W_h S_h)^2 / n under Neyman allocation
            n_total = jnp.ceil((w * s).sum() ** 2 / v_budget)
        else:
            n_total = jnp.ceil((w * s * s).sum() / v_budget)
        n_total = jnp.clip(n_total, lo, hi)
        if neyman:
            return _tables.neyman_allocation(
                w, s, n_total, min_per_stratum=min_per_stratum)
        return _tables.proportional_allocation(
            w, n_total, min_per_stratum=min_per_stratum)

    return jax.jit(prog)


def phase2_sizes_for_margin(
    weights: Sequence[float],
    within_stds: Sequence[float],
    phase1_n: int,
    between_var: float,
    *,
    target_margin_abs: float,
    confidence: float = 0.95,
    allocation: str = "neyman",
    min_per_stratum: int = 2,
    max_total: int = 10**7,
    precision=None,
) -> np.ndarray:
    """Choose phase-2 per-stratum sizes so the eq. (6) margin hits a target.

    This implements the paper's Table IV sizing policy: the phase-1 term
    ``between_var / phase1_n`` is fixed; we solve for the total phase-2 size
    whose stratified term brings the *combined* margin under
    ``target_margin_abs``, then allocate across strata — the solve and the
    allocation run as one jitted device program under the
    ``PrecisionPolicy`` (default ``host_parity``: f64 trace off-TPU, so
    sizes match the historic numpy reduction). The attainability check
    stays host-side: an unattainable margin is a *caller* error and must
    raise eagerly, not poison a traced program with NaN.
    """
    from ..precision import PrecisionPolicy
    from .types import critical_value

    pp = precision if precision is not None else PrecisionPolicy.host_parity()
    w = np.asarray(weights, dtype=pp.trace_dtype)
    s = np.asarray(within_stds, dtype=pp.trace_dtype)
    z = critical_value(confidence, None)
    v_target = (target_margin_abs / z) ** 2
    v_phase1 = between_var / phase1_n
    v_budget = v_target - v_phase1
    if v_budget <= 0:
        raise ValueError(
            "target margin unattainable: phase-1 variance term alone "
            f"({v_phase1:.3e}) exceeds the variance budget ({v_target:.3e})")
    if allocation not in ("neyman", "proportional"):
        raise ValueError(f"unknown allocation {allocation!r}")

    program = _sizing_program(allocation, int(min_per_stratum))
    with pp.x64_context():
        n_h = program(w, s, np.asarray(v_budget, pp.trace_dtype),
                      np.asarray(2 * len(w), pp.trace_dtype),
                      np.asarray(max_total, pp.trace_dtype))
    return np.asarray(n_h)
