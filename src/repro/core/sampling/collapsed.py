"""Collapsed-strata variance estimation (Appendix A, Section C).

With one sampling unit per stratum the within-stratum variance cannot be
estimated directly. The method of collapsed strata (Cochran Sec. 5A.12)
pairs strata expected to be similar and uses (paper eq. 4):

    s_h^2 = s_{h+1}^2 = (y_h - y_{h+1})^2 / 4,   n_h = n_{h+1} = 1

Pairs are formed from *neighboring* strata after ordering by an auxiliary
value (the paper orders by Config-0 stratum CPI). Degrees of freedom:
df = L - J with J collapsed groups ([18]); pairwise collapsing gives L/2.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .types import Estimate


def collapsed_strata_estimate(
    y_per_stratum: Sequence[float],
    weights: Sequence[float],
    *,
    order_by: Optional[Sequence[float]] = None,
    confidence: float = 0.95,
) -> Estimate:
    """CI for a one-unit-per-stratum design via pairwise collapsed strata.

    ``y_per_stratum[h]``: the single sampled value from stratum h.
    ``weights[h]``: W_h.
    ``order_by``: auxiliary per-stratum values used to sort strata before
      pairing neighbours (e.g. baseline-config stratum mean CPI). Defaults
      to the sampled values themselves.

    Variance uses the standard collapsed-strata estimator
        v(ybar) = sum_pairs (W_g1 y_g1 - W_g2 y_g2 ... ) — we use the
    Cochran form with per-unit variances from eq. (4) plugged into the
    stratified formula: v = sum_h W_h^2 s_h^2 / 1.
    With an odd number of strata the last *three* strata form one group and
    the group variance is the sample variance of its members.
    """
    y = np.asarray(y_per_stratum, dtype=np.float64)
    w = np.asarray(weights, dtype=np.float64)
    if y.shape != w.shape:
        raise ValueError("y and weights must align")
    L = y.shape[0]
    if L < 2:
        raise ValueError("need at least two strata to collapse")
    if not np.isclose(w.sum(), 1.0, atol=1e-6):
        raise ValueError(f"weights sum to {w.sum()}, expected 1")

    key = np.asarray(order_by, dtype=np.float64) if order_by is not None else y
    if key.shape[0] != L:
        raise ValueError("order_by must have one value per stratum")
    order = np.argsort(key, kind="stable")

    mean = float((w * y).sum())

    # Group neighbouring strata pairwise; odd L puts the final stratum into
    # the last group (a 3-stratum group).
    groups: list[np.ndarray] = []
    i = 0
    while i + 1 < L:
        if i + 3 == L:  # final group of three
            groups.append(order[i:i + 3])
            i += 3
        else:
            groups.append(order[i:i + 2])
            i += 2

    var = 0.0
    for g in groups:
        if len(g) == 2:
            h1, h2 = g
            s2 = (y[h1] - y[h2]) ** 2 / 4.0   # eq. (4)
            var += (w[h1] ** 2) * s2 + (w[h2] ** 2) * s2
        else:
            vals = y[g]
            s2 = float(vals.var(ddof=1))
            for h in g:
                var += (w[h] ** 2) * s2

    J = len(groups)
    df = float(max(L - J, 1))   # [18]; pairwise collapsing => df = L/2
    return Estimate(mean=mean, variance=var, n=L, df=df,
                    confidence=confidence, scheme="collapsed_strata")
