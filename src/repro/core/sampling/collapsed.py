"""Collapsed-strata variance estimation (Appendix A, Section C).

With one sampling unit per stratum the within-stratum variance cannot be
estimated directly. The method of collapsed strata (Cochran Sec. 5A.12)
pairs strata expected to be similar and uses (paper eq. 4):

    s_h^2 = s_{h+1}^2 = (y_h - y_{h+1})^2 / 4,   n_h = n_{h+1} = 1

Pairs are formed from *neighboring* strata after ordering by an auxiliary
value (the paper orders by Config-0 stratum CPI). Degrees of freedom:
df = L - J with J collapsed groups ([18]); pairwise collapsing gives L/2.

The scalar estimator here is a one-lane view over
``tables.collapsed_pairs_variance`` — the batched form the Monte-Carlo
trial engine evaluates for every (app, trial) lane in one program.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from . import tables as _tables
from .types import Estimate, apply_coverage_contract

__all__ = [
    "collapsed_strata_estimate",
]



def collapsed_strata_estimate(
    y_per_stratum: Sequence[float],
    weights: Sequence[float],
    *,
    order_by: Optional[Sequence[float]] = None,
    confidence: float = 0.95,
    strict: bool = False,
) -> Estimate:
    """CI for a one-unit-per-stratum design via pairwise collapsed strata.

    ``y_per_stratum[h]``: the single sampled value from stratum h.
    ``weights[h]``: W_h.
    ``order_by``: auxiliary per-stratum values used to sort strata before
      pairing neighbours (e.g. baseline-config stratum mean CPI). Defaults
      to the sampled values themselves.

    Variance uses the Cochran form with per-unit variances from eq. (4)
    plugged into the stratified formula: v = sum_h W_h^2 s_h^2 / 1.
    With an odd number of strata the last *three* strata form one group
    and the group variance is the sample variance of its members.

    Strata whose sampled value is missing (NaN — an empty stratum in a
    deterministic selection) follow the package coverage contract
    (docs/statistics.md): they are dropped from the estimate and the
    pairing, the mean is renormalized by the covered weight, and a
    ``UserWarning`` names the bias — ``strict=True`` raises instead.
    """
    y = np.asarray(y_per_stratum, dtype=np.float64)
    w = np.asarray(weights, dtype=np.float64)
    if y.shape != w.shape:
        raise ValueError("y and weights must align")
    L = y.shape[0]
    if L < 2:
        raise ValueError("need at least two strata to collapse")
    if not np.isclose(w.sum(), 1.0, atol=1e-6):
        raise ValueError(f"weights sum to {w.sum()}, expected 1")

    key = np.asarray(order_by, dtype=np.float64) if order_by is not None else y
    if key.shape[0] != L:
        raise ValueError("order_by must have one value per stratum")

    valid = np.isfinite(y)
    covered = float(w[valid].sum())
    frac = apply_coverage_contract(
        covered, float(w.sum()), strict=strict,
        empty_msg="every stratum value is missing; no units to "
                  "estimate from",
        what="strata with sampled values")
    if frac <= 0.0:
        return Estimate(mean=float("nan"), variance=float("nan"), n=0,
                        df=None, confidence=confidence,
                        scheme="collapsed_strata")
    v_cnt = int(valid.sum())
    if v_cnt < 2:
        raise ValueError("need at least two sampled strata to collapse")

    # valid strata first, in key order (the batched engine's layout)
    order = np.argsort(np.where(valid, key, np.inf), kind="stable")
    y_s, w_s = y[order], w[order]
    mean = float((w_s[:v_cnt] * y_s[:v_cnt]).sum())
    if v_cnt < L:                      # renormalize only under partial coverage
        mean /= covered
        # the variance must renormalize consistently (W_h -> W_h/covered,
        # so each pair term scales by 1/covered²) or the CI is too narrow
        # for the renormalized estimate it brackets
        w_s = w_s / covered
    var, df = _tables.collapsed_pairs_variance(y_s, w_s, v_cnt,
                                               num_strata=L)
    return Estimate(mean=mean, variance=float(var), n=v_cnt,
                    df=float(max(df, 1.0)), confidence=confidence,
                    scheme="collapsed_strata")
