"""Dalenius-Gurney optimal stratification on a scalar variable (Appendix A.E).

Orders units by the auxiliary variable x (here: baseline CPI) and picks
stratum boundaries so that W_h * s_h is approximately equal across strata
(paper eq. 7). Implemented exactly as the paper describes: start from
equidistant (equal-count) boundaries, iteratively refine.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "dalenius_gurney_strata",
    "stratum_products",
]



def dalenius_gurney_strata(
    x,
    num_strata: int,
    *,
    max_iters: int = 200,
    tol: float = 1e-3,
) -> np.ndarray:
    """Assign each unit a stratum label in [0, num_strata) by x-value.

    Returns integer labels aligned with ``x``. Boundaries are refined until
    the W_h*s_h products are within ``tol`` (relative spread) of equal, or
    ``max_iters`` is reached. Degenerate strata (constant x) are tolerated:
    their W_h*s_h is 0 and the algorithm shifts boundaries away from them.
    """
    xv = np.asarray(x, dtype=np.float64).reshape(-1)
    n = xv.shape[0]
    L = int(num_strata)
    if L < 1:
        raise ValueError("num_strata must be >= 1")
    if L == 1:
        return np.zeros(n, dtype=np.int32)
    if n < L:
        raise ValueError(f"cannot form {L} strata from {n} units")

    order = np.argsort(xv, kind="stable")
    sorted_x = xv[order]

    # Boundaries as cut positions in the sorted array: L-1 interior cuts.
    cuts = np.linspace(0, n, L + 1).round().astype(int)
    cuts[0], cuts[-1] = 0, n

    # products(c) is called after every boundary move; per-(lo, hi)
    # memoization makes each move cost two fresh segment stds instead of
    # L, which is the difference between O(L * iters) and O(n * L *
    # iters) std work on census-scale inputs (the fig5 bench hot spot)
    seg_cache: dict[tuple[int, int], float] = {}

    def product(lo: int, hi: int) -> float:
        key = (lo, hi)
        if key not in seg_cache:
            seg = sorted_x[lo:hi]
            w = seg.size / n
            s = seg.std(ddof=1) if seg.size > 1 else 0.0
            seg_cache[key] = w * s
        return seg_cache[key]

    def products(c: np.ndarray) -> np.ndarray:
        out = np.empty(L)
        for h in range(L):
            out[h] = product(c[h], c[h + 1])
        return out

    for _ in range(max_iters):
        p = products(cuts)
        target = p.mean()
        if target > 0 and (p.max() - p.min()) / target < tol:
            break
        moved = False
        # Move each interior boundary one step toward balancing its two
        # neighbouring strata (greedy coordinate descent; robust and simple).
        for b in range(1, L):
            left, right = p[b - 1], p[b]
            if left > right and cuts[b] - cuts[b - 1] > 1:
                step = max(1, (cuts[b] - cuts[b - 1]) // 16)
                cuts[b] -= step
                moved = True
            elif right > left and cuts[b + 1] - cuts[b] > 1:
                step = max(1, (cuts[b + 1] - cuts[b]) // 16)
                cuts[b] += step
                moved = True
            if moved:
                p = products(cuts)
        if not moved:
            break

    labels_sorted = np.empty(n, dtype=np.int32)
    for h in range(L):
        labels_sorted[cuts[h]:cuts[h + 1]] = h
    labels = np.empty(n, dtype=np.int32)
    labels[order] = labels_sorted
    return labels


def stratum_products(x, labels, num_strata: int) -> np.ndarray:
    """Diagnostic: the W_h * s_h products eq. (7) tries to equalize."""
    xv = np.asarray(x, dtype=np.float64).reshape(-1)
    lv = np.asarray(labels)
    n = xv.shape[0]
    out = np.zeros(num_strata)
    for h in range(num_strata):
        seg = xv[lv == h]
        if seg.size > 1:
            out[h] = (seg.size / n) * seg.std(ddof=1)
    return out
