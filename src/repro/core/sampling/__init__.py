"""Sampling estimators and designs (paper Appendix A + Fig. 14 flow)."""

from .allocation import (neyman_allocation, proportional_allocation,
                         required_total_neyman, required_total_proportional)
from .collapsed import collapsed_strata_estimate
from .dalenius import dalenius_gurney_strata, stratum_products
from .design import Stratification, TwoPhaseFlow
from .selection import (select_centroid, select_mean, select_random,
                        weighted_point_estimate)
from .srs import draw_srs, srs_estimate, srs_required_n
from .stratified import (StratumSummary, satterthwaite_df,
                         stratified_estimate,
                         stratified_estimate_from_samples, stratified_mean,
                         stratified_variance, summarize_strata)
from .two_phase import phase2_sizes_for_margin, two_phase_estimate
from .types import Estimate, critical_value

__all__ = [
    "Estimate", "critical_value", "StratumSummary",
    "srs_estimate", "srs_required_n", "draw_srs",
    "summarize_strata", "stratified_mean", "stratified_variance",
    "stratified_estimate", "stratified_estimate_from_samples",
    "satterthwaite_df",
    "collapsed_strata_estimate",
    "two_phase_estimate", "phase2_sizes_for_margin",
    "dalenius_gurney_strata", "stratum_products",
    "proportional_allocation", "neyman_allocation",
    "required_total_neyman", "required_total_proportional",
    "select_random", "select_centroid", "select_mean",
    "weighted_point_estimate",
    "TwoPhaseFlow", "Stratification",
]
