"""Sampling estimators and designs (paper Appendix A + Fig. 14 flow).

The scalar estimators are one-lane views over the array-native engine in
``tables`` (``StratumTables`` + batched lane-wise estimators); import
``repro.core.sampling.tables`` directly for the batched API.

``plan`` holds the composable design objects — ``SamplingPlan`` =
``Stratifier`` × ``SelectionPolicy`` × ``Estimator`` — and the registry
(``register_stratifier`` / ``register_policy``) through which new
stratifications and selection policies plug into the experiment engine
without engine edits.
"""

from . import plan, tables
from ..precision import DEFAULT_PRECISION, PrecisionPolicy, resolve_precision
from .allocation import (neyman_allocation, proportional_allocation,
                         required_total_neyman, required_total_proportional)
from .collapsed import collapsed_strata_estimate
from .dalenius import dalenius_gurney_strata, stratum_products
from .design import Stratification, TwoPhaseFlow
from .plan import (BBVClusters, Centroid, CollapsedPairsCI, DaleniusGurney,
                   Estimator, RandomUnit, RankedSetUnit, RFVClusters,
                   SamplingPlan, SelectionPolicy, Stratifier, StratumMean,
                   TwoPhaseCI, WeightedPoint, make_policy, make_stratifier,
                   register_policy, register_stratifier, registered_policies,
                   registered_stratifiers)
from .selection import (select_centroid, select_mean, select_random,
                        weighted_point_estimate)
from .srs import draw_srs, srs_estimate, srs_required_n
from .stratified import (StratumSummary, satterthwaite_df,
                         stratified_estimate,
                         stratified_estimate_from_samples, stratified_mean,
                         stratified_variance, summarize_strata)
from .tables import (StratumTables, TrialStats, log_hist_quantile,
                     stratum_tables, tables_from_summaries,
                     trial_stats_init, trial_stats_merge,
                     trial_stats_update)
from .two_phase import (phase2_sizes_for_margin, two_phase_estimate,
                        two_phase_estimate_tables)
from .types import (Estimate, apply_coverage_contract, critical_value,
                    critical_values)

__all__ = [
    "Estimate", "critical_value", "critical_values",
    "apply_coverage_contract", "StratumSummary",
    "StratumTables", "stratum_tables", "tables_from_summaries", "tables",
    "srs_estimate", "srs_required_n", "draw_srs",
    "summarize_strata", "stratified_mean", "stratified_variance",
    "stratified_estimate", "stratified_estimate_from_samples",
    "satterthwaite_df",
    "collapsed_strata_estimate",
    "two_phase_estimate", "two_phase_estimate_tables",
    "phase2_sizes_for_margin",
    "dalenius_gurney_strata", "stratum_products",
    "proportional_allocation", "neyman_allocation",
    "required_total_neyman", "required_total_proportional",
    "select_random", "select_centroid", "select_mean",
    "weighted_point_estimate",
    "TwoPhaseFlow", "Stratification",
    # sampling-plan objects + registry
    "plan", "SamplingPlan", "Stratifier", "SelectionPolicy", "Estimator",
    "BBVClusters", "RFVClusters", "DaleniusGurney",
    "Centroid", "StratumMean", "RandomUnit", "RankedSetUnit",
    "WeightedPoint", "CollapsedPairsCI", "TwoPhaseCI",
    "register_stratifier", "register_policy",
    "registered_stratifiers", "registered_policies",
    "make_stratifier", "make_policy",
    # precision policy + streaming trial statistics
    "PrecisionPolicy", "DEFAULT_PRECISION", "resolve_precision",
    "TrialStats", "trial_stats_init", "trial_stats_update",
    "trial_stats_merge", "log_hist_quantile",
]
