"""Within-stratum sample-unit selection policies (paper Section V.B).

SimPoint uses deterministic *centroid* selection (the unit whose feature
vector is nearest the cluster centroid). The paper additionally evaluates
*random* selection (textbook stratified sampling) and *mean selection*
(the unit whose baseline CPI is nearest the stratum's mean baseline CPI).
Deterministic selection is "better than random", so random-selection CIs
serve as conservative bounds (paper Section III).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .types import apply_coverage_contract

__all__ = [
    "select_random",
    "select_centroid",
    "select_mean",
    "weighted_point_estimate",
]



def select_random(
    labels: np.ndarray,
    num_strata: int,
    rng: np.random.Generator,
    *,
    per_stratum: int = 1,
) -> list[np.ndarray]:
    """Uniform without-replacement choice of ``per_stratum`` units per stratum.

    Returns a list of index arrays, one per stratum (empty for empty strata;
    fewer than ``per_stratum`` if the stratum is small).
    """
    out = []
    for h in range(num_strata):
        idx = np.flatnonzero(labels == h)
        if idx.size == 0:
            out.append(idx)
            continue
        k = min(per_stratum, idx.size)
        out.append(rng.choice(idx, size=k, replace=False))
    return out


def select_centroid(
    labels: np.ndarray,
    features: np.ndarray,
    centroids: np.ndarray,
    *,
    per_stratum: int = 1,
) -> list[np.ndarray]:
    """SimPoint-style: units whose feature vectors are nearest the centroid.

    ``features``: (n, d) standardized feature matrix used for clustering.
    ``centroids``: (L, d). Returns the ``per_stratum`` nearest units per
    stratum (ties broken by index order for determinism).
    """
    num_strata = centroids.shape[0]
    out = []
    for h in range(num_strata):
        idx = np.flatnonzero(labels == h)
        if idx.size == 0:
            out.append(idx)
            continue
        d = np.linalg.norm(features[idx] - centroids[h][None, :], axis=1)
        k = min(per_stratum, idx.size)
        nearest = idx[np.argsort(d, kind="stable")[:k]]
        out.append(nearest)
    return out


def select_mean(
    labels: np.ndarray,
    baseline_y: np.ndarray,
    *,
    num_strata: int,
    per_stratum: int = 1,
) -> list[np.ndarray]:
    """Mean selection (paper V.B.2): unit with baseline CPI nearest the
    stratum's mean baseline CPI."""
    out = []
    for h in range(num_strata):
        idx = np.flatnonzero(labels == h)
        if idx.size == 0:
            out.append(idx)
            continue
        target = baseline_y[idx].mean()
        d = np.abs(baseline_y[idx] - target)
        k = min(per_stratum, idx.size)
        out.append(idx[np.argsort(d, kind="stable")[:k]])
    return out


def weighted_point_estimate(
    selected: list[np.ndarray],
    y: np.ndarray,
    weights: np.ndarray,
    *,
    strict: bool = False,
) -> float:
    """SimPoint-style weighted mean over deterministically selected units.

    ``weights[h]`` = W_h; multiple units per stratum are averaged within the
    stratum before weighting.

    When strata with positive weight have no selected units, the estimate
    is renormalized by the covered weight — which silently *biases* it
    toward the covered strata. With ``strict=True`` that condition raises;
    by default it emits a ``UserWarning`` so callers can no longer miss it
    (the package-wide coverage contract — ``types.apply_coverage_contract``,
    documented in docs/statistics.md).
    """
    mean = 0.0
    total_w = 0.0
    for h, idx in enumerate(selected):
        if idx.size == 0:
            continue
        mean += weights[h] * float(y[idx].mean())
        total_w += weights[h]
    apply_coverage_contract(
        total_w, float(np.sum(weights)), strict=strict,
        empty_action="raise", empty_msg="no strata selected",
        what="selected units")
    return mean / total_w
