"""End-to-end two-phase sampling flow (paper Fig. 14, Section VI.A).

Steps:
  1. Initial characterization — large SRS on the baseline configuration.
  2. Construct RFVs (and CPI distributions) from the phase-1 runs.
  3. Stratify via k-means on RFVs; pick one region per stratum (centroid).
  4. Day-to-day studies use the selected regions (4a); periodic CI checks
     sample multiple units per stratum and apply the two-phase formulas (4b).

The flow is substrate-agnostic: the caller supplies a ``measure`` callable
(indices -> per-region study values) so the same driver runs the simcpu
population, an LM sampled-eval corpus, or a step-profiling stream.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import numpy as np

from . import plan as _plan
from .selection import weighted_point_estimate
from .srs import draw_srs, srs_estimate
from .types import Estimate

__all__ = ["Stratification", "TwoPhaseFlow"]


@dataclasses.dataclass
class Stratification:
    """Frozen phase-1 artifact reused across configuration studies."""

    labels: np.ndarray            # per phase-1 unit
    weights: np.ndarray           # W_h estimated from phase-1 proportions
    centroids: Optional[np.ndarray]
    features: Optional[np.ndarray]   # standardized features used to cluster
    phase1_indices: np.ndarray    # population indices of phase-1 units
    phase1_baseline_y: np.ndarray  # baseline-config y for phase-1 units
    scheme: str

    @property
    def num_strata(self) -> int:
        return int(self.weights.shape[0])

    def stratum_order_key(self) -> np.ndarray:
        """Per-stratum baseline mean CPI — the paper's collapsed-strata
        pairing key ("ordering the strata based on CPI for Config 0")."""
        out = np.zeros(self.num_strata)
        for h in range(self.num_strata):
            m = self.labels == h
            out[h] = self.phase1_baseline_y[m].mean() if m.any() else np.inf
        return out


@dataclasses.dataclass
class TwoPhaseFlow:
    """Driver for the recommended methodology.

    ``population_size``: number of regions in the application.
    ``measure_baseline``: indices -> (y_baseline, feature_matrix). The
      feature matrix is the RFV (or BBV) per region.
    """

    population_size: int
    rng: np.random.Generator

    # -- Step 1: initial characterization ------------------------------------
    def characterize(
        self,
        measure_baseline: Callable[[np.ndarray], tuple[np.ndarray, np.ndarray]],
        n_phase1: int,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, Estimate]:
        idx = draw_srs(self.rng, self.population_size, n_phase1)
        y0, feats = measure_baseline(idx)
        est = srs_estimate(y0)
        return idx, np.asarray(y0), np.asarray(feats), est

    # -- Step 3: stratify + select -------------------------------------------
    def stratify(
        self,
        phase1_indices: np.ndarray,
        phase1_baseline_y: np.ndarray,
        features: Optional[np.ndarray],
        *,
        num_strata: Optional[int] = None,
        scheme: Union[str, "_plan.Stratifier"] = "rfv",
        seed: Optional[int] = None,
        kmeans_backend: Optional[str] = None,
    ) -> Stratification:
        """Stratify the phase-1 sample under a ``Stratifier``.

        ``scheme`` is a plan-object ``Stratifier`` (``RFVClusters``,
        ``BBVClusters``, ``DaleniusGurney`` or any registry plug-in)
        owning its k-means / boundary-search parameters — the
        ``num_strata``/``seed``/``kmeans_backend`` keywords then belong
        to the object, and passing a *conflicting* value here raises
        rather than being silently ignored. Passing a string
        (``'rfv'`` | ``'bbv'`` | ``'cpi'``/``'dg'``) is deprecated: it
        resolves through the plan registry (the keywords parameterize
        the constructed object) and warns.
        """
        if isinstance(scheme, str):
            _plan.warn_string_dispatch(
                "TwoPhaseFlow.stratify(scheme=...)",
                "pass a Stratifier object (e.g. RFVClusters(num_strata=20))")
            if num_strata is None:
                raise ValueError("string schemes need num_strata")
            scheme = _plan.make_stratifier(
                scheme, num_strata=num_strata, seed=seed or 0,
                backend=kmeans_backend or "jnp")
        else:
            for arg, field, val in (("num_strata", "num_strata", num_strata),
                                    ("seed", "seed", seed),
                                    ("kmeans_backend", "backend",
                                     kmeans_backend)):
                if val is not None and getattr(scheme, field, None) != val:
                    raise ValueError(
                        f"{arg}={val!r} conflicts with the Stratifier "
                        f"object ({field}="
                        f"{getattr(scheme, field, None)!r}); configure "
                        "the Stratifier instead")
        labels, centroids, feats = scheme.fit(phase1_baseline_y, features)
        num_strata = scheme.num_strata
        counts = np.bincount(labels, minlength=num_strata).astype(np.float64)
        weights = counts / counts.sum()
        return Stratification(
            labels=np.asarray(labels), weights=weights,
            centroids=np.asarray(centroids), features=np.asarray(feats),
            phase1_indices=np.asarray(phase1_indices),
            phase1_baseline_y=np.asarray(phase1_baseline_y),
            scheme=type(scheme).name)

    def select(
        self,
        strat: Stratification,
        *,
        policy: Union[str, "_plan.SelectionPolicy"] = "centroid",
        per_stratum: Optional[int] = None,
        seed: int = 0,
    ) -> list[np.ndarray]:
        """Population indices of selected regions, one array per stratum.

        ``policy`` is a plan-object ``SelectionPolicy`` (``Centroid``,
        ``StratumMean``, ``RandomUnit(per_stratum=...)``,
        ``RankedSetUnit`` or any registry plug-in); its ``select_local``
        runs against the stratification. ``per_stratum`` overrides the
        policy's own configuration when given (``None`` defers to it).
        Passing a string is deprecated and resolves through the plan
        registry — warning once per call site.
        """
        if isinstance(policy, str):
            _plan.warn_string_dispatch(
                "TwoPhaseFlow.select(policy=...)",
                "pass a SelectionPolicy object (e.g. Centroid())")
            policy = _plan.make_policy(policy,
                                       per_stratum=per_stratum or 1)
        local = policy.select_local(
            strat.labels, features=strat.features,
            centroids=strat.centroids, baseline=strat.phase1_baseline_y,
            num_strata=strat.num_strata, seed=seed,
            per_stratum=per_stratum)
        return [strat.phase1_indices[l] for l in local]

    # -- Step 4a: day-to-day point estimate ----------------------------------
    def point_estimate(
        self,
        strat: Stratification,
        selected: Sequence[np.ndarray],
        measure: Callable[[np.ndarray], np.ndarray],
    ) -> float:
        flat = np.concatenate([s for s in selected if s.size > 0])
        y = np.asarray(measure(flat))
        per_stratum: list[np.ndarray] = []
        off = 0
        for s in selected:
            per_stratum.append(np.arange(off, off + s.size))
            off += s.size
        return weighted_point_estimate(
            [np.asarray(p) for p in per_stratum], y, strat.weights)

    def collapsed_ci(
        self,
        strat: Stratification,
        selected: Sequence[np.ndarray],
        measure: Callable[[np.ndarray], np.ndarray],
        *,
        confidence: float = 0.95,
    ) -> Estimate:
        """Practical one-unit-per-stratum CI (paper V.A.3, Fig 9) — the
        plan-level ``CollapsedPairsCI`` estimator view."""
        y_h = np.array([float(measure(s)[0]) for s in selected])
        return _plan.CollapsedPairsCI(confidence=confidence).estimate(
            y_h, strat.weights, order_by=strat.stratum_order_key())

    # -- Step 4b: periodic multi-unit CI check -------------------------------
    def ci_check(
        self,
        strat: Stratification,
        measure: Callable[[np.ndarray], np.ndarray],
        *,
        per_stratum_sizes: np.ndarray,
        confidence: float = 0.95,
        seed: int = 0,
    ) -> Estimate:
        """Stratified multi-unit sample + two-phase CI (paper eq. 5/6).

        Strata whose phase-1 pool yields fewer than 2 sampled units cannot
        provide a within-stratum variance; they are collapsed into the
        neighboring stratum in baseline-CPI order (the paper fn.7 remedy)
        instead of crashing the variance formula — one-lane view over
        ``tables.collapse_small_strata``, estimated by the plan-level
        ``TwoPhaseCI`` view (the same merge + eq. 5/6 the batched
        estimators apply lane-wise).
        """
        from . import tables as _tables

        rng = np.random.default_rng(seed)
        ys: list[np.ndarray] = []
        labs: list[np.ndarray] = []
        for h in range(strat.num_strata):
            pool = strat.phase1_indices[strat.labels == h]
            k = int(min(per_stratum_sizes[h], pool.size))
            if k == 0:
                continue
            chosen = rng.choice(pool, size=k, replace=False)
            ys.append(np.asarray(measure(chosen)))
            labs.append(np.full(k, h))
        y = np.concatenate(ys) if ys else np.empty(0)
        lab = np.concatenate(labs) if labs else np.empty(0, np.int64)
        t = _tables.stratum_tables(y, lab, weights=strat.weights,
                                   num_strata=strat.num_strata)
        merged, _, n_groups = _tables.collapse_small_strata(
            t, strat.stratum_order_key())
        if int(n_groups) < 1:
            raise ValueError("ci_check needs at least 2 sampled units")
        # estimate from the merged-group lanes only (trailing slots are
        # zero-count, zero-weight: they contribute nothing)
        return _plan.TwoPhaseCI(confidence=confidence).estimate(
            merged, phase1_n=strat.phase1_indices.size)
