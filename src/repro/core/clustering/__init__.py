"""Clustering for stratification: k-means, random projection, standardize."""

from .kmeans import (BackendFallbackWarning, KMeansBank, KMeansResult,
                     ResolvedBackend, best_of, kmeans, kmeans_bank,
                     kmeans_batch, kmeans_multi_seed, resolve_backend)
from .random_projection import projection_matrix, random_project
from .standardize import Standardizer

__all__ = [
    "kmeans", "kmeans_batch", "kmeans_bank", "kmeans_multi_seed", "best_of",
    "KMeansResult", "KMeansBank",
    "resolve_backend", "ResolvedBackend", "BackendFallbackWarning",
    "random_project", "projection_matrix", "Standardizer",
]
