"""Clustering for stratification: k-means, random projection, standardize."""

from .kmeans import (KMeansBank, KMeansResult, best_of, kmeans, kmeans_bank,
                     kmeans_batch, kmeans_multi_seed)
from .random_projection import projection_matrix, random_project
from .standardize import Standardizer

__all__ = [
    "kmeans", "kmeans_batch", "kmeans_bank", "kmeans_multi_seed", "best_of",
    "KMeansResult", "KMeansBank",
    "random_project", "projection_matrix", "Standardizer",
]
