"""k-means clustering in JAX (SimPoint's stratification step).

Design notes
------------
* kmeans++ initialization, Lloyd iterations inside ``lax.while_loop`` —
  the whole fit is one jitted computation.
* Pluggable assignment backend: ``"jnp"`` (pure jnp, the oracle) or
  ``"pallas"`` (the batch-native tiled TPU kernel in
  ``repro.kernels.kmeans_assign``). Requesting ``"pallas"`` off-TPU falls
  back with a one-time ``BackendFallbackWarning`` naming the reason
  (platform → interpret mode, import failure → jnp oracle); the backend
  that actually ran is recorded on every fit result.
* Empty clusters are re-seeded to the point farthest from its centroid —
  standard practice; keeps L strata non-empty, which the stratified
  estimators require.
* The paper repeats clustering with 10 seeds for the stochastic schemes
  (Fig 7); ``kmeans_multi_seed`` supports that and best-of-N selection.
* ALL fits route through ONE natively-stacked Lloyd loop
  (``_kmeans_fit_stacked``): the key/restart axis of ``kmeans_batch`` and
  the app axis of ``kmeans_bank`` are a real leading array axis of every
  step — assignment is one batched kernel dispatch over a ``(batch,
  tile)`` grid, never a vmap of ``pallas_call``. Only the pure-jnp
  seeding/update steps are vmapped (array ops, free to batch). Converged
  lanes are frozen with per-lane masks, reproducing exactly what
  ``vmap(while_loop)`` used to do, so per-lane results match an unbatched
  fit with the same key.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# Backend policy is shared across kernels (repro.kernels.backend); the
# historic import sites (`from repro.core.clustering.kmeans import
# BackendFallbackWarning, resolve_backend, _reset_backend_warnings`)
# keep working through these aliases.
from repro.kernels.backend import (BackendFallbackWarning,  # noqa: F401
                                   ResolvedBackend)
from repro.kernels.backend import \
    reset_backend_warnings as _reset_backend_warnings  # noqa: F401
from repro.kernels.backend import resolve_backend as _resolve_shared


def _probe_kmeans_kernel() -> None:
    from repro.kernels.kmeans_assign import ops as _ops  # noqa: F401


def resolve_backend(requested: str) -> ResolvedBackend:
    """Map a requested assignment backend to the one that can run here.

    ``"jnp"`` always resolves to itself. ``"pallas"`` resolves to
    ``"pallas"`` on TPU, to ``"pallas_interpret"`` (same kernel, Pallas
    interpreter — correctness validation, not speed) on other platforms,
    and to ``"jnp"`` when the kernel package cannot be imported. Any
    fallback emits a one-time ``BackendFallbackWarning`` naming the
    reason (shared policy: ``repro.kernels.backend``).
    """
    if requested not in ("jnp", "pallas"):
        raise ValueError(f"unknown backend {requested!r}; "
                         "expected 'jnp' or 'pallas'")
    return _resolve_shared(requested, kernel="k-means assignment",
                           import_probe=_probe_kmeans_kernel)


@dataclasses.dataclass(frozen=True)
class KMeansResult:
    """One fitted stratification.

    ``backend`` records the assignment backend that actually ran
    (``resolve_backend``'s ``active`` value), so benchmarks/tests can
    assert which path produced the fit.
    """

    centroids: np.ndarray   # (k, d)
    labels: np.ndarray      # (n,)
    inertia: float          # sum of squared distances to assigned centroid
    iterations: int
    backend: str = "jnp"    # active assignment backend ("jnp" | "pallas*")


def _assign_jnp_stacked(x: jax.Array, centroids: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Batched oracle assignment: (B, n, d) x (B, k, d) -> (B, n) pairs."""
    x2 = jnp.sum(x * x, axis=2, keepdims=True)           # (B, n, 1)
    c2 = jnp.sum(centroids * centroids, axis=2)          # (B, k)
    # dist2 = |x|^2 - 2 x.c^T + |c|^2 : the x.c^T matmul is the MXU hot spot.
    xc = jnp.einsum("bnd,bkd->bnk", x, centroids)
    d2 = x2 - 2.0 * xc + c2[:, None, :]
    labels = jnp.argmin(d2, axis=2)
    return labels, jnp.maximum(jnp.min(d2, axis=2), 0.0)


def _assign_jnp(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment, one ``(n, d)`` problem: lane 0 of the
    stacked oracle (single source of truth for the distance formulation).
    Kept for host-side callers (``repro.core.clustering.distributed``)."""
    labels, min_d2 = _assign_jnp_stacked(x[None], centroids[None])
    return labels[0], min_d2[0]


def _assign_pallas_stacked(x: jax.Array, centroids: jax.Array
                           ) -> tuple[jax.Array, jax.Array]:
    """Batched kernel assignment: ONE (batch, tile)-grid Pallas dispatch."""
    from repro.kernels.kmeans_assign import ops as _ops
    return _ops.kmeans_assign(x, centroids)


# active-backend name -> stacked assignment fn ((B,n,d),(B,k,d)) -> (B,n) x2
_ASSIGN = {
    "jnp": _assign_jnp_stacked,
    "pallas": _assign_pallas_stacked,
    "pallas_interpret": _assign_pallas_stacked,
}


def _update_centroids(x: jax.Array, labels: jax.Array, k: int,
                      old: jax.Array, w=None) -> jax.Array:
    """(Weighted) mean of assigned points; empty clusters keep their old
    centroid. ``w=None`` is the exact historic unweighted path."""
    xw = x if w is None else x * w[:, None]
    ones = jnp.ones((x.shape[0],), x.dtype) if w is None else w
    sums = jax.ops.segment_sum(xw, labels, num_segments=k)
    counts = jax.ops.segment_sum(ones, labels, num_segments=k)
    safe = jnp.maximum(counts, 1.0)
    means = sums / safe[:, None]
    return jnp.where((counts > 0)[:, None], means, old)


def _kmeanspp_init(key: jax.Array, x: jax.Array, k: int, w=None) -> jax.Array:
    """kmeans++ seeding (jit-friendly, O(k) passes).

    With point weights, selection probabilities are scaled by ``w`` so
    zero-weight (padded) rows are never chosen as seeds.
    """
    n = x.shape[0]

    def body(carry, i):
        key, centroids, min_d2 = carry
        key, sub = jax.random.split(key)
        scaled = min_d2 if w is None else min_d2 * w
        probs = scaled / jnp.maximum(scaled.sum(), 1e-30)
        idx = jax.random.choice(sub, n, p=probs)
        c_new = x[idx]
        centroids = centroids.at[i].set(c_new)
        d2_new = jnp.sum((x - c_new[None, :]) ** 2, axis=1)
        return (key, centroids, jnp.minimum(min_d2, d2_new)), None

    key, sub = jax.random.split(key)
    if w is None:
        first = x[jax.random.randint(sub, (), 0, n)]
    else:
        first = x[jax.random.choice(sub, n,
                                    p=w / jnp.maximum(w.sum(), 1e-30))]
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)
    min_d2 = jnp.sum((x - first[None, :]) ** 2, axis=1)
    (key, centroids, _), _ = jax.lax.scan(
        body, (key, centroids, min_d2), jnp.arange(1, k))
    return centroids


@functools.partial(jax.jit, static_argnames=("k", "max_iters", "backend",
                                             "tol"))
def _kmeans_fit_stacked(keys: jax.Array, x: jax.Array, k: int,
                        max_iters: int, backend: str, tol: float, w=None):
    """THE Lloyd loop: every lane of a (B, n, d) stack fit in one program.

    ``keys``: (B, ...) PRNG keys (one per lane); ``x``: (B, n, d) points —
    or (n, d) shared by all lanes, broadcast INSIDE the jitted program so
    callers never materialize B host-side copies; ``w``: optional (B, n)
    point weights. ``backend`` must be an ACTIVE
    backend name (see ``resolve_backend``). Assignment for all B lanes is
    one batched dispatch per Lloyd step — on the pallas backends that is a
    single ``(batch, tile)``-grid kernel launch, NOT a vmap of per-lane
    ``pallas_call``s. Per-lane ``active`` masks freeze converged lanes
    (state held, iteration counter stopped), replicating
    ``vmap(while_loop)`` semantics exactly: lane ``b``'s result is
    identical to an unbatched fit with ``keys[b]``.

    Returns ``(centroids (B, k, d), labels (B, n), inertia (B,),
    iterations (B,))``.
    """
    assign = _ASSIGN[backend]
    b = keys.shape[0]
    if x.ndim == 2:
        x = jnp.broadcast_to(x, (b,) + x.shape)

    if w is None:
        init = jax.vmap(
            lambda kk, xx: _kmeanspp_init(kk, xx, k))(keys, x)
    else:
        init = jax.vmap(
            lambda kk, xx, ww: _kmeanspp_init(kk, xx, k, ww))(keys, x, w)

    update = jax.vmap(
        lambda xx, ll, old, ww: _update_centroids(xx, ll, k, old, ww),
        in_axes=(0, 0, 0, None if w is None else 0))

    def cond(state):
        _, _, it, shift = state
        return jnp.any(jnp.logical_and(it < max_iters, shift > tol))

    def body(state):
        centroids, labels, it, shift = state
        active = jnp.logical_and(it < max_iters, shift > tol)   # (B,)
        new_labels, _ = assign(x, centroids)
        new_c = update(x, new_labels, centroids, w)
        new_shift = jnp.max(jnp.sum((new_c - centroids) ** 2, axis=2),
                            axis=1)
        centroids = jnp.where(active[:, None, None], new_c, centroids)
        labels = jnp.where(active[:, None], new_labels, labels)
        shift = jnp.where(active, new_shift, shift)
        it = it + active.astype(it.dtype)
        return centroids, labels, it, shift

    labels0, _ = assign(x, init)
    state = (init, labels0, jnp.zeros((b,), jnp.int32),
             jnp.full((b,), jnp.inf, x.dtype))
    centroids, labels, iters, _ = jax.lax.while_loop(cond, body, state)
    labels, min_d2 = assign(x, centroids)
    inertia = min_d2.sum(axis=1) if w is None else (min_d2 * w).sum(axis=1)
    return centroids, labels, inertia, iters


@functools.partial(jax.jit, static_argnames=("k", "max_iters", "backend",
                                             "tol"))
def _kmeans_fit(key: jax.Array, x: jax.Array, k: int, max_iters: int,
                backend: str, tol: float, w=None):
    """Single (n, d) fit: lane 0 of the stacked loop with B=1."""
    out = _kmeans_fit_stacked(key[None], x[None], k, max_iters, backend,
                              tol, None if w is None else w[None])
    return jax.tree.map(lambda o: o[0], out)


def _as_key_batch(keys, seeds) -> jax.Array:
    if (keys is None) == (seeds is None):
        raise ValueError("pass exactly one of keys= or seeds=")
    if keys is None:
        keys = [jax.random.PRNGKey(int(s)) for s in seeds]
    if not isinstance(keys, jax.Array):
        keys = jnp.stack(list(keys))
    if keys.ndim == 1:
        keys = keys[None, :]
    return keys


def kmeans_batch(
    features,
    k: int,
    *,
    keys=None,
    seeds=None,
    max_iters: int = 100,
    backend: str = "jnp",
    tol: float = 1e-8,
) -> list[KMeansResult]:
    """Batched k-means: one fit per key/seed as a single stacked program.

    Equivalent to ``[kmeans(features, k, key=key) for key in keys]`` but
    compiled and dispatched once (the paper's 10-seed repetitions for
    Figs 7-8 and best-of-N restarts): the key axis is a native leading
    batch axis of the Lloyd loop, so assignment runs the batch-grid
    kernel (backend ``"pallas"``) or one batched einsum (``"jnp"``).
    Returns one ``KMeansResult`` per key, in key order, each carrying the
    ``backend`` that actually ran.
    """
    x = jnp.asarray(features, dtype=jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"expected (n, d), got {x.shape}")
    if k < 1 or k > x.shape[0]:
        raise ValueError(f"k={k} invalid for n={x.shape[0]}")
    kb = _as_key_batch(keys, seeds)
    resolved = resolve_backend(backend)
    centroids, labels, inertia, iters = _kmeans_fit_stacked(
        kb, x, k, max_iters, resolved.active, tol)
    centroids, labels = np.asarray(centroids), np.asarray(labels)
    return [
        KMeansResult(centroids=centroids[i], labels=labels[i],
                     inertia=float(inertia[i]), iterations=int(iters[i]),
                     backend=resolved.active)
        for i in range(kb.shape[0])
    ]


def kmeans(
    features,
    k: int,
    *,
    key: Optional[jax.Array] = None,
    seed: int = 0,
    max_iters: int = 100,
    backend: str = "jnp",
    tol: float = 1e-8,
    restarts: int = 1,
) -> KMeansResult:
    """Fit k-means; returns numpy-backed result (host-side strata labels).

    ``restarts`` > 1 runs several kmeans++ initializations and keeps the
    lowest-inertia fit (Lloyd can land in local minima even on perfectly
    separated data). ``result.backend`` records the active assignment
    backend after ``resolve_backend`` (a requested ``"pallas"`` may fall
    back off-TPU, with a one-time ``BackendFallbackWarning``).
    """
    x = jnp.asarray(features, dtype=jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"expected (n, d), got {x.shape}")
    n = x.shape[0]
    if k < 1 or k > n:
        raise ValueError(f"k={k} invalid for n={n}")
    if key is None:
        key = jax.random.PRNGKey(seed)
    if restarts <= 1:
        # restarts=1 consumes the caller's key directly (stable results for
        # seeded single-fit callers); multi-restart splits per attempt.
        resolved = resolve_backend(backend)
        centroids, labels, inertia, iters = _kmeans_fit(
            key, x, k, max_iters, resolved.active, tol)
        return KMeansResult(
            centroids=np.asarray(centroids),
            labels=np.asarray(labels),
            inertia=float(inertia),
            iterations=int(iters),
            backend=resolved.active,
        )
    subs = []
    for _ in range(restarts):
        key, sub = jax.random.split(key)
        subs.append(sub)
    return best_of(kmeans_batch(x, k, keys=jnp.stack(subs),
                                max_iters=max_iters, backend=backend,
                                tol=tol))


def kmeans_multi_seed(
    features,
    k: int,
    *,
    seeds,
    max_iters: int = 100,
    backend: str = "jnp",
) -> list[KMeansResult]:
    """One fit per seed (the paper's 10-seed repetitions for Figs 7-8),
    batched into a single stacked computation."""
    return kmeans_batch(features, k, seeds=list(seeds), max_iters=max_iters,
                        backend=backend)


def best_of(results: list[KMeansResult]) -> KMeansResult:
    """The lowest-inertia fit of a batch."""
    return min(results, key=lambda r: r.inertia)


@dataclasses.dataclass(frozen=True)
class KMeansBank:
    """Stacked per-app fits: one lane per dataset of an (A, n, d) stack.

    ``backend`` is the active assignment backend the whole bank ran on.
    """

    centroids: np.ndarray   # (A, k, d)
    labels: np.ndarray      # (A, n)
    inertia: np.ndarray     # (A,)
    iterations: np.ndarray  # (A,)
    backend: str = "jnp"    # active assignment backend ("jnp" | "pallas*")

    def __len__(self) -> int:
        return int(self.labels.shape[0])

    def lane(self, a: int, n_valid: Optional[int] = None) -> KMeansResult:
        """Lane ``a`` as a single ``KMeansResult`` (labels cut to
        ``n_valid`` when the lane was padded)."""
        end = self.labels.shape[1] if n_valid is None else int(n_valid)
        return KMeansResult(centroids=self.centroids[a],
                            labels=self.labels[a, :end],
                            inertia=float(self.inertia[a]),
                            iterations=int(self.iterations[a]),
                            backend=self.backend)


def kmeans_bank(
    features,
    k: int,
    *,
    weights=None,
    key: Optional[jax.Array] = None,
    seed: int = 0,
    max_iters: int = 100,
    backend: str = "jnp",
    tol: float = 1e-8,
    mesh=None,
) -> KMeansBank:
    """One k-means fit per DATASET lane of an ``(A, n, d)`` stack.

    This is the app-axis companion of ``kmeans_batch`` (which stacks over
    seeds for one dataset): every lane fits its own point set with its own
    point ``weights`` (weight 0 = padded row, never seeds a centroid and
    never moves one — how ragged per-app populations share one stack).
    All lanes share the same PRNG ``key``/``seed`` so lane ``a`` matches a
    single-dataset weighted fit with that key. The app axis is a native
    batch axis of the Lloyd loop — with ``backend="pallas"`` every
    assignment step is ONE ``(batch, tile)``-grid kernel launch for all
    lanes. With ``mesh`` (a 1-D ``("app",)`` mesh) lanes run
    device-parallel; per-lane results are identical to the single-device
    run because lanes never interact.
    """
    x = jnp.asarray(features, jnp.float32)
    if x.ndim != 3:
        raise ValueError(f"expected (A, n, d), got {x.shape}")
    if k < 1 or k > x.shape[1]:
        raise ValueError(f"k={k} invalid for n={x.shape[1]}")
    w = jnp.ones(x.shape[:2], x.dtype) if weights is None else \
        jnp.asarray(weights, jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(seed)

    resolved = resolve_backend(backend)
    fit = _bank_fit_fn(k, max_iters, resolved.active, tol)
    if mesh is None:
        out = fit(key, x, w)
    else:
        from ...distributed.appaxis import app_sharded_cached
        out = app_sharded_cached(fit, mesh, (0,))(key, x, w)
    centroids, labels, inertia, iters = (np.asarray(o) for o in out)
    return KMeansBank(centroids=centroids, labels=labels, inertia=inertia,
                      iterations=iters, backend=resolved.active)


@functools.lru_cache(maxsize=None)
def _bank_fit_fn(k: int, max_iters: int, backend: str, tol: float):
    """Stable (cacheable) stacked bank fit: one compile per parameter set,
    shared by the single-device and shard_map paths. The shared key is
    broadcast to one key per lane; the lane axis is the stacked loop's
    native batch axis (``backend`` must already be resolved/active)."""
    def fit(key, xa, wa):
        keys = jnp.broadcast_to(key, (xa.shape[0],) + key.shape)
        return _kmeans_fit_stacked(keys, xa, k, max_iters, backend, tol, wa)
    return fit
