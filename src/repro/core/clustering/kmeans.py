"""k-means clustering in JAX (SimPoint's stratification step).

Design notes
------------
* kmeans++ initialization, Lloyd iterations inside ``lax.while_loop`` —
  the whole fit is one jitted computation.
* Pluggable assignment backend: ``"jnp"`` (pure jnp, the oracle) or
  ``"pallas"`` (the tiled TPU kernel in ``repro.kernels.kmeans_assign``,
  run with interpret=True on CPU). Both produce identical assignments.
* Empty clusters are re-seeded to the point farthest from its centroid —
  standard practice; keeps L strata non-empty, which the stratified
  estimators require.
* The paper repeats clustering with 10 seeds for the stochastic schemes
  (Fig 7); ``kmeans_multi_seed`` supports that and best-of-N selection.
* ``kmeans_batch`` vmaps the whole fit over a key axis so multi-seed /
  multi-restart studies run as ONE batched XLA computation (one compile,
  one dispatch) instead of a Python loop of fits. ``kmeans_multi_seed``
  and ``restarts > 1`` route through it.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class KMeansResult:
    centroids: np.ndarray   # (k, d)
    labels: np.ndarray      # (n,)
    inertia: float          # sum of squared distances to assigned centroid
    iterations: int


def _assign_jnp(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment. Returns (labels, min_dist2)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)           # (n, 1)
    c2 = jnp.sum(centroids * centroids, axis=1)          # (k,)
    # dist2 = |x|^2 - 2 x.c^T + |c|^2 : the x.c^T matmul is the MXU hot spot.
    d2 = x2 - 2.0 * (x @ centroids.T) + c2[None, :]
    labels = jnp.argmin(d2, axis=1)
    return labels, jnp.maximum(jnp.min(d2, axis=1), 0.0)


def _assign_pallas(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    from repro.kernels.kmeans_assign import ops as _ops
    return _ops.kmeans_assign(x, centroids)


_ASSIGN = {"jnp": _assign_jnp, "pallas": _assign_pallas}


def _update_centroids(x: jax.Array, labels: jax.Array, k: int,
                      old: jax.Array, w=None) -> jax.Array:
    """(Weighted) mean of assigned points; empty clusters keep their old
    centroid. ``w=None`` is the exact historic unweighted path."""
    xw = x if w is None else x * w[:, None]
    ones = jnp.ones((x.shape[0],), x.dtype) if w is None else w
    sums = jax.ops.segment_sum(xw, labels, num_segments=k)
    counts = jax.ops.segment_sum(ones, labels, num_segments=k)
    safe = jnp.maximum(counts, 1.0)
    means = sums / safe[:, None]
    return jnp.where((counts > 0)[:, None], means, old)


def _kmeanspp_init(key: jax.Array, x: jax.Array, k: int, w=None) -> jax.Array:
    """kmeans++ seeding (jit-friendly, O(k) passes).

    With point weights, selection probabilities are scaled by ``w`` so
    zero-weight (padded) rows are never chosen as seeds.
    """
    n = x.shape[0]

    def body(carry, i):
        key, centroids, min_d2 = carry
        key, sub = jax.random.split(key)
        scaled = min_d2 if w is None else min_d2 * w
        probs = scaled / jnp.maximum(scaled.sum(), 1e-30)
        idx = jax.random.choice(sub, n, p=probs)
        c_new = x[idx]
        centroids = centroids.at[i].set(c_new)
        d2_new = jnp.sum((x - c_new[None, :]) ** 2, axis=1)
        return (key, centroids, jnp.minimum(min_d2, d2_new)), None

    key, sub = jax.random.split(key)
    if w is None:
        first = x[jax.random.randint(sub, (), 0, n)]
    else:
        first = x[jax.random.choice(sub, n,
                                    p=w / jnp.maximum(w.sum(), 1e-30))]
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)
    min_d2 = jnp.sum((x - first[None, :]) ** 2, axis=1)
    (key, centroids, _), _ = jax.lax.scan(
        body, (key, centroids, min_d2), jnp.arange(1, k))
    return centroids


@functools.partial(jax.jit, static_argnames=("k", "max_iters", "backend", "tol"))
def _kmeans_fit(key: jax.Array, x: jax.Array, k: int, max_iters: int,
                backend: str, tol: float, w=None):
    assign = _ASSIGN[backend]
    init = _kmeanspp_init(key, x, k, w)

    def cond(state):
        _, _, it, shift = state
        return jnp.logical_and(it < max_iters, shift > tol)

    def body(state):
        centroids, _, it, _ = state
        labels, _ = assign(x, centroids)
        new_c = _update_centroids(x, labels, k, centroids, w)
        shift = jnp.max(jnp.sum((new_c - centroids) ** 2, axis=1))
        return new_c, labels, it + 1, shift

    labels0, _ = assign(x, init)
    state = (init, labels0, jnp.asarray(0), jnp.asarray(jnp.inf, x.dtype))
    centroids, labels, iters, _ = jax.lax.while_loop(cond, body, state)
    labels, min_d2 = assign(x, centroids)
    inertia = min_d2.sum() if w is None else (min_d2 * w).sum()
    return centroids, labels, inertia, iters


@functools.partial(jax.jit,
                   static_argnames=("k", "max_iters", "backend", "tol"))
def _kmeans_fit_batch(keys: jax.Array, x: jax.Array, k: int, max_iters: int,
                      backend: str, tol: float):
    """All fits in one program: vmap ``_kmeans_fit`` over the key axis.

    Under vmap the Lloyd ``while_loop`` runs until every lane converges;
    already-converged lanes keep their state frozen, so each lane's result
    is identical to an unbatched fit with the same key.
    """
    fit = lambda key: _kmeans_fit(key, x, k, max_iters, backend, tol)
    return jax.vmap(fit)(keys)


def _as_key_batch(keys, seeds) -> jax.Array:
    if (keys is None) == (seeds is None):
        raise ValueError("pass exactly one of keys= or seeds=")
    if keys is None:
        keys = [jax.random.PRNGKey(int(s)) for s in seeds]
    if not isinstance(keys, jax.Array):
        keys = jnp.stack(list(keys))
    if keys.ndim == 1:
        keys = keys[None, :]
    return keys


def kmeans_batch(
    features,
    k: int,
    *,
    keys=None,
    seeds=None,
    max_iters: int = 100,
    backend: str = "jnp",
    tol: float = 1e-8,
) -> list[KMeansResult]:
    """Batched k-means: one fit per key/seed as a single vmapped computation.

    Equivalent to ``[kmeans(features, k, key=key) for key in keys]`` but
    compiled and dispatched once (the paper's 10-seed repetitions for
    Figs 7-8 and best-of-N restarts). Returns one ``KMeansResult`` per key,
    in key order.
    """
    x = jnp.asarray(features, dtype=jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"expected (n, d), got {x.shape}")
    if k < 1 or k > x.shape[0]:
        raise ValueError(f"k={k} invalid for n={x.shape[0]}")
    kb = _as_key_batch(keys, seeds)
    centroids, labels, inertia, iters = _kmeans_fit_batch(
        kb, x, k, max_iters, backend, tol)
    centroids, labels = np.asarray(centroids), np.asarray(labels)
    return [
        KMeansResult(centroids=centroids[i], labels=labels[i],
                     inertia=float(inertia[i]), iterations=int(iters[i]))
        for i in range(kb.shape[0])
    ]


def kmeans(
    features,
    k: int,
    *,
    key: Optional[jax.Array] = None,
    seed: int = 0,
    max_iters: int = 100,
    backend: str = "jnp",
    tol: float = 1e-8,
    restarts: int = 1,
) -> KMeansResult:
    """Fit k-means; returns numpy-backed result (host-side strata labels).

    ``restarts`` > 1 runs several kmeans++ initializations and keeps the
    lowest-inertia fit (Lloyd can land in local minima even on perfectly
    separated data).
    """
    x = jnp.asarray(features, dtype=jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"expected (n, d), got {x.shape}")
    n = x.shape[0]
    if k < 1 or k > n:
        raise ValueError(f"k={k} invalid for n={n}")
    if key is None:
        key = jax.random.PRNGKey(seed)
    if restarts <= 1:
        # restarts=1 consumes the caller's key directly (stable results for
        # seeded single-fit callers); multi-restart splits per attempt.
        centroids, labels, inertia, iters = _kmeans_fit(
            key, x, k, max_iters, backend, tol)
        return KMeansResult(
            centroids=np.asarray(centroids),
            labels=np.asarray(labels),
            inertia=float(inertia),
            iterations=int(iters),
        )
    subs = []
    for _ in range(restarts):
        key, sub = jax.random.split(key)
        subs.append(sub)
    return best_of(kmeans_batch(x, k, keys=jnp.stack(subs),
                                max_iters=max_iters, backend=backend,
                                tol=tol))


def kmeans_multi_seed(
    features,
    k: int,
    *,
    seeds,
    max_iters: int = 100,
    backend: str = "jnp",
) -> list[KMeansResult]:
    """One fit per seed (the paper's 10-seed repetitions for Figs 7-8),
    batched into a single vmapped computation."""
    return kmeans_batch(features, k, seeds=list(seeds), max_iters=max_iters,
                        backend=backend)


def best_of(results: list[KMeansResult]) -> KMeansResult:
    return min(results, key=lambda r: r.inertia)


@dataclasses.dataclass(frozen=True)
class KMeansBank:
    """Stacked per-app fits: one lane per dataset of an (A, n, d) stack."""

    centroids: np.ndarray   # (A, k, d)
    labels: np.ndarray      # (A, n)
    inertia: np.ndarray     # (A,)
    iterations: np.ndarray  # (A,)

    def __len__(self) -> int:
        return int(self.labels.shape[0])

    def lane(self, a: int, n_valid: Optional[int] = None) -> KMeansResult:
        end = self.labels.shape[1] if n_valid is None else int(n_valid)
        return KMeansResult(centroids=self.centroids[a],
                            labels=self.labels[a, :end],
                            inertia=float(self.inertia[a]),
                            iterations=int(self.iterations[a]))


def kmeans_bank(
    features,
    k: int,
    *,
    weights=None,
    key: Optional[jax.Array] = None,
    seed: int = 0,
    max_iters: int = 100,
    backend: str = "jnp",
    tol: float = 1e-8,
    mesh=None,
) -> KMeansBank:
    """One k-means fit per DATASET lane of an ``(A, n, d)`` stack.

    This is the app-axis companion of ``kmeans_batch`` (which vmaps over
    seeds for one dataset): every lane fits its own point set with its own
    point ``weights`` (weight 0 = padded row, never seeds a centroid and
    never moves one — how ragged per-app populations share one stack).
    All lanes share the same PRNG ``key``/``seed`` so lane ``a`` matches a
    single-dataset weighted fit with that key. With ``mesh`` (a 1-D
    ``("app",)`` mesh) lanes run device-parallel; per-lane results are
    identical to the single-device vmap because lanes never interact
    (under vmap the Lloyd ``while_loop`` freezes converged lanes).
    """
    x = jnp.asarray(features, jnp.float32)
    if x.ndim != 3:
        raise ValueError(f"expected (A, n, d), got {x.shape}")
    if k < 1 or k > x.shape[1]:
        raise ValueError(f"k={k} invalid for n={x.shape[1]}")
    w = jnp.ones(x.shape[:2], x.dtype) if weights is None else \
        jnp.asarray(weights, jnp.float32)
    if key is None:
        key = jax.random.PRNGKey(seed)

    fit = _bank_fit_fn(k, max_iters, backend, tol)
    if mesh is None:
        out = fit(key, x, w)
    else:
        from ...distributed.appaxis import app_sharded_cached
        out = app_sharded_cached(fit, mesh, (0,))(key, x, w)
    centroids, labels, inertia, iters = (np.asarray(o) for o in out)
    return KMeansBank(centroids=centroids, labels=labels, inertia=inertia,
                      iterations=iters)


@functools.lru_cache(maxsize=None)
def _bank_fit_fn(k: int, max_iters: int, backend: str, tol: float):
    """Stable (cacheable) vmapped bank fit: one compile per parameter set,
    shared by the single-device and shard_map paths."""
    def fit(key, xa, wa):
        return _kmeans_fit(key, xa, k, max_iters, backend, tol, wa)
    return jax.vmap(fit, in_axes=(None, 0, 0))
