"""Random projection for BBV dimensionality reduction (SimPoint step 2).

SimPoint projects the (very high-dimensional, sparse) basic block vectors
down to ~15 dimensions before k-means. We use a dense Gaussian projection
scaled by 1/sqrt(d_out) (Johnson-Lindenstrauss); the paper notes RFVs are
low-dimensional enough (38) that projection is skipped for them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def projection_matrix(key: jax.Array, d_in: int, d_out: int,
                      dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (d_in, d_out), dtype) / jnp.sqrt(
        jnp.asarray(d_out, dtype))


def random_project(
    features: jax.Array,
    d_out: int,
    *,
    key: jax.Array,
    normalize_rows: bool = True,
) -> jax.Array:
    """Project (n, d_in) -> (n, d_out).

    ``normalize_rows`` first L1-normalizes each BBV (SimPoint treats BBVs as
    frequency distributions so region length doesn't dominate distances).
    """
    x = jnp.asarray(features)
    if x.ndim != 2:
        raise ValueError(f"expected (n, d) matrix, got {x.shape}")
    if normalize_rows:
        norm = jnp.maximum(jnp.abs(x).sum(axis=1, keepdims=True), 1e-12)
        x = x / norm
    proj = projection_matrix(key, x.shape[1], d_out, x.dtype)
    return x @ proj
