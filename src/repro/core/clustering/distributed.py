"""Distributed k-means via shard_map (multi-pod stratification).

The paper's §VII.B scalability argument: instead of clustering BBVs for the
*entire* application, cluster a large (≈100 k) phase-1 random sample. At
fleet scale even that benefits from data-parallel clustering: points are
sharded across the ("pod", "data") mesh axes, every device computes local
assignments and local per-cluster (sum, count, sumsq) statistics, and a
single ``psum`` per Lloyd iteration reduces them — the classic
communication-optimal distributed k-means: collective bytes per iteration
are O(k·d), independent of n.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .kmeans import _assign_jnp

# version-compat shard_map shim shared with the app-axis sharding helpers
from ...distributed.appaxis import shard_map as _shard_map


def _local_stats(x, centroids, k):
    labels, min_d2 = _assign_jnp(x, centroids)
    ones = jnp.ones((x.shape[0],), x.dtype)
    sums = jax.ops.segment_sum(x, labels, num_segments=k)
    counts = jax.ops.segment_sum(ones, labels, num_segments=k)
    return labels, sums, counts, min_d2.sum()


def make_distributed_kmeans_step(mesh: Mesh, data_axes: Sequence[str], k: int):
    """Build a jitted one-Lloyd-iteration function over a sharded point set.

    Inputs: x sharded (n/devices, d) along ``data_axes``; centroids
    replicated (k, d). Output: new centroids (replicated), global inertia.
    """
    axes = tuple(data_axes)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(axes), P()),
        out_specs=(P(), P()),
    )
    def step(x_local, centroids):
        _, sums, counts, inertia = _local_stats(x_local, centroids, k)
        sums = jax.lax.psum(sums, axes)          # (k, d) — O(k d) bytes
        counts = jax.lax.psum(counts, axes)      # (k,)
        inertia = jax.lax.psum(inertia, axes)
        safe = jnp.maximum(counts, 1.0)
        new_c = jnp.where((counts > 0)[:, None], sums / safe[:, None], centroids)
        return new_c, inertia

    return jax.jit(step)


def make_distributed_assign(mesh: Mesh, data_axes: Sequence[str]):
    """Sharded final assignment: labels stay sharded with their points."""
    axes = tuple(data_axes)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(axes), P()),
        out_specs=P(axes),
    )
    def assign(x_local, centroids):
        labels, _ = _assign_jnp(x_local, centroids)
        return labels

    return jax.jit(assign)


def distributed_kmeans(
    x,
    k: int,
    mesh: Mesh,
    *,
    data_axes: Sequence[str] = ("data",),
    iters: int = 25,
    seed: int = 0,
):
    """Convenience driver: shard x, init from first k points of a shuffled
    copy (cheap deterministic init; kmeans++ is host-side in kmeans.py),
    run ``iters`` Lloyd steps, return (centroids, labels, inertia)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    sharding = NamedSharding(mesh, P(tuple(data_axes)))
    # kmeans++ init on a host subsample (cheap), refined distributed
    from .kmeans import kmeans as _kmeans
    sub = np.asarray(x[:min(n, 8192)])
    centroids = jnp.asarray(_kmeans(sub, k, seed=seed, max_iters=1,
                                    restarts=2).centroids)
    x = jax.device_put(x, sharding)

    step = make_distributed_kmeans_step(mesh, data_axes, k)
    inertia = jnp.inf
    for _ in range(iters):
        centroids, inertia = step(x, centroids)
    assign = make_distributed_assign(mesh, data_axes)
    labels = assign(x, centroids)
    return centroids, labels, float(inertia)
