"""Feature standardization for RFV clustering (paper IV.B: "we did
standardize the values")."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Standardizer:
    """Column-wise z-score transform fitted on phase-1 data.

    Constant columns get scale 1 so they map to 0 instead of NaN (several
    Table III counters are exactly zero for some configs, e.g. prefetcher
    stats when the prefetcher is disabled).
    """

    mean: np.ndarray
    scale: np.ndarray

    @staticmethod
    def fit(features) -> "Standardizer":
        arr = np.asarray(features, dtype=np.float64)
        if arr.ndim != 2:
            raise ValueError(f"expected (n, d) matrix, got {arr.shape}")
        mean = arr.mean(axis=0)
        std = arr.std(axis=0)
        scale = np.where(std > 1e-12, std, 1.0)
        return Standardizer(mean=mean, scale=scale)

    def transform(self, features):
        arr = jnp.asarray(features)
        return (arr - self.mean.astype(arr.dtype)) / self.scale.astype(arr.dtype)

    @staticmethod
    def fit_transform(features) -> tuple["Standardizer", jnp.ndarray]:
        st = Standardizer.fit(features)
        return st, st.transform(features)
