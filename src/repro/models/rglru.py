"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Real-Gated Linear Recurrent Unit over a width-``rnn_width`` channel state:

    r_t = sigmoid(W_r x_t + b_r)           (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)           (input gate)
    a_t = a^(c * r_t)   with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is a diagonal affine map, hence ASSOCIATIVE — training and
prefill run it with ``jax.lax.associative_scan`` (log-depth, TPU-friendly),
decode with a single fused step. Used inside the Griffin residual block:
conv1d(width 4) -> RG-LRU -> gated output projection, alternating with
local sliding-window attention in a (R, R, A) pattern.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import KeyGen, ModelConfig, leaf

_C = 8.0


class RglruState(NamedTuple):
    h: jax.Array          # (b, w) recurrent state
    conv: jax.Array       # (b, 3, w) last conv inputs (kernel 4)


def init_rglru(cfg: ModelConfig, kg: KeyGen) -> dict:
    d = cfg.d_model
    w = cfg.rnn_width or d
    return {
        "w_in": leaf((d, w), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_gate_in": leaf((d, w), cfg.dtype, abstract=kg.abstract, key=kg()),
        "conv_k": leaf((4, w), cfg.dtype, abstract=kg.abstract, key=kg(),
                       scale=0.2),
        "w_r": leaf((w, w), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_i": leaf((w, w), cfg.dtype, abstract=kg.abstract, key=kg()),
        "lam": leaf((w,), jnp.float32, abstract=kg.abstract, key=kg(),
                    scale=1.0),
        "w_out": leaf((w, d), cfg.dtype, abstract=kg.abstract, key=kg()),
    }


def _gates(params, u):
    """u: (b, s, w) post-conv activations -> (a, gated_in) f32."""
    r = jax.nn.sigmoid((u @ params["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_i"]).astype(jnp.float32))
    log_a0 = jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    log_a = _C * r * log_a0[None, None, :]           # (b, s, w), <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, beta * i * u.astype(jnp.float32)


def _conv(params, u, carry):
    """Causal conv1d width 4. u: (b, s, w); carry: (b, 3, w)."""
    ext = jnp.concatenate([carry.astype(u.dtype), u], axis=1)
    k = params["conv_k"]
    out = (ext[:, 3:] * k[3] + ext[:, 2:-1] * k[2] +
           ext[:, 1:-2] * k[1] + ext[:, :-3] * k[0])
    return out, ext[:, -3:]


def rglru_block(params: dict, x: jax.Array, cfg: ModelConfig,
                state: RglruState) -> tuple[jax.Array, RglruState]:
    """Griffin recurrent residual branch. x: (b, s, d)."""
    u = x @ params["w_in"]                           # (b, s, w)
    gate = jax.nn.gelu((x @ params["w_gate_in"]).astype(jnp.float32))
    u, conv_carry = _conv(params, u, state.conv)
    a, bx = _gates(params, u)

    # associative scan over the diagonal affine recurrence
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    h0 = state.h.astype(jnp.float32)
    # fold h0 into the first element
    bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)
    a_scan, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    out = (h * gate).astype(x.dtype) @ params["w_out"]
    return out, RglruState(h=h[:, -1, :].astype(state.h.dtype),
                           conv=conv_carry)


def rglru_step(params: dict, x: jax.Array, cfg: ModelConfig,
               state: RglruState) -> tuple[jax.Array, RglruState]:
    """Single-token decode. x: (b, 1, d)."""
    u = x @ params["w_in"]
    gate = jax.nn.gelu((x @ params["w_gate_in"]).astype(jnp.float32))
    u, conv_carry = _conv(params, u, state.conv)
    a, bx = _gates(params, u)
    h = a[:, 0] * state.h.astype(jnp.float32) + bx[:, 0]
    out = (h[:, None, :] * gate).astype(x.dtype) @ params["w_out"]
    return out, RglruState(h=h.astype(state.h.dtype), conv=conv_carry)


def make_rglru_state(cfg: ModelConfig, batch: int, n_layers: int,
                     *, abstract: bool = False) -> RglruState:
    w = cfg.rnn_width or cfg.d_model
    h_shape = (n_layers, batch, w)
    c_shape = (n_layers, batch, 3, w)
    if abstract:
        return RglruState(jax.ShapeDtypeStruct(h_shape, jnp.float32),
                          jax.ShapeDtypeStruct(c_shape, cfg.dtype))
    return RglruState(jnp.zeros(h_shape, jnp.float32),
                      jnp.zeros(c_shape, cfg.dtype))
