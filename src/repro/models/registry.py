"""Model registry: family-dispatching build/apply functions."""

from __future__ import annotations

from typing import Optional

import jax

from . import encdec, transformer
from .common import ModelConfig


def init_params(cfg: ModelConfig, key: Optional[jax.Array] = None,
                *, abstract: bool = False):
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key, abstract=abstract)
    return transformer.init_lm(cfg, key, abstract=abstract)


def loss_fn(cfg: ModelConfig):
    """(params, batch) -> scalar loss, matching the family's batch schema."""
    if cfg.family == "encdec":
        return lambda p, b: encdec.encdec_loss(p, b, cfg)
    return lambda p, b: transformer.lm_loss(p, b, cfg)


def forward_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        return lambda p, b: encdec.forward_encdec(
            p, b["src_embeds"], b["tokens"], cfg)
    return lambda p, b: transformer.forward(p, b["tokens"], cfg)


def make_decode_state(cfg: ModelConfig, batch: int, s_max: int,
                      *, s_src: int = 0, abstract: bool = False):
    if cfg.family == "encdec":
        return encdec.make_encdec_caches(cfg, batch, s_max, s_src or 128,
                                         abstract=abstract)
    return transformer.make_decode_caches(cfg, batch, s_max,
                                          abstract=abstract)


def decode_fn(cfg: ModelConfig):
    """(params, tokens, caches, pos) -> (logits, caches)."""
    if cfg.family == "encdec":
        return lambda p, t, c, pos: encdec.decode_step_encdec(p, t, c, pos, cfg)
    return lambda p, t, c, pos: transformer.decode_step(p, t, c, pos, cfg)
