"""Shared model-definition utilities (pure JAX, functional params-as-pytree).

Conventions:
* params are nested dicts of jnp arrays; per-layer params are STACKED on a
  leading (n_layers,) axis and consumed by ``jax.lax.scan`` — one layer
  trace regardless of depth (compile time stays flat in n_layers, which is
  what makes the 94-layer Qwen3-MoE dry-run tractable);
* ``abstract=True`` init builds jax.ShapeDtypeStruct trees (for
  ``jit.lower`` dry-runs — no host allocation);
* activations/params default to bf16 for full configs, f32 for smoke.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_capacity_factor: float = 1.25
    # hybrid (RG-LRU) / local attention
    window: Optional[int] = None         # local-attention width
    rnn_width: Optional[int] = None      # RG-LRU recurrence width
    hybrid_period: int = 3               # (R, R, A) repeating pattern
    # ssm (RWKV6)
    rwkv_head_dim: int = 64
    # enc-dec
    encoder_layers: int = 0              # 0 => decoder-only
    # misc
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    # modality frontend stub: inputs are precomputed embeddings, not ids
    embed_frontend: bool = False

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (bounded attention state)."""
        return self.family in ("ssm", "hybrid")

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            ffn = self.moe_experts * 3 * d * f + d * self.moe_experts
        else:
            ffn = 3 * d * f
        qkvo = d * (self.n_heads * self.head_dim) * 2 + \
            d * (self.n_kv_heads * self.head_dim) * 2
        per_layer = ffn + qkvo + 2 * d
        total = emb + self.n_layers * per_layer
        if self.encoder_layers:
            total += self.encoder_layers * per_layer
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_ffn = self.moe_topk * 3 * d * f
        moe_ffn = self.moe_experts * 3 * d * f
        return int(self.param_count() - self.n_layers * (moe_ffn - dense_ffn))


def leaf(shape, dtype, *, abstract: bool, key=None, scale: float = 0.02):
    if abstract:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    if key is None:
        raise ValueError("concrete init needs a key")
    return (jax.random.normal(key, tuple(shape), jnp.float32) * scale
            ).astype(dtype)


class KeyGen:
    """Splittable key source usable in abstract mode (keys unused)."""

    def __init__(self, key: Optional[jax.Array], abstract: bool):
        self._key = key
        self.abstract = abstract

    def __call__(self) -> Optional[jax.Array]:
        if self.abstract:
            return None
        self._key, sub = jax.random.split(self._key)
        return sub


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt) * gamma.astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., s, h, d); positions: (s,) or (b, s)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs   # (..., s, half)
    cos = jnp.cos(angles)[..., None, :]    # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


def stack_layers(init_one: Callable[[], PyTree], n: int,
                 *, abstract: bool) -> PyTree:
    """Stack per-layer param trees along a leading axis for lax.scan."""
    layers = [init_one() for _ in range(n)]
    if abstract:
        return jax.tree.map(
            lambda *ls: jax.ShapeDtypeStruct((n,) + tuple(ls[0].shape),
                                             ls[0].dtype), *layers)
    return jax.tree.map(lambda *ls: jnp.stack(ls), *layers)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in f32. logits: (b, s, v); labels: (b, s).

    Vocab-parallel-safe form: the gold logit is selected with an iota
    compare + masked reduce instead of a gather, so a vocab-sharded logits
    tensor needs only a psum, never an all-gather (Megatron-style
    vocab-parallel loss).
    """
    from ..distributed.ctx import constrain
    logits = constrain(logits, "logits_v")
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0),
                   axis=-1)
    return jnp.mean(logz - gold)
