"""Mixture-of-Experts feed-forward with top-k token-choice routing.

GShard-style *grouped* formulation: tokens are split into G groups (G = the
mesh's data-parallel degree, 1 on a single host), each group routes its own
tokens with a per-group expert capacity — so the position-in-expert cumsum
never crosses a data shard, and the dispatch/combine gathers stay local to
a group. Under pjit:

* token groups carry P(dp, None, None); expert slot tensors carry
  P(dp, "model", None, None) — the reshard between the two IS the
  all-to-all of a production EP implementation, materialized by GSPMD;
* expert weights are sharded expert-wise on "model" AND FSDP-sharded on
  the data axes over d_model (qwen3-235B's 470 GB of bf16 expert weight
  becomes ~1.8 GB/device on a 16x16 mesh);
* FLOPs = top-k expert FLOPs only (gather/scatter dispatch, no
  (t, e, cap) dispatch-einsum blow-up).

Tokens beyond a group's capacity are dropped (capacity_factor 1.25,
GShard semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.ctx import constrain, moe_group_count
from .common import KeyGen, ModelConfig, leaf


def init_moe(cfg: ModelConfig, kg: KeyGen) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    return {
        "router": leaf((d, e), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_gate": leaf((e, d, f), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_up": leaf((e, d, f), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_down": leaf((e, f, d), cfg.dtype, abstract=kg.abstract, key=kg()),
    }


def moe(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    t = b * s
    g = moe_group_count()
    if t % g:
        g = 1
    tl = t // g                                           # tokens per group

    xt = constrain(x.reshape(g, tl, d), "gtd")
    scores = (xt @ params["router"]).astype(jnp.float32)  # (g, tl, e)
    gates, idx = jax.lax.top_k(scores, k)                 # (g, tl, k)
    gates = jax.nn.softmax(gates, axis=-1)

    cap = int(tl * k / e * cfg.moe_capacity_factor)
    cap = max(8, -(-cap // 8) * 8)                        # align 8

    flat_expert = idx.reshape(g, tl * k)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tl), k)[None], (g, tl * k))
    flat_gate = gates.reshape(g, tl * k)

    # Position of each (token, expert) pair within its expert's per-group
    # slots: cumsum of the one-hot along the group's token axis.
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (g, tl*k, e)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot       # exclusive
    slot = jnp.take_along_axis(pos_in_expert, flat_expert[..., None],
                               axis=2)[..., 0]                # (g, tl*k)
    keep = slot < cap
    safe_slot = jnp.where(keep, slot, cap)

    # Scatter tokens into per-group (e, cap) slot tables (drop -> slot cap).
    def build_tables(fe, ss, ft, fg):
        st = jnp.full((e, cap + 1), tl, jnp.int32)
        gt = jnp.zeros((e, cap + 1), jnp.float32)
        st = st.at[fe, ss].set(jnp.where(ss < cap, ft, tl))
        gt = gt.at[fe, ss].set(jnp.where(ss < cap, fg, 0.0))
        return st[:, :cap], gt[:, :cap]

    slot_table, gate_table = jax.vmap(build_tables)(
        constrain(flat_expert, "gt"), constrain(safe_slot, "gt"),
        flat_token, constrain(flat_gate, "gt"))
    slot_table = constrain(slot_table, "gec")
    gate_table = constrain(gate_table, "gec")

    # Gather token activations per expert slot: (g, e, cap, d); pad row = 0.
    xt_pad = jnp.concatenate([xt, jnp.zeros((g, 1, d), xt.dtype)], axis=1)
    xe = jax.vmap(lambda xp, st: xp[st])(xt_pad, slot_table)
    xe = constrain(xe, "gecd")      # <- the EP all-to-all happens here

    # Expert SwiGLU (einsum batched over experts -> MXU).
    gate_h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]
                                    ).astype(jnp.float32))
    up_h = jnp.einsum("gecd,edf->gecf", xe,
                      params["w_up"]).astype(jnp.float32)
    ye = jnp.einsum("gecf,efd->gecd", (gate_h * up_h).astype(x.dtype),
                    params["w_down"])                     # (g, e, cap, d)

    # Combine: gate-weighted scatter-add back to the group's tokens.
    ye_w = constrain(ye.astype(jnp.float32) * gate_table[..., None], "gecd")

    def combine(st, yw):
        return jnp.zeros((tl + 1, d), jnp.float32).at[
            st.reshape(-1)].add(yw.reshape(-1, d))[:tl]

    out = jax.vmap(combine)(slot_table, ye_w)             # (g, tl, d)
    out = constrain(out.astype(x.dtype), "gtd")
    return out.reshape(b, s, d)
