"""Decoder-only LM assembly for dense / MoE / SSM (RWKV6) / hybrid (Griffin).

Per-layer parameters are stacked on a leading (n_layers,) axis and the
layer stack runs under ``jax.lax.scan`` (+ optional ``jax.checkpoint``
remat), so trace/compile cost is depth-independent. Hybrid models scan over
(R, R, A) super-blocks with a remainder tail.

Decode paths thread explicit caches/states: KV cache for attention
families, RWKV state for ssm, RG-LRU state + ring-buffer local-attention
cache for hybrid — the ring buffer is why recurrentgemma's decode cost is
identical at 32 k and 500 k context.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..distributed.ctx import constrain
from .attention import attention, init_attention, make_kv_cache
from .common import (KeyGen, ModelConfig, cross_entropy_loss, leaf, rms_norm,
                     stack_layers)
from .mlp import init_mlp, mlp
from .moe import init_moe, moe
from .rglru import (RglruState, init_rglru, make_rglru_state, rglru_block,
                    rglru_step)
from .rwkv6 import (RwkvState, init_rwkv_channel_mix, init_rwkv_time_mix,
                    make_rwkv_state, rwkv_channel_mix, rwkv_time_mix_chunked,
                    rwkv_time_mix_step)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, kg: KeyGen) -> dict:
    d = cfg.d_model
    block = {
        "ln1": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
        "ln2": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
    }
    if cfg.family == "moe":
        block["attn"] = init_attention(cfg, kg)
        block["ffn"] = init_moe(cfg, kg)
    elif cfg.family == "ssm":
        block["tm"] = init_rwkv_time_mix(cfg, kg)
        block["cm"] = init_rwkv_channel_mix(cfg, kg)
    else:  # dense
        block["attn"] = init_attention(cfg, kg)
        block["ffn"] = init_mlp(cfg, kg)
    return block


def _init_hybrid_super(cfg: ModelConfig, kg: KeyGen) -> dict:
    """(R, R, A) super-block for Griffin-style hybrids."""
    d = cfg.d_model

    def rec():
        return {
            "ln": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
            "rglru": init_rglru(cfg, kg),
            "ln_ffn": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
            "ffn": init_mlp(cfg, kg),
        }

    return {
        "r0": rec(),
        "r1": rec(),
        "attn": {
            "ln": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
            "attn": init_attention(cfg, kg),
            "ln_ffn": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
            "ffn": init_mlp(cfg, kg),
        },
    }


def init_lm(cfg: ModelConfig, key: Optional[jax.Array] = None,
            *, abstract: bool = False) -> dict:
    kg = KeyGen(key if key is not None else (None if abstract else
                                             jax.random.PRNGKey(0)), abstract)
    d, v = cfg.d_model, cfg.vocab
    params: dict[str, Any] = {
        "embed": leaf((v, d), cfg.dtype, abstract=abstract, key=kg()),
        "final_norm": leaf((d,), jnp.float32, abstract=abstract, key=kg(),
                           scale=1.0),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = leaf((d, v), cfg.dtype, abstract=abstract, key=kg())
    if cfg.family == "hybrid":
        n_super, rem = divmod(cfg.n_layers, 3)
        params["supers"] = stack_layers(
            lambda: _init_hybrid_super(cfg, kg), n_super, abstract=abstract)
        params["tail"] = stack_layers(
            lambda: _init_hybrid_super(cfg, kg)["r0"], rem, abstract=abstract) \
            if rem else {}
    else:
        params["layers"] = stack_layers(
            lambda: _init_block(cfg, kg), cfg.n_layers, abstract=abstract)
    return params


# ---------------------------------------------------------------------------
# forward (training / prefill, no cache)
# ---------------------------------------------------------------------------


def _block_fwd(cfg: ModelConfig, layer: dict, x: jax.Array,
               positions: jax.Array) -> jax.Array:
    x = constrain(x, "bsd_batch_only" if cfg.family == "ssm" else "bsd")
    h = rms_norm(x, layer["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        b, s, d = x.shape
        st = RwkvState(
            s=jnp.zeros((b, d // cfg.rwkv_head_dim, cfg.rwkv_head_dim,
                         cfg.rwkv_head_dim), jnp.float32),
            x_prev=jnp.zeros((b, d), x.dtype))
        out, _ = rwkv_time_mix_chunked(layer["tm"], h, cfg, st)
        x = x + out
        h2 = rms_norm(x, layer["ln2"], cfg.norm_eps)
        out2, _ = rwkv_channel_mix(layer["cm"], h2,
                                   jnp.zeros((b, d), x.dtype))
        return x + out2
    att = attention(layer["attn"], h, cfg, positions, window=cfg.window
                    if cfg.family == "hybrid" else None)
    x = x + att
    h2 = rms_norm(x, layer["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        return x + moe(layer["ffn"], h2, cfg)
    return x + mlp(layer["ffn"], h2)


def _rec_fwd(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    x = constrain(x, "bsd")
    b = x.shape[0]
    w = cfg.rnn_width or cfg.d_model
    st = RglruState(h=jnp.zeros((b, w), jnp.float32),
                    conv=jnp.zeros((b, 3, w), x.dtype))
    out, _ = rglru_block(p["rglru"], rms_norm(x, p["ln"], cfg.norm_eps),
                         cfg, st)
    x = x + out
    return x + mlp(p["ffn"], rms_norm(x, p["ln_ffn"], cfg.norm_eps))


def _attn_fwd(cfg: ModelConfig, p: dict, x: jax.Array,
              positions: jax.Array) -> jax.Array:
    out = attention(p["attn"], rms_norm(x, p["ln"], cfg.norm_eps), cfg,
                    positions, window=cfg.window)
    x = x + out
    return x + mlp(p["ffn"], rms_norm(x, p["ln_ffn"], cfg.norm_eps))


def forward(params: dict, tokens_or_embeds: jax.Array, cfg: ModelConfig,
            *, remat: bool = True) -> jax.Array:
    """Full-sequence forward -> logits (b, s, vocab)."""
    if cfg.embed_frontend and tokens_or_embeds.ndim == 3:
        x = tokens_or_embeds.astype(cfg.dtype)
    else:
        x = params["embed"][tokens_or_embeds]
    b, s, _ = x.shape
    positions = jnp.arange(s)

    if cfg.family == "hybrid":
        def super_fwd(x, p):
            x = _rec_fwd(cfg, p["r0"], x)
            x = _rec_fwd(cfg, p["r1"], x)
            x = _attn_fwd(cfg, p["attn"], x, positions)
            return x, None
        fn = jax.checkpoint(super_fwd) if remat else super_fwd
        x, _ = jax.lax.scan(fn, x, params["supers"])
        if params.get("tail"):
            def tail_fwd(x, p):
                return _rec_fwd(cfg, p, x), None
            x, _ = jax.lax.scan(tail_fwd, x, params["tail"])
    else:
        def layer_fwd(x, layer):
            return _block_fwd(cfg, layer, x, positions), None
        fn = jax.checkpoint(layer_fwd) if remat else layer_fwd
        x, _ = jax.lax.scan(fn, x, params["layers"])

    x = constrain(x, "bsd")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain(x @ head, "logits_v")


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            *, remat: bool = True) -> jax.Array:
    logits = forward(params, batch["tokens"], cfg, remat=remat)
    return cross_entropy_loss(logits, batch["labels"])


# ---------------------------------------------------------------------------
# decode (single token, explicit caches)
# ---------------------------------------------------------------------------


class DecodeCaches(NamedTuple):
    kv: Optional[tuple] = None            # stacked KV cache(s)
    rwkv: Optional[RwkvState] = None      # stacked rwkv states
    cm_prev: Optional[jax.Array] = None   # (L, b, d) channel-mix shift
    rglru: Optional[RglruState] = None    # stacked rglru states
    ring_pos: Optional[jax.Array] = None  # (L_attn, window) global positions


def make_decode_caches(cfg: ModelConfig, batch: int, s_max: int,
                       *, abstract: bool = False) -> DecodeCaches:
    if cfg.family == "ssm":
        st = make_rwkv_state(cfg, batch, cfg.n_layers, abstract=abstract)
        shape = (cfg.n_layers, batch, cfg.d_model)
        cm = (jax.ShapeDtypeStruct(shape, cfg.dtype) if abstract
              else jnp.zeros(shape, cfg.dtype))
        return DecodeCaches(rwkv=st, cm_prev=cm)
    if cfg.family == "hybrid":
        n_super, rem = divmod(cfg.n_layers, 3)
        n_rec = 2 * n_super + rem
        win = min(cfg.window or s_max, s_max)
        kv = make_kv_cache(cfg, batch, win, n_super, abstract=abstract)
        rg = make_rglru_state(cfg, batch, n_rec, abstract=abstract)
        rp_shape = (n_super, win)
        rp = (jax.ShapeDtypeStruct(rp_shape, jnp.int32) if abstract
              else jnp.full(rp_shape, -1, jnp.int32))
        return DecodeCaches(kv=kv, rglru=rg, ring_pos=rp)
    kv = make_kv_cache(cfg, batch, s_max, cfg.n_layers, abstract=abstract)
    return DecodeCaches(kv=kv)


def _decode_block(cfg, layer, x, kv_l, pos):
    """One dense/moe layer decode step. kv_l: (k, v) for this layer."""
    h = rms_norm(x, layer["ln1"], cfg.norm_eps)
    out, kv_l = attention(layer["attn"], h, cfg, pos[None],
                          cache=kv_l, cache_index=pos)
    x = x + out
    h2 = rms_norm(x, layer["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe(layer["ffn"], h2, cfg)
    else:
        x = x + mlp(layer["ffn"], h2)
    return x, kv_l


def decode_step(params: dict, tokens: jax.Array, caches: DecodeCaches,
                pos: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, DecodeCaches]:
    """One decode step. tokens: (b, 1) int32 (or (b, 1, d) embeds);
    pos: scalar int32 — current global position (cache insert index)."""
    if cfg.embed_frontend and tokens.ndim == 3:
        x = tokens.astype(cfg.dtype)
    else:
        x = params["embed"][tokens]

    if cfg.family == "ssm":
        def step(x, inputs):
            layer, st_s, st_x, cm_prev = inputs
            h = rms_norm(x, layer["ln1"], cfg.norm_eps)
            out, st = rwkv_time_mix_step(layer["tm"], h, cfg,
                                         RwkvState(st_s, st_x))
            x = x + out
            h2 = rms_norm(x, layer["ln2"], cfg.norm_eps)
            out2, cm_new = rwkv_channel_mix(layer["cm"], h2, cm_prev)
            return x + out2, (st.s, st.x_prev, cm_new)
        x, (s_new, xp_new, cm_new) = jax.lax.scan(
            lambda c, i: step(c, i), x,
            (params["layers"], caches.rwkv.s, caches.rwkv.x_prev,
             caches.cm_prev))
        caches = caches._replace(rwkv=RwkvState(s_new, xp_new),
                                 cm_prev=cm_new)
    elif cfg.family == "hybrid":
        x, caches = _decode_hybrid(params, x, caches, pos, cfg)
    else:
        def step(x, inputs):
            layer, k_l, v_l = inputs
            x, (k_l, v_l) = _decode_block(cfg, layer, x, (k_l, v_l), pos)
            return x, (k_l, v_l)
        x, (k_new, v_new) = jax.lax.scan(
            lambda c, i: step(c, i), x,
            (params["layers"], caches.kv[0], caches.kv[1]))
        caches = caches._replace(kv=(k_new, v_new))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, caches


def _decode_hybrid(params, x, caches: DecodeCaches, pos, cfg):
    """Hybrid decode: scan supers; local attention uses a ring buffer."""
    win = caches.kv[0].shape[3]
    slot = pos % win

    def rec_step(x, p, st_h, st_c):
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        out, st = rglru_step(p["rglru"], h, cfg, RglruState(st_h, st_c))
        x = x + out
        x = x + mlp(p["ffn"], rms_norm(x, p["ln_ffn"], cfg.norm_eps))
        return x, st

    def super_step(x, inputs):
        p, k_l, v_l, rp, h0, c0, h1, c1 = inputs
        x, st0 = rec_step(x, p["r0"], h0, c0)
        x, st1 = rec_step(x, p["r1"], h1, c1)
        # local attention on ring buffer
        pa = p["attn"]
        h = rms_norm(x, pa["ln"], cfg.norm_eps)
        b, s, d = h.shape
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        from .common import rope
        q = rope((h @ pa["attn"]["wq"]).reshape(b, s, hq, dh),
                 pos[None], cfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope((h @ pa["attn"]["wk"]).reshape(b, s, hkv, dh),
                 pos[None], cfg.rope_theta).transpose(0, 2, 1, 3)
        v = (h @ pa["attn"]["wv"]).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype),
                                           (0, 0, slot, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype),
                                           (0, 0, slot, 0))
        rp = jax.lax.dynamic_update_slice(rp, pos[None].astype(rp.dtype), (slot,))
        group = hq // hkv
        kk = jnp.repeat(k_l, group, axis=1) if group > 1 else k_l
        vv = jnp.repeat(v_l, group, axis=1) if group > 1 else v_l
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) \
            / (dh ** 0.5)
        valid = (rp >= 0) & (rp <= pos) & (rp > pos - (cfg.window or win))
        logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        att = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
        att = att.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
        x = x + att @ pa["attn"]["wo"]
        x = x + mlp(pa["ffn"], rms_norm(x, pa["ln_ffn"], cfg.norm_eps))
        return x, (k_l, v_l, rp, st0.h, st0.conv, st1.h, st1.conv)

    n_super = caches.kv[0].shape[0]
    rg = caches.rglru
    h_pairs = rg.h[:2 * n_super].reshape(n_super, 2, *rg.h.shape[1:])
    c_pairs = rg.conv[:2 * n_super].reshape(n_super, 2, *rg.conv.shape[1:])
    x, (k_new, v_new, rp_new, h0n, c0n, h1n, c1n) = jax.lax.scan(
        lambda c, i: super_step(c, i), x,
        (params["supers"], caches.kv[0], caches.kv[1], caches.ring_pos,
         h_pairs[:, 0], c_pairs[:, 0], h_pairs[:, 1], c_pairs[:, 1]))
    h_new = jnp.stack([h0n, h1n], axis=1).reshape(2 * n_super,
                                                  *rg.h.shape[1:])
    c_new = jnp.stack([c0n, c1n], axis=1).reshape(2 * n_super,
                                                  *rg.conv.shape[1:])
    # tail recurrent layers
    if params.get("tail"):
        rem = rg.h.shape[0] - 2 * n_super

        def tail_step(x, inputs):
            p, h_t, c_t = inputs
            x, st = rec_step(x, p, h_t, c_t)
            return x, (st.h, st.conv)
        x, (ht_new, ct_new) = jax.lax.scan(
            lambda c, i: tail_step(c, i), x,
            (params["tail"], rg.h[2 * n_super:], rg.conv[2 * n_super:]))
        h_new = jnp.concatenate([h_new, ht_new], axis=0)
        c_new = jnp.concatenate([c_new, ct_new], axis=0)
    caches = caches._replace(kv=(k_new, v_new), ring_pos=rp_new,
                             rglru=RglruState(h_new, c_new))
    return x, caches
