"""RWKV-6 "Finch" time-mix / channel-mix blocks (attention-free).

Recurrence (per head, d_k × d_v state S):

    S_t = diag(w_t) · S_{t-1} + kᵀ_t v_t
    o_t = r_t · (S_{t-1} + diag(u) kᵀ_t v_t)

with data-dependent decay w_t = exp(-exp(w_lora(x_t))) — the Finch change
over RWKV-5's static decay. Two execution forms:

* ``chunked`` (training/prefill): the affine diagonal recurrence is
  associative, so the sequence is processed in chunks — within a chunk an
  O(C²) masked-decay attention-like form (MXU matmuls), across chunks the
  carried state. Wall-clock parallel over the sequence.
* ``step`` (decode): O(1) per token — the reason rwkv6 runs the long_500k
  shape with a fixed-size state instead of a 500k KV cache.

Token-shift (the x_{t-1} mix) is implemented with a roll within the
sequence and a carried last-token for decode.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import KeyGen, ModelConfig, leaf


class RwkvState(NamedTuple):
    s: jax.Array        # (b, h, dk, dv) wkv state
    x_prev: jax.Array   # (b, d) last token (for token-shift)


def init_rwkv_time_mix(cfg: ModelConfig, kg: KeyGen) -> dict:
    d = cfg.d_model
    return {
        "mix_r": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
        "mix_k": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
        "mix_v": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
        "mix_w": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
        "wr": leaf((d, d), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wk": leaf((d, d), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wv": leaf((d, d), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wo": leaf((d, d), cfg.dtype, abstract=kg.abstract, key=kg()),
        # decay LoRA: d -> 64 -> d (data-dependent decay, the Finch core)
        "w_lora_a": leaf((d, 64), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_lora_b": leaf((64, d), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_bias": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
        "u_bonus": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
    }


def _project(params, x, x_shift):
    """Token-shifted projections. x, x_shift: (b, s, d)."""
    def mix(name):
        m = params[f"mix_{name}"].astype(jnp.float32)
        return (x * (1 - m) + x_shift * m).astype(x.dtype)
    r = mix("r") @ params["wr"]
    k = mix("k") @ params["wk"]
    v = mix("v") @ params["wv"]
    w_in = mix("w") @ params["w_lora_a"]
    w_log = (jnp.tanh(w_in.astype(jnp.float32)) @
             params["w_lora_b"].astype(jnp.float32)) + \
        params["w_bias"].astype(jnp.float32)
    # per-step log-decay in [-0.5, ~0): the floor bounds the factored
    # exponentials of the chunked form (exp(+cum) stays <= e^(0.5*chunk)),
    # and both execution forms share the same clamp so they stay equal.
    logw = jnp.maximum(-jnp.exp(jnp.clip(w_log, -12.0, 4.0)), -0.5)
    return r, k, v, logw


def _split_heads(x, h, dh):
    b, s, _ = x.shape
    return x.reshape(b, s, h, dh).transpose(0, 2, 1, 3)   # (b, h, s, dh)


def rwkv_time_mix_chunked(params: dict, x: jax.Array, cfg: ModelConfig,
                          state: RwkvState, chunk: int = 64
                          ) -> tuple[jax.Array, RwkvState]:
    """Chunked-parallel form. x: (b, s, d) with s % chunk == 0."""
    b, s, d = x.shape
    dh = cfg.rwkv_head_dim
    h = d // dh
    x_shift = jnp.concatenate([state.x_prev[:, None, :], x[:, :-1]], axis=1)
    r, k, v, logw = _project(params, x, x_shift)
    u = params["u_bonus"].astype(jnp.float32).reshape(h, 1, dh)

    # operands stay in the model dtype; decays are derived PER CHUNK inside
    # the scan (no full-sequence f32 materialization of r/k/v/cum — at 32k
    # context those five f32 copies were ~5 GB/layer/device of pure HBM
    # traffic, the dominant term of the rwkv prefill roofline).
    r = _split_heads(r, h, dh)
    k = _split_heads(k, h, dh)
    v = _split_heads(v, h, dh)
    logw = _split_heads(logw, h, dh)                      # (b, h, s, dh) f32

    nc = s // chunk
    rc = r.reshape(b, h, nc, chunk, dh)
    kc = k.reshape(b, h, nc, chunk, dh)
    vc = v.reshape(b, h, nc, chunk, dh)
    lw = logw.reshape(b, h, nc, chunk, dh)

    def chunk_step(S, inputs):
        rc_, kc_, vc_, lw_ = inputs                       # (b,h,chunk,dh)
        cum_ = jnp.cumsum(lw_, axis=2)                    # inclusive
        cumex_ = cum_ - lw_                               # exclusive
        total_ = cum_[:, :, -1, :]
        dt = rc_.dtype
        # contribution of the carried state: r_t decayed from chunk start
        r_dec = rc_ * jnp.exp(cumex_).astype(dt)
        out_state = jnp.einsum("bhtk,bhkv->bhtv", r_dec.astype(jnp.float32),
                               S)
        # intra-chunk: pair (t, j<t) with decay prod_(j+1..t-1); factored
        # as exp(cumex_t) * exp(-cum_j), safe under the -0.5 log-decay floor
        att = jnp.einsum("bhtk,bhsk->bhts", r_dec,
                         kc_ * jnp.exp(-cum_).astype(dt),
                         preferred_element_type=jnp.float32)
        mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
        att = att * mask
        out_intra = jnp.einsum("bhts,bhsv->bhtv", att.astype(dt), vc_,
                               preferred_element_type=jnp.float32)
        # bonus diagonal term u ⊙ k_t v_t
        out_diag = jnp.einsum(
            "bhtk,bhtk->bht", rc_.astype(jnp.float32),
            kc_.astype(jnp.float32) * u[None])[..., None] \
            * vc_.astype(jnp.float32)
        # state update: S' = diag(total decay) S + sum_t decay_rest k v
        k_tail = kc_ * jnp.exp(total_[:, :, None, :] - cum_).astype(dt)
        S_new = S * jnp.exp(total_)[:, :, :, None] + \
            jnp.einsum("bhtk,bhtv->bhkv", k_tail, vc_,
                       preferred_element_type=jnp.float32)
        return S_new, out_state + out_intra + out_diag

    S0 = state.s.astype(jnp.float32)
    S_fin, outs = jax.lax.scan(
        chunk_step, S0,
        (rc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
         vc.transpose(2, 0, 1, 3, 4), lw.transpose(2, 0, 1, 3, 4)))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dh)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    out = out @ params["wo"]
    return out, RwkvState(s=S_fin.astype(state.s.dtype), x_prev=x[:, -1, :])


def rwkv_time_mix_step(params: dict, x: jax.Array, cfg: ModelConfig,
                       state: RwkvState) -> tuple[jax.Array, RwkvState]:
    """Single-token decode. x: (b, 1, d) -> (b, 1, d), O(1) state update."""
    b, _, d = x.shape
    dh = cfg.rwkv_head_dim
    h = d // dh
    x_shift = state.x_prev[:, None, :]
    r, k, v, logw = _project(params, x, x_shift)
    u = params["u_bonus"].astype(jnp.float32).reshape(h, dh)

    r = r.reshape(b, h, dh).astype(jnp.float32)
    k = k.reshape(b, h, dh).astype(jnp.float32)
    v = v.reshape(b, h, dh).astype(jnp.float32)
    w = jnp.exp(logw.reshape(b, h, dh))

    S = state.s.astype(jnp.float32)                        # (b, h, dk, dv)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", r, S + u[None, :, :, None] * kv)
    S_new = S * w[..., None] + kv
    out = out.reshape(b, 1, d).astype(x.dtype) @ params["wo"]
    return out, RwkvState(s=S_new.astype(state.s.dtype), x_prev=x[:, -1, :])


def make_rwkv_state(cfg: ModelConfig, batch: int, n_layers: int,
                    *, abstract: bool = False) -> RwkvState:
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    h = d // dh
    s_shape = (n_layers, batch, h, dh, dh)
    x_shape = (n_layers, batch, d)
    if abstract:
        return RwkvState(jax.ShapeDtypeStruct(s_shape, jnp.float32),
                         jax.ShapeDtypeStruct(x_shape, cfg.dtype))
    return RwkvState(jnp.zeros(s_shape, jnp.float32),
                     jnp.zeros(x_shape, cfg.dtype))


def init_rwkv_channel_mix(cfg: ModelConfig, kg: KeyGen) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix_k": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
        "mix_r": leaf((d,), cfg.dtype, abstract=kg.abstract, key=kg(), scale=0.5),
        "wk": leaf((d, f), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wv": leaf((f, d), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wr": leaf((d, d), cfg.dtype, abstract=kg.abstract, key=kg()),
    }


def rwkv_channel_mix(params: dict, x: jax.Array, x_prev: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """RWKV squared-ReLU channel mix with token shift.

    x: (b, s, d); x_prev: (b, d) carried last token. Returns (out, new_prev).
    """
    x_shift = jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)

    def mix(name):
        m = params[f"mix_{name}"].astype(jnp.float32)
        return (x * (1 - m) + x_shift * m).astype(x.dtype)

    k = jnp.square(jax.nn.relu((mix("k") @ params["wk"]).astype(jnp.float32)))
    r = jax.nn.sigmoid((mix("r") @ params["wr"]).astype(jnp.float32))
    out = (r * (k.astype(x.dtype) @ params["wv"]).astype(jnp.float32))
    return out.astype(x.dtype), x[:, -1, :]
