"""Dense SwiGLU feed-forward block."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, ModelConfig, leaf


def init_mlp(cfg: ModelConfig, kg: KeyGen, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    return {
        "w_gate": leaf((d, f), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_up": leaf((d, f), cfg.dtype, abstract=kg.abstract, key=kg()),
        "w_down": leaf((f, d), cfg.dtype, abstract=kg.abstract, key=kg()),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
    up = (x @ params["w_up"]).astype(jnp.float32)
    return ((gate * up).astype(x.dtype)) @ params["w_down"]
