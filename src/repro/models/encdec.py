"""Encoder-decoder transformer (seamless-m4t backbone).

The modality frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (b, s_src, d_model) — ``input_specs`` supplies
them. Encoder blocks are bidirectional; decoder blocks are causal
self-attention + cross-attention to the encoder output. Decode caches the
self-attention KV (growing) and the cross-attention KV (computed once from
the encoder output).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..distributed.ctx import constrain
from .attention import init_attention, make_kv_cache, mha_attend
from .common import (KeyGen, ModelConfig, cross_entropy_loss, leaf, rms_norm,
                     rope, stack_layers)
from .mlp import init_mlp, mlp


def _init_enc_block(cfg: ModelConfig, kg: KeyGen) -> dict:
    d = cfg.d_model
    return {
        "ln1": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
        "attn": init_attention(cfg, kg),
        "ln2": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
        "ffn": init_mlp(cfg, kg),
    }


def _init_dec_block(cfg: ModelConfig, kg: KeyGen) -> dict:
    d = cfg.d_model
    return {
        "ln1": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
        "self_attn": init_attention(cfg, kg),
        "ln_x": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
        "cross_attn": init_attention(cfg, kg),
        "ln2": leaf((d,), jnp.float32, abstract=kg.abstract, key=kg(), scale=1.0),
        "ffn": init_mlp(cfg, kg),
    }


def init_encdec(cfg: ModelConfig, key: Optional[jax.Array] = None,
                *, abstract: bool = False) -> dict:
    kg = KeyGen(key if key is not None else (None if abstract else
                                             jax.random.PRNGKey(0)), abstract)
    d, v = cfg.d_model, cfg.vocab
    return {
        "embed": leaf((v, d), cfg.dtype, abstract=abstract, key=kg()),
        "enc_layers": stack_layers(lambda: _init_enc_block(cfg, kg),
                                   cfg.encoder_layers, abstract=abstract),
        "dec_layers": stack_layers(lambda: _init_dec_block(cfg, kg),
                                   cfg.n_layers, abstract=abstract),
        "enc_norm": leaf((d,), jnp.float32, abstract=abstract, key=kg(), scale=1.0),
        "final_norm": leaf((d,), jnp.float32, abstract=abstract, key=kg(), scale=1.0),
        "lm_head": leaf((d, v), cfg.dtype, abstract=abstract, key=kg()),
    }


def _mha(p, xq, xkv, cfg, *, causal, q_pos, kv_pos):
    """Generic attention: bidirectional (encoder/cross) or causal (self).
    Shares the constrained + streaming-softmax machinery with the
    decoder-only stack (see attention.py)."""
    b, sq, d = xq.shape
    skv = xkv.shape[1]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = constrain((xq @ p["wq"]).reshape(b, sq, hq, dh), "bshd")
    k = constrain((xkv @ p["wk"]).reshape(b, skv, hkv, dh), "bshd_kv")
    v = constrain((xkv @ p["wv"]).reshape(b, skv, hkv, dh), "bshd_kv")
    q = rope(q, q_pos, cfg.rope_theta)
    k = rope(k, kv_pos, cfg.rope_theta)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    out = mha_attend(q, k, v, causal=causal)
    out = out.astype(xq.dtype).transpose(0, 2, 1, 3).reshape(b, sq, hq * dh)
    return out @ p["wo"]


def encode(params: dict, src_embeds: jax.Array, cfg: ModelConfig,
           *, remat: bool = True) -> jax.Array:
    """src_embeds: (b, s_src, d) from the (stubbed) modality frontend."""
    x = src_embeds.astype(cfg.dtype)
    pos = jnp.arange(x.shape[1])

    def block(x, p):
        x = constrain(x, "bsd")
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + _mha(p["attn"], h, h, cfg, causal=False, q_pos=pos,
                     kv_pos=pos)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["ffn"], h2), None

    fn = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward_encdec(params: dict, src_embeds: jax.Array,
                   tgt_tokens: jax.Array, cfg: ModelConfig,
                   *, remat: bool = True) -> jax.Array:
    """Training forward -> logits (b, s_tgt, vocab)."""
    memory = encode(params, src_embeds, cfg, remat=remat)
    x = params["embed"][tgt_tokens]
    pos_t = jnp.arange(x.shape[1])
    pos_s = jnp.arange(memory.shape[1])

    def block(x, p):
        x = constrain(x, "bsd")
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + _mha(p["self_attn"], h, h, cfg, causal=True, q_pos=pos_t,
                     kv_pos=pos_t)
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + _mha(p["cross_attn"], hx, memory, cfg, causal=False,
                     q_pos=pos_t, kv_pos=pos_s)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["ffn"], h2), None

    fn = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(fn, x, params["dec_layers"])
    x = constrain(x, "bsd")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x @ params["lm_head"], "logits_v")


def encdec_loss(params: dict, batch: dict, cfg: ModelConfig,
                *, remat: bool = True) -> jax.Array:
    logits = forward_encdec(params, batch["src_embeds"], batch["tokens"],
                            cfg, remat=remat)
    return cross_entropy_loss(logits, batch["labels"])


class EncDecCaches(NamedTuple):
    self_kv: tuple          # (L, b, hkv, s_max, dh) x2
    cross_k: jax.Array      # (L, b, hkv, s_src, dh)
    cross_v: jax.Array
    memory_pos: jax.Array   # (s_src,)


def make_encdec_caches(cfg: ModelConfig, batch: int, s_max: int, s_src: int,
                       *, abstract: bool = False) -> EncDecCaches:
    kv = make_kv_cache(cfg, batch, s_max, cfg.n_layers, abstract=abstract)
    cshape = (cfg.n_layers, batch, cfg.n_kv_heads, s_src, cfg.head_dim)
    if abstract:
        ck = jax.ShapeDtypeStruct(cshape, cfg.dtype)
        cv = jax.ShapeDtypeStruct(cshape, cfg.dtype)
        mp = jax.ShapeDtypeStruct((s_src,), jnp.int32)
    else:
        ck = jnp.zeros(cshape, cfg.dtype)
        cv = jnp.zeros(cshape, cfg.dtype)
        mp = jnp.arange(s_src, dtype=jnp.int32)
    return EncDecCaches(self_kv=kv, cross_k=ck, cross_v=cv, memory_pos=mp)


def decode_step_encdec(params: dict, tokens: jax.Array,
                       caches: EncDecCaches, pos: jax.Array,
                       cfg: ModelConfig) -> tuple[jax.Array, EncDecCaches]:
    """One decoder step against precomputed cross-attention KV."""
    from .attention import _decode_attend

    x = params["embed"][tokens]
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def block(x, inputs):
        p, k_l, v_l, ck_l, cv_l = inputs
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q = rope((h @ p["self_attn"]["wq"]).reshape(b, s, hq, dh), pos[None],
                 cfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope((h @ p["self_attn"]["wk"]).reshape(b, s, hkv, dh), pos[None],
                 cfg.rope_theta).transpose(0, 2, 1, 3)
        v = (h @ p["self_attn"]["wv"]).reshape(b, s, hkv, dh
                                               ).transpose(0, 2, 1, 3)
        k_l = jax.lax.dynamic_update_slice(k_l, k.astype(k_l.dtype),
                                           (0, 0, pos, 0))
        v_l = jax.lax.dynamic_update_slice(v_l, v.astype(v_l.dtype),
                                           (0, 0, pos, 0))
        out = _decode_attend(q, k_l, v_l, kv_len=pos + s, window=None)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
        x = x + out @ p["self_attn"]["wo"]
        # cross attention against fixed memory
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        qx = rope((hx @ p["cross_attn"]["wq"]).reshape(b, s, hq, dh),
                  pos[None], cfg.rope_theta).transpose(0, 2, 1, 3)
        group = hq // hkv
        ck = jnp.repeat(ck_l, group, axis=1) if group > 1 else ck_l
        cv = jnp.repeat(cv_l, group, axis=1) if group > 1 else cv_l
        logits = jnp.einsum("bhqd,bhkd->bhqk", qx, ck).astype(jnp.float32) \
            / (dh ** 0.5)
        probs = jax.nn.softmax(logits, axis=-1)
        outx = jnp.einsum("bhqk,bhkd->bhqd", probs, cv.astype(jnp.float32))
        outx = outx.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
        x = x + outx @ p["cross_attn"]["wo"]
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h2)
        return x, (k_l, v_l)

    x, (k_new, v_new) = jax.lax.scan(
        lambda c, i: block(c, i), x,
        (params["dec_layers"], caches.self_kv[0], caches.self_kv[1],
         caches.cross_k, caches.cross_v))
    caches = caches._replace(self_kv=(k_new, v_new))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"], caches


def precompute_cross_kv(params: dict, memory: jax.Array, cfg: ModelConfig
                        ) -> tuple[jax.Array, jax.Array]:
    """Cross-attention K/V for all decoder layers from encoder output."""
    b, s_src, d = memory.shape
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    pos = jnp.arange(s_src)

    def one(p):
        k = rope((memory @ p["cross_attn"]["wk"]).reshape(b, s_src, hkv, dh),
                 pos, cfg.rope_theta).transpose(0, 2, 1, 3)
        v = (memory @ p["cross_attn"]["wv"]).reshape(b, s_src, hkv, dh
                                                     ).transpose(0, 2, 1, 3)
        return k, v

    ks, vs = jax.vmap(one)(params["dec_layers"])
    return ks, vs
