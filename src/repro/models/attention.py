"""GQA attention with RoPE, KV cache, and optional local window.

Prefill/training uses the flash-attention Pallas kernel on TPU (jnp oracle
elsewhere — identical numerics, see kernels/flash_attention). Decode is a
single-query attention against the cache: memory-bound, expressed directly
in jnp so XLA fuses the cache read with the dot.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.ctx import constrain
from ..kernels.flash_attention.ref import attention_ref
from .common import KeyGen, ModelConfig, leaf, rope

USE_FLASH_KERNEL = False  # flipped on TPU backends by launch/train.py


def init_attention(cfg: ModelConfig, kg: KeyGen) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": leaf((d, hq * dh), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wk": leaf((d, hkv * dh), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wv": leaf((d, hkv * dh), cfg.dtype, abstract=kg.abstract, key=kg()),
        "wo": leaf((hq * dh, d), cfg.dtype, abstract=kg.abstract, key=kg()),
    }


CHUNKED_KV_THRESHOLD = 2048
KV_CHUNK = 1024
# f32-accumulate with bf16 operands (TPU-native; no f32 materialization of
# q or kv chunks). Toggleable for the §Perf A/B (launch/perf.py).
BF16_ATTENTION_OPERANDS = True


def _attend(q, k, v, *, window: Optional[int]) -> jax.Array:
    """q: (b, hq, sq, dh); k, v: (b, hkv, skv, dh)."""
    if USE_FLASH_KERNEL and window is None and q.shape[2] > 1:
        from ..kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=True)
    group = q.shape[1] // k.shape[1]
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    if k.shape[2] > CHUNKED_KV_THRESHOLD:
        return _attend_chunked(q, k, v, window=window)
    return attention_ref(q, k, v, causal=True, window=window)


def mha_attend(q, k, v, *, causal: bool) -> jax.Array:
    """Shared attention entry for the enc-dec stacks (bidirectional
    encoder / cross-attention or causal decoder self-attention); routes
    long sequences through the streaming-softmax path so the (sq, skv)
    logits never materialize."""
    group = q.shape[1] // k.shape[1]
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    if k.shape[2] > CHUNKED_KV_THRESHOLD:
        return _attend_chunked(q, k, v, window=None, causal=causal)
    return attention_ref(q, k, v, causal=causal, window=None)


def _attend_chunked(q, k, v, *, window: Optional[int],
                    kv_chunk: int = KV_CHUNK,
                    causal: bool = True) -> jax.Array:
    """Streaming-softmax attention in pure jnp (the flash algorithm as a
    lax.scan over kv chunks). Never materializes the (sq, skv) logits —
    peak temp is one (sq, kv_chunk) tile; valid on every backend, so the
    dry-run's memory_analysis reflects the production kernel's footprint.
    """
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    pad = (-skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nkc = k.shape[2] // kv_chunk
    kc = k.reshape(b, h, nkc, kv_chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, nkc, kv_chunk, dh).transpose(2, 0, 1, 3, 4)
    scale = 1.0 / (dh ** 0.5)
    qf = q if BF16_ATTENTION_OPERANDS else q.astype(jnp.float32)
    rows = (jnp.arange(sq) + (skv - sq))[:, None]          # global q index

    def step(carry, inputs):
        m, l, acc, ci = carry
        k_c, v_c = inputs
        if BF16_ATTENTION_OPERANDS:
            s = jnp.einsum("bhqd,bhkd->bhqk", qf, k_c,
                           preferred_element_type=jnp.float32) * scale
        else:
            s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                           k_c.astype(jnp.float32)) * scale
        cols = ci * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = (cols <= rows) if causal else (cols >= 0)
        mask &= cols < skv
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        if BF16_ATTENTION_OPERANDS:
            acc = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_c.dtype), v_c,
                preferred_element_type=jnp.float32)
        else:
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p,
                                           v_c.astype(jnp.float32))
        return (m_new, l, acc, ci + 1), None

    m0 = jnp.full((b, h, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, jnp.int32(0)),
                                     (kc, vc))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def attention(params: dict, x: jax.Array, cfg: ModelConfig,
              positions: jax.Array,
              cache: Optional[tuple[jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              window: Optional[int] = None):
    """x: (b, s, d). With ``cache`` (k, v) of shape (b, hkv, s_max, dh) and
    ``cache_index`` (scalar insert position), runs decode/appending mode and
    returns (out, new_cache); otherwise self-attention over x only."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = constrain((x @ params["wq"]).reshape(b, s, hq, dh), "bshd")
    k = constrain((x @ params["wk"]).reshape(b, s, hkv, dh), "bshd_kv")
    v = constrain((x @ params["wv"]).reshape(b, s, hkv, dh), "bshd_kv")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)          # (b, hq, s, dh)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    if cache is not None:
        # decode: append this step's k/v at cache_index, attend to the
        # valid prefix only (runtime-masked — slots past cache_index are
        # zeros and must not leak into the softmax).
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, 0, cache_index, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, 0, cache_index, 0))
        out = _decode_attend(q, ck, cv, kv_len=cache_index + s,
                             window=window)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
        return out @ params["wo"], (ck, cv)

    out = _attend(q, k, v, window=window)          # (b, hq, s, dh)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return out @ params["wo"]


def _decode_attend(q, k, v, *, kv_len, window: Optional[int]) -> jax.Array:
    """Single-step (or short) decode attention with runtime valid length.

    q: (b, hq, s, dh); k, v: (b, hkv, s_max, dh); kv_len: traced scalar —
    number of valid cache slots. GQA is handled with a grouped einsum so
    the kv cache is never head-replicated in memory (a ``jnp.repeat`` here
    would materialize group× the cache — the dominant decode buffer).
    """
    b, hq, s, dh = q.shape
    hkv, s_max = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, s, dh)
    scale = 1.0 / (dh ** 0.5)
    # f32 accumulation WITHOUT materializing an f32 copy of the cache
    # (v.astype(f32) would stream + store the whole cache twice)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    ki = jnp.arange(s_max)[None, :]
    qi = jnp.arange(s)[:, None] + (kv_len - s)     # global query positions
    mask = ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, s, dh).astype(q.dtype)


def make_kv_cache(cfg: ModelConfig, batch: int, s_max: int, n_layers: int,
                  *, abstract: bool = False):
    shape = (n_layers, batch, cfg.n_kv_heads, s_max, cfg.head_dim)
    if abstract:
        return (jax.ShapeDtypeStruct(shape, cfg.dtype),
                jax.ShapeDtypeStruct(shape, cfg.dtype))
    return (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))
