"""Ambient activation-sharding context.

Model code calls ``constrain(x, kind)`` at strategic points; outside a
distribution context (unit tests, smoke runs on one device) these are
no-ops, while under ``activation_sharding(mesh)`` they emit
``with_sharding_constraint`` so GSPMD produces the intended collective
schedule instead of guessing.

Kinds:
    bsd        (b, s, d)  tokens: batch over data axes; seq over "model"
               (Megatron sequence parallelism) when cfg.seq_parallel
    bshd       (b, s, h, dh) attention heads over "model"
    bhsd       (b, h, s, dh)
    logits_v   (b, s, v) vocab over "model" (vocab-parallel loss)
    ecd        (e, c, d) MoE expert-parallel
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

_STACK: list[dict] = []


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, seq_parallel: bool = True):
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    entry = {
        "mesh": mesh,
        "dp": dp if len(dp) > 1 else (dp[0] if dp else None),
        "tp": "model" if "model" in names else None,
        "seq_parallel": seq_parallel,
        "mp_size": mesh.shape["model"] if "model" in names else 1,
        "dp_size": int(jax.numpy.prod(jax.numpy.array(
            [mesh.shape[a] for a in dp]))) if dp else 1,
    }
    _STACK.append(entry)
    try:
        yield
    finally:
        _STACK.pop()


def _active() -> Optional[dict]:
    return _STACK[-1] if _STACK else None


def _divisible(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def constrain(x: jax.Array, kind: str) -> jax.Array:
    ctx = _active()
    if ctx is None or ctx["tp"] is None:
        return x
    dp, tp, mp = ctx["dp"], ctx["tp"], ctx["mp_size"]
    spec = None
    if kind == "bsd" and x.ndim == 3:
        seq = tp if (ctx["seq_parallel"] and _divisible(x.shape[1], mp)) \
            else None
        spec = P(dp, seq, None)
    elif kind == "bsd_batch_only" and x.ndim == 3:
        # recurrent (scan-over-sequence) blocks: sequence sharding would
        # force GSPMD to all-gather the full sequence per layer AND
        # replicate the scan across the TP axis — batch-only here.
        spec = P(dp, None, None)
    elif kind == "bshd" and x.ndim == 4:
        # prefer head-sharded TP; fall back to sharding the query sequence
        # (attention rows are independent) when heads don't divide.
        if _divisible(x.shape[2], mp):
            spec = P(dp, None, tp, None)
        elif _divisible(x.shape[1], mp):
            spec = P(dp, tp, None, None)
        else:
            spec = P(dp, None, None, None)
    elif kind == "bshd_kv" and x.ndim == 4:
        # keys/values must keep the full sequence; shard heads or replicate
        spec = P(dp, None, tp if _divisible(x.shape[2], mp) else None, None)
    elif kind == "bhsd" and x.ndim == 4:
        spec = P(dp, tp if _divisible(x.shape[1], mp) else None, None, None)
    elif kind == "logits_v" and x.ndim == 3:
        # vocab-parallel when the vocab divides; else sequence-parallel
        # (a replicated (b, s, V) logits tensor is the single biggest
        # memory hazard in the whole framework)
        if _divisible(x.shape[2], mp):
            spec = P(dp, None, tp)
        elif _divisible(x.shape[1], mp):
            spec = P(dp, tp, None)
        else:
            spec = P(dp, None, None)
    elif kind == "ecd" and x.ndim == 3:
        spec = P(tp if _divisible(x.shape[0], mp) else None, None, None)
    elif kind == "gtd" and x.ndim == 3:
        spec = P(dp if _divisible(x.shape[0], ctx["dp_size"]) else None,
                 None, None)
    elif kind == "gecd" and x.ndim == 4:
        spec = P(dp if _divisible(x.shape[0], ctx["dp_size"]) else None,
                 tp if _divisible(x.shape[1], mp) else None, None, None)
    elif kind == "gec" and x.ndim == 3:
        spec = P(dp if _divisible(x.shape[0], ctx["dp_size"]) else None,
                 tp if _divisible(x.shape[1], mp) else None, None)
    elif kind == "gt" and x.ndim == 2:
        spec = P(dp if _divisible(x.shape[0], ctx["dp_size"]) else None,
                 None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def moe_group_count() -> int:
    """Number of MoE routing groups = the data-parallel degree (1 off-mesh)."""
    ctx = _active()
    return int(ctx["dp_size"]) if ctx else 1


def seq_parallel_enabled() -> bool:
    ctx = _active()
    return bool(ctx and ctx["seq_parallel"])
