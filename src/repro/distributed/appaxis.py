"""App-axis data parallelism for batched-over-app array programs.

The experiment engine treats "application" as a leading batch axis: every
heavy dispatch (census evaluation, memo fills, k-means fits, Monte-Carlo
trials) is a vmapped program over ``(A, ...)`` stacks. This module turns
those same programs into device-parallel ones by ``shard_map``-ping the app
axis over a 1-D ``("app",)`` mesh (see ``repro.launch.mesh.make_app_mesh``).

Per-app results are bit-identical to the single-device vmap: lanes never
communicate, so sharding only changes *where* a lane runs. The app axis is
padded up to the device count by edge-replication (recomputing a real app
is always numerically safe; padded rows are dropped on return).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# jax >= 0.5 promotes shard_map to the top-level namespace; 0.4.x only has
# the experimental home. Support both (shared by repro.core.clustering too).
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def app_axis_name(mesh: Mesh) -> str:
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"app sharding expects a 1-D mesh, got axes {mesh.axis_names}")
    return mesh.axis_names[0]


def pad_app_axis(arr, multiple: int):
    """Pad the leading axis to a multiple by edge-replicating the last row."""
    a = arr.shape[0]
    pad = (-a) % multiple
    if pad == 0:
        return arr
    reps = np.concatenate([np.arange(a), np.full(pad, a - 1)])
    return arr[reps] if isinstance(arr, np.ndarray) else \
        jax.numpy.take(arr, jax.numpy.asarray(reps), axis=0)


def make_app_sharded(fn: Callable, mesh: Mesh,
                     replicated: Sequence[int] = ()) -> Callable:
    """Wrap a batched-over-app ``fn`` so its app axis runs device-parallel.

    ``fn`` takes arrays whose leading axis is the app axis (except argument
    positions in ``replicated``, which are broadcast — e.g. a config
    matrix) and returns a pytree of arrays sharded the same way. The
    wrapper pads the app axis to the device count, dispatches one
    ``shard_map``-ped program, and trims the padding.
    """
    axis = app_axis_name(mesh)
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    rep = frozenset(replicated)

    @functools.lru_cache(maxsize=8)
    def build(n_args: int):
        in_specs = tuple(P() if i in rep else P(axis) for i in range(n_args))
        # check_rep=False: jax 0.4.x has no replication rule for while_loop
        # (the k-means Lloyd loop); lanes are independent so it is vacuous
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=P(axis), check_rep=False))

    def call(*args: Any):
        a_size = next(np.shape(a)[0] for i, a in enumerate(args)
                      if i not in rep)
        padded = tuple(a if i in rep else pad_app_axis(a, n_dev)
                       for i, a in enumerate(args))
        out = build(len(args))(*padded)
        return jax.tree.map(lambda o: o[:a_size], out)

    return call


@functools.lru_cache(maxsize=None)
def app_sharded_cached(fn: Callable, mesh: Mesh,
                       replicated: tuple = ()) -> Callable:
    """Memoized ``make_app_sharded`` for module-level fns (stable hash)."""
    return make_app_sharded(fn, mesh, replicated)
