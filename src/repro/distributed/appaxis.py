"""App-axis (and trial-axis) data parallelism for batched array programs.

The experiment engine treats "application" as a leading batch axis: every
heavy dispatch (census evaluation, memo fills, k-means fits, Monte-Carlo
trials) is a vmapped program over ``(A, ...)`` stacks. This module turns
those same programs into device-parallel ones by ``shard_map``-ping the app
axis over a 1-D ``("app",)`` mesh (see ``repro.launch.mesh.make_app_mesh``).

Per-app results are bit-identical to the single-device vmap: lanes never
communicate, so sharding only changes *where* a lane runs. The app axis is
padded up to the device count by edge-replication (recomputing a real app
is always numerically safe; padded rows are dropped on return).

The streaming Monte-Carlo engine adds a second mesh dimension: a 2-D
``("app", "trial")`` mesh (``repro.launch.mesh.make_app_trial_mesh``)
splits each trial *chunk* across the trial axis on top of the app split.
``make_app_trial_sharded`` is the generalized wrapper: inputs still shard
over the app axis only (tables are per-app state; each trial-device
derives its own draws from the shared PRNG-block contract), while the
trial axis appears in the *outputs* — additive ``TrialStats``
accumulators arrive pre-merged by an in-program ``psum`` over the trial
axis (the cross-device coverage/CI merge: every leaf is a sum, so
sharded totals equal single-device totals exactly for the integer
leaves), and optional dense per-trial stacks re-assemble along it.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# jax >= 0.5 promotes shard_map to the top-level namespace; 0.4.x only has
# the experimental home. Support both (shared by repro.core.clustering too).
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def app_axis_name(mesh: Mesh) -> str:
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"app sharding expects a 1-D mesh, got axes {mesh.axis_names}")
    return mesh.axis_names[0]


def app_trial_axes(mesh: Mesh) -> tuple[str, "str | None"]:
    """(app_axis, trial_axis) names of a trial-engine mesh.

    Accepts the 1-D ``("app",)`` mesh (trial axis ``None`` — every device
    evaluates full chunks) and the 2-D ``("app", "trial")`` mesh (chunks
    split across the second axis). Axis order is positional: the leading
    axis shards apps, the trailing one trials.
    """
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0], None
    if len(mesh.axis_names) == 2:
        return mesh.axis_names[0], mesh.axis_names[1]
    raise ValueError(
        f"trial sharding expects a 1-D ('app',) or 2-D ('app', 'trial') "
        f"mesh, got axes {mesh.axis_names}")


def pad_app_axis(arr, multiple: int):
    """Pad the leading axis to a multiple by edge-replicating the last row."""
    a = arr.shape[0]
    pad = (-a) % multiple
    if pad == 0:
        return arr
    reps = np.concatenate([np.arange(a), np.full(pad, a - 1)])
    return arr[reps] if isinstance(arr, np.ndarray) else \
        jax.numpy.take(arr, jax.numpy.asarray(reps), axis=0)


def make_app_sharded(fn: Callable, mesh: Mesh,
                     replicated: Sequence[int] = ()) -> Callable:
    """Wrap a batched-over-app ``fn`` so its app axis runs device-parallel.

    ``fn`` takes arrays whose leading axis is the app axis (except argument
    positions in ``replicated``, which are broadcast — e.g. a config
    matrix) and returns a pytree of arrays sharded the same way. The
    wrapper pads the app axis to the app-axis size, dispatches one
    ``shard_map``-ped program, and trims the padding. On a 2-D
    ``("app", "trial")`` mesh only the app axis is used — the program is
    replicated along the trial axis (trial parallelism is the streaming
    trial engine's job, via ``make_app_trial_sharded``).
    """
    axis, _ = app_trial_axes(mesh)
    n_dev = int(mesh.shape[axis])
    rep = frozenset(replicated)

    @functools.lru_cache(maxsize=8)
    def build(n_args: int):
        in_specs = tuple(P() if i in rep else P(axis) for i in range(n_args))
        # check_rep=False: jax 0.4.x has no replication rule for while_loop
        # (the k-means Lloyd loop); lanes are independent so it is vacuous
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=P(axis), check_rep=False))

    def call(*args: Any):
        a_size = next(np.shape(a)[0] for i, a in enumerate(args)
                      if i not in rep)
        padded = tuple(a if i in rep else pad_app_axis(a, n_dev)
                       for i, a in enumerate(args))
        out = build(len(args))(*padded)
        return jax.tree.map(lambda o: o[:a_size], out)

    return call


@functools.lru_cache(maxsize=None)
def app_sharded_cached(fn: Callable, mesh: Mesh,
                       replicated: tuple = ()) -> Callable:
    """Memoized ``make_app_sharded`` for module-level fns (stable hash)."""
    return make_app_sharded(fn, mesh, replicated)


def make_app_trial_sharded(fn: Callable, mesh: Mesh,
                           replicated: Sequence[int] = (),
                           *, out_specs,
                           trim: "Callable | None" = None) -> Callable:
    """``make_app_sharded`` generalized to ``("app", "trial")`` meshes.

    Inputs follow the app contract exactly — leading-axis arrays shard
    over the app axis (positions in ``replicated`` broadcast) and the
    app axis pads to the mesh's app-axis size by edge replication. The
    differences serve the streaming trial programs:

    * ``out_specs`` is caller-supplied (a pytree prefix over ``fn``'s
      outputs): a streaming program returns mixed layouts — per-app
      accumulators (``P(app)``, replicated over the trial axis after the
      in-program ``psum`` merge) next to optional dense chunk stacks
      assembled over both axes (``P(None, app, trial)``).
    * ``trim(out, a_size)`` drops the app padding, because the app axis
      is not leading in every output (default: leading-axis slice on
      every leaf, matching ``make_app_sharded``).

    ``fn`` itself may read ``jax.lax.axis_index`` of either axis to pick
    its shard of the work — see ``repro.experiments.montecarlo``.
    """
    app, _ = app_trial_axes(mesh)
    n_app = int(mesh.shape[app])
    rep = frozenset(replicated)

    @functools.lru_cache(maxsize=8)
    def build(n_args: int):
        in_specs = tuple(P() if i in rep else P(app) for i in range(n_args))
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    def call(*args: Any):
        a_size = next(np.shape(a)[0] for i, a in enumerate(args)
                      if i not in rep)
        padded = tuple(a if i in rep else pad_app_axis(a, n_app)
                       for i, a in enumerate(args))
        out = build(len(args))(*padded)
        if trim is None:
            return jax.tree.map(lambda o: o[:a_size], out)
        return trim(out, a_size)

    return call
