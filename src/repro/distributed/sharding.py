"""Parameter / activation / cache sharding rules.

Megatron-style tensor parallelism on the "model" axis, data parallelism on
("pod", "data"). Rules are name-based over flattened param paths, with an
automatic divisibility fallback: any dim that the mesh axis does not divide
is replicated instead (logged once). Stacked per-layer leaves (leading
n_layers axis from lax.scan stacking) get a leading None prepended
automatically by ndim comparison.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..launch.mesh import data_axes

PyTree = Any

# (path-suffix substring, spec WITHOUT the stacked-layer axis). Earlier
# rules win. Specs use "model" for TP and None elsewhere; the stacked layer
# dim is inferred.
# "model" = Megatron tensor parallel; "__dp__" = FSDP over the data axes
# (GSPMD all-gathers per use, reduce-scatters grads — ZeRO-3 semantics).
_PARAM_RULES: tuple[tuple[str, tuple], ...] = (
    # embeddings / heads
    ("embed", ("model", "__dp__")),
    ("lm_head", ("__dp__", "model")),
    # attention
    ("attn.wq", ("__dp__", "model")),
    ("attn.wk", ("__dp__", "model")),
    ("attn.wv", ("__dp__", "model")),
    ("attn.wo", ("model", "__dp__")),
    ("self_attn.wq", ("__dp__", "model")),
    ("self_attn.wk", ("__dp__", "model")),
    ("self_attn.wv", ("__dp__", "model")),
    ("self_attn.wo", ("model", "__dp__")),
    ("cross_attn.wq", ("__dp__", "model")),
    ("cross_attn.wk", ("__dp__", "model")),
    ("cross_attn.wv", ("__dp__", "model")),
    ("cross_attn.wo", ("model", "__dp__")),
    # dense mlp
    ("ffn.w_gate", ("__dp__", "model")),
    ("ffn.w_up", ("__dp__", "model")),
    ("ffn.w_down", ("model", "__dp__")),
    # moe (expert-parallel on "model")
    ("ffn.router", (None, None)),
    # note: moe w_gate/w_up/w_down are 4-D stacked — see _spec_for
    # rwkv time mix
    ("tm.wr", ("__dp__", "model")),
    ("tm.wk", ("__dp__", "model")),
    ("tm.wv", ("__dp__", "model")),
    ("tm.wo", ("model", "__dp__")),
    ("tm.w_lora_a", (None, None)),
    ("tm.w_lora_b", (None, None)),
    # rwkv channel mix
    ("cm.wk", ("__dp__", "model")),
    ("cm.wv", ("model", "__dp__")),
    ("cm.wr", ("__dp__", "model")),
    # rglru
    ("rglru.w_in", ("__dp__", "model")),
    ("rglru.w_gate_in", ("__dp__", "model")),
    ("rglru.conv_k", (None, "model")),
    ("rglru.w_r", ("__dp__", "model")),
    ("rglru.w_i", ("__dp__", "model")),
    ("rglru.lam", ("model",)),
    ("rglru.w_out", ("model", "__dp__")),
)

# expert weights: experts on "model", FSDP on the data axes over d_model /
# d_ff (GSPMD all-gathers per layer; ZeRO-3 semantics)
_MOE_3D = {"w_gate": ("model", "__dp__", None),
           "w_up": ("model", "__dp__", None),
           "w_down": ("model", "__dp__", None)}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return ".".join(parts)


def _fallback(spec: tuple, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Replicate any dim the mesh axis does not divide. The placeholder
    "__dp__" resolves to the mesh's data axes (FSDP sharding)."""
    dp = data_axes(mesh)
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        if ax == "__dp__":
            size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
            if size > 1 and dim % size == 0:
                fixed.append(dp if len(dp) > 1 else dp[0])
            else:
                fixed.append(None)
            continue
        size = mesh.shape[ax] if ax in mesh.axis_names else 1
        fixed.append(ax if size > 1 and dim % size == 0 else None)
    return P(*fixed)


def _spec_for(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    ndim = len(shape)
    # MoE expert tensors are 4-D when layer-stacked (L, e, d, f); dense MLP
    # stacked leaves are 3-D and must fall through to the dense rules.
    for key, spec in _MOE_3D.items():
        if path.endswith("ffn." + key) and ndim == 4:
            return _fallback((None,) + tuple(spec), shape, mesh)
    for suffix, spec in _PARAM_RULES:
        if suffix in path:
            spec = tuple(spec)
            if ndim == len(spec) + 1:        # layer-stacked
                spec = (None,) + spec
            if ndim != len(spec):
                return P()                   # shape surprise: replicate
            return _fallback(spec, shape, mesh)
    return P()                               # norms, scalars: replicated


def param_specs(params: PyTree, mesh: Mesh, *,
                serving: bool = False) -> PyTree:
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs).

    ``serving=True`` drops the FSDP ("__dp__") axes when the TP-sharded
    parameters fit in HBM: inference has no optimizer state and re-reads
    weights every token, so per-layer FSDP all-gathers are pure collective
    overhead. Models too big for TP-only sharding keep FSDP (the gathers
    are then the price of fitting).
    """
    drop_dp = False
    if serving:
        mp = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") \
            else (mesh.shape["model"] if "model" in mesh.axis_names else 1)
        total = sum(
            int(np.prod(l.shape)) * getattr(l.dtype, "itemsize", 2)
            for l in jax.tree_util.tree_leaves(params))
        drop_dp = (total / max(mp, 1)) < 12 * 2**30

    def spec(path, leaf):
        p = _spec_for(_path_str(path), tuple(leaf.shape), mesh)
        if drop_dp:
            dp = set(data_axes(mesh))
            parts = tuple(
                None if (a in dp or (isinstance(a, tuple) and set(a) & dp))
                else a for a in tuple(p))
            return P(*parts)
        return p
    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def opt_state_specs(params: PyTree, mesh: Mesh, *, zero: bool = True
                    ) -> PyTree:
    """Optimizer-moment specs. ``zero=True`` additionally shards moments
    over the data axes on the first divisible unsharded dim (ZeRO-style
    optimizer-state partitioning — 8x memory cut at dp=16/32)."""
    specs = param_specs(params, mesh)
    if not zero:
        return specs
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def shard_more(path, leaf, spec):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for ax in parts:
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a is not None:
                    used.add(a)
        if used & set(dp):
            return P(*parts)        # param already FSDP-sharded on data
        for i, (dim, ax) in enumerate(zip(leaf.shape, parts)):
            if ax is None and dp_size > 1 and dim % dp_size == 0 and dim >= dp_size * 8:
                parts[i] = dp if len(dp) > 1 else dp[0]
                break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf, spec: shard_more(path, leaf, spec),
        params, specs)


def batch_specs(cfg, mesh: Mesh, kind: str) -> PyTree:
    """Input shardings for a shape cell. tokens/labels: (b, s)."""
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)
    tok = P(dpa, None)
    out = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        out["src_embeds"] = P(dpa, None, None)
    if kind != "train":
        out.pop("labels")
    return out


def cache_specs(cfg, caches: PyTree, mesh: Mesh) -> PyTree:
    """Decode-cache shardings: batch on data axes; long sequence dims on
    "model" (flash-decoding style sequence sharding); everything else
    replicated if not divisible."""
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else (dp[0] if dp else None)
    mp_size = mesh.shape["model"] if "model" in mesh.axis_names else 1
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec(leaf):
        shape = tuple(leaf.shape)
        parts: list = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dp_size == 0 and dp_size > 1:
            parts[1] = dpa                     # (L, b, ...) batch dim
        # shard the TP axis on heads, else head_dim, else the longest dim
        # (seq) — heads/head_dim keep decode's dynamic_update_slice local,
        # avoiding GSPMD's involuntary full rematerialization of the cache.
        if mp_size > 1 and len(shape) == 5:    # (L, b, h, s, dh) kv cache
            for cand in (2, 4, 3):
                if shape[cand] % mp_size == 0 and shape[cand] >= mp_size:
                    parts[cand] = "model"
                    break
        elif mp_size > 1 and len(shape) >= 3:
            cand = max(range(2, len(shape)), key=lambda i: shape[i])
            if shape[cand] % mp_size == 0 and shape[cand] >= mp_size * 8:
                parts[cand] = "model"
        return P(*parts)

    return jax.tree.map(spec, caches)
