"""Shared backend-selection policy for the Pallas kernels.

Every kernel wrapper in ``repro.kernels`` offers the same backend
contract (documented in ``docs/kernels.md``):

* ``"jnp"`` — the pure-jnp oracle; always available, never warns.
* ``"pallas"`` — the TPU kernel as requested. Off-TPU it degrades to the
  Pallas *interpreter* (same kernel body, correctness validation only)
  and on import failure to the oracle — each degradation emits a
  one-time ``BackendFallbackWarning`` naming the reason.
* ``"auto"`` — the production default: the kernel on TPU, the oracle
  elsewhere (interpret mode is far too slow for hot paths). The off-TPU
  choice emits a one-time ``BackendFallbackWarning`` so runs that
  expected TPU throughput can see they did not get it.

``repro.core.clustering.kmeans`` re-exports these names so historic
imports (`from repro.core.clustering.kmeans import BackendFallbackWarning`)
keep working.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp


class BackendFallbackWarning(UserWarning):
    """Raised once per (kernel, requested, active) triple when a requested
    kernel backend falls back to a different active backend."""


@dataclasses.dataclass(frozen=True)
class ResolvedBackend:
    """Outcome of kernel-backend selection.

    ``requested`` is the caller's ``backend=`` string; ``active`` is what
    will actually run (``"jnp"``, ``"pallas"`` or ``"pallas_interpret"``);
    ``reason`` explains any divergence (``None`` when served as asked).
    """

    requested: str
    active: str
    reason: Optional[str] = None


_FALLBACK_WARNED: set[tuple[str, str, str]] = set()


def warn_fallback_once(kernel: str, requested: str, active: str,
                       reason: str) -> None:
    """Emit ``BackendFallbackWarning`` once per (kernel, requested, active)."""
    key = (kernel, requested, active)
    if key in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(key)
    if requested == "auto":
        msg = (f"{kernel} backend 'auto' resolved to {active!r} ({reason})")
    else:
        msg = (f"{kernel} backend {requested!r} is not available as "
               f"requested; using {active!r} instead ({reason})")
    warnings.warn(msg, BackendFallbackWarning, stacklevel=4)


def reset_backend_warnings() -> None:
    """Re-arm the one-time fallback warnings (test helper)."""
    _FALLBACK_WARNED.clear()


def kernel_compute_dtype(precision=None) -> jnp.dtype:
    """The dtype a kernel contract computes in under a ``PrecisionPolicy``.

    The jnp oracle honors the policy's *trace* dtype exactly; the Pallas
    kernel bodies accumulate in f32 by construction, so wider traces only
    widen the oracle path (kernel wrappers cast back to f32 before a
    Pallas launch). ``precision=None`` resolves to the repo-wide default
    policy (f32 trace) — the historic hardcoded-f32 behavior.
    """
    from repro.core.precision import resolve_precision

    return jnp.dtype(resolve_precision(precision).trace)


def resolve_backend(requested: str, *, kernel: str,
                    import_probe: Callable[[], None]) -> ResolvedBackend:
    """Map a requested kernel backend to the one that can run here.

    ``kernel`` names the kernel for warning messages; ``import_probe``
    imports the kernel package (raising on failure). Selection policy:

    * ``"jnp"`` resolves to itself, silently.
    * ``"pallas"`` resolves to ``"pallas"`` on TPU, to
      ``"pallas_interpret"`` elsewhere, and to ``"jnp"`` when the kernel
      package cannot import — the latter two warn once.
    * ``"auto"`` resolves to ``"pallas"`` on TPU and to ``"jnp"``
      elsewhere (warning once off-TPU: interpret mode is validation-only,
      not a production path).
    """
    if requested == "jnp":
        return ResolvedBackend("jnp", "jnp")
    if requested not in ("pallas", "auto"):
        raise ValueError(f"unknown backend {requested!r}; "
                         "expected 'jnp', 'pallas' or 'auto'")
    try:
        import_probe()
    except Exception as e:  # pragma: no cover - import is cheap and local
        reason = (f"import of the {kernel} kernel failed: "
                  f"{type(e).__name__}: {e}")
        warn_fallback_once(kernel, requested, "jnp", reason)
        return ResolvedBackend(requested, "jnp", reason)
    platform = jax.default_backend()
    if platform == "tpu":
        return ResolvedBackend(requested, "pallas")
    if requested == "auto":
        reason = (f"platform={platform!r} has no TPU; using the jnp oracle "
                  "(interpret mode is correctness validation, not a "
                  "production path)")
        warn_fallback_once(kernel, requested, "jnp", reason)
        return ResolvedBackend("auto", "jnp", reason)
    reason = (f"platform={platform!r} has no TPU; the Pallas kernel "
              "runs in interpret mode (correctness validation only)")
    warn_fallback_once(kernel, requested, "pallas_interpret", reason)
    return ResolvedBackend("pallas", "pallas_interpret", reason)
