"""Pallas TPU kernels for the simulation-sampling hot spots.

Each kernel is a subpackage with the same three-file layout — the kernel
body + launcher (``<name>/<name>.py``), the public padded wrapper
(``<name>/ops.py``) and a pure-jnp oracle (``<name>/ref.py``). Contracts
(block shapes, padding rules, batch-grid layout, testing recipe) are
documented in ``docs/kernels.md``.
"""
