"""Jitted public wrapper for flash attention with GQA + padding handling."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import BLOCK_K, BLOCK_Q, flash_attention_padded


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True,
                    scale: Optional[float] = None) -> jax.Array:
    """Causal attention. q: (b, hq, sq, d); k, v: (b, hkv, skv, d).

    GQA: hq must be a multiple of hkv; kv heads are broadcast. q and kv are
    FRONT-padded to tile multiples, which preserves the causal
    end-alignment (row i attends cols <= i + skv - sq); padded kv columns
    are excluded via the kernel's ``kv_start`` mask, and padded q rows are
    sliced off the output.
    """
    if not causal:
        raise NotImplementedError("kernel path is causal-only; use ref.py")
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"GQA heads mismatch: {hq} % {hkv}")
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    sq_p = _round_up(sq, BLOCK_Q)
    skv_p = _round_up(skv, BLOCK_K)
    d_p = _round_up(d, 128)
    fq = sq_p - sq
    fk = skv_p - skv
    qp = jnp.zeros((b, hq, sq_p, d_p), q.dtype).at[:, :, fq:, :d].set(q)
    kp = jnp.zeros((b, hq, skv_p, d_p), k.dtype).at[:, :, fk:, :d].set(k)
    vp = jnp.zeros((b, hq, skv_p, d_p), v.dtype).at[:, :, fk:, :d].set(v)

    out = flash_attention_padded(
        qp.reshape(b * hq, sq_p, d_p),
        kp.reshape(b * hq, skv_p, d_p),
        vp.reshape(b * hq, skv_p, d_p),
        causal=True, scale=scale, kv_start=fk,
        interpret=jax.default_backend() != "tpu")
    return out.reshape(b, hq, sq_p, d_p)[:, :, fq:, :d]
