"""Pallas TPU kernel: causal flash attention (streaming softmax).

Standard online-softmax formulation adapted to TPU VMEM tiling:

* grid = (batch·heads, q_blocks, kv_blocks) with kv innermost, so the
  running (m, l, acc) state lives in VMEM scratch across kv steps;
* q tile (BLOCK_Q, d) and k/v tiles (BLOCK_K, d) are MXU-aligned
  (d = head_dim is 128 for every assigned architecture);
* causal masking via broadcasted iotas with END-alignment: query row i
  (global) attends kv columns <= i + (skv - sq), so the same kernel serves
  training (sq == skv) and decode (sq == 1, skv == cache length);
* ``kv_start`` masks front-padding columns (ops.py pads q and kv at the
  front to reach tile multiples, which preserves end-alignment).

Numerics: accumulation in f32 regardless of input dtype (bf16 on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 256
BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, sq: int, skv: int,
                  kv_start: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (BLOCK_Q, d)
    k = k_ref[0].astype(jnp.float32)                 # (BLOCK_K, d)
    v = v_ref[0].astype(jnp.float32)                 # (BLOCK_K, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = qi * BLOCK_Q + jax.lax.broadcasted_iota(
        jnp.int32, (BLOCK_Q, BLOCK_K), 0)
    cols = ki * BLOCK_K + jax.lax.broadcasted_iota(
        jnp.int32, (BLOCK_Q, BLOCK_K), 1)
    mask = cols >= kv_start
    if causal:
        mask &= cols <= rows + (skv - sq)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (BLOCK_Q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (BLOCK_Q, BLOCK_K)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "kv_start",
                                    "interpret"))
def flash_attention_padded(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool, scale: float, kv_start: int = 0,
                           interpret: bool = False) -> jax.Array:
    """q: (bh, sq, d); k, v: (bh, skv, d); sq % BLOCK_Q == 0,
    skv % BLOCK_K == 0. Columns < kv_start are never attended."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    grid = (bh, sq // BLOCK_Q, skv // BLOCK_K)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               sq=sq, skv=skv, kv_start=kv_start)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_K, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BLOCK_K, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),   # running max m
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((BLOCK_Q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
