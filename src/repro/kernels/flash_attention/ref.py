"""Pure-jnp oracle for causal (optionally windowed) attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  *, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (b, h, sq, d); k, v: (b, h, skv, d) (kv heads already broadcast).

    ``window``: local-attention width (keys within [i-window+1, i], used by
    the RecurrentGemma hybrid); None = full causal.
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(sq)[:, None] + (skv - sq)   # align ends (decode-friendly)
    ki = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)
                      ).astype(q.dtype)
