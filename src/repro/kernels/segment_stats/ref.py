"""Pure-jnp oracle for per-stratum statistics."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_stats_ref(x: jax.Array, labels: jax.Array, num_segments: int
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-segment (sum, sum-of-squares, count) of rows of x.

    x: (n, d) f32; labels: (n,) int32 in [0, num_segments).
    Returns sums (k, d), sumsq (k, d), counts (k,).
    These are exactly the sufficient statistics of the stratified estimators
    (eq. 3): means, within-stratum variances, and weights.
    """
    x = x.astype(jnp.float32)
    sums = jax.ops.segment_sum(x, labels, num_segments=num_segments)
    sumsq = jax.ops.segment_sum(x * x, labels, num_segments=num_segments)
    counts = jax.ops.segment_sum(jnp.ones(x.shape[:1], jnp.float32), labels,
                                 num_segments=num_segments)
    return sums, sumsq, counts
