"""Pure-jnp oracle for per-stratum statistics, any rank."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_stats_ref(x: jax.Array, labels: jax.Array, num_segments: int
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-segment (sum, sum-of-squares, count) of rows of x, batched.

    x: ``(..., n, d)`` — or ``(..., n)``, treated as ``d=1``; labels:
    ``(..., n)`` int32 in ``[0, num_segments)`` with ``-1`` marking
    masked rows that contribute nothing (the kernel's padding label).
    Leading axes are shared batch axes. Returns sums ``(..., k, d)``,
    sumsq ``(..., k, d)``, counts ``(..., k)``.
    These are exactly the sufficient statistics of the stratified
    estimators (eq. 3): means, within-stratum variances, and weights.
    """
    x = jnp.asarray(x, jnp.float32)
    labels = jnp.asarray(labels, jnp.int32)
    if x.shape == labels.shape:
        x = x[..., None]
    if x.shape[:-1] != labels.shape:
        raise ValueError(f"labels shape {labels.shape} does not match "
                         f"x shape {x.shape} (need x = labels shape + (d,))")
    batch_shape = labels.shape[:-1]
    n = labels.shape[-1]
    d = x.shape[-1]
    b = 1
    for s in batch_shape:
        b *= s
    xb = x.reshape(b, n, d)
    lb = labels.reshape(b, n)
    # out-of-range labels contribute nothing, exactly like the kernel's
    # one-hot compare (an id >= num_segments must not bleed into the next
    # lane's flat segment space)
    valid = (lb >= 0) & (lb < num_segments)
    # one flat segment id space: lane i owns ids [i*k, (i+1)*k)
    flat = jnp.where(valid, lb, 0) + num_segments * jnp.arange(b)[:, None]
    # w is the masked value, so w*w is the masked square — never multiply
    # by the raw xb, which may be NaN in masked rows
    w = jnp.where(valid[..., None], xb, 0.0).reshape(b * n, d)
    ones = valid.astype(jnp.float32).reshape(b * n)
    flat = flat.reshape(b * n)
    sums = jax.ops.segment_sum(w, flat, num_segments=b * num_segments)
    sumsq = jax.ops.segment_sum(w * w, flat, num_segments=b * num_segments)
    counts = jax.ops.segment_sum(ones, flat, num_segments=b * num_segments)
    return (sums.reshape(*batch_shape, num_segments, d),
            sumsq.reshape(*batch_shape, num_segments, d),
            counts.reshape(*batch_shape, num_segments))
