"""Jitted public wrapper for the batch-native segment-stats kernel.

ONE dispatch path for every input rank: ``(n,)`` / ``(n, d)`` single
problems, ``(A, n)`` app stacks and ``(A, T, n)`` trial stacks all
flatten their leading axes into the kernel's batch grid dimension — no
vmap-of-``pallas_call`` anywhere. Mirrors the ``kmeans_assign``
backend/dispatch-marker contract: ``resolve_backend`` picks the kernel
on TPU and the jnp oracle elsewhere (``backend="auto"``, warning once),
``backend="pallas"`` forces the kernel (interpret mode off-TPU), and
``last_dispatch()`` exposes a trace-time marker describing the most
recent kernel launch so tests can assert the batch-native path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..backend import (ResolvedBackend, kernel_compute_dtype,
                       resolve_backend)
from .ref import segment_stats_ref
from .segment_stats import BLOCK_N, segment_stats_padded

# trace-time record of the most recent kernel dispatch (see last_dispatch)
_last_dispatch: Optional[dict] = None


def last_dispatch() -> Optional[dict]:
    """Snapshot of the most recent ``segment_stats`` kernel dispatch.

    Returns ``None`` if the kernel was never dispatched, else a dict with
    ``batch`` (flattened leading-axes size fed to the batch grid axis),
    ``batch_shape`` (the caller's leading axes, ``()`` for unbatched
    input), ``n``/``k``/``d`` (logical problem shape), ``grid`` (kernel
    launch geometry) and ``interpret``. Only the Pallas path writes the
    record — jnp-oracle calls (the ``"auto"`` fallback off-TPU) leave it
    untouched, so tests can tell the two paths apart.
    """
    return None if _last_dispatch is None else dict(_last_dispatch)


def _reset_dispatch_record() -> None:
    """Clear the dispatch marker (test helper)."""
    global _last_dispatch
    _last_dispatch = None


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _probe_kernel() -> None:
    from . import segment_stats as _mod  # noqa: F401


def resolve_segment_backend(requested: str) -> ResolvedBackend:
    """``repro.kernels.backend.resolve_backend`` bound to this kernel."""
    return resolve_backend(requested, kernel="segment_stats",
                           import_probe=_probe_kernel)


def segment_stats(x: jax.Array, labels: jax.Array, num_segments: int,
                  *, backend: str = "auto", precision=None
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-segment ``(sums, sumsq, counts)`` over any leading batch axes.

    Args:
      x: values — ``(n,)``, ``(n, d)``, or any leading batch axes:
        ``(A, n)``, ``(A, T, n)``, ``(A, n, d)``, ... When ``x`` and
        ``labels`` have the same shape a feature axis of size 1 is
        appended (outputs keep it, matching the historic 1-D contract).
      labels: int32 segment ids, shape = ``x`` minus the feature axis.
        ``-1`` marks masked rows (padding) that contribute nothing.
      num_segments: k, the static number of segments per lane.
      backend: ``"auto"`` (kernel on TPU, jnp oracle elsewhere —
        warning once), ``"pallas"`` (force the kernel; interpret mode
        off-TPU) or ``"jnp"`` (force the oracle).
      precision: optional ``PrecisionPolicy``; the oracle computes in its
        trace dtype (``kernel_compute_dtype``). The Pallas kernel body is
        f32 by construction, so a wider trace is honored by the oracle
        path only.

    Returns:
      ``(sums (..., k, d), sumsq (..., k, d), counts (..., k))`` in the
      compute dtype (float32 under the default policy).

    The Pallas path pads n to ``BLOCK_N`` with label ``-1`` rows
    (matching no segment, contributing nothing) and flattens every
    leading axis into the kernel's ``(batch, n_tiles)`` grid — one
    dispatch regardless of rank.
    """
    x = jnp.asarray(x, kernel_compute_dtype(precision))
    labels = jnp.asarray(labels, jnp.int32)
    if x.shape == labels.shape:
        x = x[..., None]
    if x.shape[:-1] != labels.shape:
        raise ValueError(f"labels shape {labels.shape} does not match "
                         f"x shape {x.shape} (need x = labels shape + (d,))")

    active = resolve_segment_backend(backend).active
    if active == "jnp":
        return segment_stats_ref(x, labels, num_segments)

    # masked/out-of-range rows must contribute NOTHING even when their
    # values are NaN/inf: the one-hot matmul would otherwise turn
    # 0 * NaN into NaN and poison every segment of the lane
    dead = (labels < 0) | (labels >= num_segments)
    x = jnp.where(dead[..., None], 0.0, x).astype(jnp.float32)

    batch_shape = labels.shape[:-1]
    n = labels.shape[-1]
    d = x.shape[-1]
    b = 1
    for s in batch_shape:
        b *= s
    n_p = _round_up(max(n, 1), BLOCK_N)
    x_p = jnp.zeros((b, n_p, d), jnp.float32).at[:, :n].set(
        x.reshape(b, n, d))
    lab_p = jnp.full((b, n_p, 1), -1, jnp.int32).at[:, :n, 0].set(
        labels.reshape(b, n))
    interpret = active == "pallas_interpret"
    global _last_dispatch
    _last_dispatch = {
        "batch": b, "batch_shape": batch_shape, "n": n,
        "k": num_segments, "d": d, "grid": (b, n_p // BLOCK_N),
        "interpret": interpret,
    }
    sums, sumsq, counts = segment_stats_padded(
        x_p, lab_p, num_segments, interpret=interpret)
    return (sums.reshape(*batch_shape, num_segments, d),
            sumsq.reshape(*batch_shape, num_segments, d),
            counts.reshape(*batch_shape, num_segments))


def stratum_moments(x: jax.Array, labels: jax.Array, num_segments: int,
                    *, backend: str = "auto"
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(means, sample variances, counts) per stratum from the kernel stats.

    Any leading batch axes (same contract as ``segment_stats``). Variance
    uses the n-1 denominator (matches eq. 2); strata with fewer than 2
    units get NaN variance (flagging that collapsed strata or more
    sampling is needed — paper fn. 7).
    """
    sums, sumsq, counts = segment_stats(x, labels, num_segments,
                                        backend=backend)
    safe = jnp.maximum(counts, 1.0)
    means = sums / safe[..., None]
    ss = sumsq - counts[..., None] * means * means
    var = jnp.where((counts > 1)[..., None],
                    ss / jnp.maximum(counts - 1.0, 1.0)[..., None], jnp.nan)
    return means, var, counts
