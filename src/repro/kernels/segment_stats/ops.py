"""Jitted public wrapper for the segment-stats kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .segment_stats import BLOCK_N, segment_stats_padded


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def segment_stats(x: jax.Array, labels: jax.Array, num_segments: int
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-segment (sums, sumsq, counts). Pads n to BLOCK_N with label -1
    rows (matching no segment) so padding contributes nothing."""
    x = jnp.asarray(x, jnp.float32)
    labels = jnp.asarray(labels, jnp.int32)
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    if labels.shape != (n,):
        raise ValueError(f"labels shape {labels.shape} != ({n},)")
    n_p = _round_up(max(n, 1), BLOCK_N)
    x_p = jnp.zeros((n_p, d), jnp.float32).at[:n].set(x)
    lab_p = jnp.full((n_p, 1), -1, jnp.int32).at[:n, 0].set(labels)
    interpret = jax.default_backend() != "tpu"
    return segment_stats_padded(x_p, lab_p, num_segments, interpret=interpret)


def stratum_moments(x: jax.Array, labels: jax.Array, num_segments: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(means, sample variances, counts) per stratum from the kernel stats.

    Variance uses the n-1 denominator (matches eq. 2); strata with fewer
    than 2 units get NaN variance (flagging that collapsed strata or more
    sampling is needed — paper fn. 7).
    """
    sums, sumsq, counts = segment_stats(x, labels, num_segments)
    safe = jnp.maximum(counts, 1.0)
    means = sums / safe[:, None]
    ss = sumsq - counts[:, None] * means * means
    var = jnp.where((counts > 1)[:, None],
                    ss / jnp.maximum(counts - 1.0, 1.0)[:, None], jnp.nan)
    return means, var, counts
