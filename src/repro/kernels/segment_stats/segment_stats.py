"""Pallas TPU kernel: per-stratum sufficient statistics, batch-native.

TPU adaptation of the centroid-update / stratified-moment scatter: a scatter
by stratum label is hostile to the TPU memory system, so it is recast as a
one-hot matmul — ``onehot(labels)ᵀ @ x`` — which runs on the MXU.

The grid is ``(batch, n_tiles)`` with the tile axis innermost (the same
layout as ``kmeans_assign``): batch element ``b`` keeps its ``(k, d)``
output blocks resident while its row tiles stream through. Outputs map
every tile step of a batch element to the same block (revisited
accumulation): zero-initialized at tile 0, accumulated thereafter. Labels
arrive as a ``(batch, n, 1)`` int32 column so the one-hot compare
vectorizes over lanes; label ``-1`` (padding / masked rows) matches no
segment and contributes nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _segment_kernel(x_ref, lab_ref, sums_ref, sumsq_ref, counts_ref):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        sumsq_ref[...] = jnp.zeros_like(sumsq_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[0].astype(jnp.float32)                   # (BLOCK_N, d)
    labels = lab_ref[0]                                # (BLOCK_N, 1)
    k = sums_ref.shape[1]
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_N, k), 1)
    onehot = (labels == seg_ids).astype(jnp.float32)   # (BLOCK_N, k)
    # MXU: (k, BLOCK_N) @ (BLOCK_N, d)
    sums_ref[0] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    sumsq_ref[0] += jax.lax.dot_general(
        onehot, x * x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    counts_ref[0] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def segment_stats_padded(x: jax.Array, labels: jax.Array, num_segments: int,
                         *, interpret: bool = False):
    """x: (b, n, d), n % BLOCK_N == 0; labels: (b, n, 1) int32 (pad = -1).

    Returns per-batch-element ``(sums (b, k, d), sumsq (b, k, d),
    counts (b, k))`` over the ``(batch, n_tiles)`` kernel grid.
    """
    b, n, d = x.shape
    grid = (b, n // BLOCK_N)
    return pl.pallas_call(
        _segment_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_N, d), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, BLOCK_N, 1), lambda bi, i: (bi, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, num_segments, d), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, num_segments, d), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, num_segments), lambda bi, i: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, num_segments, d), jnp.float32),
            jax.ShapeDtypeStruct((b, num_segments, d), jnp.float32),
            jax.ShapeDtypeStruct((b, num_segments), jnp.float32),
        ],
        interpret=interpret,
    )(x, labels)
