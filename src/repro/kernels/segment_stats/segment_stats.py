"""Pallas TPU kernel: per-stratum sufficient statistics.

TPU adaptation of the centroid-update / stratified-moment scatter: a scatter
by stratum label is hostile to the TPU memory system, so it is recast as a
one-hot matmul — ``onehot(labels)ᵀ @ x`` — which runs on the MXU.

Grid iterates over row blocks; outputs map every grid step to the same
block (revisited accumulation): zero-initialized at step 0, accumulated
thereafter. Labels arrive as an (n, 1) int32 column so the one-hot compare
vectorizes over lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _segment_kernel(x_ref, lab_ref, sums_ref, sumsq_ref, counts_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        sumsq_ref[...] = jnp.zeros_like(sumsq_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...].astype(jnp.float32)                 # (BLOCK_N, d)
    labels = lab_ref[...]                              # (BLOCK_N, 1)
    k = sums_ref.shape[0]
    seg_ids = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_N, k), 1)
    onehot = (labels == seg_ids).astype(jnp.float32)   # (BLOCK_N, k)
    # MXU: (k, BLOCK_N) @ (BLOCK_N, d)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    sumsq_ref[...] += jax.lax.dot_general(
        onehot, x * x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def segment_stats_padded(x: jax.Array, labels: jax.Array, num_segments: int,
                         *, interpret: bool = False):
    """x: (n, d), n % BLOCK_N == 0; labels: (n, 1) int32 (pad rows = -1)."""
    n, d = x.shape
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _segment_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
            pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
            pl.BlockSpec((num_segments,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
            jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
            jax.ShapeDtypeStruct((num_segments,), jnp.float32),
        ],
        interpret=interpret,
    )(x, labels)
