"""Pallas TPU kernel: batch-native tiled nearest-centroid assignment.

The clustering hot spot at fleet scale (paper §VII.B: clustering ≥100 k
BBVs) is the (n, d) × (d, k) distance matmul, repeated across a leading
batch of independent problems — the flattened key × restart × app axes of
``kmeans_batch`` / ``kmeans_bank``. TPU adaptation:

* the squared distance is expanded to |x|² − 2·x·cᵀ + |c|², so the inner
  loop is a plain matmul that maps onto the 128×128 MXU;
* the grid is ``(batch, n_tiles)`` with the tile axis innermost: batch
  element ``b`` keeps its centroid block resident in VMEM while its point
  tiles stream through — no vmap-of-``pallas_call`` lifting, every batch
  element is a first-class grid coordinate with its own centroid block
  selected by the ``BlockSpec`` index maps;
* points are tiled along n with ``block_n`` rows resident in VMEM; the
  per-batch centroid block (k ≤ ~1024, d ≤ ~512 after projection and
  standardization) also lives in VMEM — k·d·4 B ≈ 2 MB worst case, well
  under the ~16 MB v5e VMEM budget together with a 512×512 x-tile (1 MB);
* the argmin over k runs on the VPU on the (block_n, k) distance tile.

Padding rules (handled by ops.py, identical for every batch element):
n → multiple of ``block_n``, k → multiple of 128 with +inf ``|c|²``
sentinel entries, d → multiple of 128 with zero columns. Padded point
rows are all-zero tiles whose outputs are sliced off by the wrapper;
padded centroids can never win the argmin; padded feature columns are
zero in both operands so distances are unchanged — the same
padding-invariance contract the unbatched kernel had.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512


def _assign_kernel(x_ref, c_ref, c2_ref, labels_ref, mind2_ref):
    """One (batch element, point tile) grid step.

    Block shapes: x (1, block_n, d), c (1, k, d), c2 (1, 1, k) — the
    leading 1 is the batch block; outputs (1, block_n).
    """
    x = x_ref[0].astype(jnp.float32)            # (block_n, d)
    c = c_ref[0].astype(jnp.float32)            # (k, d)
    c2 = c2_ref[0]                              # (1, k) — +inf on pad rows
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (block_n, 1)
    # MXU: (block_n, d) @ (d, k)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = x2 - 2.0 * xc + c2                     # (block_n, k)
    labels_ref[0, :] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2_ref[0, :] = jnp.maximum(jnp.min(d2, axis=1), 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_padded(x: jax.Array, c: jax.Array, c2: jax.Array,
                         *, block_n: int = BLOCK_N, interpret: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """Batch-native assignment on pre-padded operands.

    Args:
      x: ``(B, n, d)`` points, ``n % block_n == 0``.
      c: ``(B, k, d)`` centroids (one block per batch element).
      c2: ``(B, 1, k)`` squared centroid norms, ``+inf`` on padded rows.
      block_n: point-tile rows resident in VMEM per grid step.
      interpret: run the Pallas interpreter (CPU validation) instead of
        compiling for TPU.

    Returns:
      ``(labels (B, n) int32, min_d2 (B, n) float32)``.
    """
    b, n, d = x.shape
    k = c.shape[1]
    grid = (b, n // block_n)                    # tile axis innermost:
    # the (k, d) centroid block is re-fetched only when b advances
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d), lambda b, i: (b, i, 0)),  # x tile
            pl.BlockSpec((1, k, d), lambda b, i: (b, 0, 0)),        # centroids
            pl.BlockSpec((1, 1, k), lambda b, i: (b, 0, 0)),        # |c|^2 row
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, c, c2)
