"""Pallas TPU kernel: tiled nearest-centroid assignment.

The clustering hot spot at fleet scale (paper §VII.B: clustering ≥100 k
BBVs) is the (n, d) × (d, k) distance matmul. TPU adaptation:

* the squared distance is expanded to |x|² − 2·x·cᵀ + |c|², so the inner
  loop is a plain matmul that maps onto the 128×128 MXU;
* points are tiled along n with BLOCK_N rows resident in VMEM; the full
  centroid block (k ≤ ~1024, d ≤ ~512 after projection/standardization)
  also lives in VMEM — k·d·4 B ≈ 2 MB worst case, well under the ~16 MB
  v5e VMEM budget together with a 512×512 x-tile (1 MB);
* the argmin over k runs on the VPU on the (BLOCK_N, k) distance tile.

Padding rules (handled by ops.py): n → multiple of BLOCK_N, k → multiple
of 128 with +inf sentinel rows, d → multiple of 128 with zero columns.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512


def _assign_kernel(x_ref, c_ref, c2_ref, labels_ref, mind2_ref):
    x = x_ref[...].astype(jnp.float32)          # (BLOCK_N, d)
    c = c_ref[...].astype(jnp.float32)          # (k, d)
    c2 = c2_ref[...]                            # (1, k) — +inf on pad rows
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (BLOCK_N, 1)
    # MXU: (BLOCK_N, d) @ (d, k)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    d2 = x2 - 2.0 * xc + c2                     # (BLOCK_N, k)
    labels_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2_ref[...] = jnp.maximum(jnp.min(d2, axis=1), 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kmeans_assign_padded(x: jax.Array, c: jax.Array, c2: jax.Array,
                         *, interpret: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """x: (n, d) with n % BLOCK_N == 0; c: (k, d); c2: (1, k) (+inf pads)."""
    n, d = x.shape
    k = c.shape[0]
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),   # x tile
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # centroids
            pl.BlockSpec((1, k), lambda i: (0, 0)),         # |c|^2 row
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x, c, c2)
