"""Jitted public wrapper for the batch-native k-means assignment kernel.

ONE dispatch path for every input rank: ``(n, d)`` single problems,
``(B, n, d)`` key/restart batches and ``(A, R, n, d)``-style bank shapes
all flatten their leading axes into the kernel's batch grid dimension —
no vmap-of-``pallas_call`` anywhere. Handles padding to hardware-aligned
shapes and falls back to interpret mode off-TPU (this container validates
the kernel body on CPU; TPU is the compile target).

``last_dispatch()`` exposes a trace-time marker describing the most
recent kernel dispatch (batch size, grid, block shape, interpret flag) so
tests and benchmarks can assert the batch-native path was taken rather
than a lifted/vmapped one.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans_assign import BLOCK_N, kmeans_assign_padded

# trace-time record of the most recent kernel dispatch (see last_dispatch)
_last_dispatch: Optional[dict] = None


def last_dispatch() -> Optional[dict]:
    """Snapshot of the most recent ``kmeans_assign`` kernel dispatch.

    Returns ``None`` if the kernel was never dispatched, else a dict with
    ``batch`` (flattened leading-axes size fed to the batch grid axis),
    ``batch_shape`` (the caller's leading axes, ``()`` for 2-D input),
    ``n``/``k``/``d`` (logical problem shape), ``grid``/``block_n``
    (kernel launch geometry) and ``interpret``. The record is written at
    trace time: jit-cached re-executions of an already-traced fit do not
    refresh it, so tests should use fresh shapes to force a trace.
    """
    return None if _last_dispatch is None else dict(_last_dispatch)


def _reset_dispatch_record() -> None:
    """Clear the dispatch marker (test helper)."""
    global _last_dispatch
    _last_dispatch = None


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kmeans_assign(x: jax.Array, centroids: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment via the batch-native Pallas kernel.

    Args:
      x: points — ``(n, d)``, ``(B, n, d)`` or any higher-rank stack such
        as a ``(A, R, n, d)`` bank; every axis before the trailing two is
        treated as batch.
      centroids: ``(..., k, d)`` with leading axes matching ``x`` exactly
        (one centroid block per batch element).

    Returns:
      ``(labels, min_d2)`` with shapes ``(..., n)`` — int32 labels and
      float32 squared distance to the winning centroid.

    All batch elements share one ``(batch, n_tiles)`` kernel grid: leading
    axes are flattened into the batch grid axis, n is padded to the point
    tile, k and d to multiples of 128. Padded centroids get +inf ``|c|²``
    so they can never win the argmin; padded d columns are zero in both
    operands so distances are unchanged; padded n rows are computed then
    sliced off — assignment of every valid row is invariant to padding.
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centroids, jnp.float32)
    if x.ndim < 2 or c.ndim != x.ndim:
        raise ValueError(
            f"rank mismatch: x {x.shape} vs centroids {c.shape} "
            "(need matching leading axes plus trailing (n|k, d))")
    if x.shape[:-2] != c.shape[:-2]:
        raise ValueError(
            f"batch mismatch: x {x.shape} vs centroids {c.shape}")
    if c.shape[-1] != x.shape[-1]:
        raise ValueError(f"dim mismatch: x {x.shape} vs centroids {c.shape}")

    batch_shape = x.shape[:-2]
    n, d = x.shape[-2:]
    k = c.shape[-2]
    b = math.prod(batch_shape) if batch_shape else 1

    # hardware-aligned padding, shared by every batch element
    d_p = _round_up(max(d, 1), 128)
    k_p = _round_up(max(k, 1), 128)
    block_n = min(BLOCK_N, _round_up(max(n, 1), 128))
    n_p = _round_up(max(n, 1), block_n)

    xb = x.reshape(b, n, d)
    cb = c.reshape(b, k, d)
    x_p = jnp.zeros((b, n_p, d_p), jnp.float32).at[:, :n, :d].set(xb)
    c_p = jnp.zeros((b, k_p, d_p), jnp.float32).at[:, :k, :d].set(cb)
    c2 = jnp.full((b, 1, k_p), jnp.inf, jnp.float32).at[:, 0, :k].set(
        jnp.sum(cb * cb, axis=2))

    interpret = not _on_tpu()
    global _last_dispatch
    _last_dispatch = {
        "batch": b, "batch_shape": batch_shape, "n": n, "k": k, "d": d,
        "grid": (b, n_p // block_n), "block_n": block_n,
        "interpret": interpret,
    }
    labels, mind2 = kmeans_assign_padded(x_p, c_p, c2, block_n=block_n,
                                         interpret=interpret)
    labels = labels[:, :n].reshape(*batch_shape, n)
    mind2 = mind2[:, :n].reshape(*batch_shape, n)
    return labels, mind2


def kmeans_assign_np(x: np.ndarray, centroids: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
    """``kmeans_assign`` with numpy in/out (host-side callers)."""
    labels, mind2 = kmeans_assign(x, centroids)
    return np.asarray(labels), np.asarray(mind2)
