"""Jitted public wrapper for the k-means assignment kernel.

Handles padding to hardware-aligned shapes and falls back to interpret mode
off-TPU (this container validates the kernel body on CPU; TPU is the
compile target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans_assign import BLOCK_N, kmeans_assign_padded


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kmeans_assign(x: jax.Array, centroids: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment via the Pallas kernel.

    x: (n, d), centroids: (k, d) -> (labels (n,) int32, min_d2 (n,) f32).
    Pads n to BLOCK_N, k and d to multiples of 128; padded centroids get
    +inf |c|^2 so they can never win the argmin; padded d columns are zero
    in both operands so distances are unchanged.
    """
    x = jnp.asarray(x, jnp.float32)
    c = jnp.asarray(centroids, jnp.float32)
    n, d = x.shape
    k = c.shape[0]
    if c.shape[1] != d:
        raise ValueError(f"dim mismatch: x {x.shape} vs centroids {c.shape}")

    n_p = _round_up(max(n, 1), BLOCK_N)
    d_p = _round_up(max(d, 1), 128)
    k_p = _round_up(max(k, 1), 128)

    x_p = jnp.zeros((n_p, d_p), jnp.float32).at[:n, :d].set(x)
    c_p = jnp.zeros((k_p, d_p), jnp.float32).at[:k, :d].set(c)
    c2 = jnp.full((1, k_p), jnp.inf, jnp.float32).at[0, :k].set(
        jnp.sum(c * c, axis=1))

    labels, mind2 = kmeans_assign_padded(x_p, c_p, c2,
                                         interpret=not _on_tpu())
    return labels[:n], mind2[:n]


def kmeans_assign_np(x: np.ndarray, centroids: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
    labels, mind2 = kmeans_assign(x, centroids)
    return np.asarray(labels), np.asarray(mind2)
