"""Pure-jnp oracle for the k-means assignment kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment (correctness reference, any rank).

    Args:
      x: ``(..., n, d)`` points; centroids: ``(..., k, d)`` with matching
        leading (batch) axes — the same contract as ``ops.kmeans_assign``.

    Returns:
      ``(labels int32 (..., n), min squared distance f32 (..., n))``.
      Distances computed in f32 with the expanded form
      |x|^2 - 2 x.cT + |c|^2 (matching the kernel's MXU-friendly
      formulation).
    """
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)          # (..., n, 1)
    c2 = jnp.sum(c * c, axis=-1)                         # (..., k)
    xc = jnp.einsum("...nd,...kd->...nk", x, c)
    d2 = x2 - 2.0 * xc + c2[..., None, :]                # (..., n, k)
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return labels, jnp.maximum(jnp.min(d2, axis=-1), 0.0)
