"""Generative SPECint-2017-like region populations.

Every application is a seeded generative model producing, per 1 M-instruction
region, a vector of *intrinsic* (config-independent) workload features. The
analytical core model (perfmodel.py) then maps features × UarchConfig to CPI
and the 38 Table III counters.

The generator encodes the phenomena the paper's methodology depends on:

* **Latent phases** (sticky Markov sequence) — multimodal CPI distributions
  (paper Figs 1, 6).
* **Input-data jitter** — within-phase variation of memory/branch behavior
  *not* reflected in the code profile, the reason BBV↔CPI correlation is
  imperfect (paper III.A).
* **BBV aliasing** — distinct behavior phases sharing one basic-block
  profile (same function, different data), which makes BBV stratification
  *worse than random* for some apps (paper V.A.1: gcc, mcf, omnetpp,
  xalancbmk, xz).
* **Heavy-tail outliers** — e.g. a gcc-like L2-miss-chain mode with CPI≈28
  against a 1.36 mean (paper V.A.1), invisible to BBVs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import zlib

# Feature column indices (see perfmodel.py for their use).
FEATURES = (
    "ilp",              # 0  sustainable IPC ignoring stalls
    "br_pki",           # 1  branches / kilo-instruction
    "br_mpr",           # 2  baseline mispredict rate per branch (config0 TAGE)
    "br_predict",       # 3  TAGE capacity scaling exponent
    "cond_frac",        # 4  conditional share of mispredicts
    "ic_mpki",          # 5  icache MPKI at 32 KB
    "ic_alpha",         # 6  icache size sensitivity
    "itlb_mpki",        # 7
    "l1d_apki",         # 8  L1D accesses / ki
    "load_frac",        # 9
    "l1d_mpki",         # 10 L1D MPKI at 32 KB
    "l1d_alpha",        # 11
    "l2_mpki",          # 12 L2 MPKI at 512 KB
    "l2_alpha",         # 13
    "l3_mpki",          # 14 L3 MPKI at 2 MB
    "l3_alpha",         # 15
    "wb_frac",          # 16 dirty-evict fraction
    "sms_cov",          # 17 SMS prefetch coverage of DRAM misses
    "bo_cov",           # 18 Best-Offset coverage of L3-hit misses
    "mlp",              # 19 memory-level parallelism of the miss stream
    "rob_sens",         # 20 ILP gain from a larger ROB (0..1)
)
NUM_FEATURES = len(FEATURES)
REGION_LEN_INSTR = 1_000_000   # paper IV.A: 1 M-instruction regions


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """Knobs of one synthetic application's population generator."""

    name: str
    n_regions: int
    n_phases: int
    phase1_n: int                    # Table II sample size
    # lognormal sigma of within-phase input-data jitter on rate features
    jitter: float
    # per-app scale factors applied to phase-mean draws
    ilp_range: tuple[float, float]
    br_pki_mean: float
    br_mpr_mean: float
    mem_l1_mpki_mean: float          # L1D MPKI scale
    mem_escape: float                # fraction surviving each cache level
    mlp_range: tuple[float, float]
    prefetchability: float           # mean SMS/BO coverage
    phase_spread: float              # multiplicative spread of phase means
    # heavy-tail outliers
    outlier_prob: float = 0.0
    outlier_l3_mpki: float = 0.0
    outlier_sms_cov: float = 0.7
    # BBV aliasing: number of phase pairs sharing a BBV profile.
    # "adjacent" pairs neighbouring-popularity phases (balanced mixtures);
    # "spread" pairs popular with rare phases (skewed mixtures).
    alias_pairs: int = 0
    alias_scheme: str = "adjacent"
    # memory-rate multiplier range for the aliased (heavier-input) phase
    alias_mem_scale: tuple[float, float] = (2.0, 3.5)
    zipf: float = 0.7                # phase-popularity skew
    # The dominant phase (id 0) may model "one hot code path, wildly varying
    # input data": its own jitter sigma and a memory-rate multiplier.
    dominant_jitter: Optional[float] = None
    dominant_mem_scale: float = 1.0
    # Bimodal input regime for the dominant phase: (heavy fraction, u-shift).
    # Small working sets vs huge ones running the same code; k=20 BBV
    # clustering keeps both regimes in one cluster, k=50 separates them —
    # the paper's gcc 20->50 sensitivity.
    dominant_bimodal: Optional[tuple[float, float]] = None
    markov_stickiness: float = 0.995


# Populations sized so a full census stays cheap while Table II phase-1
# sample sizes remain small fractions (<~15 %) of the population.
APP_SPECS: tuple[AppSpec, ...] = (
    AppSpec("500.perlbench_r", 60_000, 8, 1_997, jitter=0.30,
            ilp_range=(2.2, 5.0), br_pki_mean=190.0, br_mpr_mean=0.013,
            mem_l1_mpki_mean=15.8, mem_escape=0.28, mlp_range=(1.8, 5.0),
            prefetchability=0.45, phase_spread=0.47),
    AppSpec("502.gcc_r", 120_000, 40, 6_195, jitter=0.30,
            ilp_range=(2.0, 5.0), br_pki_mean=210.0, br_mpr_mean=0.011,
            mem_l1_mpki_mean=11.4, mem_escape=0.38, mlp_range=(1.8, 4.5),
            prefetchability=0.40, phase_spread=0.55, zipf=1.3,
            dominant_jitter=1.00, dominant_mem_scale=1.6,
            dominant_bimodal=(0.40, 2.8),
            outlier_prob=0.0010, outlier_l3_mpki=70.0, outlier_sms_cov=0.72,
            alias_pairs=4),
    AppSpec("505.mcf_r", 40_000, 4, 964, jitter=0.45,
            ilp_range=(2.0, 3.5), br_pki_mean=160.0, br_mpr_mean=0.016,
            mem_l1_mpki_mean=34.0, mem_escape=0.46, mlp_range=(3.0, 7.0),
            prefetchability=0.30, phase_spread=0.12, alias_pairs=1,
            alias_mem_scale=(1.5, 2.0)),
    AppSpec("520.omnetpp_r", 40_000, 6, 967, jitter=0.08,
            ilp_range=(2.0, 4.0), br_pki_mean=180.0, br_mpr_mean=0.010,
            mem_l1_mpki_mean=9.3, mem_escape=0.38, mlp_range=(1.8, 3.0),
            prefetchability=0.35, phase_spread=0.10, alias_pairs=2,
            alias_mem_scale=(1.35, 1.7)),
    AppSpec("523.xalancbmk_r", 100_000, 10, 6_861, jitter=0.40,
            ilp_range=(2.5, 5.5), br_pki_mean=200.0, br_mpr_mean=0.009,
            mem_l1_mpki_mean=12.6, mem_escape=0.31, mlp_range=(2.0, 6.0),
            prefetchability=0.55, phase_spread=0.10, alias_pairs=4,
            alias_mem_scale=(1.5, 2.2)),
    AppSpec("525.x264_r", 40_000, 5, 915, jitter=0.12,
            ilp_range=(3.6, 7.5), br_pki_mean=90.0, br_mpr_mean=0.006,
            mem_l1_mpki_mean=7.0, mem_escape=0.30, mlp_range=(6.0, 12.0),
            prefetchability=0.80, phase_spread=0.58),
    AppSpec("531.deepsjeng_r", 40_000, 4, 1_041, jitter=0.07,
            ilp_range=(3.0, 5.0), br_pki_mean=170.0, br_mpr_mean=0.017,
            mem_l1_mpki_mean=5.0, mem_escape=0.22, mlp_range=(2.0, 4.0),
            prefetchability=0.35, phase_spread=0.30),
    AppSpec("541.leela_r", 40_000, 3, 1_062, jitter=0.05,
            ilp_range=(3.0, 4.5), br_pki_mean=150.0, br_mpr_mean=0.014,
            mem_l1_mpki_mean=5.0, mem_escape=0.20, mlp_range=(2.0, 4.0),
            prefetchability=0.40, phase_spread=0.03),
    AppSpec("548.exchange2_r", 40_000, 2, 1_030, jitter=0.05,
            ilp_range=(2.8, 4.2), br_pki_mean=140.0, br_mpr_mean=0.012,
            mem_l1_mpki_mean=0.48, mem_escape=0.10, mlp_range=(2.0, 4.0),
            prefetchability=0.30, phase_spread=0.04),
    AppSpec("557.xz_r", 80_000, 30, 3_047, jitter=0.35,
            ilp_range=(2.0, 6.0), br_pki_mean=170.0, br_mpr_mean=0.015,
            mem_l1_mpki_mean=16.5, mem_escape=0.38, mlp_range=(1.5, 8.0),
            prefetchability=0.45, phase_spread=0.60, zipf=1.2,
            dominant_jitter=0.95, dominant_mem_scale=1.5,
            dominant_bimodal=(0.45, 2.9),
            outlier_prob=0.001, outlier_l3_mpki=45.0, outlier_sms_cov=0.6,
            alias_pairs=6, alias_scheme="spread"),
)

APP_NAMES = tuple(s.name for s in APP_SPECS)


@dataclasses.dataclass(frozen=True)
class AppPopulation:
    """Fully materialized population: one row of features per region."""

    spec: AppSpec
    features: np.ndarray          # (n_regions, NUM_FEATURES) float64
    phase_ids: np.ndarray         # (n_regions,) int32 — latent truth
    bbv_profile_ids: np.ndarray   # (n_phases,) int32 — phase -> BBV profile
    is_outlier: np.ndarray        # (n_regions,) bool
    jitter_u: np.ndarray          # (n_regions,) float32 — input-heaviness
                                  # z-score; weakly visible in BBVs

    @property
    def n_regions(self) -> int:
        return int(self.features.shape[0])


def _phase_means(spec: AppSpec, rng: np.random.Generator) -> np.ndarray:
    """Draw per-phase mean feature vectors from app-level priors."""
    P = spec.n_phases
    m = np.zeros((P, NUM_FEATURES))
    spread = spec.phase_spread

    def ln(mean, sig):  # lognormal with given mean, multiplicative sigma
        return mean * np.exp(rng.normal(0.0, sig, P))

    m[:, 0] = rng.uniform(*spec.ilp_range, P)                       # ilp
    m[:, 1] = ln(spec.br_pki_mean, 0.2)                             # br_pki
    m[:, 2] = np.clip(ln(spec.br_mpr_mean, spread), 1e-4, 0.08)     # br_mpr
    m[:, 3] = rng.uniform(0.10, 0.45, P)                            # br_predict
    m[:, 4] = rng.uniform(0.6, 0.95, P)                             # cond_frac
    m[:, 5] = np.clip(ln(1.2, spread), 0.01, 40.0)                  # ic_mpki
    m[:, 6] = rng.uniform(0.3, 1.0, P)                              # ic_alpha
    m[:, 7] = np.clip(ln(0.15, 0.4), 0.0, 4.0)                      # itlb_mpki
    m[:, 8] = ln(350.0, 0.10)                                       # l1d_apki
    m[:, 9] = rng.uniform(0.6, 0.8, P)                              # load_frac
    m[:, 10] = np.clip(ln(spec.mem_l1_mpki_mean, spread), 0.02, 120.)  # l1d_mpki
    m[:, 11] = rng.uniform(0.2, 0.9, P)                             # l1d_alpha
    esc = np.clip(spec.mem_escape * np.exp(rng.normal(0, spread/2, P)),
                  0.02, 0.85)
    m[:, 12] = m[:, 10] * esc                                       # l2_mpki
    m[:, 13] = rng.uniform(0.2, 0.9, P)                             # l2_alpha
    esc3 = np.clip(spec.mem_escape * np.exp(rng.normal(0, spread/2, P)),
                   0.02, 0.85)
    m[:, 14] = m[:, 12] * esc3                                      # l3_mpki
    m[:, 15] = rng.uniform(0.1, 0.8, P)                             # l3_alpha
    m[:, 16] = rng.uniform(0.15, 0.5, P)                            # wb_frac
    m[:, 17] = np.clip(spec.prefetchability *
                       np.exp(rng.normal(0, 0.3, P)), 0.02, 0.95)   # sms_cov
    m[:, 18] = np.clip(spec.prefetchability *
                       np.exp(rng.normal(0, 0.3, P)), 0.02, 0.95)   # bo_cov
    m[:, 19] = rng.uniform(*spec.mlp_range, P)                      # mlp
    m[:, 20] = rng.uniform(0.1, 0.9, P)                             # rob_sens
    return m


def _phase_sequence(spec: AppSpec, rng: np.random.Generator) -> np.ndarray:
    """Sticky Markov phase sequence over the region timeline."""
    P, n = spec.n_phases, spec.n_regions
    # stationary-ish: stay with prob s, else jump to a random phase with
    # phase-specific popularity (Zipf-ish so cluster weights are unbalanced).
    pop = 1.0 / np.arange(1, P + 1) ** spec.zipf
    pop /= pop.sum()
    seq = np.empty(n, dtype=np.int32)
    seq[0] = rng.choice(P, p=pop)
    stay = spec.markov_stickiness
    jumps = rng.random(n) > stay
    targets = rng.choice(P, size=n, p=pop)
    for i in range(1, n):
        seq[i] = targets[i] if jumps[i] else seq[i - 1]
    return seq


# Rate-like feature columns that receive within-phase input-data jitter
# (invisible to BBVs — same code, different data).
_JITTER_COLS = (2, 5, 10, 12, 14, 19)


def _alias_profiles(spec: AppSpec) -> tuple[np.ndarray, dict[int, int]]:
    """BBV profile per phase; aliased pairs share one profile id.

    "adjacent" pairs neighbouring-popularity phases (balanced mixtures, the
    worst case for centroid selection); "spread" pairs popular with rare
    phases (skewed mixtures).
    """
    profile_ids = np.arange(spec.n_phases, dtype=np.int32)
    alias_of: dict[int, int] = {}
    for a in range(spec.alias_pairs):
        if spec.alias_scheme == "adjacent":
            i, j = 2 * a, 2 * a + 1
        else:  # "spread"
            i, j = a, spec.n_phases - 1 - a
        if i < j < spec.n_phases:
            profile_ids[j] = profile_ids[i]
            alias_of[j] = i
    return profile_ids, alias_of


# Feature columns shared by aliased phases (same static code => same ILP,
# branch structure, footprint profile) vs scaled (bigger input data).
_CODE_COLS = (0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 11, 13, 15, 16, 20)
_DATA_SCALE_COLS = (10, 12, 14)   # l1d/l2/l3 MPKI: larger working set


def generate_population(spec: AppSpec, *, seed: int = 0) -> AppPopulation:
    # Independent child streams so tuning one mechanism (e.g. alias scale)
    # does not reshuffle the draws of every other mechanism.
    root = np.random.SeedSequence([zlib.crc32(spec.name.encode()), seed])
    rng_means, rng_alias, rng_seq, rng_jit, rng_out = [
        np.random.default_rng(s) for s in root.spawn(5)]

    means = _phase_means(spec, rng_means)
    profile_ids, alias_of = _alias_profiles(spec)
    # Aliased phase j executes phase i's code on a heavier input: code
    # features copied, memory rates scaled up, MLP degraded. This is the
    # systematic (same-sign) error source for BBV centroid selection: the
    # popular regime's centroid region stands in for the slow regime too.
    for j, i in alias_of.items():
        means[j, list(_CODE_COLS)] = means[i, list(_CODE_COLS)]
        scale = rng_alias.uniform(*spec.alias_mem_scale)
        means[j, list(_DATA_SCALE_COLS)] = means[i, list(_DATA_SCALE_COLS)] * scale
        means[j, 19] = max(1.0, means[i, 19] * rng_alias.uniform(0.55, 0.8))
        means[j, 17] = means[i, 17]
        means[j, 18] = means[i, 18]
    if spec.dominant_mem_scale != 1.0:
        means[0, list(_DATA_SCALE_COLS)] *= spec.dominant_mem_scale
    seq = _phase_sequence(spec, rng_seq)
    feats = means[seq].copy()

    # Within-phase input-data jitter (lognormal). A single latent
    # "input-heaviness" z-score u drives all memory-rate deviations of a
    # region, so jitter is one direction in behavior space (as a data-set
    # size would be), not independent noise per counter. The dominant phase
    # may carry its own (heavier) sigma.
    n = spec.n_regions
    sigma = np.full(n, spec.jitter)
    if spec.dominant_jitter is not None:
        sigma[seq == 0] = spec.dominant_jitter
    u = rng_jit.normal(0.0, 1.0, n)
    if spec.dominant_bimodal is not None and spec.dominant_jitter is not None:
        frac_heavy, delta_u = spec.dominant_bimodal
        dom = seq == 0
        u[dom] = rng_jit.normal(0.0, 0.55, int(dom.sum()))
        heavy = dom & (rng_jit.random(n) < frac_heavy)
        u[heavy] += delta_u
    for col in _JITTER_COLS:
        mix = 0.75 * u + 0.25 * rng_jit.normal(0.0, 1.0, n)
        feats[:, col] *= np.exp(sigma * mix)
    feats[:, 0] = np.clip(feats[:, 0] + rng_jit.normal(0, 0.15, n), 1.0, 8.0)
    feats[:, 19] = np.clip(
        feats[:, 19] * np.exp(-0.3 * sigma * u +
                              (spec.jitter / 2) * rng_jit.normal(0.0, 1.0, n)),
        1.0, 16.0)

    # Heavy-tail outliers: dependent L2/L3-miss chains (mlp -> 1).
    rng = rng_out
    is_out = rng.random(n) < spec.outlier_prob
    if is_out.any():
        feats[is_out, 14] = spec.outlier_l3_mpki * \
            np.exp(rng.normal(0, 0.15, int(is_out.sum())))
        feats[is_out, 12] = np.maximum(feats[is_out, 12], feats[is_out, 14] * 1.1)
        feats[is_out, 10] = np.maximum(feats[is_out, 10], feats[is_out, 12] * 1.2)
        feats[is_out, 19] = 1.0                      # no MLP: serialized chain
        feats[is_out, 15] = 0.05                     # bigger L3 doesn't help
        feats[is_out, 17] = spec.outlier_sms_cov     # SMS-prefetchable chain
        feats[is_out, 20] = 0.1

    return AppPopulation(spec=spec, features=feats, phase_ids=seq,
                         bbv_profile_ids=profile_ids, is_outlier=is_out,
                         jitter_u=u.astype(np.float32))


_POP_CACHE: dict[tuple[str, int], AppPopulation] = {}


def get_population(name: str, *, seed: int = 0) -> AppPopulation:
    """Cached population lookup by application name."""
    key = (name, seed)
    if key not in _POP_CACHE:
        spec = next((s for s in APP_SPECS if s.name == name), None)
        if spec is None:
            raise KeyError(f"unknown application {name!r}; "
                           f"available: {APP_NAMES}")
        _POP_CACHE[key] = generate_population(spec, seed=seed)
    return _POP_CACHE[key]


@dataclasses.dataclass(frozen=True)
class PopulationBank:
    """Stacked populations: the app axis as a data-parallel array dimension.

    All apps' region features live in ONE ``(A, N, F)`` array (zero-padded
    to the largest population, with a validity ``mask``) so the perf model,
    the memo table, and the Monte-Carlo trial engine can treat "application"
    as just another batch axis — vmapped on one device, sharded over an
    ``("app",)`` mesh across many.
    """

    names: tuple[str, ...]
    pops: tuple[AppPopulation, ...]
    features: np.ndarray      # (A, N_max, NUM_FEATURES) float32, zero-padded
    mask: np.ndarray          # (A, N_max) bool — True for real regions
    n_regions: np.ndarray     # (A,) int64

    @property
    def num_apps(self) -> int:
        return len(self.names)

    @property
    def max_regions(self) -> int:
        return int(self.features.shape[1])

    def row(self, name: str) -> int:
        return self.names.index(name)

    def pop(self, name: str) -> AppPopulation:
        return self.pops[self.row(name)]


def stack_ragged(arrays, *, dtype=None, fill=0) -> tuple[np.ndarray, np.ndarray]:
    """Stack same-rank arrays of ragged leading length into (A, K_max, ...).

    Returns ``(stacked, valid)`` where ``valid`` is the (A, K_max) bool
    row-validity mask. The padded tail is filled with ``fill``.
    """
    arrays = [np.asarray(a) for a in arrays]
    k_max = max((a.shape[0] for a in arrays), default=0)
    trail = arrays[0].shape[1:] if arrays else ()
    out = np.full((len(arrays), k_max) + trail, fill,
                  dtype=dtype or arrays[0].dtype)
    valid = np.zeros((len(arrays), k_max), bool)
    for i, a in enumerate(arrays):
        out[i, :a.shape[0]] = a
        valid[i, :a.shape[0]] = True
    return out, valid


def build_population_bank(names, *, seed: int = 0) -> PopulationBank:
    names = tuple(names)
    pops = tuple(get_population(n, seed=seed) for n in names)
    feats, mask = stack_ragged([p.features for p in pops], dtype=np.float32)
    return PopulationBank(
        names=names, pops=pops, features=feats, mask=mask,
        n_regions=np.asarray([p.n_regions for p in pops], np.int64))


_BANK_CACHE: dict[tuple[tuple[str, ...], int], PopulationBank] = {}


def get_population_bank(names=APP_NAMES, *, seed: int = 0) -> PopulationBank:
    """Cached stacked-population lookup (shares ``get_population`` entries)."""
    key = (tuple(names), seed)
    if key not in _BANK_CACHE:
        _BANK_CACHE[key] = build_population_bank(names, seed=seed)
    return _BANK_CACHE[key]
