"""Analytical out-of-order core performance model (vectorized JAX).

Maps (region intrinsic features × UarchConfig) -> CPI plus the 38 Table III
counters. This is the TPU-idiomatic stand-in for the cycle-accurate
simulator: inherently-serial discrete-event simulation does not transfer to
TPU, but the *population evaluation* — what the sampling methodology needs —
is embarrassingly parallel and lives as one fused vector program.

Model structure (classic top-down decomposition):
  CPI = 1/ipc_core                                 (retire/issue/ILP bound)
      + branch-flush stalls                        (TAGE-capacity dependent)
      + frontend miss stalls (icache/iTLB)
      + data-side miss stalls / effective MLP      (cache + prefetch + ROB)

All cache miss rates follow power-law size scaling  mpki(size) =
mpki_ref * (ref/size)^alpha; prefetchers convert a coverage fraction of
next-level misses into L2-latency hits; a larger ROB raises the usable MLP
of the miss stream. Deterministic per (region, config): repeated simulation
of the same region is bit-identical, like re-running a deterministic
simulator checkpoint.
"""

from __future__ import annotations

# jaxlint: disable-file=JL003 — the perf model is float32 BY CONTRACT
# (deterministic bit-identical CPI across dispatch paths keys the
# MemoBank); its dtypes are the contract itself, not policy leaks.

import functools
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.features import RFV_METRICS
from .uarch import UarchConfig
from .workload import NUM_FEATURES

NUM_CONFIG_FIELDS = 14

_F = {name: i for i, name in enumerate(
    ("ilp", "br_pki", "br_mpr", "br_predict", "cond_frac", "ic_mpki",
     "ic_alpha", "itlb_mpki", "l1d_apki", "load_frac", "l1d_mpki",
     "l1d_alpha", "l2_mpki", "l2_alpha", "l3_mpki", "l3_alpha", "wb_frac",
     "sms_cov", "bo_cov", "mlp", "rob_sens"))}


def _config_vector(cfg: UarchConfig) -> jnp.ndarray:
    return jnp.asarray([
        cfg.issue_width, cfg.retire_width, cfg.rob_size,
        cfg.icache_kb, cfg.dcache_kb, cfg.l2_kb, cfg.l3_mb,
        cfg.l2_hit_lat, cfg.l3_hit_latency_cyc, cfg.mem_latency_cyc,
        1.0 if cfg.sms_pf else 0.0, 1.0 if cfg.bo_pf else 0.0,
        cfg.tage_capacity_ratio, cfg.fetch_width,
    ], jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def _evaluate(features: jnp.ndarray, cv: jnp.ndarray) -> dict[str, jnp.ndarray]:
    f = lambda name: features[:, _F[name]]
    (issue_w, retire_w, rob, ic_kb, dc_kb, l2_kb, l3_mb, l2_lat, l3_lat,
     mem_lat, sms_on, bo_on, tage_ratio, fetch_w) = [cv[i] for i in range(14)]

    # --- core-bound term ----------------------------------------------------
    ilp_eff = f("ilp") * (1.0 + 0.08 * f("rob_sens") * (rob / 128.0 - 1.0))
    ipc_core = jnp.minimum(jnp.minimum(ilp_eff, retire_w), issue_w)
    base_cpi = 1.0 / ipc_core

    # --- branch mispredictions ----------------------------------------------
    mpr_eff = f("br_mpr") * tage_ratio ** (-f("br_predict"))
    br_mpki = f("br_pki") * jnp.clip(mpr_eff, 0.0, 0.15)
    flush_penalty = 12.0 + rob / 32.0
    stall_br = br_mpki / 1000.0 * flush_penalty

    # --- frontend misses ----------------------------------------------------
    ic_mpki = f("ic_mpki") * (32.0 / ic_kb) ** f("ic_alpha")
    stall_ic = ic_mpki / 1000.0 * l2_lat * 0.7     # partly hidden by BTB/queue
    itlb_mpki = f("itlb_mpki")
    stall_itlb = itlb_mpki / 1000.0 * 20.0

    # --- data-side cache hierarchy -------------------------------------------
    l1d_mpki = f("l1d_mpki") * (32.0 / dc_kb) ** f("l1d_alpha")
    l2_mpki = jnp.minimum(l1d_mpki, f("l2_mpki") * (512.0 / l2_kb) ** f("l2_alpha"))
    l3_mpki = jnp.minimum(l2_mpki, f("l3_mpki") * (2.0 / l3_mb) ** f("l3_alpha"))

    l2_served = jnp.maximum(l1d_mpki - l2_mpki, 0.0)   # hit in L2
    l3_served = jnp.maximum(l2_mpki - l3_mpki, 0.0)    # hit in L3
    mem_served = l3_mpki                               # go to DRAM

    cov_sms = f("sms_cov") * sms_on                    # covers DRAM misses
    cov_bo = f("bo_cov") * bo_on                       # covers L3-hit misses
    mem_cost = mem_served * ((1.0 - cov_sms) * mem_lat + cov_sms * l2_lat)
    l3_cost = l3_served * ((1.0 - cov_bo) * l3_lat + cov_bo * l2_lat)
    l2_cost = l2_served * l2_lat * 0.5                 # mostly OoO-hidden

    rob_cap = rob / 32.0
    mlp = f("mlp")
    mlp_eff = 1.0 + (mlp - 1.0) * jnp.clip(rob_cap / mlp, 0.0, 1.0)
    stall_mem = (mem_cost + l3_cost + l2_cost) / 1000.0 / mlp_eff

    cpi = base_cpi + stall_br + stall_ic + stall_itlb + stall_mem

    # --- Table III counters (rates per kilo-instruction) ---------------------
    cond = f("cond_frac")
    l1d_total = l1d_mpki
    demand_l3_misses = mem_served * (1.0 - cov_sms)
    demand_l2_misses = l3_served * (1.0 - cov_bo) + mem_served
    out: dict[str, jnp.ndarray] = {
        "cpi": cpi,
        "branch_mispredicts": br_mpki,
        "cond_branch_mispredicts": br_mpki * cond,
        "target_branch_mispredicts": br_mpki * (1.0 - cond),
        "icache_misses": ic_mpki,
        "itlb_misses": itlb_mpki,
        "l1d_access": f("l1d_apki"),
        "l1d_load_miss": l1d_total * f("load_frac"),
        "l1d_store_miss": l1d_total * (1.0 - f("load_frac")),
        "l1d_total_miss": l1d_total,
        "l1d_writeback": l1d_total * f("wb_frac"),
        "l2_misses": demand_l2_misses,
        "l2_load_misses": demand_l2_misses * f("load_frac"),
        "l2_writebacks": l2_mpki * f("wb_frac"),
        "l3_read_accesses": demand_l2_misses,
        "l3_write_accesses": l2_mpki * f("wb_frac"),
        "l3_misses": demand_l3_misses,
    }

    # --- 21 top-down stall bins (cycles per instruction, x1000 => per ki) ----
    dram_stall = mem_cost / 1000.0 / mlp_eff
    l3_stall = l3_cost / 1000.0 / mlp_eff
    l2_stall = l2_cost / 1000.0 / mlp_eff
    fe_lat = stall_ic + stall_itlb
    fe_bw = jnp.maximum(0.0, (1.0 / fetch_w) - (1.0 / ipc_core)) + 0.01 * base_cpi
    rob_press = jnp.clip(mlp - rob_cap, 0.0, None) / (mlp + 1.0)
    bins = [
        stall_ic,                          # 00 frontend icache
        stall_itlb,                        # 01 frontend itlb
        stall_br * 0.4,                    # 02 branch resteer
        fe_bw,                             # 03 frontend bandwidth
        stall_br * 0.6,                    # 04 bad speculation
        l2_stall,                          # 05 backend mem L2-bound
        l3_stall,                          # 06 backend mem L3-bound
        dram_stall,                        # 07 backend mem DRAM-bound
        l1d_total * f("wb_frac") / 1000.0 * 2.0,  # 08 store-bound
        rob_press * stall_mem,             # 09 ROB-full
        base_cpi * 0.10,                   # 10 RS-full proxy
        base_cpi * 0.05,                   # 11 phys-reg pressure
    ]
    # 12..20: finer-grained sub-bins of the real stall terms (a real top-down
    # profiler splits the same cycles into more buckets, it does not invent
    # orthogonal noise dimensions).
    mixes = [
        dram_stall * 0.30 + l3_stall * 0.10,       # 12 mem latency-bound
        dram_stall * 0.10 + l2_stall * 0.40,       # 13 mem bandwidth proxy
        stall_mem * rob_press * 0.50,              # 14 ROB-blocked mem
        stall_br * 0.25 + fe_bw * 0.30,            # 15 resteer bandwidth
        stall_ic * 0.50 + stall_itlb * 0.20,       # 16 fetch latency split
        base_cpi * 0.08 + stall_br * 0.05,         # 17 dispatch stalls
        l2_stall * 0.20 + l3_stall * 0.30,         # 18 L2/L3 queueing
        stall_mem * 0.15,                          # 19 store/forwarding
        base_cpi * 0.04 + stall_mem * 0.02,        # 20 misc core
    ]
    bins.extend(mixes)
    for i, b in enumerate(bins):
        out[f"stall_bin_{i:02d}"] = b
    return out


class _Evaluator:
    """Caches jitted evaluation per config vector."""

    def __init__(self):
        self._feat_cache: dict[int, jnp.ndarray] = {}

    def __call__(self, features: np.ndarray, cfg: UarchConfig,
                 indices=None) -> dict[str, np.ndarray]:
        x = jnp.asarray(features, jnp.float32)
        if indices is not None:
            x = x[jnp.asarray(indices)]
        stats = _evaluate(x, _config_vector(cfg))
        return {k: np.asarray(v) for k, v in stats.items()}


evaluate_regions = _Evaluator()


def config_matrix(cfgs: Sequence[UarchConfig]) -> jnp.ndarray:
    """Stack config vectors into a (C, 14) matrix for batched evaluation."""
    if not cfgs:
        raise ValueError("need at least one config")
    return jnp.stack([_config_vector(c) for c in cfgs])


# One XLA program for all configs: vmap the fused model over the config axis.
_evaluate_batch = jax.jit(jax.vmap(_evaluate, in_axes=(None, 0)))
# cpi-only variant: XLA dead-code-eliminates the 37 unused counters, so
# census-scale sweeps don't materialize (C, N, 38) intermediates.
_cpi_batch = jax.jit(
    lambda x, cm: jax.vmap(_evaluate, in_axes=(None, 0))(x, cm)["cpi"])


def evaluate_regions_batch(features: np.ndarray, cfgs: Sequence[UarchConfig],
                           indices=None) -> dict[str, np.ndarray]:
    """Evaluate many configs in one batched dispatch.

    Returns the same metric dict as ``evaluate_regions`` but with every
    value shaped ``(len(cfgs), n_regions)``; row ``i`` matches
    ``evaluate_regions(features, cfgs[i], indices)`` to float32 precision.
    """
    x = jnp.asarray(features, jnp.float32)
    if indices is not None:
        x = x[jnp.asarray(indices)]
    stats = _evaluate_batch(x, config_matrix(cfgs))
    return {k: np.asarray(v) for k, v in stats.items()}


def cpi_batch(features: np.ndarray, cfgs: Sequence[UarchConfig],
              indices=None) -> np.ndarray:
    """(C, n) CPI matrix across configs in one batched dispatch."""
    x = jnp.asarray(features, jnp.float32)
    if indices is not None:
        x = x[jnp.asarray(indices)]
    return np.asarray(_cpi_batch(x, config_matrix(cfgs)))


def cpi_only(features: np.ndarray, cfg: UarchConfig, indices=None) -> np.ndarray:
    return evaluate_regions(features, cfg, indices)["cpi"]


# --- app-axis (bank) entry points ------------------------------------------
# The application axis of a PopulationBank is plain data parallelism: the
# same fused model vmapped over the leading (A, ...) axis. These programs
# are what the experiment engine shards over an ("app",) mesh — per-app
# lanes never communicate, so sharded and single-device results agree.
def _cpi_bank_fn(x: jnp.ndarray, cm: jnp.ndarray) -> jnp.ndarray:
    """(A, N, F) features x (C, 14) configs -> (A, C, N) CPI."""
    per_app = lambda xa: jax.vmap(_evaluate, in_axes=(None, 0))(xa, cm)["cpi"]
    return jax.vmap(per_app)(x)


def _rfv_bank_fn(x: jnp.ndarray, cv: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(A, N, F) features x one config vector -> ((A, N) cpi, (A, N, 38) rfv)."""
    stats = jax.vmap(lambda xa: _evaluate(xa, cv))(x)
    rfv = jnp.stack([stats[m] for m in RFV_METRICS], axis=-1)
    return stats["cpi"], rfv


_cpi_bank_jit = jax.jit(_cpi_bank_fn)
_rfv_bank_jit = jax.jit(_rfv_bank_fn)


def _sharded(fn, mesh):
    from ..distributed.appaxis import app_sharded_cached
    return app_sharded_cached(fn, mesh, (1,))


def _as_config_matrix(cfgs) -> jnp.ndarray:
    return cfgs if hasattr(cfgs, "ndim") else config_matrix(cfgs)


def cpi_bank(features, cfgs, *, mesh=None) -> np.ndarray:
    """(A, C, N) CPI matrix for stacked app features, one batched dispatch.

    ``features``: (A, N, F) stacked (possibly padded) app feature arrays;
    ``cfgs``: a config sequence or a prebuilt (C, 14) matrix. With ``mesh``
    (a 1-D ``("app",)`` mesh) the app axis runs device-parallel with
    results identical to the single-device path.
    """
    x = jnp.asarray(features, jnp.float32)
    cm = _as_config_matrix(cfgs)
    fn = _cpi_bank_jit if mesh is None else _sharded(_cpi_bank_fn, mesh)
    return np.asarray(fn(x, cm))


def rfv_bank(features, cfg: UarchConfig, *, mesh=None
             ) -> tuple[np.ndarray, np.ndarray]:
    """Stacked phase-1 measurement: (A, N) CPI + (A, N, 38) RFV matrix."""
    x = jnp.asarray(features, jnp.float32)
    cv = _config_vector(cfg)
    fn = _rfv_bank_jit if mesh is None else _sharded(_rfv_bank_fn, mesh)
    cpi, rfv = fn(x, cv)
    return np.asarray(cpi), np.asarray(rfv)


def stats_matrix(stats: Mapping[str, np.ndarray]) -> np.ndarray:
    """Order the stats dict into the canonical 38-column RFV matrix."""
    return np.stack([np.asarray(stats[m]) for m in RFV_METRICS], axis=1)


assert NUM_FEATURES == len(_F)


@functools.partial(jax.jit, static_argnames=())
def _evaluate_approx(features: jnp.ndarray, cv: jnp.ndarray) -> dict:
    """Deliberately degraded fast model (paper §VI.C 'cheaper
    characterization with a faster simulator'): two-term CPI (core +
    unoverlapped memory), no branch/frontend modeling, no prefetchers.
    ~half the metrics, systematically biased — only its *correlation* with
    the accurate model matters for stratification."""
    f = lambda name: features[:, _F[name]]
    (issue_w, retire_w, rob, ic_kb, dc_kb, l2_kb, l3_mb, l2_lat, l3_lat,
     mem_lat, sms_on, bo_on, tage_ratio, fetch_w) = [cv[i] for i in range(14)]
    ipc_core = jnp.minimum(f("ilp"), retire_w)
    l1d_mpki = f("l1d_mpki") * (32.0 / dc_kb) ** f("l1d_alpha")
    l2_mpki = jnp.minimum(l1d_mpki, f("l2_mpki") * (512.0 / l2_kb) ** 0.5)
    l3_mpki = jnp.minimum(l2_mpki, f("l3_mpki") * (2.0 / l3_mb) ** 0.5)
    stall = (l3_mpki * mem_lat + (l2_mpki - l3_mpki) * l3_lat) / 1000.0 \
        / jnp.maximum(f("mlp") * 0.5, 1.0)
    cpi = 1.0 / ipc_core + stall
    out = {"cpi": cpi, "l1d_mpki": l1d_mpki, "l2_mpki": l2_mpki,
           "l3_mpki": l3_mpki, "ipc_core": ipc_core, "stall_mem": stall}
    return out


def evaluate_regions_approx(features: np.ndarray, cfg: UarchConfig,
                            indices=None) -> dict[str, np.ndarray]:
    """Fast approximate simulator (6 metrics, ~1/6 the model terms)."""
    x = jnp.asarray(features, jnp.float32)
    if indices is not None:
        x = x[jnp.asarray(indices)]
    stats = _evaluate_approx(x, _config_vector(cfg))
    return {k: np.asarray(v) for k, v in stats.items()}
