"""Array-native memoizing simulation cache (app x config x region memo).

``MemoBank`` is the cost-accounting heart of the sweep engine: one
``(A, C, N)`` mask + value table covering every (application, config,
region) triple the experiments have paid for. The ledger is charged for
cache *misses only* — the paper's cost unit is "number of 1 M-instruction
region simulations", and a real simulation farm keeps the results it
already paid for. Because the perf model is deterministic, the bank can be
filled by any dispatch path (single app, stacked apps, app-sharded over a
mesh) and later ``merge``-d: device-local banks from a sharded sweep fold
into one table whose charge totals equal a single-host run's.

``CachedSimulator`` keeps the historic per-app surface (``simulate``,
``simulate_cpi``, ``simulate_cpi_batch``) as a one-row view over a
``MemoBank`` — standalone construction gets a private bank; the experiment
engine hands every app a row of its shared bank so one sweep-wide fill is
ONE vmapped (optionally ``shard_map``-ped) dispatch.

Value memoization covers CPI (the sweep/trial hot path). Full-38-metric
requests (``simulate``/``simulate_rfv``) re-run the vectorized perf model
each call — deterministic, so values never change, and NOT re-charged
(the mask is the single source of cost truth) — a deliberate trade: the
bank stays a compact (A, C, N) value table instead of (A, C, N, 38).

``census_stats`` stays analysis-only (free of charge, like the base
simulator) and deliberately does NOT populate the charged memo — otherwise
a census would make every later ``simulate`` call free and the cost
accounting meaningless.
"""

from __future__ import annotations

# jaxlint: disable-file=JL003 — MemoBank's (A, C, N) cpi table is
# float32 storage BY CONTRACT: device mirrors of the table (the fused
# sweep's block cache) must match it bit-for-bit, so the storage dtype
# is part of the memo contract, not a PrecisionPolicy leak.

from typing import Optional, Sequence

import numpy as np

from ..core.features import build_rfv
from .perfmodel import cpi_bank, evaluate_regions_batch
from .simulator import CycleAccurateSimulator, Ledger
from .uarch import UarchConfig
from .workload import get_population


class MemoBank:
    """Growable ``(A, C, N)`` mask + CPI-value memo with per-app ledgers."""

    def __init__(self):
        self.names: list[str] = []
        self.ledgers: list[Optional[Ledger]] = []
        self.n_regions: list[int] = []
        self.hit_count: list[int] = []     # per-app requested-and-cached units
        self.miss_count: list[int] = []    # per-app newly-charged units
        self._cfg_cols: dict[UarchConfig, int] = {}
        self.configs: list[UarchConfig] = []
        self.mask = np.zeros((0, 0, 0), bool)         # (A, C, N)
        self.cpi = np.zeros((0, 0, 0), np.float32)    # (A, C, N)
        self.charges = np.zeros((0, 0), np.int64)     # (A, C) miss counts
        # bumped on every mask/cpi table mutation (content or shape);
        # device-resident mirrors of the tables (the fused sweep's block
        # cache) key their validity on it. Code that writes the tables
        # directly — test/bench snapshot-restore helpers — must call
        # ``touch()``.
        self.version = 0
        # column-granularity reuse bookkeeping for the serving-path
        # eviction policy: last-use tick per column (LRU order) and the
        # host-spill store of evicted-with-spill columns
        self._col_tick: dict[int, int] = {}
        self._lru_clock = 0
        self._spill: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    # -- shape management ---------------------------------------------------
    @property
    def num_apps(self) -> int:
        return len(self.names)

    def touch(self) -> None:
        """Invalidate device-resident mirrors after direct table writes.

        Every mutating method bumps ``version`` itself; only code that
        assigns into ``mask``/``cpi`` directly (snapshot-restore helpers
        in tests and benches) needs to call this."""
        self.version += 1

    def _grow(self, a: int, c: int, n: int) -> None:
        a0, c0, n0 = self.mask.shape
        if (a, c, n) == (a0, c0, n0):
            return
        mask = np.zeros((a, c, n), bool)
        cpi = np.zeros((a, c, n), np.float32)
        charges = np.zeros((a, c), np.int64)
        mask[:a0, :c0, :n0] = self.mask
        cpi[:a0, :c0, :n0] = self.cpi
        charges[:a0, :c0] = self.charges
        self.mask, self.cpi, self.charges = mask, cpi, charges
        self.version += 1

    def add_app(self, name: str, n_regions: int,
                ledger: Optional[Ledger] = None) -> int:
        """Register an app row; returns its row index."""
        row = len(self.names)
        self.names.append(name)
        self.ledgers.append(ledger)
        self.n_regions.append(int(n_regions))
        self.hit_count.append(0)
        self.miss_count.append(0)
        a0, c0, n0 = self.mask.shape
        self._grow(row + 1, c0, max(n0, int(n_regions)))
        return row

    def cols_for(self, cfgs: Sequence[UarchConfig]) -> np.ndarray:
        """Column indices for configs, growing the config axis as needed.

        This is the single column-resolution chokepoint every fill/
        checkout path routes through, so it doubles as the eviction
        policy's touch point: each resolved column's last-use tick
        advances (LRU order for ``evict_to_cap``), and columns that were
        ``spill``-ed restore transparently from the host spill store —
        a free operation (values were already paid for), so ledger
        totals match a never-spilled run.
        """
        for cfg in cfgs:
            if cfg not in self._cfg_cols:
                self._cfg_cols[cfg] = len(self.configs)
                self.configs.append(cfg)
        a0, c0, n0 = self.mask.shape
        self._grow(a0, len(self.configs), n0)
        cols = [self._cfg_cols[c] for c in cfgs]
        self._lru_clock += 1
        for c in cols:
            self._col_tick[c] = self._lru_clock
            if c in self._spill:
                self._unspill(c)
        return np.asarray(cols, np.int64)

    # -- eviction / host spill (the serving-path residency policy) -----------
    def _unspill(self, col: int) -> None:
        """Restore one spilled column into the live tables (free)."""
        mask_c, cpi_c = self._spill.pop(col)
        a, n = mask_c.shape
        self.mask[:a, col, :n] = mask_c
        self.cpi[:a, col, :n] = cpi_c
        self.version += 1

    def resident_columns(self) -> list[int]:
        """Config columns currently holding memo data in the live tables
        (spilled/evicted columns are not resident until re-requested)."""
        return [c for c in range(len(self.configs))
                if c not in self._spill and bool(self.mask[:, c, :].any())]

    def evict(self, cols: Sequence[int], *, spill: bool = False) -> None:
        """Drop the given config columns from the live tables.

        ``spill=False`` discards the data: a later request for an
        evicted config is a miss again and is RE-CHARGED (exactly once —
        the refill repopulates the mask like any first fill). With
        ``spill=True`` the column's mask/value data moves to a host
        spill store instead; ``cols_for`` restores it transparently on
        the next request, free of charge, so ledger totals equal a
        never-evicted run. Either way ``version`` bumps, invalidating
        every device-resident block mirror (the fused sweep's
        ``_BLOCK_CACHE``) — no stale-block reuse.
        """
        cols = [int(c) for c in cols]
        for c in cols:
            if c in self._spill:
                continue                       # already spilled: no-op
            if spill:
                self._spill[c] = (self.mask[:, c, :].copy(),
                                  self.cpi[:, c, :].copy())
            # charges stay: they are the cumulative cost HISTORY (ledger
            # totals never roll back); a re-request of a dropped column
            # adds its refill misses on top, exactly like a first fill
            self.mask[:, c, :] = False
            self.cpi[:, c, :] = 0.0
            self._col_tick.pop(c, None)
        if cols:
            self.version += 1

    def spill(self, cols: Sequence[int]) -> None:
        """``evict`` with host spill: data parks off the live tables and
        restores free on the next request (see ``evict``)."""
        self.evict(cols, spill=True)

    def evict_to_cap(self, cap: int, *, policy: str = "lru",
                     spill: bool = False) -> list[int]:
        """Evict/spill columns until at most ``cap`` remain resident.

        ``policy="lru"`` drops least-recently-used columns first;
        ``policy="charge"`` drops the cheapest-to-recompute first
        (lowest accumulated charge, LRU tie-break) — the charge-weighted
        option for banks whose columns cost very different region
        counts. Returns the evicted column indices (empty when already
        under cap).
        """
        if policy not in ("lru", "charge"):
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             "choose 'lru' or 'charge'")
        resident = self.resident_columns()
        if cap < 0 or len(resident) <= cap:
            return []
        if policy == "charge":
            order = sorted(resident,
                           key=lambda c: (int(self.charges[:, c].sum()),
                                          self._col_tick.get(c, 0)))
        else:
            order = sorted(resident, key=lambda c: self._col_tick.get(c, 0))
        victims = order[:len(resident) - cap]
        self.evict(victims, spill=spill)
        return victims

    # -- the one batched fill path ------------------------------------------
    def fill(self, rows, idx, valid, cfgs: Sequence[UarchConfig], *,
             feats=None, values=None, mesh=None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Serve ``(R, C, K)`` CPI through the memo; charge misses only.

        ``rows``: (R,) app rows; ``idx``: (R, K) region indices (padding
        allowed, flagged invalid in ``valid``); ``feats``: (R, K, F)
        gathered features, evaluated in ONE vmapped dispatch (app-sharded
        when ``mesh`` is given) — or ``values``: (R, C, K) precomputed CPI
        (full-stats path). Returns ``(cpi, n_miss)`` with ``n_miss`` the
        per-(row, config) newly-charged region counts.
        """
        rows = np.asarray(rows, np.int64)
        idx = np.asarray(idx, np.int64)
        valid = np.ones(idx.shape, bool) if valid is None \
            else np.asarray(valid, bool)
        cols = self.cols_for(cfgs)
        n = self.mask.shape[2]
        r_n, k = idx.shape
        c_n = cols.size
        sub = (rows[:, None], cols[None, :])

        req = np.zeros((r_n, n), bool)
        rr = np.broadcast_to(np.arange(r_n)[:, None], idx.shape)
        req[rr[valid], idx[valid]] = True
        miss = req[:, None, :] & ~self.mask[sub]          # (R, C, N)
        n_miss = miss.sum(axis=2)                          # (R, C)
        requested = valid.sum(axis=1) * c_n                # (R,) incl. dups
        for i, row in enumerate(rows.tolist()):
            self.miss_count[row] += int(n_miss[i].sum())
            self.hit_count[row] += int(requested[i] - n_miss[i].sum())

        if not n_miss.any():                               # fully memoized
            out = np.take_along_axis(self.cpi[sub],
                                     np.broadcast_to(idx[:, None, :],
                                                     (r_n, c_n, k)), axis=2)
            return out, n_miss

        if values is None:
            values = cpi_bank(feats, cfgs, mesh=mesh)      # (R, C, K)
        values = np.asarray(values, np.float32)

        # scatter valid entries into dense (R, C, N), then write misses only
        dense = np.zeros((r_n, c_n, n), np.float32)
        r3 = np.broadcast_to(np.arange(r_n)[:, None, None], values.shape)
        c3 = np.broadcast_to(np.arange(c_n)[None, :, None], values.shape)
        i3 = np.broadcast_to(idx[:, None, :], values.shape)
        v3 = np.broadcast_to(valid[:, None, :], values.shape)
        dense[r3[v3], c3[v3], i3[v3]] = values[v3]
        blk = self.cpi[sub]
        self.cpi[sub] = np.where(miss, dense, blk)
        self.mask[sub] |= miss
        self.charges[sub] += n_miss
        self.version += 1
        for i, row in enumerate(rows.tolist()):
            ledger = self.ledgers[row]
            if ledger is not None:
                ledger.charge(int(n_miss[i].sum()))
        out = np.take_along_axis(self.cpi[sub],
                                 np.broadcast_to(idx[:, None, :],
                                                 (r_n, c_n, k)), axis=2)
        return out, n_miss

    # -- donated-buffer fused-fill contract ----------------------------------
    def donation_block(self, rows, cfgs: Sequence[UarchConfig]
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(mask, cpi, cols) block for a donated fused sweep dispatch.

        Extracts the ``(R, C, N)`` mask + value sub-block the fused
        program (``repro.experiments.fused``) consumes as DONATED device
        buffers: ownership transfers to the program — the caller must
        not read these arrays after dispatch — and the updated block
        comes back through ``absorb_block``. Fancy indexing copies, so
        the bank's own tables are never aliased by donated memory.
        """
        rows = np.asarray(rows, np.int64)
        cols = self.cols_for(cfgs)
        sub = (rows[:, None], cols[None, :])
        return self.mask[sub], self.cpi[sub], cols

    def absorb_block(self, rows, cols, new_mask, new_cpi, n_miss,
                     requested) -> None:
        """Write back a fused program's updated block; account as
        ``fill`` would, bitwise.

        ``new_mask``/``new_cpi``: the (R, C, N) block returned by the
        fused program (old block ∪ misses); ``n_miss``: its (R, C)
        newly-charged counts; ``requested``: (R,) requested region-units
        per row INCLUDING duplicates times configs (``fill``'s
        ``valid.sum(axis=1) * C`` convention). Hit/miss counters, the
        charge matrix and the per-app ledgers advance exactly as one
        equivalent ``fill`` call — ledger totals are path-independent.
        """
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        sub = (rows[:, None], cols[None, :])
        n_miss = np.asarray(n_miss, np.int64)
        requested = np.asarray(requested, np.int64)
        self.cpi[sub] = np.asarray(new_cpi, np.float32)
        self.mask[sub] = np.asarray(new_mask, bool)
        self.charges[sub] += n_miss
        self.version += 1
        for i, row in enumerate(rows.tolist()):
            row_miss = int(n_miss[i].sum())
            self.miss_count[row] += row_miss
            self.hit_count[row] += int(requested[i]) - row_miss
            ledger = self.ledgers[row]
            if ledger is not None and row_miss:
                ledger.charge(row_miss)

    def absorb_selected(self, rows, cols, picks, miss_sel, values, n_miss,
                        requested) -> None:
        """Write back a fused program's selected-unit results; account as
        ``fill`` would, bitwise — without an (R, C, N) block transfer.

        ``picks``: (R, K) picked region indices; ``miss_sel``: (R, C, K)
        True where the pick was newly computed (invalid strata already
        False); ``values``: (R, C, K) CPI at the picks (stored on hits,
        freshly computed on misses) — only missed cells are written, so
        the host tables end up identical to an ``absorb_block`` of the
        full updated block. ``n_miss``/``requested`` follow the
        ``absorb_block`` conventions (dedup-exact counts from the
        program's dense request scatter).
        """
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        picks = np.asarray(picks, np.int64)
        miss_sel = np.asarray(miss_sel, bool)
        values = np.asarray(values, np.float32)
        n_miss = np.asarray(n_miss, np.int64)
        requested = np.asarray(requested, np.int64)
        r3 = np.broadcast_to(rows[:, None, None], miss_sel.shape)
        c3 = np.broadcast_to(cols[None, :, None], miss_sel.shape)
        i3 = np.broadcast_to(picks[:, None, :], miss_sel.shape)
        if miss_sel.any():
            self.cpi[r3[miss_sel], c3[miss_sel], i3[miss_sel]] = \
                values[miss_sel]
            self.mask[r3[miss_sel], c3[miss_sel], i3[miss_sel]] = True
            self.version += 1
        sub = (rows[:, None], cols[None, :])
        self.charges[sub] += n_miss
        for i, row in enumerate(rows.tolist()):
            row_miss = int(n_miss[i].sum())
            self.miss_count[row] += row_miss
            self.hit_count[row] += int(requested[i]) - row_miss
            ledger = self.ledgers[row]
            if ledger is not None and row_miss:
                ledger.charge(row_miss)

    def absorb_picks(self, rows, cols, picks, valid, values) -> np.ndarray:
        """Absorb one request's selected-unit results, recomputing its
        miss flags against the CURRENT host tables.

        The coalescing batcher (``repro.serving``) stacks many requests
        into one fused dispatch; the program's in-trace miss counts are
        computed per request against the shared PRE-dispatch block, so
        two coalesced requests touching the same cold cell would each
        count it as a miss. This method restores serial accounting:
        called once per request in submission order, it re-derives the
        dense dedup-exact request scatter (``fill``'s convention)
        against the tables as the EARLIER requests left them, then
        delegates to ``absorb_selected`` — so charges, hit/miss counters
        and ledger totals land bitwise-identical to the same requests
        run serially. ``values`` holds the request's (R, C, K) selected
        CPI (stored on hits, computed on misses — bitwise equal either
        way for same-program lanes); only newly-missed cells are
        written. Returns the (R, C) per-request miss counts.
        """
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        picks = np.asarray(picks, np.int64)
        valid = np.asarray(valid, bool)
        r_n, k = picks.shape
        c_n = cols.size
        n = self.mask.shape[2]
        sub = (rows[:, None], cols[None, :])
        picks_b = np.broadcast_to(picks[:, None, :], (r_n, c_n, k))
        hit_sel = np.take_along_axis(self.mask[sub], picks_b, axis=2)
        if bool((hit_sel | ~valid[:, None, :]).all()):
            # warm fast path: every valid pick is already present. The
            # dense request scatter only marks picked regions, so zero
            # selected misses means zero misses anywhere — skip the
            # (R, C, N) materialization; the accounting below is
            # bitwise what the dense path would produce.
            n_miss = np.zeros((r_n, c_n), np.int64)
            self.absorb_selected(rows, cols, picks,
                                 np.zeros((r_n, c_n, k), bool), values,
                                 n_miss, requested=valid.sum(axis=1) * c_n)
            return n_miss
        req = np.zeros((r_n, n), bool)
        rr = np.broadcast_to(np.arange(r_n)[:, None], picks.shape)
        req[rr[valid], picks[valid]] = True
        miss = req[:, None, :] & ~self.mask[sub]            # (R, C, N)
        n_miss = miss.sum(axis=2)
        miss_sel = np.take_along_axis(miss, picks_b, axis=2) \
            & valid[:, None, :]
        self.absorb_selected(rows, cols, picks, miss_sel, values, n_miss,
                             requested=valid.sum(axis=1) * c_n)
        return n_miss

    # -- snapshot / restore (the checkpointed-fleet contract) ----------------
    def state(self) -> tuple[dict, dict]:
        """``(tree, meta)`` snapshot of the bank's full mutable state.

        ``tree`` is a checkpointable array pytree — mask + CPI tables,
        the charge matrix, hit/miss counters, per-app ledger totals and
        the ``version`` counter; ``meta`` is the JSON-able identity
        (app names, region counts, config reprs — ``UarchConfig`` reprs
        are unique via their ``name`` field) a restore validates and
        resolves columns against. Restoring ``state()`` into an
        identically-built bank reproduces every later fill bitwise,
        including the cost accounting. Spilled columns are restored into
        the live tables first so the snapshot always carries the full
        memo content (the spill store itself is not serialized).
        """
        for col in sorted(self._spill):
            self._unspill(col)
        regions = [0 if l is None else int(l.regions_simulated)
                   for l in self.ledgers]
        instr = [0 if l is None else int(l.instructions_simulated)
                 for l in self.ledgers]
        tree = {
            "mask": self.mask.copy(),
            "cpi": self.cpi.copy(),
            "charges": self.charges.copy(),
            "hit_count": np.asarray(self.hit_count, np.int64),
            "miss_count": np.asarray(self.miss_count, np.int64),
            "ledger_regions": np.asarray(regions, np.int64),
            "ledger_instr": np.asarray(instr, np.int64),
            "version": np.asarray(self.version, np.int64),
        }
        meta = {"names": list(self.names),
                "n_regions": [int(n) for n in self.n_regions],
                "configs": [repr(c) for c in self.configs]}
        return tree, meta

    def prepare_restore(self, meta: dict, *, universe: Sequence = ()
                        ) -> np.ndarray:
        """Validate a snapshot's identity against this bank and align the
        config axis: grows columns so every snapshot config has a local
        column (objects resolved by repr from ``universe`` + the bank's
        own configs). Returns the (C_snapshot,) local column index per
        snapshot column. Raises ``ValueError`` on any identity drift —
        app set, region counts, unknown configs, or local columns the
        snapshot does not cover (their state would be inconsistent)."""
        if list(meta["names"]) != self.names:
            raise ValueError(
                f"memobank snapshot is for apps {meta['names']}, "
                f"this bank holds {self.names}")
        if [int(n) for n in meta["n_regions"]] != \
                [int(n) for n in self.n_regions]:
            raise ValueError("memobank snapshot region counts differ")
        by_repr = {repr(c): c for c in list(self.configs) + list(universe)}
        missing = [r for r in meta["configs"] if r not in by_repr]
        if missing:
            raise ValueError(
                f"snapshot configs not resolvable from the given universe:"
                f" {missing}")
        snap = set(meta["configs"])
        extra = [repr(c) for c in self.configs if repr(c) not in snap]
        if extra:
            raise ValueError(
                f"bank holds config columns the snapshot does not cover "
                f"(restore would leave them inconsistent): {extra}")
        return self.cols_for([by_repr[r] for r in meta["configs"]])

    def load_state(self, tree: dict, meta: dict, *,
                   universe: Sequence = ()) -> None:
        """Overwrite this bank's state with a ``state()`` snapshot.

        The bank must hold the same apps (a deterministic engine rebuild
        does); config columns may be fewer or permuted — they are grown/
        aligned via ``prepare_restore``. Every piece of cost accounting
        (charges, hit/miss counters, ledger totals) is REPLACED by the
        snapshot's, so re-fills performed since construction (e.g. the
        engine's phase-1 build fill, re-charged on restart) are not
        double-counted. ``version`` restores exactly as saved.
        """
        cols = self.prepare_restore(meta, universe=universe)
        # the snapshot carries the full live tables; stale spill entries
        # must not "restore" over them later
        self._spill.clear()
        self.mask[:, cols, :] = np.asarray(tree["mask"], bool)
        self.cpi[:, cols, :] = np.asarray(tree["cpi"], np.float32)
        self.charges[:, cols] = np.asarray(tree["charges"], np.int64)
        self.hit_count = [int(x) for x in np.asarray(tree["hit_count"])]
        self.miss_count = [int(x) for x in np.asarray(tree["miss_count"])]
        regions = np.asarray(tree["ledger_regions"])
        instr = np.asarray(tree["ledger_instr"])
        for i, ledger in enumerate(self.ledgers):
            if ledger is not None:
                ledger.regions_simulated = int(regions[i])
                ledger.instructions_simulated = int(instr[i])
        # version restores exactly in the fresh-rebuild case (the bank
        # never reached the saved version, so no device-resident mirror
        # can be stamped with it); rolling BACK a bank that already
        # advanced past the snapshot must instead move forward, or a
        # stale fused-block mirror stamped at the saved version would
        # revalidate against different table contents
        saved = int(np.asarray(tree["version"]))
        self.version = saved if saved >= self.version else self.version + 1

    # -- cross-device merge --------------------------------------------------
    def merge(self, other: "MemoBank") -> None:
        """Fold a device-local bank into this one.

        Apps/configs unknown here are added. Values for entries both banks
        hold agree by determinism; charges ADD (each device paid for its
        own misses), so merged ledger totals equal a single-host run's when
        the work was partitioned disjointly.

        Apps the banks share must agree on their region counts — two
        rows with the same name but different populations are different
        app universes, and merging them would corrupt both tables.
        Mismatches raise ``ValueError`` naming the offending apps
        instead of surfacing as an indexing shape error deep in numpy.
        """
        mismatched = [
            (name, self.n_regions[self.names.index(name)], int(n_reg))
            for name, n_reg in zip(other.names, other.n_regions)
            if name in self.names
            and self.n_regions[self.names.index(name)] != int(n_reg)]
        if mismatched:
            detail = ", ".join(f"{name!r} ({mine} regions here, {theirs} "
                               "in the other bank)"
                               for name, mine, theirs in mismatched)
            raise ValueError(
                "cannot merge MemoBanks with mismatched app universes: "
                + detail)
        for col in sorted(other._spill):
            other._unspill(col)
        for col in sorted(self._spill):
            self._unspill(col)
        row_map = []
        for name, n_reg in zip(other.names, other.n_regions):
            if name in self.names:
                row_map.append(self.names.index(name))
            else:
                row_map.append(self.add_app(name, n_reg, Ledger()))
        cols = self.cols_for(other.configs)
        n_other = other.mask.shape[2]
        for i, row in enumerate(row_map):
            om = other.mask[i]                  # (C_other, N_other)
            sl = (row, cols[:, None], np.arange(n_other)[None, :])
            new = om & ~self.mask[sl]
            self.cpi[sl] = np.where(new, other.cpi[i], self.cpi[sl])
            self.mask[sl] |= om
            self.version += 1
            self.charges[row, cols] += other.charges[i]
            self.hit_count[row] += other.hit_count[i]
            self.miss_count[row] += other.miss_count[i]
            ledger = self.ledgers[row]
            if ledger is not None:
                ledger.charge(int(other.charges[i].sum()))

    def total_charges(self) -> int:
        return int(self.charges.sum())


class CachedSimulator:
    """``CycleAccurateSimulator`` with an app-row view over a ``MemoBank``.

    Same interface as the base simulator; the ledger is charged only for
    cache *misses*. ``hits`` / ``misses`` count requested region-units
    served from / added to the memo.
    """

    def __init__(self, sim: CycleAccurateSimulator, *,
                 bank: Optional[MemoBank] = None, row: Optional[int] = None):
        self.sim = sim
        if bank is None:
            bank = MemoBank()
            row = bank.add_app(sim.pop.spec.name, sim.pop.n_regions,
                               sim.ledger)
        self.bank = bank
        self.row = int(row)

    # hit/miss accounting lives on the bank so engine-level stacked fills
    # are reflected in every app view
    @property
    def hits(self) -> int:
        return self.bank.hit_count[self.row]

    @property
    def misses(self) -> int:
        return self.bank.miss_count[self.row]

    # base-simulator surface -------------------------------------------------
    @property
    def pop(self):
        return self.sim.pop

    @property
    def ledger(self) -> Ledger:
        return self.sim.ledger

    def _fill(self, idx: np.ndarray, cfgs: Sequence[UarchConfig],
              values=None) -> np.ndarray:
        feats = None if values is not None else \
            self.pop.features[idx][None].astype(np.float32)
        cpi, _ = self.bank.fill(
            np.asarray([self.row]), idx[None, :], None, cfgs,
            feats=feats, values=values)
        return cpi[0]

    def simulate(self, indices, cfg: UarchConfig) -> dict[str, np.ndarray]:
        """All 38 Table III counters; CPI memoized, misses charged once."""
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        stats = evaluate_regions_batch(self.pop.features, (cfg,), idx)
        stats = {m: v[0] for m, v in stats.items()}
        self._fill(idx, (cfg,), values=stats["cpi"][None, None, :])
        return stats

    def simulate_cpi(self, indices, cfg: UarchConfig) -> np.ndarray:
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        return self._fill(idx, (cfg,))[0]

    def simulate_rfv(self, indices, cfg: UarchConfig
                     ) -> tuple[np.ndarray, np.ndarray]:
        stats = self.simulate(indices, cfg)
        return stats["cpi"], build_rfv(stats)

    # batched surface (the experiment engine's hot path) ---------------------
    def simulate_batch(self, indices, cfgs: Sequence[UarchConfig]
                       ) -> dict[str, np.ndarray]:
        """Metric dict of (C, n) matrices for ``indices`` across ``cfgs``,
        evaluated in one vmapped dispatch; misses charged per config."""
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        stats = evaluate_regions_batch(self.pop.features, cfgs, idx)
        self._fill(idx, tuple(cfgs), values=stats["cpi"][None])
        return stats

    def simulate_cpi_batch(self, indices, cfgs: Sequence[UarchConfig]
                           ) -> np.ndarray:
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        return self._fill(idx, tuple(cfgs))

    # -- ground truth (free of charge, never touches the charged memo) ------
    def census_stats(self, cfg: UarchConfig) -> dict[str, np.ndarray]:
        return self.sim.census_stats(cfg)

    def true_mean_cpi(self, cfg: UarchConfig) -> float:
        return self.sim.true_mean_cpi(cfg)


def make_cached_simulator(app_name: str, *, seed: int = 0,
                          ledger: Optional[Ledger] = None,
                          bank: Optional[MemoBank] = None,
                          row: Optional[int] = None) -> CachedSimulator:
    sim = CycleAccurateSimulator(get_population(app_name, seed=seed), ledger)
    return CachedSimulator(sim, bank=bank, row=row)
