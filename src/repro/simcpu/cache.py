"""Memoizing simulation cache (region × config memo table).

``CachedSimulator`` wraps ``CycleAccurateSimulator`` so that each region is
*simulated once per configuration*: repeated requests for the same
(region, config) pair are served from the memo table and charge the
``Ledger`` nothing. This fixes the double-charging that occurs when
benchmarks re-simulate the same selected regions across figures — the
paper's cost unit is "number of 1 M-instruction region simulations", and a
real simulation farm would of course keep the results it already paid for.

The memo is compact: per config it stores only the rows actually simulated
(a position map + a growing (rows, 38) matrix), not dense (N, 38) tables,
so caching all 7 configs for all 10 apps stays in the tens of MB.

``census_stats`` stays analysis-only (free of charge, like the base
simulator) and deliberately does NOT populate the charged memo — otherwise
a census would make every later ``simulate`` call free and the cost
accounting meaningless.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from ..core.features import build_rfv
from .perfmodel import evaluate_regions_batch
from .simulator import CycleAccurateSimulator, Ledger
from .uarch import UarchConfig
from .workload import get_population


class _ConfigMemo:
    """Rows simulated so far for one config: region -> row position."""

    __slots__ = ("pos", "data")

    def __init__(self):
        self.pos: dict[int, int] = {}
        self.data: Optional[np.ndarray] = None   # (capacity, n_metrics)

    def missing(self, idx: np.ndarray) -> np.ndarray:
        pos = self.pos
        return np.unique(np.asarray(
            [i for i in idx.tolist() if i not in pos], np.int64))

    def store(self, idx: np.ndarray, rows: np.ndarray) -> None:
        n_new = idx.size
        if n_new == 0:
            return
        n_old = len(self.pos)
        if self.data is None:
            cap = max(n_new, 64)
            self.data = np.empty((cap, rows.shape[1]), np.float32)
        elif n_old + n_new > self.data.shape[0]:
            cap = max(2 * self.data.shape[0], n_old + n_new)
            grown = np.empty((cap, self.data.shape[1]), np.float32)
            grown[:n_old] = self.data[:n_old]
            self.data = grown
        self.data[n_old:n_old + n_new] = rows
        for j, i in enumerate(idx.tolist()):
            self.pos[i] = n_old + j

    def rows(self, idx: np.ndarray) -> np.ndarray:
        pos = self.pos
        return self.data[[pos[i] for i in idx.tolist()]]


class CachedSimulator:
    """``CycleAccurateSimulator`` with a region × config memo table.

    Same interface as the base simulator; the ledger is charged only for
    cache *misses*. ``hits`` / ``misses`` count requested region-units
    served from / added to the memo.
    """

    def __init__(self, sim: CycleAccurateSimulator):
        self.sim = sim
        self._memo: dict[UarchConfig, _ConfigMemo] = {}
        self._metrics: Optional[tuple[str, ...]] = None
        self.hits = 0
        self.misses = 0

    # base-simulator surface -------------------------------------------------
    @property
    def pop(self):
        return self.sim.pop

    @property
    def ledger(self) -> Ledger:
        return self.sim.ledger

    def _fill(self, cfgs: Sequence[UarchConfig], idx: np.ndarray) -> None:
        """Simulate whatever part of ``idx`` is missing, one batched dispatch
        over all configs; charge each config only for its own misses."""
        memos = [self._memo.setdefault(c, _ConfigMemo()) for c in cfgs]
        missing = [m.missing(idx) for m in memos]
        union = np.unique(np.concatenate(missing)) if missing else \
            np.empty(0, np.int64)
        if union.size == 0 and self._metrics is not None:
            return
        stats = evaluate_regions_batch(self.pop.features, cfgs, union)
        if self._metrics is None:
            self._metrics = tuple(stats)
        mat = np.stack([stats[k] for k in self._metrics], axis=2)  # (C,n,M)
        for ci, (memo, miss) in enumerate(zip(memos, missing)):
            self.ledger.charge(miss.size)
            self.misses += int(miss.size)
            # every union region was requested for every config, so storing
            # the full union is "simulated once per config", not pre-charging
            new = union[[j for j, i in enumerate(union.tolist())
                         if i not in memo.pos]]
            sel = np.searchsorted(union, new)
            memo.store(new, mat[ci, sel])

    def _lookup(self, cfg: UarchConfig, idx: np.ndarray
                ) -> dict[str, np.ndarray]:
        rows = self._memo[cfg].rows(idx)
        return {k: rows[:, j] for j, k in enumerate(self._metrics)}

    def simulate(self, indices, cfg: UarchConfig) -> dict[str, np.ndarray]:
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        before = self.misses
        self._fill((cfg,), idx)
        self.hits += int(idx.size) - (self.misses - before)
        return self._lookup(cfg, idx)

    def simulate_cpi(self, indices, cfg: UarchConfig) -> np.ndarray:
        return self.simulate(indices, cfg)["cpi"]

    def simulate_rfv(self, indices, cfg: UarchConfig
                     ) -> tuple[np.ndarray, np.ndarray]:
        stats = self.simulate(indices, cfg)
        return stats["cpi"], build_rfv(stats)

    # batched surface (the experiment engine's hot path) ---------------------
    def simulate_batch(self, indices, cfgs: Sequence[UarchConfig]
                       ) -> dict[str, np.ndarray]:
        """Metric dict of (C, n) matrices for ``indices`` across ``cfgs``,
        evaluated in one vmapped dispatch; misses charged per config."""
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        before = self.misses
        self._fill(tuple(cfgs), idx)
        self.hits += int(idx.size) * len(cfgs) - (self.misses - before)
        per_cfg = [self._lookup(c, idx) for c in cfgs]
        return {k: np.stack([s[k] for s in per_cfg])
                for k in self._metrics}

    def simulate_cpi_batch(self, indices, cfgs: Sequence[UarchConfig]
                           ) -> np.ndarray:
        return self.simulate_batch(indices, cfgs)["cpi"]

    # -- ground truth (free of charge, never touches the charged memo) ------
    def census_stats(self, cfg: UarchConfig) -> dict[str, np.ndarray]:
        return self.sim.census_stats(cfg)

    def true_mean_cpi(self, cfg: UarchConfig) -> float:
        return self.sim.true_mean_cpi(cfg)


def make_cached_simulator(app_name: str, *, seed: int = 0,
                          ledger: Optional[Ledger] = None) -> CachedSimulator:
    return CachedSimulator(
        CycleAccurateSimulator(get_population(app_name, seed=seed), ledger))
