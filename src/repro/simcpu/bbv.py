"""Basic-block-vector synthesis (SimPoint's stratification variable).

Each application has ``NUM_BLOCKS`` static basic blocks. Every BBV *profile*
(one per non-aliased phase) is a sparse Dirichlet draw over blocks; a
region's BBV is its phase's profile with small execution noise. Crucially:

* regions from *aliased* phases (same code, different input data) share a
  profile — their very different memory behavior is invisible here;
* within-phase input jitter (perfmodel's rate jitter) does NOT perturb the
  BBV — the paper's III.A limitation ("a function's CPI may vary widely
  depending on its input data, even if the same basic blocks are executed").
"""

from __future__ import annotations

import zlib

import numpy as np

from .workload import REGION_LEN_INSTR, AppPopulation

NUM_BLOCKS = 256
BBV_NOISE = 0.04
# How strongly a region's input-heaviness z-score bends its BBV along the
# profile's "data-size direction" (loop-iteration counts shift with input
# size). Small vs profile separation: k-means only resolves it once clusters
# are plentiful — the reason the paper's gcc improves from k=20 to k=50.
JITTER_VISIBILITY = 0.03


def synthesize_bbvs(pop: AppPopulation, *, seed: int = 1) -> np.ndarray:
    """(n_regions, NUM_BLOCKS) float32 block execution counts."""
    rng = np.random.default_rng(
        np.random.SeedSequence([zlib.crc32(pop.spec.name.encode()), seed, 7]))
    n_profiles = int(pop.bbv_profile_ids.max()) + 1
    # Sparse-ish profiles: ~10% of blocks active per profile.
    profiles = rng.dirichlet(np.full(NUM_BLOCKS, 0.06), size=n_profiles)
    directions = rng.choice([-1.0, 1.0], size=(n_profiles, NUM_BLOCKS))
    region_profiles = profiles[pop.bbv_profile_ids[pop.phase_ids]]
    region_dirs = directions[pop.bbv_profile_ids[pop.phase_ids]]
    noise = rng.normal(1.0, BBV_NOISE, region_profiles.shape)
    sway = 1.0 + JITTER_VISIBILITY * pop.jitter_u[:, None] * region_dirs
    bbv = region_profiles * np.clip(noise * np.clip(sway, 0.2, 3.0), 0.2, 3.0)
    bbv /= bbv.sum(axis=1, keepdims=True)
    return (bbv * REGION_LEN_INSTR).astype(np.float32)


_BBV_CACHE: dict[tuple[str, int], np.ndarray] = {}


def get_bbvs(pop: AppPopulation, *, seed: int = 1) -> np.ndarray:
    key = (pop.spec.name, seed)
    if key not in _BBV_CACHE:
        _BBV_CACHE[key] = synthesize_bbvs(pop, seed=seed)
    return _BBV_CACHE[key]
