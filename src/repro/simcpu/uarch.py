"""Microarchitecture configurations (paper Table I).

A four-wide-retire out-of-order ARMv9-class core (Config 0) plus six
progressively faster variants. Frequency is fixed at 3 GHz so the
nanosecond latencies in Table I convert to cycles.
"""

from __future__ import annotations

import dataclasses

FREQ_GHZ = 3.0


@dataclasses.dataclass(frozen=True)
class UarchConfig:
    name: str
    fetch_width: int = 8
    issue_width: int = 8
    dcache_hit_lat: int = 3          # cycles
    l2_hit_lat: int = 8              # cycles
    icache_kb: int = 32
    dcache_kb: int = 32
    l2_kb: int = 512
    l3_mb: int = 2
    sms_pf: bool = False             # Spatial Memory Streaming prefetcher
    rob_size: int = 128
    phys_regs: int = 128
    retire_width: int = 4
    mem_latency_ns: float = 130.0
    l3_hit_latency_ns: float = 30.0
    bo_pf: bool = False              # Best-Offset L2 prefetcher
    tage_tables: int = 4
    tage_entries: int = 2048

    @property
    def mem_latency_cyc(self) -> float:
        return self.mem_latency_ns * FREQ_GHZ

    @property
    def l3_hit_latency_cyc(self) -> float:
        return self.l3_hit_latency_ns * FREQ_GHZ

    @property
    def tage_capacity_ratio(self) -> float:
        """Branch-predictor capacity relative to Config 0."""
        return (self.tage_tables * self.tage_entries) / (4 * 2048)


# Table I, highlighted deltas relative to the baseline.
CONFIG_0 = UarchConfig(name="config0")
CONFIG_1 = dataclasses.replace(
    CONFIG_0, name="config1", icache_kb=64, dcache_kb=64, l2_kb=1024, l3_mb=4)
CONFIG_2 = dataclasses.replace(CONFIG_1, name="config2", sms_pf=True)
CONFIG_3 = dataclasses.replace(
    CONFIG_2, name="config3", rob_size=256, phys_regs=256, retire_width=8)
CONFIG_4 = dataclasses.replace(
    CONFIG_3, name="config4", mem_latency_ns=90.0, l3_hit_latency_ns=20.0)
CONFIG_5 = dataclasses.replace(CONFIG_4, name="config5", bo_pf=True)
CONFIG_6 = dataclasses.replace(
    CONFIG_5, name="config6", tage_tables=8, tage_entries=4096)

CONFIGS: tuple[UarchConfig, ...] = (
    CONFIG_0, CONFIG_1, CONFIG_2, CONFIG_3, CONFIG_4, CONFIG_5, CONFIG_6)

BASELINE = CONFIG_0
