"""Simulation API over the synthetic populations + a cost ledger.

``CycleAccurateSimulator`` mimics the interface of a detailed simulator
farm: you hand it region indices and a configuration; it returns the 38
Table III counters for those regions and charges the ledger (the paper's
cost unit is "number of 1 M-instruction region simulations"). A full
``census`` is what the paper calls simulating the application end-to-end —
possible here, prohibitive in reality, which is exactly the asymmetry the
methodology exploits.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.features import build_rfv
from .perfmodel import evaluate_regions
from .uarch import UarchConfig
from .workload import REGION_LEN_INSTR, AppPopulation, get_population


@dataclasses.dataclass
class Ledger:
    """Accounting of simulation cost (regions × configs actually run)."""

    regions_simulated: int = 0
    instructions_simulated: int = 0

    def charge(self, n_regions: int) -> None:
        self.regions_simulated += int(n_regions)
        self.instructions_simulated += int(n_regions) * REGION_LEN_INSTR

    def reset(self) -> None:
        self.regions_simulated = 0
        self.instructions_simulated = 0


class CycleAccurateSimulator:
    """Detailed-simulation stand-in for one application."""

    def __init__(self, pop: AppPopulation, ledger: Optional[Ledger] = None):
        self.pop = pop
        self.ledger = ledger if ledger is not None else Ledger()

    def simulate(self, indices, cfg: UarchConfig) -> dict[str, np.ndarray]:
        idx = np.asarray(indices)
        self.ledger.charge(idx.size)
        return evaluate_regions(self.pop.features, cfg, idx)

    def simulate_cpi(self, indices, cfg: UarchConfig) -> np.ndarray:
        return self.simulate(indices, cfg)["cpi"]

    def simulate_rfv(self, indices, cfg: UarchConfig
                     ) -> tuple[np.ndarray, np.ndarray]:
        """(cpi, rfv_matrix) for the given regions — the phase-1 output."""
        stats = self.simulate(indices, cfg)
        return stats["cpi"], build_rfv(stats)

    # -- ground truth (free of charge: analysis-only, not part of the flow) --
    def census_stats(self, cfg: UarchConfig) -> dict[str, np.ndarray]:
        return evaluate_regions(self.pop.features, cfg, None)

    def true_mean_cpi(self, cfg: UarchConfig) -> float:
        return float(self.census_stats(cfg)["cpi"].mean())


def make_simulator(app_name: str, *, seed: int = 0,
                   ledger: Optional[Ledger] = None) -> CycleAccurateSimulator:
    return CycleAccurateSimulator(get_population(app_name, seed=seed), ledger)
