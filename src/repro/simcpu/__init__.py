"""Synthetic CPU-simulation substrate (stands in for gem5 + SPECint 2017)."""

from .bbv import NUM_BLOCKS, get_bbvs, synthesize_bbvs
from .cache import CachedSimulator, MemoBank, make_cached_simulator
from .perfmodel import (config_matrix, cpi_bank, cpi_batch, cpi_only,
                        evaluate_regions, evaluate_regions_batch, rfv_bank,
                        stats_matrix)
from .simulator import CycleAccurateSimulator, Ledger, make_simulator
from .uarch import BASELINE, CONFIGS, UarchConfig
from .workload import (APP_NAMES, APP_SPECS, REGION_LEN_INSTR, AppPopulation,
                       AppSpec, PopulationBank, build_population_bank,
                       generate_population, get_population,
                       get_population_bank, stack_ragged)

__all__ = [
    "UarchConfig", "CONFIGS", "BASELINE",
    "AppSpec", "AppPopulation", "APP_SPECS", "APP_NAMES",
    "generate_population", "get_population", "REGION_LEN_INSTR",
    "PopulationBank", "build_population_bank", "get_population_bank",
    "stack_ragged",
    "evaluate_regions", "evaluate_regions_batch", "cpi_batch", "cpi_only",
    "cpi_bank", "rfv_bank", "config_matrix", "stats_matrix",
    "synthesize_bbvs", "get_bbvs", "NUM_BLOCKS",
    "CycleAccurateSimulator", "Ledger", "make_simulator",
    "CachedSimulator", "MemoBank", "make_cached_simulator",
]
