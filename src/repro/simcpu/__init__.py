"""Synthetic CPU-simulation substrate (stands in for gem5 + SPECint 2017)."""

from .bbv import NUM_BLOCKS, get_bbvs, synthesize_bbvs
from .perfmodel import cpi_only, evaluate_regions, stats_matrix
from .simulator import CycleAccurateSimulator, Ledger, make_simulator
from .uarch import BASELINE, CONFIGS, UarchConfig
from .workload import (APP_NAMES, APP_SPECS, REGION_LEN_INSTR, AppPopulation,
                       AppSpec, generate_population, get_population)

__all__ = [
    "UarchConfig", "CONFIGS", "BASELINE",
    "AppSpec", "AppPopulation", "APP_SPECS", "APP_NAMES",
    "generate_population", "get_population", "REGION_LEN_INSTR",
    "evaluate_regions", "cpi_only", "stats_matrix",
    "synthesize_bbvs", "get_bbvs", "NUM_BLOCKS",
    "CycleAccurateSimulator", "Ledger", "make_simulator",
]
