"""App-sharded batched experiment engine (stacked populations, vmapped
configs/seeds/trials, memoized simulation).

``ExperimentEngine.build(names)`` constructs per-app state via
batched-over-app programs (census truth, phase-1 sample, BBV/RFV/DG
stratifications) on top of one shared ``MemoBank``;
``run_sweep(engine, SweepSpec(...))`` and
``run_trials(engine, TrialSpec(...))`` drive apps × configs × schemes ×
Monte-Carlo trials through the batched (optionally app-sharded) paths.
"""

from .engine import (NUM_STRATA, PHASE1_SEED, AppExperiment,
                     ExperimentEngine, SweepStack, scheme_selection,
                     scheme_selection_bank)
from .montecarlo import TrialResult, TrialSpec, run_trials, trial_uniforms
from .sweep import ResultsTable, SweepRow, SweepSpec, run_sweep

__all__ = [
    "ExperimentEngine", "AppExperiment", "SweepStack",
    "scheme_selection", "scheme_selection_bank",
    "SweepSpec", "SweepRow", "ResultsTable", "run_sweep",
    "TrialSpec", "TrialResult", "run_trials", "trial_uniforms",
    "NUM_STRATA", "PHASE1_SEED",
]
