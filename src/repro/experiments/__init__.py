"""Batched experiment engine (vmapped configs + seeds, memoized simulation).

``ExperimentEngine`` builds per-app state once (census truth via one
vmapped all-config dispatch, phase-1 sample, BBV/RFV/DG stratifications)
on top of ``CachedSimulator``; ``run_sweep(engine, SweepSpec(...))``
drives apps × configs × schemes through the batched paths.
"""

from .engine import (NUM_STRATA, PHASE1_SEED, AppExperiment,
                     ExperimentEngine, scheme_selection)
from .sweep import ResultsTable, SweepRow, SweepSpec, run_sweep

__all__ = [
    "ExperimentEngine", "AppExperiment", "scheme_selection",
    "SweepSpec", "SweepRow", "ResultsTable", "run_sweep",
    "NUM_STRATA", "PHASE1_SEED",
]
