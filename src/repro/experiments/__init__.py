"""App-sharded batched experiment engine (stacked populations, vmapped
configs/seeds/trials, memoized simulation).

``ExperimentEngine.build(names)`` constructs per-app state via
batched-over-app programs (census truth, phase-1 sample, BBV/RFV/DG
stratifications) on top of one shared ``MemoBank``;
``run_sweep(engine, SweepSpec(...))`` and
``run_trials(engine, TrialSpec(...))`` drive apps × configs × plans ×
Monte-Carlo trials through the batched (optionally app-sharded) paths.

Sampling designs are ``SamplingPlan`` objects
(``repro.core.sampling.plan``): the engine dispatches on the plan's
stratifier/policy/estimator components only, so registry plug-ins run
through ``plan_selection_bank``/``run_sweep`` without engine edits.
Legacy scheme/policy strings still work as deprecated shims.
"""

from .fused import fused_sweep_program, run_fused_sweep
from .engine import (NUM_STRATA, PHASE1_SEED, AppExperiment,
                     ExperimentEngine, SweepStack, plan_selection,
                     plan_selection_bank, scheme_selection,
                     scheme_selection_bank)
from .montecarlo import (SRS_DRAWS, TRIAL_SCHEMES, TrialResult, TrialSpec,
                         run_trials, trial_uniforms)
from .resumable import (FleetReport, run_sweep_resumable,
                        run_trials_resumable, supervise_sweep,
                        supervise_trials)
from .sweep import (SRS_SCHEME, ResultsTable, SweepRow, SweepSpec,
                    known_schemes, run_sweep)

__all__ = [
    "ExperimentEngine", "AppExperiment", "SweepStack",
    "plan_selection", "plan_selection_bank",
    "scheme_selection", "scheme_selection_bank",
    "SweepSpec", "SweepRow", "ResultsTable", "run_sweep",
    "fused_sweep_program", "run_fused_sweep",
    "SRS_SCHEME", "known_schemes",
    "TrialSpec", "TrialResult", "run_trials", "trial_uniforms",
    "SRS_DRAWS", "TRIAL_SCHEMES",
    "NUM_STRATA", "PHASE1_SEED",
    "FleetReport", "run_sweep_resumable", "run_trials_resumable",
    "supervise_sweep", "supervise_trials",
]
