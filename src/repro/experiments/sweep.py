"""Declarative sweeps: ``SweepSpec`` -> results table.

A sweep is the cross product (apps × configs) for one estimation scheme:

* ``scheme="srs"`` — phase-1 simple-random-sample estimate per config
  (paper Fig 5), with its 95 % margin.
* ``scheme in {"bbv", "rfv", "dg"}`` — stratified selection (paper
  Figs 10/11): pick units per stratum under ``policy``, project CPI for
  every config, weight by stratum weights.

The driver is app-sharded: selection is vectorized over the whole app
stack (``scheme_selection_bank``) and the region sets of ALL apps are
simulated across the requested configs in ONE vmapped dispatch through the
engine's shared memo bank — ``shard_map``-ped over the app axis when the
engine has a mesh. No host-side per-app loops remain on the simulation
path; Python only assembles the result rows afterwards.

``SweepSpec.trials`` attaches a Monte-Carlo study (``TrialSpec``): the
sweep additionally runs vmapped selection trials and reports the
95th-percentile error for rows at the trial config.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.sampling import plan as sampling_plan
from ..core.sampling import tables as sampling_tables
from ..core.sampling.types import critical_values
from ..simcpu import APP_NAMES
from .engine import ExperimentEngine, plan_selection_bank

__all__ = ["SRS_SCHEME", "SweepSpec", "SweepRow", "ResultsTable",
           "assemble_rows", "run_sweep", "known_schemes"]

# the one structurally-special scheme: the phase-1 simple random sample
# (no stratification, no plan) — everything else is a SamplingPlan
SRS_SCHEME = "srs"


def known_schemes() -> tuple[str, ...]:
    """Scheme names ``SweepSpec`` accepts: ``"srs"`` plus every
    registered stratifier (``repro.core.sampling.plan``)."""
    return (SRS_SCHEME,) + sampling_plan.registered_stratifiers()


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One sweep = apps × configs for a single sampling plan.

    The modern spelling passes a ``SamplingPlan``
    (``SweepSpec(plan=SamplingPlan(RFVClusters(), Centroid()))``);
    ``scheme``/``policy`` then carry the plan's registered names as row
    labels. The legacy string spelling
    (``SweepSpec(scheme="rfv", policy="centroid")``) still works: it
    resolves the names through the plan registry *at construction* —
    unknown names raise here, not deep inside the engine — and emits a
    ``DeprecationWarning``. ``scheme="srs"`` is the plan-less phase-1
    estimate.
    """

    apps: tuple[str, ...] = tuple(APP_NAMES)
    scheme: str = SRS_SCHEME                 # row label / legacy name
    policy: Optional[str] = None             # row label / legacy name
    plan: Optional[sampling_plan.SamplingPlan] = None
    config_indices: Optional[tuple[int, ...]] = None   # None = all engine configs
    selection_seed: int = 0                  # rng seed for policy="random"
    # stratified sweeps dispatch through the fused megaprogram
    # (repro.experiments.fused) by default; False forces the staged
    # selection → fill → estimate chain (debug / parity reference)
    fused: bool = True
    # optional Monte-Carlo study riding along (see experiments.montecarlo):
    # rows at trials.config_index gain a 95th-percentile |error| column
    trials: Optional["TrialSpec"] = None     # noqa: F821

    def __post_init__(self):
        if self.plan is not None:
            # a stale scheme/policy string alongside plan= must not be
            # silently relabeled: either omit it or make it agree
            if self.scheme not in (SRS_SCHEME, self.plan.scheme) \
                    or self.policy not in (None, self.plan.policy_name):
                raise ValueError(
                    f"scheme={self.scheme!r}/policy={self.policy!r} "
                    f"conflict with plan="
                    f"({self.plan.scheme!r}, {self.plan.policy_name!r}); "
                    "drop the strings when passing plan=")
            object.__setattr__(self, "scheme", self.plan.scheme)
            object.__setattr__(self, "policy", self.plan.policy_name)
        elif self.scheme != SRS_SCHEME:
            sampling_plan.warn_string_dispatch(
                "SweepSpec(scheme=..., policy=...)",
                "pass SweepSpec(plan=SamplingPlan.from_strings(...))")
            # registry lookup validates both names at spec construction;
            # aliases (e.g. "cpi") normalize to the canonical name so
            # row labels always match plan.scheme
            object.__setattr__(self, "plan", sampling_plan.SamplingPlan
                               .from_strings(self.scheme,
                                             self.policy or "centroid"))
            object.__setattr__(self, "scheme", self.plan.scheme)
            object.__setattr__(self, "policy", self.plan.policy_name)
        elif self.policy is not None:
            raise ValueError(
                "scheme='srs' takes no selection policy (phase-1 SRS has "
                "no strata to select from)")
        if (self.trials is not None and self.config_indices is not None
                and self.trials.config_index not in self.config_indices):
            raise ValueError(
                f"trials.config_index={self.trials.config_index} is not in "
                f"config_indices={self.config_indices}; the Monte-Carlo "
                "study would run (and charge the ledger) with its result "
                "attached to no row")


@dataclasses.dataclass(frozen=True)
class SweepRow:
    """One (app, config) result row of a sweep's ``ResultsTable``."""

    app: str
    scheme: str
    config_index: int
    estimate: float       # estimated mean CPI
    truth: float          # census mean CPI
    err_pct: float        # 100 * |estimate - truth| / truth
    n_units: int          # regions the estimate is built from
    margin_pct: Optional[float] = None   # 95% margin (srs scheme only)
    p95_err_pct: Optional[float] = None  # Monte-Carlo p95 |error| (trials)
    ci_half_pct: Optional[float] = None  # Monte-Carlo mean CI half-width (%)
    coverage: Optional[float] = None     # Monte-Carlo empirical CI coverage


class ResultsTable:
    """Thin list-of-rows wrapper with filter/column helpers."""

    def __init__(self, rows: Sequence[SweepRow]):
        self.rows = list(rows)

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def filter(self, **fields) -> "ResultsTable":
        """Rows whose attributes equal every ``field=value`` given."""
        return ResultsTable([
            r for r in self.rows
            if all(getattr(r, k) == v for k, v in fields.items())])

    def column(self, field: str) -> np.ndarray:
        """(len(rows),) array of one ``SweepRow`` field, in row order."""
        return np.asarray([getattr(r, field) for r in self.rows])

    def matrix(self, field: str = "estimate") -> np.ndarray:
        """(C, A) matrix of ``field`` over config × app, in spec order.

        Both axes follow first appearance in the rows — i.e. the order
        of ``SweepSpec.apps`` / ``config_indices`` — so an unsorted
        ``config_indices`` keeps its caller-chosen row order instead of
        being silently re-sorted.
        """
        configs = list(dict.fromkeys(r.config_index for r in self.rows))
        apps = list(dict.fromkeys(r.app for r in self.rows))
        out = np.full((len(configs), len(apps)), np.nan)
        ci = {c: i for i, c in enumerate(configs)}
        ai = {a: j for j, a in enumerate(apps)}
        for r in self.rows:
            out[ci[r.config_index], ai[r.app]] = getattr(r, field)
        return out

    def to_csv(self) -> str:
        """The table as CSV text (header + one line per row; optional
        margin/p95/CI columns empty when absent)."""
        hdr = ("app,scheme,config_index,estimate,truth,err_pct,n_units,"
               "margin_pct,p95_err_pct,ci_half_pct,coverage")
        lines = [hdr]
        for r in self.rows:
            m = "" if r.margin_pct is None else f"{r.margin_pct:.4f}"
            p = "" if r.p95_err_pct is None else f"{r.p95_err_pct:.4f}"
            h = "" if r.ci_half_pct is None else f"{r.ci_half_pct:.4f}"
            c = "" if r.coverage is None else f"{r.coverage:.4f}"
            lines.append(f"{r.app},{r.scheme},{r.config_index},"
                         f"{r.estimate:.6f},{r.truth:.6f},{r.err_pct:.4f},"
                         f"{r.n_units},{m},{p},{h},{c}")
        return "\n".join(lines)


def _srs_stats(cpi: np.ndarray, valid: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized ``srs_estimate`` over an (A, C, K) masked CPI stack:
    returns (A, C) means and margins (percent) — one-call view over the
    batched eq. (2) helper in ``repro.core.sampling.tables``."""
    mean, v_mean, n = sampling_tables.masked_srs_stats(
        cpi.astype(np.float64), valid[:, None, :])
    crit = critical_values(0.95, np.where(n < 30, n - 1.0, np.inf))
    margin = crit * np.sqrt(v_mean)
    return mean, 100.0 * margin / np.abs(mean)


def _warn_partial_coverage(spec: SweepSpec, valid: np.ndarray,
                           weights: np.ndarray) -> None:
    """Warn when selected units cover only part of the stratum weight
    (the renormalized eq. (3) mean is then biased) — shared by the fused
    and staged stratified paths so the diagnostic cannot drift."""
    covered = np.where(valid, weights, 0.0).sum(axis=1)          # (A,)
    total = weights.sum(axis=1)
    low = covered < total * (1.0 - 1e-6)
    if low.any():
        import warnings
        bad = [spec.apps[a] for a in np.flatnonzero(low)]
        warnings.warn(
            f"selected units cover only part of the stratum weight for "
            f"{bad}; renormalizing biases those estimates",
            UserWarning, stacklevel=3)


def assemble_rows(spec: SweepSpec, cfg_is: Sequence[int], ests, errs,
                  n_units, truth, *, margins=None, p95=None, ci_half=None,
                  cov=None) -> ResultsTable:
    """Assemble a sweep's (A, C) result arrays into its ``ResultsTable``.

    The one row-construction path shared by ``run_sweep`` and the
    request-coalescing batcher (``repro.serving``): rows follow spec
    order (apps outer, ``cfg_is`` inner), the optional Monte-Carlo
    columns attach only to rows at ``spec.trials.config_index``, and
    every value converts to plain Python floats/ints exactly once — so
    a coalesced request's table is field-for-field identical to the
    serial ``run_sweep`` table built from the same arrays.
    """
    rows: list[SweepRow] = []
    for a, name in enumerate(spec.apps):
        for pos, ci in enumerate(cfg_is):
            at_trial_cfg = (spec.trials is not None
                            and spec.trials.config_index == ci)
            rows.append(SweepRow(
                app=name, scheme=spec.scheme, config_index=ci,
                estimate=float(ests[a, pos]), truth=float(truth[a, pos]),
                err_pct=float(errs[a, pos]),
                n_units=int(n_units[a]),
                margin_pct=(float(margins[a, pos])
                            if margins is not None else None),
                p95_err_pct=float(p95[a]) if at_trial_cfg else None,
                ci_half_pct=float(ci_half[a]) if at_trial_cfg else None,
                coverage=float(cov[a]) if at_trial_cfg else None))
    return ResultsTable(rows)


def run_sweep(engine: ExperimentEngine, spec: SweepSpec,
              mesh=None) -> ResultsTable:
    """Execute one sweep: ONE batched (optionally app-sharded) dispatch
    over all apps × requested configs (only those are simulated and
    ledger-charged).

    Stratified sweeps dispatch on ``spec.plan`` only. By default
    (``spec.fused``) the whole selection → memo-fill → estimate pipeline
    runs as ONE donated-buffer device program (``repro.experiments
    .fused``); ``fused=False`` keeps the staged reference chain —
    ``plan_selection_bank`` then ``MemoBank.fill`` then the estimator's
    jitted ``StratumTables`` program. Either way ``sampling_plan
    .last_sweep_dispatch`` records the dispatch and estimates + percent
    errors come off-device ready-made; no host-side weighted-mean
    reduction remains on the path.
    """
    exps = engine.build(spec.apps)
    stack = engine.stack(spec.apps)
    mesh = engine.mesh if mesh is None else mesh
    cfg_is = (tuple(range(len(engine.configs)))
              if spec.config_indices is None else spec.config_indices)
    cfgs = tuple(engine.configs[i] for i in cfg_is)
    truth = np.stack([e.truth for e in exps])[:, list(cfg_is)]   # (A, C')

    if spec.plan is None:                                # phase-1 SRS
        cpi, _ = engine.memo.fill(stack.rows, stack.idx1, stack.idx1_valid,
                                  cfgs, feats=stack.gather_feats(stack.idx1),
                                  mesh=mesh)
        ests, margins = _srs_stats(cpi, stack.idx1_valid)
        errs = 100.0 * np.abs(ests - truth) / truth
        n_units = stack.idx1_valid.sum(axis=1)
    elif spec.fused:                         # fused megaprogram (one dispatch)
        from .fused import run_fused_sweep
        ests, errs, valid, weights = run_fused_sweep(
            engine, spec, exps, stack, cfgs, truth, mesh=mesh)
        _warn_partial_coverage(spec, valid, weights)
        margins = None
        n_units = valid.sum(axis=1)
    else:                                    # staged reference chain
        picks, valid, weights = plan_selection_bank(
            exps, spec.plan, seed=spec.selection_seed)
        cpi, _ = engine.memo.fill(stack.rows, picks, valid, cfgs,
                                  feats=stack.gather_feats(picks), mesh=mesh)
        _warn_partial_coverage(spec, valid, weights)
        ests, errs = spec.plan.estimator.sweep_estimates(
            cpi, valid, weights, truth, precision=engine.precision)
        margins = None
        n_units = valid.sum(axis=1)

    p95 = ci_half = cov = None
    if spec.trials is not None:
        from .montecarlo import SRS_DRAWS, run_trials
        mc_scheme = SRS_DRAWS if spec.plan is None else spec.scheme
        strats = None if spec.plan is None \
            else {mc_scheme: spec.plan.stratifier}
        mc = run_trials(engine,
                        dataclasses.replace(spec.trials,
                                            schemes=(mc_scheme,)),
                        apps=spec.apps, mesh=mesh, stratifiers=strats)
        p95 = mc.p95(mc_scheme)
        mc_truth = np.stack(
            [e.truth[spec.trials.config_index] for e in exps])
        # streamed mean half-width percent (the nanmean over trials now
        # accumulates inside the chunked scan — TrialStats.half_mean)
        ci_half = mc.half_width_pct(mc_scheme, mc_truth)
        cov = mc.coverage[mc_scheme]

    return assemble_rows(spec, cfg_is, ests, errs, n_units, truth,
                         margins=margins, p95=p95, ci_half=ci_half, cov=cov)
