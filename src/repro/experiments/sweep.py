"""Declarative sweeps: ``SweepSpec`` -> results table.

A sweep is the cross product (apps × configs) for one estimation scheme:

* ``scheme="srs"`` — phase-1 simple-random-sample estimate per config
  (paper Fig 5), with its 95 % margin.
* ``scheme in {"bbv", "rfv", "dg"}`` — stratified selection (paper
  Figs 10/11): pick units per stratum under ``policy``, project CPI for
  every config, weight by stratum weights.

The driver simulates each app's region set across ALL configs as one
batched dispatch (``AppExperiment.cpi_all``) and serves repeats from the
simulator memo, replacing the per-(config, app) Python loops the
benchmarks used to run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.sampling import srs_estimate
from ..simcpu import APP_NAMES
from .engine import ExperimentEngine, scheme_selection

SCHEMES = ("srs", "bbv", "rfv", "dg")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One sweep = apps × configs for a single scheme/policy."""

    apps: tuple[str, ...] = tuple(APP_NAMES)
    scheme: str = "srs"                      # "srs" | "bbv" | "rfv" | "dg"
    policy: Optional[str] = None             # selection policy (non-srs)
    config_indices: Optional[tuple[int, ...]] = None   # None = all engine configs
    selection_seed: int = 0                  # rng seed for policy="random"

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}")
        if self.scheme != "srs" and self.policy is None:
            object.__setattr__(self, "policy", "centroid")


@dataclasses.dataclass(frozen=True)
class SweepRow:
    app: str
    scheme: str
    config_index: int
    estimate: float       # estimated mean CPI
    truth: float          # census mean CPI
    err_pct: float        # 100 * |estimate - truth| / truth
    n_units: int          # regions the estimate is built from
    margin_pct: Optional[float] = None   # 95% margin (srs scheme only)


class ResultsTable:
    """Thin list-of-rows wrapper with filter/column helpers."""

    def __init__(self, rows: Sequence[SweepRow]):
        self.rows = list(rows)

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def filter(self, **fields) -> "ResultsTable":
        return ResultsTable([
            r for r in self.rows
            if all(getattr(r, k) == v for k, v in fields.items())])

    def column(self, field: str) -> np.ndarray:
        return np.asarray([getattr(r, field) for r in self.rows])

    def matrix(self, field: str = "estimate") -> np.ndarray:
        """(C, A) matrix of ``field`` over config × app, in spec order."""
        configs = sorted({r.config_index for r in self.rows})
        apps = list(dict.fromkeys(r.app for r in self.rows))
        out = np.full((len(configs), len(apps)), np.nan)
        ci = {c: i for i, c in enumerate(configs)}
        ai = {a: j for j, a in enumerate(apps)}
        for r in self.rows:
            out[ci[r.config_index], ai[r.app]] = getattr(r, field)
        return out

    def to_csv(self) -> str:
        hdr = "app,scheme,config_index,estimate,truth,err_pct,n_units,margin_pct"
        lines = [hdr]
        for r in self.rows:
            m = "" if r.margin_pct is None else f"{r.margin_pct:.4f}"
            lines.append(f"{r.app},{r.scheme},{r.config_index},"
                         f"{r.estimate:.6f},{r.truth:.6f},{r.err_pct:.4f},"
                         f"{r.n_units},{m}")
        return "\n".join(lines)


def run_sweep(engine: ExperimentEngine, spec: SweepSpec) -> ResultsTable:
    """Execute one sweep; one batched dispatch per app over the requested
    configs (only those are simulated and ledger-charged)."""
    cfg_is = (tuple(range(len(engine.configs)))
              if spec.config_indices is None else spec.config_indices)
    rows: list[SweepRow] = []
    for name in spec.apps:
        exp = engine.app(name)
        if spec.scheme == "srs":
            mat = exp.cpi_for(exp.idx1, cfg_is)            # (C', n1)
            for pos, ci in enumerate(cfg_is):
                est = srs_estimate(mat[pos])
                rows.append(SweepRow(
                    app=name, scheme="srs", config_index=ci,
                    estimate=est.mean, truth=float(exp.truth[ci]),
                    err_pct=100 * abs(est.mean - exp.truth[ci])
                    / exp.truth[ci],
                    n_units=exp.idx1.size, margin_pct=est.margin_pct))
            continue
        sel, weights = scheme_selection(exp, spec.scheme, spec.policy,
                                        seed=spec.selection_seed)
        ests = exp.weighted_cpi_all(sel, weights, config_indices=cfg_is)
        n_sel = int(sum(s.size for s in sel))
        for pos, ci in enumerate(cfg_is):
            rows.append(SweepRow(
                app=name, scheme=spec.scheme, config_index=ci,
                estimate=float(ests[pos]), truth=float(exp.truth[ci]),
                err_pct=float(100 * abs(ests[pos] - exp.truth[ci])
                              / exp.truth[ci]),
                n_units=n_sel, margin_pct=None))
    return ResultsTable(rows)
