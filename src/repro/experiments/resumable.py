"""Checkpointed, fault-tolerant drivers over ``run_sweep``/``run_trials``.

Long sweep campaigns are restartable batch jobs: this module adds
restart boundaries ("quanta") at the paths' natural grain and proves —
structurally, not probabilistically — that a killed-and-resumed run is
the same run:

* **Sweeps** (``run_sweep_resumable``): a quantum is one
  ``(app-block × config-block)`` sub-sweep executed by the ordinary
  ``run_sweep`` (fused or staged). Selection, fills and estimates are
  pure functions of ``(engine build, spec, block)``, and the memo bank
  charges misses only — so any blocking's union of fills equals the
  unblocked run's, and ledger totals are path-independent.
* **Trials** (``run_trials_resumable``): a quantum is one segment of
  scan chunks per scheme. PRNG blocks are pure functions of
  ``(seed, scheme, block, app)`` (the ``TRIAL_BLOCK`` contract in
  ``repro.experiments.montecarlo``), so the streaming program replays
  any chunk suffix via its ``chunk0`` offset; the additive ``TrialStats``
  segments merge exactly like the in-scan carry.

After every quantum the driver snapshots the ``MemoBank`` (mask+value
blocks, charge matrix, ledger totals, ``version``), the partial results
and the progress cursor through ``repro.runtime.checkpoint`` — written
atomically, validated manifest-first on restore. Restore ORDER matters:
the engine is rebuilt (deterministically re-paying its phase-1 fill),
then ``MemoBank.load_state`` OVERWRITES all accounting with the
snapshot's, so nothing is double-charged and a resumed run's totals are
bitwise-equal to an uninterrupted one's.

The supervisors (``supervise_sweep``/``supervise_trials``) wrap a driver
in the elastic retry loop: catch ``HostLoss`` (real or injected via
``repro.runtime.faults``), shrink the device pool, re-plan the
``("app",)`` / ``("app", "trial")`` mesh (``repro.runtime.elastic``),
rebuild the engine, restore the latest checkpoint and continue — with
``repro.runtime.health.QuantumHealth`` recording per-quantum wall times
for the ``FleetReport`` postmortem.

Equivalence discipline (tests/test_fault_tolerance.py): killed/resumed
vs uninterrupted runs of the same blocking are bitwise-identical in
estimates, ledger charge totals and every ``TrialStats`` leaf. Across
*different* blockings (resumable vs plain, or an elastic re-mesh), the
integer leaves stay bitwise and float moment sums agree to summation
order; dense per-trial arrays are bitwise across chunkings of the same
dispatch (the PRNG block contract) but a re-mesh can refuse XLA's
per-trial arithmetic at the ULP level when the per-device block count
degenerates to one. Selection policies that consume
host-side randomness (``random``/``rankedset``) draw per app-block, so
their picks are deterministic given ``(seed, blocking)`` but differ
from an unblocked run — the paper matrix's deterministic policies
(``centroid``/``mean``) are blocking-invariant.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from ..core.sampling import tables as sampling_tables
from ..runtime.checkpoint import (latest_step, restore_checkpoint,
                                  save_checkpoint)
from ..runtime.elastic import ElasticRunner, build_mesh
from ..runtime.faults import FaultPlan, HostLoss
from ..runtime.health import QuantumHealth
from ..simcpu import APP_NAMES
from .engine import ExperimentEngine
from .montecarlo import (_KEEP_TRIALS_MAX, TRIAL_BLOCK, TrialResult,
                         TrialSpec, _chunk_blocks, _scheme_setup,
                         _streaming_program, _trim_streaming_out, trial_key)
from .sweep import ResultsTable, SweepRow, SweepSpec, run_sweep

__all__ = ["FleetReport", "run_sweep_resumable", "run_trials_resumable",
           "supervise_sweep", "supervise_trials"]


def _trial_axis_size(mesh) -> int:
    if mesh is None:
        return 1
    from ..distributed.appaxis import app_trial_axes
    _, trial_axis = app_trial_axes(mesh)
    return 1 if trial_axis is None else int(mesh.shape[trial_axis])


# ------------------------------------------------------------------ sweeps
def run_sweep_resumable(engine: ExperimentEngine, spec: SweepSpec,
                        directory, *, app_block: int = 1,
                        config_block: Optional[int] = None,
                        injector=None, mesh=None,
                        monitor: Optional[Callable] = None,
                        keep: int = 3) -> ResultsTable:
    """``run_sweep`` with restart boundaries at app/config blocks.

    The sweep's (apps × configs) grid is partitioned into quanta of
    ``app_block`` apps × ``config_block`` configs (default: all configs
    per quantum); each quantum runs through the ordinary ``run_sweep``
    (fused or staged per ``spec.fused``) and is followed by one atomic
    checkpoint of the memo bank + partial result matrices + cursor into
    ``directory``. If ``directory`` already holds a checkpoint for the
    SAME run identity (scheme, policy, apps, configs, seeds, blocking —
    validated manifest-first), execution resumes at the saved cursor;
    a different identity raises ``ManifestMismatch`` before loading.

    ``injector`` is a ``repro.runtime.faults.FaultInjector`` threaded
    through the quantum lifecycle; ``monitor(quantum, seconds)`` feeds
    the supervisor's health trace. Returns the same ``ResultsTable`` an
    uninterrupted ``run_sweep`` of this blocking produces.
    """
    if spec.trials is not None:
        raise ValueError(
            "run_sweep_resumable checkpoints the sweep grid only; run the "
            "Monte-Carlo study through run_trials_resumable")
    mesh = engine.mesh if mesh is None else mesh
    apps = tuple(spec.apps)
    cfg_is = (tuple(range(len(engine.configs)))
              if spec.config_indices is None
              else tuple(int(i) for i in spec.config_indices))
    a_n, c_n = len(apps), len(cfg_is)
    ab = max(1, int(app_block))
    cb = c_n if config_block is None else max(1, int(config_block))
    quanta = [(a0, min(a0 + ab, a_n), c0, min(c0 + cb, c_n))
              for a0 in range(0, a_n, ab) for c0 in range(0, c_n, cb)]

    exps = engine.build(apps)                   # deterministic rebuild
    # fix the memo's config axis up front so every checkpoint in this
    # run (and its resumed continuations) has congruent table shapes
    engine.memo.cols_for(tuple(engine.configs[i] for i in cfg_is))
    truth = np.stack([e.truth for e in exps])[:, list(cfg_is)]

    run_id = {"kind": "sweep", "scheme": spec.scheme,
              "policy": spec.policy, "apps": list(apps),
              "config_indices": list(cfg_is),
              "selection_seed": int(spec.selection_seed),
              "fused": bool(spec.fused),
              "app_block": ab, "config_block": cb}

    ests = np.full((a_n, c_n), np.nan)
    errs = np.full((a_n, c_n), np.nan)
    margins = np.full((a_n, c_n), np.nan)
    n_units = np.zeros(a_n, np.int64)

    def snapshot():
        tree, meta = engine.memo.state()
        return {"memo": tree,
                "results": {"ests": ests, "errs": errs,
                            "margins": margins, "n_units": n_units}}, meta

    start = 0
    if latest_step(directory) is not None:
        template, _ = snapshot()
        tree, extra = restore_checkpoint(directory, template,
                                         expect={"run": run_id})
        engine.memo.load_state(tree["memo"], extra["memobank"],
                               universe=engine.configs)
        res = tree["results"]
        ests, errs = res["ests"], res["errs"]
        margins, n_units = res["margins"], res["n_units"]
        start = int(extra["next_quantum"])
    if injector is not None:
        injector.on_resume(start)

    for q in range(start, len(quanta)):
        t0 = time.perf_counter()
        a0, a1, c0, c1 = quanta[q]
        sub = dataclasses.replace(spec, apps=apps[a0:a1],
                                  config_indices=cfg_is[c0:c1])
        table = run_sweep(engine, sub, mesh=mesh)
        for i in range(a1 - a0):
            for j in range(c1 - c0):
                row = table.rows[i * (c1 - c0) + j]
                ests[a0 + i, c0 + j] = row.estimate
                errs[a0 + i, c0 + j] = row.err_pct
                if row.margin_pct is not None:
                    margins[a0 + i, c0 + j] = row.margin_pct
                n_units[a0 + i] = row.n_units
        if injector is not None:
            injector.quantum_computed()
        tree, meta = snapshot()
        save_checkpoint(directory, q, tree,
                        extra={"run": run_id, "memobank": meta,
                               "next_quantum": q + 1},
                        keep=keep,
                        fault_hook=None if injector is None
                        else injector.hook)
        if monitor is not None:
            monitor(q, time.perf_counter() - t0)
        if injector is not None:
            injector.quantum_checkpointed()

    srs = spec.plan is None
    rows = []
    for a, name in enumerate(apps):
        for j, cix in enumerate(cfg_is):
            rows.append(SweepRow(
                app=name, scheme=spec.scheme, config_index=int(cix),
                estimate=float(ests[a, j]), truth=float(truth[a, j]),
                err_pct=float(errs[a, j]), n_units=int(n_units[a]),
                margin_pct=float(margins[a, j]) if srs else None))
    return ResultsTable(rows)


# ------------------------------------------------------------------ trials
def run_trials_resumable(engine: ExperimentEngine,
                         spec: TrialSpec, directory, *,
                         apps: Optional[Sequence[str]] = None,
                         segment_trials: Optional[int] = None,
                         injector=None, mesh=None,
                         monitor: Optional[Callable] = None,
                         keep: int = 3) -> TrialResult:
    """``run_trials`` with restart boundaries at chunk segments.

    A quantum is one (scheme, chunk-segment) cell: ``segment_trials``
    trials' worth of scan chunks (default: the scheme's whole run in one
    quantum), executed by the shared streaming program with its
    ``chunk0`` offset — the PRNG-block contract makes the replayed
    chunks bitwise-identical to the same chunks of an uninterrupted
    scan. Segment ``TrialStats`` merge additively into the running
    accumulator (integer leaves exact; float moments associate by
    segment, identically in every resumed replay of the same blocking);
    dense per-trial arrays (when kept) slot into their trial range
    unchanged. Checkpoints carry accumulator + dense partials + memo
    bank + cursor, atomically, manifest-validated; ``injector`` /
    ``monitor`` follow ``run_sweep_resumable``.
    """
    apps = tuple(apps or APP_NAMES)
    mesh = engine.mesh if mesh is None else mesh
    # blocking is part of the run identity, so it must NOT depend on the
    # attempt's mesh (an elastic re-mesh would otherwise change the
    # quantum grid and refuse its own checkpoints): derive it
    # mesh-independently, and shard the trial axis only when it divides
    # the blocking — otherwise this attempt dispatches unsharded, which
    # is bitwise-equal (the chunked == unchunked contract), just slower
    kb, n_chunks = _chunk_blocks(spec, 1)
    ntd = _trial_axis_size(mesh)
    prog_mesh = mesh if (mesh is None or kb % max(ntd, 1) == 0) else None
    keep_dense = (spec.keep_trials if spec.keep_trials is not None
                  else spec.trials <= _KEEP_TRIALS_MAX)
    seg_chunks = (n_chunks if segment_trials is None
                  else max(1, -(-int(segment_trials) // (kb * TRIAL_BLOCK))))
    segments = [(c0, min(seg_chunks, n_chunks - c0))
                for c0 in range(0, n_chunks, seg_chunks)]
    quanta = [(scheme, c0, nc)
              for scheme in spec.schemes for (c0, nc) in segments]

    truth, pp, setups = _scheme_setup(engine, spec, apps, mesh, None)
    tdt = pp.trace_dtype
    a_n = len(apps)
    app_ids = np.arange(a_n, dtype=np.int32)
    t_pad = n_chunks * kb * TRIAL_BLOCK

    run_id = {"kind": "trials", "apps": list(apps),
              "schemes": list(spec.schemes), "trials": int(spec.trials),
              "units_per_trial": int(spec.units_per_trial),
              "config_index": int(spec.config_index),
              "seed": int(spec.seed), "confidence": float(spec.confidence),
              "precision": [str(pp.trace), str(pp.accum)],
              "kb": int(kb), "seg_chunks": int(seg_chunks),
              "keep": bool(keep_dense)}

    stats = {s: sampling_tables.trial_stats_init(
        (a_n,), accum_dtype=np.dtype(pp.accum), xp=np)
        for s in spec.schemes}
    dense = ({s: {"est": np.zeros((a_n, t_pad), tdt),
                  "err": np.zeros((a_n, t_pad), tdt),
                  "half": np.zeros((a_n, t_pad), tdt)}
              for s in spec.schemes} if keep_dense else None)

    def snapshot():
        tree, meta = engine.memo.state()
        out = {"memo": tree, "stats": stats}
        if dense is not None:
            out["dense"] = dense
        return out, meta

    start = 0
    if latest_step(directory) is not None:
        template, _ = snapshot()
        tree, extra = restore_checkpoint(directory, template,
                                         expect={"run": run_id})
        engine.memo.load_state(tree["memo"], extra["memobank"],
                               universe=engine.configs)
        stats = tree["stats"]
        dense = tree.get("dense", dense)
        start = int(extra["next_quantum"])
    if injector is not None:
        injector.on_resume(start)

    for q in range(start, len(quanta)):
        t0 = time.perf_counter()
        scheme, c0, nc = quanta[q]
        chunk_fn, draws, crit, tables = setups[scheme]
        program = _streaming_program(
            chunk_fn, prog_mesh, kb=kb, n_chunks=nc, trials=spec.trials,
            draws=draws, trace=pp.trace, accum=pp.accum, keep=keep_dense)
        with pp.x64_context():
            st, ys = program(trial_key(spec, scheme), np.int32(c0),
                             app_ids, truth.astype(tdt), crit, *tables)
            if prog_mesh is None:
                st, ys = _trim_streaming_out((st, ys), a_n)
        st = jax.tree.map(np.asarray, st)
        stats[scheme] = sampling_tables.trial_stats_merge(stats[scheme], st)
        if keep_dense:
            off = c0 * kb * TRIAL_BLOCK
            for name, y in zip(("est", "err", "half"), ys):
                arr = np.asarray(y).transpose(1, 0, 2).reshape(a_n, -1)
                dense[scheme][name][:, off:off + arr.shape[1]] = arr
        if injector is not None:
            injector.quantum_computed()
        tree, meta = snapshot()
        save_checkpoint(directory, q, tree,
                        extra={"run": run_id, "memobank": meta,
                               "next_quantum": q + 1},
                        keep=keep,
                        fault_hook=None if injector is None
                        else injector.hook)
        if monitor is not None:
            monitor(q, time.perf_counter() - t0)
        if injector is not None:
            injector.quantum_checkpointed()

    estimates, errors, halves = {}, {}, {}
    if keep_dense:
        for s in spec.schemes:
            estimates[s] = dense[s]["est"][:, :spec.trials]
            errors[s] = dense[s]["err"][:, :spec.trials]
            halves[s] = dense[s]["half"][:, :spec.trials]
    return TrialResult(apps=apps, spec=spec, stats=dict(stats),
                       estimates=estimates, errors=errors,
                       half_widths=halves)


# -------------------------------------------------------------- supervisor
@dataclasses.dataclass
class FleetReport:
    """Postmortem of one supervised (elastic, fault-tolerant) run.

    ``attempts`` records each driver attempt (device count, mesh shape,
    outcome); ``mesh_history`` the elastic re-plans; ``quanta`` /
    ``stragglers`` the per-quantum health trace from ``QuantumHealth``.
    """

    attempts: list
    mesh_history: list
    quanta: list
    stragglers: list

    @property
    def restarts(self) -> int:
        """Restart count: attempts beyond the first."""
        return max(0, len(self.attempts) - 1)


def _supervise(run_attempt, *, faults: Optional[FaultPlan],
               max_restarts: int, mesh_kind: str, app_devices: int = 1,
               devices: Optional[Sequence] = None):
    """The elastic retry loop shared by both supervisors.

    Each attempt plans a mesh over the current healthy pool, builds it
    on those devices explicitly, and calls ``run_attempt(mesh, injector,
    monitor)``. A ``HostLoss`` (injected or real) shrinks the pool by
    ``devices_lost`` (never below 1) and retries — the driver's
    checkpoint restore plus the re-mesh invariant (app/trial lanes are
    pure data parallelism; global work is unchanged) carry the run
    forward. One injector spans all attempts so each planned fault fires
    exactly once.
    """
    pool = list(jax.devices() if devices is None else devices)
    injector = None if faults is None else faults.injector()
    runner = ElasticRunner(mesh_kind=mesh_kind, app_devices=app_devices)
    health = QuantumHealth()
    attempts: list[dict] = []
    for attempt in range(max_restarts + 1):
        n = len(pool)
        if n > 1:
            plan = runner.on_pool_change(n)
            mesh = build_mesh(plan, pool)
            shape = tuple(plan.shape)
        else:
            # a single device needs no mesh: the engine paths treat
            # mesh=None as the (bitwise-equal) unsharded dispatch
            mesh, shape = None, (1,)
            runner.history.append({"n_devices": 1, "shape": shape})
        record = {"attempt": attempt, "n_devices": n, "mesh_shape": shape}
        try:
            result = run_attempt(mesh, injector, health.record)
            record["outcome"] = "completed"
            attempts.append(record)
            return result, FleetReport(attempts=attempts,
                                       mesh_history=list(runner.history),
                                       quanta=list(health.quanta),
                                       stragglers=list(health.stragglers))
        except HostLoss as loss:
            record["outcome"] = "host_loss"
            record["error"] = str(loss)
            attempts.append(record)
            lost = max(0, int(loss.devices_lost))
            pool = pool[:max(1, n - lost)]
    raise RuntimeError(
        f"supervised run did not complete within {max_restarts} restarts")


def supervise_sweep(make_engine: Callable, spec: SweepSpec, directory, *,
                    faults: Optional[FaultPlan] = None, app_block: int = 1,
                    config_block: Optional[int] = None,
                    max_restarts: int = 8, keep: int = 3,
                    devices: Optional[Sequence] = None
                    ) -> tuple[ResultsTable, FleetReport]:
    """Run a checkpointed sweep under the elastic supervisor.

    ``make_engine(mesh)`` builds a fresh ``ExperimentEngine`` for each
    attempt's mesh (engines are rebuilt, state comes from the checkpoint
    in ``directory``); ``faults`` optionally injects a deterministic
    failure schedule. Returns ``(ResultsTable, FleetReport)``.
    """
    def attempt(mesh, injector, monitor):
        engine = make_engine(mesh)
        return run_sweep_resumable(
            engine, spec, directory, app_block=app_block,
            config_block=config_block, injector=injector, mesh=mesh,
            monitor=monitor, keep=keep)
    return _supervise(attempt, faults=faults, max_restarts=max_restarts,
                      mesh_kind="app", devices=devices)


def supervise_trials(make_engine: Callable, spec: TrialSpec, directory, *,
                     apps: Optional[Sequence[str]] = None,
                     faults: Optional[FaultPlan] = None,
                     segment_trials: Optional[int] = None,
                     max_restarts: int = 8, app_devices: int = 1,
                     keep: int = 3, devices: Optional[Sequence] = None
                     ) -> tuple[TrialResult, FleetReport]:
    """Run a checkpointed Monte-Carlo study under the elastic supervisor.

    Same contract as ``supervise_sweep`` over ``run_trials_resumable``;
    the mesh re-plans as 2-D ``("app", "trial")`` with the app degree
    held at ``app_devices`` while the trial axis absorbs pool shrink.
    Returns ``(TrialResult, FleetReport)``.
    """
    def attempt(mesh, injector, monitor):
        engine = make_engine(mesh)
        return run_trials_resumable(
            engine, spec, directory, apps=apps,
            segment_trials=segment_trials, injector=injector, mesh=mesh,
            monitor=monitor, keep=keep)
    return _supervise(attempt, faults=faults, max_restarts=max_restarts,
                      mesh_kind="app_trial", app_devices=app_devices,
                      devices=devices)
