"""The fused sweep megaprogram: selection → memo fill → estimates, ONE
dispatch.

The staged sweep path runs four host-synchronized stages per sweep —
``plan_selection_bank`` (selection), ``MemoBank.fill`` (miss-only CPI
fill), ``StratumTables`` construction, and the estimator's jitted
reduction — and at paper scale the launch overhead between them swamps
the device work. This module fuses the whole pipeline into one jitted
program per ``SamplingPlan`` shape:

* the selection context is built **in-trace** (``build_selection_context``
  is namespace-agnostic; the stratum summary routes through the same
  ``segment_stats`` kernel contract the staged path uses),
* the policy's picks drive an in-trace miss-only memo update — the memo
  mask/value blocks enter as **donated buffers** (``donate_argnums``) so
  the update is in-place where the backend supports it,
* the selected-unit CPI gathers straight out of the updated block and
  flows into ``Estimator.estimate_stage`` (the same traceable stage the
  staged jitted program calls), so the two paths cannot drift.

Only O(apps × configs × strata) selected-unit results come home with
the estimates — the updated (A, C, N) blocks stay device-side, aliased
to the donated inputs — and are folded back into the host ``MemoBank``
via ``absorb_selected``; ledger charge totals are bitwise identical to
the staged path's ``fill``. Random selection policies pre-draw their
uniforms on the host with the staged rng sequence (``uses_uniforms``),
so fused picks equal staged picks exactly.

Programs are cached per ``(plan, precision policy, mesh)``; under an
``("app",)`` mesh the program is ``shard_map``-ped over the app axis
with the config matrix replicated, and padding rows are trimmed before
any memo write-back so sharded accounting matches single-device.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.precision import PrecisionPolicy, resolve_precision
from ..core.sampling import plan as sampling_plan
from ..simcpu.perfmodel import _cpi_bank_fn, config_matrix

__all__ = ["fused_sweep_program", "run_fused_sweep"]

# positions of the donated memo blocks in the traced signature below
_DONATE = (11, 12)
# position of the replicated config matrix under an app mesh
_REPLICATED = frozenset({9})

# device-resident uploads of per-sweep-constant host arrays, keyed by
# object identity + trace dtype (the held reference keeps the id valid).
# ``stratifier.resolve`` and ``engine.stack`` are cached, so repeated
# sweeps see the same host objects and skip the host->device copies that
# otherwise dominate the warm driver time.
_DEV_CACHE: dict = {}


def _dev_bank_arrays(bank, dt, x64: bool):
    """The StratumBank's seven traced inputs, uploaded once per bank."""
    key = (id(bank), np.dtype(dt).name, x64)
    hit = _DEV_CACHE.get(key)
    if hit is not None and hit[0] is bank:
        return hit[1]
    arrs = (jnp.asarray(bank.labels), jnp.asarray(bank.valid),
            jnp.asarray(bank.weights, dt), jnp.asarray(bank.baseline),
            None if bank.pool is None else jnp.asarray(bank.pool),
            None if bank.feats is None else jnp.asarray(bank.feats),
            None if bank.centroids is None else jnp.asarray(bank.centroids))
    _DEV_CACHE[key] = (bank, arrs)
    return arrs


def _dev_feats(feats, x64: bool):
    """The stacked population features, uploaded once per stack."""
    key = (id(feats), "feats", x64)
    hit = _DEV_CACHE.get(key)
    if hit is not None and hit[0] is feats:
        return hit[1]
    arr = jnp.asarray(feats)
    _DEV_CACHE[key] = (feats, arr)
    return arr


# device-resident memo blocks, chained through donation: each fused
# sweep CONSUMES the previous sweep's output blocks (donated in, updated
# in place, emitted as outputs) so warm re-sweeps skip the host block
# checkout + upload entirely. One entry per MemoBank, keyed by the
# bank's ``version`` counter — any host-side table mutation (a staged
# ``fill``, a ``merge``, growth, or an explicit ``touch()``) invalidates
# it and the next sweep re-checks out via ``donation_block``.
_BLOCK_CACHE: dict = {}


def _checkout_blocks(memo, rows, cfgs):
    """(mask, cpi, cols) for the dispatch: cached device blocks when the
    bank is unchanged since the last fused sweep, else a fresh host
    checkout. The cache entry is REMOVED here — the blocks are about to
    be donated — and re-stamped by the caller after absorb."""
    cols = memo.cols_for(cfgs)
    rows_key = tuple(np.asarray(rows, np.int64).tolist())
    cols_key = tuple(cols.tolist())
    hit = _BLOCK_CACHE.get(id(memo))
    if (hit is not None and hit[0] is memo and hit[1] == rows_key
            and hit[2] == cols_key and hit[3] == memo.version):
        del _BLOCK_CACHE[id(memo)]
        return hit[4], hit[5], cols, rows_key, cols_key
    mask_blk, cpi_blk, cols = memo.donation_block(rows, cfgs)
    return mask_blk, cpi_blk, cols, rows_key, cols_key


@functools.lru_cache(maxsize=None)
def _dev_config_matrix(cfgs):
    """float32 device config matrix, built once per config tuple.

    Pinned to float32 OUTSIDE any x64 context: the perf model is float32
    by contract, and an f64 matrix would promote the in-trace CPI
    evaluation away from the staged ``cpi_bank`` dispatch's ulps.
    """
    mat = config_matrix(cfgs)
    return jnp.asarray(mat, jnp.float32)  # jaxlint: disable=JL003


def _traced_summarize(labels, valid, num_strata, values, precision=None):
    """In-trace mirror of ``engine._segment_sums_counts``: same
    ``segment_stats`` kernel contract, same ``PrecisionPolicy`` dtypes,
    but traceable (no eager dispatch, no host round-trip)."""
    from ..kernels.segment_stats.ops import segment_stats

    pp = resolve_precision(precision)
    lab = jnp.where(valid, labels, -1).astype(jnp.int32)
    sums, _, counts = segment_stats(jnp.asarray(values, pp.trace_dtype),
                                    lab, num_strata, precision=pp)
    return (sums[..., 0].astype(pp.host_dtype),
            counts.astype(pp.host_dtype))


def _make_traced(plan: sampling_plan.SamplingPlan):
    """The full selection→fill→estimate trace for one plan.

    Positional signature (optional arrays pass ``None`` — a static
    empty-pytree branch under ``jit``): ``labels, valid_units, weights,
    baseline, pool, feats_sel, cents, uniforms, feats_pop, cm, truth,
    mask_blk, cpi_blk`` with ``mask_blk``/``cpi_blk`` donated.
    """

    def traced(labels, valid_units, weights, baseline, pool, feats_sel,
               cents, uniforms, feats_pop, cm, truth, mask_blk, cpi_blk):
        bank = sampling_plan.StratumBank(
            labels=labels, valid=valid_units, weights=weights,
            baseline=baseline, feats=feats_sel, centroids=cents, pool=pool)
        ctx = sampling_plan.build_selection_context(
            bank, summarize=_traced_summarize, uniforms=uniforms)
        local = plan.policy(ctx)
        # barrier: without it XLA may fuse the fill/estimator stages
        # backward into the policy's distance/argmin subgraph, changing
        # its rounding (FMA contraction) and flipping near-tie picks vs
        # the staged eager selection — picks must be program-shape
        # independent
        local, counts = jax.lax.optimization_barrier((local, ctx.counts))
        valid_sel = counts > 0
        picks = local if pool is None \
            else jnp.take_along_axis(pool, local, axis=1)
        picks = jnp.where(valid_sel, picks, 0)

        a_n, n_strata = picks.shape
        c_n = cm.shape[0]
        n_memo = mask_blk.shape[-1]
        # miss-only fill, mirroring MemoBank.fill's dense-request
        # accounting: duplicate picks dedup through the request scatter,
        # invalid picks scatter to the out-of-range sentinel and drop
        safe = jnp.where(valid_sel, picks, n_memo)
        req = jnp.zeros((a_n, n_memo), bool).at[
            jnp.arange(a_n)[:, None], safe].set(True, mode="drop")
        miss = req[:, None, :] & ~mask_blk
        n_miss = miss.sum(axis=2)

        gfeats = jnp.take_along_axis(
            feats_pop, jnp.minimum(picks, feats_pop.shape[1] - 1)[:, :, None],
            axis=1)
        computed = _cpi_bank_fn(gfeats, cm)            # (A, C, L) float32
        # everything below stays O(A*C*L): gather the stored values and
        # miss flags at the picked columns, select computed-vs-stored,
        # and write the selected column back into the DONATED block
        # in-place (hits rewrite their stored value — a no-op — and
        # invalid picks hit the out-of-range sentinel and drop)
        picks_b = jnp.broadcast_to(picks[:, None, :], (a_n, c_n, n_strata))
        stored = jnp.take_along_axis(cpi_blk, picks_b, axis=2)
        miss_sel = jnp.take_along_axis(miss, picks_b, axis=2)
        cpi_sel = jnp.where(miss_sel, computed, stored)
        new_cpi = cpi_blk.at[
            jnp.arange(a_n)[:, None, None],
            jnp.arange(c_n)[None, :, None],
            jnp.broadcast_to(safe[:, None, :], (a_n, c_n, n_strata))].set(
                cpi_sel, mode="drop")
        new_mask = mask_blk | miss

        est, err = plan.estimator.estimate_stage(
            cpi_sel.astype(truth.dtype), valid_sel,
            weights.astype(truth.dtype), truth)
        return (est, err, valid_sel, picks, n_miss, miss_sel, cpi_sel,
                new_mask, new_cpi)

    return traced


@functools.lru_cache(maxsize=None)
def fused_sweep_program(plan: sampling_plan.SamplingPlan,
                        precision: PrecisionPolicy, mesh=None):
    """The jitted (optionally app-sharded) megaprogram for one plan.

    Cached per ``(plan, precision, mesh)`` — the plan fixes the traced
    selection/estimator code, the policy fixes the trace dtypes, and
    ``jit`` itself re-specializes per input shape, so one cache entry
    serves every sweep with the same plan. The memo mask/value blocks
    (last two arguments) are donated.
    """
    traced = _make_traced(plan)
    if mesh is None:
        return jax.jit(traced, donate_argnums=_DONATE)

    from ..distributed.appaxis import (app_trial_axes, pad_app_axis,
                                       shard_map)
    from jax.sharding import PartitionSpec as P

    axis, _ = app_trial_axes(mesh)
    n_dev = int(mesh.shape[axis])
    in_specs = tuple(P() if i in _REPLICATED else P(axis)
                     for i in range(13))
    prog = jax.jit(shard_map(traced, mesh=mesh, in_specs=in_specs,
                             out_specs=P(axis), check_rep=False),
                   donate_argnums=_DONATE)

    def call(*args):
        a_size = np.shape(args[0])[0]
        padded = tuple(
            a if (i in _REPLICATED or a is None) else pad_app_axis(a, n_dev)
            for i, a in enumerate(args))
        out = prog(*padded)
        # trim padding BEFORE any write-back: duplicate edge rows never
        # reach the host MemoBank, so sharded accounting == single-device
        return jax.tree.map(lambda o: o[:a_size], out)

    return call


def run_fused_sweep(engine, spec, exps, stack, cfgs, truth, mesh=None):
    """Drive one fused sweep: resolve the plan's ``StratumBank``, check
    out the memo blocks under the donation contract, dispatch the
    megaprogram once, and absorb the selected-unit results + miss counts
    back into the host ``MemoBank`` (ledger totals
    bitwise-staged-identical).

    Returns ``(ests, errs, valid, weights)`` — percent errors included,
    all host numpy — and records the ``fused=True`` dispatch marker
    (``sampling_plan.last_sweep_dispatch``).
    """
    plan = spec.plan
    bank = plan.stratifier.resolve(exps)
    a_n, n_strata = bank.weights.shape
    pp = resolve_precision(engine.precision, PrecisionPolicy.host_parity())
    dt = pp.trace_dtype
    uniforms = None
    if plan.policy.uses_uniforms:
        # the staged policy's exact rng sequence (first draw from the
        # selection seed), so fused picks == staged picks bit-for-bit
        uniforms = np.random.default_rng(spec.selection_seed).random(
            (a_n, n_strata))
    if mesh is None:
        mask_blk, cpi_blk, cols, rows_key, cols_key = _checkout_blocks(
            engine.memo, stack.rows, cfgs)
    else:
        # sharded runs keep the per-sweep checkout: their outputs are
        # trimmed/padded views whose chaining isn't worth the bookkeeping
        mask_blk, cpi_blk, cols = engine.memo.donation_block(
            stack.rows, cfgs)
    cm = _dev_config_matrix(cfgs)
    prog = fused_sweep_program(plan, pp, mesh)
    with pp.x64_context():
        mask_dev = jnp.asarray(mask_blk)
        cpi_dev = jnp.asarray(cpi_blk)
        args = _dev_bank_arrays(bank, dt, pp.needs_x64) + (
            None if uniforms is None else jnp.asarray(uniforms, dt),
            _dev_feats(stack.feats, pp.needs_x64), cm,
            jnp.asarray(truth, dt), mask_dev, cpi_dev)
        with warnings.catch_warnings():
            # CPU XLA may decline donation; correctness is unaffected
            # (the donated flag in the dispatch marker records it)
            warnings.filterwarnings(
                "ignore", message=".*donated buffers were not usable.*")
            (est, err, valid_sel, picks, n_miss, miss_sel, cpi_sel,
             _new_mask, _new_cpi) = prog(*args)
        # only the O(A*C*L) selected-unit results come home; the updated
        # (A, C, N) block outputs stay device-side (aliased to the
        # donated inputs) and are dropped — the host MemoBank mirror
        # advances from the selected results below
        est, err = np.asarray(est), np.asarray(err)
        valid = np.asarray(valid_sel)
        picks, n_miss = np.asarray(picks), np.asarray(n_miss)
        miss_sel, cpi_sel = np.asarray(miss_sel), np.asarray(cpi_sel)
    donated = bool(mask_dev.is_deleted() and cpi_dev.is_deleted())
    engine.memo.absorb_selected(stack.rows, cols, picks, miss_sel, cpi_sel,
                                n_miss,
                                requested=valid.sum(axis=1) * len(cfgs))
    if mesh is None:
        # the program's output blocks hold exactly the post-absorb table
        # content: stamp them with the post-absorb version so the next
        # fused sweep over the same rows/configs skips the checkout
        _BLOCK_CACHE[id(engine.memo)] = (
            engine.memo, rows_key, cols_key, engine.memo.version,
            _new_mask, _new_cpi)
    sampling_plan._record_sweep_dispatch(
        batch_shape=(a_n, len(cfgs)), num_strata=n_strata,
        x64=pp.needs_x64, backend=jax.default_backend(),
        fused=True, donated=donated)
    return est, err, valid, np.asarray(bank.weights)
