"""App-sharded batched experiment engine over the simulation substrate.

The engine treats the application axis as a data-parallel array dimension:
``build(names)`` stacks every requested app's population into one
``(A, N, F)`` device array (``PopulationBank``) and runs each build phase
as ONE batched-over-app program —

* census ground truth: ``cpi_bank`` vmapped over (app, config, region);
* BBV projection + k-means: ``random_project``/``kmeans_bank`` vmapped
  over the app axis with zero-weight padding rows;
* phase-1 SRS measurement: one ``rfv_bank`` dispatch for all apps'
  phase-1 samples, charged through the shared ``MemoBank``;
* RFV standardization + k-means: masked batched z-scoring + weighted
  ``kmeans_bank``.

With a 1-D ``("app",)`` mesh (``repro.launch.mesh.make_app_mesh``) each of
those programs is ``shard_map``-ped so apps run device-parallel; the
single-device path is the default and produces identical results (lanes
never communicate). Dalenius-Gurney stratification stays a host-side
scalar algorithm per app (it is an iterative boundary search on a few
thousand values, not a device program).

Per-app state is exposed exactly as before through ``AppExperiment`` — a
view slicing the stacked arrays back to one app — so figure code keeps
reading ``exp.bbv_labels`` etc. while sweeps and Monte-Carlo trials use
the stacked arrays directly.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional, Sequence

import jax
import numpy as np

from ..core.clustering import kmeans_bank, kmeans_batch, random_project
from ..core.sampling import dalenius_gurney_strata, draw_srs
from ..core.sampling import plan as sampling_plan
from ..simcpu import (APP_NAMES, CONFIGS, CachedSimulator, MemoBank,
                      config_matrix, cpi_bank, get_population_bank,
                      make_simulator, rfv_bank, stack_ragged)

NUM_STRATA = 20
PHASE1_SEED = 42

__all__ = [
    "NUM_STRATA", "PHASE1_SEED", "AppExperiment", "SweepStack",
    "ExperimentEngine", "stratum_tables",
    "plan_selection", "plan_selection_bank",
    "scheme_selection", "scheme_selection_bank",
]


@dataclasses.dataclass
class AppExperiment:
    """Per-application view shared by every figure/sweep."""

    name: str
    sim: CachedSimulator
    configs: tuple                # the sweep's config axis
    truth: np.ndarray             # (C,) census mean CPI per config
    census_mat: np.ndarray        # (C, N) census CPI (analysis-only)
    # BBV stratification (census, SimPoint-style)
    bbv_labels: np.ndarray        # (N,)
    bbv_weights: np.ndarray       # (L,)
    bbv_feats: np.ndarray         # projected (N, 15)
    bbv_centroids: np.ndarray
    # phase-1 sample + RFV stratification
    idx1: np.ndarray
    cpi0_1: np.ndarray            # baseline CPI of phase-1 units
    rfv_z: np.ndarray             # standardized RFVs of phase-1 units
    rfv_labels: np.ndarray
    rfv_weights: np.ndarray
    rfv_centroids: np.ndarray
    # Dalenius-Gurney on baseline CPI (phase-1 sample)
    dg_labels: np.ndarray
    dg_weights: np.ndarray
    num_strata: int = NUM_STRATA

    def cpi(self, cfg_i: int, indices) -> np.ndarray:
        """(n,) CPI for one config, through the memo table."""
        return self.sim.simulate_cpi(indices, self.configs[cfg_i])

    def cpi_for(self, indices,
                config_indices: Optional[Sequence[int]] = None) -> np.ndarray:
        """(C', n) CPI for a config subset in one batched dispatch.

        Only the requested configs are simulated (and ledger-charged)."""
        cfgs = (self.configs if config_indices is None
                else tuple(self.configs[i] for i in config_indices))
        return self.sim.simulate_cpi_batch(indices, cfgs)

    def cpi_all(self, indices) -> np.ndarray:
        """(C, n) CPI across ALL configs in one batched dispatch."""
        return self.cpi_for(indices)

    def weighted_cpi_all(self, selected: Sequence[np.ndarray], weights,
                         *, config_indices: Optional[Sequence[int]] = None,
                         strict: bool = False) -> np.ndarray:
        """(C',) stratified weighted-mean CPI per config, one dispatch.

        ``selected``: per-stratum population index arrays (any count per
        stratum). Strata with no selected units renormalize the estimate
        by the covered weight — with the same warn/raise contract as
        ``weighted_point_estimate`` so the bias can't pass silently. When
        EVERY stratum is empty there is nothing to renormalize to: that
        raises under ``strict=True`` and otherwise warns and returns NaN
        estimates.
        """
        n_cfg = len(self.configs) if config_indices is None \
            else len(tuple(config_indices))
        weights = np.asarray(weights, np.float64)
        sel = [np.atleast_1d(np.asarray(s)) for s in selected]
        nonempty = [s for s in sel if s.size]
        if not nonempty:
            msg = ("every stratum selection is empty; no units to "
                   "estimate from")
            if strict:
                raise ValueError(msg)
            warnings.warn(msg, UserWarning, stacklevel=2)
            return np.full(n_cfg, np.nan)
        flat = np.concatenate(nonempty)
        seg = np.concatenate([np.full(s.size, h, np.int64)
                              for h, s in enumerate(sel) if s.size])
        counts = np.bincount(seg, minlength=len(sel))
        covered = float(weights[counts > 0].sum())
        total = float(weights.sum())
        if covered < total * (1.0 - 1e-6):
            msg = (f"selected units cover only {covered / total:.4f} of the "
                   "stratum weight; renormalizing biases the estimate "
                   "toward the covered strata")
            if strict:
                raise ValueError(msg)
            warnings.warn(msg, UserWarning, stacklevel=2)
        mat = self.cpi_for(flat, config_indices)
        w_per_unit = np.where(counts[seg] > 0,
                              weights[seg] / np.maximum(counts[seg], 1), 0.0)
        return (mat * w_per_unit[None, :]).sum(axis=1) / covered

    def census(self, cfg_i: int) -> np.ndarray:
        """(N,) census CPI of every region for config ``cfg_i``
        (analysis-only ground truth, never ledger-charged)."""
        return self.census_mat[cfg_i]


@dataclasses.dataclass(frozen=True)
class SweepStack:
    """Stacked per-app arrays backing the engine's batched dispatch paths."""

    names: tuple[str, ...]
    rows: np.ndarray            # (A,) MemoBank rows
    n_regions: np.ndarray       # (A,)
    feats: np.ndarray           # (A, N_max, F) float32 (zero-padded)
    region_mask: np.ndarray     # (A, N_max) bool
    idx1: np.ndarray            # (A, n1_max) phase-1 indices (padded)
    idx1_valid: np.ndarray      # (A, n1_max) bool

    @property
    def num_apps(self) -> int:
        """Number of apps (A) stacked in this view."""
        return len(self.names)

    def gather_feats(self, idx: np.ndarray) -> np.ndarray:
        """(A, K, F) features at per-app region indices (padding-safe)."""
        return self.feats[np.arange(len(self.names))[:, None], idx]


def _segment_sums_counts(labels: np.ndarray, valid: np.ndarray,
                         num_strata: int, values: np.ndarray,
                         precision=None) -> tuple[np.ndarray, np.ndarray]:
    """(A, L) per-stratum value sums AND counts over valid entries, from
    ONE batched ``segment_stats`` dispatch (the Pallas kernel on TPU, the
    jnp oracle elsewhere — ``repro.kernels.segment_stats``).

    This is the engine's stratum-summary hot path: every build/selection
    summarization (stratum weights, centroid targets, gather tables)
    routes through the same kernel contract the estimator tables use.
    Dtypes follow the ``PrecisionPolicy`` (``repro.core.precision``;
    default f32 trace / f64 host): the kernel computes in the trace
    dtype — counts are exact below 2^24 per stratum, and f32 value sums
    carry ~1e-7 relative rounding — so selection keys built from them
    (dg centroids, mean-policy targets, CI ordering keys) are
    trace-dtype-stable by design, not bit-equal to a float64 bincount.
    Results come home in the policy's host dtype.
    """
    from ..core.precision import resolve_precision
    from ..kernels.segment_stats.ops import segment_stats

    pp = resolve_precision(precision)
    lab = np.where(valid, labels, -1).astype(np.int32)
    with pp.x64_context():
        sums, _, counts = segment_stats(np.asarray(values, pp.trace_dtype),
                                        lab, num_strata, precision=pp)
    return (np.asarray(sums[..., 0], pp.host_dtype),
            np.asarray(counts, pp.host_dtype))


def _offset_bincount(labels: np.ndarray, valid: np.ndarray,
                     num_strata: int, weights=None) -> np.ndarray:
    """(A, L) per-app stratum counts — or weighted sums — over valid
    entries (one ``_segment_sums_counts`` dispatch)."""
    if weights is None:
        return _segment_sums_counts(labels, valid, num_strata,
                                    np.ones(labels.shape))[1]
    return _segment_sums_counts(labels, valid, num_strata, weights)[0]


def stratum_tables(labels: np.ndarray, valid: np.ndarray, num_strata: int,
                   counts: Optional[np.ndarray] = None
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-stratum gather tables for an (A, n) label stack.

    Returns ``(order, offsets, counts)``: stratum ``h`` of app ``a`` owns
    positions ``order[a, offsets[a, h] : offsets[a, h] + counts[a, h]]``,
    in index order (invalid entries sort last). Shared by vectorized
    selection and the Monte-Carlo trial engine so draw indexing can never
    drift between the two. Callers that already hold the stratum counts
    from a ``_segment_sums_counts`` dispatch pass them via ``counts`` to
    avoid a second dispatch. NOTE: for trailing empty strata ``offsets``
    equals the row width — gathers must clamp (empty strata are masked
    out of every consumer anyway)."""
    if counts is None:
        counts = _offset_bincount(labels, valid, num_strata)
    counts = np.asarray(counts).astype(np.int64)
    order = np.argsort(np.where(valid, labels, num_strata), axis=1,
                       kind="stable")
    offsets = np.cumsum(counts, axis=1) - counts
    return order, offsets, counts


class ExperimentEngine:
    """Builds ``AppExperiment`` state batched over apps; runs batched sweeps.

    ``mesh``: optional ``("app",)`` mesh — every batched build/sweep
    dispatch is then ``shard_map``-ped over the app axis — or a 2-D
    ``("app", "trial")`` mesh, which additionally splits Monte-Carlo
    trial chunks across the second axis (``run_trials``; build/sweep
    dispatches treat such a mesh as app-only). ``None`` (the default)
    runs the identical programs on one device.
    """

    @classmethod
    def auto(cls, **kwargs) -> "ExperimentEngine":
        """Engine with an ``("app",)`` mesh when >1 device is present —
        THE way examples/benchmarks pick up ``--devices N`` /
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
        if "mesh" not in kwargs:
            mesh = None
            if len(jax.devices()) > 1:
                from ..launch.mesh import make_app_mesh
                mesh = make_app_mesh()
            kwargs["mesh"] = mesh
        return cls(**kwargs)

    def __init__(self, *, configs: Sequence = CONFIGS,
                 num_strata: int = NUM_STRATA,
                 phase1_seed: int = PHASE1_SEED,
                 mesh=None, precision=None):
        self.configs = tuple(configs)
        self.num_strata = num_strata
        self.phase1_seed = phase1_seed
        self.mesh = mesh
        # engine-wide PrecisionPolicy override; None defers to each
        # pipeline's default (trials: DEFAULT_PRECISION, sweep estimates:
        # PrecisionPolicy.host_parity) — see repro.core.precision
        self.precision = precision
        self.memo = MemoBank()
        self._apps: dict[tuple[str, int], AppExperiment] = {}
        self._stacks: dict[tuple[tuple[str, ...], int], SweepStack] = {}

    def app(self, name: str, kmeans_seed: int = 0) -> AppExperiment:
        """The ``AppExperiment`` view for one app (built on demand)."""
        return self.build((name,), kmeans_seed)[0]

    def apps(self, names: Optional[Sequence[str]] = None
             ) -> list[AppExperiment]:
        """Views for ``names`` (default: all paper apps), built batched."""
        return self.build(tuple(names or APP_NAMES))

    def build(self, names: Sequence[str],
              kmeans_seed: int = 0) -> list[AppExperiment]:
        """Batched build: every not-yet-built app in ``names`` is
        constructed in ONE set of stacked-over-app programs."""
        names = tuple(names)
        todo = tuple(dict.fromkeys(
            n for n in names if (n, kmeans_seed) not in self._apps))
        if todo:
            self._build_stacked(todo, kmeans_seed)
        return [self._apps[(n, kmeans_seed)] for n in names]

    def stack(self, names: Sequence[str],
              kmeans_seed: int = 0) -> SweepStack:
        """Stacked view over (already built) apps for batched dispatches."""
        names = tuple(names)
        key = (names, kmeans_seed)
        if key not in self._stacks:
            exps = self.build(names, kmeans_seed)
            bank = get_population_bank(names)
            idx1, idx1_valid = stack_ragged([e.idx1 for e in exps])
            self._stacks[key] = SweepStack(
                names=names,
                rows=np.asarray([e.sim.row for e in exps], np.int64),
                n_regions=bank.n_regions, feats=bank.features,
                region_mask=bank.mask, idx1=idx1, idx1_valid=idx1_valid)
        return self._stacks[key]

    # ------------------------------------------------------------------ build
    def _build_stacked(self, names: tuple[str, ...], kmeans_seed: int) -> None:
        from ..simcpu import get_bbvs

        L = self.num_strata
        mesh = self.mesh
        bank = get_population_bank(names)
        a_n = bank.num_apps
        ar = np.arange(a_n)

        sims = []
        for name, pop in zip(names, bank.pops):
            base = make_simulator(name)
            row = self.memo.add_app(name, pop.n_regions, base.ledger)
            sims.append(CachedSimulator(base, bank=self.memo, row=row))

        # census ground truth for every config: one vmapped program
        # (analysis-only — free of charge, bypasses the charged memo)
        census = cpi_bank(bank.features, config_matrix(self.configs),
                          mesh=mesh)                       # (A, C, N)
        truth = np.where(bank.mask[:, None, :], census, 0.0).sum(
            axis=2, dtype=np.float64) / bank.n_regions[:, None]

        # SimPoint-style BBV stratification over the full populations
        bbvs, _ = stack_ragged([get_bbvs(p) for p in bank.pops],
                               dtype=np.float32)
        z = np.asarray(_project_bank(bbvs, mesh=mesh))     # (A, N, 15)
        bbv_fit = kmeans_bank(z, L, weights=bank.mask.astype(np.float32),
                              seed=kmeans_seed, mesh=mesh)
        bbv_counts = _offset_bincount(bbv_fit.labels, bank.mask, L)
        bbv_w = bbv_counts / bank.n_regions[:, None]

        # phase 1: SRS at the paper's Table II sizes, measured on config 0
        # as ONE stacked dispatch, charged through the shared memo bank
        idx1_list = [draw_srs(np.random.default_rng(self.phase1_seed),
                              pop.n_regions, pop.spec.phase1_n)
                     for pop in bank.pops]
        idx1, idx1_valid = stack_ragged(idx1_list)
        cpi0, rfv = rfv_bank(bank.features[ar[:, None], idx1],
                             self.configs[0], mesh=mesh)
        rows = np.asarray([s.row for s in sims], np.int64)
        self.memo.fill(rows, idx1, idx1_valid, (self.configs[0],),
                       values=cpi0[:, None, :])

        # RFV stratification: masked batched z-scoring + weighted k-means
        n1 = idx1_valid.sum(axis=1)                        # (A,)
        v3 = idx1_valid[:, :, None]
        mean = np.where(v3, rfv, 0.0).sum(1) / n1[:, None]
        var = np.where(v3, (rfv - mean[:, None, :]) ** 2, 0.0).sum(1) \
            / n1[:, None]
        scale = np.sqrt(var)
        scale = np.where(scale > 1e-12, scale, 1.0)
        zr = np.where(v3, (rfv - mean[:, None, :]) / scale[:, None, :], 0.0)
        rfv_fit = kmeans_bank(zr, L, weights=idx1_valid.astype(np.float32),
                              seed=kmeans_seed, mesh=mesh)
        rfv_w = _offset_bincount(rfv_fit.labels, idx1_valid, L) / n1[:, None]

        # Dalenius-Gurney on baseline CPI (host-side scalar refinement)
        dg_list = [dalenius_gurney_strata(cpi0[a, :n1[a]], L)
                   for a in range(a_n)]
        dg, _ = stack_ragged(dg_list)
        dg_w = _offset_bincount(dg, idx1_valid, L) / n1[:, None]

        for a, (name, sim, pop) in enumerate(zip(names, sims, bank.pops)):
            n, n1_a = pop.n_regions, int(n1[a])
            self._apps[(name, kmeans_seed)] = AppExperiment(
                name=name, sim=sim, configs=self.configs,
                truth=truth[a], census_mat=census[a, :, :n],
                bbv_labels=bbv_fit.labels[a, :n], bbv_weights=bbv_w[a],
                bbv_feats=z[a, :n], bbv_centroids=bbv_fit.centroids[a],
                idx1=idx1_list[a], cpi0_1=cpi0[a, :n1_a],
                rfv_z=zr[a, :n1_a],
                rfv_labels=rfv_fit.labels[a, :n1_a], rfv_weights=rfv_w[a],
                rfv_centroids=rfv_fit.centroids[a],
                dg_labels=dg_list[a], dg_weights=dg_w[a], num_strata=L)

    # multi-seed stratification (paper Figs 7-8): one vmapped computation
    def rfv_stratifications(self, name: str, seeds: Sequence[int]):
        """k-means RFV fits for many clustering seeds as one batched fit."""
        exp = self.app(name)
        return kmeans_batch(exp.rfv_z, self.num_strata, seeds=list(seeds))


@functools.lru_cache(maxsize=None)
def _project_bank_fn(mesh):
    key = jax.random.PRNGKey(0)
    fn = jax.vmap(lambda b: random_project(b, 15, key=key))
    if mesh is None:
        return jax.jit(fn)
    from ..distributed.appaxis import make_app_sharded
    return make_app_sharded(fn, mesh)


def _project_bank(bbvs: np.ndarray, *, mesh=None):
    """(A, N, 256) BBVs -> (A, N, 15) projections, one batched dispatch.

    Every app uses the same JL projection matrix (same key), matching the
    historic per-app ``random_project(bbv, 15, key=PRNGKey(0))`` exactly.
    """
    return _project_bank_fn(mesh)(bbvs)


# --------------------------------------------------------------- selection
def plan_selection_bank(
    exps: Sequence[AppExperiment], plan: sampling_plan.SamplingPlan,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized one-unit-per-stratum selection for a stack of apps.

    THE engine's single selection dispatch site: the plan's stratifier
    resolves the engine-built artifacts into a stacked ``StratumBank``,
    ONE stratum-summary dispatch (the ``segment_stats`` kernel contract,
    via ``build_selection_context``) serves the counts, the mean-policy
    targets and any baseline-derived centroids, and the plan's policy —
    a batched callable — picks one unit per stratum. Registry plug-ins
    (new stratifiers/policies) run through here without any engine edit.

    Returns ``(picks, valid, weights)``: (A, L) population indices, an
    (A, L) validity mask (False where the stratum is empty — empty strata
    are masked out of selection entirely, they can't contribute NaN
    centroids or distances), and the (A, L) stratum weights.
    """
    bank = plan.stratifier.resolve(exps)
    ctx = sampling_plan.build_selection_context(
        bank, seed=seed, summarize=_segment_sums_counts)
    local = np.asarray(plan.policy(ctx))
    valid = ctx.counts > 0
    picks = local if bank.pool is None \
        else np.take_along_axis(bank.pool, local, axis=1)
    return np.where(valid, picks, 0), valid, bank.weights


def plan_selection(exp: AppExperiment, plan: sampling_plan.SamplingPlan,
                   seed: int = 0) -> tuple[list[np.ndarray], np.ndarray]:
    """Population indices per stratum + weights for one app's plan.

    Thin per-app wrapper over ``plan_selection_bank`` so single-app
    callers and the batched sweep driver share one code path.
    """
    picks, valid, weights = plan_selection_bank([exp], plan, seed)
    sel = [np.asarray([picks[0, h]], np.int64) if valid[0, h]
           else np.empty(0, np.int64) for h in range(exp.num_strata)]
    return sel, weights[0]


def scheme_selection_bank(
    exps: Sequence[AppExperiment], scheme: str, policy: str, seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deprecated string shim over ``plan_selection_bank``.

    Constructs ``SamplingPlan.from_strings(scheme, policy)`` through the
    registry and dispatches the plan path — identical results, one
    ``DeprecationWarning``.
    """
    sampling_plan.warn_string_dispatch(
        "scheme_selection_bank",
        "use plan_selection_bank(exps, SamplingPlan.from_strings(...))")
    return plan_selection_bank(
        exps, sampling_plan.SamplingPlan.from_strings(scheme, policy), seed)


def scheme_selection(exp: AppExperiment, scheme: str, policy: str,
                     seed: int = 0) -> tuple[list[np.ndarray], np.ndarray]:
    """Deprecated string shim over ``plan_selection`` (see
    ``scheme_selection_bank`` for the contract)."""
    sampling_plan.warn_string_dispatch(
        "scheme_selection",
        "use plan_selection(exp, SamplingPlan.from_strings(...))")
    return plan_selection(
        exp, sampling_plan.SamplingPlan.from_strings(scheme, policy), seed)
