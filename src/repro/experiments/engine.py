"""Batched experiment engine over the synthetic simulation substrate.

One ``ExperimentEngine`` owns, per application, an ``AppExperiment``: a
``CachedSimulator`` (region × config memo, miss-only cost accounting), the
census ground truth for every config (computed as ONE vmapped dispatch over
the stacked config matrix), and the paper's three stratifications (BBV,
RFV, Dalenius-Gurney). Sweeps over (app × config × scheme) then run through
``AppExperiment.cpi_all`` — one batched XLA program per region set instead
of C sequential dispatches — and through the memo table, so a region is
charged once per config no matter how many figures touch it.

This used to live in ``benchmarks/simcpu_common.py`` as nested Python
loops; ``benchmarks/simcpu_common`` now re-exports from here.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

import jax
import numpy as np

from ..core.clustering import (Standardizer, kmeans, kmeans_batch,
                               random_project)
from ..core.sampling import (dalenius_gurney_strata, draw_srs,
                             select_centroid, select_mean, select_random)
from ..simcpu import (APP_NAMES, CONFIGS, CachedSimulator, cpi_batch,
                      get_bbvs, make_cached_simulator)

NUM_STRATA = 20
PHASE1_SEED = 42


@dataclasses.dataclass
class AppExperiment:
    """Per-application state shared by every figure/sweep."""

    name: str
    sim: CachedSimulator
    configs: tuple                # the sweep's config axis
    truth: np.ndarray             # (C,) census mean CPI per config
    census_mat: np.ndarray        # (C, N) census CPI (analysis-only)
    # BBV stratification (census, SimPoint-style)
    bbv_labels: np.ndarray        # (N,)
    bbv_weights: np.ndarray       # (L,)
    bbv_feats: np.ndarray         # projected (N, 15)
    bbv_centroids: np.ndarray
    # phase-1 sample + RFV stratification
    idx1: np.ndarray
    cpi0_1: np.ndarray            # baseline CPI of phase-1 units
    rfv_z: np.ndarray             # standardized RFVs of phase-1 units
    rfv_labels: np.ndarray
    rfv_weights: np.ndarray
    rfv_centroids: np.ndarray
    # Dalenius-Gurney on baseline CPI (phase-1 sample)
    dg_labels: np.ndarray
    dg_weights: np.ndarray
    num_strata: int = NUM_STRATA

    def cpi(self, cfg_i: int, indices) -> np.ndarray:
        """(n,) CPI for one config, through the memo table."""
        return self.sim.simulate_cpi(indices, self.configs[cfg_i])

    def cpi_for(self, indices,
                config_indices: Optional[Sequence[int]] = None) -> np.ndarray:
        """(C', n) CPI for a config subset in one batched dispatch.

        Only the requested configs are simulated (and ledger-charged)."""
        cfgs = (self.configs if config_indices is None
                else tuple(self.configs[i] for i in config_indices))
        return self.sim.simulate_cpi_batch(indices, cfgs)

    def cpi_all(self, indices) -> np.ndarray:
        """(C, n) CPI across ALL configs in one batched dispatch."""
        return self.cpi_for(indices)

    def weighted_cpi_all(self, selected: Sequence[np.ndarray], weights,
                         *, config_indices: Optional[Sequence[int]] = None,
                         strict: bool = False) -> np.ndarray:
        """(C',) stratified weighted-mean CPI per config, one dispatch.

        ``selected``: per-stratum population index arrays (any count per
        stratum). Strata with no selected units renormalize the estimate
        by the covered weight — with the same warn/raise contract as
        ``weighted_point_estimate`` so the bias can't pass silently.
        """
        weights = np.asarray(weights, np.float64)
        sel = [np.atleast_1d(np.asarray(s)) for s in selected]
        flat = np.concatenate([s for s in sel if s.size])
        seg = np.concatenate([np.full(s.size, h, np.int64)
                              for h, s in enumerate(sel) if s.size])
        counts = np.bincount(seg, minlength=len(sel))
        covered = float(weights[counts > 0].sum())
        total = float(weights.sum())
        if covered < total * (1.0 - 1e-6):
            msg = (f"selected units cover only {covered / total:.4f} of the "
                   "stratum weight; renormalizing biases the estimate "
                   "toward the covered strata")
            if strict:
                raise ValueError(msg)
            warnings.warn(msg, UserWarning, stacklevel=2)
        mat = self.cpi_for(flat, config_indices)
        w_per_unit = np.where(counts[seg] > 0,
                              weights[seg] / np.maximum(counts[seg], 1), 0.0)
        return (mat * w_per_unit[None, :]).sum(axis=1) / covered

    def census(self, cfg_i: int) -> np.ndarray:
        return self.census_mat[cfg_i]


class ExperimentEngine:
    """Builds and memoizes ``AppExperiment`` state; runs batched sweeps."""

    def __init__(self, *, configs: Sequence = CONFIGS,
                 num_strata: int = NUM_STRATA,
                 phase1_seed: int = PHASE1_SEED):
        self.configs = tuple(configs)
        self.num_strata = num_strata
        self.phase1_seed = phase1_seed
        self._apps: dict[tuple[str, int], AppExperiment] = {}

    def app(self, name: str, kmeans_seed: int = 0) -> AppExperiment:
        key = (name, kmeans_seed)
        if key not in self._apps:
            self._apps[key] = self._build(name, kmeans_seed)
        return self._apps[key]

    def apps(self, names: Optional[Sequence[str]] = None
             ) -> list[AppExperiment]:
        return [self.app(n) for n in (names or APP_NAMES)]

    def _build(self, name: str, kmeans_seed: int) -> AppExperiment:
        L = self.num_strata
        sim = make_cached_simulator(name)
        pop = sim.pop
        N = pop.n_regions
        rng = np.random.default_rng(self.phase1_seed)

        # census ground truth for every config: one vmapped program
        # (analysis-only — free of charge, bypasses the charged memo)
        census_mat = cpi_batch(pop.features, self.configs)
        truth = census_mat.mean(axis=1, dtype=np.float64)

        # SimPoint-style BBV stratification over the full population
        bbv = get_bbvs(pop)
        z = np.asarray(random_project(bbv, 15, key=jax.random.PRNGKey(0)))
        km = kmeans(z, L, seed=kmeans_seed)
        bbv_w = np.bincount(km.labels, minlength=L) / N

        # phase 1: SRS at the paper's Table II size, RFVs on config 0
        idx1 = draw_srs(rng, N, pop.spec.phase1_n)
        cpi0_1, rfv = sim.simulate_rfv(idx1, self.configs[0])
        _, zr = Standardizer.fit_transform(rfv)
        zr = np.asarray(zr)
        km2 = kmeans(zr, L, seed=kmeans_seed)
        rfv_w = np.bincount(km2.labels, minlength=L) / idx1.size

        dg = dalenius_gurney_strata(cpi0_1, L)
        dg_w = np.bincount(dg, minlength=L) / idx1.size

        return AppExperiment(
            name=name, sim=sim, configs=self.configs,
            truth=truth, census_mat=census_mat,
            bbv_labels=km.labels, bbv_weights=bbv_w, bbv_feats=z,
            bbv_centroids=km.centroids,
            idx1=idx1, cpi0_1=np.asarray(cpi0_1), rfv_z=zr,
            rfv_labels=km2.labels, rfv_weights=rfv_w,
            rfv_centroids=km2.centroids,
            dg_labels=dg, dg_weights=dg_w, num_strata=L)

    # multi-seed stratification (paper Figs 7-8): one vmapped computation
    def rfv_stratifications(self, name: str, seeds: Sequence[int]):
        """k-means RFV fits for many clustering seeds as one batched fit."""
        exp = self.app(name)
        return kmeans_batch(exp.rfv_z, self.num_strata, seeds=list(seeds))


def scheme_selection(exp: AppExperiment, scheme: str, policy: str,
                     seed: int = 0) -> tuple[list[np.ndarray], np.ndarray]:
    """Population indices per stratum + weights for a scheme/policy."""
    L = exp.num_strata
    if scheme == "bbv":
        labels, weights = exp.bbv_labels, exp.bbv_weights
        feats, cents = exp.bbv_feats, exp.bbv_centroids
        pool = np.arange(labels.shape[0])
        baseline = exp.census(0)
    else:
        labels = exp.rfv_labels if scheme == "rfv" else exp.dg_labels
        weights = exp.rfv_weights if scheme == "rfv" else exp.dg_weights
        feats = exp.rfv_z if scheme == "rfv" else exp.cpi0_1[:, None]
        pool = exp.idx1
        baseline = exp.cpi0_1
        if scheme == "dg":
            cents = np.array([[baseline[labels == h].mean()]
                              if (labels == h).any() else [np.nan]
                              for h in range(L)])
        else:
            cents = exp.rfv_centroids
    if policy == "random":
        local = select_random(labels, L, np.random.default_rng(seed))
    elif policy == "centroid":
        local = select_centroid(labels, feats, cents)
    elif policy == "mean":
        local = select_mean(labels, baseline, num_strata=L)
    else:
        raise ValueError(policy)
    return [pool[l] for l in local], weights
