"""Engine-backed Monte-Carlo trials (paper Fig 8) as vmapped trial axes.

``bench_ci_empirical`` used to run 1000-trial numpy loops per app and per
stratum; ``run_trials`` folds both into array axes: ONE program per scheme
evaluates every (app, trial, stratum) draw — uniforms of shape
``(A, T, L)`` (or ``(A, T, n)`` for the SRS scheme) gathered against
per-app stratum tables. With an ``("app",)`` mesh the app axis runs
device-parallel; the uniforms are drawn *outside* the sharded region from
one PRNG key, so sharded and single-device runs use identical draws and
produce identical estimates.

The same one-dispatch-per-scheme program also evaluates a per-trial
confidence interval (the Fig 8 → CI-claim bridge): the SRS scheme uses
the eq. (2) t-interval, the one-unit-per-stratum schemes the pairwise
collapsed-strata variance (eq. 4) over the occupied strata in
baseline-CPI order — evaluated lane-wise by the batched estimators in
``repro.core.sampling.tables``. ``TrialResult`` reports the absolute CI
half-width per (app, trial) and the empirical coverage of the census
truth per app; t critical values come from per-app static dfs, computed
host-side once per scheme. The per-stratum order keys route through the
``segment_stats`` kernel contract (one batched dispatch, jnp oracle
off-TPU).

Cost accounting matches the figure's semantics exactly: schemes drawing
from census CPI (``random``, ``bbv``) are analysis-only and free; schemes
drawing from the phase-1 sample (``rfv``, ``dg``) pull their value pool
through the engine's charged ``MemoBank`` (paid once, like the historic
``exp.cpi(cfg, exp.idx1)``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sampling import plan as sampling_plan
from ..core.sampling import tables as sampling_tables
from ..core.sampling.types import critical_values
from ..simcpu import APP_NAMES, stack_ragged
from .engine import ExperimentEngine, stratum_tables

__all__ = ["SRS_DRAWS", "TRIAL_SCHEMES", "TrialSpec", "TrialResult",
           "run_trials", "trial_key", "trial_uniforms"]

# the plan-less trial scheme: n-unit uniform draws from the census pool
SRS_DRAWS = "random"
# canonical scheme order: key derivation is position-based so a scheme's
# draws are identical no matter which subset a TrialSpec requests;
# registry plug-ins hash their name past this range (trial_key)
TRIAL_SCHEMES = (SRS_DRAWS, "bbv", "rfv", "dg")


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """Monte-Carlo repetition axes for one study configuration.

    ``schemes`` names the stratifications to study: ``"random"`` (the
    plan-less SRS reference) plus any *registered* stratifier name
    (``repro.core.sampling.plan``) — names are validated against the
    registry at construction, so an unknown scheme fails here rather
    than mid-study.
    """

    trials: int = 1000
    units_per_trial: int = 20          # SRS draw size (scheme "random")
    schemes: tuple[str, ...] = TRIAL_SCHEMES
    config_index: int = 6              # study config (paper: Config 6)
    seed: int = 7
    confidence: float = 0.95           # per-trial CI level

    def __post_init__(self):
        unknown = (set(self.schemes) - {SRS_DRAWS}
                   - set(sampling_plan.registered_stratifiers()))
        if unknown:
            raise ValueError(
                f"unknown trial scheme(s) {sorted(unknown)}; known: "
                f"{(SRS_DRAWS,) + sampling_plan.registered_stratifiers()}")


@dataclasses.dataclass(frozen=True)
class TrialResult:
    """Per-scheme Monte-Carlo outcomes for one ``run_trials`` study.

    ``estimates[scheme]`` / ``errors[scheme]`` / ``half_widths[scheme]``
    are ``(A, T)`` arrays over the (app, trial) axes: estimated mean CPI,
    percent |error| vs the census truth at ``spec.config_index``, and the
    absolute CI half-width at ``spec.confidence``. ``coverage[scheme]``
    is the ``(A,)`` empirical coverage — the fraction of trials whose CI
    contains the truth (the paper's conservative-CI claim evaluated
    empirically). SRS trials use the eq. (2) t-interval; stratified
    one-unit-per-stratum trials the eq. (4) collapsed-pairs interval.
    """

    apps: tuple[str, ...]
    spec: TrialSpec
    estimates: dict[str, np.ndarray]    # scheme -> (A, T) estimated mean CPI
    errors: dict[str, np.ndarray]       # scheme -> (A, T) percent |error|
    half_widths: dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)           # scheme -> (A, T) abs CI half-width
    coverage: dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)           # scheme -> (A,) empirical coverage

    def p95(self, scheme: str) -> np.ndarray:
        """(A,) 95th-percentile |error| per app (the Fig 8 statistic)."""
        return np.percentile(self.errors[scheme], 95, axis=1)

    def half_width_pct(self, scheme: str, truth: np.ndarray) -> np.ndarray:
        """(A, T) CI half-widths as percent of the per-app truth."""
        return 100.0 * self.half_widths[scheme] / np.asarray(truth)[:, None]


def trial_key(spec: TrialSpec, scheme: str) -> jax.Array:
    """Per-scheme PRNG key; exposed so reference implementations (tests)
    can reproduce the exact uniforms ``run_trials`` consumes.

    Canonical schemes keep their historic fold-in positions; registered
    plug-in schemes hash their name past the canonical range
    (``sampling_plan.trial_scheme_index``) so draws never depend on
    registration order.
    """
    return jax.random.fold_in(
        jax.random.PRNGKey(spec.seed),
        sampling_plan.trial_scheme_index(scheme, TRIAL_SCHEMES))


def trial_uniforms(spec: TrialSpec, scheme: str, num_apps: int,
                   draws_per_trial: int) -> np.ndarray:
    """The (A, T, D) uniform draws backing one scheme's trials."""
    return np.asarray(jax.random.uniform(
        trial_key(spec, scheme),
        (num_apps, spec.trials, draws_per_trial), jnp.float32))


def _srs_trials(u, pool, n_valid, truth, crit):
    """(A, T, n) uniforms x (A, N) value pool -> per-trial estimate,
    percent error, eq. (2) t-interval half-width, and coverage."""
    a, t, n = u.shape
    idx = jnp.minimum((u * n_valid[:, None, None]).astype(jnp.int32),
                      (n_valid - 1)[:, None, None].astype(jnp.int32))
    vals = jnp.take_along_axis(
        jnp.broadcast_to(pool[:, None, :], (a, t, pool.shape[1])), idx,
        axis=2)
    est = vals.mean(axis=2)
    err = 100.0 * jnp.abs(est - truth[:, None]) / truth[:, None]
    ss = ((vals - est[:, :, None]) ** 2).sum(axis=2)
    v_mean = jnp.where(n > 1, ss / max(n - 1, 1), jnp.nan) / n
    half = crit[:, None] * jnp.sqrt(v_mean)
    cover = (jnp.abs(est - truth[:, None]) <= half).mean(axis=1)
    return est, err, half, cover


def _stratified_trials(u, sorted_vals, offsets, counts, weights, truth,
                       key_order, w_sorted, n_occ, crit):
    """One unit per non-empty stratum per trial, weighted sum (the Fig 8
    estimator: empty strata contribute nothing, no renormalization) —
    plus the eq. (4) collapsed-pairs CI over occupied strata, evaluated
    lane-wise by ``sampling_tables.collapsed_pairs_variance``."""
    a, t, l = u.shape
    pick = offsets[:, None, :] + jnp.minimum(
        (u * counts[:, None, :]).astype(jnp.int32),
        jnp.maximum(counts - 1, 0)[:, None, :].astype(jnp.int32))
    # trailing empty strata put offsets at the row width: clamp explicitly
    # (the pick is zero-weighted via `occupied` below)
    pick = jnp.minimum(pick, sorted_vals.shape[1] - 1)
    vals = jnp.take_along_axis(
        jnp.broadcast_to(sorted_vals[:, None, :],
                         (a, t, sorted_vals.shape[1])), pick, axis=2)
    occupied = (counts > 0)[:, None, :]
    est = jnp.sum(vals * weights[:, None, :] * occupied, axis=2)
    err = 100.0 * jnp.abs(est - truth[:, None]) / truth[:, None]
    # collapsed-pairs CI: stratum draws gathered into key order
    y_sorted = jnp.take_along_axis(
        vals, jnp.broadcast_to(key_order[:, None, :], (a, t, l)), axis=2)
    var, _ = sampling_tables.collapsed_pairs_variance(
        y_sorted, w_sorted[:, None, :], n_occ[:, None], num_strata=l)
    half = crit[:, None] * jnp.sqrt(var)
    cover = (jnp.abs(est - truth[:, None]) <= half).mean(axis=1)
    return est, err, half, cover


_srs_trials_jit = jax.jit(_srs_trials)
_stratified_trials_jit = jax.jit(_stratified_trials)


def _dispatch(fn, fn_jit, mesh, *args):
    if mesh is None:
        return fn_jit(*args)
    from ..distributed.appaxis import app_sharded_cached
    return app_sharded_cached(fn, mesh)(*args)


def _stratum_key_counts(baseline: np.ndarray, labels: np.ndarray,
                        valid: np.ndarray, num_strata: int
                        ) -> tuple[np.ndarray, np.ndarray]:
    """(A, L) per-stratum mean-baseline-CPI ordering key (+inf for empty
    strata) AND the stratum counts, from the engine's ONE-dispatch
    stratum-summary path (the ``segment_stats`` kernel contract) — the
    counts feed ``stratum_tables`` so no second dispatch is needed."""
    from .engine import _segment_sums_counts

    sums, cnts = _segment_sums_counts(labels, valid, num_strata, baseline)
    key = np.where(cnts > 0, sums / np.maximum(cnts, 1.0), np.inf)
    return key, cnts


def run_trials(engine: ExperimentEngine, spec: TrialSpec = TrialSpec(),
               apps: Optional[Sequence[str]] = None,
               mesh=None, stratifiers: Optional[dict] = None) -> TrialResult:
    """Monte-Carlo selection trials for every app in one program per scheme.

    No host-side per-app or per-trial loops: each scheme is one vmapped
    (optionally app-sharded) dispatch over the (app, trial, stratum/unit)
    axes — including the per-trial CI half-width and its empirical
    coverage of the census truth (see ``TrialResult``).

    ``stratifiers`` optionally maps scheme names to configured
    ``Stratifier`` *instances* (``run_sweep`` passes its plan's), so a
    parameterized plug-in studies the same stratification its sweep
    used; unmapped schemes are built from the registry with defaults.
    """
    apps = tuple(apps or APP_NAMES)
    exps = engine.build(apps)
    stack = engine.stack(apps)
    mesh = engine.mesh if mesh is None else mesh
    ci = spec.config_index
    cfg = engine.configs[ci]
    l_n = engine.num_strata
    truth = np.stack([e.truth[ci] for e in exps])

    # registry-resolved stratifications: each scheme name becomes a
    # Stratifier whose StratumBank declares its labels, weights and
    # order key — and whose ``pool_kind`` declares the value-pool cost
    # semantics — no per-scheme branches below
    strats = {s: (stratifiers or {}).get(s)
              or sampling_plan.make_stratifier(s)
              for s in spec.schemes if s != SRS_DRAWS}
    banks = {s: strat.resolve(exps) for s, strat in strats.items()}
    charged = {s for s, strat in strats.items()
               if strat.pool_kind == "phase1"}

    # value pools: census CPI (free) and phase-1 CPI (charged once)
    census, _ = stack_ragged([e.census(ci) for e in exps], dtype=np.float32)
    p1_pool = None
    if charged:
        cpi, _ = engine.memo.fill(stack.rows, stack.idx1, stack.idx1_valid,
                                  (cfg,),
                                  feats=stack.gather_feats(stack.idx1),
                                  mesh=mesh)
        p1_pool = cpi[:, 0, :].astype(np.float32)          # (A, n1_max)

    estimates: dict[str, np.ndarray] = {}
    errors: dict[str, np.ndarray] = {}
    halves: dict[str, np.ndarray] = {}
    coverage: dict[str, np.ndarray] = {}
    for scheme in spec.schemes:
        if scheme == SRS_DRAWS:
            n = spec.units_per_trial
            dfs = np.full(len(apps), float(n - 1) if n < 30 else np.inf)
            crit = critical_values(spec.confidence, dfs).astype(np.float32)
            u = trial_uniforms(spec, scheme, len(apps), n)
            est, err, half, cov = _dispatch(
                _srs_trials, _srs_trials_jit, mesh,
                u, census, stack.n_regions, truth, crit)
        else:
            bank = banks[scheme]
            labels, lv = bank.labels, bank.valid
            weights = bank.weights
            if scheme in charged:                 # phase-1 pool, paid once
                pool = p1_pool
            elif bank.pool is None:               # census-indexed labels
                pool = census
            else:                                 # census values at pool idx
                pool = np.take_along_axis(census, bank.pool, axis=1)
            baseline = bank.baseline.astype(np.float32)
            # ONE stratum-summary dispatch serves the collapsed-pairs
            # ordering key AND the gather-table counts
            key, countsf = _stratum_key_counts(baseline, labels, lv, l_n)
            order, offsets, counts = stratum_tables(labels, lv, l_n,
                                                    counts=countsf)
            sorted_vals = np.take_along_axis(pool, order, axis=1)
            # collapsed-pairs CI geometry: occupied strata first, in
            # baseline-CPI key order (static per app)
            key_order = np.argsort(key, axis=1, kind="stable")
            w_sorted = np.take_along_axis(weights, key_order, axis=1)
            n_occ = (counts > 0).sum(axis=1)
            dfs = np.maximum(n_occ - n_occ // 2, 1).astype(np.float64)
            crit = critical_values(spec.confidence, dfs).astype(np.float32)
            u = trial_uniforms(spec, scheme, len(apps), l_n)
            est, err, half, cov = _dispatch(
                _stratified_trials, _stratified_trials_jit, mesh,
                u, sorted_vals, offsets.astype(np.int32),
                counts.astype(np.int32), weights.astype(np.float32), truth,
                key_order.astype(np.int32), w_sorted.astype(np.float32),
                n_occ.astype(np.int32), crit)
        estimates[scheme] = np.asarray(est)
        errors[scheme] = np.asarray(err)
        halves[scheme] = np.asarray(half)
        coverage[scheme] = np.asarray(cov)
    return TrialResult(apps=apps, spec=spec, estimates=estimates,
                       errors=errors, half_widths=halves, coverage=coverage)
