"""Engine-backed Monte-Carlo trials (paper Fig 8) as a streaming reduction.

``run_trials`` used to vmap one monolithic ``(A, T, ...)`` program per
scheme, so host and device memory scaled linearly with the trial count T
— fine at the paper's 1000 trials, a wall at the 10^5–10^6 replications
the conservative-CI claim needs. This module streams instead: a chunked
``lax.scan`` over fixed-size trial blocks folds every chunk's per-trial
outcomes into an *additive* accumulator (``TrialStats`` in
``repro.core.sampling.tables`` — running coverage counts, error moments,
log-histogram quantile sketches), so memory is bounded by one chunk at
any trial count and per-trial arrays never materialize unless asked for.

PRNG contract (the chunked == unchunked bitwise guarantee): uniforms are
drawn in fixed ``TRIAL_BLOCK``-sized trial blocks, block ``b`` of app
``a`` from ``fold_in(fold_in(trial_key, b), a)`` — a pure function of
(seed, scheme, block, app). Any chunking of the scan, any ``("app",)``
or ``("app", "trial")`` mesh sharding, and the ``trial_uniforms``
reference helper therefore consume bitwise-identical draws.

Mesh story: with a 2-D ``("app", "trial")`` mesh
(``repro.launch.mesh.make_app_trial_mesh``) each chunk is ``shard_map``-
ped over both axes — app lanes stay independent, and the trial axis
splits each chunk's blocks across devices, with the accumulator merged
by a ``psum`` over the trial axis (additivity makes the cross-device
coverage/CI merge exact: sharded totals equal single-device totals).

The per-trial math is unchanged from the vmapped design: the SRS scheme
evaluates the eq. (2) t-interval, the one-unit-per-stratum schemes the
pairwise collapsed-strata variance (eq. 4) over occupied strata in
baseline-CPI order, lane-wise via ``repro.core.sampling.tables``.
Dtypes route through ONE ``PrecisionPolicy`` (``repro.core.precision``):
trace dtype for the chunk programs, accumulator dtype for the scan
carry, host dtype for numpy-side statistics.

Cost accounting matches the figure's semantics exactly: schemes drawing
from census CPI (``random``, ``bbv``) are analysis-only and free; schemes
drawing from the phase-1 sample (``rfv``, ``dg``) pull their value pool
through the engine's charged ``MemoBank`` (paid once, like the historic
``exp.cpi(cfg, exp.idx1)``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.precision import PrecisionPolicy, resolve_precision
from ..core.sampling import plan as sampling_plan
from ..core.sampling import tables as sampling_tables
from ..core.sampling.types import critical_values
from ..simcpu import APP_NAMES, stack_ragged
from .engine import ExperimentEngine, stratum_tables

__all__ = ["SRS_DRAWS", "TRIAL_SCHEMES", "TRIAL_BLOCK", "TrialSpec",
           "TrialResult", "charged_pool_fill", "run_trials", "trial_key",
           "trial_uniforms"]

# the plan-less trial scheme: n-unit uniform draws from the census pool
SRS_DRAWS = "random"
# canonical scheme order: key derivation is position-based so a scheme's
# draws are identical no matter which subset a TrialSpec requests;
# registry plug-ins hash their name past this range (trial_key)
TRIAL_SCHEMES = (SRS_DRAWS, "bbv", "rfv", "dg")

# PRNG block granularity: uniforms are drawn per TRIAL_BLOCK trials from a
# per-block fold-in, so draws are a function of the block index alone —
# the unit the chunked scan, the trial-mesh split and the dense reference
# all agree on. Chunk sizes are multiples of this.
TRIAL_BLOCK = 256
# default trials per scan step: bounds live memory at ~chunk × pool-width
_DEFAULT_CHUNK = 4096
# keep dense (A, T) per-trial arrays by default up to this many trials
# (the Fig 8 regime); past it only the streamed statistics come home
_KEEP_TRIALS_MAX = 8192


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """Monte-Carlo repetition axes for one study configuration.

    ``schemes`` names the stratifications to study: ``"random"`` (the
    plan-less SRS reference) plus any *registered* stratifier name
    (``repro.core.sampling.plan``) — names are validated against the
    registry at construction, so an unknown scheme fails here rather
    than mid-study.

    Streaming knobs: ``chunk_size`` fixes the trials evaluated per scan
    step (a positive multiple of ``TRIAL_BLOCK``; default ~4096, rounded
    to the trial-mesh split) — it changes memory and scheduling, never
    results. ``keep_trials`` forces (True) or suppresses (False) the
    dense per-trial ``(A, T)`` arrays; default keeps them only up to
    8192 trials. ``precision`` overrides the engine's
    ``PrecisionPolicy`` for the trial programs.
    """

    trials: int = 1000
    units_per_trial: int = 20          # SRS draw size (scheme "random")
    schemes: tuple[str, ...] = TRIAL_SCHEMES
    config_index: int = 6              # study config (paper: Config 6)
    seed: int = 7
    confidence: float = 0.95           # per-trial CI level
    chunk_size: Optional[int] = None   # trials per scan step
    keep_trials: Optional[bool] = None  # materialize dense (A, T) arrays
    precision: Optional[PrecisionPolicy] = None

    def __post_init__(self):
        unknown = (set(self.schemes) - {SRS_DRAWS}
                   - set(sampling_plan.registered_stratifiers()))
        if unknown:
            raise ValueError(
                f"unknown trial scheme(s) {sorted(unknown)}; known: "
                f"{(SRS_DRAWS,) + sampling_plan.registered_stratifiers()}")
        if self.chunk_size is not None and (
                self.chunk_size <= 0 or self.chunk_size % TRIAL_BLOCK):
            raise ValueError(
                f"chunk_size must be a positive multiple of TRIAL_BLOCK="
                f"{TRIAL_BLOCK}, got {self.chunk_size}")


@dataclasses.dataclass(frozen=True)
class TrialResult:
    """Per-scheme Monte-Carlo outcomes for one ``run_trials`` study.

    ``stats[scheme]`` is the streamed ``TrialStats`` accumulator — the
    always-available product of the chunked scan: trial/coverage counts,
    error and half-width moments, and log-histogram quantile sketches,
    all per app. ``coverage``, ``p95`` and ``half_width_pct`` read from
    it, so they work at any trial count without per-trial arrays.

    ``estimates[scheme]`` / ``errors[scheme]`` / ``half_widths[scheme]``
    are the dense ``(A, T)`` per-trial arrays (estimated mean CPI,
    percent |error| vs the census truth, absolute CI half-width at
    ``spec.confidence``) — populated only when the spec keeps them
    (``TrialSpec.keep_trials``; default up to 8192 trials). SRS trials
    use the eq. (2) t-interval; stratified one-unit-per-stratum trials
    the eq. (4) collapsed-pairs interval.
    """

    apps: tuple[str, ...]
    spec: TrialSpec
    stats: dict[str, sampling_tables.TrialStats]
    estimates: dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)       # scheme -> (A, T), only when kept
    errors: dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)       # scheme -> (A, T), only when kept
    half_widths: dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)       # scheme -> (A, T), only when kept

    @property
    def coverage(self) -> dict[str, np.ndarray]:
        """scheme -> (A,) empirical coverage — the fraction of trials
        whose CI contains the truth, from the streamed counts (exact)."""
        return {s: np.asarray(st.coverage) for s, st in self.stats.items()}

    def p95(self, scheme: str) -> np.ndarray:
        """(A,) 95th-percentile |error| per app (the Fig 8 statistic),
        read from the streamed quantile sketch — no per-trial arrays."""
        return np.asarray(self.stats[scheme].err_quantile(0.95))

    def half_width_pct(self, scheme: str, truth: np.ndarray) -> np.ndarray:
        """(A,) mean CI half-width as percent of the per-app truth, from
        the streamed moments (the nanmean of per-trial widths)."""
        return 100.0 * np.asarray(self.stats[scheme].half_mean) \
            / np.asarray(truth)


def trial_key(spec: TrialSpec, scheme: str) -> jax.Array:
    """Per-scheme PRNG key; exposed so reference implementations (tests)
    can reproduce the exact uniforms ``run_trials`` consumes.

    Canonical schemes keep their historic fold-in positions; registered
    plug-in schemes hash their name past the canonical range
    (``sampling_plan.trial_scheme_index``) so draws never depend on
    registration order.
    """
    return jax.random.fold_in(
        jax.random.PRNGKey(spec.seed),
        sampling_plan.trial_scheme_index(scheme, TRIAL_SCHEMES))


def _block_uniforms(key, block_index, app_ids, draws: int, dtype):
    """(A, TRIAL_BLOCK, D) canonical draws for one trial block.

    Block ``b`` of app ``a`` is ``uniform(fold_in(fold_in(key, b), a))``
    — a pure function of (key, block, app), independent of the total
    trial count, the chunking, the mesh, or which apps run together.
    This is the contract that makes chunked == unchunked and sharded ==
    single-device runs consume bitwise-identical uniforms.
    """
    bk = jax.random.fold_in(key, block_index)
    return jax.vmap(lambda a: jax.random.uniform(
        jax.random.fold_in(bk, a), (TRIAL_BLOCK, draws), dtype))(app_ids)


def _run_uniforms(key, start_block, num_blocks: int, app_ids,
                  draws: int, dtype):
    """(A, num_blocks * TRIAL_BLOCK, D) draws for consecutive blocks."""
    blocks = jax.vmap(
        lambda b: _block_uniforms(key, b, app_ids, draws, dtype))(
            start_block + jnp.arange(num_blocks))
    a = app_ids.shape[0]
    return blocks.transpose(1, 0, 2, 3).reshape(
        a, num_blocks * TRIAL_BLOCK, draws)


def trial_uniforms(spec: TrialSpec, scheme: str, num_apps: int,
                   draws_per_trial: int) -> np.ndarray:
    """The (A, T, D) uniform draws backing one scheme's trials — the
    dense reference view of the block-based PRNG contract
    (``_block_uniforms``); trial ``t`` lives at offset ``t % TRIAL_BLOCK``
    of block ``t // TRIAL_BLOCK``."""
    pp = resolve_precision(spec.precision)
    n_blocks = -(-spec.trials // TRIAL_BLOCK)
    u = _run_uniforms(trial_key(spec, scheme), 0, n_blocks,
                      jnp.arange(num_apps), draws_per_trial,
                      jnp.dtype(pp.trace))
    return np.asarray(u[:, :spec.trials])


def _srs_chunk(u, truth, crit, pool, n_valid):
    """(A, Tc, n) uniforms x (A, N) value pool -> per-trial estimate,
    percent error, eq. (2) t-interval half-width and CI-covers-truth."""
    a, t, n = u.shape
    idx = jnp.minimum((u * n_valid[:, None, None]).astype(jnp.int32),
                      (n_valid - 1)[:, None, None].astype(jnp.int32))
    vals = jnp.take_along_axis(
        jnp.broadcast_to(pool[:, None, :], (a, t, pool.shape[1])), idx,
        axis=2)
    est = vals.mean(axis=2)
    err = 100.0 * jnp.abs(est - truth[:, None]) / truth[:, None]
    ss = ((vals - est[:, :, None]) ** 2).sum(axis=2)
    v_mean = jnp.where(n > 1, ss / max(n - 1, 1), jnp.nan) / n
    half = crit[:, None] * jnp.sqrt(v_mean)
    covered = jnp.abs(est - truth[:, None]) <= half
    return est, err, half, covered


def _stratified_chunk(u, truth, crit, sorted_vals, offsets, counts,
                      weights, key_order, w_sorted, n_occ):
    """One unit per non-empty stratum per trial, weighted sum (the Fig 8
    estimator: empty strata contribute nothing, no renormalization) —
    plus the eq. (4) collapsed-pairs CI over occupied strata, evaluated
    lane-wise by ``sampling_tables.collapsed_pairs_variance``."""
    a, t, l = u.shape
    pick = offsets[:, None, :] + jnp.minimum(
        (u * counts[:, None, :]).astype(jnp.int32),
        jnp.maximum(counts - 1, 0)[:, None, :].astype(jnp.int32))
    # trailing empty strata put offsets at the row width: clamp explicitly
    # (the pick is zero-weighted via `occupied` below)
    pick = jnp.minimum(pick, sorted_vals.shape[1] - 1)
    vals = jnp.take_along_axis(
        jnp.broadcast_to(sorted_vals[:, None, :],
                         (a, t, sorted_vals.shape[1])), pick, axis=2)
    occupied = (counts > 0)[:, None, :]
    est = jnp.sum(vals * weights[:, None, :] * occupied, axis=2)
    err = 100.0 * jnp.abs(est - truth[:, None]) / truth[:, None]
    # collapsed-pairs CI: stratum draws gathered into key order
    y_sorted = jnp.take_along_axis(
        vals, jnp.broadcast_to(key_order[:, None, :], (a, t, l)), axis=2)
    var, _ = sampling_tables.collapsed_pairs_variance(
        y_sorted, w_sorted[:, None, :], n_occ[:, None], num_strata=l)
    half = crit[:, None] * jnp.sqrt(var)
    covered = jnp.abs(est - truth[:, None]) <= half
    return est, err, half, covered


@functools.lru_cache(maxsize=None)
def _streaming_program(chunk_fn, mesh, *, kb: int, n_chunks: int,
                       trials: int, draws: int, trace: str, accum: str,
                       keep: bool):
    """Build (and cache) the chunked-scan trial program for one geometry.

    The returned callable takes ``(key, chunk0, app_ids, truth, crit,
    *tables)`` — app-leading arrays except the replicated key and the
    traced scalar ``chunk0`` — and returns ``(TrialStats, ys)`` where
    ``ys`` is the per-chunk dense stack ``(n_chunks, A, chunk)`` triple
    when ``keep`` else ``None``.

    ``chunk0`` offsets the whole scan by that many chunks into the
    global PRNG-block sequence: chunk ``c`` of the scan draws the blocks
    of global chunk ``chunk0 + c``. A full run passes 0; the resumable
    driver (``repro.experiments.resumable``) replays any suffix of a
    run's chunk sequence from a checkpoint — the scan fold is
    position-based, so segment-at-a-time accumulation reproduces the
    same per-chunk outcomes bitwise.

    Geometry: each scan step evaluates one chunk of ``kb`` PRNG blocks
    (``kb * TRIAL_BLOCK`` trials). Under an ``("app", "trial")`` mesh the
    chunk's blocks split evenly across the trial axis (``kb`` is a
    multiple of the axis size), each device folds its own blocks into a
    local accumulator, and a final ``psum`` over the trial axis merges
    the totals — additive leaves make the merge exact.
    """
    chunk = kb * TRIAL_BLOCK
    dt = jnp.dtype(trace)
    if mesh is None:
        trial_axis, ntd = None, 1
    else:
        from ..distributed.appaxis import app_trial_axes
        _, trial_axis = app_trial_axes(mesh)
        ntd = 1 if trial_axis is None else mesh.shape[trial_axis]
    kbd = kb // ntd                 # blocks per trial-device per chunk
    tc = kbd * TRIAL_BLOCK          # trials per trial-device per chunk

    def prog(key, chunk0, app_ids, truth, crit, *tables):
        ti = (jax.lax.axis_index(trial_axis)
              if trial_axis is not None else 0)
        stats0 = sampling_tables.trial_stats_init(
            (app_ids.shape[0],), accum_dtype=np.dtype(accum), xp=jnp)

        def step(carry, c):
            b0 = (chunk0 + c) * kb + ti * kbd
            u = _run_uniforms(key, b0, kbd, app_ids, draws, dt)
            est, err, half, covered = chunk_fn(u, truth, crit, *tables)
            valid = (b0 * TRIAL_BLOCK + jnp.arange(tc)) < trials
            carry = sampling_tables.trial_stats_update(
                carry, err, half, covered, valid[None, :])
            return carry, ((est, err, half) if keep else None)

        stats, ys = jax.lax.scan(step, stats0, jnp.arange(n_chunks))
        if trial_axis is not None:
            stats = jax.tree.map(lambda x: jax.lax.psum(x, trial_axis),
                                 stats)
        return stats, ys

    if mesh is None:
        return jax.jit(prog)
    from jax.sharding import PartitionSpec as P

    from ..distributed.appaxis import app_trial_axes, make_app_trial_sharded
    app_axis, trial_axis = app_trial_axes(mesh)
    ys_spec = (P(None, app_axis, trial_axis),) * 3 if keep else None
    return make_app_trial_sharded(
        prog, mesh, replicated=(0, 1), out_specs=(P(app_axis), ys_spec),
        trim=_trim_streaming_out)


def _trim_streaming_out(out, a_size: int):
    """Drop app-axis padding: stats lead with the app axis, dense chunk
    stacks carry it second (``(n_chunks, A, chunk)``)."""
    stats, ys = out
    stats = jax.tree.map(lambda x: x[:a_size], stats)
    if ys is not None:
        ys = jax.tree.map(lambda y: y[:, :a_size], ys)
    return stats, ys


def _chunk_blocks(spec: TrialSpec, ntd: int) -> tuple[int, int]:
    """(kb, n_chunks): blocks per chunk — a multiple of the trial-axis
    size so each device owns whole blocks — and the scan length."""
    blocks_needed = -(-spec.trials // TRIAL_BLOCK)
    kb = -(-(spec.chunk_size or _DEFAULT_CHUNK) // TRIAL_BLOCK)
    kb = min(kb, blocks_needed)
    kb = -(-kb // ntd) * ntd
    n_chunks = -(-blocks_needed // kb)
    return kb, n_chunks


def _stratum_key_counts(baseline: np.ndarray, labels: np.ndarray,
                        valid: np.ndarray, num_strata: int,
                        precision: Optional[PrecisionPolicy] = None,
                        ) -> tuple[np.ndarray, np.ndarray]:
    """(A, L) per-stratum mean-baseline-CPI ordering key (+inf for empty
    strata) AND the stratum counts, from the engine's ONE-dispatch
    stratum-summary path (the ``segment_stats`` kernel contract) — the
    counts feed ``stratum_tables`` so no second dispatch is needed."""
    from .engine import _segment_sums_counts

    sums, cnts = _segment_sums_counts(labels, valid, num_strata, baseline,
                                      precision=precision)
    key = np.where(cnts > 0, sums / np.maximum(cnts, 1.0), np.inf)
    return key, cnts


def charged_pool_fill(engine: ExperimentEngine, spec: TrialSpec, apps,
                      mesh=None, stratifiers: Optional[dict] = None
                      ) -> Optional[np.ndarray]:
    """Run the trial path's ONLY charged memo interaction for ``spec``.

    Schemes whose stratifier draws values from the phase-1 sample
    (``pool_kind == "phase1"``) pull their pool through the engine's
    charged ``MemoBank`` at the study config — paid once, hits
    thereafter. Returns the (A, n1_max) phase-1 CPI pool, or ``None``
    when no requested scheme needs one (census-pool schemes are
    analysis-only and free).

    Exposed for the serving path: when identical trial requests dedup to
    one ``run_trials`` execution, replaying this fill per duplicate (a
    pure cache hit) keeps hit/miss counters and ledger totals identical
    to running every request serially.
    """
    charged = any(
        ((stratifiers or {}).get(s)
         or sampling_plan.make_stratifier(s)).pool_kind == "phase1"
        for s in spec.schemes if s != SRS_DRAWS)
    if not charged:
        return None
    stack = engine.stack(tuple(apps))
    cfg = engine.configs[spec.config_index]
    cpi, _ = engine.memo.fill(stack.rows, stack.idx1, stack.idx1_valid,
                              (cfg,),
                              feats=stack.gather_feats(stack.idx1),
                              mesh=mesh)
    return cpi[:, 0, :]


def _scheme_setup(engine: ExperimentEngine, spec: TrialSpec, apps, mesh,
                  stratifiers: Optional[dict] = None):
    """Resolve everything a scheme's chunk program consumes on the host.

    Returns ``(truth, pp, setups)`` — the (A,) census truth at the study
    config, the resolved ``PrecisionPolicy`` and, per scheme, the tuple
    ``(chunk_fn, draws, crit, tables)`` the streaming program binds.

    Shared by ``run_trials`` and the resumable driver
    (``repro.experiments.resumable``) so an interrupted run re-derives
    bitwise-identical program inputs: the stratum tables, value pools
    and critical values are pure functions of the engine build, and the
    memo fills here are the trial path's ONLY charged work (re-running
    them after a restore is a pure cache hit, keeping ledger totals
    path-independent).
    """
    exps = engine.build(apps)
    stack = engine.stack(apps)
    ci = spec.config_index
    cfg = engine.configs[ci]
    l_n = engine.num_strata
    pp = resolve_precision(spec.precision, engine.precision)
    tdt = pp.trace_dtype
    truth = np.stack([e.truth[ci] for e in exps])

    # registry-resolved stratifications: each scheme name becomes a
    # Stratifier whose StratumBank declares its labels, weights and
    # order key — and whose ``pool_kind`` declares the value-pool cost
    # semantics — no per-scheme branches below
    strats = {s: (stratifiers or {}).get(s)
              or sampling_plan.make_stratifier(s)
              for s in spec.schemes if s != SRS_DRAWS}
    banks = {s: strat.resolve(exps) for s, strat in strats.items()}
    charged = {s for s, strat in strats.items()
               if strat.pool_kind == "phase1"}

    # value pools: census CPI (free) and phase-1 CPI (charged once, via
    # the serving-shared helper so request dedup can replay the hit)
    census, _ = stack_ragged([e.census(ci) for e in exps], dtype=tdt)
    p1_pool = charged_pool_fill(engine, spec, apps, mesh, stratifiers)
    if p1_pool is not None:
        p1_pool = p1_pool.astype(tdt)                      # (A, n1_max)

    setups: dict[str, tuple] = {}
    for scheme in spec.schemes:
        if scheme == SRS_DRAWS:
            n = spec.units_per_trial
            dfs = np.full(len(apps), float(n - 1) if n < 30 else np.inf)
            crit = critical_values(spec.confidence, dfs).astype(tdt)
            setups[scheme] = (_srs_chunk, n, crit,
                              (census, stack.n_regions))
            continue
        bank = banks[scheme]
        labels, lv = bank.labels, bank.valid
        weights = bank.weights
        if scheme in charged:                 # phase-1 pool, paid once
            pool = p1_pool
        elif bank.pool is None:               # census-indexed labels
            pool = census
        else:                                 # census values at pool idx
            pool = np.take_along_axis(census, bank.pool, axis=1)
        baseline = bank.baseline.astype(tdt)
        # ONE stratum-summary dispatch serves the collapsed-pairs
        # ordering key AND the gather-table counts
        key, countsf = _stratum_key_counts(baseline, labels, lv, l_n,
                                           precision=pp)
        order, offsets, counts = stratum_tables(labels, lv, l_n,
                                                counts=countsf)
        sorted_vals = np.take_along_axis(pool, order, axis=1)
        # collapsed-pairs CI geometry: occupied strata first, in
        # baseline-CPI key order (static per app)
        key_order = np.argsort(key, axis=1, kind="stable")
        w_sorted = np.take_along_axis(weights, key_order, axis=1)
        n_occ = (counts > 0).sum(axis=1)
        dfs = np.maximum(n_occ - n_occ // 2, 1).astype(np.float64)
        crit = critical_values(spec.confidence, dfs).astype(tdt)
        setups[scheme] = (_stratified_chunk, l_n, crit,
                          (sorted_vals, offsets.astype(np.int32),
                           counts.astype(np.int32), weights.astype(tdt),
                           key_order.astype(np.int32), w_sorted.astype(tdt),
                           n_occ.astype(np.int32)))
    return truth, pp, setups


def run_trials(engine: ExperimentEngine, spec: TrialSpec = TrialSpec(),
               apps: Optional[Sequence[str]] = None,
               mesh=None, stratifiers: Optional[dict] = None) -> TrialResult:
    """Monte-Carlo selection trials, one streaming program per scheme.

    No host-side per-app or per-trial loops: each scheme is one chunked
    ``lax.scan`` dispatch (optionally ``shard_map``-ped over an
    ``("app",)`` or ``("app", "trial")`` mesh) that folds every chunk of
    trials into the additive ``TrialStats`` accumulator — including the
    per-trial CI half-width and its empirical coverage of the census
    truth (see ``TrialResult``). Memory is bounded by one chunk at any
    trial count; results are invariant to the chunking and the mesh.

    ``stratifiers`` optionally maps scheme names to configured
    ``Stratifier`` *instances* (``run_sweep`` passes its plan's), so a
    parameterized plug-in studies the same stratification its sweep
    used; unmapped schemes are built from the registry with defaults.
    """
    apps = tuple(apps or APP_NAMES)
    mesh = engine.mesh if mesh is None else mesh
    if mesh is None:
        ntd = 1
    else:
        from ..distributed.appaxis import app_trial_axes
        _, trial_axis = app_trial_axes(mesh)
        ntd = 1 if trial_axis is None else mesh.shape[trial_axis]
    kb, n_chunks = _chunk_blocks(spec, ntd)
    keep = (spec.keep_trials if spec.keep_trials is not None
            else spec.trials <= _KEEP_TRIALS_MAX)
    app_ids = np.arange(len(apps), dtype=np.int32)
    truth, pp, setups = _scheme_setup(engine, spec, apps, mesh, stratifiers)
    tdt = pp.trace_dtype

    stats: dict[str, sampling_tables.TrialStats] = {}
    estimates: dict[str, np.ndarray] = {}
    errors: dict[str, np.ndarray] = {}
    halves: dict[str, np.ndarray] = {}
    for scheme in spec.schemes:
        chunk_fn, draws, crit, tables = setups[scheme]
        program = _streaming_program(
            chunk_fn, mesh, kb=kb, n_chunks=n_chunks, trials=spec.trials,
            draws=draws, trace=pp.trace, accum=pp.accum, keep=keep)
        with pp.x64_context():
            st, ys = program(trial_key(spec, scheme), np.int32(0), app_ids,
                             truth.astype(tdt), crit, *tables)
            if mesh is None:
                st, ys = _trim_streaming_out((st, ys), len(apps))
        stats[scheme] = jax.tree.map(np.asarray, st)
        if keep:
            # (n_chunks, A, chunk) stacks -> (A, T) trial-major views
            est, err, half = (
                np.asarray(y).transpose(1, 0, 2).reshape(len(apps), -1)
                [:, :spec.trials] for y in ys)
            estimates[scheme] = est
            errors[scheme] = err
            halves[scheme] = half
    return TrialResult(apps=apps, spec=spec, stats=stats,
                       estimates=estimates, errors=errors,
                       half_widths=halves)
