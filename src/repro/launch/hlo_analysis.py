"""Collective-traffic analysis of compiled SPMD HLO.

``collective_bytes`` walks the optimized HLO text of a compiled executable,
sums the bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, and — critically — weights ops inside
``while`` bodies by the loop trip count (XLA canonicalizes counted loops to
``pred = compare(iv, constant(N))``, so N is recoverable from the condition
computation). Without this, a scanned 94-layer model would under-count its
collectives 94x.

Wire-byte convention (ring algorithms, large groups):
    all-gather          result_bytes              (received per device)
    reduce-scatter      operand-equivalent  = result_bytes * group
    all-reduce          2 * result_bytes          (reduce-scatter + gather)
    all-to-all          result_bytes
    collective-permute  result_bytes
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_CALL_RE = re.compile(r"(?:body|to_apply|calls)=([%\w\.\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=([%\w\.\-]+).*?body=([%\w\.\-]+)"
                       r"|while\(.*?\).*?body=([%\w\.\-]+).*?condition=([%\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    count: float = 0.0
    result_bytes: float = 0.0
    wire_bytes: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def _split_computations(txt: str) -> dict[str, str]:
    """computation name -> body text.

    A computation header is a line that ends with "{" and is not an
    instruction ("=" assignments never end a line with "{"); the name is
    the first token (module-level "HloModule"/metadata lines are skipped).
    This survives nested parens in typed signatures, which a paren-matching
    regex does not.
    """
    comps: dict[str, str] = {}
    cur = None
    buf: list[str] = []
    for line in txt.splitlines():
        stripped = line.rstrip()
        is_header = (stripped.endswith("{") and " = " not in line
                     and "(" in line)
        if is_header:
            if cur is not None:
                comps[cur] = "\n".join(buf)
            tok = stripped.split()[0]
            if tok == "ENTRY":
                tok = stripped.split()[1]
            cur = tok.lstrip("%")
            buf = [line]
        elif cur is not None:
            buf.append(line)
            if stripped == "}" or stripped.startswith("} "):
                comps[cur] = "\n".join(buf)
                cur = None
                buf = []
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def _trip_count(cond_body: str) -> float:
    consts = re.findall(r"constant\((\d+)\)", cond_body)
    if consts:
        return float(max(int(c) for c in consts))
    return 1.0


def collective_bytes(hlo_text: str) -> dict[str, CollectiveStats]:
    comps = _split_computations(hlo_text)

    def local_stats(body: str) -> dict[str, CollectiveStats]:
        out: dict[str, CollectiveStats] = defaultdict(CollectiveStats)
        for line in body.splitlines():
            m = _COLL_RE.search(line)
            if not m:
                continue
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            rb = _shape_bytes(dtype, dims)
            gm = _GROUPS_RE.search(line)
            group = int(gm.group(2)) if gm else 1
            if kind == "all-reduce":
                wb = 2.0 * rb
            elif kind == "reduce-scatter":
                wb = float(rb) * max(group, 1)
            else:
                wb = float(rb)
            st = out[kind]
            st.count += 1
            st.result_bytes += rb
            st.wire_bytes += wb
        return out

    def calls_of(body: str) -> list[tuple[str, float]]:
        """(callee, multiplier) pairs in a computation body."""
        out = []
        for line in body.splitlines():
            if " while(" in line:
                mcond = re.search(r"condition=%?([\w\.\-]+)", line)
                mbody = re.search(r"body=%?([\w\.\-]+)", line)
                if mbody:
                    trips = _trip_count(comps.get(
                        mcond.group(1), "")) if mcond else 1.0
                    out.append((mbody.group(1), trips))
            else:
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", line):
                    out.append((m.group(1), 1.0))
        return out

    memo: dict[str, dict[str, CollectiveStats]] = {}

    def total(name: str, depth: int = 0) -> dict[str, CollectiveStats]:
        if name in memo:
            return memo[name]
        body = comps.get(name, "")
        acc = local_stats(body)
        if depth < 32:
            for callee, mult in calls_of(body):
                sub = total(callee, depth + 1)
                for kind, st in sub.items():
                    a = acc[kind]
                    a.count += st.count * mult
                    a.result_bytes += st.result_bytes * mult
                    a.wire_bytes += st.wire_bytes * mult
        memo[name] = acc
        return acc

    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if m:
        entry = m.group(1)
    else:  # fall back: computation with the most text
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""
    return dict(total(entry))


def summarize_collectives(hlo_text: str) -> dict:
    stats = collective_bytes(hlo_text)
    return {
        "per_type": {k: v.as_dict() for k, v in stats.items()},
        "total_wire_bytes": sum(v.wire_bytes for v in stats.values()),
        "total_result_bytes": sum(v.result_bytes for v in stats.values()),
        "total_count": sum(v.count for v in stats.values()),
    }


# ---------------------------------------------------------------------------
# Trip-count-weighted program costs (XLA's cost_analysis() reports loop
# bodies ONCE; a scanned 94-layer model under-counts 94x without this).
# ---------------------------------------------------------------------------

_NAME_SHAPE_RE = re.compile(r"%([\w\.\-]+) = \(?(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"=\s+\(?(\w+)\[([\d,]*)\][^\s]*\s+([\w\-]+)\(")
_DOT_LINE_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+dot\(([^)]*)\)")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# Ops whose operands/outputs genuinely stream HBM on a fusing backend.
# The CPU HLO this analysis reads is LESS fused than a TPU build, so plain
# elementwise chains (convert/add/multiply/...) are excluded — on TPU they
# fuse into their producers; counting them would overstate traffic ~10-40x.
_TRAFFIC_OPS = {
    "dot", "fusion", "reduce", "reduce-window", "copy", "slice",
    "dynamic-slice", "dynamic-update-slice", "scatter", "gather",
    "concatenate", "pad", "sort", "cholesky", "triangular-solve",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _build_shape_map(txt: str) -> dict[str, tuple[str, str]]:
    """instruction name -> (dtype, dims) across the whole module."""
    out: dict[str, tuple[str, str]] = {}
    for m in _NAME_SHAPE_RE.finditer(txt):
        out.setdefault(m.group(1), (m.group(2), m.group(3)))
    return out


def _dot_flops(line: str, shapes: dict) -> float:
    m = _DOT_LINE_RE.search(line)
    if not m:
        return 0.0
    out_dims = [int(d) for d in m.group(2).split(",") if d]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    names = _OPERAND_NAME_RE.findall(m.group(3))
    mc = _CONTRACT_RE.search(line)
    if not names or not mc or names[0] not in shapes:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in shapes[names[0]][1].split(",") if d]
    k = 1
    for i in (int(x) for x in mc.group(1).split(",") if x):
        if i < len(lhs_dims):
            k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _line_bytes(line: str, shapes: dict) -> float:
    """HBM traffic proxy for one instruction: output bytes + operand bytes
    (operands resolved by name); only ops in _TRAFFIC_OPS count.

    dynamic-update-slice is special-cased: with buffer aliasing it writes
    only the update slice (operand 1), not the whole buffer."""
    m = _INSTR_RE.search(line)
    if not m:
        return 0.0
    dtype, dims, op = m.group(1), m.group(2), m.group(3)
    if op not in _TRAFFIC_OPS:
        return 0.0
    paren = line.split("(", 1)
    names = []
    if len(paren) == 2:
        args = paren[1].split(")", 1)[0]
        names = [n for n in _OPERAND_NAME_RE.findall(args) if n in shapes]
    out_b = float(_shape_bytes(dtype, dims))
    if op == "dynamic-update-slice" and len(names) >= 2:
        return 2.0 * _shape_bytes(*shapes[names[1]])
    if op == "fusion" and "dynamic-update-slice" in line:
        # in-place cache update fused with converts/copies: true traffic is
        # the update slice (read + write) plus the small index/update
        # operands — NOT the whole aliased buffer. Count operands smaller
        # than out/4 twice; if none parse, fall back to the output size.
        small = sum(_shape_bytes(*shapes[n]) for n in names
                    if _shape_bytes(*shapes[n]) < out_b / 4)
        return 2.0 * small if small else out_b
    total = out_b
    for name in names:
        total += _shape_bytes(*shapes[name])
    return total


def _convert_only_computations(comps: dict[str, str]) -> set[str]:
    """Fused computations that only dtype-convert (wrapped_convert etc.).

    XLA:CPU cannot run mixed-precision dots, so it materializes f32 copies
    of bf16 weights/caches around every dot — traffic that does NOT exist
    on the TPU target (native bf16 MXU). Excluding these keeps the memory
    term faithful to the hardware being modeled.
    """
    out = set()
    allowed = ("convert(", "parameter(", "bitcast", "copy(",
               "get-tuple-element")
    for name, body in comps.items():
        lines = [l.strip() for l in body.splitlines()[1:-1] if "=" in l]
        if lines and all(any(a in l for a in allowed) for l in lines):
            out.add(name)
    return out


def program_costs(hlo_text: str) -> dict:
    """Trip-count-weighted {flops, bytes} over the entry computation."""
    comps = _split_computations(hlo_text)
    shapes = _build_shape_map(hlo_text)
    convert_only = _convert_only_computations(comps)

    def local(body: str) -> tuple[float, float]:
        fl = by = 0.0
        for line in body.splitlines():
            if " dot(" in line:
                fl += _dot_flops(line, shapes)
            if "fusion(" in line:
                cm = re.search(r"calls=%?([\w\.\-]+)", line)
                if cm and cm.group(1) in convert_only:
                    continue       # CPU-only bf16<->f32 materialization
            by += _line_bytes(line, shapes)
        return fl, by

    def calls_of(body: str) -> list[tuple[str, float]]:
        """Recurse ONLY into while bodies (x trip count) and conditional
        branches: fusion internals execute in registers — the call site's
        operands/output already are their HBM traffic."""
        out = []
        for line in body.splitlines():
            if " while(" in line:
                mcond = re.search(r"condition=%?([\w\.\-]+)", line)
                mbody = re.search(r"body=%?([\w\.\-]+)", line)
                if mbody:
                    trips = _trip_count(comps.get(
                        mcond.group(1), "")) if mcond else 1.0
                    out.append((mbody.group(1), trips))
            elif " conditional(" in line:
                for m in re.finditer(
                        r"(?:branch_computations=\{|true_computation=|"
                        r"false_computation=)%?([\w\.\-]+)", line):
                    out.append((m.group(1), 1.0))
        return out

    # dots inside fused computations still execute on the MXU: count the
    # flops of every computation reachable via calls=..., but bytes only
    # via while recursion (call-site accounting).
    fusion_flops: dict[str, float] = {}

    def dot_flops_of(name: str, depth: int = 0) -> float:
        if name in fusion_flops:
            return fusion_flops[name]
        body = comps.get(name, "")
        fl = sum(_dot_flops(l, shapes) for l in body.splitlines()
                 if " dot(" in l)
        if depth < 16:
            for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)",
                                 body):
                fl += dot_flops_of(m.group(1), depth + 1)
        fusion_flops[name] = fl
        return fl

    memo: dict[str, tuple[float, float]] = {}

    def total(name: str, depth: int = 0) -> tuple[float, float]:
        if name in memo:
            return memo[name]
        body = comps.get(name, "")
        fl, by = local(body)
        # add dot flops hidden inside this computation's fusions
        for line in body.splitlines():
            fm = re.search(r"fusion\(", line)
            if fm:
                cm = re.search(r"calls=%?([\w\.\-]+)", line)
                if cm:
                    fl += dot_flops_of(cm.group(1))
        if depth < 32:
            for callee, mult in calls_of(body):
                sfl, sby = total(callee, depth + 1)
                fl += sfl * mult
                by += sby * mult
        memo[name] = (fl, by)
        return fl, by

    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    entry = m.group(1) if m else (max(comps, key=lambda k: len(comps[k]))
                                  if comps else "")
    fl, by = total(entry)
    return {"flops": fl, "bytes": by}
