import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may touch jax ---------------------------------------
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this records, to ``results/dryrun/<arch>__<shape>__<mesh>.json``:
  * memory_analysis (per-device argument/output/temp bytes — proves fit),
  * cost_analysis flops / bytes accessed,
  * the collective schedule (per-type counts + bytes, trip-count weighted),
  * MODEL_FLOPS (6·N·D, active-N for MoE) for the roofline "useful" ratio.

Usage:
    python -m repro.launch.dryrun                      # full sweep
    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k \
        --mesh single
Existing result files are skipped (incremental; delete to re-run).
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ALL_ARCHS, SHAPE_BY_NAME, cells_for, get_config
from ..launch.hlo_analysis import (program_costs,
                                   summarize_collectives)
from ..launch.mesh import make_production_mesh
from ..train.step import lower_cell

RESULTS_DIR = Path(os.environ.get("REPRO_RESULTS", "results/dryrun"))


def model_flops_per_step(cfg, cell) -> float:
    """6·N_active·D for training; 2·N_active·D for inference forward;
    decode: 2·N_active per token · batch (+ attention cache reads are
    bytes, not flops)."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch  # one token per sequence


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path) -> dict:
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists():
        return json.loads(out_path.read_text())
    cfg = get_config(arch)
    cell = SHAPE_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": [int(mesh.shape[a]) for a in mesh.axis_names],
           "axes": list(mesh.axis_names)}
    try:
        t0 = time.time()
        lowered = lower_cell(cfg, cell, mesh)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis()
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
        hlo_txt = compiled.as_text()
        rec["collectives"] = summarize_collectives(hlo_txt)
        # trip-count-weighted per-device costs (XLA's cost_analysis counts
        # while bodies once; see hlo_analysis.program_costs)
        rec["cost_weighted"] = program_costs(hlo_txt)
        rec["model_flops"] = model_flops_per_step(cfg, cell)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else ALL_ARCHS
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        cells = cells_for(cfg)
        shapes = ([args.shape] if args.shape
                  else [c.name for c in cells])
        for shape_name in shapes:
            for mesh_kind in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_kind, out_dir)
                status = "OK " if rec.get("ok") else "FAIL"
                n_ok += rec.get("ok", False)
                n_fail += not rec.get("ok", False)
                mem = rec.get("memory", {})
                tot = (mem.get("temp_bytes", 0) +
                       mem.get("argument_bytes", 0)) / 2**30
                print(f"[{status}] {arch:24s} {shape_name:12s} {mesh_kind:6s} "
                      f"{round(time.time()-t0,1):6}s mem {tot:6.1f} GB "
                      f"{rec.get('error','')[:90]}",
                      flush=True)
                jax.clear_caches()
    print(f"dry-run sweep done: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
