"""Training launcher: real steps on the host mesh, fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production use supplies ``--mesh production`` (on a real 256-chip pod the
same code path lowers the full config; on this CPU container that is the
dry-run's job). The loop demonstrates the fault-tolerance contract:
deterministic data from (seed, step), atomic checkpoints every
``--ckpt-every`` steps, automatic resume, straggler flagging.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..data.synthetic import make_pipeline
from ..distributed.ctx import activation_sharding
from ..distributed.sharding import param_shardings
from ..models.registry import init_params
from ..optim import AdamW, cosine_with_warmup
from ..runtime.checkpoint import (latest_step, restore_checkpoint,
                                  save_checkpoint)
from ..runtime.health import StepTimer, StragglerDetector
from .mesh import make_host_mesh, make_production_mesh
from ..train.step import make_train_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production", "production-multipod"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mesh == "host":
        mesh = make_host_mesh(args.model_parallel)
    else:
        mesh = make_production_mesh(
            multi_pod=(args.mesh == "production-multipod"))

    opt = AdamW(lr=cosine_with_warmup(args.lr, 10, args.steps))
    train_fn = make_train_fn(cfg, opt, microbatches=args.microbatches)
    pipe = make_pipeline(cfg, args.seq, args.batch, seed=args.seed)

    with mesh, activation_sharding(mesh):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        p_sh = param_shardings(params, mesh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = opt.init(params)

        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            (params, opt_state), extra = restore_checkpoint(
                args.ckpt_dir, (params, opt_state))
            start = int(extra["step"]) + 1
            print(f"resumed from step {start - 1}")

        step_jit = jax.jit(train_fn, donate_argnums=(0, 1))
        timer = StepTimer()
        detector = StragglerDetector()
        for step in range(start, args.steps):
            batch = pipe.batch(step)
            t0 = time.perf_counter()
            params, opt_state, loss = step_jit(params, opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            timer.record(dt)
            flag = " STRAGGLER" if detector.is_straggler(timer.times, dt) \
                else ""
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} {dt*1e3:8.1f} ms"
                      f"{flag}", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step, (params, opt_state),
                                extra={"step": step, "seed": args.seed})
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps - 1,
                            (params, opt_state),
                            extra={"step": args.steps - 1,
                                   "seed": args.seed})
        times = timer.times
        if times.size:
            print(f"mean step {np.mean(times)*1e3:.1f} ms  "
                  f"p50 {np.percentile(times,50)*1e3:.1f}  "
                  f"p95 {np.percentile(times,95)*1e3:.1f}")


if __name__ == "__main__":
    main()
