import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may touch jax ---------------------------------------
"""Performance hillclimbing driver (EXPERIMENTS.md §Perf).

For a chosen (arch × shape) cell, lowers a set of VARIANTS, derives the
three-term roofline from the trip-weighted HLO costs, and logs
hypothesis → change → before → after. Variants:

    baseline        the sweep configuration (results/dryrun)
    serving_params  drop FSDP axes for inference params (prefill/decode)
    mb<K>           gradient-accumulation depth K (train)
    remat_off       no activation checkpointing (train)
    kvchunk<N>      streaming-attention chunk size N

Usage:
    PYTHONPATH=src python -m repro.launch.perf --arch chameleon-34b \
        --shape prefill_32k --variants baseline,serving_params
"""

import argparse
import json
import time
from pathlib import Path

import jax

from ..configs import SHAPE_BY_NAME, get_config
from ..launch.hlo_analysis import program_costs, summarize_collectives
from ..launch.mesh import make_production_mesh
from ..train.step import lower_cell

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def measure(cfg, cell, mesh, *, microbatches=None, serving_params=False,
            kv_chunk=None, bf16_attn=None, fsdp=None, moe_ffshard=False,
            remat=True) -> dict:
    import repro.distributed.sharding as sh_mod
    import repro.models.attention as attn_mod
    old_chunk = attn_mod.KV_CHUNK
    old_bf16 = attn_mod.BF16_ATTENTION_OPERANDS
    old_moe = dict(sh_mod._MOE_3D)
    if kv_chunk:
        attn_mod.KV_CHUNK = kv_chunk
    if bf16_attn is not None:
        attn_mod.BF16_ATTENTION_OPERANDS = bf16_attn
    if moe_ffshard:
        # shard expert d_ff/d_model over the data axes INSTEAD of FSDP:
        # same per-device bytes, but the per-microbatch weight all-gather
        # becomes an activation-sized collective inside the expert einsum.
        sh_mod._MOE_3D = {"w_gate": ("model", None, "__dp__"),
                          "w_up": ("model", None, "__dp__"),
                          "w_down": ("model", "__dp__", None)}
    try:
        t0 = time.time()
        lowered = lower_cell(cfg, cell, mesh, microbatches=microbatches,
                             serving_params=serving_params, fsdp=fsdp)
        compiled = lowered.compile()
        wall = time.time() - t0
        txt = compiled.as_text()
        costs = program_costs(txt)
        colls = summarize_collectives(txt)
        ma = compiled.memory_analysis()
        t_comp = costs["flops"] / PEAK_FLOPS
        t_mem = costs["bytes"] / HBM_BW
        t_coll = colls["total_wire_bytes"] / ICI_BW
        bound = max(t_comp, t_mem, t_coll)
        return {
            "t_compute_ms": t_comp * 1e3,
            "t_memory_ms": t_mem * 1e3,
            "t_collective_ms": t_coll * 1e3,
            "bound_ms": bound * 1e3,
            "dominant": max((t_comp, "compute"), (t_mem, "memory"),
                            (t_coll, "collective"))[1],
            "temp_gb": ma.temp_size_in_bytes / 2**30,
            "arg_gb": ma.argument_size_in_bytes / 2**30,
            "compile_s": round(wall, 1),
            "hlo_flops": costs["flops"],
            "hlo_bytes": costs["bytes"],
            "wire_bytes": colls["total_wire_bytes"],
        }
    finally:
        attn_mod.KV_CHUNK = old_chunk
        attn_mod.BF16_ATTENTION_OPERANDS = old_bf16
        sh_mod._MOE_3D = old_moe


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cell = SHAPE_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    for variant in args.variants.split(","):
        kwargs = {}
        if variant == "baseline":
            pass
        elif variant == "serving_params":
            kwargs["serving_params"] = True
        elif variant.startswith("mb"):
            kwargs["microbatches"] = int(variant[2:])
        elif variant.startswith("kvchunk"):
            kwargs["kv_chunk"] = int(variant[7:])
        elif variant == "f32attn":
            kwargs["bf16_attn"] = False
        elif variant == "bf16attn":
            kwargs["bf16_attn"] = True
        elif variant == "fsdp_on":
            kwargs["fsdp"] = True
        elif variant == "fsdp_off":
            kwargs["fsdp"] = False
        elif variant == "moe_ffshard":
            kwargs["moe_ffshard"] = True
        elif variant.startswith("mbff"):
            kwargs["moe_ffshard"] = True
            kwargs["microbatches"] = int(variant[4:])
        else:
            raise SystemExit(f"unknown variant {variant}")
        rec = measure(cfg, cell, mesh, **kwargs)
        rec.update(arch=args.arch, shape=args.shape, mesh=args.mesh,
                   variant=variant)
        path = out_dir / f"{args.arch}__{args.shape}__{variant}.json"
        path.write_text(json.dumps(rec, indent=1))
        print(f"{args.arch},{args.shape},{variant},"
              f"compute={rec['t_compute_ms']:.1f}ms,"
              f"memory={rec['t_memory_ms']:.1f}ms,"
              f"collective={rec['t_collective_ms']:.1f}ms,"
              f"dominant={rec['dominant']},temp={rec['temp_gb']:.1f}GB",
              flush=True)
        jax.clear_caches()


if __name__ == "__main__":
    main()
