"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — "pod" is
an additional pure-data-parallel axis across the inter-pod DCN/ICI links.

Defined as functions (not module constants) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devs)} — "
            "the dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    # more devices than needed (e.g. 512 host devices, single-pod mesh):
    # build the mesh on a slice.
    grid = np.asarray(devs[:need]).reshape(shape)
    return Mesh(grid, axes)


def make_app_mesh(max_devices: Optional[int] = None, *,
                  devices: Optional[Sequence] = None) -> Mesh:
    """1-D ``("app",)`` mesh for app-sharded sweeps (experiment engine).

    The application axis of a stacked sweep is pure data parallelism:
    lanes never communicate, so any device count works — the engine pads
    the app axis up to it by edge replication. ``devices`` overrides the
    pool (the elastic supervisor passes the surviving subset after a
    simulated host loss); default is every local device.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs) if max_devices is None else max(1, min(max_devices,
                                                         len(devs)))
    return Mesh(np.asarray(devs[:n]), ("app",))


def make_app_trial_mesh(app_devices: int = 1,
                        max_devices: Optional[int] = None, *,
                        devices: Optional[Sequence] = None) -> Mesh:
    """2-D ``("app", "trial")`` mesh for the streaming Monte-Carlo engine.

    ``app_devices`` lanes shard the application axis (pure data
    parallelism, as in ``make_app_mesh``); the remaining devices form the
    trial axis, across which each scan chunk's PRNG blocks split and the
    additive ``TrialStats`` accumulator is ``psum``-merged
    (``repro.distributed.appaxis.make_app_trial_sharded``). Devices that
    do not fill the rectangle are left idle. ``devices`` overrides the
    pool (elastic supervisor's surviving subset).
    """
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs) if max_devices is None else max(1, min(max_devices,
                                                         len(devs)))
    app = max(1, min(app_devices, n))
    trial = n // app
    grid = np.asarray(devs[:app * trial]).reshape(app, trial)
    return Mesh(grid, ("app", "trial"))


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """All pure data-parallel axes of a mesh ("pod" folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    size = 1
    for n in names:
        if n in mesh.axis_names:
            size *= mesh.shape[n]
    return size
